"""Accumulator-precision simulation tests (paper §4.4 / Tables 4–5)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fp16_sim


class TestFp16Accum:
    def test_matches_fp32_for_small_problems(self, key):
        a = jax.random.normal(key, (16, 32)) * 0.5
        b = jax.random.normal(jax.random.fold_in(key, 1), (32, 16)) * 0.5
        c16 = fp16_sim.matmul_fp16_accum(a, b)
        c32 = a @ b
        np.testing.assert_allclose(
            np.asarray(c16, dtype=np.float32), np.asarray(c32), rtol=0.02, atol=0.05)

    def test_accumulator_rounding_visible_at_long_k(self, key):
        # adding many tiny values into a large fp16 accumulator loses them;
        # an fp32 accumulator does not
        k_dim = 4096
        a = jnp.ones((1, k_dim)) * 0.001
        b = jnp.ones((k_dim, 1))
        exact = float((a @ b)[0, 0])
        c16 = float(fp16_sim.matmul_fp16_accum(a, b)[0, 0])
        # the fp16 result is close but visibly quantized
        assert abs(c16 - exact) / exact < 0.05
        assert c16 != exact

    def test_attention_pv_regime_no_accuracy_loss(self, key):
        # the paper's claim: for P ∈ [0,1], V ~ O(1), fp16 accumulation is
        # as accurate as fp32 (Tables 4, 5: identical metrics)
        kp, kv = jax.random.split(key)
        p = jax.nn.softmax(jax.random.normal(kp, (128, 64)) * 3.0, axis=-1)
        p = p / jnp.max(p, axis=-1, keepdims=True)  # P̃ style, max 1
        v = jax.random.normal(kv, (64, 64))
        o16 = fp16_sim.matmul_fp16_accum(p, v).astype(jnp.float32)
        o32 = fp16_sim.matmul_fp32_accum(p, v)
        csim = float(jnp.sum(o16 * o32)
                     / jnp.sqrt(jnp.sum(o16 * o16) * jnp.sum(o32 * o32)))
        assert csim > 0.9999

    def test_batched_shapes(self, key):
        a = jax.random.normal(key, (2, 3, 8, 32))
        b = jax.random.normal(jax.random.fold_in(key, 1), (2, 3, 32, 8))
        c = fp16_sim.matmul_fp16_accum(a, b)
        assert c.shape == (2, 3, 8, 8)

    def test_unaligned_k_dimension(self, key):
        # k not a multiple of the 16-wide mma chunk
        a = jax.random.normal(key, (4, 37))
        b = jax.random.normal(jax.random.fold_in(key, 1), (37, 4))
        c = fp16_sim.matmul_fp16_accum(a, b).astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(c), np.asarray(a @ b), atol=0.1)

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 32), k=st.integers(1, 128), n=st.integers(1, 32),
           seed=st.integers(0, 2**31 - 1))
    def test_property_close_to_fp32(self, m, k, n, seed):
        ka, kb = jax.random.split(jax.random.PRNGKey(seed))
        a = jax.random.normal(ka, (m, k)) * 0.3
        b = jax.random.normal(kb, (k, n)) * 0.3
        c16 = np.asarray(fp16_sim.matmul_fp16_accum(a, b), dtype=np.float32)
        c32 = np.asarray(a @ b)
        scale = max(1e-3, float(np.abs(c32).max()))
        assert np.max(np.abs(c16 - c32)) / scale < 0.05


class TestInt8Matmul:
    def test_exact_within_range(self):
        a = jnp.array([[1, -2], [127, 0]], dtype=jnp.int8)
        b = jnp.array([[3, 4], [-5, 6]], dtype=jnp.int8)
        c = fp16_sim.matmul_int8(a, b)
        assert c.dtype == jnp.int32
        np.testing.assert_array_equal(
            np.asarray(c), np.array([[13, -8], [381, 508]], dtype=np.int32))

    def test_no_overflow_at_max_values(self):
        # 127*127*K must not overflow int32 for realistic K
        k_dim = 128
        a = jnp.full((1, k_dim), 127, dtype=jnp.int8)
        b = jnp.full((k_dim, 1), 127, dtype=jnp.int8)
        c = int(fp16_sim.matmul_int8(a, b)[0, 0])
        assert c == 127 * 127 * k_dim
