"""Pallas kernel vs oracles — the core correctness signal.

Three comparison tiers, all against `ref.attention_ref` (exact fp32):
  1. the straight-line quantized oracle (`sage_attention_ref`)
  2. the Pallas kernel (`sage_attention`) — must agree with (1) tightly
  3. hypothesis sweeps over shapes / causal / variants
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sage_attn, synth


def cos(a, b):
    a = a.reshape(-1)
    b = b.reshape(-1)
    return float(jnp.sum(a * b) / jnp.sqrt(jnp.sum(a * a) * jnp.sum(b * b)))


ALL_VARIANTS = list(ref.VARIANTS.values())


class TestOnlineSoftmaxTiling:
    def test_matches_exact(self, qkv_diffusion):
        q, k, v = qkv_diffusion
        o1 = ref.attention_ref(q, k, v)
        o2 = ref.attention_online_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)

    def test_matches_exact_causal_unaligned(self, key):
        q, k, v = synth.make_qkv(key, (1, 2, 193, 64), synth.LLAMA_LIKE)
        o1 = ref.attention_ref(q, k, v, causal=True)
        o2 = ref.attention_online_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


class TestSageOracle:
    @pytest.mark.parametrize("variant", ALL_VARIANTS, ids=lambda v: v.name)
    def test_tracks_exact_on_outlier_data(self, qkv_diffusion, variant):
        q, k, v = qkv_diffusion
        gold = ref.attention_ref(q, k, v)
        o = ref.sage_attention_ref(q, k, v, variant)
        min_cos = 0.999 if variant.pv_dtype == "fp16" else 0.99
        assert cos(gold, o) > min_cos

    def test_smoothing_required_on_outlier_data(self, qkv_diffusion):
        q, k, v = qkv_diffusion
        gold = ref.attention_ref(q, k, v)
        with_sm = ref.sage_attention_ref(q, k, v, ref.SAGE_ATTN_T, do_smooth_k=True)
        without = ref.sage_attention_ref(q, k, v, ref.SAGE_ATTN_T, do_smooth_k=False)
        assert cos(gold, with_sm) > cos(gold, without)

    def test_llama_data_tolerates_no_smoothing(self, qkv_llama):
        # §A.6: Llama-like distributions are benign
        q, k, v = qkv_llama
        gold = ref.attention_ref(q, k, v)
        without = ref.sage_attention_ref(q, k, v, ref.SAGE_ATTN_T, do_smooth_k=False)
        assert cos(gold, without) > 0.999


class TestPallasKernel:
    @pytest.mark.parametrize("variant", ALL_VARIANTS, ids=lambda v: v.name)
    def test_matches_oracle(self, qkv_diffusion, variant):
        q, k, v = qkv_diffusion
        o_oracle = ref.sage_attention_ref(q, k, v, variant)
        o_pallas = sage_attn.sage_attention(q, k, v, variant)
        # same quantized inputs, same math; differences come from the
        # online-softmax reassociation (fp16 path) plus P̃ being quantized
        # against the *running* row max instead of the global one (int8 PV)
        assert cos(o_oracle, o_pallas) > 0.9995
        atol = 2e-2 if variant.pv_dtype == "fp16" else \
            0.05 * float(jnp.max(jnp.abs(v)))
        np.testing.assert_allclose(
            np.asarray(o_oracle), np.asarray(o_pallas), atol=atol)

    @pytest.mark.parametrize("variant", ALL_VARIANTS, ids=lambda v: v.name)
    def test_causal(self, qkv_diffusion, variant):
        q, k, v = qkv_diffusion
        gold = ref.attention_ref(q, k, v, causal=True)
        o = sage_attn.sage_attention(q, k, v, variant, causal=True)
        assert cos(gold, o) > 0.99

    def test_unaligned_lengths_padded_correctly(self, key):
        # N not a multiple of the block sizes exercises the padding path
        q, k, v = synth.make_qkv(key, (1, 2, 201, 64), synth.DIFFUSION_LIKE)
        gold = ref.attention_ref(q, k, v)
        o = sage_attn.sage_attention(q, k, v, "SageAttn-B")
        assert cos(gold, o) > 0.999

    def test_cross_attention_shapes(self, key):
        # n_q != n_kv (encoder-decoder style)
        kq, kk = jax.random.split(key)
        q, _, _ = synth.make_qkv(kq, (1, 2, 64, 64), synth.LLAMA_LIKE)
        _, k, v = synth.make_qkv(kk, (1, 2, 192, 64), synth.LLAMA_LIKE)
        gold = ref.attention_ref(q, k, v)
        o = sage_attn.sage_attention(q, k, v, "SageAttn-T")
        assert cos(gold, o) > 0.999

    def test_output_finite_on_extreme_inputs(self, key):
        q, k, v = synth.make_qkv(
            key, (1, 1, 128, 64), synth.DIFFUSION_LIKE._replace(k_bias_scale=100.0))
        o = sage_attn.sage_attention(q, k, v, "SageAttn-B")
        assert bool(jnp.all(jnp.isfinite(o)))

    def test_custom_block_sizes(self, key):
        q, k, v = synth.make_qkv(key, (1, 2, 256, 64), synth.DIFFUSION_LIKE)
        gold = ref.attention_ref(q, k, v)
        o = sage_attn.sage_attention(q, k, v, "SageAttn-B", block_q=64, block_kv=32)
        assert cos(gold, o) > 0.999

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 2),
        h=st.integers(1, 3),
        n=st.integers(16, 300),
        d=st.sampled_from([32, 64, 128]),
        causal=st.booleans(),
        variant=st.sampled_from(ALL_VARIANTS),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_sweep(self, b, h, n, d, causal, variant, seed):
        q, k, v = synth.make_qkv(
            jax.random.PRNGKey(seed), (b, h, n, d), synth.VIT_LIKE)
        gold = ref.attention_ref(q, k, v, causal=causal)
        o = sage_attn.sage_attention(q, k, v, variant, causal=causal)
        assert o.shape == gold.shape
        assert bool(jnp.all(jnp.isfinite(o)))
        assert cos(gold, o) > 0.98, (b, h, n, d, causal, variant.name)
