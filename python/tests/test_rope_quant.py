"""Fused RoPE+quantization kernel tests (paper §4.6 fusion trick)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref, rope_quant, sage_attn, synth


class TestRopeTables:
    def test_rotation_preserves_norm(self, key):
        x = jax.random.normal(key, (1, 1, 32, 64))
        cos, sin = rope_quant.rope_tables(32, 64)
        r = rope_quant.apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            np.asarray(jnp.linalg.norm(r, axis=-1)),
            np.asarray(jnp.linalg.norm(x, axis=-1)),
            rtol=1e-5)

    def test_position_zero_is_identity(self, key):
        x = jax.random.normal(key, (1, 1, 1, 16))
        cos, sin = rope_quant.rope_tables(1, 16)
        r = rope_quant.apply_rope(x, cos, sin)
        np.testing.assert_allclose(np.asarray(r), np.asarray(x), atol=1e-6)

    def test_relative_position_property(self, key):
        # <rope(q, m), rope(k, n)> depends only on m - n
        d = 32
        kq, kk = jax.random.split(key)
        q = jax.random.normal(kq, (1, 1, 1, d))
        k = jax.random.normal(kk, (1, 1, 1, d))
        def dot_at(m, n):
            cq = rope_quant.rope_tables(1, d, offset=m)
            ck = rope_quant.rope_tables(1, d, offset=n)
            rq = rope_quant.apply_rope(q, *cq)
            rk = rope_quant.apply_rope(k, *ck)
            return float(jnp.sum(rq * rk))
        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4

    def test_offset_continuation(self):
        cos_full, sin_full = rope_quant.rope_tables(64, 32)
        cos_tail, sin_tail = rope_quant.rope_tables(16, 32, offset=48)
        np.testing.assert_allclose(np.asarray(cos_full[48:]), np.asarray(cos_tail), atol=1e-6)
        np.testing.assert_allclose(np.asarray(sin_full[48:]), np.asarray(sin_tail), atol=1e-6)


class TestFusedKernel:
    def test_matches_unfused_path_exactly(self, key):
        q, k, _ = synth.make_qkv(key, (2, 2, 150, 64), synth.DIFFUSION_LIKE)
        cos, sin = rope_quant.rope_tables(150, 64)
        qr = rope_quant.apply_rope(q, cos, sin)
        kr = rope_quant.apply_rope(k, cos, sin)
        (qq_f, qs_f), (kq_f, ks_f) = rope_quant.rope_quantize_qk(q, k)
        (qq, qs), (kq, ks) = quant.quantize_qk(qr, kr, granularity="token")
        np.testing.assert_array_equal(np.asarray(qq_f), np.asarray(qq))
        np.testing.assert_array_equal(np.asarray(kq_f), np.asarray(kq))
        np.testing.assert_allclose(np.asarray(qs_f), np.asarray(qs), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(ks_f), np.asarray(ks), rtol=1e-5)

    def test_end_to_end_through_attention(self, key):
        q, k, v = synth.make_qkv(key, (1, 2, 128, 64), synth.DIFFUSION_LIKE)
        cos, sin = rope_quant.rope_tables(128, 64)
        qr = rope_quant.apply_rope(q, cos, sin)
        kr = rope_quant.apply_rope(k, cos, sin)
        gold = ref.attention_ref(qr, kr, v)
        (qq, qs), (kq, ks) = rope_quant.rope_quantize_qk(q, k)
        o = sage_attn.sage_attention_quantized(
            qq, qs, kq, ks, v.astype(jnp.float16), None, pv_int8=False)
        c = float(jnp.sum(o * gold) / jnp.sqrt(jnp.sum(o * o) * jnp.sum(gold * gold)))
        # RoPE's rotation mixes channels position-by-position, so the
        # post-RoPE K bias is no longer perfectly token-constant and
        # smooth-K removes slightly less of it than in the un-roped case
        assert c > 0.995

    def test_no_smooth_mode(self, key):
        q, k, _ = synth.make_qkv(key, (1, 1, 64, 32), synth.LLAMA_LIKE)
        (_, _), (kq, ks) = rope_quant.rope_quantize_qk(q, k, do_smooth_k=False)
        cos, sin = rope_quant.rope_tables(64, 32)
        kr = rope_quant.apply_rope(k, cos, sin)
        deq = kq.astype(jnp.float32) * ks
        np.testing.assert_allclose(
            np.asarray(deq), np.asarray(kr),
            atol=float(jnp.max(jnp.abs(kr))) / 100)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(8, 200), d=st.sampled_from([32, 64, 128]),
           seed=st.integers(0, 2**31 - 1))
    def test_property_fused_equals_unfused(self, n, d, seed):
        key = jax.random.PRNGKey(seed)
        q, k, _ = synth.make_qkv(key, (1, 2, n, d), synth.VIT_LIKE)
        cos, sin = rope_quant.rope_tables(n, d)
        qr = rope_quant.apply_rope(q, cos, sin)
        kr = rope_quant.apply_rope(k, cos, sin)
        (qq_f, _), (kq_f, _) = rope_quant.rope_quantize_qk(q, k)
        (qq, _), (kq, _) = quant.quantize_qk(qr, kr, granularity="token")
        # int8 payloads may differ by 1 ulp from fp reassociation; bound it
        dq = np.abs(np.asarray(qq_f, np.int32) - np.asarray(qq, np.int32))
        dk = np.abs(np.asarray(kq_f, np.int32) - np.asarray(kq, np.int32))
        assert dq.max() <= 1 and dk.max() <= 1
        assert (dq > 0).mean() < 0.01 and (dk > 0).mean() < 0.01
