"""Shared pytest fixtures: deterministic keys and synthetic QKV factories."""

import jax
import pytest

from compile.kernels import synth


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def qkv_diffusion(key):
    """Hostile Figure-4 distribution: channel-bias outliers in K."""
    return synth.make_qkv(key, (2, 3, 256, 64), synth.DIFFUSION_LIKE)


@pytest.fixture
def qkv_llama(key):
    return synth.make_qkv(key, (2, 3, 256, 64), synth.LLAMA_LIKE)
