"""Quantizer unit + property tests (hypothesis sweeps shapes/dtypes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant


def _rand(key, shape, scale=3.0):
    return jax.random.normal(key, shape) * scale


class TestInt8Quantizers:
    def test_per_token_roundtrip_bound(self, key):
        x = _rand(key, (37, 64))
        q = quant.quant_int8_per_token(x)
        deq = quant.dequant(q)
        # error per element bounded by half a quantization step of its row
        err = jnp.abs(x - deq)
        bound = 0.5 * q.scale + 1e-6
        assert bool(jnp.all(err <= bound))

    def test_per_token_scale_shape(self, key):
        q = quant.quant_int8_per_token(_rand(key, (2, 3, 17, 8)))
        assert q.scale.shape == (2, 3, 17, 1)
        assert q.q.dtype == jnp.int8

    def test_per_channel_scale_shape(self, key):
        q = quant.quant_int8_per_channel(_rand(key, (2, 3, 17, 8)))
        assert q.scale.shape == (2, 3, 1, 8)

    def test_per_tensor_single_scale(self, key):
        q = quant.quant_int8_per_tensor(_rand(key, (5, 6)))
        assert q.scale.size == 1

    def test_per_block_scales_block_constant(self, key):
        x = _rand(key, (100, 16))
        q = quant.quant_int8_per_block(x, block=32)
        s = np.asarray(q.scale)[:, 0]
        for r in range(100):
            assert s[r] == s[(r // 32) * 32]

    def test_per_block_equals_per_token_when_block_1(self, key):
        x = _rand(key, (13, 8))
        qb = quant.quant_int8_per_block(x, block=1)
        qt = quant.quant_int8_per_token(x)
        np.testing.assert_array_equal(np.asarray(qb.q), np.asarray(qt.q))
        np.testing.assert_allclose(np.asarray(qb.scale), np.asarray(qt.scale), rtol=1e-6)

    def test_int8_payload_range(self, key):
        q = quant.quant_int8_per_token(_rand(key, (64, 64), scale=100.0))
        assert int(jnp.max(jnp.abs(q.q.astype(jnp.int32)))) <= 127

    def test_zero_input_safe(self):
        q = quant.quant_int8_per_token(jnp.zeros((4, 4)))
        assert bool(jnp.all(q.q == 0))
        assert bool(jnp.all(jnp.isfinite(quant.dequant(q))))

    @settings(max_examples=25, deadline=None)
    @given(rows=st.integers(1, 65), cols=st.sampled_from([8, 16, 64, 128]),
           block=st.sampled_from([1, 16, 128]), seed=st.integers(0, 2**31 - 1))
    def test_property_roundtrip_all_granularities(self, rows, cols, block, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * 5.0
        for fn in (quant.quant_int8_per_token,
                   quant.quant_int8_per_tensor,
                   quant.quant_int8_per_channel,
                   lambda a: quant.quant_int8_per_block(a, block)):
            deq = quant.dequant(fn(x))
            # dequantization must stay within one step of the max magnitude
            assert float(jnp.max(jnp.abs(x - deq))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-5


class TestFp8:
    def test_e4m3_exact_on_grid(self):
        for v in [1.0, 1.125, 448.0, -3.5, 0.015625]:
            q = quant.quant_fp8_per_tensor(jnp.array([[abs(v), -448.0]]), "e4m3")
            deq = quant.dequant(q)
            # 448 scale maps grid values onto themselves
            assert np.isfinite(np.asarray(deq)).all()

    def test_fp8_formats_distinct_precision(self, key):
        x = _rand(key, (64, 64), scale=1.0)
        d43 = quant.dequant(quant.quant_fp8_per_token(x, "e4m3"))
        d52 = quant.dequant(quant.quant_fp8_per_token(x, "e5m2"))
        e43 = float(jnp.mean(jnp.abs(x - d43)))
        e52 = float(jnp.mean(jnp.abs(x - d52)))
        assert e43 < e52  # e4m3 has one more mantissa bit

    def test_int8_beats_fp8_for_qk_style_data(self, key):
        # Table 17's ordering: INT8 > E4M3 > E5M2 for per-token quantization
        x = _rand(key, (128, 64), scale=2.0)
        e_int8 = float(jnp.mean(jnp.abs(x - quant.fake_quant(x, "int8_token"))))
        e_e4m3 = float(jnp.mean(jnp.abs(x - quant.fake_quant(x, "e4m3"))))
        e_e5m2 = float(jnp.mean(jnp.abs(x - quant.fake_quant(x, "e5m2"))))
        assert e_int8 < e_e4m3 < e_e5m2


class TestSmoothK:
    def test_removes_channel_mean(self, qkv_diffusion):
        _, k, _ = qkv_diffusion
        sm = quant.smooth_k(k)
        mean = jnp.mean(sm, axis=-2)
        assert float(jnp.max(jnp.abs(mean))) < 1e-4

    def test_attention_scores_invariant(self, key):
        # softmax(q (K - mean)ᵀ) == softmax(q Kᵀ) (paper §4.2)
        kq, kk = jax.random.split(key)
        q = _rand(kq, (1, 1, 32, 16))
        k = _rand(kk, (1, 1, 32, 16)) + 7.0
        s1 = jax.nn.softmax(q @ jnp.swapaxes(k, -1, -2), axis=-1)
        s2 = jax.nn.softmax(q @ jnp.swapaxes(quant.smooth_k(k), -1, -2), axis=-1)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)

    def test_smoothing_shrinks_quant_error(self, qkv_diffusion):
        _, k, _ = qkv_diffusion
        raw_err = float(jnp.mean(jnp.abs(k - quant.fake_quant(k, "int8_token"))))
        sm = quant.smooth_k(k)
        sm_err = float(jnp.mean(jnp.abs(sm - quant.fake_quant(sm, "int8_token"))))
        assert sm_err < 0.3 * raw_err

    def test_quantize_qk_folds_sqrt_d(self, key):
        kq, kk = jax.random.split(key)
        q = _rand(kq, (1, 1, 16, 64))
        k = _rand(kk, (1, 1, 16, 64))
        (qq, qs), _ = quant.quantize_qk(q, k, granularity="token")
        deq = qq.astype(jnp.float32) * qs
        np.testing.assert_allclose(
            np.asarray(deq), np.asarray(q / jnp.sqrt(64.0)), atol=0.05)
