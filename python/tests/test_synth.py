"""Synthetic distribution generator tests: the Figure-4 structure must
actually hold, since every accuracy table rests on it."""

import jax
import jax.numpy as jnp

from compile.kernels import quant, synth


class TestProfiles:
    def test_k_channel_bias_dominates_in_diffusion_profile(self, key):
        _, k, _ = synth.make_qkv(key, (1, 1, 512, 64), synth.DIFFUSION_LIKE)
        mean = jnp.mean(k, axis=-2)       # (1, 1, 64) per-channel bias
        resid = k - mean[..., None, :]
        ratio = float(jnp.mean(jnp.abs(mean))) / float(jnp.std(resid))
        assert ratio > 3.0, f"bias/signal ratio {ratio}"

    def test_llama_profile_is_benign(self, key):
        _, k, _ = synth.make_qkv(key, (1, 1, 512, 64), synth.LLAMA_LIKE)
        mean = jnp.mean(k, axis=-2)
        resid = k - mean[..., None, :]
        ratio = float(jnp.mean(jnp.abs(mean))) / float(jnp.std(resid))
        assert ratio < 3.0

    def test_v_has_channel_structure(self, key):
        _, _, v = synth.make_qkv(key, (1, 1, 512, 64), synth.DIFFUSION_LIKE)
        chan_std = jnp.std(v, axis=-2)[0, 0]     # (64,)
        spread = float(jnp.max(chan_std) / jnp.min(chan_std))
        assert spread > 3.0, f"V channel spread {spread}"

    def test_quant_error_ordering_matches_figure3(self, key):
        """Unsmoothed per-token INT8 K-quantization must drown the useful
        (token-varying) signal on the diffusion profile but not on the
        llama profile — the distributional fact behind Figure 3 / Table 18.

        The right denominator is the *centered* signal: the shared channel
        bias cancels inside softmax, so what matters is quantization noise
        (whose step scales with the large biased magnitudes) relative to
        the small residual that actually carries attention information.
        """
        def signal_to_noise(profile):
            _, k, _ = synth.make_qkv(key, (1, 1, 256, 64), profile)
            noise = k - quant.fake_quant(k, "int8_token")
            signal = k - jnp.mean(k, axis=-2, keepdims=True)
            return float(jnp.std(signal) / jnp.std(noise))
        assert signal_to_noise(synth.LLAMA_LIKE) > 3.0 * signal_to_noise(
            synth.DIFFUSION_LIKE)

    def test_layer_sweep_increasing_severity(self, key):
        shapes = []
        errs = []
        for _, (q, k, v) in synth.layer_sweep(key, 6, (1, 1, 128, 64)):
            deq = quant.fake_quant(k, "int8_token")
            errs.append(float(jnp.mean(jnp.abs(k - deq))))
            shapes.append(k.shape)
        assert all(s == (1, 1, 128, 64) for s in shapes)
        # later layers (stronger outliers) quantize worse on average
        assert sum(errs[3:]) > sum(errs[:3])

    def test_deterministic(self, key):
        a = synth.make_qkv(key, (1, 1, 16, 16), synth.VIT_LIKE)
        b = synth.make_qkv(key, (1, 1, 16, 16), synth.VIT_LIKE)
        for x, y in zip(a, b):
            assert bool(jnp.all(x == y))
