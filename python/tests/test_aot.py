"""AOT pipeline contract tests: lowering to HLO text and manifest shape
consistency. These run the tiny config only (fast); the full artifact set
is exercised by the rust integration tests."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M
from compile.configs import TINY


class TestHloText:
    def test_simple_fn_lowers_to_parseable_hlo(self):
        def fn(x):
            return (x * 2.0 + 1.0,)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_pallas_kernel_lowers(self):
        from compile.kernels import sage_attn

        def fn(q, k, v):
            return (sage_attn.sage_attention(q, k, v, "SageAttn-B"),)

        spec = jax.ShapeDtypeStruct((1, 1, 128, 64), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec, spec))
        assert "HloModule" in text
        # interpret-mode pallas must not leave custom-calls the CPU
        # runtime cannot execute
        assert "mosaic" not in text.lower()


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--tiny-only"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    return out


class TestManifest:
    def test_manifest_entries_complete(self, tiny_artifacts):
        with open(tiny_artifacts / "manifest.json") as f:
            manifest = json.load(f)
        entries = manifest["entries"]
        assert "tiny_train_step" in entries
        assert "tiny_decode_step_sage" in entries
        for name, e in entries.items():
            assert (tiny_artifacts / e["file"]).exists(), name
            assert e["inputs"] and e["outputs"], name

    def test_param_spec_roundtrip(self, tiny_artifacts):
        with open(tiny_artifacts / "manifest.json") as f:
            manifest = json.load(f)
        spec = manifest["configs"]["tiny"]["param_spec"]
        expected = M.param_spec(TINY)
        assert len(spec) == len(expected)
        for j, (name, shape, std) in zip(spec, expected):
            assert j["name"] == name
            assert tuple(j["shape"]) == tuple(shape)
            assert abs(j["init_std"] - std) < 1e-9

    def test_train_step_io_arity(self, tiny_artifacts):
        with open(tiny_artifacts / "manifest.json") as f:
            manifest = json.load(f)
        e = manifest["entries"]["tiny_train_step"]
        n_p = len(manifest["configs"]["tiny"]["param_spec"])
        # inputs: params + m + v + step + tokens
        assert len(e["inputs"]) == 3 * n_p + 2
        # outputs: loss + step + params' + m' + v'
        assert len(e["outputs"]) == 3 * n_p + 2

    def test_decode_step_positions_are_vectors(self, tiny_artifacts):
        with open(tiny_artifacts / "manifest.json") as f:
            manifest = json.load(f)
        e = manifest["entries"]["tiny_decode_step_sage"]
        batch = e["batch"]
        # last two inputs: token (B,), pos (B,)
        assert e["inputs"][-1]["shape"] == [batch]
        assert e["inputs"][-2]["shape"] == [batch]
