"""L2 transformer tests: shapes, training signal, prefill/decode parity,
and the plug-and-play property (swapping attention impls barely moves
outputs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import TINY

FP_PLAN = ["exact"] * TINY.n_layers
SAGE_PLAN = ["SageAttn-B"] * TINY.n_layers


@pytest.fixture(scope="module")
def params():
    return M.init_params(TINY, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, TINY.vocab)


class TestForward:
    def test_logit_shape(self, params, tokens):
        logits = M.forward(TINY, params, tokens, FP_PLAN)
        assert logits.shape == (2, 32, TINY.vocab)

    def test_causality(self, params, tokens):
        # perturbing a late token must not change earlier logits
        logits1 = M.forward(TINY, params, tokens, FP_PLAN)
        t2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % TINY.vocab)
        logits2 = M.forward(TINY, params, t2, FP_PLAN)
        np.testing.assert_allclose(
            np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]), atol=1e-5)

    def test_plug_and_play_sage_attention(self, params, tokens):
        # the paper's core claim at the model level: swapping in quantized
        # attention changes outputs only marginally
        lf = M.forward(TINY, params, tokens, FP_PLAN)
        ls = M.forward(TINY, params, tokens, SAGE_PLAN)
        cs = float(jnp.sum(lf * ls) / jnp.sqrt(jnp.sum(lf * lf) * jnp.sum(ls * ls)))
        assert cs > 0.999

    def test_mixed_adaptive_plan(self, params, tokens):
        plan = ["SageAttn-vB", "SageAttn-B"]
        logits = M.forward(TINY, params, tokens, plan)
        assert bool(jnp.all(jnp.isfinite(logits)))


class TestTraining:
    def test_loss_finite_and_near_uniform_at_init(self, params, tokens):
        loss = M.loss_fn(TINY, params, tokens, FP_PLAN)
        assert bool(jnp.isfinite(loss))
        assert abs(float(loss) - jnp.log(TINY.vocab)) < 1.0

    def test_train_step_descends(self, params):
        # a few steps on a repeating batch must reduce loss
        flat = M.params_to_list(TINY, params)
        m = [jnp.zeros_like(p) for p in flat]
        v = [jnp.zeros_like(p) for p in flat]
        tokens = jax.random.randint(jax.random.PRNGKey(3), (2, TINY.max_seq), 0,
                                    TINY.vocab)
        step = jnp.int32(0)
        fn = jax.jit(lambda *a: M.train_step(TINY, FP_PLAN, a[:len(flat)],
                                             a[len(flat):2 * len(flat)],
                                             a[2 * len(flat):3 * len(flat)],
                                             a[-2], a[-1], lr=1e-3))
        first = None
        for _ in range(5):
            out = fn(*flat, *m, *v, step, tokens)
            loss, step = out[0], out[1]
            n = len(flat)
            flat = list(out[2:2 + n])
            m = list(out[2 + n:2 + 2 * n])
            v = list(out[2 + 2 * n:2 + 3 * n])
            if first is None:
                first = float(loss)
        assert float(loss) < first - 0.05, (first, float(loss))

    def test_param_spec_matches_init(self, params):
        spec = M.param_spec(TINY)
        assert set(p[0] for p in spec) == set(params)
        for name, shape, _ in spec:
            assert params[name].shape == tuple(shape)


class TestServing:
    def test_prefill_then_decode_matches_forward(self, params):
        """Greedy decode via prefill+decode_step must agree with running
        the full forward on the concatenated sequence."""
        flat = M.params_to_list(TINY, params)
        prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0, TINY.vocab)
        logits0, kc, vc = M.prefill(TINY, FP_PLAN, flat, prompt)
        tok = jnp.argmax(logits0, axis=-1).astype(jnp.int32)
        seq = [int(prompt[0, i]) for i in range(8)] + [int(tok[0])]
        # two more steps
        pos = jnp.array([8], jnp.int32)
        for _ in range(2):
            logits, kc, vc = M.decode_step(TINY, FP_PLAN, flat, kc, vc, tok, pos)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            seq.append(int(tok[0]))
            pos = pos + 1
        # reference: teacher-forced full forward over seq[:-1]
        full = jnp.array([seq[:-1]], jnp.int32)
        ref_logits = M.forward(TINY, params, full, FP_PLAN)
        ref_next = int(jnp.argmax(ref_logits[0, -1]))
        assert ref_next == seq[-1]

    def test_decode_step_slots_independent(self, params):
        """Continuous batching: a token fed to slot 0 must not affect
        slot 1's logits."""
        flat = M.params_to_list(TINY, params)
        b = 2
        kv_shape = (TINY.n_layers, b, TINY.n_heads, TINY.max_seq, TINY.d_head)
        kc = jnp.zeros(kv_shape)
        vc = jnp.zeros(kv_shape)
        tok = jnp.array([5, 9], jnp.int32)
        pos = jnp.array([0, 3], jnp.int32)
        l1, _, _ = M.decode_step(TINY, FP_PLAN, flat, kc, vc, tok, pos)
        tok2 = jnp.array([6, 9], jnp.int32)  # only slot 0 changed
        l2, _, _ = M.decode_step(TINY, FP_PLAN, flat, kc, vc, tok2, pos)
        np.testing.assert_allclose(np.asarray(l1[1]), np.asarray(l2[1]), atol=1e-5)
        assert float(jnp.max(jnp.abs(l1[0] - l2[0]))) > 1e-3

    def test_decode_scatter_writes_correct_position(self, params):
        flat = M.params_to_list(TINY, params)
        b = 2
        kv_shape = (TINY.n_layers, b, TINY.n_heads, TINY.max_seq, TINY.d_head)
        kc = jnp.zeros(kv_shape)
        vc = jnp.zeros(kv_shape)
        tok = jnp.array([1, 2], jnp.int32)
        pos = jnp.array([0, 5], jnp.int32)
        _, kc2, _ = M.decode_step(TINY, FP_PLAN, flat, kc, vc, tok, pos)
        kc2 = np.asarray(kc2)
        # slot 0 wrote position 0 only; slot 1 wrote position 5 only
        assert np.abs(kc2[0, 0, :, 0]).max() > 0
        assert np.abs(kc2[0, 0, :, 1:]).max() == 0
        assert np.abs(kc2[0, 1, :, 5]).max() > 0
        assert np.abs(kc2[0, 1, :, :5]).max() == 0

    def test_sage_decode_close_to_fp_decode(self, params):
        flat = M.params_to_list(TINY, params)
        prompt = jax.random.randint(jax.random.PRNGKey(7), (1, 16), 0, TINY.vocab)
        lf, kcf, vcf = M.prefill(TINY, FP_PLAN, flat, prompt)
        ls, kcs, vcs = M.prefill(TINY, SAGE_PLAN, flat, prompt)
        cs = float(jnp.sum(lf * ls) / jnp.sqrt(jnp.sum(lf * lf) * jnp.sum(ls * ls)))
        assert cs > 0.995
        tok = jnp.argmax(lf, axis=-1).astype(jnp.int32)
        pos = jnp.array([16], jnp.int32)
        df, _, _ = M.decode_step(TINY, FP_PLAN, flat, kcf, vcf, tok, pos)
        ds, _, _ = M.decode_step(TINY, SAGE_PLAN, flat, kcs, vcs, tok, pos)
        cs2 = float(jnp.sum(df * ds) / jnp.sqrt(jnp.sum(df * df) * jnp.sum(ds * ds)))
        assert cs2 > 0.99
