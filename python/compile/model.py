"""L2: GPT-style transformer whose attention is SageAttention (build-time JAX).

Pure-jnp (no flax/optax) so everything lowers into a single clean HLO
module for the rust runtime. The attention implementation is selectable
per layer — "exact" (fp32 reference), or any Table-6 variant — which is
what the adaptive-quantization plan (§4.5) toggles.

Artifacts lowered from this module (see aot.py):
  * ``train_step``  — fused AdamW + loss for the E2E training driver
  * ``eval_loss``   — next-token loss for perplexity evaluation
  * ``prefill``     — logits + dense KV caches for serving
  * ``decode_step`` — single-token incremental decode against the caches

Decode-time attention uses the straight-line quantized path (q_len = 1 is
a GEMV — the paper's tiled kernel targets the prefill/training shapes);
the KV cache is re-smoothed and re-quantized against the *valid* prefix
each step, with dynamic-length masking.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import quant, ref, sage_attn
from .kernels.rope_quant import apply_rope, rope_tables

Params = Dict[str, Any]

ATTN_IMPLS = ("exact",) + tuple(ref.VARIANTS)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], float]]:
    """Flat (name, shape, init_std) list — the manifest contract with rust.

    Rust initializes parameters itself from this spec (normal(0, std), or
    ones for std < 0 which marks norm gains), so no weights cross the
    python/rust boundary.
    """
    d, h, dh, f = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff
    spec: List[Tuple[str, Tuple[int, ...], float]] = [
        ("embed", (cfg.vocab, d), 0.02),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (d,), -1.0),
            (p + "wq", (d, h * dh), 0.02),
            (p + "wk", (d, h * dh), 0.02),
            (p + "wv", (d, h * dh), 0.02),
            (p + "wo", (h * dh, d), 0.02 / (2 * cfg.n_layers) ** 0.5),
            (p + "ln2", (d,), -1.0),
            (p + "w_gate", (d, f), 0.02),
            (p + "w_up", (d, f), 0.02),
            (p + "w_down", (f, d), 0.02 / (2 * cfg.n_layers) ** 0.5),
        ]
    spec += [("ln_f", (d,), -1.0), ("unembed", (d, cfg.vocab), 0.02)]
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    params = {}
    for name, shape, std in param_spec(cfg):
        if std < 0:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            params[name] = jax.random.normal(sub, shape) * std
    return params


def params_to_list(cfg: ModelConfig, params: Params) -> List[jax.Array]:
    return [params[name] for name, _, _ in param_spec(cfg)]


def params_from_list(cfg: ModelConfig, flat: Sequence[jax.Array]) -> Params:
    return {name: arr for (name, _, _), arr in zip(param_spec(cfg), flat)}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def _split_heads(x: jax.Array, n_heads: int, d_head: int) -> jax.Array:
    b, n, _ = x.shape
    return x.reshape(b, n, n_heads, d_head).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def _attention(q: jax.Array, k: jax.Array, v: jax.Array, impl: str,
               *, causal: bool, interpret: bool = True) -> jax.Array:
    """Dispatch on the per-layer attention implementation."""
    if impl == "exact":
        return ref.attention_ref(q, k, v, causal=causal)
    return sage_attn.sage_attention(q, k, v, ref.VARIANTS[impl],
                                    causal=causal, interpret=interpret)


def _decode_attention(q1: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                      n_valid: jax.Array, impl: str) -> jax.Array:
    """Single-query attention over a dense cache with dynamic valid
    lengths. Straight-line quantized path (Eq. 4–5) — no tiling at q_len=1.

    q1: (B, H, 1, d); caches: (B, H, max_len, d); n_valid: (B,) int32 —
    per-slot live prefix length (continuous batching: slots decode at
    different positions).
    """
    max_len = k_cache.shape[-2]
    d = q1.shape[-1]
    valid = (jnp.arange(max_len)[None, :] < n_valid[:, None])[:, None, :, None]
    if impl == "exact":
        s = jnp.matmul(q1, jnp.swapaxes(k_cache, -1, -2)) / jnp.sqrt(jnp.float32(d))
        s = jnp.where(jnp.swapaxes(valid, -1, -2), s, -1e30)
        return jnp.matmul(jax.nn.softmax(s, axis=-1), v_cache)

    variant = ref.VARIANTS[impl]
    nf = jnp.maximum(n_valid.astype(jnp.float32), 1.0)[:, None, None, None]
    k_mean = jnp.sum(jnp.where(valid, k_cache, 0.0), axis=-2, keepdims=True) / nf
    k_sm = jnp.where(valid, k_cache - k_mean, 0.0)
    q_q, q_s = quant.quant_int8_per_token(q1 / jnp.sqrt(jnp.float32(d)))
    k_q, k_s = quant.quant_int8_per_token(k_sm)
    s = jnp.matmul(q_q.astype(jnp.int32), jnp.swapaxes(k_q, -1, -2).astype(jnp.int32))
    s = s.astype(jnp.float32) * q_s * jnp.swapaxes(k_s, -1, -2)
    s = jnp.where(jnp.swapaxes(valid, -1, -2), s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    if variant.pv_dtype == "int8":
        p_q = jnp.round(p * quant.INT8_MAX).astype(jnp.int8)
        vm = jnp.where(valid, v_cache, 0.0)
        v_q, v_s = quant.quant_int8_per_channel(vm)
        o = jnp.matmul(p_q.astype(jnp.int32), v_q.astype(jnp.int32))
        o = o.astype(jnp.float32) * (1.0 / quant.INT8_MAX) * v_s
    else:
        p16 = p.astype(jnp.float16)
        v16 = jnp.where(valid, v_cache, 0.0).astype(jnp.float16)
        o = jnp.matmul(p16, v16, preferred_element_type=jnp.float16)
        o = o.astype(jnp.float32)
    return o / jnp.maximum(l, 1e-30)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            attn_plan: Sequence[str], *, interpret: bool = True) -> jax.Array:
    """Training/eval forward: tokens (B, N) int32 → logits (B, N, vocab).

    ``attn_plan[i]`` names layer i's attention implementation — the
    adaptive-quantization plan (§4.5) materialized as a static argument.
    """
    assert len(attn_plan) == cfg.n_layers
    b, n = tokens.shape
    x = params["embed"][tokens]
    cos, sin = rope_tables(n, cfg.d_head, base=cfg.rope_base)
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, params[p + "ln1"])
        q = _split_heads(h @ params[p + "wq"], cfg.n_heads, cfg.d_head)
        k = _split_heads(h @ params[p + "wk"], cfg.n_heads, cfg.d_head)
        v = _split_heads(h @ params[p + "wv"], cfg.n_heads, cfg.d_head)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        o = _attention(q, k, v, attn_plan[i], causal=True, interpret=interpret)
        x = x + _merge_heads(o) @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2"])
        x = x + (jax.nn.silu(h @ params[p + "w_gate"])
                 * (h @ params[p + "w_up"])) @ params[p + "w_down"]
    return rmsnorm(x, params["ln_f"]) @ params["unembed"]


def loss_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
            attn_plan: Sequence[str], *, interpret: bool = True) -> jax.Array:
    """Mean next-token cross-entropy over (B, N) token batches."""
    logits = forward(cfg, params, tokens[:, :-1], attn_plan, interpret=interpret)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Training (fused AdamW step)
# ---------------------------------------------------------------------------

def train_step(cfg: ModelConfig, attn_plan: Sequence[str],
               flat_params: Sequence[jax.Array],
               flat_m: Sequence[jax.Array], flat_v: Sequence[jax.Array],
               step: jax.Array, tokens: jax.Array,
               lr: float = 3e-4, beta1: float = 0.9, beta2: float = 0.95,
               eps: float = 1e-8, wd: float = 0.01):
    """One AdamW step. All state flat (manifest order) for the rust driver.

    Training uses the *exact* attention path: the paper's method is
    post-training (plug-and-play at inference); we train full-precision and
    quantize at serve time, exactly as the paper deploys it.
    """
    params = params_from_list(cfg, flat_params)

    def loss_of(p):
        return loss_fn(cfg, p, tokens, attn_plan)

    loss, grads = jax.value_and_grad(loss_of)(params)
    g_flat = params_to_list(cfg, grads)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_params, g_flat, flat_m, flat_v):
        m2 = beta1 * m + (1 - beta1) * g
        v2 = beta2 * v + (1 - beta2) * jnp.square(g)
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        new_p.append(p - lr * (upd + wd * p))
        new_m.append(m2)
        new_v.append(v2)
    return (loss, step + 1) + tuple(new_p) + tuple(new_m) + tuple(new_v)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, attn_plan: Sequence[str],
            flat_params: Sequence[jax.Array], tokens: jax.Array,
            *, interpret: bool = True):
    """Process a prompt: tokens (B, N) → (last-position logits,
    k_caches (L, B, H, max_seq, d), v_caches (L, B, H, max_seq, d)).
    """
    params = params_from_list(cfg, flat_params)
    b, n = tokens.shape
    x = params["embed"][tokens]
    cos, sin = rope_tables(n, cfg.d_head, base=cfg.rope_base)
    k_caches, v_caches = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, params[p + "ln1"])
        q = _split_heads(h @ params[p + "wq"], cfg.n_heads, cfg.d_head)
        k = _split_heads(h @ params[p + "wk"], cfg.n_heads, cfg.d_head)
        v = _split_heads(h @ params[p + "wv"], cfg.n_heads, cfg.d_head)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        pad = cfg.max_seq - n
        k_caches.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        v_caches.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
        o = _attention(q, k, v, attn_plan[i], causal=True, interpret=interpret)
        x = x + _merge_heads(o) @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2"])
        x = x + (jax.nn.silu(h @ params[p + "w_gate"])
                 * (h @ params[p + "w_up"])) @ params[p + "w_down"]
    logits = rmsnorm(x[:, -1:, :], params["ln_f"]) @ params["unembed"]
    return (logits[:, 0, :], jnp.stack(k_caches), jnp.stack(v_caches))


def decode_step(cfg: ModelConfig, attn_plan: Sequence[str],
                flat_params: Sequence[jax.Array],
                k_caches: jax.Array, v_caches: jax.Array,
                token: jax.Array, pos: jax.Array):
    """One incremental decode step over a continuous batch.

    token: (B,) int32 — each slot's token at its own position.
    pos:   (B,) int32 — each slot's 0-based position (continuous batching:
           slots are at different depths; idle slots can pass pos 0).
    Returns (next-token logits (B, vocab), k_caches', v_caches').
    """
    params = params_from_list(cfg, flat_params)
    max_len = k_caches.shape[-2]
    x = params["embed"][token][:, None, :]   # (B, 1, d_model)
    half = cfg.d_head // 2
    inv_freq = 1.0 / (cfg.rope_base ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[:, None] * inv_freq[None, :]   # (B, half)
    cos = jnp.cos(ang)[:, None, None, :]     # (B, 1, 1, half)
    sin = jnp.sin(ang)[:, None, None, :]
    # one-hot over the cache axis for the per-slot scatter
    onehot = (jnp.arange(max_len)[None, :] == pos[:, None]
              ).astype(jnp.float32)[:, None, :, None]   # (B, 1, max, 1)
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, params[p + "ln1"])
        q = _split_heads(h @ params[p + "wq"], cfg.n_heads, cfg.d_head)
        k = _split_heads(h @ params[p + "wk"], cfg.n_heads, cfg.d_head)
        v = _split_heads(h @ params[p + "wv"], cfg.n_heads, cfg.d_head)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        kc = k_caches[i] * (1.0 - onehot) + k * onehot
        vc = v_caches[i] * (1.0 - onehot) + v * onehot
        new_k.append(kc)
        new_v.append(vc)
        o = _decode_attention(q, kc, vc, pos.astype(jnp.int32) + 1, attn_plan[i])
        x = x + _merge_heads(o) @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2"])
        x = x + (jax.nn.silu(h @ params[p + "w_gate"])
                 * (h @ params[p + "w_up"])) @ params[p + "w_down"]
    logits = rmsnorm(x, params["ln_f"]) @ params["unembed"]
    return (logits[:, 0, :], jnp.stack(new_k), jnp.stack(new_v))
