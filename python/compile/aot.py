"""AOT pipeline: lower L1/L2 computations to HLO text for the rust runtime.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects;
the text parser reassigns ids and round-trips cleanly.

Artifacts written to ``artifacts/``:
  * ``attn_<impl>[_causal]_<B>x<H>x<N>x<D>.hlo.txt`` — standalone attention
    computations (q, k, v) → O for the kernel benches and integration tests.
  * ``<config>_{train_step,eval_loss,prefill,decode_step}_<plan>.hlo.txt``
    — the transformer artifacts driven by the rust coordinator.
  * ``manifest.json`` — every entry's input/output shapes + dtypes, the
    parameter spec (name/shape/init-std) and model config, so rust can
    construct inputs without touching python.

Python runs once (`make artifacts`); nothing here is on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .configs import MODEL_CONFIGS, ModelConfig
from .kernels import ref

ATTN_IMPLS: Dict[str, str] = {
    "exact": "exact",
    "sage_t": "SageAttn-T",
    "sage_b": "SageAttn-B",
    "sage_vt": "SageAttn-vT",
    "sage_vb": "SageAttn-vB",
}

# Standalone attention artifact shapes: (batch, heads, seq, head_dim).
# Modest sizes — the CPU PJRT backend executes these in tests/benches;
# paper-scale shapes (N up to 32k) are covered by the rust-native
# implementations and the perf model.
ATTN_SHAPES = (
    (1, 2, 256, 64),
    (2, 4, 512, 64),
    (1, 4, 512, 128),
    (2, 8, 1024, 64),
)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


class Writer:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: Dict[str, dict] = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, arg_specs: Sequence, meta: dict | None = None):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *arg_specs)
        flat_out, _ = jax.tree.flatten(out_specs)
        self.entries[name] = {
            "file": fname,
            "inputs": [{"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                       for s in arg_specs],
            "outputs": [{"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                        for s in flat_out],
            **(meta or {}),
        }
        print(f"  wrote {fname} ({len(text)} chars, "
              f"{len(arg_specs)} in / {len(flat_out)} out)")


def emit_attention(w: Writer, shapes=ATTN_SHAPES):
    for (b, h, n, d) in shapes:
        specs = [_spec((b, h, n, d))] * 3
        for tag, impl in ATTN_IMPLS.items():
            for causal in (False, True):
                cname = "_causal" if causal else ""
                name = f"attn_{tag}{cname}_{b}x{h}x{n}x{d}"

                def fn(q, k, v, impl=impl, causal=causal):
                    return (model_lib._attention(q, k, v, impl, causal=causal),)

                w.emit(name, fn, specs,
                       meta={"kind": "attention", "impl": impl,
                             "causal": causal, "shape": [b, h, n, d]})


def emit_model(w: Writer, cfg: ModelConfig, plans: Dict[str, List[str]],
               batch: int):
    spec = model_lib.param_spec(cfg)
    p_specs = [_spec(shape) for _, shape, _ in spec]
    n_p = len(p_specs)
    tok_train = _spec((batch, cfg.max_seq), jnp.int32)
    step_spec = _spec((), jnp.int32)

    # train_step is always full-precision (post-training quantization).
    fp_plan = ["exact"] * cfg.n_layers

    def tstep(*args):
        flat_p = args[:n_p]
        flat_m = args[n_p:2 * n_p]
        flat_v = args[2 * n_p:3 * n_p]
        step, tokens = args[3 * n_p], args[3 * n_p + 1]
        return model_lib.train_step(cfg, fp_plan, flat_p, flat_m, flat_v,
                                    step, tokens)

    w.emit(f"{cfg.name}_train_step", tstep,
           p_specs * 3 + [step_spec, tok_train],
           meta={"kind": "train_step", "config": cfg.name, "batch": batch})

    kv_spec = _spec((cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.d_head))
    for plan_name, plan in plans.items():
        def eloss(*args, plan=plan):
            return (model_lib.loss_fn(cfg, model_lib.params_from_list(cfg, args[:n_p]),
                                      args[n_p], plan),)

        w.emit(f"{cfg.name}_eval_loss_{plan_name}", eloss, p_specs + [tok_train],
               meta={"kind": "eval_loss", "config": cfg.name,
                     "plan": plan, "batch": batch})

        # Prefill runs per-request (batch 1, vLLM-style): the coordinator
        # prefills each arriving prompt separately and splices its KV into
        # a free slot of the continuous decode batch. One artifact per
        # supported prompt length (powers of two up to half the context).
        n_prompt = 8
        while n_prompt <= cfg.max_seq // 2:
            tok_prompt = _spec((1, n_prompt), jnp.int32)

            def pfill(*args, plan=plan):
                return model_lib.prefill(cfg, plan, args[:n_p], args[n_p])

            w.emit(f"{cfg.name}_prefill_{plan_name}_{n_prompt}", pfill,
                   p_specs + [tok_prompt],
                   meta={"kind": "prefill", "config": cfg.name, "plan": plan,
                         "batch": 1, "n_prompt": n_prompt})
            n_prompt *= 2

        def dstep(*args, plan=plan):
            flat_p = args[:n_p]
            kc, vc, token, pos = args[n_p:n_p + 4]
            return model_lib.decode_step(cfg, plan, flat_p, kc, vc, token, pos)

        w.emit(f"{cfg.name}_decode_step_{plan_name}", dstep,
               p_specs + [kv_spec, kv_spec, _spec((batch,), jnp.int32),
                          _spec((batch,), jnp.int32)],
               meta={"kind": "decode_step", "config": cfg.name, "plan": plan,
                     "batch": batch})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default="small", choices=list(MODEL_CONFIGS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--plan-file", default=None,
                    help="JSON list of per-layer impls from `repro calibrate` "
                         "— emitted as the '<config>_*_adaptive' artifacts")
    ap.add_argument("--skip-attn", action="store_true")
    ap.add_argument("--tiny-only", action="store_true",
                    help="only the tiny config + one attention shape (CI)")
    args = ap.parse_args()

    w = Writer(args.out)
    if args.tiny_only:
        emit_attention(w, shapes=((1, 2, 256, 64),))
        emit_model(w, MODEL_CONFIGS["tiny"],
                   {"fp": ["exact"] * 2, "sage": ["SageAttn-B"] * 2}, batch=2)
    else:
        if not args.skip_attn:
            emit_attention(w)
        cfg = MODEL_CONFIGS[args.config]
        plans = {"fp": ["exact"] * cfg.n_layers,
                 "sage": ["SageAttn-B"] * cfg.n_layers}
        if args.plan_file:
            with open(args.plan_file) as f:
                plan = json.load(f)
            assert len(plan) == cfg.n_layers and all(
                p in model_lib.ATTN_IMPLS for p in plan), plan
            plans["adaptive"] = plan
        emit_model(w, cfg, plans, batch=args.batch)
        # tiny config always included for the rust integration tests
        emit_model(w, MODEL_CONFIGS["tiny"],
                   {"fp": ["exact"] * 2, "sage": ["SageAttn-B"] * 2}, batch=2)

    cfgs = {}
    for name, cfg in MODEL_CONFIGS.items():
        cfgs[name] = {
            **cfg._asdict(),
            "n_params": cfg.n_params,
            "param_spec": [{"name": n, "shape": list(s), "init_std": std}
                           for n, s, std in model_lib.param_spec(cfg)],
        }
    manifest = {"entries": w.entries, "configs": cfgs}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(w.entries)} entries -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
