"""INT8 / FP8 quantizers and the smooth-K transform (paper §3.2, §4.2, §4.3).

All quantizers operate on arrays laid out as ``(..., N, d)`` where ``N`` is
the token axis and ``d`` the channel (head-dim) axis, matching the paper's
formulation. Each returns ``(q, scale)`` such that ``q * scale ≈ x``.

Granularities (paper §3.2):
  * per-tensor : one scale for the whole array
  * per-token  : one scale per row  (axis -2), shape (..., N, 1)
  * per-channel: one scale per col  (axis -1), shape (..., 1, d)
  * per-block  : one scale per block of ``block`` consecutive tokens,
                 broadcast back to per-token shape (..., N, 1) so downstream
                 kernels are granularity-agnostic.

FP8 "quantizers" simulate E4M3/E5M2 tensor-core matmuls (the FlashAttention3
recipe, Table 1/2/3/17/18 baselines) by casting through jax's native
float8 dtypes with a per-token scale to the format's max normal.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
# Max representable normals of the two FP8 formats (OCP FP8 spec).
E4M3_MAX = 448.0
E5M2_MAX = 57344.0

_EPS = 1e-8


class Quantized(NamedTuple):
    """A quantized tensor together with its dequantization scale."""

    q: jax.Array      # low-precision payload (int8 or float8_*)
    scale: jax.Array  # broadcastable against q: q * scale ≈ original


def _amax(x: jax.Array, axis, keepdims: bool) -> jax.Array:
    return jnp.maximum(jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims), _EPS)


def _to_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.round(x / scale)
    return jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def quant_int8_per_tensor(x: jax.Array) -> Quantized:
    """δ = max|x| / 127 for the whole tensor (paper §3.2)."""
    scale = _amax(x, axis=None, keepdims=True) / INT8_MAX
    return Quantized(_to_int8(x, scale), scale.astype(jnp.float32))


def quant_int8_per_token(x: jax.Array) -> Quantized:
    """One scale per token (row of the (..., N, d) layout)."""
    scale = _amax(x, axis=-1, keepdims=True) / INT8_MAX
    return Quantized(_to_int8(x, scale), scale.astype(jnp.float32))


def quant_int8_per_channel(x: jax.Array) -> Quantized:
    """One scale per channel (column). Used for V in the -vT/-vB kernels."""
    scale = _amax(x, axis=-2, keepdims=True) / INT8_MAX
    return Quantized(_to_int8(x, scale), scale.astype(jnp.float32))


def quant_int8_per_block(x: jax.Array, block: int) -> Quantized:
    """One scale per ``block`` consecutive tokens (paper's per-block ψ).

    The scale is broadcast back to per-token shape (..., N, 1): within a
    block all rows share a value, so kernels consuming per-token scales work
    unchanged. ``N`` need not divide ``block``; the tail block is shorter.
    """
    n = x.shape[-2]
    pad = (-n) % block
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)])
    blocked = xp.reshape(*x.shape[:-2], (n + pad) // block, block, x.shape[-1])
    scale_b = _amax(blocked, axis=(-1, -2), keepdims=True) / INT8_MAX  # (..., nb, 1, 1)
    scale = jnp.broadcast_to(scale_b, blocked.shape[:-1] + (1,))
    scale = scale.reshape(*x.shape[:-2], n + pad, 1)[..., :n, :]
    return Quantized(_to_int8(x, scale), scale.astype(jnp.float32))


def dequant(qx: Quantized) -> jax.Array:
    """ψ⁻¹: elementwise rescale back to fp32."""
    return qx.q.astype(jnp.float32) * qx.scale


# ---------------------------------------------------------------------------
# FP8 simulation (FlashAttention3-style quantization, used as a baseline)
# ---------------------------------------------------------------------------

_FP8_DTYPES = {
    "e4m3": (jnp.float8_e4m3fn, E4M3_MAX),
    "e5m2": (jnp.float8_e5m2, E5M2_MAX),
}


def quant_fp8_per_token(x: jax.Array, fmt: str) -> Quantized:
    """Scale each token to the format's max normal, then cast to FP8.

    The cast itself performs round-to-nearest-even into the 8-bit mantissa
    grid, which is exactly the error a real FP8 tensor-core input takes.
    """
    dtype, fmax = _FP8_DTYPES[fmt]
    scale = _amax(x, axis=-1, keepdims=True) / fmax
    q = (x / scale).astype(dtype)
    return Quantized(q, scale.astype(jnp.float32))


def quant_fp8_per_tensor(x: jax.Array, fmt: str) -> Quantized:
    dtype, fmax = _FP8_DTYPES[fmt]
    scale = _amax(x, axis=None, keepdims=True) / fmax
    q = (x / scale).astype(dtype)
    return Quantized(q, scale.astype(jnp.float32))


def fake_quant(x: jax.Array, kind: str, block: int = 128) -> jax.Array:
    """Quantize-dequantize in one step — the numeric effect without the
    packed payload. ``kind`` ∈ {int8_token, int8_block, int8_tensor,
    int8_channel, e4m3, e5m2, fp16, none}."""
    if kind == "none":
        return x
    if kind == "fp16":
        return x.astype(jnp.float16).astype(jnp.float32)
    if kind == "int8_token":
        return dequant(quant_int8_per_token(x))
    if kind == "int8_block":
        return dequant(quant_int8_per_block(x, block))
    if kind == "int8_tensor":
        return dequant(quant_int8_per_tensor(x))
    if kind == "int8_channel":
        return dequant(quant_int8_per_channel(x))
    if kind in ("e4m3", "e5m2"):
        return dequant(quant_fp8_per_token(x, kind))
    raise ValueError(f"unknown fake-quant kind: {kind}")


# ---------------------------------------------------------------------------
# Smooth K (paper §4.2)
# ---------------------------------------------------------------------------

def smooth_k(k: jax.Array) -> jax.Array:
    """γ(K) = K − mean(K) along the token axis.

    K's channel outliers are a bias shared by all tokens (Figure 4);
    removing the mean leaves the small token-wise signal, which quantizes
    accurately. Attention is invariant: σ(q(K−mean)ᵀ) = σ(qKᵀ) because the
    subtracted term is constant within each softmax row.
    """
    return k - jnp.mean(k, axis=-2, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("granularity", "block", "do_smooth_k"))
def quantize_qk(q: jax.Array, k: jax.Array, *, granularity: str = "token",
                block: int = 128, do_smooth_k: bool = True):
    """ψ_Q(Q/√d) and φ_K(K)=ψ_K∘γ from Eq. (4), as one fused step.

    Folds the 1/√d softmax temperature into Q before quantization
    (fusion trick, §4.6). Returns ((q_i8, q_scale), (k_i8, k_scale)).
    """
    d = q.shape[-1]
    qs = q.astype(jnp.float32) * (1.0 / jnp.sqrt(jnp.float32(d)))
    ks = k.astype(jnp.float32)
    if do_smooth_k:
        ks = smooth_k(ks)
    if granularity == "token":
        return quant_int8_per_token(qs), quant_int8_per_token(ks)
    if granularity == "block":
        return quant_int8_per_block(qs, block), quant_int8_per_block(ks, block)
    if granularity == "tensor":
        return quant_int8_per_tensor(qs), quant_int8_per_tensor(ks)
    raise ValueError(f"unknown granularity: {granularity}")
