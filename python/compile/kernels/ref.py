"""Pure-jnp correctness oracles for the SageAttention kernels.

Three tiers:
  * ``attention_ref``        — exact fp32 attention (the gold standard the
                                paper measures CosSim / L1 / RMSE against).
  * ``attention_online_ref`` — fp32 FlashAttention-2 tiling + online softmax
                                (validates the tiling/recurrence alone).
  * ``sage_attention_ref``   — straight-line (non-Pallas) quantized
                                attention implementing Eq. (4)–(5) for every
                                kernel variant; the oracle the Pallas kernel
                                must match bit-for-bit up to reassociation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import quant
from .fp16_sim import matmul_fp16_accum, matmul_int8


class Variant(NamedTuple):
    """One row of the paper's Table 6."""

    name: str
    qk_granularity: str   # "token" | "block" | "tensor"
    pv_dtype: str         # "fp16" (FP16 accumulator) | "int8"


SAGE_ATTN_T = Variant("SageAttn-T", "token", "fp16")
SAGE_ATTN_B = Variant("SageAttn-B", "block", "fp16")
SAGE_ATTN_VT = Variant("SageAttn-vT", "token", "int8")
SAGE_ATTN_VB = Variant("SageAttn-vB", "block", "int8")
VARIANTS = {v.name: v for v in
            (SAGE_ATTN_T, SAGE_ATTN_B, SAGE_ATTN_VT, SAGE_ATTN_VB)}


def _causal_mask(n_q: int, n_k: int, dtype=jnp.float32) -> jax.Array:
    """Lower-triangular mask aligned to the *end* of the KV sequence, so a
    query at position i attends to keys [0, i + n_k - n_q]."""
    q_pos = jnp.arange(n_q)[:, None] + (n_k - n_q)
    k_pos = jnp.arange(n_k)[None, :]
    return jnp.where(k_pos <= q_pos, 0.0, -jnp.inf).astype(dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = False) -> jax.Array:
    """Exact attention in fp32. q,k,v: (..., N, d)."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    s = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) / jnp.sqrt(jnp.float32(q.shape[-1]))
    if causal:
        s = s + _causal_mask(q.shape[-2], k.shape[-2])
    p = jax.nn.softmax(s, axis=-1)
    return jnp.matmul(p, v)


def attention_online_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool = False,
                         block_q: int = 128, block_kv: int = 64) -> jax.Array:
    """FlashAttention-2 recurrence (Eq. 1–2) in fp32, block-by-block.

    Numerically equivalent to ``attention_ref`` up to fp reassociation;
    exists to validate the tiling before quantization enters the picture.
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    n_q, d = q.shape[-2], q.shape[-1]
    n_k = k.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    pad_q = (-n_q) % block_q
    pad_k = (-n_k) % block_kv
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 2) + [(0, pad_q), (0, 0)])
    kp = jnp.pad(k, [(0, 0)] * (k.ndim - 2) + [(0, pad_k), (0, 0)])
    vp = jnp.pad(v, [(0, 0)] * (v.ndim - 2) + [(0, pad_k), (0, 0)])
    nqb, nkb = qp.shape[-2] // block_q, kp.shape[-2] // block_kv

    mask_full = None
    if causal:
        mask_full = _causal_mask(n_q, n_k)
        mask_full = jnp.pad(mask_full, [(0, pad_q), (0, pad_k)],
                            constant_values=-jnp.inf)
    # mask out padded kv columns for every query
    if pad_k and mask_full is None:
        mask_full = jnp.zeros((n_q + pad_q, n_k + pad_k))
        mask_full = mask_full.at[:, n_k:].set(-jnp.inf)

    out = jnp.zeros_like(qp)
    for i in range(nqb):
        qi = jax.lax.dynamic_slice_in_dim(qp, i * block_q, block_q, axis=-2)
        m = jnp.full(qi.shape[:-1] + (1,), -jnp.inf)
        l = jnp.zeros(qi.shape[:-1] + (1,))
        o = jnp.zeros_like(qi)
        for j in range(nkb):
            kj = jax.lax.dynamic_slice_in_dim(kp, j * block_kv, block_kv, axis=-2)
            vj = jax.lax.dynamic_slice_in_dim(vp, j * block_kv, block_kv, axis=-2)
            s = jnp.matmul(qi, jnp.swapaxes(kj, -1, -2)) * scale
            if mask_full is not None:
                s = s + mask_full[i * block_q:(i + 1) * block_q,
                                  j * block_kv:(j + 1) * block_kv]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            m_new = jnp.maximum(m_new, -1e30)  # keep exp() finite on all-masked rows
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            o = alpha * o + jnp.matmul(p, vj)
            m = m_new
        out = jax.lax.dynamic_update_slice_in_dim(
            out, o / jnp.maximum(l, 1e-30), i * block_q, axis=-2)
    return out[..., :n_q, :]


def sage_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                       variant: Variant = SAGE_ATTN_B,
                       causal: bool = False,
                       do_smooth_k: bool = True,
                       block_q: int = 128, block_kv: int = 64) -> jax.Array:
    """Straight-line SageAttention (Eq. 4–5) — the Pallas kernel's oracle.

    Quantizes Q,K to INT8 at the variant's granularity (after smooth-K and
    folding 1/√d into Q), computes S in INT32, dequantizes, runs exact
    softmax, then either the FP16-accumulator P·V path or the INT8 P·V path
    (P per-block with the static 1/127 scale, V per-channel).
    """
    v32 = v.astype(jnp.float32)
    (q_q, q_s), (k_q, k_s) = quant.quantize_qk(
        q, k, granularity=variant.qk_granularity,
        block=block_q, do_smooth_k=do_smooth_k)
    # S = ψ⁻¹(Q̂ K̂ᵀ): int32 matmul, then scale rows by δ_Q and cols by δ_K.
    s_int = matmul_int8(q_q, jnp.swapaxes(k_q, -1, -2))
    s = s_int.astype(jnp.float32) * q_s * jnp.swapaxes(k_s, -1, -2)
    if causal:
        s = s + _causal_mask(q.shape[-2], k.shape[-2])
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)  # P̃: row max == 1 by construction
    l = jnp.sum(p, axis=-1, keepdims=True)
    if variant.pv_dtype == "fp16":
        o = matmul_fp16_accum(p.astype(jnp.float16), v32.astype(jnp.float16))
        o = o.astype(jnp.float32)
    elif variant.pv_dtype == "int8":
        # P̃ ∈ [0,1] ⇒ static per-block scale 1/127 (paper §4.3 point (2)).
        p_q = jnp.clip(jnp.round(p * quant.INT8_MAX), -127, 127).astype(jnp.int8)
        v_q, v_s = quant.quant_int8_per_channel(v32)
        o_int = matmul_int8(p_q, v_q)
        o = o_int.astype(jnp.float32) * (1.0 / quant.INT8_MAX) * v_s
    else:
        raise ValueError(variant.pv_dtype)
    return o / l
