"""L1: SageAttention Pallas kernels, quantizers, and correctness oracles.

Public surface:
  * :mod:`quant`      — INT8/FP8 quantizers, smooth-K, fused quantize_qk
  * :mod:`fp16_sim`   — tensor-core accumulator-precision simulation
  * :mod:`sage_attn`  — the Pallas FlashAttention-style quantized kernel
  * :mod:`rope_quant` — fused RoPE + smooth-K + INT8 quantization kernel
  * :mod:`ref`        — pure-jnp oracles and the Table-6 variant registry
  * :mod:`synth`      — Figure-4 synthetic QKV distribution generators
"""

from . import fp16_sim, quant, ref, rope_quant, sage_attn, synth
from .ref import (SAGE_ATTN_B, SAGE_ATTN_T, SAGE_ATTN_VB, SAGE_ATTN_VT,
                  VARIANTS, Variant)
from .sage_attn import sage_attention, sage_attention_quantized

__all__ = [
    "quant", "fp16_sim", "ref", "rope_quant", "sage_attn", "synth",
    "Variant", "VARIANTS", "SAGE_ATTN_T", "SAGE_ATTN_B", "SAGE_ATTN_VT",
    "SAGE_ATTN_VB", "sage_attention", "sage_attention_quantized",
]
