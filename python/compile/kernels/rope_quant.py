"""Fused RoPE + smooth-K + scale + INT8-quantize Pallas kernel (paper §4.6).

The paper's fusion trick: quantization is performed *before* the RoPE
result is written back to global memory, so the quantization pass costs no
extra HBM round-trip. This kernel mirrors that boundary: one grid step
reads a (block, d) tile of pre-RoPE activations from HBM, applies the
rotary embedding, optionally subtracts the (precomputed) post-RoPE key
mean (smooth-K, §4.2), folds in the 1/√d softmax temperature for Q, and
writes the INT8 payload + per-token fp32 scales.

RoPE convention: split-half ("NeoX"/Llama style) — the first d/2 lanes are
x1 and the last d/2 are x2; (x1, x2) ↦ (x1·cos − x2·sin, x2·cos + x1·sin).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant

DEFAULT_BLOCK = 128


def rope_tables(n: int, d: int, base: float = 10000.0,
                offset: int = 0) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables of shape (n, d/2) for positions [offset, offset+n)."""
    half = d // 2
    inv_freq = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos = jnp.arange(offset, offset + n, dtype=jnp.float32)
    ang = pos[:, None] * inv_freq[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Reference RoPE on (..., N, d) with (N, d/2) tables."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _rope_quant_kernel(x_ref, cos_ref, sin_ref, mean_ref,
                       q_ref, s_ref, *, scale_factor: float, subtract_mean: bool):
    x = x_ref[0].astype(jnp.float32)          # (block, d)
    cos = cos_ref[...]                        # (block, d/2)
    sin = sin_ref[...]
    half = x.shape[-1] // 2
    x1, x2 = x[:, :half], x[:, half:]
    roped = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if subtract_mean:
        roped = roped - mean_ref[0]           # smooth-K: γ(K) = K − mean(K)
    roped = roped * scale_factor              # fold 1/√d into Q (§4.6)
    amax = jnp.maximum(jnp.max(jnp.abs(roped), axis=-1, keepdims=True), 1e-8)
    scale = amax / quant.INT8_MAX
    q_ref[0, :, :] = jnp.clip(jnp.round(roped / scale),
                              -quant.INT8_MAX, quant.INT8_MAX).astype(jnp.int8)
    s_ref[0, :, :] = scale


def rope_quantize(x: jax.Array, cos: jax.Array, sin: jax.Array,
                  *, k_mean: Optional[jax.Array] = None,
                  scale_factor: float = 1.0,
                  block: int = DEFAULT_BLOCK,
                  interpret: bool = True) -> quant.Quantized:
    """Fused RoPE→(smooth)→scale→INT8 per-token quantization.

    Args:
      x: (B, H, N, d) activations (pre-RoPE Q or K).
      cos/sin: (N, d/2) tables from :func:`rope_tables`.
      k_mean: (B, H, 1, d) post-RoPE key mean for smooth-K; None for Q.
      scale_factor: 1/√d for Q (fusion trick), 1.0 for K.
    Returns (int8 payload (B,H,N,d), per-token scales (B,H,N,1)).
    """
    b, h, n, d = x.shape
    block = min(block, n)
    pad = (-n) % block
    xp = jnp.pad(x, [(0, 0), (0, 0), (0, pad), (0, 0)]).reshape(b * h, n + pad, d)
    cosp = jnp.pad(cos, [(0, pad), (0, 0)], constant_values=1.0)
    sinp = jnp.pad(sin, [(0, pad), (0, 0)])
    subtract = k_mean is not None
    mean = (k_mean.reshape(b * h, 1, d) if subtract
            else jnp.zeros((b * h, 1, d), jnp.float32))
    nb = (n + pad) // block

    kernel = functools.partial(_rope_quant_kernel,
                               scale_factor=scale_factor,
                               subtract_mean=subtract)
    q, s = pl.pallas_call(
        kernel,
        grid=(b * h, nb),
        in_specs=[
            pl.BlockSpec((1, block, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((block, d // 2), lambda bh, i: (i, 0)),
            pl.BlockSpec((block, d // 2), lambda bh, i: (i, 0)),
            pl.BlockSpec((1, 1, d), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, block, 1), lambda bh, i: (bh, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, n + pad, d), jnp.int8),
            jax.ShapeDtypeStruct((b * h, n + pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cosp, sinp, mean)
    return quant.Quantized(
        q.reshape(b, h, n + pad, d)[:, :, :n, :],
        s.reshape(b, h, n + pad, 1)[:, :, :n, :])


def rope_quantize_qk(q: jax.Array, k: jax.Array,
                     *, offset: int = 0, base: float = 10000.0,
                     do_smooth_k: bool = True, block: int = DEFAULT_BLOCK,
                     interpret: bool = True):
    """Convenience wrapper producing kernel-ready (Q̂, δ_Q), (K̂, δ_K).

    Computes the post-RoPE key mean with a cheap jnp pre-pass (one reduce —
    the paper's smooth-K overhead, measured <0.2%), then runs the fused
    kernel on both Q and K.
    """
    b, h, n, d = q.shape
    cos, sin = rope_tables(n, d, base=base, offset=offset)
    k_mean = None
    if do_smooth_k:
        k_mean = jnp.mean(apply_rope(k.astype(jnp.float32), cos, sin),
                          axis=-2, keepdims=True)
    qq = rope_quantize(q, cos, sin, k_mean=None,
                       scale_factor=float(1.0 / jnp.sqrt(jnp.float32(d))),
                       block=block, interpret=interpret)
    kq = rope_quantize(k, cos, sin, k_mean=k_mean, scale_factor=1.0,
                       block=block, interpret=interpret)
    return qq, kq
