"""SageAttention as a Pallas FlashAttention-style kernel (paper §4, Alg. 1).

The kernel follows the paper's tiling: Q-blocks of 128, K/V-blocks of 64
(Table 12), with the FlashAttention-2 online-softmax recurrence (Eq. 1–2)
and the quantized matmuls of Eq. (4)–(5):

  * S-tile  = (Q̂_i · K̂_jᵀ) in INT8×INT8→INT32, dequantized with the row
    scale δ_Q and column scale δ_K (per-token and per-block granularities
    share one kernel: per-block scales are materialized per-token, constant
    within a block, so the kernel is granularity-agnostic).
  * P·V     = either FP16×FP16 with an FP16 accumulator (SageAttn-T/-B;
    simulated by keeping the O accumulator in fp16 — see fp16_sim.py) or
    INT8×INT8→INT32 with the static 1/127 scale for P̃ and per-channel
    scales for V (SageAttn-vT/-vB).
  * online softmax stays in fp32 (paper keeps it full-precision).

TPU adaptation (DESIGN.md §2): the paper's Triton thread-block tiling maps
to `pl.BlockSpec`s scheduling HBM→VMEM copies; the mma(u8.u8.s32) /
mma(f16.f16.f16.f16) tensor-core paths map to int8→int32 and fp16-accum
dots on the MXU. Kernels run with ``interpret=True`` — real-TPU lowering
emits Mosaic custom-calls the CPU PJRT plugin cannot execute.

Quantization of Q and K happens *outside* this kernel: the paper fuses it
into the preceding RoPE kernel (§4.6, see rope_quant.py); `sage_attention`
below does it inline with jnp ops so the whole thing lowers into one HLO.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import quant
from .ref import (SAGE_ATTN_B, SAGE_ATTN_T, SAGE_ATTN_VB, SAGE_ATTN_VT,
                  VARIANTS, Variant)

# Paper Table 12: block size 128 for Q, 64 for K and V.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 64

_NEG_BIG = -1e30  # stand-in for -inf that keeps exp() finite


def _sage_kernel(q_ref, qs_ref, k_ref, ks_ref, v_ref, vs_ref,
                 o_ref, m_ref, l_ref, acc_ref,
                 *, pv_int8: bool, causal: bool,
                 n_q_valid: int, n_kv_valid: int,
                 block_q: int, block_kv: int, n_kv_blocks: int):
    """Grid = (batch*heads, n_q_blocks, n_kv_blocks); the kv axis is the
    innermost (sequential) axis, with m/l/acc carried in scratch VMEM."""
    i = pl.program_id(1)          # q-block index
    j = pl.program_id(2)          # kv-block index (sequential)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_i8 = q_ref[0]               # (block_q, d) int8
    k_i8 = k_ref[0]               # (block_kv, d) int8
    q_s = qs_ref[0]               # (block_q, 1) f32
    k_s = ks_ref[0]               # (block_kv, 1) f32

    # --- S tile: mma(u8.u8.s32) then dequantize (Eq. 5). 1/√d is already
    # folded into δ_Q by the quantization step (§4.6 fusion trick).
    s_int = jax.lax.dot_general(
        q_i8, k_i8,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    s = s_int.astype(jnp.float32) * q_s * k_s.reshape(1, block_kv)

    # --- masking: kv padding + causal (static shapes, data-free predicate)
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < n_kv_valid
    if causal:
        # queries are aligned to the END of the kv sequence (decode layout)
        mask &= k_pos <= q_pos + (n_kv_valid - n_q_valid)
    s = jnp.where(mask, s, _NEG_BIG)

    # --- online softmax (fp32, full precision)
    m_prev = m_ref[...]                                   # (block_q, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                       # (block_q, 1)
    p = jnp.exp(s - m_new)                                # P̃ ∈ [0, 1]
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new

    if pv_int8:
        # --- SageAttn-v*: ψ_P per-block with static δ_P = 1/127 (row max of
        # P̃ is ≤1), ψ_V per-channel INT8; mma(u8.u8.s32) accumulate.
        p_i8 = jnp.round(p * quant.INT8_MAX).astype(jnp.int8)
        v_i8 = v_ref[0]                                   # (block_kv, d) int8
        v_s = vs_ref[0]                                   # (1, d) f32
        pv = jax.lax.dot_general(
            p_i8, v_i8,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        pv = pv.astype(jnp.float32) * (1.0 / quant.INT8_MAX) * v_s
        acc_ref[...] = alpha * acc_ref[...] + pv
    else:
        # --- SageAttn-T/-B: FP16 P, FP16 V, FP16 accumulator. The scratch
        # accumulator itself is fp16, so every block's partial sum is
        # rounded to fp16 — the numeric effect of mma(f16.f16.f16.f16).
        p16 = p.astype(jnp.float16)
        v16 = v_ref[0]                                    # (block_kv, d) f16
        pv = jax.lax.dot_general(
            p16, v16,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float16)
        acc_ref[...] = (alpha.astype(jnp.float16) * acc_ref[...] + pv
                        ).astype(acc_ref.dtype)

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...].astype(jnp.float32) / l).astype(o_ref.dtype)


def _pad_tokens(x: jax.Array, block: int) -> jax.Array:
    pad = (-x.shape[-2]) % block
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)])


def sage_attention_quantized(
        q_i8: jax.Array, q_scale: jax.Array,
        k_i8: jax.Array, k_scale: jax.Array,
        v: jax.Array, v_scale: Optional[jax.Array],
        *, pv_int8: bool, causal: bool = False,
        n_q_valid: Optional[int] = None, n_kv_valid: Optional[int] = None,
        block_q: int = DEFAULT_BLOCK_Q, block_kv: int = DEFAULT_BLOCK_KV,
        interpret: bool = True) -> jax.Array:
    """Run the Pallas kernel on pre-quantized inputs.

    Args:
      q_i8/k_i8: (B, H, N, d) int8 with 1/√d and smooth-K already applied.
      q_scale/k_scale: (B, H, N, 1) f32 per-token (or block-constant) scales.
      v: (B, H, N, d) — fp16 when ``pv_int8=False``, int8 otherwise.
      v_scale: (B, H, 1, d) f32 per-channel scales (int8 PV only).
      n_q_valid/n_kv_valid: original lengths before padding.
    Returns: (B, H, N_q, d) f32 attention output.
    """
    b, h, n_q, d = q_i8.shape
    n_kv = k_i8.shape[-2]
    n_q_valid = n_q if n_q_valid is None else n_q_valid
    n_kv_valid = n_kv if n_kv_valid is None else n_kv_valid

    block_q = min(block_q, max(8, 1 << (n_q - 1).bit_length()) if n_q < block_q else block_q)
    block_kv = min(block_kv, max(8, 1 << (n_kv - 1).bit_length()) if n_kv < block_kv else block_kv)

    q_i8 = _pad_tokens(q_i8, block_q).reshape(b * h, -1, d)
    q_scale = _pad_tokens(q_scale, block_q).reshape(b * h, -1, 1)
    k_i8 = _pad_tokens(k_i8, block_kv).reshape(b * h, -1, d)
    k_scale = _pad_tokens(k_scale, block_kv).reshape(b * h, -1, 1)
    v = _pad_tokens(v, block_kv).reshape(b * h, -1, d)
    n_qp, n_kvp = q_i8.shape[1], k_i8.shape[1]
    nqb, nkb = n_qp // block_q, n_kvp // block_kv

    if pv_int8:
        assert v_scale is not None
        vs = v_scale.reshape(b * h, 1, d)
    else:
        # dummy scale input keeps the kernel signature uniform
        vs = jnp.ones((b * h, 1, d), jnp.float32)

    kernel = functools.partial(
        _sage_kernel, pv_int8=pv_int8, causal=causal,
        n_q_valid=n_q_valid, n_kv_valid=n_kv_valid,
        block_q=block_q, block_kv=block_kv, n_kv_blocks=nkb)

    acc_dtype = jnp.float32 if pv_int8 else jnp.float16
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, block_kv, 1), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, 1, d), lambda bh, i, j: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, n_qp, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m: running row max
            pltpu.VMEM((block_q, 1), jnp.float32),   # l: running row sum
            pltpu.VMEM((block_q, d), acc_dtype),     # O accumulator
        ],
        interpret=interpret,
    )(q_i8, q_scale, k_i8, k_scale, v, vs)

    return out.reshape(b, h, n_qp, d)[:, :, :n_q, :]


def sage_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   variant: Variant | str = SAGE_ATTN_B,
                   *, causal: bool = False, do_smooth_k: bool = True,
                   block_q: int = DEFAULT_BLOCK_Q,
                   block_kv: int = DEFAULT_BLOCK_KV,
                   interpret: bool = True) -> jax.Array:
    """Full SageAttention: quantize (Q, K[, V]) then run the Pallas kernel.

    q, k, v: (B, H, N, d) float. Returns f32 (B, H, N, d).
    """
    if isinstance(variant, str):
        variant = VARIANTS[variant]
    (q_q, q_s), (k_q, k_s) = quant.quantize_qk(
        q, k, granularity=variant.qk_granularity,
        block=block_q, do_smooth_k=do_smooth_k)
    if variant.pv_dtype == "int8":
        v_q, v_s = quant.quant_int8_per_channel(v.astype(jnp.float32))
        return sage_attention_quantized(
            q_q, q_s, k_q, k_s, v_q, v_s, pv_int8=True, causal=causal,
            block_q=block_q, block_kv=block_kv, interpret=interpret)
    return sage_attention_quantized(
        q_q, q_s, k_q, k_s, v.astype(jnp.float16), None, pv_int8=False,
        causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
