"""Synthetic Q/K/V generators reproducing the paper's Figure-4 distributions.

The whole SageAttention design hinges on one distributional fact: **K has
channel-wise outliers that are a shared bias** — every token's key is
``large per-channel bias + small token-wise signal`` — while Q is broadly
spread and V has mild channel structure. Real-model tensors (Llama2,
Unidiffuser, CogVideoX) are substituted by this generator (DESIGN.md §3);
the ``profile`` presets bracket the regimes the paper's accuracy tables
sweep over, from benign (Llama-like, quantizes fine without smoothing) to
hostile (diffusion-like, unusable without smooth-K).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class QKVProfile(NamedTuple):
    """Distribution knobs. All magnitudes are per-channel std multipliers."""

    name: str
    k_bias_scale: float      # channel-bias magnitude in K (the outlier)
    k_signal_scale: float    # token-wise signal magnitude in K
    q_scale: float           # spread of Q
    q_bias_scale: float      # channel bias in Q (paper: "Q is also affected")
    v_channel_scale: float   # per-channel magnitude variation in V
    heavy_tail: float        # 0 = gaussian; >0 mixes in a t-like tail


# Llama-like: fairly uniform — quantization is easy even per-tensor (§A.6).
LLAMA_LIKE = QKVProfile("llama-like", 2.0, 1.0, 1.0, 0.5, 1.0, 0.0)
# Diffusion-like (Unidiffuser/CogVideoX): strong shared channel bias in K —
# the regime where unsmoothed INT8 collapses (Figure 3 / Table 18).
DIFFUSION_LIKE = QKVProfile("diffusion-like", 12.0, 0.6, 1.5, 2.0, 3.0, 0.3)
# ViT-like (TIMM): moderate outliers, short sequences.
VIT_LIKE = QKVProfile("vit-like", 5.0, 0.8, 1.2, 1.0, 2.0, 0.1)

PROFILES = {p.name: p for p in (LLAMA_LIKE, DIFFUSION_LIKE, VIT_LIKE)}


def make_qkv(key: jax.Array, shape: Tuple[int, int, int, int],
             profile: QKVProfile = DIFFUSION_LIKE,
             dtype=jnp.float32) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Draw (Q, K, V) of shape (B, H, N, d) with the profile's structure."""
    b, h, n, d = shape
    ks = jax.random.split(key, 8)
    k_bias = jax.random.normal(ks[0], (b, h, 1, d)) * profile.k_bias_scale
    k_sig = jax.random.normal(ks[1], (b, h, n, d)) * profile.k_signal_scale
    k = k_bias + k_sig
    q_bias = jax.random.normal(ks[2], (b, h, 1, d)) * profile.q_bias_scale
    q = jax.random.normal(ks[3], (b, h, n, d)) * profile.q_scale + q_bias
    v_chan = jnp.exp(jax.random.normal(ks[4], (b, h, 1, d))
                     * jnp.log1p(profile.v_channel_scale) * 0.5)
    v = jax.random.normal(ks[5], (b, h, n, d)) * v_chan
    if profile.heavy_tail > 0:
        # sprinkle rare large activations (heavy-tailed mixture)
        spike_mask = jax.random.bernoulli(ks[6], 0.002, (b, h, n, d))
        spikes = jax.random.normal(ks[7], (b, h, n, d)) * 10.0
        q = q + spike_mask * spikes * profile.heavy_tail
        v = v + spike_mask * spikes * profile.heavy_tail
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


def layer_sweep(key: jax.Array, n_layers: int,
                shape: Tuple[int, int, int, int],
                profile: QKVProfile = DIFFUSION_LIKE):
    """Yield per-layer (Q, K, V) with layer-dependent severity — deeper
    layers get progressively stronger outliers, mimicking the paper's
    "worst accuracy across all layers" experiments (Tables 3/5)."""
    for layer in range(n_layers):
        sev = 0.25 + 1.5 * layer / max(n_layers - 1, 1)
        p = profile._replace(
            k_bias_scale=profile.k_bias_scale * sev,
            v_channel_scale=profile.v_channel_scale * sev,
            heavy_tail=profile.heavy_tail * sev)
        key, sub = jax.random.split(key)
        yield layer, make_qkv(sub, shape, p)
