"""Bit-faithful simulation of tensor-core accumulator precision (paper §4.4).

The paper's fast P·V path uses mma(f16.f16.f16.f16): FP16 inputs *and* an
FP16 accumulator, which on RTX4090/3090 runs at 2× the FP32-accumulator
rate. XLA on CPU always accumulates matmuls in fp32, so to reproduce the
*numerics* of an FP16 accumulator we chunk the contraction axis and round
the running sum to fp16 after every chunk — the same rounding cadence a
tensor-core HMMA pipeline applies (one round per mma issue, k=16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# One HMMA instruction contracts k=16 on Ampere/Ada; rounding the
# accumulator at this granularity matches hardware behaviour.
MMA_K = 16


@functools.partial(jax.jit, static_argnames=("chunk",))
def matmul_fp16_accum(a: jax.Array, b: jax.Array, chunk: int = MMA_K) -> jax.Array:
    """C = A @ B with fp16 inputs and a simulated fp16 accumulator.

    A: (..., m, k), B: (..., k, n). Inputs are rounded to fp16 (tensor-core
    operand precision), partial products are computed per k-chunk and the
    running accumulator is kept in fp16 throughout. Returns fp16.
    """
    a16 = a.astype(jnp.float16)
    b16 = b.astype(jnp.float16)
    k = a.shape[-1]
    pad = (-k) % chunk
    if pad:
        a16 = jnp.pad(a16, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
        b16 = jnp.pad(b16, [(0, 0)] * (b.ndim - 2) + [(0, pad), (0, 0)])
    nchunk = (k + pad) // chunk

    # (..., m, nchunk, chunk) x (..., nchunk, chunk, n) partials.
    def body(i, acc):
        asl = jax.lax.dynamic_slice_in_dim(a16, i * chunk, chunk, axis=a.ndim - 1)
        bsl = jax.lax.dynamic_slice_in_dim(b16, i * chunk, chunk, axis=b.ndim - 2)
        # Each mma's internal dot is exact-ish (products in fp16 multiplied
        # into an fp16 adder tree); model it as an fp16 dot.
        part = jnp.matmul(asl, bsl, preferred_element_type=jnp.float16)
        return (acc + part).astype(jnp.float16)

    m, n = a.shape[-2], b.shape[-1]
    batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
    acc0 = jnp.zeros(batch + (m, n), jnp.float16)
    return jax.lax.fori_loop(0, nchunk, body, acc0)


def matmul_fp32_accum(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with fp16 inputs and an FP32 accumulator (the baseline
    mma(f16.f16.f32.f32) path). Returns fp32."""
    a16 = a.astype(jnp.float16)
    b16 = b.astype(jnp.float16)
    return jnp.matmul(a16.astype(jnp.float32), b16.astype(jnp.float32))


def matmul_int8(a_q: jax.Array, b_q: jax.Array) -> jax.Array:
    """INT8 × INT8 → INT32 matmul — the mma(u8.u8.s32) path. Exact."""
    return jax.lax.dot_general(
        a_q, b_q,
        dimension_numbers=(((a_q.ndim - 1,), (b_q.ndim - 2,)), ((), ())),
        preferred_element_type=jnp.int32,
    ) if a_q.ndim == 2 else jnp.matmul(
        a_q.astype(jnp.int32), b_q.astype(jnp.int32))
