"""Model and workload configurations.

Two kinds of configs live here:

  * :class:`ModelConfig` — GPT-style transformer configs used for the AOT
    artifacts (tests, E2E training and serving).
  * :data:`PAPER_WORKLOADS` — the attention shapes of the paper's Table 7
    model zoo, used by the kernel benches and the perf model so every
    speed table sweeps exactly the shapes the paper measured.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple


class ModelConfig(NamedTuple):
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ff: int
    max_seq: int
    rope_base: float = 10000.0

    @property
    def n_params(self) -> int:
        """Approximate parameter count."""
        emb = self.vocab * self.d_model
        per_layer = (4 * self.d_model * self.n_heads * self.d_head
                     + 3 * self.d_model * self.d_ff + 2 * self.d_model)
        return emb * 2 + self.n_layers * per_layer + self.d_model


# Tiny: fast enough for pytest and rust integration tests.
TINY = ModelConfig("tiny", vocab=256, d_model=128, n_layers=2,
                   n_heads=2, d_head=64, d_ff=256, max_seq=128)

# Small: the end-to-end train/serve driver (examples/serve_llm,
# examples/e2e_train_eval). ~6M params — sized so a few hundred CPU
# training steps finish in minutes (DESIGN.md §3 substitution for the
# paper's 7B-class models; GPT_100M below is the full-scale config).
SMALL = ModelConfig("small", vocab=1024, d_model=256, n_layers=4,
                    n_heads=4, d_head=64, d_ff=1024, max_seq=256)

# The ~100M-parameter config (GPT-2-small-shaped, headdim 64 like the
# paper's kernels). Lowerable with the same code path; not used for the
# recorded CPU runs because a few hundred steps would take hours on the
# CPU PJRT backend.
GPT_100M = ModelConfig("gpt-100m", vocab=32000, d_model=768, n_layers=12,
                       n_heads=12, d_head=64, d_ff=3072, max_seq=1024)

MODEL_CONFIGS = {c.name: c for c in (TINY, SMALL, GPT_100M)}


class AttnWorkload(NamedTuple):
    """One row of the paper's Table 7: a model's attention shape."""

    model: str
    batch: int
    heads: int
    seq: int
    head_dim: int
    causal: bool
    baseline: str  # what the paper compared against for this model


# Table 7 / Table 19 shapes, verbatim from the paper.
PAPER_WORKLOADS = (
    AttnWorkload("CogvideoX", 2, 30, 17776, 64, False, "FlashAttn2"),
    AttnWorkload("Llama2", 4, 32, 1536, 128, True, "FlashAttn2"),
    AttnWorkload("UltraPixel", 2, 32, 7285, 64, False, "FlashAttn2"),
    AttnWorkload("Unidiffuser", 4, 24, 1105, 64, False, "xformers"),
    AttnWorkload("TIMM", 12, 64, 197, 64, False, "Torch"),
)

# Sequence-length sweep of Figures 6–9.
FIGURE_SEQ_LENS = (1024, 2048, 4096, 8192, 16384, 32768)
FIGURE_HEAD_DIMS = (64, 128)
