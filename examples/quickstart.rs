//! Quickstart: the three-layer stack in one page.
//!
//! 1. Open the AOT artifact store (built once by `make artifacts`).
//! 2. Run the Pallas-compiled SageAttention kernel through PJRT.
//! 3. Compare every kernel variant against full precision.
//!
//! Run: `cargo run --release --example quickstart`

use sageattention::attn::{registry, AttnSpec};
use sageattention::metrics::accuracy;
use sageattention::runtime::{Runtime, Value};
use sageattention::synth::{make_qkv, Profile};

fn main() -> anyhow::Result<()> {
    // --- 1. open the artifact store --------------------------------------
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // --- 2. synthesize an attention input with the paper's Figure-4
    //        distribution (K carries a strong shared channel bias) --------
    let (q, k, v) = make_qkv(42, [1, 2, 256, 64], Profile::diffusion_like());

    // --- 3. run the AOT Pallas kernel (INT8 QKᵀ + smooth-K + FP16-acc PV)
    let sage = rt.load("attn_sage_b_1x2x256x64")?;
    let out = sage.run(&[
        Value::from_tensor(&q),
        Value::from_tensor(&k),
        Value::from_tensor(&v),
    ])?;

    // --- 4. compare against exact fp32 attention -------------------------
    let gold = AttnSpec::exact().run(&q, &k, &v)?;
    let acc = accuracy(&gold.data, out[0].as_f32()?);
    println!("\nSageAttn-B (Pallas, AOT via PJRT) vs full precision: {acc}");

    // --- 5. sweep the kernel registry with the rust-native mirrors -------
    //        (AttnSpec::auto() is the plug-and-play entry point; here we
    //        pin each registered variant by name instead)
    println!("\nall registered kernels (rust-native mirrors):");
    for entry in registry::entries() {
        let o = AttnSpec::by_name(entry.name)?.run(&q, &k, &v)?;
        println!("  {:<12} {}", entry.name, accuracy(&gold.data, &o.data));
    }

    // --- 6. the ablation that motivates the paper: skip smooth-K ---------
    //        (parameterized kernel names resolve too)
    let o = AttnSpec::by_name("SageAttn-T-nosmooth")?.run(&q, &k, &v)?;
    println!("\nwithout smooth-K: {}", accuracy(&gold.data, &o.data));
    println!("(the CosSim drop above is Figure 3's blurry image, in numbers)");

    // --- 7. decode with quantize-once KV: prepare the prefix, then
    //        extend one row per token — no prefix requantization ----------
    let spec = AttnSpec::sage_b();
    let mut kv = spec.prepare(&k.narrow_n(0, 250), &v.narrow_n(0, 250))?;
    for t in 250..256 {
        kv.extend(&k.narrow_n(t, t + 1), &v.narrow_n(t, t + 1))?;
        let step = spec.run_prepared(&q.narrow_n(t, t + 1), &kv)?;
        assert_eq!(step.shape, vec![1, 2, 1, 64]);
    }
    println!("\nPreparedKV decode: 6 tokens appended to a 250-row prefix, quantized once");
    Ok(())
}
