//! Quickstart: the three-layer stack in one page.
//!
//! 1. Open the AOT artifact store (built once by `make artifacts`).
//! 2. Run the Pallas-compiled SageAttention kernel through PJRT.
//! 3. Compare every kernel variant against full precision.
//!
//! Run: `cargo run --release --example quickstart`

use sageattention::attn::{attention, AttnImpl};
use sageattention::metrics::accuracy;
use sageattention::runtime::{Runtime, Value};
use sageattention::synth::{make_qkv, Profile};

fn main() -> anyhow::Result<()> {
    // --- 1. open the artifact store --------------------------------------
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // --- 2. synthesize an attention input with the paper's Figure-4
    //        distribution (K carries a strong shared channel bias) --------
    let (q, k, v) = make_qkv(42, [1, 2, 256, 64], Profile::diffusion_like());

    // --- 3. run the AOT Pallas kernel (INT8 QKᵀ + smooth-K + FP16-acc PV)
    let sage = rt.load("attn_sage_b_1x2x256x64")?;
    let out = sage.run(&[
        Value::from_tensor(&q),
        Value::from_tensor(&k),
        Value::from_tensor(&v),
    ])?;

    // --- 4. compare against exact fp32 attention -------------------------
    let gold = attention(&q, &k, &v, AttnImpl::Exact, false);
    let acc = accuracy(&gold.data, out[0].as_f32()?);
    println!("\nSageAttn-B (Pallas, AOT via PJRT) vs full precision: {acc}");

    // --- 5. sweep all four Table-6 variants with the rust-native kernels -
    println!("\nall kernel variants (rust-native mirrors):");
    for name in ["SageAttn-T", "SageAttn-B", "SageAttn-vT", "SageAttn-vB"] {
        let imp = AttnImpl::by_name(name).unwrap();
        let o = attention(&q, &k, &v, imp, false);
        println!("  {name:<12} {}", accuracy(&gold.data, &o.data));
    }

    // --- 6. the ablation that motivates the paper: skip smooth-K ---------
    let no_smooth = AttnImpl::Sage {
        qk: sageattention::quant::Granularity::PerToken,
        pv: sageattention::attn::PvMode::Fp16Accum,
        smooth_k: false,
    };
    let o = attention(&q, &k, &v, no_smooth, false);
    println!("\nwithout smooth-K: {}", accuracy(&gold.data, &o.data));
    println!("(the CosSim drop above is Figure 3's blurry image, in numbers)");
    Ok(())
}
