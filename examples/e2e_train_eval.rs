//! End-to-end train → evaluate → serve driver (Table 8 surrogate).
//!
//! Trains the AOT transformer on a synthetic Markov corpus for a few
//! hundred steps *entirely from rust* (the fused AdamW train_step
//! artifact), logging the loss curve; then evaluates held-out loss and
//! perplexity twice — attention in full precision vs SageAttention — and
//! finally greedy-decodes with both plans using the trained weights.
//!
//! Run: `cargo run --release --example e2e_train_eval -- [config] [steps]`
//! (config "small" ≈ 6M params; "tiny" for a fast smoke run)

use sageattention::bench::{f4, Table};
use sageattention::coordinator::{Engine, GenParams, KvCacheManager, Request};
use sageattention::runtime::{Runtime, Value};
use sageattention::synth::Corpus;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let config = args.first().map(String::as_str).unwrap_or("small").to_owned();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    let rt = Runtime::open(Runtime::default_dir())?;
    let cfg = rt.manifest.configs[&config].clone();
    println!(
        "training '{config}': {} params, vocab {}, seq {} — {} steps",
        cfg.n_params, cfg.vocab, cfg.max_seq, steps
    );

    let train = rt.load(&format!("{config}_train_step"))?;
    let batch = train.spec.batch.unwrap_or(4);
    let n_p = cfg.param_spec.len();

    // --- init state -------------------------------------------------------
    let params = cfg.init_params(1234);
    let zeros: Vec<Value> = params.iter().map(|p| Value::zeros_f32(p.shape())).collect();
    let mut inputs: Vec<Value> = params;
    inputs.extend(zeros.iter().cloned()); // m
    inputs.extend(zeros.iter().cloned()); // v
    inputs.push(Value::scalar_i32(0));
    let mut corpus = Corpus::new(cfg.vocab, 99);
    inputs.push(Value::i32(corpus.batch(batch, cfg.max_seq), &[batch, cfg.max_seq]));

    // --- training loop ----------------------------------------------------
    let t0 = std::time::Instant::now();
    let mut first = None;
    let mut last = 0.0f32;
    for step in 0..steps {
        let out = train.run(&inputs)?;
        last = out[0].scalar_f32()?;
        first.get_or_insert(last);
        for i in 0..n_p {
            inputs[i] = out[2 + i].clone();
            inputs[n_p + i] = out[2 + n_p + i].clone();
            inputs[2 * n_p + i] = out[2 + 2 * n_p + i].clone();
        }
        inputs[3 * n_p] = out[1].clone();
        // fresh batch each step
        inputs[3 * n_p + 1] =
            Value::i32(corpus.batch(batch, cfg.max_seq), &[batch, cfg.max_seq]);
        if step % 20 == 0 || step == steps - 1 {
            println!(
                "  step {step:>4}  loss {last:.4}  ({:.1} s elapsed)",
                t0.elapsed().as_secs_f64()
            );
        }
    }
    println!(
        "loss: {:.4} -> {last:.4} over {steps} steps ({:.1} s)",
        first.unwrap(),
        t0.elapsed().as_secs_f64()
    );
    anyhow::ensure!(last < first.unwrap(), "training failed to descend");

    // --- held-out evaluation: fp vs sage attention (Table 8 surrogate) ----
    let trained: Vec<Value> = inputs[..n_p].to_vec();
    // held-out stream, pre-drawn so both plans see the *same* batches
    let mut eval_corpus = Corpus::new(cfg.vocab, 777);
    let n_batches = 8;
    let eval_batches: Vec<Value> = (0..n_batches)
        .map(|_| Value::i32(eval_corpus.batch(batch, cfg.max_seq), &[batch, cfg.max_seq]))
        .collect();
    let mut t = Table::new(&["attention", "eval loss", "perplexity"]);
    let mut losses = Vec::new();
    for plan in ["fp", "sage"] {
        let eval = rt.load(&format!("{config}_eval_loss_{plan}"))?;
        let mut acc = 0.0f64;
        for batch_tokens in &eval_batches {
            let mut ev_inputs = trained.clone();
            ev_inputs.push(batch_tokens.clone());
            acc += eval.run(&ev_inputs)?[0].scalar_f32()? as f64;
        }
        let loss = acc / n_batches as f64;
        losses.push(loss);
        t.row(&[
            if plan == "fp" { "Full-Precision" } else { "SageAttention" }.into(),
            f4(loss),
            f4(loss.exp()),
        ]);
    }
    t.print("Table 8 (surrogate): held-out loss, full-precision vs SageAttention");
    let delta = (losses[1] - losses[0]).abs() / losses[0];
    println!("relative degradation: {:.3}% (paper: ~0.02% ppl delta on Llama2)", delta * 100.0);

    // --- greedy generation agreement with trained weights ------------------
    let mut agree = 0;
    let mut total = 0;
    let mut gens: Vec<Vec<i32>> = Vec::new();
    for plan in ["fp", "sage"] {
        let mut engine = Engine::new(&rt, &config, plan, 0)?;
        let mut kv = KvCacheManager::new(256, 16);
        engine.set_params(trained.clone())?;
        let sizes = engine.prefill_sizes();
        let mut prompt_corpus = Corpus::new(cfg.vocab, 4242);
        let prompt = prompt_corpus.batch(1, sizes[0]);
        engine.add_request(
            &Request::new(
                1,
                prompt,
                GenParams { max_new_tokens: 24, ..Default::default() },
            ),
            &mut kv,
        )?;
        loop {
            let done = engine.step(&mut kv)?.finished;
            if let Some(r) = done.into_iter().next() {
                gens.push(r.tokens);
                break;
            }
        }
    }
    for (a, b) in gens[0].iter().zip(&gens[1]) {
        total += 1;
        agree += usize::from(a == b);
    }
    println!(
        "\ntrained-model greedy agreement fp vs sage: {agree}/{total} tokens");
    println!("fp:   {:?}", gens[0]);
    println!("sage: {:?}", gens[1]);
    Ok(())
}
