//! Figure 4 reproduction, in numbers: the per-tensor statistics that make
//! K hard to quantize (shared channel bias ≫ token signal) and the effect
//! of smooth-K on the INT8 signal-to-noise ratio, per activation profile.
//!
//! Run: `cargo run --release --example distribution_report`

use sageattention::bench::{f2, f3, Table};
use sageattention::quant::{fake_quant, smooth_k, FakeQuant, Granularity};
use sageattention::synth::{make_qkv, Profile};

fn std(xs: &[f32]) -> f32 {
    let m = xs.iter().sum::<f32>() / xs.len() as f32;
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32).sqrt()
}

fn main() {
    let (n, d) = (512usize, 64usize);
    let mut t = Table::new(&[
        "profile",
        "tensor",
        "chan-bias |µ|",
        "token-signal σ",
        "bias/signal",
        "INT8 SNR raw",
        "INT8 SNR smoothed",
    ]);
    for profile in [Profile::llama_like(), Profile::vit_like(), Profile::diffusion_like()] {
        let (q, k, v) = make_qkv(4, [1, 1, n, d], profile);
        for (name, tensor) in [("Q", &q), ("K", &k), ("V", &v)] {
            let plane = tensor.head(0, 0);
            // per-channel mean magnitude vs residual std (Figure 4's axes)
            let mut bias_mag = 0.0f32;
            let mut resid = vec![0.0f32; n * d];
            for c in 0..d {
                let mu: f32 = (0..n).map(|r| plane[r * d + c]).sum::<f32>() / n as f32;
                bias_mag += mu.abs() / d as f32;
                for r in 0..n {
                    resid[r * d + c] = plane[r * d + c] - mu;
                }
            }
            let sig = std(&resid);
            // quantization signal-to-noise: centered-signal std over
            // quantization-noise std, before and after smooth-K
            let snr = |x: &[f32]| {
                let deq = fake_quant(x, n, d, FakeQuant::Int8(Granularity::PerToken));
                let noise: Vec<f32> =
                    x.iter().zip(&deq).map(|(a, b)| a - b).collect();
                sig / std(&noise).max(1e-9)
            };
            let raw = snr(plane);
            let smoothed = if name == "K" {
                let (sm, _) = smooth_k(plane, n, d);
                snr(&sm)
            } else {
                raw
            };
            t.row(&[
                profile.name.into(),
                name.into(),
                f3(bias_mag as f64),
                f3(sig as f64),
                f2((bias_mag / sig) as f64),
                f2(raw as f64),
                f2(smoothed as f64),
            ]);
        }
    }
    t.print("Figure 4 (numeric): channel-bias structure and INT8 signal-to-noise");
    println!("\nreading: K's bias/signal ratio explodes on the diffusion profile, and");
    println!("smooth-K restores its INT8 SNR by an order of magnitude — Q and V change little.");
}
