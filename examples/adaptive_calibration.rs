//! §4.5 adaptive-quantization workflow, end to end:
//!
//!   1. calibrate per-layer cosine similarity of SageAttn-vB vs -B on
//!      representative inputs (synthetic layers here),
//!   2. write the resulting per-layer plan to `plan.json`,
//!   3. (offline) `make artifacts PLAN=plan.json` re-lowers the model with
//!      the mixed plan as the `*_adaptive` artifacts,
//!   4. if those artifacts exist, run them and verify parity.
//!
//! Run: `cargo run --release --example adaptive_calibration -- [n_layers]`

use sageattention::adaptive::{calibrate, synth_layer_inputs, COS_THRESHOLD};
use sageattention::attn::AttnSpec;
use sageattention::bench::{pct, Table};
use sageattention::runtime::Runtime;
use sageattention::synth::Profile;

fn main() -> anyhow::Result<()> {
    let n_layers: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);

    // 1. calibrate on a mixed-severity synthetic model: shallow layers
    //    benign, deep layers hostile — the regime where adaptivity pays
    let profile = Profile::diffusion_like().with_severity(2.0);
    let layers = synth_layer_inputs(n_layers, [1, 4, 384, 64], profile, 17);
    let (plan, detail) = calibrate(&layers, false);

    let mut t = Table::new(&["layer", "cos(-vB)", "cos(-B)", "selected kernel"]);
    for d in &detail {
        t.row(&[
            d.layer.to_string(),
            pct(d.cos_vb as f64),
            pct(d.cos_b as f64),
            d.choice.to_string(),
        ]);
    }
    t.print(&format!(
        "per-layer calibration (select -vB where cos ≥ {:.1}%)",
        COS_THRESHOLD * 100.0
    ));

    // 2. persist the plan — after proving every entry resolves through
    //    the kernel registry and runs on the calibration inputs
    for (imp, (q, k, v)) in plan.kernels()?.iter().zip(&layers) {
        AttnSpec::new(*imp).run(q, k, v)?;
    }
    let path = "plan.json";
    std::fs::write(path, plan.to_json())?;
    let n_vb = plan.0.iter().filter(|s| s.as_str() == "SageAttn-vB").count();
    println!(
        "\nwrote {path}: {n_vb}/{n_layers} layers on -vB, estimated attention \
         speedup {:.1}% over all--B",
        (plan.speedup_estimate() - 1.0) * 100.0
    );
    println!("\nnext: make artifacts PLAN={path}   # emits <config>_*_adaptive artifacts");

    // 4. if adaptive artifacts are already present, prove they serve
    if let Ok(rt) = Runtime::open(Runtime::default_dir()) {
        let adaptive: Vec<String> = rt
            .manifest
            .entries
            .keys()
            .filter(|n| n.contains("_adaptive"))
            .cloned()
            .collect();
        if adaptive.is_empty() {
            println!("(no *_adaptive artifacts in the store yet)");
        } else {
            println!("adaptive artifacts available: {adaptive:?}");
        }
    }
    Ok(())
}
