//! End-to-end serving driver (the repo's system-level validation):
//! loads the AOT transformer, then pushes a Poisson-arrival synthetic
//! workload through the full coordinator — router → batcher → paged-KV
//! admission → continuous-batching engine → PJRT decode — once with
//! full-precision attention and once with SageAttention, reporting
//! latency/throughput and output agreement.
//!
//! Run: `cargo run --release --example serve_llm -- [config] [n_requests]`

use std::time::Instant;

use sageattention::bench::{f1, Table};
use sageattention::coordinator::{
    BatchPolicy, Batcher, Engine, GenParams, KvCacheManager, Request, Scheduler,
};
use sageattention::runtime::Runtime;
use sageattention::synth::WorkloadGen;

fn run_plan(
    rt: &Runtime,
    config: &str,
    plan: &str,
    n_req: usize,
    seed: u64,
) -> anyhow::Result<(sageattention::coordinator::SchedulerReport, f64, Vec<Vec<i32>>)> {
    let engine = Engine::new(rt, config, plan, seed)?;
    println!("[{plan:>4}] kernel {} ({})", engine.kernel().name, engine.kernel().summary);
    let cfg = &rt.manifest.configs[config];
    let slots = engine.batch_slots();
    let mut gen = WorkloadGen::new(seed, cfg.vocab, 40.0, engine.prefill_sizes(), 24);
    let requests = gen.generate(n_req);

    let kv = KvCacheManager::new(slots * cfg.max_seq / 16, 16);
    let mut sched = Scheduler::new(Batcher::new(BatchPolicy::SkipSmall { window: 2 }), kv, engine);

    // open-loop arrival replay: submit when due, tick in between
    let t0 = Instant::now();
    let mut pending = requests.into_iter().enumerate().peekable();
    while pending.peek().is_some() || sched.has_work() {
        let now_ms = t0.elapsed().as_secs_f64() * 1e3;
        while let Some((i, r)) = pending.peek() {
            if r.arrival_ms <= now_ms {
                let (i, r) = (*i, pending.next().unwrap().1);
                sched.submit(Request::new(
                    i as u64,
                    r.prompt,
                    GenParams { max_new_tokens: r.max_new_tokens, ..Default::default() },
                ));
            } else {
                break;
            }
        }
        if sched.has_work() {
            sched.tick()?;
        } else if let Some((_, r)) = pending.peek() {
            // idle until the next arrival
            let wait = (r.arrival_ms - t0.elapsed().as_secs_f64() * 1e3).max(0.0);
            std::thread::sleep(std::time::Duration::from_micros((wait * 1000.0) as u64));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let occupancy = sched.engine.stats().mean_occupancy();
    let report = sched.into_report(wall);
    let mut outs: Vec<Vec<i32>> = Vec::new();
    let mut sorted = report.responses.clone();
    sorted.sort_by_key(|r| r.id);
    for r in &sorted {
        outs.push(r.tokens.clone());
    }
    println!(
        "[{plan:>4}] {} req, {} tokens, wall {:.2}s, occupancy {:.0}%",
        report.responses.len(),
        report.tokens_out,
        wall,
        occupancy * 100.0
    );
    Ok((report, occupancy, outs))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let config = args.first().map(String::as_str).unwrap_or("small").to_owned();
    let n_req: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);

    let rt = Runtime::open(Runtime::default_dir())?;
    println!(
        "serving config '{config}' ({} params) on {}\n",
        rt.manifest.configs[&config].n_params,
        rt.platform()
    );

    let (fp, _, out_fp) = run_plan(&rt, &config, "fp", n_req, 1)?;
    let (sage, _, out_sage) = run_plan(&rt, &config, "sage", n_req, 1)?;

    let mut t = Table::new(&[
        "plan", "tok/s", "TTFT p50 (ms)", "TTFT p99", "TPOT p50", "TPOT p99", "e2e p50",
    ]);
    for (name, r) in [("full-precision", &fp), ("SageAttention", &sage)] {
        t.row(&[
            name.into(),
            f1(r.throughput_tok_s()),
            f1(r.ttft.percentile(50.0)),
            f1(r.ttft.percentile(99.0)),
            f1(r.tpot.percentile(50.0)),
            f1(r.tpot.percentile(99.0)),
            f1(r.e2e.percentile(50.0)),
        ]);
    }
    t.print("serving telemetry: full-precision vs SageAttention (plug-and-play swap)");

    // plug-and-play check: greedy outputs under identical weights/workload
    let mut agree = 0usize;
    let mut total = 0usize;
    for (a, b) in out_fp.iter().zip(&out_sage) {
        total += a.len().max(b.len());
        agree += a.iter().zip(b).filter(|(x, y)| x == y).count();
    }
    println!(
        "\ngreedy token agreement fp vs sage: {agree}/{total} ({:.1}%)",
        agree as f64 / total.max(1) as f64 * 100.0
    );
    println!("(random-weight logits are near-ties, so disagreements cascade after");
    println!(" the first divergence — trained weights agree far more; see e2e_train_eval)");
    Ok(())
}
