//! Synthetic workload substrates (DESIGN.md §3):
//!
//! * QKV tensors with the paper's Figure-4 distribution structure
//!   (K = shared channel bias + small token signal) — substitutes the
//!   real-model activations behind every accuracy table.
//! * A tiny synthetic corpus (order-2 Markov chains over a small vocab)
//!   for the E2E train/eval driver.
//! * A request workload generator (Poisson arrivals, mixed prompt/output
//!   lengths) for the serving benches.

use crate::tensor::Tensor;
use crate::util::error::{bail, ensure, Context, Error, Result};
use crate::util::rng::Pcg32;

/// Distribution profile mirroring `python/compile/kernels/synth.py`.
#[derive(Clone, Copy, Debug)]
pub struct Profile {
    pub name: &'static str,
    pub k_bias_scale: f32,
    pub k_signal_scale: f32,
    pub q_scale: f32,
    pub q_bias_scale: f32,
    pub v_channel_scale: f32,
    pub heavy_tail: f32,
    /// Attention-sink strength: > 0 plants one key aligned with the mean
    /// query so every row's softmax has a dominant token plus a long flat
    /// tail ~`sink_depth` nats below. Tail probabilities land near the
    /// 1/254 rounding boundary of INT8-quantized P̃ — the worst-case-layer
    /// regime of Table 3 (real models: attention-sink layers).
    pub attn_sink: f32,
    /// How many nats below the sink the tail scores sit (5–6 is hostile).
    pub sink_depth: f32,
}

impl Profile {
    /// Llama-like: fairly uniform activations — easy to quantize (§A.6).
    pub fn llama_like() -> Profile {
        Profile {
            name: "llama-like",
            k_bias_scale: 2.0,
            k_signal_scale: 1.0,
            q_scale: 1.0,
            q_bias_scale: 0.5,
            v_channel_scale: 1.0,
            heavy_tail: 0.0,
            attn_sink: 0.0,
            sink_depth: 5.5,
        }
    }

    /// Diffusion-like (Unidiffuser/CogVideoX): strong shared channel bias
    /// in K — unsmoothed INT8 collapses here (Figure 3 / Table 18).
    pub fn diffusion_like() -> Profile {
        Profile {
            name: "diffusion-like",
            k_bias_scale: 12.0,
            k_signal_scale: 0.6,
            q_scale: 1.5,
            q_bias_scale: 2.0,
            v_channel_scale: 3.0,
            heavy_tail: 0.3,
            attn_sink: 0.0,
            sink_depth: 5.5,
        }
    }

    /// ViT-like (TIMM): moderate outliers, short sequences.
    pub fn vit_like() -> Profile {
        Profile {
            name: "vit-like",
            k_bias_scale: 5.0,
            k_signal_scale: 0.8,
            q_scale: 1.2,
            q_bias_scale: 1.0,
            v_channel_scale: 2.0,
            heavy_tail: 0.1,
            attn_sink: 0.0,
            sink_depth: 5.5,
        }
    }

    pub fn by_name(name: &str) -> Option<Profile> {
        match name {
            "llama-like" => Some(Self::llama_like()),
            "diffusion-like" => Some(Self::diffusion_like()),
            "vit-like" => Some(Self::vit_like()),
            _ => None,
        }
    }

    /// Scale outlier severity (layer sweeps: deeper layers get harsher
    /// distributions, mimicking the "worst across all layers" tables).
    pub fn with_severity(self, sev: f32) -> Profile {
        Profile {
            k_bias_scale: self.k_bias_scale * sev,
            v_channel_scale: self.v_channel_scale * sev,
            heavy_tail: self.heavy_tail * sev,
            ..self
        }
    }

    /// Add an attention-sink token (see `attn_sink`): the Table-3
    /// worst-case-layer regime.
    pub fn with_sink(self, strength: f32, depth_nats: f32) -> Profile {
        Profile { attn_sink: strength, sink_depth: depth_nats, ..self }
    }
}

/// Draw (Q, K, V) of shape [B, H, N, d] with the profile's structure.
pub fn make_qkv(seed: u64, shape: [usize; 4], p: Profile) -> (Tensor, Tensor, Tensor) {
    let [b, h, n, d] = shape;
    let mut rng = Pcg32::seeded(seed);
    let mut q = Tensor::zeros(&shape);
    let mut k = Tensor::zeros(&shape);
    let mut v = Tensor::zeros(&shape);
    for bi in 0..b {
        for hi in 0..h {
            let k_bias: Vec<f32> =
                (0..d).map(|_| rng.normal() * p.k_bias_scale).collect();
            let q_bias: Vec<f32> =
                (0..d).map(|_| rng.normal() * p.q_bias_scale).collect();
            let v_chan: Vec<f32> = (0..d)
                .map(|_| (rng.normal() * (1.0 + p.v_channel_scale).ln() * 0.5).exp())
                .collect();
            let qp = q.head_mut(bi, hi);
            for r in 0..n {
                for c in 0..d {
                    let mut x = rng.normal() * p.q_scale + q_bias[c];
                    if p.heavy_tail > 0.0 && rng.bernoulli(0.002) {
                        x += rng.normal() * 10.0 * p.heavy_tail;
                    }
                    qp[r * d + c] = x;
                }
            }
            let kp = k.head_mut(bi, hi);
            for r in 0..n {
                for c in 0..d {
                    kp[r * d + c] = k_bias[c] + rng.normal() * p.k_signal_scale;
                }
            }
            if p.attn_sink > 0.0 {
                // Plant token 0 as an attention sink: push it along the
                // mean-query direction far enough that its score clears
                // the rest of the row by ~sink_depth nats (for the mean
                // query), leaving a long tail of small probabilities.
                let qp = q.head(bi, hi);
                let mut qm = vec![0.0f32; d];
                for r in 0..n {
                    for c in 0..d {
                        qm[c] += qp[r * d + c] / n as f32;
                    }
                }
                let norm = qm.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                // mean projection of queries onto the unit mean direction
                let mean_proj = norm; // <q_i, qm/|qm|> averages to |qm|
                let beta =
                    p.attn_sink * (p.sink_depth + 2.0) * (d as f32).sqrt() / mean_proj;
                for c in 0..d {
                    kp[c] = k_bias[c] + beta * qm[c] / norm;
                }
            }
            let vp = v.head_mut(bi, hi);
            for r in 0..n {
                for c in 0..d {
                    let mut x = rng.normal() * v_chan[c];
                    if p.heavy_tail > 0.0 && rng.bernoulli(0.002) {
                        x += rng.normal() * 10.0 * p.heavy_tail;
                    }
                    vp[r * d + c] = x;
                }
            }
            if p.attn_sink > 0.0 {
                // sink tokens carry almost no value (the StreamingLLM
                // observation) — the useful output lives entirely in the
                // small tail probabilities INT8-P̃ rounds away
                for c in 0..d {
                    vp[c] *= 0.01;
                }
            }
        }
    }
    (q, k, v)
}

// ---------------------------------------------------------------------------
// Tiny corpus (E2E training)
// ---------------------------------------------------------------------------

/// Order-2 Markov token source over `vocab` symbols: enough sequential
/// structure that a transformer's loss visibly drops within a few hundred
/// steps, while being fully synthetic and reproducible.
pub struct Corpus {
    vocab: usize,
    rng: Pcg32,
    /// dense transition tables: for state (a, b) a small set of likely next
    /// tokens; sparse+deterministic mixture keeps entropy well below
    /// log(vocab) so training has signal.
    branch: usize,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        Corpus { vocab, rng: Pcg32::seeded(seed), branch: 4 }
    }

    fn next_token(&mut self, a: u32, b: u32) -> u32 {
        // deterministic candidate set derived by hashing a *coarsened*
        // state (a mod 32, b mod 32), with a small chance of a uniform
        // "noise" token. Coarsening caps the context space at 1024 states
        // × `branch` associations — learnable within a few hundred steps
        // by a few-M-parameter model, while full-vocab order-2 contexts
        // (vocab² states) would be pure noise at this data scale.
        if self.rng.bernoulli(0.1) {
            return self.rng.below(self.vocab as u32);
        }
        let pick = self.rng.below(self.branch as u32) as u64;
        let hash = ((a & 31) as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(((b & 31) as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add(pick.wrapping_mul(0x94D0_49BB_1331_11EB));
        (hash % self.vocab as u64) as u32
    }

    /// Sample a (batch, seq) token matrix, row-major.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = vec![0i32; batch * seq];
        for r in 0..batch {
            let mut a = self.rng.below(self.vocab as u32);
            let mut b = self.rng.below(self.vocab as u32);
            for c in 0..seq {
                let t = self.next_token(a, b);
                out[r * seq + c] = t as i32;
                a = b;
                b = t;
            }
        }
        out
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

// ---------------------------------------------------------------------------
// Serving workload
// ---------------------------------------------------------------------------

/// One synthetic inference request for the serving benches.
#[derive(Clone, Debug)]
pub struct SynthRequest {
    pub arrival_ms: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Poisson-arrival request stream with mixed prompt lengths.
pub struct WorkloadGen {
    rng: Pcg32,
    corpus: Corpus,
    pub rate_per_s: f32,
    pub prompt_lens: Vec<usize>,
    pub max_new: usize,
}

impl WorkloadGen {
    pub fn new(seed: u64, vocab: usize, rate_per_s: f32, prompt_lens: Vec<usize>, max_new: usize) -> Self {
        WorkloadGen {
            rng: Pcg32::seeded(seed),
            corpus: Corpus::new(vocab, seed ^ 0xC0FFEE),
            rate_per_s,
            prompt_lens,
            max_new,
        }
    }

    pub fn generate(&mut self, n: usize) -> Vec<SynthRequest> {
        let mut t = 0.0f64;
        (0..n)
            .map(|_| {
                t += self.rng.exponential(self.rate_per_s) as f64 * 1000.0;
                let plen = self.prompt_lens
                    [self.rng.below(self.prompt_lens.len() as u32) as usize];
                let prompt = self.corpus.batch(1, plen);
                let max_new = 1 + self.rng.below(self.max_new as u32) as usize;
                SynthRequest { arrival_ms: t, prompt, max_new_tokens: max_new }
            })
            .collect()
    }

    /// A shared-prefix workload: every prompt starts with the same
    /// `prefix_len`-token system prompt followed by a per-request suffix
    /// drawn from the mixed length distribution — the chat-serving shape
    /// a radix prefix cache exists for (`sage serve --workload shared`).
    pub fn generate_shared(&mut self, n: usize, prefix_len: usize) -> Vec<SynthRequest> {
        let shared = self.corpus.batch(1, prefix_len);
        let mut t = 0.0f64;
        (0..n)
            .map(|_| {
                t += self.rng.exponential(self.rate_per_s) as f64 * 1000.0;
                let slen = self.prompt_lens
                    [self.rng.below(self.prompt_lens.len() as u32) as usize];
                let mut prompt = shared.clone();
                prompt.extend(self.corpus.batch(1, slen));
                let max_new = 1 + self.rng.below(self.max_new as u32) as usize;
                SynthRequest { arrival_ms: t, prompt, max_new_tokens: max_new }
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Scenario mixes (the traffic plane's declarative workload input)
// ---------------------------------------------------------------------------

/// A named traffic scenario for the open-loop driver
/// (`sage serve --workload chat|rag|bursty|shared|mix:...`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Short prompts, medium generations, smooth Poisson arrivals.
    Chat,
    /// RAG-style long prefills (~max_seq/2+) with short generations —
    /// the head-of-line-blocking stressor chunked prefill exists for.
    Rag,
    /// Chat-shaped requests arriving in tight bursts with long gaps.
    Bursty,
    /// Every prompt shares a common system-prompt prefix (radix-cache
    /// shape, mirrors `generate_shared`).
    Shared,
}

impl Scenario {
    pub fn name(self) -> &'static str {
        match self {
            Scenario::Chat => "chat",
            Scenario::Rag => "rag",
            Scenario::Bursty => "bursty",
            Scenario::Shared => "shared",
        }
    }

    pub fn by_name(s: &str) -> Option<Scenario> {
        match s {
            "chat" => Some(Scenario::Chat),
            "rag" => Some(Scenario::Rag),
            "bursty" => Some(Scenario::Bursty),
            "shared" => Some(Scenario::Shared),
            _ => None,
        }
    }
}

/// Weighted mix of scenarios, parsed from either a bare scenario name
/// (`chat`) or the weighted form `mix:chat=0.6,rag=0.3,bursty=0.1`.
/// Weights need not sum to 1 — they are normalized at draw time.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioMix {
    pub weights: Vec<(Scenario, f32)>,
}

impl ScenarioMix {
    pub fn parse(s: &str) -> Result<ScenarioMix> {
        if let Some(rest) = s.strip_prefix("mix:") {
            let mut weights: Vec<(Scenario, f32)> = Vec::new();
            for clause in rest.split(',').map(str::trim).filter(|c| !c.is_empty()) {
                let (name, raw) = clause
                    .split_once('=')
                    .with_context(|| format!("mix clause '{clause}' missing '='"))?;
                let sc = Scenario::by_name(name).with_context(|| {
                    format!("unknown scenario '{name}' (expected chat|rag|bursty|shared)")
                })?;
                let w: f32 = raw
                    .parse()
                    .map_err(|_| Error::msg(format!("scenario '{name}': bad weight '{raw}'")))?;
                ensure!(
                    w > 0.0 && w.is_finite(),
                    "scenario '{name}': weight {w} must be positive"
                );
                ensure!(
                    !weights.iter().any(|(prev, _)| *prev == sc),
                    "scenario '{name}' listed twice"
                );
                weights.push((sc, w));
            }
            ensure!(!weights.is_empty(), "mix: wants at least one scenario=weight clause");
            Ok(ScenarioMix { weights })
        } else {
            let sc = Scenario::by_name(s).with_context(|| {
                format!("unknown workload '{s}' (expected chat|rag|bursty|shared or mix:...)")
            })?;
            Ok(ScenarioMix { weights: vec![(sc, 1.0)] })
        }
    }

    /// One-line summary; `parse` round-trips it exactly.
    pub fn summary(&self) -> String {
        if let [(sc, w)] = self.weights.as_slice() {
            if *w == 1.0 {
                return sc.name().to_owned();
            }
        }
        let parts: Vec<String> =
            self.weights.iter().map(|(sc, w)| format!("{}={}", sc.name(), w)).collect();
        format!("mix:{}", parts.join(","))
    }
}

impl WorkloadGen {
    /// Open-loop request stream drawn from a weighted scenario mix.
    /// Prompt and generation budgets are derived from (and clamped to)
    /// `max_seq` so every request fits the serving context window.
    pub fn generate_mix(
        &mut self,
        n: usize,
        mix: &ScenarioMix,
        max_seq: usize,
    ) -> Vec<SynthRequest> {
        let weights: Vec<f32> = mix.weights.iter().map(|(_, w)| *w).collect();
        let shared_len = (max_seq / 4).max(4);
        let shared = self.corpus.batch(1, shared_len);
        let span = |rng: &mut Pcg32, lo: usize, hi: usize| -> usize {
            lo + rng.below((hi.saturating_sub(lo)).max(1) as u32) as usize
        };
        let mut t = 0.0f64;
        (0..n)
            .map(|i| {
                let sc = mix.weights[self.rng.categorical(&weights)].0;
                let delta = self.rng.exponential(self.rate_per_s) as f64 * 1000.0;
                // bursty traffic: tight intra-burst spacing, long gaps
                // between bursts of ~4 — same mean offered load overall
                t += if sc == Scenario::Bursty {
                    if i % 4 == 0 {
                        delta * 3.4
                    } else {
                        delta * 0.2
                    }
                } else {
                    delta
                };
                let (prompt, budget) = match sc {
                    Scenario::Chat | Scenario::Bursty => {
                        let plen = span(&mut self.rng, (max_seq / 8).max(4), max_seq / 4);
                        (self.corpus.batch(1, plen), (max_seq / 8).max(2))
                    }
                    Scenario::Rag => {
                        let plen = span(&mut self.rng, max_seq / 2, max_seq * 3 / 4);
                        (self.corpus.batch(1, plen), 8)
                    }
                    Scenario::Shared => {
                        let slen = span(&mut self.rng, 4, (max_seq / 8).max(5));
                        let mut p = shared.clone();
                        p.extend(self.corpus.batch(1, slen));
                        (p, (max_seq / 8).max(2))
                    }
                };
                let max_new = 1 + self.rng.below(budget as u32) as usize;
                let max_new = max_new.min(max_seq.saturating_sub(prompt.len() + 1)).max(1);
                SynthRequest { arrival_ms: t, prompt, max_new_tokens: max_new }
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Fault-spec grammar (the chaos plane's declarative input)
// ---------------------------------------------------------------------------

/// A scheduled whole-replica crash: replica `replica` dies permanently at
/// its `step`-th engine step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashPoint {
    pub replica: usize,
    pub step: u64,
}

/// Declarative fault mix for the deterministic chaos plane
/// (`sage serve --faults <spec>` / `sage chaos`). Parsed from a
/// comma-separated clause list:
///
/// ```text
/// step_err:P        inject a step error with probability P per step
/// slow:Xms:P        sleep X ms before a step with probability P
/// oom:P             bounce an admission (spurious OutOfBlocks) with prob P
/// poison:P          NaN-poison the next step's logits with probability P
/// crash:rN@tM       replica N dies permanently at its M-th step
/// ```
///
/// e.g. `step_err:0.01,crash:r1@t200,slow:5ms:0.05,oom:0.02,poison:0.001`.
/// All probabilistic faults draw from one seeded stream per replica, so a
/// given `--seed` replays the identical fault schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub step_err: f32,
    pub oom: f32,
    pub poison: f32,
    /// Injected latency spike: (delay in ms, probability per step).
    pub slow_ms: f32,
    pub slow_p: f32,
    pub crashes: Vec<CrashPoint>,
}

impl FaultSpec {
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        let prob = |kind: &str, raw: &str| -> Result<f32> {
            let p: f32 = raw
                .parse()
                .map_err(|_| Error::msg(format!("fault '{kind}': bad probability '{raw}'")))?;
            ensure!((0.0..=1.0).contains(&p), "fault '{kind}': probability {p} not in [0,1]");
            Ok(p)
        };
        for clause in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (kind, rest) = clause
                .split_once(':')
                .with_context(|| format!("fault clause '{clause}' missing ':'"))?;
            match kind {
                "step_err" => spec.step_err = prob(kind, rest)?,
                "oom" => spec.oom = prob(kind, rest)?,
                "poison" => spec.poison = prob(kind, rest)?,
                "slow" => {
                    let (ms, p) = rest.split_once(':').with_context(|| {
                        format!("fault 'slow' wants slow:<X>ms:<P>, got '{clause}'")
                    })?;
                    let ms = ms.strip_suffix("ms").unwrap_or(ms);
                    spec.slow_ms = ms
                        .parse()
                        .map_err(|_| Error::msg(format!("fault 'slow': bad delay '{ms}'")))?;
                    ensure!(spec.slow_ms >= 0.0, "fault 'slow': negative delay");
                    spec.slow_p = prob(kind, p)?;
                }
                "crash" => {
                    let (r, t) = rest.split_once('@').with_context(|| {
                        format!("fault 'crash' wants crash:rN@tM, got '{clause}'")
                    })?;
                    let replica = r
                        .strip_prefix('r')
                        .and_then(|n| n.parse().ok())
                        .with_context(|| format!("fault 'crash': bad replica '{r}'"))?;
                    let step = t
                        .strip_prefix('t')
                        .and_then(|n| n.parse().ok())
                        .with_context(|| format!("fault 'crash': bad step '{t}'"))?;
                    spec.crashes.push(CrashPoint { replica, step });
                }
                other => bail!(
                    "unknown fault kind '{other}' \
                     (expected step_err|slow|oom|poison|crash)"
                ),
            }
        }
        Ok(spec)
    }

    /// No fault would ever fire under this spec.
    pub fn is_empty(&self) -> bool {
        self.step_err == 0.0
            && self.oom == 0.0
            && self.poison == 0.0
            && (self.slow_p == 0.0 || self.slow_ms == 0.0)
            && self.crashes.is_empty()
    }

    /// One-line human summary for reports.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        if self.step_err > 0.0 {
            parts.push(format!("step_err:{}", self.step_err));
        }
        if self.slow_p > 0.0 && self.slow_ms > 0.0 {
            parts.push(format!("slow:{}ms:{}", self.slow_ms, self.slow_p));
        }
        if self.oom > 0.0 {
            parts.push(format!("oom:{}", self.oom));
        }
        if self.poison > 0.0 {
            parts.push(format!("poison:{}", self.poison));
        }
        for c in &self.crashes {
            parts.push(format!("crash:r{}@t{}", c.replica, c.step));
        }
        if parts.is_empty() {
            "none".to_owned()
        } else {
            parts.join(",")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_spec_parses_full_grammar() {
        let s = FaultSpec::parse("step_err:0.01,crash:r1@t200,slow:5ms:0.05,oom:0.02,poison:0.001")
            .unwrap();
        assert_eq!(s.step_err, 0.01);
        assert_eq!(s.oom, 0.02);
        assert_eq!(s.poison, 0.001);
        assert_eq!(s.slow_ms, 5.0);
        assert_eq!(s.slow_p, 0.05);
        assert_eq!(s.crashes, vec![CrashPoint { replica: 1, step: 200 }]);
        assert!(!s.is_empty());
        // round-trips through its own summary
        assert_eq!(FaultSpec::parse(&s.summary()).unwrap(), s);
    }

    #[test]
    fn fault_spec_rejects_malformed_clauses() {
        for bad in [
            "step_err:2.0",   // probability out of range
            "step_err:x",     // not a number
            "crash:1@200",    // missing r prefix
            "crash:r1t200",   // missing @
            "slow:5ms",       // missing probability
            "explode:0.5",    // unknown kind
            "step_err",       // missing ':'
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "accepted: {bad}");
        }
        assert!(FaultSpec::parse("").unwrap().is_empty());
    }

    #[test]
    fn scenario_mix_parses_and_round_trips() {
        // bare names
        for name in ["chat", "rag", "bursty", "shared"] {
            let m = ScenarioMix::parse(name).unwrap();
            assert_eq!(m.weights.len(), 1);
            assert_eq!(m.summary(), name);
            assert_eq!(ScenarioMix::parse(&m.summary()).unwrap(), m);
        }
        // weighted form round-trips through its own summary
        let m = ScenarioMix::parse("mix:chat=0.6,rag=0.3,bursty=0.1").unwrap();
        assert_eq!(
            m.weights,
            vec![
                (Scenario::Chat, 0.6),
                (Scenario::Rag, 0.3),
                (Scenario::Bursty, 0.1)
            ]
        );
        assert_eq!(m.summary(), "mix:chat=0.6,rag=0.3,bursty=0.1");
        assert_eq!(ScenarioMix::parse(&m.summary()).unwrap(), m);
    }

    #[test]
    fn scenario_mix_rejects_malformed() {
        for bad in [
            "mix:",                 // empty clause list
            "mix:chat",             // missing '='
            "mix:chat=x",           // weight not a number
            "mix:chat=0",           // weight must be positive
            "mix:chat=-1",          // negative weight
            "mix:chat=0.5,chat=0.5", // duplicate scenario
            "mix:warp=0.5",         // unknown scenario in mix
            "quantum",              // unknown bare scenario
        ] {
            assert!(ScenarioMix::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn generate_mix_respects_scenario_shapes() {
        let max_seq = 256;
        let mut w = WorkloadGen::new(5, 256, 100.0, vec![16, 32], 16);
        let mix = ScenarioMix::parse("mix:chat=0.5,rag=0.5").unwrap();
        let reqs = w.generate_mix(200, &mix, max_seq);
        assert_eq!(reqs.len(), 200);
        let mut long_prefills = 0;
        for pair in reqs.windows(2) {
            assert!(pair[1].arrival_ms >= pair[0].arrival_ms);
        }
        for r in &reqs {
            assert!(!r.prompt.is_empty() && r.max_new_tokens >= 1);
            assert!(r.prompt.len() + r.max_new_tokens <= max_seq, "request overflows window");
            if r.prompt.len() >= max_seq / 2 {
                long_prefills += 1;
            }
        }
        assert!(long_prefills > 50, "rag half of the mix must produce long prefills");
        // shared scenario: common prefix across requests
        let mut w = WorkloadGen::new(5, 256, 100.0, vec![16], 16);
        let shared = w.generate_mix(8, &ScenarioMix::parse("shared").unwrap(), max_seq);
        let prefix = &shared[0].prompt[..max_seq / 4];
        for r in &shared {
            assert_eq!(&r.prompt[..max_seq / 4], prefix, "shared scenario must share a prefix");
        }
        // deterministic given seed
        let mut w2 = WorkloadGen::new(5, 256, 100.0, vec![16, 32], 16);
        let reqs2 = w2.generate_mix(200, &mix, max_seq);
        assert_eq!(reqs.len(), reqs2.len());
        assert!(reqs.iter().zip(&reqs2).all(|(a, b)| a.prompt == b.prompt));
    }

    #[test]
    fn k_has_channel_bias_structure() {
        let (_, k, _) = make_qkv(1, [1, 1, 512, 64], Profile::diffusion_like());
        let plane = k.head(0, 0);
        // per-channel mean should dominate per-channel (residual) std
        let mut dominated = 0;
        for c in 0..64 {
            let mean: f32 = (0..512).map(|r| plane[r * 64 + c]).sum::<f32>() / 512.0;
            let var: f32 = (0..512)
                .map(|r| (plane[r * 64 + c] - mean).powi(2))
                .sum::<f32>()
                / 512.0;
            if mean.abs() > 2.0 * var.sqrt() {
                dominated += 1;
            }
        }
        // most channels should be bias-dominated in the diffusion profile
        assert!(dominated > 40, "only {dominated}/64 channels bias-dominated");
    }

    #[test]
    fn corpus_is_learnable_structure() {
        // bigram-conditional entropy must be far below uniform entropy
        let mut c = Corpus::new(64, 9);
        let data = c.batch(64, 256);
        let mut counts = std::collections::HashMap::new();
        let mut ctx_counts = std::collections::HashMap::new();
        for row in data.chunks(256) {
            for w in row.windows(3) {
                *counts.entry((w[0], w[1], w[2])).or_insert(0u32) += 1;
                *ctx_counts.entry((w[0], w[1])).or_insert(0u32) += 1;
            }
        }
        let total: u32 = counts.values().sum();
        let mut h = 0.0f64;
        for (&(a, b, _), &n) in &counts {
            let p = n as f64 / total as f64;
            let p_cond = n as f64 / ctx_counts[&(a, b)] as f64;
            h -= p * p_cond.log2();
        }
        let uniform = (64f64).log2();
        assert!(h < 0.75 * uniform, "conditional entropy {h:.2} vs uniform {uniform:.2}");
    }

    #[test]
    fn workload_arrivals_monotone() {
        let mut w = WorkloadGen::new(3, 256, 100.0, vec![16, 32, 64], 32);
        let reqs = w.generate(50);
        assert_eq!(reqs.len(), 50);
        for pair in reqs.windows(2) {
            assert!(pair[1].arrival_ms >= pair[0].arrival_ms);
        }
        assert!(reqs.iter().all(|r| !r.prompt.is_empty() && r.max_new_tokens >= 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = make_qkv(7, [1, 1, 8, 8], Profile::llama_like());
        let b = make_qkv(7, [1, 1, 8, 8], Profile::llama_like());
        assert_eq!(a.0.data, b.0.data);
        assert_eq!(a.1.data, b.1.data);
    }
}
