//! `sage` — CLI for the SageAttention reproduction stack.
//!
//! Subcommands:
//!   smoke                         artifact round-trip sanity check
//!   serve [--plan sage] [...]     run the serving coordinator on a
//!                                 synthetic workload and print telemetry;
//!                                 --replicas N --route rr|least|power2
//!                                 drives a routed multi-replica fleet;
//!                                 --faults SPEC interposes the deterministic
//!                                 fault plane and drives a supervised fleet
//!                                 (breakers, retries, crash failover)
//!   chaos [--faults SPEC] [...]   deterministic chaos soak: same seed →
//!                                 identical fault schedule and responses
//!   trace FILE [--check]          analyze a `serve --trace` file: per-request
//!                                 critical paths + the kernel-phase latency
//!                                 share table (paper Fig. 2); --check exits
//!                                 non-zero on orphan spans or unaccounted
//!                                 requests
//!   calibrate [--out plan.json]   §4.5 adaptive-quantization calibration
//!   accuracy [--profile P]        kernel accuracy vs full precision
//!   speed [--device 4090]         cost-model kernel speed sweep
//!   kernels                       list the attention kernel registry and
//!                                 the detected ISA microkernel dispatch
//!   bench-hotpath [--seq 4096]    before/after GFLOPS on the blocked
//!                                 sage_plane hot path vs the naive loop,
//!                                 plus the PreparedKV decode lane and the
//!                                 dot-i8 / fused fp16-PV microkernel
//!                                 lanes; with --check
//!                                 FILE asserts no-regression against the
//!                                 checked-in baseline
//!
//! (arg parsing is hand-rolled: clap is unavailable offline; unknown
//! subcommands and flags exit 2 with usage instead of being ignored)

use std::collections::HashMap;
use std::time::Duration;

use sageattention::adaptive;
use sageattention::attn::isa::{self, IsaLevel};
use sageattention::attn::{
    pv, registry, sage_plane_naive, sage_plane_with, AttnImpl, AttnSpec, KvPage, PagedSegment,
    PlaneOpts, PvMode, Scratch, BLOCK_KV, BLOCK_Q, PAGE_ROWS,
};
use sageattention::bench::{bench, bench_budget, f2, pct, sci, Sample, Table};
use sageattention::coordinator::{
    BatchPolicy, Batcher, ChunkCfg, DecodeMode, Engine, EngineBackend, EngineReplica, Fleet,
    FleetCfg, FleetReport, GenParams, KvCacheManager, NativeEngine, Request, Router,
    RoutingPolicy, Scheduler, SchedulerReport, SloTargets, TrafficCfg,
};
use sageattention::metrics::{accuracy, attention_ops, LatencyStats};
use sageattention::obs::{export, Obs, PhaseTimer, DEFAULT_EVENT_CAPACITY};
use sageattention::perfmodel::{predict_tops, AttnKernel, DeviceSpec, Workpoint};
use sageattention::quant::Granularity;
use sageattention::runtime::{ModelCfg, Runtime, Value};
use sageattention::synth::{
    make_qkv, Corpus, FaultSpec, Profile, Scenario, ScenarioMix, WorkloadGen,
};
use sageattention::tensor::{default_threads, parallel_map, parallel_map_with, Tensor};
use sageattention::util::error::{ensure, Context, Result};
use sageattention::util::f16::round_f16_slice;
use sageattention::util::json::Json;
use sageattention::util::rng::Pcg32;

const USAGE: &str = "\
usage: sage <subcommand> [--key value]...   (`sage help` prints this)

subcommands:
  smoke          [--backend pjrt|native] [--artifact NAME]
                 round-trip sanity check (pjrt: artifact vs native kernels;
                 native: paged-decode bit-identity + end-to-end serve)
  serve          [--backend pjrt|native] [--config C] [--plan P] [--requests N]
                 [--seed S] [--slots N] [--kv-blocks N] [--replicas N]
                 [--route rr|least|power2] [--prefix-cache]
                 [--workload mixed|shared|chat|rag|bursty|mix:chat=0.6,rag=0.4]
                 [--faults SPEC] [--ttft-deadline T] [--total-deadline T]
                 [--prefill-chunk R] [--tick-rows R] [--slo-ttft T] [--slo-tpot T]
                 [--open-loop] [--trace FILE] [--metrics-out FILE]
                 (--prefix-cache: radix prefix cache + CoW forking, native only;
                  --workload shared: every prompt opens with one system prompt;
                  scenario names / mix:... draw from the traffic-plane scenario
                  grammar; --faults: deterministic fault plane + supervised
                  fleet, native only — SPEC is e.g. step_err:0.01,crash:r1@t200,
                  slow:5ms:0.05,oom:0.02,poison:0.001; deadlines are in virtual
                  ticks. Traffic plane (native fleet): --prefill-chunk splits
                  prefills into R-row chunks (multiple of 128 on sage plans)
                  interleaved with decode under the --tick-rows per-tick budget;
                  --slo-ttft/--slo-tpot set per-request targets in virtual ticks
                  and enable SLO shedding + goodput-under-SLO reporting;
                  --open-loop replays Poisson arrival times instead of
                  submitting everything at tick 0. Observability: --trace
                  writes a Chrome/Perfetto trace of every request's lifecycle
                  spans + engine work, --metrics-out writes a Prometheus text
                  snapshot; both arm the sampled kernel phase profiler)
  chaos          [--config C] [--plan P] [--requests N] [--seed S] [--replicas N]
                 [--slots N] [--kv-blocks N] [--route rr|least|power2]
                 [--faults SPEC] [--ttft-deadline T] [--total-deadline T]
                 deterministic chaos soak: runs the faulted fleet twice with the
                 same seed and asserts identical fault schedules and responses
  trace          FILE [--check]        analyze a `serve --trace` file: per-request
                 critical paths and the kernel-phase latency share table
                 (paper Fig. 2); --check exits non-zero on orphan spans,
                 multiple terminals, or unaccounted requests
  calibrate      [--layers N] [--profile P] [--out FILE] [--seed S]
  accuracy       [--profile P] [--seq N] [--headdim D] [--kernel NAME]
  speed          [--device 4090|3090] [--headdim D] [--causal]
  kernels                              list the kernel registry + ISA dispatch
  bench-hotpath  [--seq N] [--headdim D] [--batch B] [--heads H] [--secs S]
                 [--decode-tokens T] [--serve-seq N] [--serve-decode-tokens T]
                 [--check FILE] [--update FILE]";

/// Flags that are bare switches (no value); every other flag requires one.
const BOOLEAN_FLAGS: &[&str] = &["causal", "prefix-cache", "open-loop"];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if matches!(args.first().map(String::as_str), Some("help" | "--help" | "-h")) {
        println!("{USAGE}");
        return;
    }
    let (cmd, pos, flags) = match parse(&args) {
        Ok(parsed) => parsed,
        Err(msg) => usage_error(&msg),
    };
    if cmd == "help" {
        println!("{USAGE}");
        return;
    }
    let allowed: &[&str] = match cmd.as_str() {
        "smoke" => &["artifact", "backend"],
        "serve" => &[
            "config",
            "plan",
            "requests",
            "seed",
            "backend",
            "slots",
            "kv-blocks",
            "replicas",
            "route",
            "prefix-cache",
            "workload",
            "faults",
            "ttft-deadline",
            "total-deadline",
            "prefill-chunk",
            "tick-rows",
            "slo-ttft",
            "slo-tpot",
            "open-loop",
            "trace",
            "metrics-out",
        ],
        "chaos" => &[
            "config",
            "plan",
            "requests",
            "seed",
            "slots",
            "kv-blocks",
            "replicas",
            "route",
            "faults",
            "ttft-deadline",
            "total-deadline",
        ],
        "trace" => &["file", "check"],
        "calibrate" => &["layers", "profile", "out", "seed"],
        "accuracy" => &["profile", "seq", "headdim", "kernel"],
        "speed" => &["device", "headdim", "causal"],
        "kernels" => &[],
        "bench-hotpath" => &[
            "seq",
            "headdim",
            "batch",
            "heads",
            "secs",
            "decode-tokens",
            "serve-seq",
            "serve-decode-tokens",
            "check",
            "update",
        ],
        other => usage_error(&format!("unknown subcommand '{other}'")),
    };
    // help wins over any other flag validation (checked first so the
    // outcome never depends on HashMap iteration order)
    if flags.keys().any(|k| k == "help" || k == "h") {
        println!("{USAGE}");
        return;
    }
    // only `trace` takes a positional (the file to analyze)
    if !pos.is_empty() && cmd != "trace" {
        usage_error(&format!("unexpected positional argument '{}'", pos[0]));
    }
    if pos.len() > 1 {
        usage_error(&format!("trace takes one file, got '{}' too", pos[1]));
    }
    let mut keys: Vec<&String> = flags.keys().collect();
    keys.sort(); // deterministic error messages regardless of HashMap order
    for key in keys {
        let val = &flags[key];
        if !allowed.contains(&key.as_str()) {
            usage_error(&format!("unknown flag '--{key}' for subcommand '{cmd}'"));
        }
        // only bare boolean switches may omit a value; `--out --seed 7`
        // style mistakes are misuse, not a runtime error (`--check` is a
        // switch on `trace` but takes a baseline FILE on bench-hotpath)
        let boolean = BOOLEAN_FLAGS.contains(&key.as_str()) || (cmd == "trace" && key == "check");
        if val.is_empty() && !boolean {
            usage_error(&format!("flag '--{key}' requires a value"));
        }
        // and the switches take none: `--causal false` would otherwise
        // silently run WITH causal masking
        if !val.is_empty() && boolean {
            usage_error(&format!("flag '--{key}' is a bare switch and takes no value"));
        }
    }
    let result = match cmd.as_str() {
        "smoke" => smoke(&flags),
        "serve" => serve(&flags),
        "chaos" => chaos(&flags),
        "trace" => trace_cmd(&pos, &flags),
        "calibrate" => calibrate(&flags),
        "accuracy" => accuracy_cmd(&flags),
        "speed" => speed(&flags),
        "kernels" => kernels_cmd(),
        "bench-hotpath" => bench_hotpath(&flags),
        _ => unreachable!("subcommand validated above"),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Print the parse error + usage and exit non-zero (exit code 2
/// distinguishes CLI misuse from runtime failures, which exit 1).
fn usage_error(msg: &str) -> ! {
    eprintln!("error: {msg}\n\n{USAGE}");
    std::process::exit(2);
}

/// Split argv into (subcommand, positionals, --key value flags). A
/// `--flag` followed by another `--flag` (or nothing) is boolean-valued
/// (empty string). Errors on a missing subcommand and duplicate flags;
/// positionals after the subcommand are collected for the caller to
/// validate (only `trace` accepts one).
type Parsed = (String, Vec<String>, HashMap<String, String>);

fn parse(args: &[String]) -> std::result::Result<Parsed, String> {
    let mut flags = HashMap::new();
    let mut cmd: Option<String> = None;
    let mut positionals = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let arg = &args[i];
        if let Some(key) = arg.strip_prefix("--") {
            if key.is_empty() {
                return Err("empty flag '--'".to_owned());
            }
            let val = match args.get(i + 1) {
                Some(next) if !next.starts_with("--") => {
                    i += 2;
                    next.clone()
                }
                _ => {
                    i += 1;
                    String::new()
                }
            };
            if flags.insert(key.to_owned(), val).is_some() {
                return Err(format!("duplicate flag '--{key}'"));
            }
        } else if cmd.is_none() {
            cmd = Some(arg.clone());
            i += 1;
        } else if arg == "-h" {
            // `sage <cmd> -h` is a help request, not a stray positional
            cmd = Some("help".to_owned());
            i += 1;
        } else {
            positionals.push(arg.clone());
            i += 1;
        }
    }
    match cmd {
        Some(c) => Ok((c, positionals, flags)),
        None => Err("missing subcommand".to_owned()),
    }
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

/// Parse a numeric flag, treating a malformed or missing value as CLI
/// misuse: name the offending flag, print usage, exit 2 (runtime
/// failures keep exit 1).
fn parsed_flag<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: &str,
) -> T
where
    T::Err: std::fmt::Display,
{
    let raw = flag(flags, key, default);
    match raw.parse::<T>() {
        Ok(v) => v,
        Err(e) => usage_error(&format!("invalid value '{raw}' for '--{key}': {e}")),
    }
}

/// Round-trip sanity check. `--backend pjrt` (default): load one
/// attention artifact and compare with the rust-native exact kernel.
/// `--backend native`: zero-PJRT — pin the paged-decode bit-identity
/// invariant and serve a tiny workload end to end.
fn smoke(flags: &HashMap<String, String>) -> Result<()> {
    match flag(flags, "backend", "pjrt") {
        "native" => return smoke_native(),
        "pjrt" => {}
        other => usage_error(&format!("unknown backend '{other}' (expected pjrt|native)")),
    }
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    let name = flag(flags, "artifact", "attn_sage_b_1x2x256x64");
    let art = rt.load(name)?;
    let shape = art.spec.shape.clone().context("attention artifact missing shape")?;
    let [b, h, n, d] = [shape[0], shape[1], shape[2], shape[3]];
    let (q, k, v) = make_qkv(42, [b, h, n, d], Profile::diffusion_like());
    let out = art.run(&[
        Value::from_tensor(&q),
        Value::from_tensor(&k),
        Value::from_tensor(&v),
    ])?;
    let gold =
        AttnSpec::exact().causal(art.spec.causal.unwrap_or(false)).run(&q, &k, &v)?;
    let acc = accuracy(&gold.data, out[0].as_f32()?);
    println!("{name}: {acc}");
    ensure!(acc.cos_sim > 0.99, "artifact output diverged from reference");
    println!("smoke OK");
    Ok(())
}

/// Native-backend smoke: (1) paged decode is bit-identical to the
/// one-shot `AttnSpec::prepare`/`run_prepared` path, (2) a tiny serve
/// completes end to end with zero PJRT involvement.
fn smoke_native() -> Result<()> {
    // (1) the paged bit-identity invariant, at the attention layer
    let (n, d) = (150usize, 64usize);
    let (q, k, v) = make_qkv(42, [1, 1, n, d], Profile::diffusion_like());
    let spec = AttnSpec::sage_b().causal(true);
    let kv_state = spec.prepare(&k, &v)?;
    let gold = spec.run_prepared(&q.narrow_n(n - 1, n), &kv_state)?;
    let mut seg = PagedSegment::new(d, spec.resolve_kernel(d)?)?;
    let mut pages = vec![KvPage::new(); PagedSegment::pages_for(n)];
    for r in 0..n {
        // grow row by row, as a decode loop would
        seg.append(&mut pages, &k.data[r * d..(r + 1) * d], &v.data[r * d..(r + 1) * d]);
    }
    let refs: Vec<&KvPage> = pages.iter().collect();
    let mut scratch = Scratch::new();
    let paged =
        seg.run(&mut scratch, &q.data[(n - 1) * d..n * d], 1, &refs, PlaneOpts::causal(true));
    ensure!(
        paged == gold.data,
        "paged decode diverged from the one-shot PreparedKV path"
    );
    println!("paged-decode bit-identity: OK ({n} rows, d={d}, SageAttn-B)");

    // (2) end-to-end serve on the tiny built-in config
    let engine = Engine::native("tiny", "sage", 7)?;
    let slots = engine.batch_slots();
    let cfg = ModelCfg::builtin("tiny").unwrap();
    let kv = KvCacheManager::new(slots * cfg.max_seq.div_ceil(PAGE_ROWS), PAGE_ROWS);
    let mut sched = Scheduler::new(Batcher::new(BatchPolicy::Fifo), kv, engine);
    let mut corpus = Corpus::new(cfg.vocab, 3);
    for i in 0..2u64 {
        sched.submit(Request::new(
            i,
            corpus.batch(1, 24),
            GenParams { max_new_tokens: 6, ..Default::default() },
        ));
    }
    let report = sched.run_to_completion()?;
    ensure!(report.responses.len() == 2, "expected 2 responses");
    ensure!(report.tokens_out == 12, "expected 12 tokens, got {}", report.tokens_out);
    println!("native serve: 2 requests, {} tokens, zero PJRT", report.tokens_out);
    println!("smoke OK");
    Ok(())
}

/// Serve a synthetic workload through the full coordinator. With
/// `--replicas N` the workload is routed over N independent replicas
/// (each its own batcher + KV accountant + engine) through the
/// [`Router`] under the `--route` policy — the multi-engine front door,
/// with per-replica counts in the final report. `--replicas 1` (the
/// default) is the same machinery with a single replica.
fn serve(flags: &HashMap<String, String>) -> Result<()> {
    // validate CLI input before touching the runtime, so flag misuse
    // reports as misuse (exit 2) rather than a late runtime error
    let config = flag(flags, "config", "small");
    let plan = flag(flags, "plan", "sage");
    let n_req: usize = parsed_flag(flags, "requests", "16");
    let seed: u64 = parsed_flag(flags, "seed", "1");
    let backend = flag(flags, "backend", "pjrt");
    let replicas: usize = parsed_flag(flags, "replicas", "1");
    if replicas == 0 {
        usage_error("--replicas must be non-zero");
    }
    let route = flag(flags, "route", "rr");
    let policy = RoutingPolicy::by_name(route)
        .unwrap_or_else(|| usage_error(&format!("unknown route '{route}' (rr|least|power2)")));
    let prefix_cache = flags.contains_key("prefix-cache");
    if prefix_cache && backend != "native" {
        usage_error("--prefix-cache requires --backend native (paged physical KV)");
    }
    let workload = flag(flags, "workload", "mixed");
    // "mixed"/"shared" are the legacy closed-loop workloads; anything
    // else must parse under the traffic-plane scenario grammar
    let scenario_mix: Option<ScenarioMix> = match workload {
        "mixed" | "shared" => None,
        other => match ScenarioMix::parse(other) {
            Ok(m) => Some(m),
            Err(e) => usage_error(&format!(
                "unknown workload '{other}': {e:#} (expected mixed|shared, a scenario \
                 chat|rag|bursty|shared, or mix:chat=0.6,rag=0.4)"
            )),
        },
    };
    // --kv-blocks is validated here (before any engine is built) so flag
    // misuse still exits 2 without paying N model constructions; the
    // per-replica default is resolved later, once slots/max_seq are known
    let kv_blocks: Option<usize> = flags.get("kv-blocks").map(|_| {
        let blocks: usize = parsed_flag(flags, "kv-blocks", "0");
        if blocks == 0 {
            usage_error("--kv-blocks must be non-zero");
        }
        blocks
    });

    // --faults switches to the supervised single-threaded fleet driver
    // (virtual time: breaker cooldowns / backoff / deadlines replay
    // deterministically from --seed); deadlines are virtual-tick-based
    // and only meaningful there
    // observability: either export flag arms the shared handle (ring
    // recorder + metrics registry + sampled kernel phase profiler); with
    // neither, every emission site stays on its disabled no-op branch
    let trace_out = flags.get("trace").cloned();
    let metrics_out = flags.get("metrics-out").cloned();
    let obs = if trace_out.is_some() || metrics_out.is_some() {
        Obs::with_capacity(DEFAULT_EVENT_CAPACITY)
    } else {
        Obs::disabled()
    };

    let faults = parse_faults_flag(flags);
    let traffic = parse_traffic_flags(flags);
    let deadlines = parse_deadline_flags(flags);
    // the virtual-tick fleet path serves faults AND the traffic plane;
    // either set of flags engages it (deadlines only mean anything there)
    if faults.is_none()
        && traffic.is_none()
        && (deadlines.0.is_some() || deadlines.1.is_some())
    {
        usage_error(
            "--ttft-deadline/--total-deadline require the virtual-tick fleet \
             (--faults, --prefill-chunk, --slo-ttft/--slo-tpot, or --open-loop)",
        );
    }
    if faults.is_some() || traffic.is_some() {
        if backend != "native" {
            usage_error(
                "--faults and the traffic-plane flags require --backend native \
                 (deterministic offline fleet)",
            );
        }
        if prefix_cache {
            usage_error("--faults/traffic-plane flags with --prefix-cache are not supported yet");
        }
        let slots: usize = parsed_flag(flags, "slots", "4");
        if slots == 0 {
            usage_error("--slots must be non-zero");
        }
        let spec = faults.unwrap_or_default();
        let traffic = traffic.unwrap_or_default();
        // on the fleet path every non-"mixed" workload routes through
        // the scenario grammar ("shared" = the shared-prefix scenario)
        let fleet_mix = match (&scenario_mix, workload) {
            (Some(m), _) => Some(m.clone()),
            (None, "shared") => {
                Some(ScenarioMix { weights: vec![(Scenario::Shared, 1.0)] })
            }
            _ => None,
        };
        let fleet_cfg = FleetCfg {
            tick_prefill_rows: traffic.chunk.map(|c| c.tick_rows),
            ..FleetCfg::default()
        };
        let report = run_faulted_fleet(
            config,
            plan,
            n_req,
            seed,
            replicas,
            slots,
            kv_blocks,
            &spec,
            policy,
            deadlines,
            fleet_cfg,
            traffic,
            fleet_mix.as_ref(),
            obs.clone(),
        )?;
        print_fleet_report(&report, &spec, policy);
        write_obs_outputs(&obs, trace_out.as_deref(), metrics_out.as_deref())?;
        ensure!(
            report.fully_accounted(),
            "fleet dropped {} request(s) without a terminal response",
            report.dropped
        );
        return Ok(());
    }

    // all replicas share one seed: a fleet serves replicas of one model
    let mut engines = Vec::with_capacity(replicas);
    let (vocab, max_seq) = match backend {
        "pjrt" => {
            let rt = Runtime::open(Runtime::default_dir())?;
            for _ in 0..replicas {
                engines.push(Engine::pjrt(&rt, config, plan, seed)?);
            }
            let cfg = &rt.manifest.configs[config];
            (cfg.vocab, cfg.max_seq)
        }
        "native" => {
            let cfg = ModelCfg::builtin(config)
                .with_context(|| format!("'{config}' is not a built-in config (tiny|small)"))?;
            let slots: usize = parsed_flag(flags, "slots", "4");
            if slots == 0 {
                usage_error("--slots must be non-zero");
            }
            for _ in 0..replicas {
                engines.push(if prefix_cache {
                    Engine::native_cached(cfg.clone(), plan, seed, slots)?
                } else {
                    Engine::native_with(cfg.clone(), plan, seed, slots)?
                });
            }
            (cfg.vocab, cfg.max_seq)
        }
        other => usage_error(&format!("unknown backend '{other}' (expected pjrt|native)")),
    };
    println!(
        "backend '{}', plan '{plan}' → kernel {} ({}); {replicas} replica(s), '{}' routing",
        engines[0].backend_name(),
        engines[0].kernel().name,
        engines[0].kernel().summary,
        policy.name()
    );

    // block math: pjrt commits dense caches (block 16, legacy sizing);
    // native pages physically at PAGE_ROWS and takes --kv-blocks to
    // shrink the pool (exercises the preemption policy)
    let kv_for = |engine: &Engine| -> KvCacheManager {
        let slots = engine.batch_slots();
        match backend {
            "native" => {
                let default_blocks = slots * max_seq.div_ceil(PAGE_ROWS);
                KvCacheManager::new(kv_blocks.unwrap_or(default_blocks), PAGE_ROWS)
            }
            _ => KvCacheManager::new(slots * max_seq / 16, 16),
        }
    };
    let prefill_sizes = engines[0].prefill_sizes();
    let mut reps: Vec<EngineReplica> = engines
        .into_iter()
        .enumerate()
        .map(|(id, engine)| {
            let kv = kv_for(&engine);
            EngineReplica::new(id, Scheduler::new(Batcher::new(BatchPolicy::Fifo), kv, engine))
        })
        .collect();
    for rep in &mut reps {
        // thread-per-replica: each scheduler owns its own submit/finish
        // spans (no fleet above it), all funneled into one shared ring
        rep.sched.set_obs(obs.clone(), rep.id as u32, false);
    }

    // shared workload: half the context window is one system prompt every
    // request opens with; suffix lengths shrink to keep prompt + budget
    // inside max_seq
    let max_new = 24;
    let shared_prefix = max_seq / 2;
    let sizes = match workload {
        "shared" => {
            let cap = max_seq.saturating_sub(shared_prefix + max_new);
            let kept: Vec<usize> =
                prefill_sizes.iter().copied().filter(|&s| s <= cap).collect();
            if kept.is_empty() { vec![cap.max(1)] } else { kept }
        }
        _ => prefill_sizes,
    };
    let mut gen = WorkloadGen::new(seed, vocab, 50.0, sizes, max_new);
    // scenario mixes work closed-loop too (arrival times are ignored —
    // add --open-loop to replay them through the virtual-tick fleet)
    let reqs = match (&scenario_mix, workload) {
        (Some(m), _) => gen.generate_mix(n_req, m, max_seq),
        (None, "shared") => gen.generate_shared(n_req, shared_prefix),
        _ => gen.generate(n_req),
    };
    let mut router = Router::new(policy, reps.len());
    for (i, r) in reqs.into_iter().enumerate() {
        let req = Request::new(
            i as u64,
            r.prompt,
            GenParams { max_new_tokens: r.max_new_tokens, ..Default::default() },
        );
        ensure!(router.route(&mut reps, &req).is_ok(), "no replica accepted request {i}");
    }

    // drive every replica on its own thread, as a real fleet would —
    // ticking them round-robin on one thread would bill each request's
    // wall-clock TTFT/TPOT for the other replicas' compute
    let t0 = std::time::Instant::now();
    let drive_errs: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = reps
            .iter_mut()
            .map(|rep| {
                scope.spawn(move || -> std::result::Result<(), String> {
                    while rep.sched.has_work() {
                        rep.sched.tick().map_err(|e| format!("{e:#}"))?;
                    }
                    Ok(())
                })
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| h.join().expect("replica thread panicked").err())
            .collect()
    });
    ensure!(drive_errs.is_empty(), "replica error(s): {}", drive_errs.join("; "));
    let wall = t0.elapsed().as_secs_f64();

    let routed = router.routed.clone();
    let (mut total_resp, mut total_tokens) = (0usize, 0u64);
    let (mut total_preempt, mut total_requeued) = (0u64, 0u64);
    let (mut total_lookups, mut total_hits) = (0u64, 0u64);
    let (mut total_saved, mut total_evict, mut total_cow) = (0u64, 0u64, 0u64);
    let (mut fleet_ttft, mut fleet_tpot) = (LatencyStats::default(), LatencyStats::default());
    let mut fleet_queue = LatencyStats::default();
    let mut t =
        Table::new(&["replica", "routed", "served", "tokens", "TTFT p50 ms", "TPOT p50 ms"]);
    for EngineReplica { id, sched } in reps {
        let rep = sched.into_report(wall);
        total_resp += rep.responses.len();
        total_tokens += rep.tokens_out;
        total_preempt += rep.preemptions;
        total_requeued += rep.requeued;
        total_lookups += rep.prefix_lookups;
        total_hits += rep.prefix_hits;
        total_saved += rep.prefill_tokens_saved;
        total_evict += rep.cache_evictions;
        total_cow += rep.cow_copies;
        fleet_ttft.merge(&rep.ttft);
        fleet_tpot.merge(&rep.tpot);
        fleet_queue.merge(&rep.queue_delay);
        t.row(&[
            id.to_string(),
            routed[id].to_string(),
            rep.responses.len().to_string(),
            rep.tokens_out.to_string(),
            format!("{:.1}", rep.ttft.percentile(50.0)),
            format!("{:.1}", rep.tpot.percentile(50.0)),
        ]);
    }
    t.print(&format!("serving report ({replicas} replica(s), '{}' routing)", policy.name()));
    let tok_s = if wall > 0.0 { total_tokens as f64 / wall } else { 0.0 };
    println!(
        "\nfleet: served {total_resp} requests, {total_tokens} tokens in {wall:.2}s \
         ({tok_s:.1} tok/s)"
    );
    println!(
        "TTFT p50/p95/p99: {} ms   TPOT p50/p95/p99: {} ms   \
         queue delay p50/p95/p99: {} ms",
        percentile_triple(&fleet_ttft),
        percentile_triple(&fleet_tpot),
        percentile_triple(&fleet_queue)
    );
    if total_preempt > 0 || total_requeued > 0 {
        println!(
            "preemptions: {total_preempt} (recompute-on-resume)   \
             requeued admissions: {total_requeued}"
        );
    }
    if prefix_cache {
        let hit_rate =
            if total_lookups > 0 { total_hits as f64 / total_lookups as f64 } else { 0.0 };
        println!(
            "prefix cache: {total_hits}/{total_lookups} hits ({:.0}%), \
             {total_saved} prefill tokens saved, {total_evict} evictions, \
             {total_cow} CoW block copies",
            hit_rate * 100.0
        );
    }
    write_obs_outputs(&obs, trace_out.as_deref(), metrics_out.as_deref())?;
    ensure!(total_resp == n_req, "fleet served {total_resp} of {n_req} routed requests");
    Ok(())
}

/// `p50/p95/p99` rendering for the serve report latency lines.
fn percentile_triple(s: &LatencyStats) -> String {
    format!("{:.1}/{:.1}/{:.1}", s.percentile(50.0), s.percentile(95.0), s.percentile(99.0))
}

/// Write the `--trace` / `--metrics-out` exports from the shared handle.
fn write_obs_outputs(
    obs: &Obs,
    trace_out: Option<&str>,
    metrics_out: Option<&str>,
) -> Result<()> {
    if let Some(path) = trace_out {
        let doc = export::chrome_trace(&obs.events(), &obs.snapshot());
        std::fs::write(path, format!("{doc}\n"))
            .with_context(|| format!("writing trace {path}"))?;
        let snap = obs.snapshot();
        println!(
            "trace: {} events ({} dropped) -> {path} \
             (load in Perfetto, or `sage trace {path}`)",
            snap.events_recorded, snap.events_dropped
        );
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, export::prometheus(&obs.snapshot()))
            .with_context(|| format!("writing metrics {path}"))?;
        println!("metrics: Prometheus text exposition -> {path}");
    }
    Ok(())
}

/// Parse `--faults SPEC` (CLI misuse exits 2); `None` when absent or empty.
fn parse_faults_flag(flags: &HashMap<String, String>) -> Option<FaultSpec> {
    let raw = flags.get("faults")?;
    match FaultSpec::parse(raw) {
        Ok(spec) if spec.is_empty() => None,
        Ok(spec) => Some(spec),
        Err(e) => usage_error(&format!("invalid --faults spec: {e:#}")),
    }
}

/// Parse the virtual-tick deadline flags: (ttft, total).
fn parse_deadline_flags(flags: &HashMap<String, String>) -> (Option<u64>, Option<u64>) {
    let get = |key: &str| -> Option<u64> {
        flags.get(key).map(|_| {
            let t: u64 = parsed_flag(flags, key, "0");
            if t == 0 {
                usage_error(&format!("--{key} must be non-zero (virtual ticks)"));
            }
            t
        })
    };
    (get("ttft-deadline"), get("total-deadline"))
}

/// Virtual-time scale for open-loop arrival replay: one fleet tick
/// stands for 20ms of arrival time — the mean inter-arrival gap at the
/// workload generator's default 50 req/s, so the default offered load
/// is ~one arrival per tick.
const OPEN_LOOP_TICK_MS: f64 = 20.0;

/// Parse the traffic-plane flags (`--prefill-chunk`, `--tick-rows`,
/// `--slo-ttft`, `--slo-tpot`, `--open-loop`). `None` when none are
/// present; any of them engages the virtual-tick fleet path.
fn parse_traffic_flags(flags: &HashMap<String, String>) -> Option<TrafficCfg> {
    let chunk_rows: Option<usize> = flags.get("prefill-chunk").map(|_| {
        let rows: usize = parsed_flag(flags, "prefill-chunk", "0");
        if rows == 0 {
            usage_error("--prefill-chunk must be non-zero (rows per chunk)");
        }
        rows
    });
    let tick_rows: Option<usize> = flags.get("tick-rows").map(|_| {
        let rows: usize = parsed_flag(flags, "tick-rows", "0");
        if rows == 0 {
            usage_error("--tick-rows must be non-zero (prefill rows per tick)");
        }
        rows
    });
    if tick_rows.is_some() && chunk_rows.is_none() {
        usage_error("--tick-rows requires --prefill-chunk");
    }
    let slo_ttft: Option<u64> = flags.get("slo-ttft").map(|_| {
        let t: u64 = parsed_flag(flags, "slo-ttft", "0");
        if t == 0 {
            usage_error("--slo-ttft must be non-zero (virtual ticks)");
        }
        t
    });
    let slo_tpot: Option<f64> = flags.get("slo-tpot").map(|_| {
        let t: f64 = parsed_flag(flags, "slo-tpot", "0");
        if !t.is_finite() || t <= 0.0 {
            usage_error("--slo-tpot must be positive (virtual ticks per token)");
        }
        t
    });
    let open_loop = flags.contains_key("open-loop");
    if chunk_rows.is_none() && slo_ttft.is_none() && slo_tpot.is_none() && !open_loop {
        return None;
    }
    let chunk = chunk_rows.map(|rows| {
        match ChunkCfg::new(rows, tick_rows.unwrap_or(rows)) {
            Ok(cfg) => cfg,
            Err(e) => usage_error(&format!("invalid chunked-prefill config: {e:#}")),
        }
    });
    Some(TrafficCfg {
        chunk,
        slo: SloTargets { ttft_ticks: slo_ttft, tpot_ticks: slo_tpot },
        open_loop,
        tick_ms: OPEN_LOOP_TICK_MS,
    })
}

/// Build a supervised native fleet with the fault plane interposed on
/// every replica, submit the synthetic workload (the legacy mixed
/// stream, or a traffic-plane scenario mix), and drive it to completion
/// in virtual time — with the traffic plane (chunked prefill, token
/// streaming, SLO targets, open-loop arrivals) applied per `traffic`.
/// Fully deterministic for a given (config, plan, seed, spec, workload)
/// — the chaos soak replays it.
#[allow(clippy::too_many_arguments)]
fn run_faulted_fleet(
    config: &str,
    plan: &str,
    n_req: usize,
    seed: u64,
    replicas: usize,
    slots: usize,
    kv_blocks: Option<usize>,
    spec: &FaultSpec,
    policy: RoutingPolicy,
    (ttft_deadline, total_deadline): (Option<u64>, Option<u64>),
    fleet_cfg: FleetCfg,
    traffic: TrafficCfg,
    mix: Option<&ScenarioMix>,
    obs: Obs,
) -> Result<FleetReport> {
    let cfg = ModelCfg::builtin(config)
        .with_context(|| format!("'{config}' is not a built-in config (tiny|small)"))?;
    let mut scheds = Vec::with_capacity(replicas);
    for i in 0..replicas {
        let engine =
            Engine::native_with(cfg.clone(), plan, seed, slots)?.faulted(spec.clone(), seed, i);
        let default_blocks = slots * cfg.max_seq.div_ceil(PAGE_ROWS);
        let kv = KvCacheManager::new(kv_blocks.unwrap_or(default_blocks), PAGE_ROWS);
        scheds.push(Scheduler::new(Batcher::new(BatchPolicy::Fifo), kv, engine));
    }
    let sizes = scheds[0].engine.prefill_sizes();
    let mut fleet = Fleet::new(scheds, policy, fleet_cfg);
    fleet.set_obs(obs);
    // streaming is always on in the fleet path: TTFT is first-streamed-
    // token time and the ledger proves no duplicate/gap across failover
    fleet.enable_streaming();
    if let Some(chunk) = traffic.chunk {
        ensure!(
            fleet.set_chunked_prefill(chunk),
            "plan '{plan}' cannot chunk prefill at {} rows: chunks must align to the \
             plan's Q scale-group size ({BLOCK_Q} rows on the per-block sage plans)",
            chunk.chunk_rows
        );
    }
    let max_new = 16;
    let mut gen = WorkloadGen::new(seed, cfg.vocab, 50.0, sizes, max_new);
    let synth = match mix {
        Some(m) => gen.generate_mix(n_req, m, cfg.max_seq),
        None => gen.generate(n_req),
    };
    for (i, r) in synth.into_iter().enumerate() {
        let req = Request::new(
            i as u64,
            r.prompt,
            GenParams {
                max_new_tokens: r.max_new_tokens,
                ttft_deadline,
                total_deadline,
                slo_ttft: traffic.slo.ttft_ticks,
                slo_tpot: traffic.slo.tpot_ticks,
                ..Default::default()
            },
        );
        if traffic.open_loop {
            // honor the generator's Poisson arrival process: the request
            // enters fleet time at its arrival tick, not at tick 0
            let due = (r.arrival_ms / traffic.tick_ms.max(1e-9)).round() as u64;
            fleet.submit_at(req, due);
        } else {
            fleet.submit(req);
        }
    }
    fleet.run_to_completion()
}

/// Print the fleet's fault-tolerance telemetry (per-replica table,
/// terminal accounting, injected/recovery counters, retries histogram).
fn print_fleet_report(rep: &FleetReport, spec: &FaultSpec, policy: RoutingPolicy) {
    let mut t =
        Table::new(&["replica", "served", "tokens", "injected", "degraded", "preempt"]);
    for (i, r) in rep.replicas.iter().enumerate() {
        t.row(&[
            i.to_string(),
            r.served().to_string(),
            r.tokens_out.to_string(),
            r.injected.to_string(),
            r.degraded_fallbacks.to_string(),
            r.preemptions.to_string(),
        ]);
    }
    t.print(&format!(
        "fleet under faults '{}' ('{}' routing)",
        spec.summary(),
        policy.name()
    ));
    println!(
        "\nsubmitted {} | served {} | failed {} | deadline-cancelled {} | shed {} | dropped {}",
        rep.submitted, rep.served, rep.failed, rep.cancelled_deadline, rep.shed, rep.dropped
    );
    println!(
        "injected {} | retried {} | failed-over {} | degraded fallbacks {}",
        rep.injected, rep.retried, rep.failed_over, rep.degraded_fallbacks
    );
    if rep.streamed_tokens > 0 || rep.stream_duplicates > 0 || rep.stream_gaps > 0 {
        println!(
            "streamed {} tokens ({} duplicates, {} gaps)",
            rep.streamed_tokens, rep.stream_duplicates, rep.stream_gaps
        );
    }
    if rep.slo_tracked > 0 {
        println!(
            "SLO: {}/{} tracked requests met their targets \
             (goodput-under-SLO {:.0}%, {} shed up front)",
            rep.slo_met,
            rep.slo_tracked,
            rep.goodput_under_slo_frac() * 100.0,
            rep.shed
        );
    }
    let mut queue_delay = LatencyStats::default();
    let (mut ttft, mut tpot) = (LatencyStats::default(), LatencyStats::default());
    for r in &rep.replicas {
        queue_delay.merge(&r.queue_delay);
        ttft.merge(&r.ttft);
        tpot.merge(&r.tpot);
    }
    if !ttft.is_empty() {
        println!(
            "TTFT p50/p95/p99: {} ms   TPOT p50/p95/p99: {} ms",
            percentile_triple(&ttft),
            percentile_triple(&tpot)
        );
    }
    if !queue_delay.is_empty() {
        println!(
            "queue delay (arrival→admission) p50/p95/p99: {} ms",
            percentile_triple(&queue_delay)
        );
    }
    // latency stats (replica-side) cover first-success attempts only;
    // the histogram shows how many re-dispatches each request needed
    let hist = rep
        .retries_hist
        .iter()
        .enumerate()
        .map(|(k, n)| {
            if k + 1 == rep.retries_hist.len() {
                format!("{k}+:{n}")
            } else {
                format!("{k}:{n}")
            }
        })
        .collect::<Vec<_>>()
        .join("  ");
    println!("retries histogram (re-dispatches per request): {hist}");
    println!(
        "{} tokens over {} virtual ticks ({:.2}s wall); accounting {}",
        rep.tokens_out(),
        rep.ticks,
        rep.wall_s,
        if rep.fully_accounted() {
            "clean (served+failed+cancelled+shed == submitted)"
        } else {
            "BROKEN"
        }
    );
}

/// `sage chaos` — the deterministic chaos soak. Runs the faulted fleet
/// twice with the identical seed + spec and asserts that the injected
/// fault schedule and every terminal response replay bit-identically
/// (ISSUE 7 acceptance), and that no run drops a request.
fn chaos(flags: &HashMap<String, String>) -> Result<()> {
    let config = flag(flags, "config", "tiny");
    let plan = flag(flags, "plan", "sage");
    let n_req: usize = parsed_flag(flags, "requests", "24");
    let seed: u64 = parsed_flag(flags, "seed", "7");
    let replicas: usize = parsed_flag(flags, "replicas", "2");
    if replicas == 0 {
        usage_error("--replicas must be non-zero");
    }
    let slots: usize = parsed_flag(flags, "slots", "4");
    if slots == 0 {
        usage_error("--slots must be non-zero");
    }
    let route = flag(flags, "route", "rr");
    let policy = RoutingPolicy::by_name(route)
        .unwrap_or_else(|| usage_error(&format!("unknown route '{route}' (rr|least|power2)")));
    let kv_blocks: Option<usize> = flags.get("kv-blocks").map(|_| {
        let blocks: usize = parsed_flag(flags, "kv-blocks", "0");
        if blocks == 0 {
            usage_error("--kv-blocks must be non-zero");
        }
        blocks
    });
    let deadlines = parse_deadline_flags(flags);
    let spec = match parse_faults_flag(flags) {
        Some(spec) => spec,
        None => {
            // default soak mix: transient step errors, admission bounces,
            // occasional poisoned logits — plus a mid-run crash of the
            // last replica when there is someone to fail over to
            let mut s = String::from("step_err:0.02,oom:0.05,poison:0.01");
            if replicas > 1 {
                s.push_str(&format!(",crash:r{}@t40", replicas - 1));
            }
            FaultSpec::parse(&s).expect("default chaos spec parses")
        }
    };

    println!(
        "chaos soak: {n_req} requests, {replicas} replica(s), seed {seed}, \
         faults '{}' — running twice\n",
        spec.summary()
    );
    let run = || {
        run_faulted_fleet(
            config,
            plan,
            n_req,
            seed,
            replicas,
            slots,
            kv_blocks,
            &spec,
            policy,
            deadlines,
            FleetCfg::default(),
            TrafficCfg::default(),
            None,
            Obs::disabled(),
        )
    };
    let a = run()?;
    let b = run()?;
    print_fleet_report(&a, &spec, policy);

    // 1. identical injected-fault schedule, per replica and in total
    let inj = |r: &FleetReport| -> Vec<u64> { r.replicas.iter().map(|s| s.injected).collect() };
    ensure!(
        inj(&a) == inj(&b),
        "fault schedules diverged across replays: {:?} vs {:?}",
        inj(&a),
        inj(&b)
    );
    // 2. identical terminal responses (id, tokens, finish reason)
    ensure!(
        a.responses.len() == b.responses.len(),
        "replay produced {} responses vs {}",
        a.responses.len(),
        b.responses.len()
    );
    for (ra, rb) in a.responses.iter().zip(&b.responses) {
        ensure!(
            ra.id == rb.id && ra.tokens == rb.tokens && ra.finish == rb.finish,
            "response {} diverged across replays ({:?} vs {:?})",
            ra.id,
            ra.finish,
            rb.finish
        );
    }
    // 3. no silent drops in either run
    for (name, r) in [("first", &a), ("second", &b)] {
        ensure!(
            r.fully_accounted(),
            "{name} run dropped {} request(s) without a terminal response",
            r.dropped
        );
    }
    println!(
        "\nchaos OK: two runs with seed {seed} replayed {} injected faults and \
         {} terminal responses bit-identically",
        a.injected,
        a.responses.len()
    );
    Ok(())
}

/// `sage trace FILE` — re-read an emitted Chrome trace and print each
/// request's critical path (submit → admit → first token → terminal)
/// plus the kernel-phase latency share table, the serving-stack analog
/// of the paper's Figure 2 "which phase dominates" breakdown. With
/// `--check`, exit non-zero on any well-formedness problem: orphan
/// spans, missing or duplicate terminals, accounting mismatches, or
/// dropped events.
fn trace_cmd(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let file = match (pos.first(), flags.get("file")) {
        (Some(p), _) => p.as_str(),
        (None, Some(f)) => f.as_str(),
        (None, None) => usage_error("trace needs a file: `sage trace out.json` (or --file)"),
    };
    let text =
        std::fs::read_to_string(file).with_context(|| format!("reading trace {file}"))?;
    let doc = Json::parse(&text).with_context(|| format!("parsing {file}"))?;
    let rep = export::analyze(&doc)?;

    let fmt_opt = |v: Option<f64>| v.map_or("-".to_owned(), |v| format!("{v:.2}"));
    let mut t = Table::new(&[
        "req", "prompt", "queue ms", "ttft ms", "total ms", "chunks", "preempt", "retries",
        "terminal",
    ]);
    for r in &rep.requests {
        t.row(&[
            r.id.to_string(),
            r.prompt_len.to_string(),
            fmt_opt(r.admit_us.map(|a| (a - r.submit_us) / 1e3)),
            fmt_opt(r.first_token_us.map(|f| (f - r.submit_us) / 1e3)),
            format!("{:.2}", (r.terminal_us - r.submit_us) / 1e3),
            r.chunks.to_string(),
            r.preempts.to_string(),
            r.retries.to_string(),
            r.terminal.clone(),
        ]);
    }
    t.print(&format!("per-request critical paths ({file})"));

    let total_ns: u64 = rep.phases.iter().map(|&(_, ns)| ns).sum();
    if total_ns > 0 {
        let mut tp = Table::new(&["phase", "ns", "share"]);
        for (name, ns) in &rep.phases {
            tp.row(&[name.clone(), ns.to_string(), pct(*ns as f64 / total_ns as f64)]);
        }
        tp.print(&format!(
            "kernel phase latency share ({} sampled planes; cf. paper Fig. 2)",
            rep.phase_samples
        ));
    } else {
        println!("\nno sampled kernel phases in this trace (engine profiling was off)");
    }

    println!(
        "\n{} submitted, {} reached a terminal; {} event(s) dropped",
        rep.submitted,
        rep.requests.len(),
        rep.events_dropped
    );
    if !rep.problems.is_empty() {
        println!("\n{} problem(s):", rep.problems.len());
        for p in &rep.problems {
            println!("  - {p}");
        }
    }
    if flags.contains_key("check") {
        ensure!(
            rep.problems.is_empty(),
            "trace check failed: {} problem(s) (listed above)",
            rep.problems.len()
        );
        println!("trace check OK: every submitted request is accounted for");
    }
    Ok(())
}

/// §4.5 calibration: choose -vB vs -B per layer, write the plan JSON that
/// `aot.py --plan-file` consumes.
fn calibrate(flags: &HashMap<String, String>) -> Result<()> {
    let n_layers: usize = parsed_flag(flags, "layers", "4");
    let profile = Profile::by_name(flag(flags, "profile", "diffusion-like"))
        .context("unknown profile")?;
    let out = flag(flags, "out", "plan.json");
    let seed: u64 = parsed_flag(flags, "seed", "7");
    let layers = adaptive::synth_layer_inputs(n_layers, [1, 4, 256, 64], profile, seed);
    let (plan, detail) = adaptive::calibrate(&layers, false);
    let mut t = Table::new(&["layer", "cos(-vB)", "cos(-B)", "choice"]);
    for d in &detail {
        t.row(&[
            d.layer.to_string(),
            pct(d.cos_vb as f64),
            pct(d.cos_b as f64),
            d.choice.to_string(),
        ]);
    }
    t.print("adaptive calibration (threshold 99.8%)");
    // every plan entry must resolve through the kernel registry before
    // it is handed to aot.py
    plan.kernels()?;
    std::fs::write(out, plan.to_json())?;
    println!(
        "\nwrote {out}; estimated attention speedup over all--B: {:.1}%",
        (plan.speedup_estimate() - 1.0) * 100.0
    );
    Ok(())
}

/// Kernel accuracy vs full precision on a synthetic profile (Table 9 style).
fn accuracy_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let profile = Profile::by_name(flag(flags, "profile", "diffusion-like"))
        .context("unknown profile")?;
    let n: usize = parsed_flag(flags, "seq", "512");
    let d: usize = parsed_flag(flags, "headdim", "64");
    let names: Vec<String> = match flags.get("kernel") {
        Some(name) => vec![name.clone()],
        None => ["SageAttn-T", "SageAttn-B", "SageAttn-vT", "SageAttn-vB"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    let (q, k, v) = make_qkv(3, [2, 4, n, d], profile);
    let gold = AttnSpec::exact().run(&q, &k, &v)?;
    let mut t = Table::new(&["kernel", "CosSim", "RelL1", "RMSE"]);
    for name in &names {
        let spec = AttnSpec::by_name(name)?;
        let o = spec.run(&q, &k, &v)?;
        let a = accuracy(&gold.data, &o.data);
        t.row(&[
            name.clone(),
            pct(a.cos_sim as f64),
            f2(a.rel_l1 as f64 * 100.0) + "e-2",
            sci(a.rmse as f64),
        ]);
    }
    t.print(&format!("kernel accuracy ({} profile, N={n}, d={d})", profile.name));
    Ok(())
}

/// Cost-model speed sweep (Figures 6–9 style) on one device.
fn speed(flags: &HashMap<String, String>) -> Result<()> {
    let dev: &DeviceSpec =
        DeviceSpec::by_name(flag(flags, "device", "4090")).context("unknown device")?;
    let d: usize = parsed_flag(flags, "headdim", "64");
    let causal = flags.contains_key("causal");
    let kernels = [
        AttnKernel::TorchNaive,
        AttnKernel::Xformers,
        AttnKernel::FlashAttention2,
        AttnKernel::SageAttnB,
        AttnKernel::SageAttnVB,
    ];
    let mut t =
        Table::new(&["seq", "Torch", "xformers", "FlashAttn2", "SageAttn-B", "SageAttn-vB"]);
    for n in [1024usize, 2048, 4096, 8192, 16384, 32768] {
        let wp = Workpoint::square(4, 32, n, d, causal);
        let mut row = vec![n.to_string()];
        for k in kernels {
            row.push(f2(predict_tops(dev, k, wp)));
        }
        t.row(&row);
    }
    t.print(&format!(
        "predicted TOPS, {} headdim={d}{}",
        dev.name,
        if causal { " causal" } else { "" }
    ));
    Ok(())
}

/// List the attention kernel registry (the `core.py:sageattn` dispatch
/// table, as data) plus the detected ISA microkernel dispatch.
fn kernels_cmd() -> Result<()> {
    let caps = isa::cpu::caps();
    let act = isa::cpu::active();
    let override_note = match act.requested {
        None => "none".to_string(),
        Some(req) if req == act.level => format!("SAGE_ISA={}", req.name()),
        Some(req) => {
            format!("SAGE_ISA={} (unsupported on this host, falling back to scalar)", req.name())
        }
    };
    println!(
        "cpu ISA: active {} (detected best {}, f16c {}; override: {override_note})\n",
        act.level.name(),
        caps.best.name(),
        if isa::cpu::f16c_enabled() { "on" } else { "off" }
    );
    let mut t = Table::new(&["name", "impl", "prepared-kv", "microkernel", "summary"]);
    for e in registry::entries() {
        let prep = registry::supports(
            &e.imp,
            &registry::KernelReq { prepared: true, ..Default::default() },
        );
        // the INT8 microkernel tier this row's inner loops dispatch to;
        // the fp32/fp8 references have no INT8 hot loop
        let micro = match e.imp {
            AttnImpl::Sage { .. } => act.level.name(),
            _ => "-",
        };
        t.row(&[
            e.name.to_string(),
            e.imp.name(),
            (if prep { "yes" } else { "no" }).to_string(),
            micro.to_string(),
            e.summary.to_string(),
        ]);
    }
    t.print("registered attention kernels (auto-dispatch priority order)");

    // per-tier P·V lane detail: the f32 vector width and how the fused
    // fp16-accumulator step performs its f16 round-trip on this host
    let mut ti = Table::new(&["tier", "f32 lanes", "fp16 P*V step", "paged-KV prefetch"]);
    for level in IsaLevel::ALL {
        let Some(kern) = isa::for_level(level) else {
            continue; // tier not supported on this host
        };
        ti.row(&[
            level.name().to_string(),
            format!("{}-wide", kern.f32_width),
            kern.pv_f16_round_desc().to_string(),
            isa::PREFETCH_DESC.to_string(),
        ]);
    }
    println!();
    ti.print("P*V microkernel lanes (tiers supported on this host)");
    println!("\nparameterized forms also resolve, e.g. 'SageAttn-B64' or 'fp8(E4M3,E5M2)'");
    println!("SAGE_ISA=scalar|avx2|vnni|neon forces a microkernel tier (bit-identical output)");
    Ok(())
}

/// Before/after GFLOPS on the sage_plane hot path, in four lanes:
/// (1) the blocked, scratch-reusing kernel vs the unblocked row-at-a-time
/// reference; (2) the PreparedKV decode lane — per-token cost of
/// decoding against an N-long prefix with quantize-once state vs a full
/// `sage_plane` call (which re-runs smooth-K + INT8 quantization of the
/// whole prefix) per token; (3) the serve-decode lane (the same claim at
/// engine granularity); (4) the dot-i8 microkernel lane — the hardware's
/// best `attn::isa` SIMD tier vs forced scalar; (5) the fused fp16-PV
/// lane — the fused `pv_f16_step` microkernel vs the unfused
/// axpy + slice-round + add composition it replaced (bit-identical
/// output, so only speed is at stake). With --check FILE the
/// measured speedups are asserted against the checked-in floors (CI
/// regression gate); --update FILE rewrites the baseline with the
/// measured numbers.
fn bench_hotpath(flags: &HashMap<String, String>) -> Result<()> {
    let n: usize = parsed_flag(flags, "seq", "4096");
    let d: usize = parsed_flag(flags, "headdim", "128");
    let b: usize = parsed_flag(flags, "batch", "1");
    let h: usize = parsed_flag(flags, "heads", "4");
    let secs: u64 = parsed_flag(flags, "secs", "2");
    let t_dec: usize = parsed_flag(flags, "decode-tokens", "24");
    if n == 0 || d == 0 || b == 0 || h == 0 || secs == 0 || t_dec == 0 {
        usage_error("bench-hotpath shape dims, --secs and --decode-tokens must be non-zero");
    }
    if flags.contains_key("check") && flags.contains_key("update") {
        usage_error("--check and --update are mutually exclusive");
    }
    // decode lanes consume t_dec timed + 2 warmup tokens off the prefix
    // (bench() runs at least 3 timed iterations)
    let t_dec = t_dec.max(3);
    let warmup = 2usize;
    ensure!(
        n > t_dec + warmup + 1,
        "--seq {n} too small for --decode-tokens {t_dec} (+{warmup} warmup)"
    );
    let n0 = n - t_dec - warmup;
    let budget = Duration::from_secs(secs);
    let gran = Granularity::PerBlock(BLOCK_Q);
    println!(
        "hot path {b}x{h}x{n}x{d} ({} worker threads, ~{}s/case, ops = 4·N²·d per plane)",
        default_threads(),
        budget.as_secs()
    );

    let (q, k, v) = make_qkv(1, [b, h, n, d], Profile::diffusion_like());
    let ops = attention_ops(b, h, n, n, d, false);
    let gflops = |s: &Sample| ops / s.median_s() / 1e9;

    // "before": the unblocked reference — row-at-a-time, full softmax,
    // per-row Vec allocation, no KV tiling (same plane parallelism).
    let naive_full = |q: &Tensor, k: &Tensor, v: &Tensor| -> Vec<Vec<f32>> {
        parallel_map(b * h, default_threads(), |idx| {
            let (bi, hi) = (idx / h, idx % h);
            sage_plane_naive(
                q.head(bi, hi),
                k.head(bi, hi),
                v.head(bi, hi),
                n,
                n,
                d,
                gran,
                true,
                false,
            )
        })
    };
    let s_naive = bench_budget("naive row-wise (unblocked ref)", budget, 2, || {
        std::hint::black_box(naive_full(&q, &k, &v));
    });

    // "after": blocked tiles + per-thread scratch, same numerics family
    // (fp32-accumulated P·V) — this pair isolates the blocking win.
    let blocked_fp32 = AttnSpec::new(AttnImpl::Sage {
        qk: gran,
        pv: PvMode::Fp32Accum,
        smooth_k: true,
    });
    let s_blocked = bench_budget("blocked+scratch (fp32-acc PV)", budget, 2, || {
        std::hint::black_box(blocked_fp32.run(&q, &k, &v).unwrap());
    });

    // the two shipping variants, for the record
    let sage_b = AttnSpec::sage_b();
    let s_fp16 = bench_budget("blocked+scratch (SageAttn-B, fp16-acc sim)", budget, 2, || {
        std::hint::black_box(sage_b.run(&q, &k, &v).unwrap());
    });
    let sage_vb = AttnSpec::sage_vb();
    let s_int8 = bench_budget("blocked+scratch (SageAttn-vB, int8 PV)", budget, 2, || {
        std::hint::black_box(sage_vb.run(&q, &k, &v).unwrap());
    });

    let mut t = Table::new(&["case", "median ms", "GFLOPS", "iters"]);
    for s in [&s_naive, &s_blocked, &s_fp16, &s_int8] {
        t.row(&[
            s.name.clone(),
            format!("{:.1}", s.median_s() * 1e3),
            format!("{:.2}", gflops(s)),
            s.iters.to_string(),
        ]);
    }
    t.print("sage_plane hot path: before/after");

    let speedup = gflops(&s_blocked) / gflops(&s_naive);
    println!(
        "\nbench-hotpath speedup: {speedup:.2}x \
         (blocked+scratch sage_plane vs unblocked row-wise reference, \
          fp32-acc P·V, N={n}, d={d})"
    );
    println!("acceptance bar: >= 1.50x at N=4096, d=128");

    // ---- prepared-decode lane: per-token cost against an n0-long
    //      prefix, SageAttn-B numerics on both sides ----
    // baseline: one full sage_plane call per token — smooth-K + INT8
    // quantization of the whole prefix re-run every time (plane-level
    // slices, so no tensor-copy overhead is billed to it)
    let mut t_full = 0usize;
    let s_dec_full = bench("decode/full-requant", warmup, t_dec, || {
        let n_kv = n0 + t_full + 1;
        let out = parallel_map_with(b * h, default_threads(), Scratch::new, |scratch, idx| {
            let (bi, hi) = (idx / h, idx % h);
            let qrow = &q.head(bi, hi)[(n_kv - 1) * d..n_kv * d];
            sage_plane_with(
                scratch,
                qrow,
                &k.head(bi, hi)[..n_kv * d],
                &v.head(bi, hi)[..n_kv * d],
                1,
                n_kv,
                d,
                gran,
                PvMode::Fp16Accum,
                true,
                false,
            )
        });
        std::hint::black_box(out);
        t_full += 1;
    });

    // prepared: quantize the prefix once, then per token extend by one
    // row and run against the prepared state
    let mut kv_state = sage_b.prepare(&k.narrow_n(0, n0), &v.narrow_n(0, n0))?;
    let mut t_prep = 0usize;
    let s_dec_prep = bench("decode/prepared (extend+run)", warmup, t_dec, || {
        let row = n0 + t_prep;
        kv_state
            .extend(&k.narrow_n(row, row + 1), &v.narrow_n(row, row + 1))
            .expect("decode extend");
        let out = sage_b
            .run_prepared(&q.narrow_n(row, row + 1), &kv_state)
            .expect("prepared decode");
        std::hint::black_box(out);
        t_prep += 1;
    });

    let mut td = Table::new(&["case", "median ms/token", "tok/s", "tokens"]);
    for s in [&s_dec_full, &s_dec_prep] {
        td.row(&[
            s.name.clone(),
            format!("{:.3}", s.median_s() * 1e3),
            format!("{:.1}", 1.0 / s.median_s()),
            s.iters.to_string(),
        ]);
    }
    td.print(&format!("PreparedKV decode lane (prefix {n0}, {t_dec} tokens)"));

    let dec_speedup = s_dec_full.median_s() / s_dec_prep.median_s();
    println!(
        "\nprepared-decode speedup: {dec_speedup:.2}x \
         (PreparedKV extend+run vs full per-token requantization, N={n}, d={d})"
    );
    println!("acceptance bar: >= 3.00x at N=4096, d=128");

    // ---- serve-decode lane: the native serving backend end to end —
    //      paged PreparedKV decode vs a naive engine loop that gathers
    //      the raw prefix and re-quantizes it every step ----
    let serve_seq: usize = parsed_flag(flags, "serve-seq", "2048");
    let t_serve: usize = parsed_flag(flags, "serve-decode-tokens", "12");
    let (s_srv_requant, s_srv_prep) = serve_decode_lane(serve_seq, t_serve.max(3))?;
    let mut ts = Table::new(&["case", "median ms/token", "tok/s", "tokens"]);
    for s in [&s_srv_requant, &s_srv_prep] {
        ts.row(&[
            s.name.clone(),
            format!("{:.3}", s.median_s() * 1e3),
            format!("{:.1}", 1.0 / s.median_s()),
            s.iters.to_string(),
        ]);
    }
    ts.print(&format!(
        "serve-decode lane (native backend, max_seq {serve_seq}, full transformer step)"
    ));
    let serve_speedup = s_srv_requant.median_s() / s_srv_prep.median_s();
    println!(
        "\nserve-decode speedup: {serve_speedup:.2}x \
         (paged PreparedKV decode vs requant-every-step engine loop, max_seq {serve_seq})"
    );
    println!("acceptance bar: >= 2.00x at max_seq 2048");

    // ---- shared-prefix lane: the radix prefix cache end to end — eight
    //      requests behind one 128-token system prompt; the first seeds
    //      the cache, the other seven fork its pages and prefill only
    //      their suffix. The gated number is the fraction of prefill
    //      rows served from cached pages instead of recomputed ----
    let (shared_rep, shared_prefill) = shared_prefix_lane()?;
    let shared_frac = shared_rep.prefill_tokens_saved as f64 / shared_prefill as f64;
    println!(
        "\nshared-prefix lane: {}/{shared_prefill} prefill tokens served from cache \
         ({:.0}%), hit rate {:.0}%, {} CoW block copies",
        shared_rep.prefill_tokens_saved,
        shared_frac * 100.0,
        shared_rep.prefix_hit_rate() * 100.0,
        shared_rep.cow_copies
    );
    println!(
        "acceptance bar: prefill_tokens_saved_frac >= 0.50 \
         (8 requests, 128-token shared prefix)"
    );

    // ---- faulted-serve lane: goodput of the supervised fleet under the
    //      default mild fault mix, as a fraction of an unfaulted control
    //      on the identical workload. Virtual-time fleet + seeded faults
    //      → the fraction is deterministic (no timing dependence) ----
    let (goodput_frac, faulted_rep) = faulted_serve_lane()?;
    println!(
        "\nfaulted-serve lane: {}/{} requests served under 'step_err:0.02,oom:0.05' \
         (goodput {:.0}% of unfaulted tokens; {} injected, {} retried, {} degraded)",
        faulted_rep.served,
        faulted_rep.submitted,
        goodput_frac * 100.0,
        faulted_rep.injected,
        faulted_rep.retried,
        faulted_rep.degraded_fallbacks
    );
    println!("acceptance bar: goodput_under_faults_frac >= 0.90 (deterministic, seed 7)");

    // ---- SLO-serve lane: the traffic plane end to end — open-loop
    //      arrivals, 128-row chunked prefill, per-token streaming, and
    //      per-request TTFT/TPOT targets; the gated number is the
    //      fraction of tracked requests served within target ----
    let (slo_frac, slo_rep) = slo_serve_lane()?;
    println!(
        "\nSLO-serve lane: {}/{} tracked requests met TTFT<=64 / TPOT<=2.0 ticks under \
         open-loop 'mix:chat=0.6,rag=0.2,bursty=0.2' with {BLOCK_Q}-row chunked prefill \
         (goodput-under-SLO {:.0}%; {} shed, {} tokens streamed clean)",
        slo_rep.slo_met,
        slo_rep.slo_tracked,
        slo_frac * 100.0,
        slo_rep.shed,
        slo_rep.streamed_tokens
    );
    println!("acceptance bar: goodput_under_slo_frac >= 0.90 (deterministic, seed 7)");

    // ---- dot-i8 microkernel lane: the §4.3 mma(s8.s8.s32) primitive,
    //      hardware SIMD tier vs forced scalar (GB/s of operand bytes;
    //      2 bytes per MAC). Measures the hardware's best tier directly
    //      (independent of any SAGE_ISA override), one query row
    //      streamed against a resident K plane ----
    let hw_best = isa::cpu::caps().best;
    let mut tiers = vec![isa::for_level(IsaLevel::Scalar).expect("scalar table")];
    if hw_best != IsaLevel::Scalar {
        tiers.push(isa::for_level(hw_best).expect("detected tier table"));
    }
    let dot_rows = 4096usize;
    let mut dot_ratio = None;
    let mut rng = Pcg32::seeded(77);
    let mut td8 = Table::new(&["d", "tier", "GB/s", "iters"]);
    for dd in [64usize, 128] {
        let mut qrow = vec![0i8; dd];
        let mut kplane = vec![0i8; dot_rows * dd];
        for x in qrow.iter_mut().chain(kplane.iter_mut()) {
            *x = (rng.next_u32() & 0xFF) as u8 as i8;
        }
        let bytes = (dot_rows * dd * 2) as f64;
        let mut gbps = Vec::with_capacity(tiers.len());
        for kern in &tiers {
            let s = bench_budget(
                &format!("dot-i8 d={dd} {}", kern.level.name()),
                budget / 4,
                10,
                || {
                    let mut acc = 0i64;
                    for r in 0..dot_rows {
                        acc += (kern.dot_i8)(&qrow, &kplane[r * dd..(r + 1) * dd]) as i64;
                    }
                    std::hint::black_box(acc);
                },
            );
            gbps.push(bytes / s.median_s() / 1e9);
            td8.row(&[
                dd.to_string(),
                kern.level.name().to_string(),
                f2(*gbps.last().unwrap()),
                s.iters.to_string(),
            ]);
        }
        if dd == 128 && gbps.len() == 2 {
            dot_ratio = Some(gbps[1] / gbps[0]);
        }
    }
    td8.print("dot-i8 microkernel lane (SIMD vs forced scalar)");
    match dot_ratio {
        Some(r) => {
            println!("\ndot-i8 speedup: {r:.2}x ({} vs scalar, d=128)", hw_best.name());
            println!("acceptance bar: >= 2.00x at d=128 on an AVX2-capable host");
        }
        None => println!("\ndot-i8 lane: no SIMD tier on this host (scalar only)"),
    }

    // ---- fused fp16-PV lane: the §4.4 mma(f16.f16.f16.f16) simulation —
    //      the fused pv_f16_step/scale_round_f16 microkernels vs the
    //      unfused axpy + slice-round + add composition they replaced.
    //      Bit-identical by construction (the bit-identity suites gate
    //      that); this lane gates the speed. One BLOCK_Q-row P tile
    //      against one BLOCK_KV-row V tile, softmax-shaped P with the
    //      exact zeros the masked tail produces ----
    let pv_bk = BLOCK_KV;
    let pv_rows = BLOCK_Q;
    let pv_d = 128usize;
    let mut rng = Pcg32::seeded(99);
    let mut vtile = vec![0.0f32; pv_bk * pv_d];
    for x in vtile.iter_mut() {
        *x = rng.normal();
    }
    round_f16_slice(&mut vtile);
    let mut prows = vec![0.0f32; pv_rows * pv_bk];
    for x in prows.iter_mut() {
        let u = rng.normal().abs();
        *x = if u < 0.3 { 0.0 } else { u };
    }
    round_f16_slice(&mut prows);
    let mut o = vec![0.0f32; pv_rows * pv_d];
    let mut part = vec![0.0f32; pv_d];
    let pv_ops = (pv_rows * pv_bk * pv_d * 2) as f64;
    let mut pv_ratio = None;
    let mut tpv = Table::new(&["tier", "path", "GFLOPS", "iters"]);
    for kern in &tiers {
        let s_fused =
            bench_budget(&format!("pv-f16 fused {}", kern.level.name()), budget / 4, 10, || {
                o.fill(0.0);
                for (r, p) in prows.chunks_exact(pv_bk).enumerate() {
                    pv::fp16_tile_fused(kern, &mut o[r * pv_d..(r + 1) * pv_d], p, &vtile, pv_d);
                }
                std::hint::black_box(&mut o);
            });
        let s_unfused =
            bench_budget(&format!("pv-f16 unfused {}", kern.level.name()), budget / 4, 10, || {
                o.fill(0.0);
                for (r, p) in prows.chunks_exact(pv_bk).enumerate() {
                    pv::fp16_tile_unfused(
                        kern,
                        &mut o[r * pv_d..(r + 1) * pv_d],
                        p,
                        &vtile,
                        &mut part,
                        pv_d,
                    );
                }
                std::hint::black_box(&mut o);
            });
        for (s, path) in [(&s_fused, "fused"), (&s_unfused, "unfused")] {
            tpv.row(&[
                kern.level.name().to_string(),
                path.to_string(),
                f2(pv_ops / s.median_s() / 1e9),
                s.iters.to_string(),
            ]);
        }
        // gate the ratio only where the fused lane actually uses F16C —
        // without it the fused step falls back to the scalar round and
        // the comparison measures nothing
        if kern.level == hw_best && hw_best != IsaLevel::Scalar && isa::cpu::f16c_enabled() {
            pv_ratio = Some(s_unfused.median_s() / s_fused.median_s());
        }
    }
    tpv.print("fused fp16-PV lane (pv_f16_step vs axpy+round composition)");
    match pv_ratio {
        Some(r) => {
            println!(
                "\npv-f16 fused speedup: {r:.2}x ({} fused vs unfused, {}x{} tile, d={pv_d})",
                hw_best.name(),
                pv_rows,
                pv_bk
            );
            println!("acceptance bar: >= 1.30x on an F16C-capable host");
        }
        None => println!("\npv-f16 lane: no F16C on this host (fused ratio not gated)"),
    }

    // ---- trace-overhead lane: cost of the sampled kernel-phase timer
    //      on a decode-shaped plane. The gated number is a *fraction*
    //      (throughput with the timer armed / with it off), so 1.00 is
    //      free and the bar is >= 0.97. Rounds are interleaved and the
    //      max taken: a ~3% bar cannot survive scheduler noise in a
    //      single paired measurement ----
    let n_ov = n0.min(1024).max(BLOCK_KV);
    let kh_ov = &k.head(0, 0)[..n_ov * d];
    let vh_ov = &v.head(0, 0)[..n_ov * d];
    let q_ov = &q.head(0, 0)[(n_ov - 1) * d..n_ov * d];
    let mut ov_scratch = Scratch::new();
    let mut ov_run = |timer: PhaseTimer, label: &str| -> f64 {
        ov_scratch.set_phase_timer(timer);
        bench_budget(label, budget / 8, 10, || {
            let out = sage_plane_with(
                &mut ov_scratch,
                q_ov,
                kh_ov,
                vh_ov,
                1,
                n_ov,
                d,
                gran,
                PvMode::Fp16Accum,
                true,
                false,
            );
            std::hint::black_box(out);
        })
        .median_s()
    };
    let mut overhead_frac = 0.0f64;
    for round in 0..3 {
        let t_off = ov_run(PhaseTimer::disabled(), &format!("trace-overhead/off r{round}"));
        let t_on = ov_run(PhaseTimer::sampled(8), &format!("trace-overhead/on r{round}"));
        overhead_frac = overhead_frac.max(t_off / t_on);
    }
    println!(
        "\ntrace-overhead: {overhead_frac:.3}x throughput with the sampled phase timer armed \
         (decode plane, N={n_ov}, every-8th-plane sampling)"
    );
    println!("acceptance bar: trace_overhead_frac >= 0.97 (observability must be ~free)");

    // ---- tab09 kernel-accuracy lane (persisted alongside the ratio
    //      floors): same setup as benches/tab09_kernel_accuracy.rs ----
    let acc_measured = tab09_accuracy();
    let mut ta = Table::new(&["kernel", "CosSim"]);
    for (name, cos) in &acc_measured {
        ta.row(&[name.to_string(), pct(*cos)]);
    }
    ta.print("tab09 kernel accuracy (N(0,1) QKV, 2x8x1024x64)");

    let gflops_measured: Vec<(&str, f64)> = vec![
        ("naive", gflops(&s_naive)),
        ("blocked_fp32", gflops(&s_blocked)),
        ("sage_b", gflops(&s_fp16)),
        ("sage_vb", gflops(&s_int8)),
    ];
    let decode_tok_s: Vec<(&str, f64)> = vec![
        ("full_requant", 1.0 / s_dec_full.median_s()),
        ("prepared", 1.0 / s_dec_prep.median_s()),
        ("serve_requant", 1.0 / s_srv_requant.median_s()),
        ("serve_prepared", 1.0 / s_srv_prep.median_s()),
    ];
    let mut ratios: Vec<(&str, f64)> = vec![
        ("blocked_over_naive", speedup),
        ("prepared_decode_speedup", dec_speedup),
        ("serve_decode_speedup", serve_speedup),
        ("prefill_tokens_saved_frac", shared_frac),
        ("goodput_under_faults_frac", goodput_frac),
        ("goodput_under_slo_frac", slo_frac),
        ("trace_overhead_frac", overhead_frac),
    ];
    if let Some(r) = dot_ratio {
        ratios.push(("dot_i8_simd_over_scalar", r));
    }
    if let Some(r) = pv_ratio {
        ratios.push(("pv_f16_fused_over_unfused", r));
    }

    if let Some(path) = flags.get("check") {
        check_baseline(path, &gflops_measured, &decode_tok_s, &ratios, &acc_measured)?;
    }
    if let Some(path) = flags.get("update") {
        update_baseline(path, b, h, n, d, &gflops_measured, &decode_tok_s, &ratios, &acc_measured)?;
    }
    Ok(())
}

/// Per-token decode cost of the native serving backend at `max_seq`,
/// prepared (paged quantize-once KV) vs the naive requant-every-step
/// loop. Both run the identical transformer step (same matmuls, same
/// sampling); only how decode attention reads the KV prefix differs —
/// the engine-level version of the PreparedKV claim.
fn serve_decode_lane(max_seq: usize, t_dec: usize) -> Result<(Sample, Sample)> {
    let warmup = 2usize;
    ensure!(
        max_seq > t_dec + warmup + PAGE_ROWS,
        "--serve-seq {max_seq} too small for --serve-decode-tokens {t_dec}"
    );
    let plen = max_seq - t_dec - warmup - 4;
    let run = |mode: DecodeMode, label: &str| -> Result<Sample> {
        let cfg = ModelCfg::gpt("bench-serve", 256, 128, 2, 4, 64, 256, max_seq);
        let mut corpus = Corpus::new(cfg.vocab, 5);
        let prompt = corpus.batch(1, plen);
        let mut kv = KvCacheManager::new(max_seq.div_ceil(PAGE_ROWS), PAGE_ROWS);
        let mut eng = NativeEngine::new(cfg, "sage", 1, 1, mode)?;
        kv.allocate(0, plen).expect("fresh pool fits the prefill");
        let req = Request::new(
            0,
            prompt,
            GenParams { max_new_tokens: t_dec + warmup + 3, ..Default::default() },
        );
        ensure!(eng.add_request(&req, &mut kv)?, "bench engine refused the request");
        Ok(bench(label, warmup, t_dec, || {
            let out = eng.step(&mut kv).expect("bench decode step");
            assert!(out.finished.is_empty() && out.preempted.is_empty());
        }))
    };
    let requant = run(DecodeMode::RequantEachStep, "serve-decode/requant-each-step")?;
    let prepared = run(DecodeMode::Prepared, "serve-decode/prepared (paged)")?;
    Ok((requant, prepared))
}

/// Shared-prefix serving through the prefix-cached native backend: eight
/// requests opening with the same 128-token system prompt (the cache
/// chunk of the sage plan, `lcm(PAGE_ROWS, BLOCK_Q)`), ample KV so the
/// measured fraction reflects cache hits, not preemption. Returns the
/// report and the total prefill rows submitted.
fn shared_prefix_lane() -> Result<(SchedulerReport, u64)> {
    let n_req = 8usize;
    let (prefix, suffix, max_new) = (128usize, 32usize, 4usize);
    let cfg = ModelCfg::gpt("bench-shared", 256, 128, 2, 2, 64, 256, 256);
    let engine = Engine::native_cached(cfg.clone(), "sage", 1, 4)?;
    let kv = KvCacheManager::new(64, PAGE_ROWS);
    let mut sched = Scheduler::new(Batcher::new(BatchPolicy::Fifo), kv, engine);
    let mut corpus = Corpus::new(cfg.vocab, 11);
    let shared = corpus.batch(1, prefix);
    for i in 0..n_req {
        let mut prompt = shared.clone();
        prompt.extend(corpus.batch(1, suffix));
        sched.submit(Request::new(
            i as u64,
            prompt,
            GenParams { max_new_tokens: max_new, ..Default::default() },
        ));
    }
    let report = sched.run_to_completion()?;
    ensure!(report.responses.len() == n_req, "shared-prefix lane lost requests");
    Ok((report, (n_req * (prefix + suffix)) as u64))
}

/// Faulted-serve lane: useful output (tokens of successfully completed
/// requests) of the supervised fleet under the default mild fault mix,
/// as a fraction of an unfaulted control on the identical workload —
/// both runs drive the same virtual-time fleet machinery, so the
/// fraction measures what the recovery policy loses to terminal
/// failures, deterministically (seeded faults, no timing dependence).
fn faulted_serve_lane() -> Result<(f64, FleetReport)> {
    let mild = FaultSpec::parse("step_err:0.02,oom:0.05").expect("lane spec parses");
    let clean = FaultSpec::default();
    // a roomier retry budget than the serving default: the lane measures
    // goodput under sustained mild faults, not budget-exhaustion policy
    let fleet_cfg = FleetCfg { max_retries: 5, ..FleetCfg::default() };
    let run = |spec: &FaultSpec| {
        run_faulted_fleet(
            "tiny",
            "sage",
            16,
            7,
            2,
            4,
            None,
            spec,
            RoutingPolicy::RoundRobin,
            (None, None),
            fleet_cfg,
            TrafficCfg::default(),
            None,
            Obs::disabled(),
        )
    };
    let control = run(&clean)?;
    let faulted = run(&mild)?;
    ensure!(
        control.fully_accounted() && faulted.fully_accounted(),
        "faulted-serve lane dropped requests (control {}, faulted {})",
        control.dropped,
        faulted.dropped
    );
    let good_tokens = |r: &FleetReport| -> f64 { r.tokens_out() as f64 };
    let frac = if good_tokens(&control) > 0.0 {
        good_tokens(&faulted) / good_tokens(&control)
    } else {
        0.0
    };
    Ok((frac, faulted))
}

/// SLO-serve lane: goodput-under-SLO of the traffic plane at moderate
/// open-loop load — a chat/rag/bursty scenario mix replayed on its
/// Poisson arrival times through a 2-replica fleet with 128-row chunked
/// prefill and per-request TTFT/TPOT targets, faults off. Virtual-time
/// fleet + seeded workload → the fraction is deterministic.
fn slo_serve_lane() -> Result<(f64, FleetReport)> {
    let mix = ScenarioMix::parse("mix:chat=0.6,rag=0.2,bursty=0.2").expect("lane mix parses");
    let traffic = TrafficCfg {
        chunk: Some(ChunkCfg::per_tick(BLOCK_Q)?),
        slo: SloTargets { ttft_ticks: Some(64), tpot_ticks: Some(2.0) },
        open_loop: true,
        tick_ms: OPEN_LOOP_TICK_MS,
    };
    let fleet_cfg = FleetCfg { tick_prefill_rows: Some(BLOCK_Q), ..FleetCfg::default() };
    let report = run_faulted_fleet(
        "tiny",
        "sage",
        24,
        7,
        2,
        4,
        None,
        &FaultSpec::default(),
        RoutingPolicy::RoundRobin,
        (None, None),
        fleet_cfg,
        traffic,
        Some(&mix),
        Obs::disabled(),
    )?;
    ensure!(
        report.fully_accounted(),
        "SLO-serve lane dropped {} request(s)",
        report.dropped
    );
    ensure!(
        report.stream_duplicates == 0 && report.stream_gaps == 0,
        "SLO-serve lane streamed dirty ({} duplicates, {} gaps)",
        report.stream_duplicates,
        report.stream_gaps
    );
    Ok((report.goodput_under_slo_frac(), report))
}

/// The tab09 accuracy numbers (cosine similarity vs exact fp32 on
/// N(0,1) Q/K/V — the paper's Table 9 setup, same seed and shape as
/// `benches/tab09_kernel_accuracy.rs`).
fn tab09_accuracy() -> Vec<(&'static str, f64)> {
    let shape = [2usize, 8, 1024, 64];
    let mut rng = Pcg32::seeded(9);
    let mut mk = || {
        let mut t = Tensor::zeros(&shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    };
    let (q, k, v) = (mk(), mk(), mk());
    let gold = AttnSpec::exact().run(&q, &k, &v).expect("exact reference");
    ["SageAttn-T", "SageAttn-B", "SageAttn-vT", "SageAttn-vB"]
        .iter()
        .map(|name| {
            let o = AttnSpec::by_name(name).unwrap().run(&q, &k, &v).unwrap();
            (*name, accuracy(&gold.data, &o.data).cos_sim as f64)
        })
        .collect()
}

/// Assert the measured speedup ratios and kernel-accuracy floors against
/// the checked-in baseline file. Ratios and cosine similarities are
/// machine-portable (ratios: both sides run on the same machine;
/// accuracy: deterministic seeded inputs), so they are the hard gate;
/// recorded absolute GFLOPS / decode tok/s, when present, are compared
/// informationally.
fn check_baseline(
    path: &str,
    gflops: &[(&str, f64)],
    decode_tok_s: &[(&str, f64)],
    ratios: &[(&str, f64)],
    accuracy_cos: &[(&str, f64)],
) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench baseline {path}"))?;
    let base = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let floors = base.get("floors").context("baseline missing 'floors'")?;
    let floors = floors.as_obj().context("'floors' must be an object")?;
    println!("\nbaseline check against {path}:");
    let mut failed = Vec::new();
    for (name, floor) in floors {
        let floor = floor.as_f64().with_context(|| format!("floor '{name}' not a number"))?;
        let Some(&(_, got)) = ratios.iter().find(|(r, _)| *r == name.as_str()) else {
            // the dot-i8 lane only produces a ratio when the host has a
            // SIMD tier at all; a scalar-only host skips that floor
            if name == "dot_i8_simd_over_scalar" {
                println!("  SKIP {name}: no SIMD tier on this host");
                continue;
            }
            // the fused fp16-PV ratio is only meaningful where the fused
            // lane uses the F16C round-trip; other hosts skip that floor
            if name == "pv_f16_fused_over_unfused" {
                println!("  SKIP {name}: no F16C on this host");
                continue;
            }
            sageattention::bail!("baseline floor '{name}' is not a measured ratio");
        };
        let ok = got >= floor;
        println!(
            "  {} {name}: measured {got:.2}x, floor {floor:.2}x",
            if ok { "PASS" } else { "FAIL" }
        );
        if !ok {
            failed.push(name.clone());
        }
    }
    if let Some(acc_floors) = base.get("accuracy_cos").and_then(Json::as_obj) {
        for (name, floor) in acc_floors {
            let floor =
                floor.as_f64().with_context(|| format!("accuracy floor '{name}' not a number"))?;
            let Some(&(_, got)) = accuracy_cos.iter().find(|(k, _)| *k == name.as_str()) else {
                sageattention::bail!("accuracy floor '{name}' is not a measured kernel");
            };
            let ok = got >= floor;
            println!(
                "  {} accuracy_cos.{name}: measured {got:.5}, floor {floor:.5}",
                if ok { "PASS" } else { "FAIL" }
            );
            if !ok {
                failed.push(format!("accuracy_cos.{name}"));
            }
        }
    }
    for (key, unit, measured) in
        [("gflops", "GFLOPS", gflops), ("decode_tok_s", "tok/s", decode_tok_s)]
    {
        if let Some(Json::Obj(recorded)) = base.get(key) {
            for (name, val) in recorded {
                if let (Some(rec), Some(&(_, got))) =
                    (val.as_f64(), measured.iter().find(|(m, _)| *m == name.as_str()))
                {
                    if rec > 0.0 {
                        println!(
                            "  info {key}.{name}: measured {got:.2} vs recorded {rec:.2} {unit}"
                        );
                    }
                }
            }
        }
    }
    ensure!(
        failed.is_empty(),
        "bench-hotpath regression: {} below baseline floor (see table above); \
         rerun with --update {path} only if the slowdown is intended",
        failed.join(", ")
    );
    println!("baseline check OK");
    Ok(())
}

/// Rewrite the baseline file with measured numbers, preserving existing
/// floors (floors are policy, measurements are evidence).
#[allow(clippy::too_many_arguments)]
fn update_baseline(
    path: &str,
    b: usize,
    h: usize,
    n: usize,
    d: usize,
    gflops: &[(&str, f64)],
    decode_tok_s: &[(&str, f64)],
    ratios: &[(&str, f64)],
    accuracy_cos: &[(&str, f64)],
) -> Result<()> {
    let existing = std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok());
    let floors = existing
        .as_ref()
        .and_then(|j| j.get("floors").cloned())
        .unwrap_or_else(|| {
            Json::obj(vec![
                ("blocked_over_naive", Json::num(1.5)),
                ("prepared_decode_speedup", Json::num(3.0)),
                ("serve_decode_speedup", Json::num(2.0)),
                ("dot_i8_simd_over_scalar", Json::num(2.0)),
                ("pv_f16_fused_over_unfused", Json::num(1.3)),
                ("prefill_tokens_saved_frac", Json::num(0.5)),
                ("goodput_under_faults_frac", Json::num(0.9)),
                ("goodput_under_slo_frac", Json::num(0.9)),
                ("trace_overhead_frac", Json::num(0.97)),
            ])
        });
    let acc_floors = existing
        .as_ref()
        .and_then(|j| j.get("accuracy_cos").cloned())
        .unwrap_or_else(|| {
            Json::obj(vec![
                ("SageAttn-T", Json::num(0.995)),
                ("SageAttn-B", Json::num(0.995)),
                ("SageAttn-vT", Json::num(0.98)),
                ("SageAttn-vB", Json::num(0.98)),
            ])
        });
    let round2 = |x: f64| (x * 100.0).round() / 100.0;
    let round5 = |x: f64| (x * 1e5).round() / 1e5;
    let num_obj = |pairs: &[(&str, f64)]| {
        Json::obj(pairs.iter().map(|&(k, v)| (k, Json::num(round2(v)))).collect())
    };
    let json = Json::obj(vec![
        ("schema", Json::num(2.0)),
        (
            "shape",
            Json::obj(vec![
                ("batch", Json::num(b as f64)),
                ("heads", Json::num(h as f64)),
                ("seq", Json::num(n as f64)),
                ("headdim", Json::num(d as f64)),
            ]),
        ),
        ("floors", floors),
        ("accuracy_cos", acc_floors),
        ("gflops", num_obj(gflops)),
        ("decode_tok_s", num_obj(decode_tok_s)),
        ("ratios", num_obj(ratios)),
        (
            "accuracy_measured",
            Json::obj(
                accuracy_cos.iter().map(|&(k, v)| (k, Json::num(round5(v)))).collect(),
            ),
        ),
    ]);
    std::fs::write(path, format!("{json}\n"))?;
    println!("\nwrote {path}");
    Ok(())
}
