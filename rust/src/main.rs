//! `sage` — CLI for the SageAttention reproduction stack.
//!
//! Subcommands:
//!   smoke                         artifact round-trip sanity check
//!   serve [--plan sage] [...]     run the serving coordinator on a
//!                                 synthetic workload and print telemetry
//!   calibrate [--out plan.json]   §4.5 adaptive-quantization calibration
//!   accuracy [--profile P]        kernel accuracy vs full precision
//!   speed [--device 4090]         cost-model kernel speed sweep
//!
//! (arg parsing is hand-rolled: clap is unavailable offline)

use std::collections::HashMap;

use anyhow::{Context, Result};

use sageattention::adaptive;
use sageattention::attn::{attention, AttnImpl, SAGE_B, SAGE_T, SAGE_VB, SAGE_VT};
use sageattention::bench::{f2, pct, sci, Table};
use sageattention::coordinator::{
    BatchPolicy, Batcher, Engine, GenParams, KvCacheManager, Request, Scheduler,
};
use sageattention::metrics::accuracy;
use sageattention::perfmodel::{predict_tops, AttnKernel, DeviceSpec, Workpoint};
use sageattention::runtime::{Runtime, Value};
use sageattention::synth::{make_qkv, Profile, WorkloadGen};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, flags) = parse(&args);
    let result = match cmd.as_deref() {
        Some("smoke") => smoke(&flags),
        Some("serve") => serve(&flags),
        Some("calibrate") => calibrate(&flags),
        Some("accuracy") => accuracy_cmd(&flags),
        Some("speed") => speed(&flags),
        _ => {
            eprintln!(
                "usage: sage <smoke|serve|calibrate|accuracy|speed> [--key value]..."
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse(args: &[String]) -> (Option<String>, HashMap<String, String>) {
    let mut flags = HashMap::new();
    let mut cmd = None;
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            flags.insert(key.to_owned(), val);
            i += 2;
        } else {
            if cmd.is_none() {
                cmd = Some(args[i].clone());
            }
            i += 1;
        }
    }
    (cmd, flags)
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

/// Load one attention artifact, run it against synthetic QKV, and compare
/// with the rust-native exact implementation.
fn smoke(flags: &HashMap<String, String>) -> Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    let name = flag(flags, "artifact", "attn_sage_b_1x2x256x64");
    let art = rt.load(name)?;
    let shape = art.spec.shape.clone().context("attention artifact missing shape")?;
    let [b, h, n, d] = [shape[0], shape[1], shape[2], shape[3]];
    let (q, k, v) = make_qkv(42, [b, h, n, d], Profile::diffusion_like());
    let out = art.run(&[
        Value::from_tensor(&q),
        Value::from_tensor(&k),
        Value::from_tensor(&v),
    ])?;
    let gold = attention(&q, &k, &v, AttnImpl::Exact, art.spec.causal.unwrap_or(false));
    let acc = accuracy(&gold.data, out[0].as_f32()?);
    println!("{name}: {acc}");
    anyhow::ensure!(acc.cos_sim > 0.99, "artifact output diverged from reference");
    println!("smoke OK");
    Ok(())
}

/// Serve a synthetic workload through the full coordinator.
fn serve(flags: &HashMap<String, String>) -> Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    let config = flag(flags, "config", "small");
    let plan = flag(flags, "plan", "sage");
    let n_req: usize = flag(flags, "requests", "16").parse()?;
    let seed: u64 = flag(flags, "seed", "1").parse()?;
    let engine = Engine::new(&rt, config, plan, seed)?;
    let cfg = &rt.manifest.configs[config];
    let vocab = cfg.vocab;
    let max_seq = cfg.max_seq;
    let slots = engine.batch_slots();

    let mut gen = WorkloadGen::new(seed, vocab, 50.0, engine.prefill_sizes(), 24);
    let kv = KvCacheManager::new(slots * max_seq / 16, 16);
    let mut sched = Scheduler::new(Batcher::new(BatchPolicy::Fifo), kv, engine);
    for (i, r) in gen.generate(n_req).into_iter().enumerate() {
        sched.submit(Request::new(
            i as u64,
            r.prompt,
            GenParams { max_new_tokens: r.max_new_tokens, ..Default::default() },
        ));
    }
    let report = sched.run_to_completion()?;
    println!(
        "served {} requests, {} tokens in {:.2}s ({:.1} tok/s)",
        report.responses.len(),
        report.tokens_out,
        report.wall_s,
        report.throughput_tok_s()
    );
    println!(
        "TTFT p50/p99: {:.1}/{:.1} ms   TPOT p50/p99: {:.1}/{:.1} ms",
        report.ttft.percentile(50.0),
        report.ttft.percentile(99.0),
        report.tpot.percentile(50.0),
        report.tpot.percentile(99.0)
    );
    Ok(())
}

/// §4.5 calibration: choose -vB vs -B per layer, write the plan JSON that
/// `aot.py --plan-file` consumes.
fn calibrate(flags: &HashMap<String, String>) -> Result<()> {
    let n_layers: usize = flag(flags, "layers", "4").parse()?;
    let profile = Profile::by_name(flag(flags, "profile", "diffusion-like"))
        .context("unknown profile")?;
    let out = flag(flags, "out", "plan.json");
    let seed: u64 = flag(flags, "seed", "7").parse()?;
    let layers = adaptive::synth_layer_inputs(n_layers, [1, 4, 256, 64], profile, seed);
    let (plan, detail) = adaptive::calibrate(&layers, false);
    let mut t = Table::new(&["layer", "cos(-vB)", "cos(-B)", "choice"]);
    for d in &detail {
        t.row(&[
            d.layer.to_string(),
            pct(d.cos_vb as f64),
            pct(d.cos_b as f64),
            d.choice.to_string(),
        ]);
    }
    t.print("adaptive calibration (threshold 99.8%)");
    std::fs::write(out, plan.to_json())?;
    println!(
        "\nwrote {out}; estimated attention speedup over all--B: {:.1}%",
        (plan.speedup_estimate() - 1.0) * 100.0
    );
    Ok(())
}

/// Kernel accuracy vs full precision on a synthetic profile (Table 9 style).
fn accuracy_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let profile = Profile::by_name(flag(flags, "profile", "diffusion-like"))
        .context("unknown profile")?;
    let n: usize = flag(flags, "seq", "512").parse()?;
    let d: usize = flag(flags, "headdim", "64").parse()?;
    let (q, k, v) = make_qkv(3, [2, 4, n, d], profile);
    let gold = attention(&q, &k, &v, AttnImpl::Exact, false);
    let mut t = Table::new(&["kernel", "CosSim", "RelL1", "RMSE"]);
    for imp in [SAGE_T, SAGE_B, SAGE_VT, SAGE_VB] {
        let o = attention(&q, &k, &v, imp, false);
        let a = accuracy(&gold.data, &o.data);
        t.row(&[
            imp.name(),
            pct(a.cos_sim as f64),
            f2(a.rel_l1 as f64 * 100.0) + "e-2",
            sci(a.rmse as f64),
        ]);
    }
    t.print(&format!("kernel accuracy ({} profile, N={n}, d={d})", profile.name));
    Ok(())
}

/// Cost-model speed sweep (Figures 6–9 style) on one device.
fn speed(flags: &HashMap<String, String>) -> Result<()> {
    let dev: &DeviceSpec =
        DeviceSpec::by_name(flag(flags, "device", "4090")).context("unknown device")?;
    let d: usize = flag(flags, "headdim", "64").parse()?;
    let causal = flags.contains_key("causal");
    let kernels = [
        AttnKernel::TorchNaive,
        AttnKernel::Xformers,
        AttnKernel::FlashAttention2,
        AttnKernel::SageAttnB,
        AttnKernel::SageAttnVB,
    ];
    let mut t =
        Table::new(&["seq", "Torch", "xformers", "FlashAttn2", "SageAttn-B", "SageAttn-vB"]);
    for n in [1024usize, 2048, 4096, 8192, 16384, 32768] {
        let wp = Workpoint::square(4, 32, n, d, causal);
        let mut row = vec![n.to_string()];
        for k in kernels {
            row.push(f2(predict_tops(dev, k, wp)));
        }
        t.row(&row);
    }
    t.print(&format!(
        "predicted TOPS, {} headdim={d}{}",
        dev.name,
        if causal { " causal" } else { "" }
    ));
    Ok(())
}
