//! `sage` — CLI for the SageAttention reproduction stack.
//!
//! Subcommands:
//!   smoke                         artifact round-trip sanity check
//!   serve [--plan sage] [...]     run the serving coordinator on a
//!                                 synthetic workload and print telemetry
//!   calibrate [--out plan.json]   §4.5 adaptive-quantization calibration
//!   accuracy [--profile P]        kernel accuracy vs full precision
//!   speed [--device 4090]         cost-model kernel speed sweep
//!   bench-hotpath [--seq 4096]    before/after GFLOPS on the blocked
//!                                 sage_plane hot path vs the naive loop
//!
//! (arg parsing is hand-rolled: clap is unavailable offline; unknown
//! subcommands and flags exit 2 with usage instead of being ignored)

use std::collections::HashMap;
use std::time::Duration;

use sageattention::adaptive;
use sageattention::attn::{
    attention, sage_plane_naive, AttnImpl, PvMode, BLOCK_Q, SAGE_B, SAGE_T, SAGE_VB, SAGE_VT,
};
use sageattention::bench::{bench_budget, f2, pct, sci, Sample, Table};
use sageattention::coordinator::{
    BatchPolicy, Batcher, Engine, GenParams, KvCacheManager, Request, Scheduler,
};
use sageattention::metrics::{accuracy, attention_ops};
use sageattention::perfmodel::{predict_tops, AttnKernel, DeviceSpec, Workpoint};
use sageattention::quant::Granularity;
use sageattention::runtime::{Runtime, Value};
use sageattention::synth::{make_qkv, Profile, WorkloadGen};
use sageattention::tensor::{default_threads, parallel_map, Tensor};
use sageattention::util::error::{ensure, Context, Result};

const USAGE: &str = "\
usage: sage <subcommand> [--key value]...   (`sage help` prints this)

subcommands:
  smoke          [--artifact NAME]                    artifact round-trip sanity check
  serve          [--config C] [--plan P] [--requests N] [--seed S]
  calibrate      [--layers N] [--profile P] [--out FILE] [--seed S]
  accuracy       [--profile P] [--seq N] [--headdim D]
  speed          [--device 4090|3090] [--headdim D] [--causal]
  bench-hotpath  [--seq N] [--headdim D] [--batch B] [--heads H] [--secs S]";

/// Flags that are bare switches (no value); every other flag requires one.
const BOOLEAN_FLAGS: &[&str] = &["causal"];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if matches!(args.first().map(String::as_str), Some("help" | "--help" | "-h")) {
        println!("{USAGE}");
        return;
    }
    let (cmd, flags) = match parse(&args) {
        Ok(parsed) => parsed,
        Err(msg) => usage_error(&msg),
    };
    if cmd == "help" {
        println!("{USAGE}");
        return;
    }
    let allowed: &[&str] = match cmd.as_str() {
        "smoke" => &["artifact"],
        "serve" => &["config", "plan", "requests", "seed"],
        "calibrate" => &["layers", "profile", "out", "seed"],
        "accuracy" => &["profile", "seq", "headdim"],
        "speed" => &["device", "headdim", "causal"],
        "bench-hotpath" => &["seq", "headdim", "batch", "heads", "secs"],
        other => usage_error(&format!("unknown subcommand '{other}'")),
    };
    // help wins over any other flag validation (checked first so the
    // outcome never depends on HashMap iteration order)
    if flags.keys().any(|k| k == "help" || k == "h") {
        println!("{USAGE}");
        return;
    }
    let mut keys: Vec<&String> = flags.keys().collect();
    keys.sort(); // deterministic error messages regardless of HashMap order
    for key in keys {
        let val = &flags[key];
        if !allowed.contains(&key.as_str()) {
            usage_error(&format!("unknown flag '--{key}' for subcommand '{cmd}'"));
        }
        // only bare boolean switches may omit a value; `--out --seed 7`
        // style mistakes are misuse, not a runtime error
        let boolean = BOOLEAN_FLAGS.contains(&key.as_str());
        if val.is_empty() && !boolean {
            usage_error(&format!("flag '--{key}' requires a value"));
        }
        // and the switches take none: `--causal false` would otherwise
        // silently run WITH causal masking
        if !val.is_empty() && boolean {
            usage_error(&format!("flag '--{key}' is a bare switch and takes no value"));
        }
    }
    let result = match cmd.as_str() {
        "smoke" => smoke(&flags),
        "serve" => serve(&flags),
        "calibrate" => calibrate(&flags),
        "accuracy" => accuracy_cmd(&flags),
        "speed" => speed(&flags),
        "bench-hotpath" => bench_hotpath(&flags),
        _ => unreachable!("subcommand validated above"),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Print the parse error + usage and exit non-zero (exit code 2
/// distinguishes CLI misuse from runtime failures, which exit 1).
fn usage_error(msg: &str) -> ! {
    eprintln!("error: {msg}\n\n{USAGE}");
    std::process::exit(2);
}

/// Split argv into (subcommand, --key value flags). A `--flag` followed by
/// another `--flag` (or nothing) is boolean-valued (empty string). Errors
/// on a missing subcommand, stray positionals, and duplicate flags.
fn parse(args: &[String]) -> std::result::Result<(String, HashMap<String, String>), String> {
    let mut flags = HashMap::new();
    let mut cmd: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        let arg = &args[i];
        if let Some(key) = arg.strip_prefix("--") {
            if key.is_empty() {
                return Err("empty flag '--'".to_owned());
            }
            let val = match args.get(i + 1) {
                Some(next) if !next.starts_with("--") => {
                    i += 2;
                    next.clone()
                }
                _ => {
                    i += 1;
                    String::new()
                }
            };
            if flags.insert(key.to_owned(), val).is_some() {
                return Err(format!("duplicate flag '--{key}'"));
            }
        } else if cmd.is_none() {
            cmd = Some(arg.clone());
            i += 1;
        } else if arg == "-h" {
            // `sage <cmd> -h` is a help request, not a stray positional
            cmd = Some("help".to_owned());
            i += 1;
        } else {
            return Err(format!("unexpected positional argument '{arg}'"));
        }
    }
    match cmd {
        Some(c) => Ok((c, flags)),
        None => Err("missing subcommand".to_owned()),
    }
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

/// Parse a numeric flag, treating a malformed or missing value as CLI
/// misuse: name the offending flag, print usage, exit 2 (runtime
/// failures keep exit 1).
fn parsed_flag<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: &str,
) -> T
where
    T::Err: std::fmt::Display,
{
    let raw = flag(flags, key, default);
    match raw.parse::<T>() {
        Ok(v) => v,
        Err(e) => usage_error(&format!("invalid value '{raw}' for '--{key}': {e}")),
    }
}

/// Load one attention artifact, run it against synthetic QKV, and compare
/// with the rust-native exact implementation.
fn smoke(flags: &HashMap<String, String>) -> Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    let name = flag(flags, "artifact", "attn_sage_b_1x2x256x64");
    let art = rt.load(name)?;
    let shape = art.spec.shape.clone().context("attention artifact missing shape")?;
    let [b, h, n, d] = [shape[0], shape[1], shape[2], shape[3]];
    let (q, k, v) = make_qkv(42, [b, h, n, d], Profile::diffusion_like());
    let out = art.run(&[
        Value::from_tensor(&q),
        Value::from_tensor(&k),
        Value::from_tensor(&v),
    ])?;
    let gold = attention(&q, &k, &v, AttnImpl::Exact, art.spec.causal.unwrap_or(false));
    let acc = accuracy(&gold.data, out[0].as_f32()?);
    println!("{name}: {acc}");
    ensure!(acc.cos_sim > 0.99, "artifact output diverged from reference");
    println!("smoke OK");
    Ok(())
}

/// Serve a synthetic workload through the full coordinator.
fn serve(flags: &HashMap<String, String>) -> Result<()> {
    // validate CLI input before touching the runtime, so flag misuse
    // reports as misuse (exit 2) rather than a late runtime error
    let config = flag(flags, "config", "small");
    let plan = flag(flags, "plan", "sage");
    let n_req: usize = parsed_flag(flags, "requests", "16");
    let seed: u64 = parsed_flag(flags, "seed", "1");
    let rt = Runtime::open(Runtime::default_dir())?;
    let engine = Engine::new(&rt, config, plan, seed)?;
    let cfg = &rt.manifest.configs[config];
    let vocab = cfg.vocab;
    let max_seq = cfg.max_seq;
    let slots = engine.batch_slots();

    let mut gen = WorkloadGen::new(seed, vocab, 50.0, engine.prefill_sizes(), 24);
    let kv = KvCacheManager::new(slots * max_seq / 16, 16);
    let mut sched = Scheduler::new(Batcher::new(BatchPolicy::Fifo), kv, engine);
    for (i, r) in gen.generate(n_req).into_iter().enumerate() {
        sched.submit(Request::new(
            i as u64,
            r.prompt,
            GenParams { max_new_tokens: r.max_new_tokens, ..Default::default() },
        ));
    }
    let report = sched.run_to_completion()?;
    println!(
        "served {} requests, {} tokens in {:.2}s ({:.1} tok/s)",
        report.responses.len(),
        report.tokens_out,
        report.wall_s,
        report.throughput_tok_s()
    );
    println!(
        "TTFT p50/p99: {:.1}/{:.1} ms   TPOT p50/p99: {:.1}/{:.1} ms",
        report.ttft.percentile(50.0),
        report.ttft.percentile(99.0),
        report.tpot.percentile(50.0),
        report.tpot.percentile(99.0)
    );
    Ok(())
}

/// §4.5 calibration: choose -vB vs -B per layer, write the plan JSON that
/// `aot.py --plan-file` consumes.
fn calibrate(flags: &HashMap<String, String>) -> Result<()> {
    let n_layers: usize = parsed_flag(flags, "layers", "4");
    let profile = Profile::by_name(flag(flags, "profile", "diffusion-like"))
        .context("unknown profile")?;
    let out = flag(flags, "out", "plan.json");
    let seed: u64 = parsed_flag(flags, "seed", "7");
    let layers = adaptive::synth_layer_inputs(n_layers, [1, 4, 256, 64], profile, seed);
    let (plan, detail) = adaptive::calibrate(&layers, false);
    let mut t = Table::new(&["layer", "cos(-vB)", "cos(-B)", "choice"]);
    for d in &detail {
        t.row(&[
            d.layer.to_string(),
            pct(d.cos_vb as f64),
            pct(d.cos_b as f64),
            d.choice.to_string(),
        ]);
    }
    t.print("adaptive calibration (threshold 99.8%)");
    std::fs::write(out, plan.to_json())?;
    println!(
        "\nwrote {out}; estimated attention speedup over all--B: {:.1}%",
        (plan.speedup_estimate() - 1.0) * 100.0
    );
    Ok(())
}

/// Kernel accuracy vs full precision on a synthetic profile (Table 9 style).
fn accuracy_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let profile = Profile::by_name(flag(flags, "profile", "diffusion-like"))
        .context("unknown profile")?;
    let n: usize = parsed_flag(flags, "seq", "512");
    let d: usize = parsed_flag(flags, "headdim", "64");
    let (q, k, v) = make_qkv(3, [2, 4, n, d], profile);
    let gold = attention(&q, &k, &v, AttnImpl::Exact, false);
    let mut t = Table::new(&["kernel", "CosSim", "RelL1", "RMSE"]);
    for imp in [SAGE_T, SAGE_B, SAGE_VT, SAGE_VB] {
        let o = attention(&q, &k, &v, imp, false);
        let a = accuracy(&gold.data, &o.data);
        t.row(&[
            imp.name(),
            pct(a.cos_sim as f64),
            f2(a.rel_l1 as f64 * 100.0) + "e-2",
            sci(a.rmse as f64),
        ]);
    }
    t.print(&format!("kernel accuracy ({} profile, N={n}, d={d})", profile.name));
    Ok(())
}

/// Cost-model speed sweep (Figures 6–9 style) on one device.
fn speed(flags: &HashMap<String, String>) -> Result<()> {
    let dev: &DeviceSpec =
        DeviceSpec::by_name(flag(flags, "device", "4090")).context("unknown device")?;
    let d: usize = parsed_flag(flags, "headdim", "64");
    let causal = flags.contains_key("causal");
    let kernels = [
        AttnKernel::TorchNaive,
        AttnKernel::Xformers,
        AttnKernel::FlashAttention2,
        AttnKernel::SageAttnB,
        AttnKernel::SageAttnVB,
    ];
    let mut t =
        Table::new(&["seq", "Torch", "xformers", "FlashAttn2", "SageAttn-B", "SageAttn-vB"]);
    for n in [1024usize, 2048, 4096, 8192, 16384, 32768] {
        let wp = Workpoint::square(4, 32, n, d, causal);
        let mut row = vec![n.to_string()];
        for k in kernels {
            row.push(f2(predict_tops(dev, k, wp)));
        }
        t.row(&row);
    }
    t.print(&format!(
        "predicted TOPS, {} headdim={d}{}",
        dev.name,
        if causal { " causal" } else { "" }
    ));
    Ok(())
}

/// Before/after GFLOPS on the sage_plane hot path: an unblocked
/// row-at-a-time reference (full softmax, per-row allocation, no KV
/// tiling) vs the blocked, scratch-reusing kernel, both parallelized over
/// (batch, head) planes with the same thread pool. The speedup line is
/// the blocking + scratch win over the textbook formulation.
fn bench_hotpath(flags: &HashMap<String, String>) -> Result<()> {
    let n: usize = parsed_flag(flags, "seq", "4096");
    let d: usize = parsed_flag(flags, "headdim", "128");
    let b: usize = parsed_flag(flags, "batch", "1");
    let h: usize = parsed_flag(flags, "heads", "4");
    let secs: u64 = parsed_flag(flags, "secs", "2");
    if n == 0 || d == 0 || b == 0 || h == 0 || secs == 0 {
        usage_error("bench-hotpath shape dims and --secs must be non-zero");
    }
    let budget = Duration::from_secs(secs);
    let gran = Granularity::PerBlock(BLOCK_Q);
    println!(
        "hot path {b}x{h}x{n}x{d} ({} worker threads, ~{}s/case, ops = 4·N²·d per plane)",
        default_threads(),
        budget.as_secs()
    );

    let (q, k, v) = make_qkv(1, [b, h, n, d], Profile::diffusion_like());
    let ops = attention_ops(b, h, n, n, d, false);
    let gflops = |s: &Sample| ops / s.median_s() / 1e9;

    // "before": the unblocked reference — row-at-a-time, full softmax,
    // per-row Vec allocation, no KV tiling (same plane parallelism).
    let naive_full = |q: &Tensor, k: &Tensor, v: &Tensor| -> Vec<Vec<f32>> {
        parallel_map(b * h, default_threads(), |idx| {
            let (bi, hi) = (idx / h, idx % h);
            sage_plane_naive(
                q.head(bi, hi),
                k.head(bi, hi),
                v.head(bi, hi),
                n,
                n,
                d,
                gran,
                true,
                false,
            )
        })
    };
    let s_naive = bench_budget("naive row-wise (unblocked ref)", budget, 2, || {
        std::hint::black_box(naive_full(&q, &k, &v));
    });

    // "after": blocked tiles + per-thread scratch, same numerics family
    // (fp32-accumulated P·V) — this pair isolates the blocking win.
    let blocked_fp32 = AttnImpl::Sage { qk: gran, pv: PvMode::Fp32Accum, smooth_k: true };
    let s_blocked = bench_budget("blocked+scratch (fp32-acc PV)", budget, 2, || {
        std::hint::black_box(attention(&q, &k, &v, blocked_fp32, false));
    });

    // the two shipping variants, for the record
    let s_fp16 = bench_budget("blocked+scratch (SageAttn-B, fp16-acc sim)", budget, 2, || {
        std::hint::black_box(attention(&q, &k, &v, SAGE_B, false));
    });
    let s_int8 = bench_budget("blocked+scratch (SageAttn-vB, int8 PV)", budget, 2, || {
        std::hint::black_box(attention(&q, &k, &v, SAGE_VB, false));
    });

    let mut t = Table::new(&["case", "median ms", "GFLOPS", "iters"]);
    for s in [&s_naive, &s_blocked, &s_fp16, &s_int8] {
        t.row(&[
            s.name.clone(),
            format!("{:.1}", s.median_s() * 1e3),
            format!("{:.2}", gflops(s)),
            s.iters.to_string(),
        ]);
    }
    t.print("sage_plane hot path: before/after");

    let speedup = gflops(&s_blocked) / gflops(&s_naive);
    println!(
        "\nbench-hotpath speedup: {speedup:.2}x \
         (blocked+scratch sage_plane vs unblocked row-wise reference, \
          fp32-acc P·V, N={n}, d={d})"
    );
    println!("acceptance bar: >= 1.50x at N=4096, d=128");
    Ok(())
}
