//! Minimal row-major tensor used by the rust-native numerics substrate
//! (quantizers, attention references, synthetic generators). This is not a
//! general autodiff array — just contiguous f32 storage with shape
//! bookkeeping and the handful of views the attention kernels need.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data len {} != shape {:?}",
            data.len(),
            shape
        );
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// (B, H, N, d) accessors used throughout the attention code.
    pub fn dims4(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "expected 4-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    /// Contiguous (N, d) slab for one (batch, head) pair of a 4-D tensor.
    pub fn head(&self, b: usize, h: usize) -> &[f32] {
        let (_, nh, n, d) = self.dims4();
        let off = (b * nh + h) * n * d;
        &self.data[off..off + n * d]
    }

    pub fn head_mut(&mut self, b: usize, h: usize) -> &mut [f32] {
        let (_, nh, n, d) = self.dims4();
        let off = (b * nh + h) * n * d;
        &mut self.data[off..off + n * d]
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            data: self.data.iter().map(|&x| f(x)).collect(),
            shape: self.shape.clone(),
        }
    }

    /// Copy rows `lo..hi` of the sequence axis (axis 2) of a (B, H, N, d)
    /// tensor — the decode idiom for slicing a KV prefix or one query row.
    pub fn narrow_n(&self, lo: usize, hi: usize) -> Tensor {
        let (b, h, n, d) = self.dims4();
        assert!(lo <= hi && hi <= n, "narrow_n {lo}..{hi} out of range for N={n}");
        let rows = hi - lo;
        let mut out = Tensor::zeros(&[b, h, rows, d]);
        for bi in 0..b {
            for hi_ in 0..h {
                let src = &self.head(bi, hi_)[lo * d..hi * d];
                out.head_mut(bi, hi_).copy_from_slice(src);
            }
        }
        out
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

/// Scoped-thread parallel map over `0..n` chunks — substrate for the
/// unavailable rayon. `f(i)` must be independent per index. Results are
/// returned in order.
pub fn parallel_map<T: Send>(n: usize, threads: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    parallel_map_with(n, threads, || (), |_, i| f(i))
}

/// [`parallel_map`] with per-thread scratch state: each worker thread
/// builds one `S` via `make_scratch` and reuses it across every index it
/// processes. This is the hot-path allocation contract (§Perf): the
/// blocked attention kernels keep their tile/softmax buffers in an
/// [`crate::attn::Scratch`] that is allocated once per thread, not once
/// per (batch, head) plane — so a B×H sweep does O(threads) allocations
/// instead of O(B·H·N/128).
pub fn parallel_map_with<T: Send, S>(
    n: usize,
    threads: usize,
    make_scratch: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, usize) -> T + Sync,
) -> Vec<T> {
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        let mut scratch = make_scratch();
        return (0..n).map(|i| f(&mut scratch, i)).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut scratch = make_scratch();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(&mut scratch, i);
                    **slots[i].lock().unwrap() = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Default worker count for data-parallel numerics.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_slicing() {
        let t = Tensor::new((0..24).map(|x| x as f32).collect(), &[2, 3, 2, 2]);
        assert_eq!(t.head(0, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(t.head(1, 2), &[20.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![0.0; 5], &[2, 3]);
    }

    #[test]
    fn parallel_map_matches_serial() {
        let serial: Vec<usize> = (0..100).map(|i| i * i).collect();
        let par = parallel_map(100, 8, |i| i * i);
        assert_eq!(serial, par);
    }

    #[test]
    fn parallel_map_with_reuses_scratch_and_orders_results() {
        // scratch is a per-thread buffer; results must still land in order
        let out = parallel_map_with(
            64,
            8,
            || vec![0u8; 16],
            |scratch, i| {
                scratch[i % 16] = scratch[i % 16].wrapping_add(1);
                i * 3
            },
        );
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
        // single-threaded path shares one scratch across all indices
        let sums = parallel_map_with(
            5,
            1,
            || 0usize,
            |acc, i| {
                *acc += i;
                *acc
            },
        );
        assert_eq!(sums, vec![0, 1, 3, 6, 10]);
    }
}
