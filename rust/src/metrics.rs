//! Accuracy metrics (paper §4.3: CosSim, Relative L1, RMSE), latency
//! statistics, and TOPS accounting used by every experiment harness.

/// Cosine similarity of flattened tensors: Σxy / (√Σx² √Σy²).
pub fn cos_sim(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let (mut xy, mut xx, mut yy) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        xy += x as f64 * y as f64;
        xx += x as f64 * x as f64;
        yy += y as f64 * y as f64;
    }
    (xy / (xx.sqrt() * yy.sqrt()).max(1e-30)) as f32
}

/// Relative L1: Σ|x−y| / Σ|x| (x = reference).
pub fn rel_l1(reference: &[f32], other: &[f32]) -> f32 {
    assert_eq!(reference.len(), other.len());
    let (mut num, mut den) = (0f64, 0f64);
    for (&x, &y) in reference.iter().zip(other) {
        num += (x - y).abs() as f64;
        den += x.abs() as f64;
    }
    (num / den.max(1e-30)) as f32
}

/// Root-mean-square error.
pub fn rmse(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let sum: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum();
    ((sum / a.len() as f64).sqrt()) as f32
}

/// The paper's three-metric bundle against a full-precision reference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Accuracy {
    pub cos_sim: f32,
    pub rel_l1: f32,
    pub rmse: f32,
}

pub fn accuracy(reference: &[f32], other: &[f32]) -> Accuracy {
    Accuracy {
        cos_sim: cos_sim(reference, other),
        rel_l1: rel_l1(reference, other),
        rmse: rmse(reference, other),
    }
}

impl std::fmt::Display for Accuracy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CosSim {:.4} | RelL1 {:.4} | RMSE {:.3e}",
            self.cos_sim, self.rel_l1, self.rmse
        )
    }
}

/// Running mean/min/max accumulator (Welford) for layer sweeps —
/// "average accuracy" and "worst accuracy" across all layers (Tables 2–5).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

/// Latency sample sink with percentile queries (serving benches).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, dur: std::time::Duration) {
        self.samples_us.push(dur.as_micros() as u64);
    }

    /// Fold another sink's samples into this one (fleet-level aggregation
    /// across serving replicas — percentiles of the merged set are exact,
    /// unlike averaging per-replica percentiles).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples_us.clone();
        sorted.sort_unstable();
        let rank = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank.min(sorted.len() - 1)] as f64 / 1000.0 // ms
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64 / 1000.0
    }
}

/// Attention FLOP/OP count: 2 matmuls of N_q×N_kv×d, 2 ops per MAC
/// (the convention behind the paper's TOPS numbers).
pub fn attention_ops(batch: usize, heads: usize, n_q: usize, n_kv: usize, d: usize, causal: bool) -> f64 {
    let full = 2.0 * 2.0 * (batch * heads) as f64 * n_q as f64 * n_kv as f64 * d as f64;
    if causal {
        full / 2.0
    } else {
        full
    }
}

/// ops + seconds → TOPS.
pub fn tops(ops: f64, seconds: f64) -> f64 {
    ops / seconds / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cos_sim_identity_and_orthogonal() {
        let a = [1.0, 2.0, 3.0];
        assert!((cos_sim(&a, &a) - 1.0).abs() < 1e-6);
        let b = [0.0, 0.0, 1.0];
        let c = [0.0, 1.0, 0.0];
        assert!(cos_sim(&b, &c).abs() < 1e-6);
        let d = [-1.0, -2.0, -3.0];
        assert!((cos_sim(&a, &d) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn rel_l1_scales() {
        let a = [1.0, 1.0, 1.0, 1.0];
        let b = [1.1, 0.9, 1.1, 0.9];
        assert!((rel_l1(&a, &b) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn rmse_known() {
        let a = [0.0, 0.0];
        let b = [3.0, 4.0];
        assert!((rmse(&a, &b) - (12.5f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn welford_tracks_extremes_and_mean() {
        let mut w = Welford::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        assert_eq!(w.mean(), 2.5);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 4.0);
        assert_eq!(w.count(), 4);
    }

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyStats::default();
        for ms in 1..=100u64 {
            l.record(std::time::Duration::from_millis(ms));
        }
        assert!((l.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((l.percentile(99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn causal_halves_ops() {
        let full = attention_ops(1, 1, 1024, 1024, 64, false);
        let causal = attention_ops(1, 1, 1024, 1024, 64, true);
        assert_eq!(causal * 2.0, full);
    }
}
