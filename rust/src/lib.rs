//! # SageAttention reproduction
//!
//! Production-style reproduction of *SageAttention: Accurate 8-Bit
//! Attention for Plug-and-play Inference Acceleration* (ICLR 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — the quantized FlashAttention-style
//!   Pallas kernel (INT8 QKᵀ, smooth-K, FP16-accumulator P·V).
//! * **L2** (`python/compile/model.py`) — a GPT-style transformer calling
//!   the kernel, AOT-lowered to HLO text artifacts.
//! * **L3** (this crate) — the serving coordinator: PJRT runtime, request
//!   router, continuous batcher, paged KV cache, prefill/decode scheduler,
//!   plus the adaptive-quantization calibrator (§4.5), a GPU cost model
//!   regenerating the paper's speed figures, and rust-native mirrors of
//!   the kernels for accuracy experiments — fronted by the `sageattn`-style
//!   [`attn::AttnSpec`] builder (layout/causal/window/GQA/sm_scale over a
//!   kernel registry) and [`attn::PreparedKV`] quantize-once decode state.
//!
//! Python never runs on the request path: artifacts are compiled once by
//! `make artifacts` and executed through the PJRT C API. Offline builds
//! (no `xla` bindings) link the [`runtime::pjrt`] stub instead: all
//! rust-native numerics, the coordinator accounting, and value
//! marshalling work in full; artifact execution errors cleanly.

pub mod adaptive;
pub mod attn;
pub mod bench;
pub mod coordinator;
pub mod metrics;
pub mod obs;
pub mod perfmodel;
pub mod quant;
pub mod runtime;
pub mod synth;
pub mod tensor;
pub mod testing;
pub mod util;
