//! Micro-benchmark harness (substrate for the unavailable criterion crate):
//! warmup + timed iterations with median/p10/p90 reporting, plus table
//! formatting shared by every paper-table bench.

use std::time::{Duration, Instant};

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl Sample {
    pub fn median_s(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Run `f` with warmup, then time `iters` iterations (min 3).
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let iters = iters.max(3);
    let mut times: Vec<Duration> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    times.sort();
    let mean = times.iter().sum::<Duration>() / iters as u32;
    Sample {
        name: name.to_owned(),
        iters,
        median: times[iters / 2],
        p10: times[iters / 10],
        p90: times[(iters * 9) / 10],
        mean,
    }
}

/// Adaptive variant: keep iterating until `budget` wall time is spent
/// (at least `min_iters`). Good for cases whose cost varies 1000×.
pub fn bench_budget(name: &str, budget: Duration, min_iters: usize, mut f: impl FnMut()) -> Sample {
    f(); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_iters || (start.elapsed() < budget && times.len() < 1000) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let n = times.len();
    let mean = times.iter().sum::<Duration>() / n as u32;
    Sample {
        name: name.to_owned(),
        iters: n,
        median: times[n / 2],
        p10: times[n / 10],
        p90: times[(n * 9) / 10],
        mean,
    }
}

/// Fixed-width table printer for paper-style outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Convenience: `f64 -> "123.4"`.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn sci(x: f64) -> String {
    format!("{x:.2e}")
}

/// Percentage with 2 decimals: 0.9987 -> "99.87%".
pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_percentiles() {
        let s = bench("noop", 1, 25, || {
            std::hint::black_box(42);
        });
        assert!(s.p10 <= s.median && s.median <= s.p90);
        assert_eq!(s.iters, 25);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test"); // smoke: must not panic
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(pct(0.9987), "99.87%");
    }
}
