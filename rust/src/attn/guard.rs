//! Numeric guard for the degraded-mode fallback (ISSUE 7 tentpole §3):
//! cheap NaN/inf detection on kernel outputs so the serving stack can
//! catch a quantization blow-up on a sage plan, evict the offending
//! request, and retry it on the fp path instead of streaming garbage.
//!
//! The guard is deliberately dumb — a finite-ness sweep, no tolerance
//! knobs — because the only numeric failure it must catch is the
//! catastrophic one (NaN/±inf propagating out of a tile). Accuracy
//! regressions short of non-finite stay the calibrator's business.

/// Marker embedded in error messages produced when a non-finite value is
/// detected, so upstream recovery code can distinguish "numerics blew up,
/// retry degraded" from ordinary hard errors without a typed error enum.
pub const NONFINITE_MARKER: &str = "[nonfinite]";

/// Does this error message report a non-finite numeric failure?
pub fn is_nonfinite_err(msg: &str) -> bool {
    msg.contains(NONFINITE_MARKER)
}

/// Index of the first non-finite element, if any.
pub fn first_nonfinite(xs: &[f32]) -> Option<usize> {
    xs.iter().position(|x| !x.is_finite())
}

/// Scan a tile/row buffer; `Ok(())` when every element is finite, else a
/// marker-tagged description (`what` names the tensor, e.g. `"attn l3 h1"`).
pub fn check_finite(what: &str, xs: &[f32]) -> Result<(), String> {
    match first_nonfinite(xs) {
        None => Ok(()),
        Some(i) => Err(format!(
            "{NONFINITE_MARKER} {what}: element {i}/{} is {}",
            xs.len(),
            xs[i]
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finite_buffers_pass() {
        assert!(check_finite("x", &[0.0, -1.5, 3.0e37]).is_ok());
        assert_eq!(first_nonfinite(&[1.0, 2.0]), None);
    }

    #[test]
    fn nan_and_inf_are_caught_and_marked() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let e = check_finite("logits", &[0.0, bad, 1.0]).unwrap_err();
            assert!(is_nonfinite_err(&e), "unmarked: {e}");
            assert!(e.contains("element 1/3"), "bad index: {e}");
        }
        assert!(!is_nonfinite_err("ordinary error"));
    }
}
