//! The `sageattn`-style public attention surface: one spec type that
//! carries every knob the reference repo's
//! `sageattn(q, k, v, tensor_layout, is_causal, sm_scale, ...)` entry
//! point exposes, plus quantize-once KV state for decode.
//!
//! * [`AttnSpec`] — builder-style configuration: kernel selection (by
//!   value, by registry name, or `auto`-dispatched like
//!   `core.py:sageattn`), tensor layout ([`Layout::BHND`]/
//!   [`Layout::BNHD`]), causal masking, softmax-scale override, sliding
//!   window, and a validated GQA head-group mapping.
//! * [`PreparedKV`] — smooth-K + INT8 K + V scales computed once per KV
//!   prefix, reusable across repeated Q batches and extendable
//!   row-by-row, so a decode loop stops re-quantizing its prefix every
//!   token (`sage bench-hotpath`'s prepared-decode lane measures the
//!   win).
//! * The kernel registry ([`crate::attn::registry`]) backs name
//!   resolution and auto-dispatch.
//!
//! The legacy `attention(q, k, v, imp, causal)` free function survives
//! as a deprecated shim over `AttnSpec` (see the README migration note).

use std::borrow::Cow;

use crate::tensor::{default_threads, parallel_map_with, Tensor};
use crate::util::error::{ensure, Result};

use super::plane::{self, PlaneOpts, Scratch};
use super::prepared::{self, PreparedPlane};
use super::registry::{self, KernelReq};
use super::{AttnImpl, SAGE_B, SAGE_T, SAGE_VB, SAGE_VT};

/// Memory layout of the 4-D Q/K/V tensors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// (batch, heads, seq, head_dim) — the crate's native layout (the
    /// reference repo's `"HND"`).
    BHND,
    /// (batch, seq, heads, head_dim) — the usual PyTorch serving layout
    /// (the reference repo's `"NHD"`).
    BNHD,
}

/// Builder-style attention configuration — the `sageattn(...)` call
/// surface as a value.
///
/// ```
/// use sageattention::attn::AttnSpec;
/// use sageattention::metrics::cos_sim;
/// use sageattention::synth::{make_qkv, Profile};
///
/// let (q, k, v) = make_qkv(7, [1, 2, 64, 32], Profile::llama_like());
/// let gold = AttnSpec::exact().run(&q, &k, &v).unwrap();
///
/// // the paper's plug-and-play entry point: kernel auto-dispatched
/// // through the registry, like core.py:sageattn
/// let out = AttnSpec::auto().run(&q, &k, &v).unwrap();
/// assert!(cos_sim(&gold.data, &out.data) > 0.99);
///
/// // or pick a kernel by its table name and stack options builder-style
/// let causal = AttnSpec::by_name("SageAttn-vB")
///     .unwrap()
///     .causal(true)
///     .run(&q, &k, &v)
///     .unwrap();
/// assert_eq!(causal.shape, vec![1, 2, 64, 32]);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AttnSpec {
    kernel: Option<AttnImpl>,
    layout: Layout,
    causal: bool,
    sm_scale: Option<f32>,
    window: Option<usize>,
    kv_heads: Option<usize>,
}

impl AttnSpec {
    /// Auto-dispatch: resolve the kernel through the registry at run
    /// time (the `core.py:sageattn` behavior).
    pub fn auto() -> AttnSpec {
        AttnSpec {
            kernel: None,
            layout: Layout::BHND,
            causal: false,
            sm_scale: None,
            window: None,
            kv_heads: None,
        }
    }

    /// Pin an explicit kernel implementation.
    pub fn new(imp: AttnImpl) -> AttnSpec {
        AttnSpec { kernel: Some(imp), ..AttnSpec::auto() }
    }

    /// Resolve a kernel by registry name (`"SageAttn-B"`, `"exact"`,
    /// `"fa3-fp8"`, …) or structured `AttnImpl` name.
    pub fn by_name(name: &str) -> Result<AttnSpec> {
        match registry::resolve(name) {
            Some(imp) => Ok(AttnSpec::new(imp)),
            None => crate::bail!(
                "unknown attention kernel '{name}' (registered: {})",
                registry::known_names()
            ),
        }
    }

    /// Exact fp32 attention (accuracy gold standard).
    pub fn exact() -> AttnSpec {
        AttnSpec::new(AttnImpl::Exact)
    }

    /// FlashAttention-2-style fp32 online softmax (speed baseline).
    pub fn online() -> AttnSpec {
        AttnSpec::new(AttnImpl::OnlineFp32)
    }

    /// SageAttn-T (Table 6): per-token INT8 QK, FP16-accumulator PV.
    pub fn sage_t() -> AttnSpec {
        AttnSpec::new(SAGE_T)
    }

    /// SageAttn-B (Table 6): per-block INT8 QK, FP16-accumulator PV —
    /// the paper's plug-and-play default.
    pub fn sage_b() -> AttnSpec {
        AttnSpec::new(SAGE_B)
    }

    /// SageAttn-vT (Table 6): per-token INT8 QK, INT8 PV.
    pub fn sage_vt() -> AttnSpec {
        AttnSpec::new(SAGE_VT)
    }

    /// SageAttn-vB (Table 6): per-block INT8 QK, INT8 PV.
    pub fn sage_vb() -> AttnSpec {
        AttnSpec::new(SAGE_VB)
    }

    // ---- builder options -------------------------------------------------

    /// Tensor layout of Q/K/V (and of every output this spec produces).
    pub fn layout(mut self, layout: Layout) -> AttnSpec {
        self.layout = layout;
        self
    }

    /// Decode-aligned causal masking.
    pub fn causal(mut self, causal: bool) -> AttnSpec {
        self.causal = causal;
        self
    }

    /// Softmax scale override (default 1/√d).
    pub fn sm_scale(mut self, scale: f32) -> AttnSpec {
        self.sm_scale = Some(scale);
        self
    }

    /// Sliding-window width (requires `causal`): each query attends the
    /// last `w` keys at or before its causal limit.
    pub fn window(mut self, w: usize) -> AttnSpec {
        self.window = Some(w);
        self
    }

    /// Declare grouped-query attention: K/V carry `n_kv_heads` heads and
    /// each group of `n_heads / n_kv_heads` query heads shares one KV
    /// head. Mismatched head counts without this declaration are an
    /// error (silent shape bugs otherwise).
    pub fn kv_heads(mut self, n_kv_heads: usize) -> AttnSpec {
        self.kv_heads = Some(n_kv_heads);
        self
    }

    // ---- execution -------------------------------------------------------

    /// Kernel this spec resolves to for a given request shape (explicit
    /// kernels are capability-checked; `auto` walks the registry).
    pub fn resolve_kernel(&self, head_dim: usize) -> Result<AttnImpl> {
        self.resolve(&self.request(head_dim, false, false))
    }

    /// Human-readable kernel label for reports.
    pub fn kernel_name(&self) -> String {
        match self.kernel {
            Some(imp) => imp.name(),
            None => "auto".to_owned(),
        }
    }

    /// Run attention. Shapes (in the spec's layout): Q (B, H, N_q, d),
    /// K/V (B, H_kv, N_kv, d) with `H_kv == H` for MHA or a declared
    /// [`AttnSpec::kv_heads`] divisor for GQA.
    pub fn run(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Tensor> {
        let qn = to_bhnd(q, self.layout);
        let kn = to_bhnd(k, self.layout);
        let vn = to_bhnd(v, self.layout);
        let (b, h, n_q, d) = qn.dims4();
        let (bk, h_kv, n_kv, dk) = kn.dims4();
        ensure!(
            kn.dims4() == vn.dims4(),
            "K/V shape mismatch: {:?} vs {:?}",
            kn.shape,
            vn.shape
        );
        ensure!(bk == b, "Q batch {b} != K/V batch {bk}");
        ensure!(dk == d, "Q head_dim {d} != K/V head_dim {dk}");
        self.validate_heads(h, h_kv)?;
        self.validate_mask()?;
        let imp = self.resolve(&self.request(d, h != h_kv, false))?;
        let opts = self.plane_opts();
        let group = h / h_kv;
        // GQA note: each worker quantizes its KV plane independently, so a
        // shared KV head is quantized `group` times per call. One-shot GQA
        // calls eat that (it keeps the bit-identity with repeated-KV MHA
        // simple); amortized workloads should prepare() once instead —
        // PreparedKV quantizes each KV head exactly once.

        let planes = parallel_map_with(b * h, default_threads(), Scratch::new, |scratch, idx| {
            let (bi, hi) = (idx / h, idx % h);
            run_plane_opt(
                scratch,
                qn.head(bi, hi),
                kn.head(bi, hi / group),
                vn.head(bi, hi / group),
                n_q,
                n_kv,
                d,
                imp,
                opts,
            )
        });
        let mut out = Tensor::zeros(&[b, h, n_q, d]);
        for (idx, pl) in planes.into_iter().enumerate() {
            out.head_mut(idx / h, idx % h).copy_from_slice(&pl);
        }
        Ok(from_bhnd(out, self.layout))
    }

    /// Quantize a KV prefix once (smooth-K means, INT8 K planes,
    /// per-channel V scales, fp32 fallbacks) for reuse across repeated Q
    /// batches and row-by-row [`PreparedKV::extend`] calls.
    ///
    /// ```
    /// use sageattention::attn::AttnSpec;
    /// use sageattention::synth::{make_qkv, Profile};
    ///
    /// let (q, k, v) = make_qkv(9, [1, 2, 48, 32], Profile::llama_like());
    /// let spec = AttnSpec::sage_b();
    /// // quantize the 40-row prefix once...
    /// let mut kv = spec.prepare(&k.narrow_n(0, 40), &v.narrow_n(0, 40)).unwrap();
    /// // ...then decode: extend one row per token, never re-quantizing
    /// // the prefix, and reuse the state for every query
    /// for t in 40..48 {
    ///     kv.extend(&k.narrow_n(t, t + 1), &v.narrow_n(t, t + 1)).unwrap();
    ///     let out = spec.run_prepared(&q.narrow_n(t, t + 1), &kv).unwrap();
    ///     assert_eq!(out.shape, vec![1, 2, 1, 32]);
    /// }
    /// // incremental growth is bit-identical to one-shot preparation
    /// assert_eq!(kv, spec.prepare(&k, &v).unwrap());
    /// ```
    pub fn prepare(&self, k: &Tensor, v: &Tensor) -> Result<PreparedKV> {
        let kn = to_bhnd(k, self.layout);
        let (b, h_kv, _, d) = kn.dims4();
        drop(kn);
        let imp = self.resolve(&self.request(d, false, true))?;
        let mut kv = PreparedKV {
            imp,
            layout: self.layout,
            b,
            h_kv,
            d,
            planes: (0..b * h_kv).map(|_| PreparedPlane::new(d)).collect(),
        };
        kv.extend(k, v)?;
        Ok(kv)
    }

    /// Run attention for (possibly repeated) Q batches against a
    /// [`PreparedKV`] — the decode hot path: per call, only Q is
    /// quantized.
    pub fn run_prepared(&self, q: &Tensor, kv: &PreparedKV) -> Result<Tensor> {
        let qn = to_bhnd(q, self.layout);
        let (b, h, n_q, d) = qn.dims4();
        ensure!(b == kv.b, "Q batch {b} != PreparedKV batch {}", kv.b);
        ensure!(d == kv.d, "Q head_dim {d} != PreparedKV head_dim {}", kv.d);
        self.validate_heads(h, kv.h_kv)?;
        self.validate_mask()?;
        let imp = self.resolve(&self.request(d, h != kv.h_kv, true))?;
        ensure!(
            imp == kv.imp,
            "PreparedKV was built for kernel '{}' but the spec resolves to '{}'",
            kv.imp.name(),
            imp.name()
        );
        let opts = self.plane_opts();
        let group = h / kv.h_kv;
        let n_kv = kv.n_kv();

        let planes = parallel_map_with(b * h, default_threads(), Scratch::new, |scratch, idx| {
            let (bi, hi) = (idx / h, idx % h);
            let prep = &kv.planes[bi * kv.h_kv + hi / group];
            match imp {
                AttnImpl::Sage { qk, pv, .. } => prepared::sage_plane_prepared(
                    scratch,
                    qn.head(bi, hi),
                    prep,
                    n_q,
                    qk,
                    pv,
                    opts,
                ),
                AttnImpl::Exact => plane::exact_plane_opt(
                    qn.head(bi, hi),
                    &prep.k_raw,
                    &prep.v_raw,
                    n_q,
                    n_kv,
                    d,
                    opts,
                ),
                AttnImpl::OnlineFp32 => plane::online_plane_opt(
                    scratch,
                    qn.head(bi, hi),
                    &prep.k_raw,
                    &prep.v_raw,
                    n_q,
                    n_kv,
                    d,
                    opts,
                ),
                AttnImpl::Fp8 { .. } => unreachable!("fp8 rejected by the capability check"),
            }
        });
        let mut out = Tensor::zeros(&[b, h, n_q, d]);
        for (idx, pl) in planes.into_iter().enumerate() {
            out.head_mut(idx / h, idx % h).copy_from_slice(&pl);
        }
        Ok(from_bhnd(out, self.layout))
    }

    // ---- internals -------------------------------------------------------

    fn request(&self, head_dim: usize, gqa: bool, prepared: bool) -> KernelReq {
        KernelReq {
            head_dim,
            causal: self.causal,
            window: self.window.is_some(),
            gqa,
            prepared,
        }
    }

    fn resolve(&self, req: &KernelReq) -> Result<AttnImpl> {
        match self.kernel {
            Some(imp) => {
                ensure!(
                    registry::supports(&imp, req),
                    "kernel '{}' does not support this request \
                     (head_dim {}, prepared {}, window {})",
                    imp.name(),
                    req.head_dim,
                    req.prepared,
                    req.window
                );
                Ok(imp)
            }
            None => match registry::auto(req) {
                Some(entry) => Ok(entry.imp),
                None => crate::bail!(
                    "no registered kernel supports this request (registered: {})",
                    registry::known_names()
                ),
            },
        }
    }

    fn plane_opts(&self) -> PlaneOpts {
        PlaneOpts { causal: self.causal, window: self.window, sm_scale: self.sm_scale }
    }

    fn validate_heads(&self, h: usize, h_kv: usize) -> Result<()> {
        if let Some(expect) = self.kv_heads {
            ensure!(
                h_kv == expect,
                "K/V carry {h_kv} heads but the spec declares kv_heads({expect})"
            );
            ensure!(expect >= 1 && expect <= h, "kv_heads({expect}) must be in 1..={h}");
        }
        if h != h_kv {
            ensure!(
                self.kv_heads.is_some(),
                "K/V head count {h_kv} != Q head count {h}: declare grouped-query \
                 attention explicitly with .kv_heads({h_kv})"
            );
            ensure!(
                h % h_kv == 0,
                "GQA requires n_heads ({h}) divisible by n_kv_heads ({h_kv})"
            );
        }
        Ok(())
    }

    fn validate_mask(&self) -> Result<()> {
        if let Some(w) = self.window {
            ensure!(self.causal, "sliding window requires causal attention");
            ensure!(w >= 1, "window width must be >= 1");
        }
        Ok(())
    }
}

/// Quantize-once KV state (see [`AttnSpec::prepare`]): per (batch,
/// kv-head) plane it holds the anchored smooth-K means, the INT8 K plane
/// with its scales, the P·V-mode V representation (per-block per-channel
/// INT8 scales, or fp16-rounded rows) and the raw fp32 rows as
/// requant source / full-precision fallback. Extending row-by-row
/// touches only a bounded suffix and is bit-identical to one-shot
/// preparation.
#[derive(Clone, Debug, PartialEq)]
pub struct PreparedKV {
    imp: AttnImpl,
    layout: Layout,
    b: usize,
    h_kv: usize,
    d: usize,
    planes: Vec<PreparedPlane>,
}

impl PreparedKV {
    /// KV rows currently held.
    pub fn n_kv(&self) -> usize {
        self.planes.first().map(|p| p.n).unwrap_or(0)
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn kv_heads(&self) -> usize {
        self.h_kv
    }

    pub fn head_dim(&self) -> usize {
        self.d
    }

    /// Kernel this state was quantized for.
    pub fn kernel(&self) -> AttnImpl {
        self.imp
    }

    /// Append new KV rows (shape (B, H_kv, t, d) in the originating
    /// spec's layout) — the decode step. Only the trailing partial
    /// quantization block is re-derived; the prefix is untouched.
    pub fn extend(&mut self, k: &Tensor, v: &Tensor) -> Result<()> {
        let kn = to_bhnd(k, self.layout);
        let vn = to_bhnd(v, self.layout);
        let (b, h_kv, _, d) = kn.dims4();
        ensure!(
            kn.dims4() == vn.dims4(),
            "K/V shape mismatch: {:?} vs {:?}",
            kn.shape,
            vn.shape
        );
        ensure!(
            b == self.b && h_kv == self.h_kv && d == self.d,
            "extend shape {:?} does not match PreparedKV (b {}, kv_heads {}, d {})",
            kn.shape,
            self.b,
            self.h_kv,
            self.d
        );
        for bi in 0..b {
            for hi in 0..h_kv {
                self.planes[bi * h_kv + hi].append(kn.head(bi, hi), vn.head(bi, hi), self.imp);
            }
        }
        Ok(())
    }
}

/// Tensor-level dispatch over one (batch, head) plane.
fn run_plane_opt(
    scratch: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    imp: AttnImpl,
    opts: PlaneOpts,
) -> Vec<f32> {
    match imp {
        AttnImpl::Exact => plane::exact_plane_opt(q, k, v, n_q, n_kv, d, opts),
        AttnImpl::OnlineFp32 => plane::online_plane_opt(scratch, q, k, v, n_q, n_kv, d, opts),
        AttnImpl::Sage { qk, pv, smooth_k } => {
            plane::sage_plane_opt(scratch, q, k, v, n_q, n_kv, d, qk, pv, smooth_k, opts)
        }
        AttnImpl::Fp8 { qk, pv } => plane::fp8_plane_opt(q, k, v, n_q, n_kv, d, qk, pv, opts),
    }
}

/// Normalize a tensor to the internal (B, H, N, d) layout (borrowing
/// when it already is).
fn to_bhnd(t: &Tensor, layout: Layout) -> Cow<'_, Tensor> {
    match layout {
        Layout::BHND => Cow::Borrowed(t),
        Layout::BNHD => Cow::Owned(swap12(t)),
    }
}

/// Return an internally-(B, H, N, d) result in the spec's layout.
fn from_bhnd(t: Tensor, layout: Layout) -> Tensor {
    match layout {
        Layout::BHND => t,
        Layout::BNHD => swap12(&t),
    }
}

/// Swap axes 1 and 2 of a 4-D tensor — (B, N, H, d) ↔ (B, H, N, d),
/// its own inverse.
fn swap12(t: &Tensor) -> Tensor {
    let (b, x, y, d) = t.dims4();
    let mut out = Tensor::zeros(&[b, y, x, d]);
    for bi in 0..b {
        for xi in 0..x {
            for yi in 0..y {
                let src = ((bi * x + xi) * y + yi) * d;
                let dst = ((bi * y + yi) * x + xi) * d;
                out.data[dst..dst + d].copy_from_slice(&t.data[src..src + d]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::cos_sim;
    use crate::synth::{make_qkv, Profile};

    #[test]
    fn spec_matches_legacy_attention() {
        // the shim and the spec must agree bit-for-bit
        let (q, k, v) = make_qkv(51, [1, 2, 96, 32], Profile::diffusion_like());
        for name in ["exact", "online", "SageAttn-T", "SageAttn-vB", "fa3-fp8"] {
            let spec = AttnSpec::by_name(name).unwrap().causal(true);
            let via_spec = spec.run(&q, &k, &v).unwrap();
            #[allow(deprecated)]
            let via_legacy =
                super::super::attention(&q, &k, &v, registry::resolve(name).unwrap(), true);
            assert_eq!(via_spec.data, via_legacy.data, "{name}");
        }
    }

    #[test]
    fn swap12_is_involutive() {
        let (q, _, _) = make_qkv(52, [2, 3, 5, 4], Profile::llama_like());
        let back = swap12(&swap12(&q));
        assert_eq!(q.shape, back.shape);
        assert_eq!(q.data, back.data);
    }

    #[test]
    fn auto_dispatch_runs() {
        let (q, k, v) = make_qkv(53, [1, 1, 64, 32], Profile::llama_like());
        let gold = AttnSpec::exact().run(&q, &k, &v).unwrap();
        let out = AttnSpec::auto().causal(false).run(&q, &k, &v).unwrap();
        assert!(cos_sim(&gold.data, &out.data) > 0.99);
        assert_eq!(AttnSpec::auto().kernel_name(), "auto");
        assert_eq!(AttnSpec::auto().resolve_kernel(32).unwrap(), SAGE_B);
    }

    #[test]
    fn invalid_specs_error_cleanly() {
        let (q, k, v) = make_qkv(54, [1, 2, 32, 16], Profile::llama_like());
        // window without causal
        assert!(AttnSpec::sage_b().window(8).run(&q, &k, &v).is_err());
        // undeclared GQA (K/V with fewer heads)
        let (_, k_half, v_half) = make_qkv(54, [1, 1, 32, 16], Profile::llama_like());
        assert!(AttnSpec::sage_b().run(&q, &k_half, &v_half).is_err());
        // declared but non-divisible GQA
        let (q3, _, _) = make_qkv(55, [1, 3, 32, 16], Profile::llama_like());
        assert!(AttnSpec::sage_b().kv_heads(2).run(&q3, &k, &v).is_err());
        // unknown kernel names list the registry
        let err = AttnSpec::by_name("definitely-not-a-kernel").unwrap_err().to_string();
        assert!(err.contains("SageAttn-B"), "{err}");
        // per-channel QK is rejected by capability check, not a panic
        let bad = AttnSpec::new(AttnImpl::Sage {
            qk: crate::quant::Granularity::PerChannel,
            pv: super::super::PvMode::Fp16Accum,
            smooth_k: true,
        });
        assert!(bad.run(&q, &k, &v).is_err());
    }

    #[test]
    fn prepared_rejects_incapable_kernels() {
        let (_, k, v) = make_qkv(56, [1, 1, 64, 16], Profile::llama_like());
        assert!(AttnSpec::by_name("fa3-fp8").unwrap().prepare(&k, &v).is_err());
        let per_tensor = AttnSpec::new(AttnImpl::Sage {
            qk: crate::quant::Granularity::PerTensor,
            pv: super::super::PvMode::Fp16Accum,
            smooth_k: true,
        });
        assert!(per_tensor.prepare(&k, &v).is_err());
        // fp32 references ride the raw-row fallback
        let (q, _, _) = make_qkv(56, [1, 1, 64, 16], Profile::llama_like());
        let spec = AttnSpec::exact().causal(true);
        let kv = spec.prepare(&k, &v).unwrap();
        let a = spec.run_prepared(&q, &kv).unwrap();
        let b = spec.run(&q, &k, &v).unwrap();
        assert_eq!(a.data, b.data, "prepared exact must equal one-shot exact");
    }
}
