//! Quantize-once KV state for the decode path (the
//! [`crate::attn::api::PreparedKV`] substrate).
//!
//! A full `sage_plane` call re-runs smooth-K and re-quantizes K and V on
//! every invocation — asymptotically wasteful when one query row is
//! decoded against a static prefix T times. [`PreparedPlane`] holds the
//! quantized state of one (batch, kv-head) plane so repeated Q batches
//! reuse it, and `append` extends it row-by-row touching only a bounded
//! suffix:
//!
//! * **smooth-K mean** (§4.2): anchored to the first [`BLOCK_KV`] rows
//!   and frozen once that many exist. Softmax is invariant to *any*
//!   fixed per-channel shift of K (the `q·mean` offset is constant
//!   across keys for a given query), so freezing the anchor changes
//!   only quantization error, not the attended distribution — and it
//!   makes every later append O(new rows) instead of O(prefix).
//! * **K scales**: per-token or per-block at absolute row boundaries;
//!   appending requantizes at most the trailing partial block.
//! * **V**: per-channel INT8 scales are kept per [`BLOCK_KV`] block (the
//!   granularity at which the kernel's P·V dequant already runs), so new
//!   rows never rescale old blocks; fp16-rounded V rows are row-local.
//!
//! Because every derived quantity depends only on block-local data (plus
//! the frozen anchor), building the state in one shot and growing it
//! incrementally are **bit-identical** — the invariant
//! `tests/api_scenarios.rs` pins down.

use crate::quant::{self, Granularity};
use crate::util::f16::round_f16_slice;

use super::plane::{dot_i8, PlaneOpts, Scratch};
use super::{AttnImpl, PvMode, BLOCK_KV, BLOCK_Q};

const NEG_BIG: f32 = -1e30;

/// Prepared (quantize-once) state of one (batch, kv-head) KV plane.
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct PreparedPlane {
    pub d: usize,
    /// KV rows currently held.
    pub n: usize,
    /// fp32 fallback (and requant source): raw K/V rows as appended.
    pub k_raw: Vec<f32>,
    pub v_raw: Vec<f32>,
    /// Anchored per-channel smooth-K mean (len d; zeros when smoothing
    /// is off or no Sage kernel is attached).
    pub kmean: Vec<f32>,
    /// Rows `kmean` was computed over — frozen once it reaches BLOCK_KV.
    pub anchor_rows: usize,
    /// INT8 smoothed K plane + per-row scales.
    pub k_i8: Vec<i8>,
    pub k_scales: Vec<f32>,
    /// INT8 V plane + per-(BLOCK_KV block, channel) scales (Int8 P·V).
    pub v_i8: Vec<i8>,
    pub v_scales: Vec<f32>,
    /// fp16-rounded V rows (FP16/FP32-accumulator P·V).
    pub v_f16: Vec<f32>,
}

impl PreparedPlane {
    pub fn new(d: usize) -> PreparedPlane {
        PreparedPlane {
            d,
            n: 0,
            k_raw: Vec::new(),
            v_raw: Vec::new(),
            kmean: vec![0.0; d],
            anchor_rows: 0,
            k_i8: Vec::new(),
            k_scales: Vec::new(),
            v_i8: Vec::new(),
            v_scales: Vec::new(),
            v_f16: Vec::new(),
        }
    }

    /// Append new K/V rows and requantize the bounded suffix they can
    /// affect. One-shot preparation is `append` on an empty plane, so
    /// incremental growth is bit-identical by construction.
    pub fn append(&mut self, k_rows: &[f32], v_rows: &[f32], imp: AttnImpl) {
        let d = self.d;
        debug_assert_eq!(k_rows.len() % d, 0);
        debug_assert_eq!(k_rows.len(), v_rows.len());
        let n_old = self.n;
        self.k_raw.extend_from_slice(k_rows);
        self.v_raw.extend_from_slice(v_rows);
        self.n += k_rows.len() / d;

        let AttnImpl::Sage { qk, pv, smooth_k } = imp else {
            // exact/online fallbacks run straight off k_raw/v_raw
            return;
        };
        let group = match qk {
            Granularity::PerToken => 1,
            Granularity::PerBlock(b) => b,
            // PerTensor/PerChannel are rejected by the capability check
            // before a PreparedKV is ever built
            _ => unreachable!("unsupported prepared Q/K granularity {qk:?}"),
        };

        // anchored smooth-K mean: recomputing it forces a full requant,
        // which can only happen while n < BLOCK_KV (a bounded prefix)
        let mut from_k = n_old - n_old % group;
        if smooth_k && self.anchor_rows < BLOCK_KV.min(self.n) {
            self.anchor_rows = BLOCK_KV.min(self.n);
            self.kmean.iter_mut().for_each(|m| *m = 0.0);
            for r in 0..self.anchor_rows {
                for c in 0..d {
                    self.kmean[c] += self.k_raw[r * d + c];
                }
            }
            for m in self.kmean.iter_mut() {
                *m /= self.anchor_rows as f32;
            }
            from_k = 0;
        }
        self.requant_k_from(from_k, group);

        let from_v = match pv {
            PvMode::Int8 => n_old - n_old % BLOCK_KV,
            _ => n_old,
        };
        self.requant_v_from(from_v, pv);
    }

    /// Rebuild INT8 K data/scales for rows `from..n` (`from` must sit on
    /// a scale-group boundary; group boundaries are absolute, so partial
    /// trailing groups re-derive exactly as a one-shot build would).
    /// Each group is the ψ per-tensor transform of its smoothed rows —
    /// the same `quant` machinery the one-shot kernels use.
    fn requant_k_from(&mut self, from: usize, group: usize) {
        let d = self.d;
        debug_assert_eq!(from % group, 0, "requant must start on a scale-group boundary");
        self.k_i8.truncate(from * d);
        self.k_scales.truncate(from);
        let mut buf = Vec::with_capacity(group.min(self.n - from) * d);
        let (mut data, mut scales) = (Vec::new(), Vec::new());
        let mut g0 = from;
        while g0 < self.n {
            let g1 = (g0 + group).min(self.n);
            buf.clear();
            for r in g0..g1 {
                for c in 0..d {
                    // kmean is all-zero when smoothing is off (x - 0.0
                    // is an IEEE identity, so no branch needed)
                    buf.push(self.k_raw[r * d + c] - self.kmean[c]);
                }
            }
            quant::quant_per_tensor_into(&buf, g1 - g0, d, &mut data, &mut scales);
            self.k_i8.extend_from_slice(&data);
            self.k_scales.extend_from_slice(&scales);
            g0 = g1;
        }
    }

    /// Rebuild the V representation for rows `from..n` (`from` must sit
    /// on a BLOCK_KV boundary in Int8 mode). Each BLOCK_KV block is the
    /// ψ per-channel transform of its raw rows.
    fn requant_v_from(&mut self, from: usize, pv: PvMode) {
        let d = self.d;
        match pv {
            PvMode::Int8 => {
                debug_assert_eq!(from % BLOCK_KV, 0);
                self.v_i8.truncate(from * d);
                self.v_scales.truncate((from / BLOCK_KV) * d);
                let (mut data, mut scales) = (Vec::new(), Vec::new());
                let mut b0 = from;
                while b0 < self.n {
                    let b1 = (b0 + BLOCK_KV).min(self.n);
                    quant::quant_per_channel_into(
                        &self.v_raw[b0 * d..b1 * d],
                        b1 - b0,
                        d,
                        &mut data,
                        &mut scales,
                    );
                    self.v_i8.extend_from_slice(&data);
                    self.v_scales.extend_from_slice(&scales);
                    b0 = b1;
                }
            }
            _ => {
                self.v_f16.truncate(from * d);
                self.v_f16.extend_from_slice(&self.v_raw[from * d..self.n * d]);
                round_f16_slice(&mut self.v_f16[from * d..]);
            }
        }
    }
}

/// Blocked SageAttention kernel against a prequantized KV plane: only Q
/// is quantized per call; K data/scales (smooth-K already folded in) and
/// V come from `prep`. Mirrors `sage_plane_opt`'s tile loop — the
/// anchored smooth-K mean cancels in softmax, so no dequant correction
/// term is needed. V's per-channel scales are per KV block, which slots
/// into the P·V dequant that already runs once per block.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sage_plane_prepared(
    scratch: &mut Scratch,
    q: &[f32],
    prep: &PreparedPlane,
    n_q: usize,
    qk_gran: Granularity,
    pv: PvMode,
    opts: PlaneOpts,
) -> Vec<f32> {
    let d = prep.d;
    let n_kv = prep.n;
    assert!(
        qk_gran != Granularity::PerChannel && qk_gran != Granularity::PerTensor,
        "prepared KV supports PerToken/PerBlock Q/K granularity"
    );
    scratch.ensure_head_dim(d);
    let Scratch { s, p_i8, m, l, acc, p16, part, acc_i32, qbuf, q_i8, q_scales, .. } = scratch;

    let scale = opts.scale(d);
    qbuf.clear();
    qbuf.extend(q.iter().map(|&x| x * scale));
    quant::quantize_into(qbuf, n_q, d, qk_gran, q_i8, q_scales);

    let mut out = vec![0.0f32; n_q * d];

    let mut i0 = 0;
    while i0 < n_q {
        let iq = (i0 + BLOCK_Q).min(n_q);
        let bq = iq - i0;
        let mb = &mut m[..bq];
        mb.fill(NEG_BIG);
        let lb = &mut l[..bq];
        lb.fill(0.0);
        let accb = &mut acc[..bq * d];
        accb.fill(0.0);
        let mut j0 = 0;
        while j0 < n_kv {
            let jk = (j0 + BLOCK_KV).min(n_kv);
            let bk = jk - j0;
            // ---- S tile from the prepared INT8 K ----
            for bi in 0..bq {
                let (lo, hi) = opts.range(i0 + bi, n_q, n_kv);
                let qi = &q_i8[(i0 + bi) * d..(i0 + bi + 1) * d];
                let qs = q_scales[i0 + bi];
                for bj in 0..bk {
                    let j = j0 + bj;
                    let s_val = if j >= lo && j < hi {
                        let kj = &prep.k_i8[j * d..(j + 1) * d];
                        dot_i8(qi, kj) as f32 * qs * prep.k_scales[j]
                    } else {
                        NEG_BIG
                    };
                    s[bi * BLOCK_KV + bj] = s_val;
                }
            }
            // ---- online softmax (fp32) + P·V ----
            // per-block V scales for this tile (Int8 mode)
            let vs_base = (j0 / BLOCK_KV) * d;
            for bi in 0..bq {
                let row = &mut s[bi * BLOCK_KV..bi * BLOCK_KV + bk];
                let m_cur = row.iter().fold(NEG_BIG, |a, &b| a.max(b));
                let m_new = mb[bi].max(m_cur);
                if m_new == NEG_BIG {
                    continue;
                }
                let alpha = (mb[bi] - m_new).exp();
                let mut row_sum = 0.0;
                for p in row.iter_mut() {
                    *p = (*p - m_new).exp();
                    row_sum += *p;
                }
                lb[bi] = alpha * lb[bi] + row_sum;
                mb[bi] = m_new;
                let o = &mut accb[bi * d..(bi + 1) * d];
                match pv {
                    PvMode::Int8 => {
                        let prow = &mut p_i8[..bk];
                        for (pq, &p) in prow.iter_mut().zip(row.iter()) {
                            *pq = (p * quant::INT8_MAX).round() as i8;
                        }
                        for oc in o.iter_mut() {
                            *oc *= alpha;
                        }
                        let acc32 = &mut acc_i32[..d];
                        acc32.fill(0);
                        for (bj, &pq) in prow.iter().enumerate() {
                            if pq == 0 {
                                continue;
                            }
                            let p32 = pq as i32;
                            let vrow = &prep.v_i8[(j0 + bj) * d..(j0 + bj + 1) * d];
                            for (a, &vc) in acc32.iter_mut().zip(vrow) {
                                *a += p32 * vc as i32;
                            }
                        }
                        let vs = &prep.v_scales[vs_base..vs_base + d];
                        for (oc, (&a, &vsc)) in o.iter_mut().zip(acc32.iter().zip(vs)) {
                            *oc += a as f32 * (1.0 / quant::INT8_MAX) * vsc;
                        }
                    }
                    PvMode::Fp16Accum => {
                        for oc in o.iter_mut() {
                            *oc *= alpha;
                        }
                        round_f16_slice(o);
                        let p16b = &mut p16[..bk];
                        p16b.copy_from_slice(&row[..bk]);
                        round_f16_slice(p16b);
                        let partd = &mut part[..d];
                        let mut bj = 0;
                        while bj < bk {
                            let je = (bj + 16).min(bk);
                            partd.fill(0.0);
                            for t in bj..je {
                                let p = p16b[t];
                                if p == 0.0 {
                                    continue;
                                }
                                let vrow = &prep.v_f16[(j0 + t) * d..(j0 + t + 1) * d];
                                for (pc, &vc) in partd.iter_mut().zip(vrow) {
                                    *pc += p * vc;
                                }
                            }
                            round_f16_slice(partd);
                            for (oc, &pc) in o.iter_mut().zip(partd.iter()) {
                                *oc += pc;
                            }
                            round_f16_slice(o);
                            bj = je;
                        }
                    }
                    PvMode::Fp32Accum => {
                        for oc in o.iter_mut() {
                            *oc *= alpha;
                        }
                        let p16b = &mut p16[..bk];
                        p16b.copy_from_slice(&row[..bk]);
                        round_f16_slice(p16b);
                        for (bj, &p) in p16b.iter().enumerate() {
                            if p == 0.0 {
                                continue;
                            }
                            let vrow = &prep.v_f16[(j0 + bj) * d..(j0 + bj + 1) * d];
                            for (oc, &vc) in o.iter_mut().zip(vrow) {
                                *oc += p * vc;
                            }
                        }
                    }
                }
            }
            j0 = jk;
        }
        for bi in 0..bq {
            let inv = 1.0 / lb[bi].max(1e-30);
            let o = &mut out[(i0 + bi) * d..(i0 + bi + 1) * d];
            for (oc, &ac) in o.iter_mut().zip(&accb[bi * d..(bi + 1) * d]) {
                *oc = ac * inv;
            }
        }
        i0 = iq;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::cos_sim;
    use crate::synth::{make_qkv, Profile};
    use crate::attn::plane::exact_plane;
    use crate::attn::{SAGE_B, SAGE_T, SAGE_VB, SAGE_VT};

    fn build(k: &[f32], v: &[f32], d: usize, imp: AttnImpl) -> PreparedPlane {
        let mut p = PreparedPlane::new(d);
        p.append(k, v, imp);
        p
    }

    #[test]
    fn oneshot_equals_rowwise_incremental() {
        let (n, d) = (200usize, 32usize);
        let (_, k, v) = make_qkv(31, [1, 1, n, d], Profile::diffusion_like());
        for imp in [SAGE_T, SAGE_B, SAGE_VT, SAGE_VB] {
            let oneshot = build(&k.data, &v.data, d, imp);
            // grow row by row through every anchor/group/block boundary
            let mut inc = PreparedPlane::new(d);
            for r in 0..n {
                inc.append(&k.data[r * d..(r + 1) * d], &v.data[r * d..(r + 1) * d], imp);
            }
            assert_eq!(oneshot, inc, "{}", imp.name());
            // and in irregular chunks
            let mut chunked = PreparedPlane::new(d);
            let mut r = 0;
            for step in [1usize, 7, 63, 64, 65, 100].iter().cycle() {
                if r >= n {
                    break;
                }
                let e = (r + step).min(n);
                chunked.append(&k.data[r * d..e * d], &v.data[r * d..e * d], imp);
                r = e;
            }
            assert_eq!(oneshot, chunked, "{} chunked", imp.name());
        }
    }

    #[test]
    fn prepared_kernel_tracks_exact() {
        let (n, d) = (256usize, 64usize);
        let (q, k, v) = make_qkv(32, [1, 1, n, d], Profile::diffusion_like());
        let gold = exact_plane(&q.data, &k.data, &v.data, n, n, d, false);
        let mut scratch = Scratch::new();
        for (imp, min_cos) in [(SAGE_T, 0.999), (SAGE_B, 0.999), (SAGE_VT, 0.99), (SAGE_VB, 0.99)]
        {
            let prep = build(&k.data, &v.data, d, imp);
            let AttnImpl::Sage { qk, pv, .. } = imp else { unreachable!() };
            let out = sage_plane_prepared(
                &mut scratch, &q.data, &prep, n, qk, pv, PlaneOpts::causal(false),
            );
            let c = cos_sim(&gold, &out);
            assert!(c > min_cos, "{}: cos {c}", imp.name());
        }
    }

    #[test]
    fn anchor_freezes_after_first_block() {
        let (n, d) = (300usize, 16usize);
        let (_, k, v) = make_qkv(33, [1, 1, n, d], Profile::diffusion_like());
        let mut p = build(&k.data[..BLOCK_KV * d], &v.data[..BLOCK_KV * d], d, SAGE_T);
        let frozen = p.kmean.clone();
        p.append(&k.data[BLOCK_KV * d..], &v.data[BLOCK_KV * d..], SAGE_T);
        assert_eq!(p.kmean, frozen, "anchor mean must not move after BLOCK_KV rows");
        assert_eq!(p.anchor_rows, BLOCK_KV);
        assert_eq!(p.n, n);
        assert_eq!(p.k_scales.len(), n);
        assert_eq!(p.k_i8.len(), n * d);
    }
}
