//! Quantize-once KV state for the decode path (the
//! [`crate::attn::api::PreparedKV`] substrate).
//!
//! A full `sage_plane` call re-runs smooth-K and re-quantizes K and V on
//! every invocation — asymptotically wasteful when one query row is
//! decoded against a static prefix T times. [`PreparedPlane`] holds the
//! quantized state of one (batch, kv-head) plane so repeated Q batches
//! reuse it, and `append` extends it row-by-row touching only a bounded
//! suffix:
//!
//! * **smooth-K mean** (§4.2): anchored to the first [`BLOCK_KV`] rows
//!   and frozen once that many exist. Softmax is invariant to *any*
//!   fixed per-channel shift of K (the `q·mean` offset is constant
//!   across keys for a given query), so freezing the anchor changes
//!   only quantization error, not the attended distribution — and it
//!   makes every later append O(new rows) instead of O(prefix).
//! * **K scales**: per-token or per-block at absolute row boundaries;
//!   appending requantizes at most the trailing partial block.
//! * **V**: per-channel INT8 scales are kept per [`BLOCK_KV`] block (the
//!   granularity at which the kernel's P·V dequant already runs), so new
//!   rows never rescale old blocks; fp16-rounded V rows are row-local.
//!
//! Because every derived quantity depends only on block-local data (plus
//! the frozen anchor), building the state in one shot and growing it
//! incrementally are **bit-identical** — the invariant
//! `tests/api_scenarios.rs` pins down.

use crate::obs::phase::Phase;
use crate::quant::{self, Granularity};
use crate::util::error::Result;
use crate::util::f16::round_f16_slice;

use super::isa;
use super::plane::{self, qk_score_tile, PlaneOpts, Scratch};
use super::registry::{self, KernelReq};
use super::{AttnImpl, PvMode, BLOCK_KV, BLOCK_Q};

const NEG_BIG: f32 = -1e30;

/// Rows per physical KV page — fixed at [`BLOCK_KV`], the granularity at
/// which the kernel's K tiles and per-channel V scales (§4.3–§4.4) are
/// already block-local, so a page never shares quantization state with
/// its neighbours and the paged kernel maps tiles to pages 1:1.
pub const PAGE_ROWS: usize = BLOCK_KV;

/// Prepared (quantize-once) state of one (batch, kv-head) KV plane.
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct PreparedPlane {
    pub d: usize,
    /// KV rows currently held.
    pub n: usize,
    /// fp32 fallback (and requant source): raw K/V rows as appended.
    pub k_raw: Vec<f32>,
    pub v_raw: Vec<f32>,
    /// Anchored per-channel smooth-K mean (len d; zeros when smoothing
    /// is off or no Sage kernel is attached).
    pub kmean: Vec<f32>,
    /// Rows `kmean` was computed over — frozen once it reaches BLOCK_KV.
    pub anchor_rows: usize,
    /// INT8 smoothed K plane + per-row scales.
    pub k_i8: Vec<i8>,
    pub k_scales: Vec<f32>,
    /// INT8 V plane + per-(BLOCK_KV block, channel) scales (Int8 P·V).
    pub v_i8: Vec<i8>,
    pub v_scales: Vec<f32>,
    /// fp16-rounded V rows (FP16/FP32-accumulator P·V).
    pub v_f16: Vec<f32>,
}

impl PreparedPlane {
    pub fn new(d: usize) -> PreparedPlane {
        PreparedPlane {
            d,
            n: 0,
            k_raw: Vec::new(),
            v_raw: Vec::new(),
            kmean: vec![0.0; d],
            anchor_rows: 0,
            k_i8: Vec::new(),
            k_scales: Vec::new(),
            v_i8: Vec::new(),
            v_scales: Vec::new(),
            v_f16: Vec::new(),
        }
    }

    /// Append new K/V rows and requantize the bounded suffix they can
    /// affect. One-shot preparation is `append` on an empty plane, so
    /// incremental growth is bit-identical by construction.
    pub fn append(&mut self, k_rows: &[f32], v_rows: &[f32], imp: AttnImpl) {
        let d = self.d;
        debug_assert_eq!(k_rows.len() % d, 0);
        debug_assert_eq!(k_rows.len(), v_rows.len());
        let n_old = self.n;
        self.k_raw.extend_from_slice(k_rows);
        self.v_raw.extend_from_slice(v_rows);
        self.n += k_rows.len() / d;

        let AttnImpl::Sage { qk, pv, smooth_k } = imp else {
            // exact/online fallbacks run straight off k_raw/v_raw
            return;
        };
        let group = match qk {
            Granularity::PerToken => 1,
            Granularity::PerBlock(b) => b,
            // PerTensor/PerChannel are rejected by the capability check
            // before a PreparedKV is ever built
            _ => unreachable!("unsupported prepared Q/K granularity {qk:?}"),
        };

        // anchored smooth-K mean: recomputing it forces a full requant,
        // which can only happen while n < BLOCK_KV (a bounded prefix)
        let mut from_k = n_old - n_old % group;
        if smooth_k && self.anchor_rows < BLOCK_KV.min(self.n) {
            self.anchor_rows = BLOCK_KV.min(self.n);
            self.kmean.iter_mut().for_each(|m| *m = 0.0);
            for r in 0..self.anchor_rows {
                for c in 0..d {
                    self.kmean[c] += self.k_raw[r * d + c];
                }
            }
            for m in self.kmean.iter_mut() {
                *m /= self.anchor_rows as f32;
            }
            from_k = 0;
        }
        self.requant_k_from(from_k, group);

        let from_v = match pv {
            PvMode::Int8 => n_old - n_old % BLOCK_KV,
            _ => n_old,
        };
        self.requant_v_from(from_v, pv);
    }

    /// Rebuild INT8 K data/scales for rows `from..n` (`from` must sit on
    /// a scale-group boundary; group boundaries are absolute, so partial
    /// trailing groups re-derive exactly as a one-shot build would).
    /// Each group is the ψ per-tensor transform of its smoothed rows —
    /// the same `quant` machinery the one-shot kernels use.
    fn requant_k_from(&mut self, from: usize, group: usize) {
        let d = self.d;
        debug_assert_eq!(from % group, 0, "requant must start on a scale-group boundary");
        self.k_i8.truncate(from * d);
        self.k_scales.truncate(from);
        let mut buf = Vec::with_capacity(group.min(self.n - from) * d);
        let (mut data, mut scales) = (Vec::new(), Vec::new());
        let mut g0 = from;
        while g0 < self.n {
            let g1 = (g0 + group).min(self.n);
            buf.clear();
            for r in g0..g1 {
                for c in 0..d {
                    // kmean is all-zero when smoothing is off (x - 0.0
                    // is an IEEE identity, so no branch needed)
                    buf.push(self.k_raw[r * d + c] - self.kmean[c]);
                }
            }
            quant::quant_per_tensor_into(&buf, g1 - g0, d, &mut data, &mut scales);
            self.k_i8.extend_from_slice(&data);
            self.k_scales.extend_from_slice(&scales);
            g0 = g1;
        }
    }

    /// Rebuild the V representation for rows `from..n` (`from` must sit
    /// on a BLOCK_KV boundary in Int8 mode). Each BLOCK_KV block is the
    /// ψ per-channel transform of its raw rows.
    fn requant_v_from(&mut self, from: usize, pv: PvMode) {
        let d = self.d;
        match pv {
            PvMode::Int8 => {
                debug_assert_eq!(from % BLOCK_KV, 0);
                self.v_i8.truncate(from * d);
                self.v_scales.truncate((from / BLOCK_KV) * d);
                let (mut data, mut scales) = (Vec::new(), Vec::new());
                let mut b0 = from;
                while b0 < self.n {
                    let b1 = (b0 + BLOCK_KV).min(self.n);
                    quant::quant_per_channel_into(
                        &self.v_raw[b0 * d..b1 * d],
                        b1 - b0,
                        d,
                        &mut data,
                        &mut scales,
                    );
                    self.v_i8.extend_from_slice(&data);
                    self.v_scales.extend_from_slice(&scales);
                    b0 = b1;
                }
            }
            _ => {
                self.v_f16.truncate(from * d);
                self.v_f16.extend_from_slice(&self.v_raw[from * d..self.n * d]);
                round_f16_slice(&mut self.v_f16[from * d..]);
            }
        }
    }
}

/// Blocked SageAttention kernel against a prequantized KV plane: only Q
/// is quantized per call; K data/scales (smooth-K already folded in) and
/// V come from `prep`. Mirrors `sage_plane_opt`'s tile loop — the
/// anchored smooth-K mean cancels in softmax, so no dequant correction
/// term is needed. V's per-channel scales are per KV block, which slots
/// into the P·V dequant that already runs once per block.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sage_plane_prepared(
    scratch: &mut Scratch,
    q: &[f32],
    prep: &PreparedPlane,
    n_q: usize,
    qk_gran: Granularity,
    pv: PvMode,
    opts: PlaneOpts,
) -> Vec<f32> {
    let d = prep.d;
    let n_kv = prep.n;
    assert!(
        qk_gran != Granularity::PerChannel && qk_gran != Granularity::PerTensor,
        "prepared KV supports PerToken/PerBlock Q/K granularity"
    );
    scratch.ensure_head_dim(d);
    let Scratch { s, s_i32, p_i8, m, l, acc, p16, acc_i32, qbuf, q_i8, q_scales, timer, .. } =
        scratch;
    let kern = isa::kernels();
    timer.begin_plane();

    // prepared KV carries quantized K and rounded V already: the only
    // quantization on this path is Q (the decode-side ψ of §3's
    // quantize-once pipeline), so the f16-round phase never fires here
    let scale = opts.scale(d);
    let t_quant = timer.section();
    qbuf.clear();
    qbuf.extend(q.iter().map(|&x| x * scale));
    quant::quantize_into(qbuf, n_q, d, qk_gran, q_i8, q_scales);
    timer.commit(Phase::Quant, t_quant);

    let mut out = vec![0.0f32; n_q * d];

    let mut i0 = 0;
    while i0 < n_q {
        let iq = (i0 + BLOCK_Q).min(n_q);
        let bq = iq - i0;
        let mb = &mut m[..bq];
        mb.fill(NEG_BIG);
        let lb = &mut l[..bq];
        lb.fill(0.0);
        let accb = &mut acc[..bq * d];
        accb.fill(0.0);
        let mut j0 = 0;
        while j0 < n_kv {
            let jk = (j0 + BLOCK_KV).min(n_kv);
            let bk = jk - j0;
            // touch the next tile's K rows while this tile computes
            if jk < n_kv {
                isa::prefetch_head(&prep.k_i8[jk * d..]);
            }
            // ---- S tile from the prepared INT8 K (ISA microkernel) ----
            let t_qk = timer.section();
            qk_score_tile(
                kern,
                opts,
                q_i8,
                q_scales,
                &prep.k_i8[j0 * d..jk * d],
                &prep.k_scales[j0..jk],
                s,
                s_i32,
                i0,
                bq,
                j0,
                jk,
                n_q,
                n_kv,
                d,
            );
            timer.commit(Phase::QkTile, t_qk);
            // this tile's V rows (per-block V scales in Int8 mode)
            let vs_base = (j0 / BLOCK_KV) * d;
            let vtile = match pv {
                PvMode::Int8 => super::pv::PvTile::Int8 {
                    v: &prep.v_i8[j0 * d..jk * d],
                    scales: &prep.v_scales[vs_base..vs_base + d],
                },
                PvMode::Fp16Accum => {
                    super::pv::PvTile::F16Accum { v: &prep.v_f16[j0 * d..jk * d] }
                }
                PvMode::Fp32Accum => {
                    super::pv::PvTile::F32Accum { v: &prep.v_f16[j0 * d..jk * d] }
                }
            };
            // ---- online softmax (fp32) + P·V ----
            for bi in 0..bq {
                let t_sm = timer.section();
                let row = &mut s[bi * BLOCK_KV..bi * BLOCK_KV + bk];
                let m_cur = row.iter().fold(NEG_BIG, |a, &b| a.max(b));
                let m_new = mb[bi].max(m_cur);
                if m_new == NEG_BIG {
                    timer.commit(Phase::Softmax, t_sm);
                    continue;
                }
                let alpha = (mb[bi] - m_new).exp();
                let mut row_sum = 0.0;
                for p in row.iter_mut() {
                    *p = (*p - m_new).exp();
                    row_sum += *p;
                }
                lb[bi] = alpha * lb[bi] + row_sum;
                mb[bi] = m_new;
                timer.commit(Phase::Softmax, t_sm);
                let o = &mut accb[bi * d..(bi + 1) * d];
                // shared P·V tile formulation (attn::pv)
                let t_pv = timer.section();
                super::pv::accumulate(kern, &vtile, o, alpha, row, p_i8, p16, acc_i32, d);
                timer.commit(Phase::Pv, t_pv);
            }
            j0 = jk;
        }
        for bi in 0..bq {
            let inv = 1.0 / lb[bi].max(1e-30);
            let o = &mut out[(i0 + bi) * d..(i0 + bi + 1) * d];
            for (oc, &ac) in o.iter_mut().zip(&accb[bi * d..(bi + 1) * d]) {
                *oc = ac * inv;
            }
        }
        i0 = iq;
    }
    out
}

// ---------------------------------------------------------------------------
// Paged (random-access) surface: the serving cache's physical blocks
// ---------------------------------------------------------------------------

/// One fixed-size physical page ([`PAGE_ROWS`] rows) of one
/// (layer, kv-head) KV plane — the payload a serving block owns.
///
/// A page carries everything the paper's §3 quantize-once pipeline
/// derives for its rows: the raw fp32 rows (requant source and
/// full-precision fallback), the smoothed INT8 K rows with their per-row
/// scales (per-token or block-constant, §4.2–§4.3), and the P·V-mode V
/// representation — per-channel INT8 scales covering exactly this page
/// (§4.4) or fp16-rounded rows. All of it is page-local (plus the
/// segment's frozen smooth-K anchor), which is what makes fixed-size
/// paging possible without cross-page requantization.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvPage {
    pub(crate) k_raw: Vec<f32>,
    pub(crate) v_raw: Vec<f32>,
    pub(crate) k_i8: Vec<i8>,
    pub(crate) k_scales: Vec<f32>,
    pub(crate) v_i8: Vec<i8>,
    pub(crate) v_scales: Vec<f32>,
    pub(crate) v_f16: Vec<f32>,
}

impl KvPage {
    pub fn new() -> KvPage {
        KvPage::default()
    }

    /// KV rows currently resident in this page.
    pub fn rows(&self, d: usize) -> usize {
        debug_assert_eq!(self.k_raw.len() % d, 0);
        self.k_raw.len() / d
    }

    /// Resident payload size in bytes (telemetry).
    pub fn payload_bytes(&self) -> usize {
        (self.k_raw.len() + self.v_raw.len() + self.v_f16.len()) * 4
            + (self.k_scales.len() + self.v_scales.len()) * 4
            + self.k_i8.len()
            + self.v_i8.len()
    }
}

/// Per-(layer, kv-head) metadata of a KV plane whose rows live in
/// externally-owned [`KvPage`]s — the paged counterpart of
/// [`crate::attn::PreparedKV`]'s planes. The segment holds only O(d)
/// state (the frozen §4.2 smooth-K anchor and the row count); every
/// per-row quantity sits in the pages, resolved through whatever block
/// table the caller maintains.
///
/// [`PagedSegment::append`] mirrors the `PreparedKV` append contract:
/// one-shot building and row-by-row growth are bit-identical, and each
/// append requantizes at most the trailing partial scale group / page.
/// [`PagedSegment::run`] is bit-identical to
/// [`crate::attn::AttnSpec::run_prepared`] on the same rows (the
/// serving acceptance invariant; see `tests/native_serving.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct PagedSegment {
    imp: AttnImpl,
    d: usize,
    n: usize,
    /// Anchored per-channel smooth-K mean (frozen after the first page).
    kmean: Vec<f32>,
    anchor_rows: usize,
}

impl PagedSegment {
    /// Build an empty segment for head dim `d` quantized for `imp`.
    /// Rejects kernels without a quantize-once state (FP8, per-tensor /
    /// per-channel Q/K) exactly like [`crate::attn::AttnSpec::prepare`].
    pub fn new(d: usize, imp: AttnImpl) -> Result<PagedSegment> {
        let req = KernelReq { head_dim: d, prepared: true, ..Default::default() };
        crate::ensure!(
            registry::supports(&imp, &req),
            "kernel '{}' has no quantize-once state to page",
            imp.name()
        );
        Ok(PagedSegment { imp, d, n: 0, kmean: vec![0.0; d], anchor_rows: 0 })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn head_dim(&self) -> usize {
        self.d
    }

    pub fn kernel(&self) -> AttnImpl {
        self.imp
    }

    /// Pages needed to hold `rows` KV rows.
    pub fn pages_for(rows: usize) -> usize {
        rows.div_ceil(PAGE_ROWS)
    }

    /// A copy of this segment truncated to its first `rows` rows — the
    /// metadata half of forking a sequence for prefix sharing (the rows
    /// themselves stay in shared pages resolved through block tables, so
    /// only this O(d) state is cloned). `rows` must equal the resident
    /// count or cut on a page boundary: page-local quantization plus the
    /// frozen smooth-K anchor make such a truncated view bit-identical
    /// to a one-shot build of the same rows.
    pub fn fork_prefix(&self, rows: usize) -> Result<PagedSegment> {
        crate::ensure!(
            rows <= self.n,
            "prefix fork of {rows} rows but only {} resident",
            self.n
        );
        crate::ensure!(
            rows == self.n || rows % PAGE_ROWS == 0,
            "prefix fork must cut on a page boundary, got {rows} rows"
        );
        Ok(PagedSegment {
            imp: self.imp,
            d: self.d,
            n: rows,
            kmean: self.kmean.clone(),
            anchor_rows: self.anchor_rows.min(rows),
        })
    }

    /// First row index an append starting at row `n` may rewrite: the
    /// start of the trailing partial K scale group. Block-granular K
    /// scales can span pages (`BLOCK_Q` > [`PAGE_ROWS`]), so a
    /// copy-on-write barrier must cover every block from this row on —
    /// all other per-row state (raw rows, per-token K scales, V in
    /// either mode) is page-local to the appended rows themselves.
    pub fn mutation_horizon(&self, n: usize) -> usize {
        match self.imp {
            AttnImpl::Sage { qk: Granularity::PerBlock(b), .. } => n - n % b,
            _ => n,
        }
    }

    /// Append new K/V rows (row-major, `rows × d` each) into `pages`,
    /// requantizing only the bounded suffix they can affect. `pages`
    /// must be the segment's pages in block-table order with capacity
    /// for the new rows; the same take-append-put sequence of calls is
    /// bit-identical to a single one-shot append (the `PreparedKV`
    /// invariant, per-page).
    pub fn append(&mut self, pages: &mut [KvPage], k_rows: &[f32], v_rows: &[f32]) {
        let d = self.d;
        debug_assert_eq!(k_rows.len() % d, 0);
        debug_assert_eq!(k_rows.len(), v_rows.len());
        let rows_new = k_rows.len() / d;
        let n_old = self.n;
        assert!(
            pages.len() * PAGE_ROWS >= n_old + rows_new,
            "segment append overflows the page table: {} pages for {} rows",
            pages.len(),
            n_old + rows_new
        );
        for i in 0..rows_new {
            let r = n_old + i;
            let pg = &mut pages[r / PAGE_ROWS];
            debug_assert_eq!(pg.k_raw.len(), (r % PAGE_ROWS) * d, "page row misalignment");
            pg.k_raw.extend_from_slice(&k_rows[i * d..(i + 1) * d]);
            pg.v_raw.extend_from_slice(&v_rows[i * d..(i + 1) * d]);
        }
        self.n += rows_new;

        let AttnImpl::Sage { qk, pv, smooth_k } = self.imp else {
            // fp32 references run straight off the raw page rows
            return;
        };
        let group = match qk {
            Granularity::PerToken => 1,
            Granularity::PerBlock(b) => b,
            _ => unreachable!("unsupported paged Q/K granularity {qk:?}"),
        };

        // anchored smooth-K mean: recomputable only while the anchor is
        // still growing (n < PAGE_ROWS), i.e. entirely within page 0
        let mut from_k = n_old - n_old % group;
        if smooth_k && self.anchor_rows < BLOCK_KV.min(self.n) {
            self.anchor_rows = BLOCK_KV.min(self.n);
            self.kmean.iter_mut().for_each(|m| *m = 0.0);
            for r in 0..self.anchor_rows {
                for c in 0..d {
                    self.kmean[c] += pages[0].k_raw[r * d + c];
                }
            }
            for m in self.kmean.iter_mut() {
                *m /= self.anchor_rows as f32;
            }
            from_k = 0;
        }
        self.requant_k_from(pages, from_k, group);

        let from_v = match pv {
            PvMode::Int8 => n_old - n_old % BLOCK_KV,
            _ => n_old,
        };
        self.requant_v_from(pages, from_v, pv);
    }

    /// Rebuild INT8 K data/scales for rows `from..n` across the pages
    /// (`from` on a scale-group boundary) — the paged mirror of
    /// `PreparedPlane::requant_k_from`, gathering each group's raw rows
    /// through the page table and scattering the ψ output back.
    fn requant_k_from(&mut self, pages: &mut [KvPage], from: usize, group: usize) {
        let d = self.d;
        debug_assert_eq!(from % group, 0, "requant must start on a scale-group boundary");
        let first_pg = from / PAGE_ROWS;
        for (pi, pg) in pages.iter_mut().enumerate().skip(first_pg) {
            let local = if pi == first_pg { from % PAGE_ROWS } else { 0 };
            pg.k_i8.truncate(local * d);
            pg.k_scales.truncate(local);
        }
        let mut buf = Vec::with_capacity(group.min(self.n - from) * d);
        let (mut data, mut scales) = (Vec::new(), Vec::new());
        let mut g0 = from;
        while g0 < self.n {
            let g1 = (g0 + group).min(self.n);
            buf.clear();
            for r in g0..g1 {
                let kr = &pages[r / PAGE_ROWS].k_raw[(r % PAGE_ROWS) * d..];
                for c in 0..d {
                    buf.push(kr[c] - self.kmean[c]);
                }
            }
            quant::quant_per_tensor_into(&buf, g1 - g0, d, &mut data, &mut scales);
            for (i, r) in (g0..g1).enumerate() {
                let pg = &mut pages[r / PAGE_ROWS];
                debug_assert_eq!(pg.k_i8.len(), (r % PAGE_ROWS) * d);
                pg.k_i8.extend_from_slice(&data[i * d..(i + 1) * d]);
                pg.k_scales.push(scales[i]);
            }
            g0 = g1;
        }
    }

    /// Rebuild the V representation for rows `from..n`. Int8 mode
    /// requantizes whole pages (per-channel scales are per page, so
    /// `from` sits on a page boundary); fp16 modes round only new rows.
    fn requant_v_from(&mut self, pages: &mut [KvPage], from: usize, pv: PvMode) {
        let d = self.d;
        match pv {
            PvMode::Int8 => {
                debug_assert_eq!(from % PAGE_ROWS, 0);
                let mut p0 = from / PAGE_ROWS;
                while p0 * PAGE_ROWS < self.n {
                    let rows = (self.n - p0 * PAGE_ROWS).min(PAGE_ROWS);
                    let KvPage { v_raw, v_i8, v_scales, .. } = &mut pages[p0];
                    quant::quant_per_channel_into(&v_raw[..rows * d], rows, d, v_i8, v_scales);
                    p0 += 1;
                }
            }
            _ => {
                let first_pg = from / PAGE_ROWS;
                for (pi, pg) in pages.iter_mut().enumerate().skip(first_pg) {
                    let local = if pi == first_pg { from % PAGE_ROWS } else { 0 };
                    let KvPage { v_raw, v_f16, .. } = pg;
                    v_f16.truncate(local * d);
                    v_f16.extend_from_slice(&v_raw[local * d..]);
                    round_f16_slice(&mut v_f16[local * d..]);
                }
            }
        }
    }

    /// Run attention for `n_q` query rows against the paged rows —
    /// bit-identical to [`sage_plane_prepared`] on the equivalent
    /// contiguous state. `pages` is the block table's resolution of this
    /// segment's physical pages, in order.
    pub fn run(
        &self,
        scratch: &mut Scratch,
        q: &[f32],
        n_q: usize,
        pages: &[&KvPage],
        opts: PlaneOpts,
    ) -> Vec<f32> {
        debug_assert!(pages.len() * PAGE_ROWS >= self.n);
        match self.imp {
            AttnImpl::Sage { qk, pv, .. } => {
                sage_plane_paged(scratch, q, pages, n_q, self.n, self.d, qk, pv, opts)
            }
            AttnImpl::Exact => {
                let (k, v) = gather_raw(pages, self.n, self.d);
                plane::exact_plane_opt(q, &k, &v, n_q, self.n, self.d, opts)
            }
            AttnImpl::OnlineFp32 => {
                let (k, v) = gather_raw(pages, self.n, self.d);
                plane::online_plane_opt(scratch, q, &k, &v, n_q, self.n, self.d, opts)
            }
            AttnImpl::Fp8 { .. } => unreachable!("fp8 rejected by PagedSegment::new"),
        }
    }
}

/// Concatenate the raw fp32 K/V rows of a paged plane (full-precision
/// fallback path, and the requant-every-step serving baseline). The
/// gather is software-pipelined: each page's copy starts the prefetch of
/// the next page's rows (physical pages are not adjacent, so the
/// hardware streamer cannot follow the block table on its own).
pub fn gather_raw(pages: &[&KvPage], n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::with_capacity(n * d);
    let mut v = Vec::with_capacity(n * d);
    let mut r = 0;
    for (pi, pg) in pages.iter().enumerate() {
        if r >= n {
            break;
        }
        if let Some(next) = pages.get(pi + 1) {
            isa::prefetch_head(&next.k_raw);
            isa::prefetch_head(&next.v_raw);
        }
        let take = (n - r).min(PAGE_ROWS) * d;
        k.extend_from_slice(&pg.k_raw[..take]);
        v.extend_from_slice(&pg.v_raw[..take]);
        r += PAGE_ROWS;
    }
    (k, v)
}

/// [`sage_plane_prepared`] over paged KV state: identical arithmetic,
/// with each BLOCK_KV tile resolved to its physical page (tiles and
/// pages coincide because [`PAGE_ROWS`] == [`BLOCK_KV`]), so the decode
/// hot path reads quantized rows through the block table without ever
/// materializing a contiguous plane.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sage_plane_paged(
    scratch: &mut Scratch,
    q: &[f32],
    pages: &[&KvPage],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_gran: Granularity,
    pv: PvMode,
    opts: PlaneOpts,
) -> Vec<f32> {
    assert!(
        qk_gran != Granularity::PerChannel && qk_gran != Granularity::PerTensor,
        "paged KV supports PerToken/PerBlock Q/K granularity"
    );
    scratch.ensure_head_dim(d);
    let Scratch { s, s_i32, p_i8, m, l, acc, p16, acc_i32, qbuf, q_i8, q_scales, timer, .. } =
        scratch;
    let kern = isa::kernels();
    timer.begin_plane();

    // pages carry quantized K / rounded V already — Q is the only
    // per-call quantization, as in the prepared-plane kernel above
    let scale = opts.scale(d);
    let t_quant = timer.section();
    qbuf.clear();
    qbuf.extend(q.iter().map(|&x| x * scale));
    quant::quantize_into(qbuf, n_q, d, qk_gran, q_i8, q_scales);
    timer.commit(Phase::Quant, t_quant);

    let mut out = vec![0.0f32; n_q * d];

    let mut i0 = 0;
    while i0 < n_q {
        let iq = (i0 + BLOCK_Q).min(n_q);
        let bq = iq - i0;
        let mb = &mut m[..bq];
        mb.fill(NEG_BIG);
        let lb = &mut l[..bq];
        lb.fill(0.0);
        let accb = &mut acc[..bq * d];
        accb.fill(0.0);
        let mut j0 = 0;
        while j0 < n_kv {
            let jk = (j0 + BLOCK_KV).min(n_kv);
            let bk = jk - j0;
            // page ↔ tile correspondence: PAGE_ROWS == BLOCK_KV
            let pg = pages[j0 / PAGE_ROWS];
            // decode at long context is a pointer-chasing gather: the
            // next physical page is not sequential with this one, so
            // touch its rows now — the S-tile and P·V walks below hide
            // the latency
            if let Some(next) = pages.get(j0 / PAGE_ROWS + 1) {
                isa::prefetch_head(&next.k_i8);
                isa::prefetch_head(&next.k_scales);
                match pv {
                    PvMode::Int8 => isa::prefetch_head(&next.v_i8),
                    _ => isa::prefetch_head(&next.v_f16),
                }
            }
            // ---- S tile from the page's INT8 K (ISA microkernel) ----
            let t_qk = timer.section();
            qk_score_tile(
                kern,
                opts,
                q_i8,
                q_scales,
                &pg.k_i8[..bk * d],
                &pg.k_scales[..bk],
                s,
                s_i32,
                i0,
                bq,
                j0,
                jk,
                n_q,
                n_kv,
                d,
            );
            timer.commit(Phase::QkTile, t_qk);
            // this tile's V rows (page-local; per-page V scales in Int8)
            let vtile = match pv {
                PvMode::Int8 => {
                    super::pv::PvTile::Int8 { v: &pg.v_i8[..bk * d], scales: &pg.v_scales[..d] }
                }
                PvMode::Fp16Accum => super::pv::PvTile::F16Accum { v: &pg.v_f16[..bk * d] },
                PvMode::Fp32Accum => super::pv::PvTile::F32Accum { v: &pg.v_f16[..bk * d] },
            };
            // ---- online softmax (fp32) + P·V ----
            for bi in 0..bq {
                let t_sm = timer.section();
                let row = &mut s[bi * BLOCK_KV..bi * BLOCK_KV + bk];
                let m_cur = row.iter().fold(NEG_BIG, |a, &b| a.max(b));
                let m_new = mb[bi].max(m_cur);
                if m_new == NEG_BIG {
                    timer.commit(Phase::Softmax, t_sm);
                    continue;
                }
                let alpha = (mb[bi] - m_new).exp();
                let mut row_sum = 0.0;
                for p in row.iter_mut() {
                    *p = (*p - m_new).exp();
                    row_sum += *p;
                }
                lb[bi] = alpha * lb[bi] + row_sum;
                mb[bi] = m_new;
                timer.commit(Phase::Softmax, t_sm);
                let o = &mut accb[bi * d..(bi + 1) * d];
                // shared P·V tile formulation (attn::pv)
                let t_pv = timer.section();
                super::pv::accumulate(kern, &vtile, o, alpha, row, p_i8, p16, acc_i32, d);
                timer.commit(Phase::Pv, t_pv);
            }
            j0 = jk;
        }
        for bi in 0..bq {
            let inv = 1.0 / lb[bi].max(1e-30);
            let o = &mut out[(i0 + bi) * d..(i0 + bi + 1) * d];
            for (oc, &ac) in o.iter_mut().zip(&accb[bi * d..(bi + 1) * d]) {
                *oc = ac * inv;
            }
        }
        i0 = iq;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::cos_sim;
    use crate::synth::{make_qkv, Profile};
    use crate::attn::plane::exact_plane;
    use crate::attn::{SAGE_B, SAGE_T, SAGE_VB, SAGE_VT};

    fn build(k: &[f32], v: &[f32], d: usize, imp: AttnImpl) -> PreparedPlane {
        let mut p = PreparedPlane::new(d);
        p.append(k, v, imp);
        p
    }

    #[test]
    fn oneshot_equals_rowwise_incremental() {
        let (n, d) = (200usize, 32usize);
        let (_, k, v) = make_qkv(31, [1, 1, n, d], Profile::diffusion_like());
        for imp in [SAGE_T, SAGE_B, SAGE_VT, SAGE_VB] {
            let oneshot = build(&k.data, &v.data, d, imp);
            // grow row by row through every anchor/group/block boundary
            let mut inc = PreparedPlane::new(d);
            for r in 0..n {
                inc.append(&k.data[r * d..(r + 1) * d], &v.data[r * d..(r + 1) * d], imp);
            }
            assert_eq!(oneshot, inc, "{}", imp.name());
            // and in irregular chunks
            let mut chunked = PreparedPlane::new(d);
            let mut r = 0;
            for step in [1usize, 7, 63, 64, 65, 100].iter().cycle() {
                if r >= n {
                    break;
                }
                let e = (r + step).min(n);
                chunked.append(&k.data[r * d..e * d], &v.data[r * d..e * d], imp);
                r = e;
            }
            assert_eq!(oneshot, chunked, "{} chunked", imp.name());
        }
    }

    #[test]
    fn prepared_kernel_tracks_exact() {
        let (n, d) = (256usize, 64usize);
        let (q, k, v) = make_qkv(32, [1, 1, n, d], Profile::diffusion_like());
        let gold = exact_plane(&q.data, &k.data, &v.data, n, n, d, false);
        let mut scratch = Scratch::new();
        for (imp, min_cos) in [(SAGE_T, 0.999), (SAGE_B, 0.999), (SAGE_VT, 0.99), (SAGE_VB, 0.99)]
        {
            let prep = build(&k.data, &v.data, d, imp);
            let AttnImpl::Sage { qk, pv, .. } = imp else { unreachable!() };
            let out = sage_plane_prepared(
                &mut scratch, &q.data, &prep, n, qk, pv, PlaneOpts::causal(false),
            );
            let c = cos_sim(&gold, &out);
            assert!(c > min_cos, "{}: cos {c}", imp.name());
        }
    }

    /// Build a paged plane by appending in the given chunk sizes.
    fn build_paged(
        k: &[f32],
        v: &[f32],
        d: usize,
        imp: AttnImpl,
        chunks: &[usize],
    ) -> (PagedSegment, Vec<KvPage>) {
        let n = k.len() / d;
        let mut seg = PagedSegment::new(d, imp).unwrap();
        let mut pages = vec![KvPage::new(); PagedSegment::pages_for(n)];
        let mut r = 0;
        for step in chunks.iter().cycle() {
            if r >= n {
                break;
            }
            let e = (r + step).min(n);
            seg.append(&mut pages, &k[r * d..e * d], &v[r * d..e * d]);
            r = e;
        }
        (seg, pages)
    }

    #[test]
    fn paged_state_matches_prepared_plane_bitwise() {
        let (n, d) = (300usize, 32usize);
        let (_, k, v) = make_qkv(35, [1, 1, n, d], Profile::diffusion_like());
        for imp in [SAGE_T, SAGE_B, SAGE_VT, SAGE_VB] {
            let oneshot = build(&k.data, &v.data, d, imp);
            for chunks in [&[n][..], &[1][..], &[7, 64, 1, 100][..]] {
                let (seg, pages) = build_paged(&k.data, &v.data, d, imp, chunks);
                assert_eq!(seg.n(), n);
                assert_eq!(seg.kmean, oneshot.kmean, "{} kmean", imp.name());
                assert_eq!(seg.anchor_rows, oneshot.anchor_rows);
                // concatenated page payloads == the contiguous plane
                let cat_i8: Vec<i8> =
                    pages.iter().flat_map(|p| p.k_i8.iter().copied()).collect();
                let cat_ks: Vec<f32> =
                    pages.iter().flat_map(|p| p.k_scales.iter().copied()).collect();
                assert_eq!(cat_i8, oneshot.k_i8, "{} k_i8", imp.name());
                assert_eq!(cat_ks, oneshot.k_scales, "{} k_scales", imp.name());
                let cat_vi8: Vec<i8> =
                    pages.iter().flat_map(|p| p.v_i8.iter().copied()).collect();
                let cat_vs: Vec<f32> =
                    pages.iter().flat_map(|p| p.v_scales.iter().copied()).collect();
                let cat_vf: Vec<f32> =
                    pages.iter().flat_map(|p| p.v_f16.iter().copied()).collect();
                assert_eq!(cat_vi8, oneshot.v_i8, "{} v_i8", imp.name());
                assert_eq!(cat_vs, oneshot.v_scales, "{} v_scales", imp.name());
                assert_eq!(cat_vf, oneshot.v_f16, "{} v_f16", imp.name());
            }
        }
    }

    #[test]
    fn paged_kernel_matches_prepared_bitwise() {
        let (n, d) = (200usize, 64usize);
        let (q, k, v) = make_qkv(36, [1, 1, n, d], Profile::diffusion_like());
        let mut scratch = Scratch::new();
        for imp in [SAGE_T, SAGE_B, SAGE_VT, SAGE_VB] {
            let prep = build(&k.data, &v.data, d, imp);
            let (seg, pages) = build_paged(&k.data, &v.data, d, imp, &[13, 64, 1]);
            let refs: Vec<&KvPage> = pages.iter().collect();
            let AttnImpl::Sage { qk, pv, .. } = imp else { unreachable!() };
            for (n_q, causal) in [(1usize, true), (n, true), (n, false)] {
                let opts = PlaneOpts::causal(causal);
                let a = sage_plane_prepared(
                    &mut scratch,
                    &q.data[..n_q * d],
                    &prep,
                    n_q,
                    qk,
                    pv,
                    opts,
                );
                let b = seg.run(&mut scratch, &q.data[..n_q * d], n_q, &refs, opts);
                assert_eq!(a, b, "{} n_q={n_q} causal={causal}", imp.name());
            }
        }
    }

    #[test]
    fn paged_fp32_fallback_matches_exact() {
        let (n, d) = (130usize, 16usize);
        let (q, k, v) = make_qkv(37, [1, 1, n, d], Profile::llama_like());
        let (seg, pages) = build_paged(&k.data, &v.data, d, AttnImpl::Exact, &[9]);
        let refs: Vec<&KvPage> = pages.iter().collect();
        let mut scratch = Scratch::new();
        let out = seg.run(&mut scratch, &q.data, n, &refs, PlaneOpts::causal(true));
        let gold = exact_plane(&q.data, &k.data, &v.data, n, n, d, true);
        assert_eq!(out, gold, "paged exact must equal contiguous exact");
    }

    #[test]
    fn paged_rejects_unpreparable_kernels() {
        use crate::quant::Fp8Format;
        assert!(PagedSegment::new(
            16,
            AttnImpl::Fp8 { qk: Fp8Format::E4M3, pv: Fp8Format::E4M3 }
        )
        .is_err());
        assert!(PagedSegment::new(
            16,
            AttnImpl::Sage {
                qk: Granularity::PerTensor,
                pv: PvMode::Fp16Accum,
                smooth_k: true,
            }
        )
        .is_err());
    }

    #[test]
    fn anchor_freezes_after_first_block() {
        let (n, d) = (300usize, 16usize);
        let (_, k, v) = make_qkv(33, [1, 1, n, d], Profile::diffusion_like());
        let mut p = build(&k.data[..BLOCK_KV * d], &v.data[..BLOCK_KV * d], d, SAGE_T);
        let frozen = p.kmean.clone();
        p.append(&k.data[BLOCK_KV * d..], &v.data[BLOCK_KV * d..], SAGE_T);
        assert_eq!(p.kmean, frozen, "anchor mean must not move after BLOCK_KV rows");
        assert_eq!(p.anchor_rows, BLOCK_KV);
        assert_eq!(p.n, n);
        assert_eq!(p.k_scales.len(), n);
        assert_eq!(p.k_i8.len(), n * d);
    }
}
