//! Numeric-format sweep attention (Tables 2, 3, 17, 18): run attention
//! with (Q,K) and (P̃,V) independently forced through a chosen storage
//! format, everything else in fp32. This isolates *format* error from
//! kernel/tiling error, matching the paper's methodology ("accuracy using
//! different data types across all layers").

use crate::quant::{FakeQuant, Granularity};
use crate::tensor::{default_threads, parallel_map, Tensor};

/// Storage format for a matrix pair in the sweep (the column/row labels
/// of Tables 2, 3, 17, 18).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fmt {
    /// INT8 with scale dequantization (§3.2) — the paper's choice for Q/K.
    Int8,
    /// OCP FP8 E4M3 (the FlashAttention3-quant format).
    E4M3,
    /// OCP FP8 E5M2 (wider range, less mantissa — worst in Table 17).
    E5M2,
    /// IEEE binary16 — the paper's choice for P̃/V (§4.3–§4.4).
    Fp16,
    /// Full precision (reference rows).
    Fp32,
}

impl Fmt {
    /// Table label for this format.
    pub fn name(self) -> &'static str {
        match self {
            Fmt::Int8 => "INT8",
            Fmt::E4M3 => "E4M3",
            Fmt::E5M2 => "E5M2",
            Fmt::Fp16 => "FP16",
            Fmt::Fp32 => "FP32",
        }
    }

    fn to_fake(self, granularity: Granularity) -> FakeQuant {
        match self {
            Fmt::Int8 => FakeQuant::Int8(granularity),
            Fmt::E4M3 => FakeQuant::Fp8(crate::quant::Fp8Format::E4M3),
            Fmt::E5M2 => FakeQuant::Fp8(crate::quant::Fp8Format::E5M2),
            Fmt::Fp16 => FakeQuant::Fp16,
            Fmt::Fp32 => FakeQuant::None,
        }
    }
}

/// Attention with (Q,K) in `qk_fmt` (at `qk_gran`, after optional
/// smooth-K) and (P̃,V) in `pv_fmt` (P̃ per-block static scale semantics,
/// V per-channel for INT8; per-token scaling for FP8 — mirroring §4.3's
/// feasible-granularity table). Softmax in fp32.
pub fn attention_dtype_sim(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    qk_fmt: Fmt,
    qk_gran: Granularity,
    pv_fmt: Fmt,
    smooth_k: bool,
    causal: bool,
) -> Tensor {
    let (b, h, n_q, d) = q.dims4();
    let (_, _, n_kv, _) = k.dims4();
    let planes = parallel_map(b * h, default_threads(), |idx| {
        let (bi, hi) = (idx / h, idx % h);
        plane_dtype_sim(
            q.head(bi, hi),
            k.head(bi, hi),
            v.head(bi, hi),
            n_q,
            n_kv,
            d,
            qk_fmt,
            qk_gran,
            pv_fmt,
            smooth_k,
            causal,
        )
    });
    let mut out = Tensor::zeros(&[b, h, n_q, d]);
    for (idx, plane) in planes.into_iter().enumerate() {
        out.head_mut(idx / h, idx % h).copy_from_slice(&plane);
    }
    out
}

/// Error of the Q·Kᵀ product alone under a format (Table 17).
pub fn qk_product_dtype_sim(
    q: &[f32],
    k: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    fmt: Fmt,
) -> Vec<f32> {
    let qf = crate::quant::fake_quant(q, n_q, d, fmt.to_fake(Granularity::PerToken));
    let kf = crate::quant::fake_quant(k, n_kv, d, fmt.to_fake(Granularity::PerToken));
    let mut s = vec![0.0f32; n_q * n_kv];
    for i in 0..n_q {
        for j in 0..n_kv {
            s[i * n_kv + j] = qf[i * d..(i + 1) * d]
                .iter()
                .zip(&kf[j * d..(j + 1) * d])
                .map(|(a, b)| a * b)
                .sum();
        }
    }
    s
}

#[allow(clippy::too_many_arguments)]
fn plane_dtype_sim(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_fmt: Fmt,
    qk_gran: Granularity,
    pv_fmt: Fmt,
    smooth_k: bool,
    causal: bool,
) -> Vec<f32> {
    use crate::quant;
    let scale = 1.0 / (d as f32).sqrt();
    let q_scaled: Vec<f32> = q.iter().map(|&x| x * scale).collect();
    let k_src = if smooth_k {
        quant::smooth_k(k, n_kv, d).0
    } else {
        k.to_vec()
    };
    let qf = quant::fake_quant(&q_scaled, n_q, d, qk_fmt.to_fake(qk_gran));
    let kf = quant::fake_quant(&k_src, n_kv, d, qk_fmt.to_fake(qk_gran));
    // V: per-channel for INT8 (§4.3 point 3), per-token scaling otherwise
    let v_kind = match pv_fmt {
        Fmt::Int8 => FakeQuant::Int8(Granularity::PerChannel),
        other => other.to_fake(Granularity::PerToken),
    };
    let vf = quant::fake_quant(v, n_kv, d, v_kind);

    let mut out = vec![0.0f32; n_q * d];
    let mut s = vec![0.0f32; n_kv];
    for i in 0..n_q {
        let limit = super::plane::causal_limit(i, n_q, n_kv, causal);
        let qi = &qf[i * d..(i + 1) * d];
        let mut m = -1e30f32;
        for (j, sj) in s.iter_mut().enumerate().take(limit) {
            *sj = qi
                .iter()
                .zip(&kf[j * d..(j + 1) * d])
                .map(|(a, b)| a * b)
                .sum();
            m = m.max(*sj);
        }
        // P̃ = exp(s - m) ∈ [0,1]; force through the P format
        let mut l = 0.0f32;
        for sj in s.iter_mut().take(limit) {
            let p = (*sj - m).exp();
            *sj = match pv_fmt {
                Fmt::Int8 => (p * 127.0).round() / 127.0, // static 1/127 scale
                Fmt::E4M3 => crate::quant::Fp8Format::E4M3.round(p),
                Fmt::E5M2 => crate::quant::Fp8Format::E5M2.round(p),
                Fmt::Fp16 => crate::util::f16::round_f16(p),
                Fmt::Fp32 => p,
            };
            l += *sj;
        }
        let o = &mut out[i * d..(i + 1) * d];
        for (j, &p) in s.iter().enumerate().take(limit) {
            if p == 0.0 {
                continue;
            }
            for (oc, &vc) in o.iter_mut().zip(&vf[j * d..(j + 1) * d]) {
                *oc += p * vc;
            }
        }
        let inv = 1.0 / l.max(1e-30);
        for oc in o.iter_mut() {
            *oc *= inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::AttnSpec;
    use crate::metrics::cos_sim;
    use crate::synth::{make_qkv, Profile};

    #[test]
    fn fp32_everything_matches_exact() {
        let (q, k, v) = make_qkv(1, [1, 2, 96, 32], Profile::diffusion_like());
        let a = attention_dtype_sim(
            &q, &k, &v, Fmt::Fp32, Granularity::PerToken, Fmt::Fp32, false, false);
        let b = AttnSpec::exact().run(&q, &k, &v).unwrap();
        assert!(cos_sim(&a.data, &b.data) > 0.99999);
    }

    #[test]
    fn table2_ordering_int8_qk_beats_fp8() {
        // Table 2: with (P,V) fixed, INT8 (Q,K) > E4M3 > E5M2
        let (q, k, v) = make_qkv(2, [1, 2, 192, 64], Profile::diffusion_like());
        let gold = AttnSpec::exact().run(&q, &k, &v).unwrap();
        let mut cs = Vec::new();
        for fmt in [Fmt::Int8, Fmt::E4M3, Fmt::E5M2] {
            let o = attention_dtype_sim(
                &q, &k, &v, fmt, Granularity::PerToken, Fmt::Fp16, true, false);
            cs.push(cos_sim(&gold.data, &o.data));
        }
        assert!(cs[0] >= cs[1] && cs[1] >= cs[2], "{cs:?}");
    }

    #[test]
    fn fp16_pv_beats_int8_pv() {
        // Table 3's punchline: FP16 (P,V) is far more robust than INT8
        let (q, k, v) = make_qkv(
            3,
            [1, 2, 192, 64],
            Profile::diffusion_like().with_severity(3.0),
        );
        let gold = AttnSpec::exact().run(&q, &k, &v).unwrap();
        let fp16 = attention_dtype_sim(
            &q, &k, &v, Fmt::Int8, Granularity::PerToken, Fmt::Fp16, true, false);
        let int8 = attention_dtype_sim(
            &q, &k, &v, Fmt::Int8, Granularity::PerToken, Fmt::Int8, true, false);
        assert!(cos_sim(&gold.data, &fp16.data) >= cos_sim(&gold.data, &int8.data));
    }
}
