//! Rust-native attention implementations: the fp32 references and the four
//! SageAttention variants (paper Table 6), numerically mirroring the Pallas
//! kernels in `python/compile/kernels/`. These power the accuracy tables,
//! the adaptive-quantization calibrator and the CPU-side benches without
//! paying PJRT dispatch overhead.
//!
//! The public surface is [`api::AttnSpec`] — a builder-style spec carrying
//! kernel selection (explicit, by registry name, or auto-dispatched),
//! layout, causal/sliding-window masking, softmax scale and the GQA head
//! mapping — plus [`api::PreparedKV`], quantize-once KV state for decode.
//! [`registry`] is the kernel dispatch table behind both; underneath it,
//! [`isa`] dispatches the INT8/f32 inner loops to runtime-detected SIMD
//! microkernels (`SAGE_ISA` overrides; all tiers bit-identical to
//! scalar), and [`pv`] holds the one P·V tile formulation every blocked
//! kernel (contiguous, prepared, paged) shares. The legacy
//! `attention(q, k, v, imp, causal)` free function survives as a
//! deprecated shim.
//!
//! Layout: internally tensors are (B, H, N, d); per-(batch, head) planes
//! are processed independently (parallelized with scoped threads).

pub mod api;
pub mod dtype_sim;
pub mod guard;
pub mod isa;
mod plane;
mod prepared;
pub mod pv;
pub mod registry;

pub use api::{AttnSpec, Layout, PreparedKV};
pub use guard::{check_finite, is_nonfinite_err, NONFINITE_MARKER};
pub use dtype_sim::{attention_dtype_sim, qk_product_dtype_sim, Fmt};
pub use prepared::{gather_raw, KvPage, PagedSegment, PAGE_ROWS};
pub use plane::{
    exact_plane, exact_plane_opt, fp8_plane, fp8_plane_opt, online_plane, online_plane_opt,
    online_plane_with, sage_plane, sage_plane_naive, sage_plane_opt, sage_plane_with, PlaneOpts,
    Scratch, MAX_HEAD_DIM,
};

use crate::quant::{Fp8Format, Granularity};
use crate::tensor::Tensor;

/// P·V computation mode (paper §4.3–§4.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PvMode {
    /// FP16 operands + FP16 accumulator — mma(f16.f16.f16.f16), the paper's
    /// accurate-and-fast choice (2× the FP32-accumulator rate on RTX4090).
    Fp16Accum,
    /// FP16 operands + FP32 accumulator — mma(f16.f16.f32.f32) baseline.
    Fp32Accum,
    /// INT8 P̃ (static δ=1/127 per block) × per-channel INT8 V.
    Int8,
}

/// One attention kernel configuration (a row of Table 6, or a baseline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttnImpl {
    /// Exact fp32 softmax(QKᵀ/√d)V — the accuracy gold standard.
    Exact,
    /// FlashAttention-2 tiling in fp32 (online softmax) — speed baseline's
    /// numerics.
    OnlineFp32,
    /// A SageAttention variant: INT8 Q/K at the given granularity plus a
    /// P·V mode. `smooth_k` toggles §4.2.
    Sage { qk: Granularity, pv: PvMode, smooth_k: bool },
    /// FlashAttention3-style FP8: all four matrices quantized per-token to
    /// the given formats ((Q,K) fmt, (P,V) fmt), fp32 accumulators.
    Fp8 { qk: Fp8Format, pv: Fp8Format },
}

pub const SAGE_T: AttnImpl = AttnImpl::Sage {
    qk: Granularity::PerToken,
    pv: PvMode::Fp16Accum,
    smooth_k: true,
};
pub const SAGE_B: AttnImpl = AttnImpl::Sage {
    qk: Granularity::PerBlock(BLOCK_Q),
    pv: PvMode::Fp16Accum,
    smooth_k: true,
};
pub const SAGE_VT: AttnImpl = AttnImpl::Sage {
    qk: Granularity::PerToken,
    pv: PvMode::Int8,
    smooth_k: true,
};
pub const SAGE_VB: AttnImpl = AttnImpl::Sage {
    qk: Granularity::PerBlock(BLOCK_Q),
    pv: PvMode::Int8,
    smooth_k: true,
};

/// Paper Table 12: Q-block 128, K/V-block 64.
pub const BLOCK_Q: usize = 128;
pub const BLOCK_KV: usize = 64;

impl AttnImpl {
    /// Parse an implementation from its display name — the true inverse
    /// of [`AttnImpl::name`]: every string `name()` can emit parses back
    /// to the same implementation, including the parameterized forms
    /// (`"fp8(E4M3,E5M2)"`, `"SageAttn-+fp32accB64-nosmooth"`, …).
    /// `"fa3-fp8"` is accepted as an alias for the FA3 baseline; registry
    /// rows also resolve through [`registry::resolve`].
    pub fn by_name(name: &str) -> Option<AttnImpl> {
        match name {
            "exact" => return Some(AttnImpl::Exact),
            "online" => return Some(AttnImpl::OnlineFp32),
            // historical alias for the FA3 baseline row label
            "fa3-fp8" => {
                return Some(AttnImpl::Fp8 { qk: Fp8Format::E4M3, pv: Fp8Format::E4M3 });
            }
            _ => {}
        }
        if let Some(inner) = name.strip_prefix("fp8(").and_then(|r| r.strip_suffix(')')) {
            let (a, b) = inner.split_once(',')?;
            return Some(AttnImpl::Fp8 {
                qk: Fp8Format::by_name(a.trim())?,
                pv: Fp8Format::by_name(b.trim())?,
            });
        }
        let rest = name.strip_prefix("SageAttn-")?;
        let (rest, smooth_k) = match rest.strip_suffix("-nosmooth") {
            Some(r) => (r, false),
            None => (rest, true),
        };
        let (g, pv) = if let Some(r) = rest.strip_prefix("+fp32acc") {
            (r, PvMode::Fp32Accum)
        } else if let Some(r) = rest.strip_prefix('v') {
            (r, PvMode::Int8)
        } else {
            (rest, PvMode::Fp16Accum)
        };
        let qk = match g {
            "T" => Granularity::PerToken,
            "tensor" => Granularity::PerTensor,
            "chan" => Granularity::PerChannel,
            "B" => Granularity::PerBlock(BLOCK_Q),
            _ => {
                let block: usize = g.strip_prefix('B')?.parse().ok()?;
                if block == 0 {
                    return None;
                }
                Granularity::PerBlock(block)
            }
        };
        Some(AttnImpl::Sage { qk, pv, smooth_k })
    }

    /// Display name matching the paper's tables (Table 6 row labels).
    /// Non-default block sizes are encoded (`"SageAttn-B64"`) so
    /// [`AttnImpl::by_name`] round-trips every implementation.
    pub fn name(&self) -> String {
        match self {
            AttnImpl::Exact => "exact".into(),
            AttnImpl::OnlineFp32 => "online".into(),
            AttnImpl::Fp8 { qk, pv } => format!("fp8({},{})", qk.name(), pv.name()),
            AttnImpl::Sage { qk, pv, smooth_k } => {
                let g = match qk {
                    Granularity::PerToken => "T".to_owned(),
                    Granularity::PerBlock(b) if *b == BLOCK_Q => "B".to_owned(),
                    Granularity::PerBlock(b) => format!("B{b}"),
                    Granularity::PerTensor => "tensor".to_owned(),
                    Granularity::PerChannel => "chan".to_owned(),
                };
                let p = match pv {
                    PvMode::Fp16Accum => "",
                    PvMode::Fp32Accum => "+fp32acc",
                    PvMode::Int8 => "v",
                };
                let s = if *smooth_k { "" } else { "-nosmooth" };
                format!("SageAttn-{p}{g}{s}")
            }
        }
    }
}

/// Multi-head attention over (B, H, N, d) tensors (paper Alg. 1 applied
/// per plane) — the legacy entry point, kept as a thin shim so old call
/// sites keep compiling. New code should use [`AttnSpec`], which adds
/// layout selection, GQA, sliding windows, softmax-scale overrides and
/// the [`PreparedKV`] decode path behind the same kernels.
#[deprecated(note = "use attn::AttnSpec (see the README migration note)")]
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor, imp: AttnImpl, causal: bool) -> Tensor {
    api::AttnSpec::new(imp)
        .causal(causal)
        .run(q, k, v)
        .expect("legacy attention() call with invalid inputs")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::cos_sim;
    use crate::synth::{make_qkv, Profile};

    fn gen(seed: u64, shape: [usize; 4], profile: Profile) -> (Tensor, Tensor, Tensor) {
        make_qkv(seed, shape, profile)
    }

    fn run(q: &Tensor, k: &Tensor, v: &Tensor, imp: AttnImpl, causal: bool) -> Tensor {
        AttnSpec::new(imp).causal(causal).run(q, k, v).unwrap()
    }

    #[test]
    fn online_matches_exact() {
        let (q, k, v) = gen(1, [1, 2, 300, 64], Profile::diffusion_like());
        let a = run(&q, &k, &v, AttnImpl::Exact, false);
        let b = run(&q, &k, &v, AttnImpl::OnlineFp32, false);
        let err = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-4, "max err {err}");
    }

    #[test]
    fn online_matches_exact_causal() {
        let (q, k, v) = gen(2, [2, 2, 200, 64], Profile::llama_like());
        let a = run(&q, &k, &v, AttnImpl::Exact, true);
        let b = run(&q, &k, &v, AttnImpl::OnlineFp32, true);
        let err = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-4, "max err {err}");
    }

    #[test]
    fn sage_variants_track_exact() {
        let (q, k, v) = gen(3, [1, 2, 256, 64], Profile::diffusion_like());
        let gold = run(&q, &k, &v, AttnImpl::Exact, false);
        for (imp, min_cos) in [
            (SAGE_T, 0.999),
            (SAGE_B, 0.999),
            (SAGE_VT, 0.99),
            (SAGE_VB, 0.99),
        ] {
            let o = run(&q, &k, &v, imp, false);
            let c = cos_sim(&gold.data, &o.data);
            assert!(c > min_cos, "{}: cos {c}", imp.name());
        }
    }

    #[test]
    fn smoothing_matters_under_outliers() {
        let (q, k, v) = gen(4, [1, 2, 256, 64], Profile::diffusion_like());
        let gold = run(&q, &k, &v, AttnImpl::Exact, false);
        let with = run(&q, &k, &v, SAGE_T, false);
        let without = run(
            &q,
            &k,
            &v,
            AttnImpl::Sage {
                qk: Granularity::PerToken,
                pv: PvMode::Fp16Accum,
                smooth_k: false,
            },
            false,
        );
        let cw = cos_sim(&gold.data, &with.data);
        let cwo = cos_sim(&gold.data, &without.data);
        assert!(cw > cwo, "smooth {cw} vs raw {cwo}");
        assert!(cw > 0.999);
    }

    #[test]
    fn causal_upper_triangle_ignored() {
        // output at query i must not depend on keys > i
        let (q, k, v) = gen(5, [1, 1, 64, 32], Profile::llama_like());
        let o1 = run(&q, &k, &v, SAGE_T, true);
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        // perturb the last key/value; first-row output must be unchanged
        let n = 64 * 32;
        for c in 0..32 {
            k2.data[n - 32 + c] += 100.0;
            v2.data[n - 32 + c] -= 50.0;
        }
        let o2 = run(&q, &k2, &v2, SAGE_T, true);
        // Per-token quantization of K changes only the last row's scale;
        // smooth-K's mean shift cancels in softmax. First query row should
        // be (nearly) identical.
        for c in 0..32 {
            assert!(
                (o1.data[c] - o2.data[c]).abs() < 2e-2,
                "leak at col {c}: {} vs {}",
                o1.data[c],
                o2.data[c]
            );
        }
    }

    #[test]
    fn name_by_name_round_trips_exhaustively() {
        // parsing must be the true inverse of naming for every
        // constructible implementation...
        let mut impls = vec![AttnImpl::Exact, AttnImpl::OnlineFp32];
        for qk in [
            Granularity::PerToken,
            Granularity::PerTensor,
            Granularity::PerChannel,
            Granularity::PerBlock(BLOCK_Q),
            Granularity::PerBlock(64),
        ] {
            for pv in [PvMode::Fp16Accum, PvMode::Fp32Accum, PvMode::Int8] {
                for smooth_k in [true, false] {
                    impls.push(AttnImpl::Sage { qk, pv, smooth_k });
                }
            }
        }
        for qk in [Fp8Format::E4M3, Fp8Format::E5M2] {
            for pv in [Fp8Format::E4M3, Fp8Format::E5M2] {
                impls.push(AttnImpl::Fp8 { qk, pv });
            }
        }
        for imp in impls {
            let name = imp.name();
            assert_eq!(AttnImpl::by_name(&name), Some(imp), "'{name}' failed to round-trip");
        }
        // ...and canonical names are fixed points of name ∘ by_name
        for name in [
            "exact",
            "online",
            "SageAttn-T",
            "SageAttn-B",
            "SageAttn-vT",
            "SageAttn-vB",
            "SageAttn-B64",
            "SageAttn-+fp32accT-nosmooth",
            "SageAttn-vtensor",
            "fp8(E4M3,E5M2)",
        ] {
            let imp = AttnImpl::by_name(name).expect(name);
            assert_eq!(imp.name(), name);
        }
        // the alias resolves but canonicalizes to the structured form
        assert_eq!(
            AttnImpl::by_name("fa3-fp8").unwrap().name(),
            "fp8(E4M3,E4M3)"
        );
        assert!(AttnImpl::by_name("no-such-kernel").is_none());
        assert!(AttnImpl::by_name("SageAttn-B0").is_none(), "zero block must not parse");
    }
}
