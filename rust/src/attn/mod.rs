//! Rust-native attention implementations: the fp32 references and the four
//! SageAttention variants (paper Table 6), numerically mirroring the Pallas
//! kernels in `python/compile/kernels/`. These power the accuracy tables,
//! the adaptive-quantization calibrator and the CPU-side benches without
//! paying PJRT dispatch overhead.
//!
//! Layout: tensors are (B, H, N, d); per-(batch, head) planes are processed
//! independently (parallelized with scoped threads).

pub mod dtype_sim;
mod plane;

pub use dtype_sim::{attention_dtype_sim, qk_product_dtype_sim, Fmt};
pub use plane::{
    exact_plane, online_plane, online_plane_with, sage_plane, sage_plane_naive,
    sage_plane_with, Scratch, MAX_HEAD_DIM,
};

use crate::quant::{Fp8Format, Granularity};
use crate::tensor::{default_threads, parallel_map_with, Tensor};

/// P·V computation mode (paper §4.3–§4.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PvMode {
    /// FP16 operands + FP16 accumulator — mma(f16.f16.f16.f16), the paper's
    /// accurate-and-fast choice (2× the FP32-accumulator rate on RTX4090).
    Fp16Accum,
    /// FP16 operands + FP32 accumulator — mma(f16.f16.f32.f32) baseline.
    Fp32Accum,
    /// INT8 P̃ (static δ=1/127 per block) × per-channel INT8 V.
    Int8,
}

/// One attention kernel configuration (a row of Table 6, or a baseline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttnImpl {
    /// Exact fp32 softmax(QKᵀ/√d)V — the accuracy gold standard.
    Exact,
    /// FlashAttention-2 tiling in fp32 (online softmax) — speed baseline's
    /// numerics.
    OnlineFp32,
    /// A SageAttention variant: INT8 Q/K at the given granularity plus a
    /// P·V mode. `smooth_k` toggles §4.2.
    Sage { qk: Granularity, pv: PvMode, smooth_k: bool },
    /// FlashAttention3-style FP8: all four matrices quantized per-token to
    /// the given formats ((Q,K) fmt, (P,V) fmt), fp32 accumulators.
    Fp8 { qk: Fp8Format, pv: Fp8Format },
}

pub const SAGE_T: AttnImpl = AttnImpl::Sage {
    qk: Granularity::PerToken,
    pv: PvMode::Fp16Accum,
    smooth_k: true,
};
pub const SAGE_B: AttnImpl = AttnImpl::Sage {
    qk: Granularity::PerBlock(BLOCK_Q),
    pv: PvMode::Fp16Accum,
    smooth_k: true,
};
pub const SAGE_VT: AttnImpl = AttnImpl::Sage {
    qk: Granularity::PerToken,
    pv: PvMode::Int8,
    smooth_k: true,
};
pub const SAGE_VB: AttnImpl = AttnImpl::Sage {
    qk: Granularity::PerBlock(BLOCK_Q),
    pv: PvMode::Int8,
    smooth_k: true,
};

/// Paper Table 12: Q-block 128, K/V-block 64.
pub const BLOCK_Q: usize = 128;
pub const BLOCK_KV: usize = 64;

impl AttnImpl {
    /// Look up an implementation by its table name (`"SageAttn-B"`, …);
    /// inverse of [`AttnImpl::name`] for the four paper variants and the
    /// two baselines.
    pub fn by_name(name: &str) -> Option<AttnImpl> {
        Some(match name {
            "exact" => AttnImpl::Exact,
            "online" => AttnImpl::OnlineFp32,
            "SageAttn-T" => SAGE_T,
            "SageAttn-B" => SAGE_B,
            "SageAttn-vT" => SAGE_VT,
            "SageAttn-vB" => SAGE_VB,
            "fa3-fp8" => AttnImpl::Fp8 { qk: Fp8Format::E4M3, pv: Fp8Format::E4M3 },
            _ => return None,
        })
    }

    /// Display name matching the paper's tables (Table 6 row labels).
    pub fn name(&self) -> String {
        match self {
            AttnImpl::Exact => "exact".into(),
            AttnImpl::OnlineFp32 => "online".into(),
            AttnImpl::Fp8 { qk, pv } => format!("fp8({},{})", qk.name(), pv.name()),
            AttnImpl::Sage { qk, pv, smooth_k } => {
                let g = match qk {
                    Granularity::PerToken => "T",
                    Granularity::PerBlock(_) => "B",
                    Granularity::PerTensor => "tensor",
                    Granularity::PerChannel => "chan",
                };
                let p = match pv {
                    PvMode::Fp16Accum => "",
                    PvMode::Fp32Accum => "+fp32acc",
                    PvMode::Int8 => "v",
                };
                let s = if *smooth_k { "" } else { "-nosmooth" };
                format!("SageAttn-{p}{g}{s}")
            }
        }
    }
}

/// Multi-head attention over (B, H, N, d) tensors (paper Alg. 1 applied
/// per plane). Planes are processed in parallel over (batch, head) via
/// scoped worker threads, each owning one preallocated [`Scratch`] reused
/// across all planes it handles — the online-softmax loop itself never
/// allocates (§Perf).
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor, imp: AttnImpl, causal: bool) -> Tensor {
    let (b, h, n_q, d) = q.dims4();
    let (_, _, n_kv, _) = k.dims4();
    assert_eq!(k.dims4().3, d);
    assert_eq!(v.dims4(), k.dims4());

    let planes = parallel_map_with(b * h, default_threads(), Scratch::new, |scratch, idx| {
        let (bi, hi) = (idx / h, idx % h);
        run_plane(
            scratch,
            q.head(bi, hi),
            k.head(bi, hi),
            v.head(bi, hi),
            n_q,
            n_kv,
            d,
            imp,
            causal,
        )
    });
    let mut out = Tensor::zeros(&[b, h, n_q, d]);
    for (idx, plane) in planes.into_iter().enumerate() {
        let (bi, hi) = (idx / h, idx % h);
        out.head_mut(bi, hi).copy_from_slice(&plane);
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn run_plane(
    scratch: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    imp: AttnImpl,
    causal: bool,
) -> Vec<f32> {
    match imp {
        AttnImpl::Exact => exact_plane(q, k, v, n_q, n_kv, d, causal),
        AttnImpl::OnlineFp32 => online_plane_with(scratch, q, k, v, n_q, n_kv, d, causal),
        AttnImpl::Sage { qk, pv, smooth_k } => {
            sage_plane_with(scratch, q, k, v, n_q, n_kv, d, qk, pv, smooth_k, causal)
        }
        AttnImpl::Fp8 { qk, pv } => plane::fp8_plane(q, k, v, n_q, n_kv, d, qk, pv, causal),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::cos_sim;
    use crate::synth::{make_qkv, Profile};

    fn gen(seed: u64, shape: [usize; 4], profile: Profile) -> (Tensor, Tensor, Tensor) {
        make_qkv(seed, shape, profile)
    }

    #[test]
    fn online_matches_exact() {
        let (q, k, v) = gen(1, [1, 2, 300, 64], Profile::diffusion_like());
        let a = attention(&q, &k, &v, AttnImpl::Exact, false);
        let b = attention(&q, &k, &v, AttnImpl::OnlineFp32, false);
        let err = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-4, "max err {err}");
    }

    #[test]
    fn online_matches_exact_causal() {
        let (q, k, v) = gen(2, [2, 2, 200, 64], Profile::llama_like());
        let a = attention(&q, &k, &v, AttnImpl::Exact, true);
        let b = attention(&q, &k, &v, AttnImpl::OnlineFp32, true);
        let err = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-4, "max err {err}");
    }

    #[test]
    fn sage_variants_track_exact() {
        let (q, k, v) = gen(3, [1, 2, 256, 64], Profile::diffusion_like());
        let gold = attention(&q, &k, &v, AttnImpl::Exact, false);
        for (imp, min_cos) in [
            (SAGE_T, 0.999),
            (SAGE_B, 0.999),
            (SAGE_VT, 0.99),
            (SAGE_VB, 0.99),
        ] {
            let o = attention(&q, &k, &v, imp, false);
            let c = cos_sim(&gold.data, &o.data);
            assert!(c > min_cos, "{}: cos {c}", imp.name());
        }
    }

    #[test]
    fn smoothing_matters_under_outliers() {
        let (q, k, v) = gen(4, [1, 2, 256, 64], Profile::diffusion_like());
        let gold = attention(&q, &k, &v, AttnImpl::Exact, false);
        let with = attention(&q, &k, &v, SAGE_T, false);
        let without = attention(
            &q,
            &k,
            &v,
            AttnImpl::Sage {
                qk: Granularity::PerToken,
                pv: PvMode::Fp16Accum,
                smooth_k: false,
            },
            false,
        );
        let cw = cos_sim(&gold.data, &with.data);
        let cwo = cos_sim(&gold.data, &without.data);
        assert!(cw > cwo, "smooth {cw} vs raw {cwo}");
        assert!(cw > 0.999);
    }

    #[test]
    fn causal_upper_triangle_ignored() {
        // output at query i must not depend on keys > i
        let (q, k, v) = gen(5, [1, 1, 64, 32], Profile::llama_like());
        let o1 = attention(&q, &k, &v, SAGE_T, true);
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        // perturb the last key/value; first-row output must be unchanged
        let n = 64 * 32;
        for c in 0..32 {
            k2.data[n - 32 + c] += 100.0;
            v2.data[n - 32 + c] -= 50.0;
        }
        let o2 = attention(&q, &k2, &v2, SAGE_T, true);
        // Per-token quantization of K changes only the last row's scale;
        // smooth-K's mean shift cancels in softmax. First query row should
        // be (nearly) identical.
        for c in 0..32 {
            assert!(
                (o1.data[c] - o2.data[c]).abs() < 2e-2,
                "leak at col {c}: {} vs {}",
                o1.data[c],
                o2.data[c]
            );
        }
    }
}
