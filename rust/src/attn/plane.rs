//! Per-(batch, head) attention kernels over contiguous (N, d) planes.
//! These mirror the Pallas kernels' numerics exactly: FlashAttention-2
//! tiling (Q-block 128, KV-block 64), INT8 S-tile with row/col scale
//! dequantization, fp32 online softmax, and either the simulated-FP16
//! accumulator or the INT8 P·V path.

use crate::quant::{self, Fp8Format, Granularity};
use crate::util::f16::{round_f16, round_f16_slice};

use super::{PvMode, BLOCK_KV, BLOCK_Q};

const NEG_BIG: f32 = -1e30;

/// Exact fp32 attention — softmax(QKᵀ/√d)V with a numerically stable
/// row-wise softmax.
pub fn exact_plane(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    causal: bool,
) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n_q * d];
    let mut s = vec![0.0f32; n_kv];
    for i in 0..n_q {
        let qi = &q[i * d..(i + 1) * d];
        let limit = causal_limit(i, n_q, n_kv, causal);
        let mut m = NEG_BIG;
        for (j, sj) in s.iter_mut().enumerate().take(limit) {
            let kj = &k[j * d..(j + 1) * d];
            let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
            *sj = dot * scale;
            m = m.max(*sj);
        }
        let mut l = 0.0f32;
        for sj in s.iter_mut().take(limit) {
            *sj = (*sj - m).exp();
            l += *sj;
        }
        let o = &mut out[i * d..(i + 1) * d];
        for (j, &p) in s.iter().enumerate().take(limit) {
            let vj = &v[j * d..(j + 1) * d];
            for (oc, &vc) in o.iter_mut().zip(vj) {
                *oc += p * vc;
            }
        }
        let inv = 1.0 / l.max(1e-30);
        for oc in o.iter_mut() {
            *oc *= inv;
        }
    }
    out
}

/// Highest attendable key index + 1 for query `i` (queries aligned to the
/// end of the KV sequence, the decode convention).
#[inline]
fn causal_limit(i: usize, n_q: usize, n_kv: usize, causal: bool) -> usize {
    if causal {
        (i + n_kv - n_q + 1).min(n_kv)
    } else {
        n_kv
    }
}

/// FlashAttention-2 fp32 tiling (Eq. 1–2) — validates the online-softmax
/// recurrence and serves as the full-precision speed baseline's numerics.
pub fn online_plane(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    causal: bool,
) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n_q * d];
    let mut s = vec![0.0f32; BLOCK_Q * BLOCK_KV];

    let mut i0 = 0;
    while i0 < n_q {
        let iq = (i0 + BLOCK_Q).min(n_q);
        let bq = iq - i0;
        let mut m = vec![NEG_BIG; bq];
        let mut l = vec![0.0f32; bq];
        let mut acc = vec![0.0f32; bq * d];
        let mut j0 = 0;
        while j0 < n_kv {
            let jk = (j0 + BLOCK_KV).min(n_kv);
            let bk = jk - j0;
            // S tile
            for bi in 0..bq {
                let limit = causal_limit(i0 + bi, n_q, n_kv, causal);
                let qi = &q[(i0 + bi) * d..(i0 + bi + 1) * d];
                for bj in 0..bk {
                    let s_val = if j0 + bj < limit {
                        let kj = &k[(j0 + bj) * d..(j0 + bj + 1) * d];
                        qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale
                    } else {
                        NEG_BIG
                    };
                    s[bi * BLOCK_KV + bj] = s_val;
                }
            }
            // online softmax update
            for bi in 0..bq {
                let row = &mut s[bi * BLOCK_KV..bi * BLOCK_KV + bk];
                let m_cur = row.iter().fold(NEG_BIG, |a, &b| a.max(b));
                let m_new = m[bi].max(m_cur);
                let alpha = (m[bi] - m_new).exp();
                let mut row_sum = 0.0;
                for p in row.iter_mut() {
                    *p = (*p - m_new).exp();
                    row_sum += *p;
                }
                l[bi] = alpha * l[bi] + row_sum;
                m[bi] = m_new;
                let o = &mut acc[bi * d..(bi + 1) * d];
                for oc in o.iter_mut() {
                    *oc *= alpha;
                }
                for (bj, &p) in row.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    let vj = &v[(j0 + bj) * d..(j0 + bj + 1) * d];
                    for (oc, &vc) in o.iter_mut().zip(vj) {
                        *oc += p * vc;
                    }
                }
            }
            j0 = jk;
        }
        for bi in 0..bq {
            let inv = 1.0 / l[bi].max(1e-30);
            let o = &mut out[(i0 + bi) * d..(i0 + bi + 1) * d];
            for (oc, &ac) in o.iter_mut().zip(&acc[bi * d..(bi + 1) * d]) {
                *oc = ac * inv;
            }
        }
        i0 = iq;
    }
    out
}

/// SageAttention plane (Alg. 1): INT8 QKᵀ + fp32 online softmax + the
/// selected P·V mode. Mirrors `python/compile/kernels/sage_attn.py`.
#[allow(clippy::too_many_arguments)]
pub fn sage_plane(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_gran: Granularity,
    pv: PvMode,
    smooth: bool,
    causal: bool,
) -> Vec<f32> {
    assert!(d <= 256, "head_dim > 256 unsupported by the native sage kernel");
    // ---- quantize Q (with folded 1/√d) and K (after smooth-K) ----
    let scale = 1.0 / (d as f32).sqrt();
    let q_scaled: Vec<f32> = q.iter().map(|&x| x * scale).collect();
    let k_sm;
    let k_src: &[f32] = if smooth {
        let (sm, _) = quant::smooth_k(k, n_kv, d);
        k_sm = sm;
        &k_sm
    } else {
        k
    };
    let qq = quant::quantize(&q_scaled, n_q, d, qk_gran);
    let kq = quant::quantize(k_src, n_kv, d, qk_gran);

    // ---- quantize / round V per P·V mode ----
    let (v_i8, v_chan_scale, v_f16): (Vec<i8>, Vec<f32>, Vec<f32>) = match pv {
        PvMode::Int8 => {
            let vq = quant::quant_per_channel(v, n_kv, d);
            (vq.data, vq.scales, Vec::new())
        }
        _ => (
            Vec::new(),
            Vec::new(),
            v.iter().map(|&x| round_f16(x)).collect(),
        ),
    };

    let mut out = vec![0.0f32; n_q * d];
    let mut s = vec![0.0f32; BLOCK_Q * BLOCK_KV];
    let mut p_i8 = vec![0i8; BLOCK_Q * BLOCK_KV];

    let mut i0 = 0;
    while i0 < n_q {
        let iq = (i0 + BLOCK_Q).min(n_q);
        let bq = iq - i0;
        let mut m = vec![NEG_BIG; bq];
        let mut l = vec![0.0f32; bq];
        let mut acc = vec![0.0f32; bq * d]; // held as fp16 values when Fp16Accum
        let mut j0 = 0;
        while j0 < n_kv {
            let jk = (j0 + BLOCK_KV).min(n_kv);
            let bk = jk - j0;
            // ---- S tile: mma(u8.u8.s32) + dequant ----
            for bi in 0..bq {
                let limit = causal_limit(i0 + bi, n_q, n_kv, causal);
                let qi = &qq.data[(i0 + bi) * d..(i0 + bi + 1) * d];
                let qs = qq.scales[i0 + bi];
                for bj in 0..bk {
                    let s_val = if j0 + bj < limit {
                        let kj = &kq.data[(j0 + bj) * d..(j0 + bj + 1) * d];
                        let dot = dot_i8(qi, kj);
                        dot as f32 * qs * kq.scales[j0 + bj]
                    } else {
                        NEG_BIG
                    };
                    s[bi * BLOCK_KV + bj] = s_val;
                }
            }
            // ---- online softmax (fp32) + P·V ----
            for bi in 0..bq {
                let row = &mut s[bi * BLOCK_KV..bi * BLOCK_KV + bk];
                let m_cur = row.iter().fold(NEG_BIG, |a, &b| a.max(b));
                let m_new = m[bi].max(m_cur);
                let alpha = (m[bi] - m_new).exp();
                let mut row_sum = 0.0;
                for p in row.iter_mut() {
                    *p = (*p - m_new).exp();
                    row_sum += *p;
                }
                l[bi] = alpha * l[bi] + row_sum;
                m[bi] = m_new;
                let o = &mut acc[bi * d..(bi + 1) * d];
                match pv {
                    PvMode::Int8 => {
                        // P̃ ∈ [0,1]: static per-block scale 1/127 (§4.3)
                        let prow = &mut p_i8[..bk];
                        for (pq, &p) in prow.iter_mut().zip(row.iter()) {
                            *pq = (p * quant::INT8_MAX).round() as i8;
                        }
                        for oc in o.iter_mut() {
                            *oc *= alpha;
                        }
                        // int32 accumulate over the block (row-major V
                        // walk — contiguous loads vectorize), dequant once
                        let mut acc_i32 = [0i32; 256];
                        let acc_i32 = &mut acc_i32[..d];
                        for (bj, &pq) in prow.iter().enumerate() {
                            if pq == 0 {
                                continue;
                            }
                            let p32 = pq as i32;
                            let vrow = &v_i8[(j0 + bj) * d..(j0 + bj + 1) * d];
                            for (a, &vc) in acc_i32.iter_mut().zip(vrow) {
                                *a += p32 * vc as i32;
                            }
                        }
                        for (oc, (&a, &vs)) in
                            o.iter_mut().zip(acc_i32.iter().zip(&v_chan_scale[..d]))
                        {
                            *oc += a as f32 * (1.0 / quant::INT8_MAX) * vs;
                        }
                    }
                    PvMode::Fp16Accum => {
                        // rescale in registers, store rounded to fp16
                        for oc in o.iter_mut() {
                            *oc *= alpha;
                        }
                        round_f16_slice(o);
                        // fp16 operands (P̃ rounded once per row, not per
                        // output channel); accumulator rounded every
                        // MMA_K=16 contraction steps (matches fp16_sim.py).
                        // All roundings go through the F16C-vectorized
                        // slice helper.
                        let mut p16 = [0.0f32; BLOCK_KV];
                        p16[..bk].copy_from_slice(&row[..bk]);
                        round_f16_slice(&mut p16[..bk]);
                        let mut part = [0.0f32; 256];
                        let part = &mut part[..d];
                        let mut bj = 0;
                        while bj < bk {
                            let je = (bj + 16).min(bk);
                            part.fill(0.0);
                            for t in bj..je {
                                let p = p16[t];
                                if p == 0.0 {
                                    continue;
                                }
                                let vrow = &v_f16[(j0 + t) * d..(j0 + t + 1) * d];
                                for (pc, &vc) in part.iter_mut().zip(vrow) {
                                    *pc += p * vc;
                                }
                            }
                            round_f16_slice(part);
                            for (oc, &pc) in o.iter_mut().zip(part.iter()) {
                                *oc += pc;
                            }
                            round_f16_slice(o);
                            bj = je;
                        }
                    }
                    PvMode::Fp32Accum => {
                        for oc in o.iter_mut() {
                            *oc *= alpha;
                        }
                        let mut p16 = [0.0f32; BLOCK_KV];
                        p16[..bk].copy_from_slice(&row[..bk]);
                        round_f16_slice(&mut p16[..bk]);
                        for (bj, &p) in p16[..bk].iter().enumerate() {
                            if p == 0.0 {
                                continue;
                            }
                            let vrow = &v_f16[(j0 + bj) * d..(j0 + bj + 1) * d];
                            for (oc, &vc) in o.iter_mut().zip(vrow) {
                                *oc += p * vc;
                            }
                        }
                    }
                }
            }
            j0 = jk;
        }
        for bi in 0..bq {
            let inv = 1.0 / l[bi].max(1e-30);
            let o = &mut out[(i0 + bi) * d..(i0 + bi + 1) * d];
            for (oc, &ac) in o.iter_mut().zip(&acc[bi * d..(bi + 1) * d]) {
                *oc = ac * inv;
            }
        }
        i0 = iq;
    }
    out
}

/// FlashAttention3-FP8-style plane: Q,K and P,V all FP8 per-token scaled,
/// no smoothing, fp32 accumulation (the Hopper FP8 path's numerics).
#[allow(clippy::too_many_arguments)]
pub fn fp8_plane(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_fmt: Fp8Format,
    pv_fmt: Fp8Format,
    causal: bool,
) -> Vec<f32> {
    use crate::quant::FakeQuant;
    let qf = quant::fake_quant(q, n_q, d, FakeQuant::Fp8(qk_fmt));
    let kf = quant::fake_quant(k, n_kv, d, FakeQuant::Fp8(qk_fmt));
    // V quantized per-token to FP8; P̃ rounded to FP8 inside the loop.
    let vf = quant::fake_quant(v, n_kv, d, FakeQuant::Fp8(pv_fmt));
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n_q * d];
    let mut s = vec![0.0f32; n_kv];
    for i in 0..n_q {
        let qi = &qf[i * d..(i + 1) * d];
        let limit = causal_limit(i, n_q, n_kv, causal);
        let mut m = NEG_BIG;
        for (j, sj) in s.iter_mut().enumerate().take(limit) {
            let kj = &kf[j * d..(j + 1) * d];
            let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
            *sj = dot * scale;
            m = m.max(*sj);
        }
        let mut l = 0.0f32;
        for sj in s.iter_mut().take(limit) {
            *sj = pv_fmt.round((*sj - m).exp());
            l += *sj;
        }
        let o = &mut out[i * d..(i + 1) * d];
        for (j, &p) in s.iter().enumerate().take(limit) {
            if p == 0.0 {
                continue;
            }
            let vj = &vf[j * d..(j + 1) * d];
            for (oc, &vc) in o.iter_mut().zip(vj) {
                *oc += p * vc;
            }
        }
        let inv = 1.0 / l.max(1e-30);
        for oc in o.iter_mut() {
            *oc *= inv;
        }
    }
    out
}

/// INT8 dot product with i32 accumulation — the mma(u8.u8.s32) primitive.
/// Eight independent accumulator lanes let LLVM vectorize the i8→i32
/// widening MACs (pmaddwd-shaped codegen on x86).
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0i32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for i in 0..8 {
            lanes[i] += xa[i] as i32 * xb[i] as i32;
        }
    }
    let mut acc: i32 = lanes.iter().sum();
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        acc += *x as i32 * *y as i32;
    }
    acc
}
