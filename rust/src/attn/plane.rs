//! Per-(batch, head) attention kernels over contiguous (N, d) planes.
//! These mirror the Pallas kernels' numerics exactly: FlashAttention-2
//! tiling (Q-block 128, KV-block 64), INT8 S-tile with row/col scale
//! dequantization, fp32 online softmax, and either the simulated-FP16
//! accumulator or the INT8 P·V path.
//!
//! §Perf layout: the blocked kernels take their tile/softmax buffers from
//! a caller-owned [`Scratch`] so the online-softmax loop performs **zero**
//! heap allocation — [`crate::attn::api::AttnSpec`] allocates one
//! `Scratch` per worker thread and reuses it across every (batch, head)
//! plane; the per-plane INT8 planes and scale vectors also live here
//! (filled via [`crate::quant::quantize_into`]). The arithmetic itself —
//! whole QKᵀ score tiles, and the per-tile P·V accumulation via the
//! shared [`crate::attn::pv`] formulation (fused fp16 contraction steps,
//! INT8 accumulate, f32 axpy/rescale) — dispatches through the
//! [`crate::attn::isa`] microkernel tables (AVX2 / AVX-512 VNNI / NEON
//! dotprod / scalar, selected at runtime, bit-identical across tiers).
//! [`sage_plane_naive`] is a deliberately *unblocked* row-at-a-time
//! reference (the textbook formulation, which the seed's kernels never
//! shipped) kept as the measurable "before" for `sage bench-hotpath` and
//! as a numerics cross-check oracle.
//!
//! Every kernel comes in two forms: the legacy positional signature
//! (`*_plane`/`*_plane_with`, unchanged and bit-identical to the seed)
//! and an `*_opt` form taking [`PlaneOpts`], which adds the sliding
//! window and softmax-scale knobs the [`crate::attn::api`] surface
//! exposes.

use crate::obs::phase::{Phase, PhaseTimer};
use crate::quant::{self, Fp8Format, Granularity};
use crate::util::f16::{round_f16, round_f16_slice};

use super::isa;
use super::{PvMode, BLOCK_KV, BLOCK_Q};

const NEG_BIG: f32 = -1e30;

/// Head dimension the scratch tiles preallocate for (covers every shape
/// in the paper; d ≤ 128 in all benchmarked models). Larger head dims
/// still work — [`Scratch`] grows its d-sized buffers on first use.
pub const MAX_HEAD_DIM: usize = 256;

/// Masking and scaling options threaded through every plane kernel.
///
/// The legacy `causal: bool` signatures wrap this with
/// [`PlaneOpts::causal`]; [`crate::attn::api::AttnSpec`] builds the full
/// form. With `window`/`sm_scale` unset the `*_opt` kernels are
/// bit-identical to their legacy counterparts.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PlaneOpts {
    /// Decode-aligned causal masking (queries aligned to the end of the
    /// KV sequence).
    pub causal: bool,
    /// Sliding-window width (causal only): query `i` attends the last
    /// `w` keys at or before its causal limit (Mistral-style SWA).
    pub window: Option<usize>,
    /// Softmax scale override; `None` = 1/√d.
    pub sm_scale: Option<f32>,
}

impl PlaneOpts {
    /// Plain causal/non-causal masking — the legacy kernels' semantics.
    pub fn causal(causal: bool) -> PlaneOpts {
        PlaneOpts { causal, window: None, sm_scale: None }
    }

    pub(crate) fn scale(&self, d: usize) -> f32 {
        self.sm_scale.unwrap_or_else(|| 1.0 / (d as f32).sqrt())
    }

    /// Attendable key range `[lo, hi)` for query `i`.
    pub(crate) fn range(&self, i: usize, n_q: usize, n_kv: usize) -> (usize, usize) {
        let hi = causal_limit(i, n_q, n_kv, self.causal);
        let lo = match self.window {
            Some(w) if self.causal => hi.saturating_sub(w),
            _ => 0,
        };
        (lo, hi)
    }
}

/// Preallocated per-thread working memory for the blocked kernels.
///
/// One `Scratch` holds every buffer the BLOCK_Q × BLOCK_KV online-softmax
/// loop touches (S tile, running max/normalizer, output accumulator, P̃
/// staging, INT8/FP16 partials) plus whole-plane staging vectors whose
/// capacity is retained across planes — including the INT8 data and
/// scale vectors the quantizers fill via [`crate::quant::quantize_into`],
/// so the per-plane `QuantizedPlane` allocations of the seed are gone.
/// Construct once per thread (see [`crate::tensor::parallel_map_with`])
/// and feed to the `*_with`/`*_opt` kernels.
pub struct Scratch {
    /// S tile: BLOCK_Q × BLOCK_KV dequantized scores.
    pub(super) s: Vec<f32>,
    /// Raw i32 QKᵀ tile (the [`crate::attn::isa`] microkernel output,
    /// dequantized into `s`).
    pub(super) s_i32: Vec<i32>,
    /// INT8-quantized P̃ row (Int8 P·V mode).
    pub(super) p_i8: Vec<i8>,
    /// Per-Q-row online-softmax running max.
    pub(super) m: Vec<f32>,
    /// Per-Q-row online-softmax normalizer.
    pub(super) l: Vec<f32>,
    /// Output accumulator for one Q block (BLOCK_Q × MAX_HEAD_DIM).
    pub(super) acc: Vec<f32>,
    /// fp16-rounded P̃ row.
    pub(super) p16: Vec<f32>,
    /// int32 accumulator lanes (INT8 P·V).
    pub(super) acc_i32: Vec<i32>,
    /// Whole-plane staging: Q with folded softmax scale.
    pub(super) qbuf: Vec<f32>,
    /// Whole-plane staging: smoothed K.
    pub(super) kbuf: Vec<f32>,
    /// Per-channel K mean removed by smooth-K (§4.2).
    pub(super) kmean: Vec<f32>,
    /// Whole-plane staging: fp16-rounded V.
    pub(super) vbuf: Vec<f32>,
    /// INT8 Q plane + its scales (ψ output, `quantize_into` target).
    pub(super) q_i8: Vec<i8>,
    pub(super) q_scales: Vec<f32>,
    /// INT8 K plane + its scales.
    pub(super) k_i8: Vec<i8>,
    pub(super) k_scales: Vec<f32>,
    /// INT8 V plane + per-channel scales (Int8 P·V mode).
    pub(super) v_i8: Vec<i8>,
    pub(super) v_scales: Vec<f32>,
    /// Sampled kernel phase profiler ([`crate::obs::PhaseTimer`]).
    /// Disabled (a dead branch per phase) unless armed via
    /// [`Scratch::set_phase_timer`].
    pub(super) timer: PhaseTimer,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch {
            s: vec![0.0; BLOCK_Q * BLOCK_KV],
            s_i32: vec![0; BLOCK_Q * BLOCK_KV],
            p_i8: vec![0; BLOCK_KV],
            m: vec![0.0; BLOCK_Q],
            l: vec![0.0; BLOCK_Q],
            acc: vec![0.0; BLOCK_Q * MAX_HEAD_DIM],
            p16: vec![0.0; BLOCK_KV],
            acc_i32: vec![0; MAX_HEAD_DIM],
            qbuf: Vec::new(),
            kbuf: Vec::new(),
            kmean: Vec::new(),
            vbuf: Vec::new(),
            q_i8: Vec::new(),
            q_scales: Vec::new(),
            k_i8: Vec::new(),
            k_scales: Vec::new(),
            v_i8: Vec::new(),
            v_scales: Vec::new(),
            timer: PhaseTimer::disabled(),
        }
    }

    /// Arm (or disarm) the sampled kernel phase profiler. On sampled
    /// plane calls the blocked sage kernels time their quantization,
    /// QKᵀ-tile, online-softmax, P·V and fp16-round phases into it —
    /// the measured mirror of the paper's Figure 2 latency breakdown.
    pub fn set_phase_timer(&mut self, timer: PhaseTimer) {
        self.timer = timer;
    }

    /// Whether a phase profiler is armed.
    pub fn phase_timer_enabled(&self) -> bool {
        self.timer.is_enabled()
    }

    /// Drain accumulated phase nanoseconds and the sampled-plane count,
    /// keeping the sampling cadence armed (feed to
    /// [`crate::obs::Obs::add_phase`]).
    pub fn take_phase_ns(&mut self) -> ([u64; crate::obs::PHASE_COUNT], u64) {
        self.timer.take()
    }

    /// Grow the d-sized buffers for planes wider than [`MAX_HEAD_DIM`]
    /// (amortized: a no-op once grown).
    pub(super) fn ensure_head_dim(&mut self, d: usize) {
        if self.acc.len() < BLOCK_Q * d {
            self.acc.resize(BLOCK_Q * d, 0.0);
        }
        if self.acc_i32.len() < d {
            self.acc_i32.resize(d, 0);
        }
    }
}

impl Default for Scratch {
    fn default() -> Scratch {
        Scratch::new()
    }
}

/// Exact fp32 attention — softmax(QKᵀ/√d)V with a numerically stable
/// row-wise softmax. The accuracy gold standard for every table.
pub fn exact_plane(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    causal: bool,
) -> Vec<f32> {
    exact_plane_opt(q, k, v, n_q, n_kv, d, PlaneOpts::causal(causal))
}

/// [`exact_plane`] with the full masking/scaling options.
pub fn exact_plane_opt(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    opts: PlaneOpts,
) -> Vec<f32> {
    let scale = opts.scale(d);
    let mut out = vec![0.0f32; n_q * d];
    let mut s = vec![0.0f32; n_kv];
    for i in 0..n_q {
        let qi = &q[i * d..(i + 1) * d];
        let (lo, hi) = opts.range(i, n_q, n_kv);
        let mut m = NEG_BIG;
        for (j, sj) in s.iter_mut().enumerate().take(hi).skip(lo) {
            let kj = &k[j * d..(j + 1) * d];
            let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
            *sj = dot * scale;
            m = m.max(*sj);
        }
        let mut l = 0.0f32;
        for sj in s.iter_mut().take(hi).skip(lo) {
            *sj = (*sj - m).exp();
            l += *sj;
        }
        let o = &mut out[i * d..(i + 1) * d];
        for (j, &p) in s.iter().enumerate().take(hi).skip(lo) {
            let vj = &v[j * d..(j + 1) * d];
            for (oc, &vc) in o.iter_mut().zip(vj) {
                *oc += p * vc;
            }
        }
        let inv = 1.0 / l.max(1e-30);
        for oc in o.iter_mut() {
            *oc *= inv;
        }
    }
    out
}

/// One INT8 score tile for Q block `[i0, i0+bq)` × KV block `[j0, jk)`:
/// run the ISA `qk_tile_i8` microkernel over the contiguous hull of Q
/// rows with any attendable key in the block (so fully-masked rows cost
/// no dot products, exactly like the per-pair loops this replaces), then
/// dequantize + mask into `s` as `dot · q_scale · k_scale` / `NEG_BIG`.
/// `k_tile`/`k_scales` are the KV block's rows and per-row scales
/// (tile-local, `bk = jk - j0` entries) — the one thing that differs
/// between the plain, prepared and paged kernels.
#[allow(clippy::too_many_arguments)]
pub(super) fn qk_score_tile(
    kern: &isa::Kernels,
    opts: PlaneOpts,
    q_i8: &[i8],
    q_scales: &[f32],
    k_tile: &[i8],
    k_scales: &[f32],
    s: &mut [f32],
    s_i32: &mut [i32],
    i0: usize,
    bq: usize,
    j0: usize,
    jk: usize,
    n_q: usize,
    n_kv: usize,
    d: usize,
) {
    let bk = jk - j0;
    // contiguous hull of Q rows whose [lo, hi) overlaps [j0, jk)
    let mut r0 = bq;
    let mut r1 = 0;
    for bi in 0..bq {
        let (lo, hi) = opts.range(i0 + bi, n_q, n_kv);
        if lo < jk && hi > j0 {
            if r0 == bq {
                r0 = bi;
            }
            r1 = bi + 1;
        }
    }
    if r0 < r1 {
        (kern.qk_tile_i8)(
            &q_i8[(i0 + r0) * d..(i0 + r1) * d],
            k_tile,
            d,
            r1 - r0,
            bk,
            &mut s_i32[r0 * BLOCK_KV..],
            BLOCK_KV,
        );
    }
    for bi in 0..bq {
        let (lo, hi) = opts.range(i0 + bi, n_q, n_kv);
        let qs = q_scales[i0 + bi];
        let srow = &mut s[bi * BLOCK_KV..bi * BLOCK_KV + bk];
        if bi < r0 || bi >= r1 {
            srow.fill(NEG_BIG);
            continue;
        }
        let irow = &s_i32[bi * BLOCK_KV..bi * BLOCK_KV + bk];
        for (bj, sv) in srow.iter_mut().enumerate() {
            let j = j0 + bj;
            *sv = if j >= lo && j < hi {
                irow[bj] as f32 * qs * k_scales[bj]
            } else {
                NEG_BIG
            };
        }
    }
}

/// Highest attendable key index + 1 for query `i` (queries aligned to the
/// end of the KV sequence, the decode convention). Saturating: with
/// n_q > n_kv the earliest queries precede every key and attend nothing
/// (limit 0) instead of underflowing into an unmasked row.
#[inline]
pub(super) fn causal_limit(i: usize, n_q: usize, n_kv: usize, causal: bool) -> usize {
    if causal {
        (i + n_kv + 1).saturating_sub(n_q).min(n_kv)
    } else {
        n_kv
    }
}

/// FlashAttention-2 fp32 tiling (Eq. 1–2) — validates the online-softmax
/// recurrence and serves as the full-precision speed baseline's numerics.
/// Convenience wrapper over [`online_plane_with`] with a fresh [`Scratch`].
pub fn online_plane(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    causal: bool,
) -> Vec<f32> {
    online_plane_with(&mut Scratch::new(), q, k, v, n_q, n_kv, d, causal)
}

/// [`online_plane`] against caller-owned scratch (the hot-path entry).
#[allow(clippy::too_many_arguments)]
pub fn online_plane_with(
    scratch: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    causal: bool,
) -> Vec<f32> {
    online_plane_opt(scratch, q, k, v, n_q, n_kv, d, PlaneOpts::causal(causal))
}

/// [`online_plane_with`] with the full masking/scaling options.
#[allow(clippy::too_many_arguments)]
pub fn online_plane_opt(
    scratch: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    opts: PlaneOpts,
) -> Vec<f32> {
    scratch.ensure_head_dim(d);
    let Scratch { s, m, l, acc, .. } = scratch;
    let scale = opts.scale(d);
    let mut out = vec![0.0f32; n_q * d];

    let mut i0 = 0;
    while i0 < n_q {
        let iq = (i0 + BLOCK_Q).min(n_q);
        let bq = iq - i0;
        let mb = &mut m[..bq];
        mb.fill(NEG_BIG);
        let lb = &mut l[..bq];
        lb.fill(0.0);
        let accb = &mut acc[..bq * d];
        accb.fill(0.0);
        let mut j0 = 0;
        while j0 < n_kv {
            let jk = (j0 + BLOCK_KV).min(n_kv);
            let bk = jk - j0;
            // S tile
            for bi in 0..bq {
                let (lo, hi) = opts.range(i0 + bi, n_q, n_kv);
                let qi = &q[(i0 + bi) * d..(i0 + bi + 1) * d];
                for bj in 0..bk {
                    let j = j0 + bj;
                    let s_val = if j >= lo && j < hi {
                        let kj = &k[j * d..(j + 1) * d];
                        qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale
                    } else {
                        NEG_BIG
                    };
                    s[bi * BLOCK_KV + bj] = s_val;
                }
            }
            // online softmax update
            for bi in 0..bq {
                let row = &mut s[bi * BLOCK_KV..bi * BLOCK_KV + bk];
                let m_cur = row.iter().fold(NEG_BIG, |a, &b| a.max(b));
                let m_new = mb[bi].max(m_cur);
                if m_new == NEG_BIG {
                    // row fully masked so far (causal_limit == 0): without
                    // this guard exp(NEG_BIG - NEG_BIG) = 1 would weight
                    // every masked key; skip so the row stays zero like
                    // the exact/naive references
                    continue;
                }
                let alpha = (mb[bi] - m_new).exp();
                let mut row_sum = 0.0;
                for p in row.iter_mut() {
                    *p = (*p - m_new).exp();
                    row_sum += *p;
                }
                lb[bi] = alpha * lb[bi] + row_sum;
                mb[bi] = m_new;
                let o = &mut accb[bi * d..(bi + 1) * d];
                for oc in o.iter_mut() {
                    *oc *= alpha;
                }
                for (bj, &p) in row.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    let vj = &v[(j0 + bj) * d..(j0 + bj + 1) * d];
                    for (oc, &vc) in o.iter_mut().zip(vj) {
                        *oc += p * vc;
                    }
                }
            }
            j0 = jk;
        }
        for bi in 0..bq {
            let inv = 1.0 / lb[bi].max(1e-30);
            let o = &mut out[(i0 + bi) * d..(i0 + bi + 1) * d];
            for (oc, &ac) in o.iter_mut().zip(&accb[bi * d..(bi + 1) * d]) {
                *oc = ac * inv;
            }
        }
        i0 = iq;
    }
    out
}

/// SageAttention plane (Alg. 1): INT8 QKᵀ + fp32 online softmax + the
/// selected P·V mode. Mirrors `python/compile/kernels/sage_attn.py`.
/// Convenience wrapper over [`sage_plane_with`] with a fresh [`Scratch`];
/// the tensor-level entry point is [`crate::attn::api::AttnSpec`].
#[allow(clippy::too_many_arguments)]
pub fn sage_plane(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_gran: Granularity,
    pv: PvMode,
    smooth: bool,
    causal: bool,
) -> Vec<f32> {
    sage_plane_with(&mut Scratch::new(), q, k, v, n_q, n_kv, d, qk_gran, pv, smooth, causal)
}

/// [`sage_plane`] against caller-owned scratch — the serving hot path.
/// Identical arithmetic (and therefore bit-identical output) to the
/// wrapper; only the buffer lifetimes differ.
#[allow(clippy::too_many_arguments)]
pub fn sage_plane_with(
    scratch: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_gran: Granularity,
    pv: PvMode,
    smooth: bool,
    causal: bool,
) -> Vec<f32> {
    sage_plane_opt(scratch, q, k, v, n_q, n_kv, d, qk_gran, pv, smooth, PlaneOpts::causal(causal))
}

/// [`sage_plane_with`] with the full masking/scaling options.
#[allow(clippy::too_many_arguments)]
pub fn sage_plane_opt(
    scratch: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_gran: Granularity,
    pv: PvMode,
    smooth: bool,
    opts: PlaneOpts,
) -> Vec<f32> {
    // per-channel scales are per *column*; the S-tile dequant below indexes
    // scales per token row, so PerChannel Q/K would read out of bounds —
    // and §4.3 rules it out for Q/K inside the tiled kernel anyway
    assert!(
        qk_gran != Granularity::PerChannel,
        "per-channel Q/K quantization is infeasible in the tiled kernel (§4.3); \
         use PerToken/PerBlock/PerTensor"
    );
    scratch.ensure_head_dim(d);
    let Scratch {
        s,
        s_i32,
        p_i8,
        m,
        l,
        acc,
        p16,
        acc_i32,
        qbuf,
        kbuf,
        kmean,
        vbuf,
        q_i8,
        q_scales,
        k_i8,
        k_scales,
        v_i8,
        v_scales,
        timer,
    } = scratch;
    let kern = isa::kernels();
    timer.begin_plane();

    // ---- quantize Q (with folded softmax scale) and K (after smooth-K),
    //      all into scratch-owned buffers (zero per-plane allocation) ----
    let scale = opts.scale(d);
    let t_quant = timer.section();
    qbuf.clear();
    qbuf.extend(q.iter().map(|&x| x * scale));
    let k_src: &[f32] = if smooth {
        quant::smooth_k_into(k, n_kv, d, kbuf, kmean);
        kbuf
    } else {
        k
    };
    quant::quantize_into(qbuf, n_q, d, qk_gran, q_i8, q_scales);
    quant::quantize_into(k_src, n_kv, d, qk_gran, k_i8, k_scales);
    timer.commit(Phase::Quant, t_quant);

    // ---- quantize / round V per P·V mode ----
    match pv {
        PvMode::Int8 => {
            let t0 = timer.section();
            quant::quant_per_channel_into(v, n_kv, d, v_i8, v_scales);
            timer.commit(Phase::Quant, t0);
        }
        _ => {
            let t0 = timer.section();
            vbuf.clear();
            vbuf.extend_from_slice(v);
            round_f16_slice(vbuf);
            timer.commit(Phase::F16Round, t0);
        }
    }
    let v_f16: &[f32] = vbuf;

    let mut out = vec![0.0f32; n_q * d];

    let mut i0 = 0;
    while i0 < n_q {
        let iq = (i0 + BLOCK_Q).min(n_q);
        let bq = iq - i0;
        let mb = &mut m[..bq];
        mb.fill(NEG_BIG);
        let lb = &mut l[..bq];
        lb.fill(0.0);
        // held as fp16 values when Fp16Accum
        let accb = &mut acc[..bq * d];
        accb.fill(0.0);
        let mut j0 = 0;
        while j0 < n_kv {
            let jk = (j0 + BLOCK_KV).min(n_kv);
            let bk = jk - j0;
            // ---- S tile: mma(u8.u8.s32) via the ISA tile microkernel,
            //      then dequant + mask into `s` ----
            let t_qk = timer.section();
            qk_score_tile(
                kern,
                opts,
                q_i8,
                q_scales,
                &k_i8[j0 * d..jk * d],
                &k_scales[j0..jk],
                s,
                s_i32,
                i0,
                bq,
                j0,
                jk,
                n_q,
                n_kv,
                d,
            );
            timer.commit(Phase::QkTile, t_qk);
            // this tile's V rows in the P·V mode's representation
            // (per-channel V scales are whole-plane here, length d)
            let vtile = match pv {
                PvMode::Int8 => {
                    super::pv::PvTile::Int8 { v: &v_i8[j0 * d..jk * d], scales: &v_scales[..d] }
                }
                PvMode::Fp16Accum => super::pv::PvTile::F16Accum { v: &v_f16[j0 * d..jk * d] },
                PvMode::Fp32Accum => super::pv::PvTile::F32Accum { v: &v_f16[j0 * d..jk * d] },
            };
            // ---- online softmax (fp32) + P·V ----
            for bi in 0..bq {
                let t_sm = timer.section();
                let row = &mut s[bi * BLOCK_KV..bi * BLOCK_KV + bk];
                let m_cur = row.iter().fold(NEG_BIG, |a, &b| a.max(b));
                let m_new = mb[bi].max(m_cur);
                if m_new == NEG_BIG {
                    // fully-masked row (causal_limit == 0): skip so it
                    // stays zero like the exact/naive references instead
                    // of exp(0)-weighting every masked key
                    timer.commit(Phase::Softmax, t_sm);
                    continue;
                }
                let alpha = (mb[bi] - m_new).exp();
                let mut row_sum = 0.0;
                for p in row.iter_mut() {
                    *p = (*p - m_new).exp();
                    row_sum += *p;
                }
                lb[bi] = alpha * lb[bi] + row_sum;
                mb[bi] = m_new;
                timer.commit(Phase::Softmax, t_sm);
                let o = &mut accb[bi * d..(bi + 1) * d];
                // shared P·V tile formulation (attn::pv): α-rescale + P̃·V
                // in the mode's numerics through the fused ISA lanes
                let t_pv = timer.section();
                super::pv::accumulate(kern, &vtile, o, alpha, row, p_i8, p16, acc_i32, d);
                timer.commit(Phase::Pv, t_pv);
            }
            j0 = jk;
        }
        for bi in 0..bq {
            let inv = 1.0 / lb[bi].max(1e-30);
            let o = &mut out[(i0 + bi) * d..(i0 + bi + 1) * d];
            for (oc, &ac) in o.iter_mut().zip(&accb[bi * d..(bi + 1) * d]) {
                *oc = ac * inv;
            }
        }
        i0 = iq;
    }
    out
}

/// Unblocked row-at-a-time reference: INT8-QKᵀ attention with a full
/// (non-online) softmax and a fresh score buffer allocated inside the
/// loop for every query row — no KV tiling, so K and V stream through
/// cache once per query. This is the textbook formulation the blocked
/// kernel improves on (the seed's `sage_plane` was already tiled; what
/// PR 1 added there is scratch reuse). Numerically it tracks
/// [`sage_plane`] with [`PvMode::Fp32Accum`] (same quantizers,
/// fp16-rounded P̃ and V, fp32 accumulation; only the summation order
/// differs). Used as the measured "before" of `sage bench-hotpath` and
/// as a cross-check oracle for the blocked kernel.
#[allow(clippy::too_many_arguments)]
pub fn sage_plane_naive(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_gran: Granularity,
    smooth: bool,
    causal: bool,
) -> Vec<f32> {
    // scales are read per token row below (qq.scales[i], kq.scales[j]);
    // per-channel scales are per column, so PerChannel would index out of
    // bounds — reject it the way the blocked kernel does
    assert!(
        qk_gran != Granularity::PerChannel,
        "per-channel Q/K quantization unsupported: this kernel dequantizes with \
         per-token-row scales; use PerToken/PerBlock/PerTensor"
    );
    let scale = 1.0 / (d as f32).sqrt();
    let q_scaled: Vec<f32> = q.iter().map(|&x| x * scale).collect();
    let k_sm;
    let k_src: &[f32] = if smooth {
        let (sm, _) = quant::smooth_k(k, n_kv, d);
        k_sm = sm;
        &k_sm
    } else {
        k
    };
    let qq = quant::quantize(&q_scaled, n_q, d, qk_gran);
    let kq = quant::quantize(k_src, n_kv, d, qk_gran);
    let v_f16: Vec<f32> = v.iter().map(|&x| round_f16(x)).collect();

    let mut out = vec![0.0f32; n_q * d];
    for i in 0..n_q {
        // the per-row allocation the blocked kernel eliminates
        let mut s = vec![0.0f32; n_kv];
        let limit = causal_limit(i, n_q, n_kv, causal);
        let qi = &qq.data[i * d..(i + 1) * d];
        let qs = qq.scales[i];
        let mut mx = NEG_BIG;
        for (j, sj) in s.iter_mut().enumerate().take(limit) {
            let kj = &kq.data[j * d..(j + 1) * d];
            *sj = isa::dot_i8(qi, kj) as f32 * qs * kq.scales[j];
            mx = mx.max(*sj);
        }
        let mut lsum = 0.0f32;
        for sj in s.iter_mut().take(limit) {
            *sj = round_f16((*sj - mx).exp());
            lsum += *sj;
        }
        let o = &mut out[i * d..(i + 1) * d];
        for (j, &p) in s.iter().enumerate().take(limit) {
            if p == 0.0 {
                continue;
            }
            let vj = &v_f16[j * d..(j + 1) * d];
            for (oc, &vc) in o.iter_mut().zip(vj) {
                *oc += p * vc;
            }
        }
        let inv = 1.0 / lsum.max(1e-30);
        for oc in o.iter_mut() {
            *oc *= inv;
        }
    }
    out
}

/// FlashAttention3-FP8-style plane: Q,K and P,V all FP8 per-token scaled,
/// no smoothing, fp32 accumulation (the Hopper FP8 path's numerics).
#[allow(clippy::too_many_arguments)]
pub fn fp8_plane(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_fmt: Fp8Format,
    pv_fmt: Fp8Format,
    causal: bool,
) -> Vec<f32> {
    fp8_plane_opt(q, k, v, n_q, n_kv, d, qk_fmt, pv_fmt, PlaneOpts::causal(causal))
}

/// [`fp8_plane`] with the full masking/scaling options.
#[allow(clippy::too_many_arguments)]
pub fn fp8_plane_opt(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    qk_fmt: Fp8Format,
    pv_fmt: Fp8Format,
    opts: PlaneOpts,
) -> Vec<f32> {
    use crate::quant::FakeQuant;
    let qf = quant::fake_quant(q, n_q, d, FakeQuant::Fp8(qk_fmt));
    let kf = quant::fake_quant(k, n_kv, d, FakeQuant::Fp8(qk_fmt));
    // V quantized per-token to FP8; P̃ rounded to FP8 inside the loop.
    let vf = quant::fake_quant(v, n_kv, d, FakeQuant::Fp8(pv_fmt));
    let scale = opts.scale(d);
    let mut out = vec![0.0f32; n_q * d];
    let mut s = vec![0.0f32; n_kv];
    for i in 0..n_q {
        let qi = &qf[i * d..(i + 1) * d];
        let (lo, hi) = opts.range(i, n_q, n_kv);
        let mut m = NEG_BIG;
        for (j, sj) in s.iter_mut().enumerate().take(hi).skip(lo) {
            let kj = &kf[j * d..(j + 1) * d];
            let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
            *sj = dot * scale;
            m = m.max(*sj);
        }
        let mut l = 0.0f32;
        for sj in s.iter_mut().take(hi).skip(lo) {
            *sj = pv_fmt.round((*sj - m).exp());
            l += *sj;
        }
        let o = &mut out[i * d..(i + 1) * d];
        for (j, &p) in s.iter().enumerate().take(hi).skip(lo) {
            if p == 0.0 {
                continue;
            }
            let vj = &vf[j * d..(j + 1) * d];
            for (oc, &vc) in o.iter_mut().zip(vj) {
                *oc += p * vc;
            }
        }
        let inv = 1.0 / l.max(1e-30);
        for oc in o.iter_mut() {
            *oc *= inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::cos_sim;
    use crate::synth::{make_qkv, Profile};

    #[test]
    fn scratch_reuse_is_deterministic() {
        // one scratch driven across planes of different shapes must give
        // bit-identical results to fresh-scratch calls (no stale state)
        let mut scratch = Scratch::new();
        let (q1, k1, v1) = make_qkv(1, [1, 1, 200, 64], Profile::diffusion_like());
        let (q2, k2, v2) = make_qkv(2, [1, 1, 96, 32], Profile::llama_like());
        for pv in [PvMode::Fp16Accum, PvMode::Int8, PvMode::Fp32Accum] {
            let fresh1 = sage_plane(
                &q1.data, &k1.data, &v1.data, 200, 200, 64,
                Granularity::PerToken, pv, true, false,
            );
            let fresh2 = sage_plane(
                &q2.data, &k2.data, &v2.data, 96, 96, 32,
                Granularity::PerBlock(128), pv, true, true,
            );
            let reused1 = sage_plane_with(
                &mut scratch, &q1.data, &k1.data, &v1.data, 200, 200, 64,
                Granularity::PerToken, pv, true, false,
            );
            let reused2 = sage_plane_with(
                &mut scratch, &q2.data, &k2.data, &v2.data, 96, 96, 32,
                Granularity::PerBlock(128), pv, true, true,
            );
            assert_eq!(fresh1, reused1, "{pv:?} large plane");
            assert_eq!(fresh2, reused2, "{pv:?} small plane after large");
        }
    }

    #[test]
    #[should_panic(expected = "per-channel Q/K")]
    fn per_channel_qk_rejected() {
        let (q, k, v) = make_qkv(6, [1, 1, 32, 16], Profile::llama_like());
        sage_plane(
            &q.data, &k.data, &v.data, 32, 32, 16,
            Granularity::PerChannel, PvMode::Fp32Accum, true, false,
        );
    }

    #[test]
    fn online_with_matches_wrapper() {
        let (q, k, v) = make_qkv(3, [1, 1, 300, 64], Profile::vit_like());
        let mut scratch = Scratch::new();
        let a = online_plane(&q.data, &k.data, &v.data, 300, 300, 64, false);
        let b = online_plane_with(&mut scratch, &q.data, &k.data, &v.data, 300, 300, 64, false);
        assert_eq!(a, b);
    }

    #[test]
    fn window_covering_sequence_is_full_attention() {
        // a sliding window at least as wide as the sequence must be
        // bit-identical to plain causal attention, for every kernel family
        let (n, d) = (150usize, 32usize);
        let (q, k, v) = make_qkv(21, [1, 1, n, d], Profile::llama_like());
        let causal = PlaneOpts::causal(true);
        let windowed = PlaneOpts { window: Some(n), ..causal };
        assert_eq!(
            exact_plane_opt(&q.data, &k.data, &v.data, n, n, d, causal),
            exact_plane_opt(&q.data, &k.data, &v.data, n, n, d, windowed),
        );
        let mut scratch = Scratch::new();
        assert_eq!(
            online_plane_opt(&mut scratch, &q.data, &k.data, &v.data, n, n, d, causal),
            online_plane_opt(&mut scratch, &q.data, &k.data, &v.data, n, n, d, windowed),
        );
        let sage = |opts| {
            sage_plane_opt(
                &mut Scratch::new(), &q.data, &k.data, &v.data, n, n, d,
                Granularity::PerToken, PvMode::Fp16Accum, true, opts,
            )
        };
        assert_eq!(sage(causal), sage(windowed));
    }

    #[test]
    fn window_restricts_reach() {
        // with a narrow window, query i must ignore keys before i-w+1:
        // perturbing an early key must not change a late query's output
        let (n, d, w) = (96usize, 16usize, 8usize);
        let (q, k, v) = make_qkv(22, [1, 1, n, d], Profile::llama_like());
        let opts = PlaneOpts { window: Some(w), ..PlaneOpts::causal(true) };
        let o1 = exact_plane_opt(&q.data, &k.data, &v.data, n, n, d, opts);
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for c in 0..d {
            k2.data[c] += 100.0; // key 0, far outside the last row's window
            v2.data[c] -= 50.0;
        }
        let o2 = exact_plane_opt(&q.data, &k2.data, &v2.data, n, n, d, opts);
        let last = (n - 1) * d;
        assert_eq!(&o1[last..], &o2[last..], "window leaked an out-of-range key");
        // ...but the windowed result differs from full causal attention
        let full = exact_plane_opt(&q.data, &k.data, &v.data, n, n, d, PlaneOpts::causal(true));
        assert_ne!(o1, full);
    }

    #[test]
    fn sm_scale_default_is_inv_sqrt_d() {
        let (n, d) = (64usize, 32usize);
        let (q, k, v) = make_qkv(23, [1, 1, n, d], Profile::vit_like());
        let explicit = PlaneOpts {
            sm_scale: Some(1.0 / (d as f32).sqrt()),
            ..PlaneOpts::causal(false)
        };
        assert_eq!(
            exact_plane_opt(&q.data, &k.data, &v.data, n, n, d, PlaneOpts::causal(false)),
            exact_plane_opt(&q.data, &k.data, &v.data, n, n, d, explicit),
        );
        // a different scale changes the distribution
        let sharp = PlaneOpts { sm_scale: Some(1.0), ..PlaneOpts::causal(false) };
        let o = exact_plane_opt(&q.data, &k.data, &v.data, n, n, d, sharp);
        assert_ne!(o, exact_plane_opt(&q.data, &k.data, &v.data, n, n, d, explicit));
        assert!(o.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causal_with_more_queries_than_keys_masks_fully() {
        // decode-aligned causal with n_q > n_kv: the earliest queries
        // precede every key — their rows must be exactly zero (not a
        // uniform average of V), matching the exact reference
        let (n_q, n_kv, d) = (150usize, 40usize, 32usize);
        let (q, k, v) = make_qkv(12, [1, 1, n_q, d], Profile::llama_like());
        let kp = &k.data[..n_kv * d];
        let vp = &v.data[..n_kv * d];
        let zero_rows = (n_q - n_kv) * d;

        let gold = exact_plane(&q.data, kp, vp, n_q, n_kv, d, true);
        assert!(gold[..zero_rows].iter().all(|&x| x == 0.0));

        let on = online_plane(&q.data, kp, vp, n_q, n_kv, d, true);
        assert!(on[..zero_rows].iter().all(|&x| x == 0.0), "online leaked masked keys");
        assert!(cos_sim(&gold[zero_rows..], &on[zero_rows..]) > 0.9999);

        let blocked = sage_plane(
            &q.data, kp, vp, n_q, n_kv, d,
            Granularity::PerToken, PvMode::Fp32Accum, true, true,
        );
        assert!(blocked[..zero_rows].iter().all(|&x| x == 0.0), "sage leaked masked keys");
        let naive = sage_plane_naive(
            &q.data, kp, vp, n_q, n_kv, d, Granularity::PerToken, true, true,
        );
        assert!(naive[..zero_rows].iter().all(|&x| x == 0.0));
        assert!(cos_sim(&blocked[zero_rows..], &naive[zero_rows..]) > 0.999);
    }

    #[test]
    fn head_dim_beyond_prealloc_grows_scratch() {
        // d > MAX_HEAD_DIM must grow the scratch, not panic or truncate
        let (q, k, v) = make_qkv(9, [1, 1, 40, 320], Profile::llama_like());
        let gold = exact_plane(&q.data, &k.data, &v.data, 40, 40, 320, false);
        let on = online_plane(&q.data, &k.data, &v.data, 40, 40, 320, false);
        assert!(cos_sim(&gold, &on) > 0.9999);
        let mut scratch = Scratch::new();
        for pv in [PvMode::Fp16Accum, PvMode::Int8, PvMode::Fp32Accum] {
            let out = sage_plane_with(
                &mut scratch, &q.data, &k.data, &v.data, 40, 40, 320,
                Granularity::PerToken, pv, true, false,
            );
            assert!(cos_sim(&gold, &out) > 0.98, "{pv:?}");
        }
    }

    #[test]
    fn naive_tracks_blocked_fp32acc() {
        // the bench-hotpath baseline must be the same computation up to
        // fp32 summation order
        let (q, k, v) = make_qkv(4, [1, 1, 256, 64], Profile::diffusion_like());
        let naive = sage_plane_naive(
            &q.data, &k.data, &v.data, 256, 256, 64,
            Granularity::PerToken, true, false,
        );
        let blocked = sage_plane(
            &q.data, &k.data, &v.data, 256, 256, 64,
            Granularity::PerToken, PvMode::Fp32Accum, true, false,
        );
        let c = cos_sim(&naive, &blocked);
        assert!(c > 0.999, "naive vs blocked cos {c}");
        assert!(naive.iter().all(|x| x.is_finite()));
    }
}
