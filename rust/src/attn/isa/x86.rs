//! x86-64 microkernels: AVX2 (i8→i16 widening + `pmaddwd`) and AVX-512
//! VNNI (`vpdpbusd`) tiers.
//!
//! Bit-identity argument: every INT8 kernel accumulates in i32 — integer
//! addition is associative, so lane order is irrelevant and the result
//! equals the scalar reference exactly. `vpdpbusd` multiplies an
//! *unsigned* byte by a signed one, so the signed×signed dot is computed
//! with a bias trick: `Σ(a+128)·b = Σa·b + 128·Σb`, all in exact i32,
//! corrected after the loop. The f32 kernels are element-wise with an
//! explicit mul-then-add (never `fmadd`), so each lane performs the same
//! two IEEE operations as the scalar loop.
//!
//! Safety: the `unsafe` `#[target_feature]` functions are only reachable
//! through the [`super::Kernels`] tables, which [`super::for_level`]
//! hands out strictly behind [`super::cpu::supported`] runtime detection.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;

use super::cpu::{self, supported, IsaLevel};
use crate::util::f16::round_f16;

// ---------------------------------------------------------------------------
// AVX2 tier
// ---------------------------------------------------------------------------

pub(super) fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
    debug_assert!(supported(IsaLevel::Avx2), "avx2 kernel on an unsupported host");
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: reachable only via a table gated on runtime AVX2 detection.
    unsafe { dot_i8_avx2_imp(a, b) }
}

#[target_feature(enable = "avx", enable = "avx2")]
unsafe fn dot_i8_avx2_imp(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len();
    let nv = n - n % 32;
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i < nv {
        let a0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i) as *const _));
        let a1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i + 16) as *const _));
        let b0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(i) as *const _));
        let b1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(i + 16) as *const _));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a0, b0));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a1, b1));
        i += 32;
    }
    let mut dot = hsum_epi32(acc);
    while i < n {
        dot += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    dot
}

/// Horizontal i32 sum of one 256-bit accumulator.
#[target_feature(enable = "avx", enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4E>(s)); // swap 64-bit halves
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xB1>(s)); // swap 32-bit pairs
    _mm_cvtsi128_si32(s)
}

pub(super) fn qk_tile_i8_avx2(
    q: &[i8],
    k: &[i8],
    d: usize,
    bq: usize,
    bk: usize,
    out: &mut [i32],
    stride: usize,
) {
    debug_assert!(supported(IsaLevel::Avx2), "avx2 kernel on an unsupported host");
    debug_assert!(q.len() >= bq * d && k.len() >= bk * d);
    debug_assert!(bq == 0 || out.len() >= (bq - 1) * stride + bk);
    // SAFETY: reachable only via a table gated on runtime AVX2 detection.
    unsafe { qk_tile_i8_avx2_imp(q, k, d, bq, bk, out, stride) }
}

/// Register-blocked tile: 4 Q-row accumulators share each widened K
/// chunk, so K is loaded (and sign-extended) once per 4 Q rows instead
/// of once per scoreline — the multi-accumulator unrolling that
/// amortizes K traffic across the Q block.
#[target_feature(enable = "avx", enable = "avx2")]
unsafe fn qk_tile_i8_avx2_imp(
    q: &[i8],
    k: &[i8],
    d: usize,
    bq: usize,
    bk: usize,
    out: &mut [i32],
    stride: usize,
) {
    let dv = d - d % 32;
    let mut r = 0;
    while r < bq {
        let rn = (r + 4).min(bq);
        for c in 0..bk {
            let kp = k.as_ptr().add(c * d);
            let mut acc = [_mm256_setzero_si256(); 4];
            let mut j = 0;
            while j < dv {
                let k0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(kp.add(j) as *const _));
                let k1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(kp.add(j + 16) as *const _));
                for t in 0..rn - r {
                    let qp = q.as_ptr().add((r + t) * d + j);
                    let q0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(qp as *const _));
                    let q1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(qp.add(16) as *const _));
                    acc[t] = _mm256_add_epi32(acc[t], _mm256_madd_epi16(q0, k0));
                    acc[t] = _mm256_add_epi32(acc[t], _mm256_madd_epi16(q1, k1));
                }
                j += 32;
            }
            for t in 0..rn - r {
                let mut dot = hsum_epi32(acc[t]);
                for j in dv..d {
                    dot += q[(r + t) * d + j] as i32 * k[c * d + j] as i32;
                }
                out[(r + t) * stride + c] = dot;
            }
        }
        r = rn;
    }
}

pub(super) fn pv_accum_i8_avx2(acc: &mut [i32], v: &[i8], p: i32) {
    debug_assert!(supported(IsaLevel::Avx2), "avx2 kernel on an unsupported host");
    debug_assert_eq!(acc.len(), v.len());
    // SAFETY: reachable only via a table gated on runtime AVX2 detection.
    unsafe { pv_accum_i8_avx2_imp(acc, v, p) }
}

#[target_feature(enable = "avx", enable = "avx2")]
unsafe fn pv_accum_i8_avx2_imp(acc: &mut [i32], v: &[i8], p: i32) {
    let n = acc.len();
    let nv = n - n % 8;
    let pv = _mm256_set1_epi32(p);
    let mut i = 0;
    while i < nv {
        let vv = _mm256_cvtepi8_epi32(_mm_loadl_epi64(v.as_ptr().add(i) as *const _));
        let av = _mm256_loadu_si256(acc.as_ptr().add(i) as *const _);
        let sum = _mm256_add_epi32(av, _mm256_mullo_epi32(pv, vv));
        _mm256_storeu_si256(acc.as_mut_ptr().add(i) as *mut _, sum);
        i += 8;
    }
    while i < n {
        acc[i] += p * v[i] as i32;
        i += 1;
    }
}

pub(super) fn axpy_f32_avx(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert!(supported(IsaLevel::Avx2), "avx2 kernel on an unsupported host");
    debug_assert_eq!(out.len(), x.len());
    // SAFETY: reachable only via a table gated on runtime AVX2 detection
    // (which implies AVX).
    unsafe { axpy_f32_avx_imp(out, x, a) }
}

#[target_feature(enable = "avx")]
unsafe fn axpy_f32_avx_imp(out: &mut [f32], x: &[f32], a: f32) {
    let n = out.len();
    let nv = n - n % 8;
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i < nv {
        let o = _mm256_loadu_ps(out.as_ptr().add(i));
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        // mul then add — same two IEEE ops per lane as the scalar loop
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(o, _mm256_mul_ps(av, xv)));
        i += 8;
    }
    while i < n {
        out[i] += a * x[i];
        i += 1;
    }
}

pub(super) fn scale_f32_avx(out: &mut [f32], a: f32) {
    debug_assert!(supported(IsaLevel::Avx2), "avx2 kernel on an unsupported host");
    // SAFETY: reachable only via a table gated on runtime AVX2 detection.
    unsafe { scale_f32_avx_imp(out, a) }
}

#[target_feature(enable = "avx")]
unsafe fn scale_f32_avx_imp(out: &mut [f32], a: f32) {
    let n = out.len();
    let nv = n - n % 8;
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i < nv {
        let o = _mm256_loadu_ps(out.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(o, av));
        i += 8;
    }
    while i < n {
        out[i] *= a;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Fused fp16-accumulator lanes (AVX + F16C). F16C is detected at runtime
// separately from AVX2 (`cpu::f16c_enabled`, which `SAGE_ISA=scalar`
// also pins off); without it the scalar formulation is bit-identical, so
// the wrappers simply fall through to it.
// ---------------------------------------------------------------------------

/// 8-lane f32→f16→f32 round-trip (round-to-nearest-even — bit-identical
/// to `util::f16::round_f16`, the contract `util::f16` tests pin).
#[target_feature(enable = "avx", enable = "f16c")]
unsafe fn round_f16_256(x: __m256) -> __m256 {
    _mm256_cvtph_ps(_mm256_cvtps_ph::<_MM_FROUND_TO_NEAREST_INT>(x))
}

pub(super) fn pv_f16_step_avx(o: &mut [f32], p: &[f32], v: &[f32], d: usize) {
    debug_assert!(supported(IsaLevel::Avx2), "avx2 kernel on an unsupported host");
    debug_assert!(o.len() >= d && v.len() >= p.len() * d);
    if !cpu::f16c_enabled() {
        // no hardware round-trip (or SAGE_ISA pinned the software
        // converter): the scalar fused formulation is bit-identical
        return super::scalar::pv_f16_step(o, p, v, d);
    }
    // SAFETY: reachable only via a table gated on runtime AVX2 detection;
    // `f16c_enabled` adds the detected F16C bit.
    unsafe { pv_f16_step_f16c_imp(o, p, v, d) }
}

/// The whole MMA_K contraction block in registers: 8 output channels
/// accumulate all ≤16 steps, then round the partial and the accumulator
/// once each — one pass over `o` where the unfused composition made
/// three (axpy into part, round part, add + round o).
#[target_feature(enable = "avx", enable = "f16c")]
unsafe fn pv_f16_step_f16c_imp(o: &mut [f32], p: &[f32], v: &[f32], d: usize) {
    let dv = d - d % 8;
    let mut c = 0;
    while c < dv {
        let mut acc = _mm256_setzero_ps();
        for (t, &pt) in p.iter().enumerate() {
            if pt == 0.0 {
                continue;
            }
            let vv = _mm256_loadu_ps(v.as_ptr().add(t * d + c));
            // mul then add — same two IEEE ops per lane as the axpy walk
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(pt), vv));
        }
        acc = round_f16_256(acc);
        let ov = _mm256_loadu_ps(o.as_ptr().add(c));
        _mm256_storeu_ps(o.as_mut_ptr().add(c), round_f16_256(_mm256_add_ps(ov, acc)));
        c += 8;
    }
    while c < d {
        let mut acc = 0.0f32;
        for (t, &pt) in p.iter().enumerate() {
            if pt != 0.0 {
                acc += pt * v[t * d + c];
            }
        }
        // software round == F16C round bit-for-bit (pinned in util::f16)
        acc = round_f16(acc);
        o[c] = round_f16(o[c] + acc);
        c += 1;
    }
}

pub(super) fn scale_round_f16_avx(out: &mut [f32], a: f32) {
    debug_assert!(supported(IsaLevel::Avx2), "avx2 kernel on an unsupported host");
    if !cpu::f16c_enabled() {
        return super::scalar::scale_round_f16(out, a);
    }
    // SAFETY: reachable only via a table gated on runtime AVX2 detection;
    // `f16c_enabled` adds the detected F16C bit.
    unsafe { scale_round_f16_f16c_imp(out, a) }
}

#[target_feature(enable = "avx", enable = "f16c")]
unsafe fn scale_round_f16_f16c_imp(out: &mut [f32], a: f32) {
    let n = out.len();
    let nv = n - n % 8;
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i < nv {
        let o = _mm256_loadu_ps(out.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), round_f16_256(_mm256_mul_ps(o, av)));
        i += 8;
    }
    while i < n {
        out[i] = round_f16(out[i] * a);
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// AVX-512 VNNI tier: `vpdpbusd` dot/tile plus 16-wide AVX-512F f32 and
// fused-f16 lanes (the byte-widening INT8 P·V multiply has no
// VNNI-specific instruction and stays on the AVX2 lane).
// `sage_avx512` is emitted by build.rs on rustc ≥ 1.89, where the
// AVX-512 intrinsics and target features are stable; older toolchains
// compile without this tier and top out at AVX2.
// ---------------------------------------------------------------------------

#[cfg(sage_avx512)]
pub(super) fn dot_i8_vnni(a: &[i8], b: &[i8]) -> i32 {
    debug_assert!(supported(IsaLevel::Vnni), "vnni kernel on an unsupported host");
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: reachable only via a table gated on runtime AVX-512
    // F/BW/VNNI detection.
    unsafe { dot_i8_vnni_imp(a, b) }
}

/// `vpdpbusd`-shaped signed dot: bias `a` into unsigned bytes
/// (`a ^ 0x80 == a + 128`), accumulate `Σ(a+128)·b` and `Σb` with two
/// dpbusd streams, and undo the bias with `- 128·Σb` — exact in i32.
#[cfg(sage_avx512)]
#[target_feature(enable = "avx512f", enable = "avx512bw", enable = "avx512vnni")]
unsafe fn dot_i8_vnni_imp(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len();
    let nv = n - n % 64;
    let bias = _mm512_set1_epi8(-128);
    let ones = _mm512_set1_epi8(1);
    let mut acc = _mm512_setzero_si512();
    let mut bsum = _mm512_setzero_si512();
    let mut i = 0;
    while i < nv {
        let av = _mm512_loadu_si512(a.as_ptr().add(i) as *const _);
        let bv = _mm512_loadu_si512(b.as_ptr().add(i) as *const _);
        acc = _mm512_dpbusd_epi32(acc, _mm512_xor_si512(av, bias), bv);
        bsum = _mm512_dpbusd_epi32(bsum, ones, bv);
        i += 64;
    }
    let mut dot = _mm512_reduce_add_epi32(acc) - 128 * _mm512_reduce_add_epi32(bsum);
    while i < n {
        dot += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    dot
}

#[cfg(sage_avx512)]
pub(super) fn qk_tile_i8_vnni(
    q: &[i8],
    k: &[i8],
    d: usize,
    bq: usize,
    bk: usize,
    out: &mut [i32],
    stride: usize,
) {
    debug_assert!(supported(IsaLevel::Vnni), "vnni kernel on an unsupported host");
    debug_assert!(q.len() >= bq * d && k.len() >= bk * d);
    debug_assert!(bq == 0 || out.len() >= (bq - 1) * stride + bk);
    // SAFETY: reachable only via a table gated on runtime AVX-512
    // F/BW/VNNI detection.
    unsafe { qk_tile_i8_vnni_imp(q, k, d, bq, bk, out, stride) }
}

/// VNNI tile: K is the biased (unsigned) dpbusd operand, loaded and
/// biased once per 4 Q-row accumulators; the per-Q-row `Σq` bias
/// correction is computed once per tile row-group.
#[cfg(sage_avx512)]
#[target_feature(enable = "avx512f", enable = "avx512bw", enable = "avx512vnni")]
unsafe fn qk_tile_i8_vnni_imp(
    q: &[i8],
    k: &[i8],
    d: usize,
    bq: usize,
    bk: usize,
    out: &mut [i32],
    stride: usize,
) {
    let dv = d - d % 64;
    let bias = _mm512_set1_epi8(-128);
    let mut r = 0;
    while r < bq {
        let rn = (r + 4).min(bq);
        // Σq over the vectorized prefix of each row in the group
        // (Σ(k+128)·q = Σk·q + 128·Σq, so dot = acc - 128·Σq)
        let mut qsum = [0i32; 4];
        for (t, qs) in qsum.iter_mut().enumerate().take(rn - r) {
            let row = &q[(r + t) * d..(r + t) * d + dv];
            *qs = row.iter().map(|&x| x as i32).sum();
        }
        for c in 0..bk {
            let kp = k.as_ptr().add(c * d);
            let mut acc = [_mm512_setzero_si512(); 4];
            let mut j = 0;
            while j < dv {
                let ku = _mm512_xor_si512(_mm512_loadu_si512(kp.add(j) as *const _), bias);
                for t in 0..rn - r {
                    let qv = _mm512_loadu_si512(q.as_ptr().add((r + t) * d + j) as *const _);
                    acc[t] = _mm512_dpbusd_epi32(acc[t], ku, qv);
                }
                j += 64;
            }
            for t in 0..rn - r {
                let mut dot = _mm512_reduce_add_epi32(acc[t]) - 128 * qsum[t];
                for j in dv..d {
                    dot += q[(r + t) * d + j] as i32 * k[c * d + j] as i32;
                }
                out[(r + t) * stride + c] = dot;
            }
        }
        r = rn;
    }
}

#[cfg(sage_avx512)]
pub(super) fn axpy_f32_avx512(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert!(supported(IsaLevel::Vnni), "vnni kernel on an unsupported host");
    debug_assert_eq!(out.len(), x.len());
    // SAFETY: reachable only via a table gated on runtime AVX-512
    // F/BW/VNNI detection (which implies AVX-512F).
    unsafe { axpy_f32_avx512_imp(out, x, a) }
}

#[cfg(sage_avx512)]
#[target_feature(enable = "avx512f")]
unsafe fn axpy_f32_avx512_imp(out: &mut [f32], x: &[f32], a: f32) {
    let n = out.len();
    let nv = n - n % 16;
    let av = _mm512_set1_ps(a);
    let mut i = 0;
    while i < nv {
        let o = _mm512_loadu_ps(out.as_ptr().add(i));
        let xv = _mm512_loadu_ps(x.as_ptr().add(i));
        // mul then add — same two IEEE ops per lane as the scalar loop
        _mm512_storeu_ps(out.as_mut_ptr().add(i), _mm512_add_ps(o, _mm512_mul_ps(av, xv)));
        i += 16;
    }
    while i < n {
        out[i] += a * x[i];
        i += 1;
    }
}

#[cfg(sage_avx512)]
pub(super) fn scale_f32_avx512(out: &mut [f32], a: f32) {
    debug_assert!(supported(IsaLevel::Vnni), "vnni kernel on an unsupported host");
    // SAFETY: reachable only via a table gated on runtime AVX-512
    // F/BW/VNNI detection (which implies AVX-512F).
    unsafe { scale_f32_avx512_imp(out, a) }
}

#[cfg(sage_avx512)]
#[target_feature(enable = "avx512f")]
unsafe fn scale_f32_avx512_imp(out: &mut [f32], a: f32) {
    let n = out.len();
    let nv = n - n % 16;
    let av = _mm512_set1_ps(a);
    let mut i = 0;
    while i < nv {
        let o = _mm512_loadu_ps(out.as_ptr().add(i));
        _mm512_storeu_ps(out.as_mut_ptr().add(i), _mm512_mul_ps(o, av));
        i += 16;
    }
    while i < n {
        out[i] *= a;
        i += 1;
    }
}

/// 16-lane f32→f16→f32 round-trip, built from two 256-bit F16C
/// conversions (there is no stable 512-wide `cvtps_ph`): split with
/// AVX-512F extract/insert, round each half, rejoin. Bit-identical to
/// `util::f16::round_f16` per lane.
#[cfg(sage_avx512)]
#[target_feature(enable = "avx512f", enable = "avx", enable = "f16c")]
unsafe fn round_f16_512(x: __m512) -> __m512 {
    let lo = _mm512_castps512_ps256(x);
    let hi = _mm256_castsi256_ps(_mm512_extracti64x4_epi64::<1>(_mm512_castps_si512(x)));
    let lo = _mm256_castps_si256(round_f16_256(lo));
    let hi = _mm256_castps_si256(round_f16_256(hi));
    _mm512_castsi512_ps(_mm512_inserti64x4::<1>(_mm512_castsi256_si512(lo), hi))
}

#[cfg(sage_avx512)]
pub(super) fn pv_f16_step_avx512(o: &mut [f32], p: &[f32], v: &[f32], d: usize) {
    debug_assert!(supported(IsaLevel::Vnni), "vnni kernel on an unsupported host");
    debug_assert!(o.len() >= d && v.len() >= p.len() * d);
    if !cpu::f16c_enabled() {
        return super::scalar::pv_f16_step(o, p, v, d);
    }
    // SAFETY: reachable only via a table gated on runtime AVX-512
    // F/BW/VNNI detection; `f16c_enabled` adds the detected F16C bit.
    unsafe { pv_f16_step_avx512_imp(o, p, v, d) }
}

/// 16-wide variant of [`pv_f16_step_f16c_imp`]: one contraction block in
/// registers per 16 output channels, f16 round-trips through
/// [`round_f16_512`].
#[cfg(sage_avx512)]
#[target_feature(enable = "avx512f", enable = "avx", enable = "f16c")]
unsafe fn pv_f16_step_avx512_imp(o: &mut [f32], p: &[f32], v: &[f32], d: usize) {
    let dv = d - d % 16;
    let mut c = 0;
    while c < dv {
        let mut acc = _mm512_setzero_ps();
        for (t, &pt) in p.iter().enumerate() {
            if pt == 0.0 {
                continue;
            }
            let vv = _mm512_loadu_ps(v.as_ptr().add(t * d + c));
            // mul then add — same two IEEE ops per lane as the axpy walk
            acc = _mm512_add_ps(acc, _mm512_mul_ps(_mm512_set1_ps(pt), vv));
        }
        acc = round_f16_512(acc);
        let ov = _mm512_loadu_ps(o.as_ptr().add(c));
        _mm512_storeu_ps(o.as_mut_ptr().add(c), round_f16_512(_mm512_add_ps(ov, acc)));
        c += 16;
    }
    while c < d {
        let mut acc = 0.0f32;
        for (t, &pt) in p.iter().enumerate() {
            if pt != 0.0 {
                acc += pt * v[t * d + c];
            }
        }
        // software round == F16C round bit-for-bit (pinned in util::f16)
        acc = round_f16(acc);
        o[c] = round_f16(o[c] + acc);
        c += 1;
    }
}

#[cfg(sage_avx512)]
pub(super) fn scale_round_f16_avx512(out: &mut [f32], a: f32) {
    debug_assert!(supported(IsaLevel::Vnni), "vnni kernel on an unsupported host");
    if !cpu::f16c_enabled() {
        return super::scalar::scale_round_f16(out, a);
    }
    // SAFETY: reachable only via a table gated on runtime AVX-512
    // F/BW/VNNI detection; `f16c_enabled` adds the detected F16C bit.
    unsafe { scale_round_f16_avx512_imp(out, a) }
}

#[cfg(sage_avx512)]
#[target_feature(enable = "avx512f", enable = "avx", enable = "f16c")]
unsafe fn scale_round_f16_avx512_imp(out: &mut [f32], a: f32) {
    let n = out.len();
    let nv = n - n % 16;
    let av = _mm512_set1_ps(a);
    let mut i = 0;
    while i < nv {
        let o = _mm512_loadu_ps(out.as_ptr().add(i));
        _mm512_storeu_ps(out.as_mut_ptr().add(i), round_f16_512(_mm512_mul_ps(o, av)));
        i += 16;
    }
    while i < n {
        out[i] = round_f16(out[i] * a);
        i += 1;
    }
}
