//! CPU-capability detection core: which SIMD microkernel tier this host
//! can run, detected once (`OnceLock`) and overridable with the
//! `SAGE_ISA` environment variable (`scalar|avx2|vnni|neon`).
//!
//! This is the single feature-detection surface of the crate — the INT8
//! microkernel dispatch ([`super::kernels`]) and the F16C fast path in
//! [`crate::util::f16::round_f16_slice`] both resolve through it, so
//! `SAGE_ISA=scalar` forces every portable fallback at once (the knob
//! `make verify` uses to keep the scalar paths covered).

use std::sync::OnceLock;

/// A microkernel instruction-set tier, from portable to widest.
///
/// `Scalar` is the reference implementation every other tier must match
/// **bit-exactly** (all INT8 paths accumulate in i32, so this is a hard
/// equality, not a tolerance — see `tests/isa_differential.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IsaLevel {
    /// Portable Rust (LLVM autovectorization only) — always available.
    Scalar,
    /// x86-64 AVX2: i8→i16 widening + `pmaddwd` MACs (the
    /// mma(s8.s8.s32)-shaped path of §4.3 on 256-bit vectors).
    Avx2,
    /// x86-64 AVX-512 VNNI: `vpdpbusd` 4-way byte dot products (the
    /// closest CPU analogue of the tensor-core INT8 MMA).
    Vnni,
    /// AArch64 NEON with the `sdot` (dotprod) extension.
    Neon,
}

impl IsaLevel {
    /// Every tier, in detection-preference order (widest last).
    pub const ALL: [IsaLevel; 4] =
        [IsaLevel::Scalar, IsaLevel::Avx2, IsaLevel::Vnni, IsaLevel::Neon];

    /// Stable lowercase name (the `SAGE_ISA` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            IsaLevel::Scalar => "scalar",
            IsaLevel::Avx2 => "avx2",
            IsaLevel::Vnni => "vnni",
            IsaLevel::Neon => "neon",
        }
    }

    /// Parse a `SAGE_ISA` value (case-insensitive). Inverse of
    /// [`IsaLevel::name`].
    pub fn from_name(name: &str) -> Option<IsaLevel> {
        match name.to_ascii_lowercase().as_str() {
            "scalar" => Some(IsaLevel::Scalar),
            "avx2" => Some(IsaLevel::Avx2),
            "vnni" => Some(IsaLevel::Vnni),
            "neon" => Some(IsaLevel::Neon),
            _ => None,
        }
    }
}

/// What the hardware supports (independent of any `SAGE_ISA` override).
#[derive(Clone, Copy, Debug)]
pub struct CpuCaps {
    /// Widest microkernel tier this host can execute.
    pub best: IsaLevel,
    /// x86 F16C conversion instructions available (the vectorized
    /// `round_f16_slice` path).
    pub f16c: bool,
}

/// Detected hardware capabilities, probed once per process.
pub fn caps() -> &'static CpuCaps {
    static CAPS: OnceLock<CpuCaps> = OnceLock::new();
    CAPS.get_or_init(detect)
}

#[cfg(target_arch = "x86_64")]
fn detect() -> CpuCaps {
    let avx2 = std::arch::is_x86_feature_detected!("avx2");
    // the VNNI kernels use 512-bit dpbusd plus BW byte broadcasts; the
    // tier only exists on toolchains with stable AVX-512 support
    // (rustc ≥ 1.89 — build.rs emits `sage_avx512` there)
    #[cfg(sage_avx512)]
    let vnni = avx2
        && std::arch::is_x86_feature_detected!("avx512f")
        && std::arch::is_x86_feature_detected!("avx512bw")
        && std::arch::is_x86_feature_detected!("avx512vnni");
    #[cfg(not(sage_avx512))]
    let vnni = false;
    let best = if vnni {
        IsaLevel::Vnni
    } else if avx2 {
        IsaLevel::Avx2
    } else {
        IsaLevel::Scalar
    };
    CpuCaps { best, f16c: std::arch::is_x86_feature_detected!("f16c") }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> CpuCaps {
    let best = if std::arch::is_aarch64_feature_detected!("dotprod") {
        IsaLevel::Neon
    } else {
        IsaLevel::Scalar
    };
    CpuCaps { best, f16c: false }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> CpuCaps {
    CpuCaps { best: IsaLevel::Scalar, f16c: false }
}

/// Can this host execute `level`'s kernel table?
pub fn supported(level: IsaLevel) -> bool {
    match level {
        IsaLevel::Scalar => true,
        IsaLevel::Avx2 => matches!(caps().best, IsaLevel::Avx2 | IsaLevel::Vnni),
        IsaLevel::Vnni => caps().best == IsaLevel::Vnni,
        IsaLevel::Neon => caps().best == IsaLevel::Neon,
    }
}

/// The resolved dispatch decision: detected tier, clamped by `SAGE_ISA`.
#[derive(Clone, Copy, Debug)]
pub struct ActiveIsa {
    /// Tier the microkernel tables dispatch to.
    pub level: IsaLevel,
    /// The `SAGE_ISA` override, if one was set. When it names a tier the
    /// hardware lacks, `level` falls back to [`IsaLevel::Scalar`] (the
    /// only always-safe interpretation of "force").
    pub requested: Option<IsaLevel>,
}

/// The active dispatch decision, resolved once per process: `SAGE_ISA`
/// is read at first use, so set it before the first kernel call (tests
/// that need a different tier spawn a fresh `sage` process — see
/// `tests/isa_differential.rs` — or reach a specific table through
/// [`super::for_level`]).
///
/// Panics on a malformed `SAGE_ISA` value: silently running the wrong
/// tier would invalidate every benchmark that builds on it.
pub fn active() -> &'static ActiveIsa {
    static ACTIVE: OnceLock<ActiveIsa> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let requested = match std::env::var("SAGE_ISA") {
            Ok(raw) => match IsaLevel::from_name(&raw) {
                Some(level) => Some(level),
                None => panic!(
                    "invalid SAGE_ISA value '{raw}': expected one of scalar|avx2|vnni|neon"
                ),
            },
            Err(_) => None,
        };
        let level = match requested {
            Some(level) if supported(level) => level,
            Some(_) => IsaLevel::Scalar,
            None => caps().best,
        };
        ActiveIsa { level, requested }
    })
}

/// Should [`crate::util::f16::round_f16_slice`] take the F16C path?
/// Requires the hardware bit, and `SAGE_ISA=scalar` forces the portable
/// (bit-identical) f16 conversion loop along with the scalar INT8
/// microkernels. Keyed on the *override*, not the detected INT8 tier:
/// an F16C-capable host without AVX2 keeps its hardware conversions.
pub fn f16c_enabled() -> bool {
    caps().f16c && active().requested != Some(IsaLevel::Scalar)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_names_round_trip() {
        for level in IsaLevel::ALL {
            assert_eq!(IsaLevel::from_name(level.name()), Some(level));
            assert_eq!(IsaLevel::from_name(&level.name().to_uppercase()), Some(level));
        }
        assert_eq!(IsaLevel::from_name("avx512"), None);
        assert_eq!(IsaLevel::from_name(""), None);
    }

    #[test]
    fn detection_is_coherent() {
        let caps = caps();
        assert!(supported(IsaLevel::Scalar));
        assert!(supported(caps.best), "the detected best tier must be supported");
        // the ladder never reports a wider tier without its narrower one
        if supported(IsaLevel::Vnni) {
            assert!(supported(IsaLevel::Avx2), "vnni implies avx2");
        }
    }

    #[test]
    fn active_tier_is_executable() {
        let act = active();
        assert!(supported(act.level), "active tier must be hardware-supported");
        if let Some(req) = act.requested {
            // an honored override is exact; an unsupported one clamps to scalar
            assert!(act.level == req || act.level == IsaLevel::Scalar);
        } else {
            assert_eq!(act.level, caps().best);
        }
    }
}
