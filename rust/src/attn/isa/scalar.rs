//! Portable reference microkernels — the numerics contract every SIMD
//! tier must reproduce bit-exactly. The INT8 kernels accumulate in i32
//! (associative, so any summation order is the same integer); the f32
//! kernels are strictly element-wise (one mul + one add per lane, never
//! fused), so vector reimplementations are IEEE-identical per element;
//! the fused f16 lanes perform per element exactly the operation
//! sequence of the `axpy_f32` + `round_f16` composition they replace.

use crate::util::f16::round_f16;

/// INT8 dot product with i32 accumulation — the mma(u8.u8.s32) primitive
/// (§4.3). Eight independent accumulator lanes let LLVM vectorize the
/// i8→i32 widening MACs (pmaddwd-shaped codegen on x86) even at this
/// portable tier.
pub(super) fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0i32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for i in 0..8 {
            lanes[i] += xa[i] as i32 * xb[i] as i32;
        }
    }
    let mut acc: i32 = lanes.iter().sum();
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        acc += *x as i32 * *y as i32;
    }
    acc
}

/// Score tile: `out[r*stride + c] = dot(q_row_r, k_row_c)` for a
/// `bq × bk` block of row-major (len-`d`) INT8 rows.
pub(super) fn qk_tile_i8(
    q: &[i8],
    k: &[i8],
    d: usize,
    bq: usize,
    bk: usize,
    out: &mut [i32],
    stride: usize,
) {
    debug_assert!(q.len() >= bq * d, "q block too short");
    debug_assert!(k.len() >= bk * d, "k block too short");
    debug_assert!(bq == 0 || out.len() >= (bq - 1) * stride + bk, "out tile too short");
    for r in 0..bq {
        let qr = &q[r * d..(r + 1) * d];
        let orow = &mut out[r * stride..r * stride + bk];
        for (c, o) in orow.iter_mut().enumerate() {
            *o = dot_i8(qr, &k[c * d..(c + 1) * d]);
        }
    }
}

/// INT8 P·V accumulation lane: `acc[i] += p * v[i]` in exact i32
/// (the per-row inner loop of the §4.3 INT8 P·V mode).
pub(super) fn pv_accum_i8(acc: &mut [i32], v: &[i8], p: i32) {
    debug_assert_eq!(acc.len(), v.len());
    for (a, &x) in acc.iter_mut().zip(v) {
        *a += p * x as i32;
    }
}

/// f32 axpy: `out[i] += a * x[i]`, element-wise, mul-then-add (no FMA
/// contraction) — the P·V accumulation step of the fp16/fp32 modes.
pub(super) fn axpy_f32(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &xv) in out.iter_mut().zip(x) {
        *o += a * xv;
    }
}

/// f32 rescale: `out[i] *= a` (the online-softmax α correction).
pub(super) fn scale_f32(out: &mut [f32], a: f32) {
    for o in out.iter_mut() {
        *o *= a;
    }
}

/// Fused α-rescale + f16 store: `out[i] = round_f16(out[i] * a)` — one
/// pass over the Fp16Accum accumulator where `scale_f32` +
/// `round_f16_slice` made two. Element-wise identical to that
/// composition (same mul, same round, per element).
pub(super) fn scale_round_f16(out: &mut [f32], a: f32) {
    for o in out.iter_mut() {
        *o = round_f16(*o * a);
    }
}

/// One fused contraction block of the fp16-accumulator P·V simulation
/// (§4.4): for each output channel, up to 16 `p·v` MACs accumulate in an
/// f32 register (mul-then-add in step order, skipping `p == 0.0` like
/// the axpy walk), the partial is rounded to f16 once, and the f16-held
/// accumulator absorbs it with one more round. Exactly the per-element
/// operation sequence of the unfused axpy-into-part / round(part) /
/// add / round(o) composition — in one pass over `o` instead of three.
pub(super) fn pv_f16_step(o: &mut [f32], p: &[f32], v: &[f32], d: usize) {
    debug_assert!(o.len() >= d, "accumulator shorter than head dim");
    debug_assert!(v.len() >= p.len() * d, "v tile shorter than steps × d");
    for (c, oc) in o.iter_mut().enumerate().take(d) {
        let mut acc = 0.0f32;
        for (t, &pt) in p.iter().enumerate() {
            if pt == 0.0 {
                continue;
            }
            acc += pt * v[t * d + c];
        }
        acc = round_f16(acc);
        *oc = round_f16(*oc + acc);
    }
}
