//! Portable reference microkernels — the numerics contract every SIMD
//! tier must reproduce bit-exactly. The INT8 kernels accumulate in i32
//! (associative, so any summation order is the same integer); the f32
//! kernels are strictly element-wise (one mul + one add per lane, never
//! fused), so vector reimplementations are IEEE-identical per element.

/// INT8 dot product with i32 accumulation — the mma(u8.u8.s32) primitive
/// (§4.3). Eight independent accumulator lanes let LLVM vectorize the
/// i8→i32 widening MACs (pmaddwd-shaped codegen on x86) even at this
/// portable tier.
pub(super) fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0i32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for i in 0..8 {
            lanes[i] += xa[i] as i32 * xb[i] as i32;
        }
    }
    let mut acc: i32 = lanes.iter().sum();
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        acc += *x as i32 * *y as i32;
    }
    acc
}

/// Score tile: `out[r*stride + c] = dot(q_row_r, k_row_c)` for a
/// `bq × bk` block of row-major (len-`d`) INT8 rows.
pub(super) fn qk_tile_i8(
    q: &[i8],
    k: &[i8],
    d: usize,
    bq: usize,
    bk: usize,
    out: &mut [i32],
    stride: usize,
) {
    debug_assert!(q.len() >= bq * d, "q block too short");
    debug_assert!(k.len() >= bk * d, "k block too short");
    debug_assert!(bq == 0 || out.len() >= (bq - 1) * stride + bk, "out tile too short");
    for r in 0..bq {
        let qr = &q[r * d..(r + 1) * d];
        let orow = &mut out[r * stride..r * stride + bk];
        for (c, o) in orow.iter_mut().enumerate() {
            *o = dot_i8(qr, &k[c * d..(c + 1) * d]);
        }
    }
}

/// INT8 P·V accumulation lane: `acc[i] += p * v[i]` in exact i32
/// (the per-row inner loop of the §4.3 INT8 P·V mode).
pub(super) fn pv_accum_i8(acc: &mut [i32], v: &[i8], p: i32) {
    debug_assert_eq!(acc.len(), v.len());
    for (a, &x) in acc.iter_mut().zip(v) {
        *a += p * x as i32;
    }
}

/// f32 axpy: `out[i] += a * x[i]`, element-wise, mul-then-add (no FMA
/// contraction) — the P·V accumulation step of the fp16/fp32 modes.
pub(super) fn axpy_f32(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &xv) in out.iter_mut().zip(x) {
        *o += a * xv;
    }
}

/// f32 rescale: `out[i] *= a` (the online-softmax α correction).
pub(super) fn scale_f32(out: &mut [f32], a: f32) {
    for o in out.iter_mut() {
        *o *= a;
    }
}
