//! AArch64 NEON microkernels (the `sdot` dot-product extension tier).
//!
//! `vdotq_s32` is natively signed×signed, so no bias trick is needed:
//! the i32 accumulation is exact and bit-identical to scalar by
//! associativity. The f32 kernels use explicit `vmulq`+`vaddq` (never
//! `vmlaq`/`fmla`, which would fuse the rounding) so each lane performs
//! the same two IEEE operations as the scalar loop.
//!
//! Safety: the `unsafe` `#[target_feature]` functions are only reachable
//! through the [`super::Kernels`] table that [`super::for_level`] hands
//! out behind [`super::cpu::supported`] runtime `dotprod` detection.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::*;

use super::cpu::{supported, IsaLevel};
use crate::util::f16::round_f16;

pub(super) fn dot_i8_neon(a: &[i8], b: &[i8]) -> i32 {
    debug_assert!(supported(IsaLevel::Neon), "neon kernel on an unsupported host");
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: reachable only via a table gated on runtime dotprod detection.
    unsafe { dot_i8_neon_imp(a, b) }
}

#[target_feature(enable = "neon", enable = "dotprod")]
unsafe fn dot_i8_neon_imp(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len();
    let nv = n - n % 16;
    let mut acc = vdupq_n_s32(0);
    let mut i = 0;
    while i < nv {
        acc = vdotq_s32(acc, vld1q_s8(a.as_ptr().add(i)), vld1q_s8(b.as_ptr().add(i)));
        i += 16;
    }
    let mut dot = vaddvq_s32(acc);
    while i < n {
        dot += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    dot
}

pub(super) fn qk_tile_i8_neon(
    q: &[i8],
    k: &[i8],
    d: usize,
    bq: usize,
    bk: usize,
    out: &mut [i32],
    stride: usize,
) {
    debug_assert!(supported(IsaLevel::Neon), "neon kernel on an unsupported host");
    debug_assert!(q.len() >= bq * d && k.len() >= bk * d);
    debug_assert!(bq == 0 || out.len() >= (bq - 1) * stride + bk);
    // SAFETY: reachable only via a table gated on runtime dotprod detection.
    unsafe { qk_tile_i8_neon_imp(q, k, d, bq, bk, out, stride) }
}

/// 4 Q-row accumulators share each K chunk load (the multi-accumulator
/// unrolling that amortizes K traffic across the Q block).
#[target_feature(enable = "neon", enable = "dotprod")]
unsafe fn qk_tile_i8_neon_imp(
    q: &[i8],
    k: &[i8],
    d: usize,
    bq: usize,
    bk: usize,
    out: &mut [i32],
    stride: usize,
) {
    let dv = d - d % 16;
    let mut r = 0;
    while r < bq {
        let rn = (r + 4).min(bq);
        for c in 0..bk {
            let kp = k.as_ptr().add(c * d);
            let mut acc = [vdupq_n_s32(0); 4];
            let mut j = 0;
            while j < dv {
                let kv = vld1q_s8(kp.add(j));
                for t in 0..rn - r {
                    let qv = vld1q_s8(q.as_ptr().add((r + t) * d + j));
                    acc[t] = vdotq_s32(acc[t], qv, kv);
                }
                j += 16;
            }
            for t in 0..rn - r {
                let mut dot = vaddvq_s32(acc[t]);
                for j in dv..d {
                    dot += q[(r + t) * d + j] as i32 * k[c * d + j] as i32;
                }
                out[(r + t) * stride + c] = dot;
            }
        }
        r = rn;
    }
}

pub(super) fn pv_accum_i8_neon(acc: &mut [i32], v: &[i8], p: i32) {
    debug_assert!(supported(IsaLevel::Neon), "neon kernel on an unsupported host");
    debug_assert_eq!(acc.len(), v.len());
    // SAFETY: reachable only via a table gated on runtime NEON detection.
    unsafe { pv_accum_i8_neon_imp(acc, v, p) }
}

#[target_feature(enable = "neon")]
unsafe fn pv_accum_i8_neon_imp(acc: &mut [i32], v: &[i8], p: i32) {
    let n = acc.len();
    let nv = n - n % 8;
    let pl = vdupq_n_s32(p);
    let mut i = 0;
    while i < nv {
        let v16 = vmovl_s8(vld1_s8(v.as_ptr().add(i)));
        let lo = vmovl_s16(vget_low_s16(v16));
        let hi = vmovl_s16(vget_high_s16(v16));
        let a0 = vld1q_s32(acc.as_ptr().add(i));
        let a1 = vld1q_s32(acc.as_ptr().add(i + 4));
        vst1q_s32(acc.as_mut_ptr().add(i), vaddq_s32(a0, vmulq_s32(lo, pl)));
        vst1q_s32(acc.as_mut_ptr().add(i + 4), vaddq_s32(a1, vmulq_s32(hi, pl)));
        i += 8;
    }
    while i < n {
        acc[i] += p * v[i] as i32;
        i += 1;
    }
}

pub(super) fn axpy_f32_neon(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert!(supported(IsaLevel::Neon), "neon kernel on an unsupported host");
    debug_assert_eq!(out.len(), x.len());
    // SAFETY: reachable only via a table gated on runtime NEON detection.
    unsafe { axpy_f32_neon_imp(out, x, a) }
}

#[target_feature(enable = "neon")]
unsafe fn axpy_f32_neon_imp(out: &mut [f32], x: &[f32], a: f32) {
    let n = out.len();
    let nv = n - n % 4;
    let av = vdupq_n_f32(a);
    let mut i = 0;
    while i < nv {
        let o = vld1q_f32(out.as_ptr().add(i));
        let xv = vld1q_f32(x.as_ptr().add(i));
        // explicit mul then add — vmlaq would contract to fma and break
        // bit-identity with the scalar reference
        vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(o, vmulq_f32(av, xv)));
        i += 4;
    }
    while i < n {
        out[i] += a * x[i];
        i += 1;
    }
}

pub(super) fn scale_f32_neon(out: &mut [f32], a: f32) {
    debug_assert!(supported(IsaLevel::Neon), "neon kernel on an unsupported host");
    // SAFETY: reachable only via a table gated on runtime NEON detection.
    unsafe { scale_f32_neon_imp(out, a) }
}

#[target_feature(enable = "neon")]
unsafe fn scale_f32_neon_imp(out: &mut [f32], a: f32) {
    let n = out.len();
    let nv = n - n % 4;
    let av = vdupq_n_f32(a);
    let mut i = 0;
    while i < nv {
        let o = vld1q_f32(out.as_ptr().add(i));
        vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(o, av));
        i += 4;
    }
    while i < n {
        out[i] *= a;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Fused fp16-accumulator lanes. The f16 NEON intrinsics (`float16x4_t`,
// `vcvt_f16_f32`) are still unstable in Rust, so the round-trip uses the
// software converter per lane — the MAC accumulation is still the fused
// register-blocked walk (one pass over `o` instead of three), which is
// where the win is.
// ---------------------------------------------------------------------------

pub(super) fn pv_f16_step_neon(o: &mut [f32], p: &[f32], v: &[f32], d: usize) {
    debug_assert!(supported(IsaLevel::Neon), "neon kernel on an unsupported host");
    debug_assert!(o.len() >= d && v.len() >= p.len() * d);
    // SAFETY: reachable only via a table gated on runtime NEON detection.
    unsafe { pv_f16_step_neon_imp(o, p, v, d) }
}

#[target_feature(enable = "neon")]
unsafe fn pv_f16_step_neon_imp(o: &mut [f32], p: &[f32], v: &[f32], d: usize) {
    let dv = d - d % 4;
    let mut buf = [0.0f32; 4];
    let mut c = 0;
    while c < dv {
        let mut acc = vdupq_n_f32(0.0);
        for (t, &pt) in p.iter().enumerate() {
            if pt == 0.0 {
                continue;
            }
            let vv = vld1q_f32(v.as_ptr().add(t * d + c));
            // explicit mul then add — vmlaq would contract to fma and
            // break bit-identity with the scalar reference
            acc = vaddq_f32(acc, vmulq_f32(vdupq_n_f32(pt), vv));
        }
        vst1q_f32(buf.as_mut_ptr(), acc);
        for (lane, &partial) in buf.iter().enumerate() {
            let oc = &mut o[c + lane];
            *oc = round_f16(*oc + round_f16(partial));
        }
        c += 4;
    }
    while c < d {
        let mut acc = 0.0f32;
        for (t, &pt) in p.iter().enumerate() {
            if pt != 0.0 {
                acc += pt * v[t * d + c];
            }
        }
        acc = round_f16(acc);
        o[c] = round_f16(o[c] + acc);
        c += 1;
    }
}

pub(super) fn scale_round_f16_neon(out: &mut [f32], a: f32) {
    debug_assert!(supported(IsaLevel::Neon), "neon kernel on an unsupported host");
    // the f16 store dominates and has no stable NEON round-trip; the
    // fused scalar pass (one mul + one round per element) is the win
    // over the old two-pass scale + slice-round
    super::scalar::scale_round_f16(out, a);
}
