//! `attn::isa` — runtime-dispatched SIMD microkernels for the INT8 hot
//! path, the CPU analogue of the paper's CUDA kernel work (§4.3: INT8
//! `mma(s8.s8.s32)` is what makes SageAttention fast; here the same dot
//! products hit `pmaddwd`/`vpdpbusd`/`sdot` instead of tensor cores).
//!
//! Structure:
//! * [`cpu`] — capability detection (`OnceLock`-cached) plus the
//!   `SAGE_ISA` override (`scalar|avx2|vnni|neon`).
//! * [`Kernels`] — one dispatch table per tier: [`dot_i8`] (the raw
//!   mma primitive), [`Kernels::qk_tile_i8`] (a whole BLOCK_Q×BLOCK_KV
//!   score tile per call, amortizing K loads across Q rows), and the
//!   P·V accumulation lanes (`pv_accum_i8`, `axpy_f32`, `scale_f32`).
//! * [`kernels`] — the table for the active tier (what
//!   `attn::plane` / `attn::prepared` call); [`for_level`] reaches a
//!   specific tier for differential tests and benches.
//!
//! **Bit-identity guarantee**: every tier returns exactly the scalar
//! reference's bits. INT8 kernels accumulate in i32 (associative — any
//! lane order gives the same integer); f32 kernels are element-wise
//! mul-then-add with FMA contraction explicitly avoided. The existing
//! plane/prepared bit-identity suites therefore pin all tiers at once,
//! and `tests/isa_differential.rs` fuzzes the microkernels directly.

pub mod cpu;

mod scalar;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

pub use cpu::{ActiveIsa, CpuCaps, IsaLevel};

/// `dot(a, b)` over INT8 with exact i32 accumulation.
pub type DotI8Fn = fn(&[i8], &[i8]) -> i32;
/// `(q, k, d, bq, bk, out, stride)`: `out[r*stride + c] = dot(q_r, k_c)`
/// for a `bq × bk` tile of row-major length-`d` INT8 rows.
pub type QkTileI8Fn = fn(&[i8], &[i8], usize, usize, usize, &mut [i32], usize);
/// `(acc, v, p)`: `acc[i] += p * v[i]` in exact i32.
pub type PvAccumI8Fn = fn(&mut [i32], &[i8], i32);
/// `(out, x, a)`: `out[i] += a * x[i]`, element-wise mul-then-add.
pub type AxpyF32Fn = fn(&mut [f32], &[f32], f32);
/// `(out, a)`: `out[i] *= a`.
pub type ScaleF32Fn = fn(&mut [f32], f32);

/// One tier's microkernel dispatch table. Tables are only handed out for
/// tiers the host supports ([`for_level`]), which is what makes the
/// `#[target_feature]` implementations behind these pointers sound.
pub struct Kernels {
    pub level: IsaLevel,
    pub dot_i8: DotI8Fn,
    pub qk_tile_i8: QkTileI8Fn,
    pub pv_accum_i8: PvAccumI8Fn,
    pub axpy_f32: AxpyF32Fn,
    pub scale_f32: ScaleF32Fn,
}

static SCALAR: Kernels = Kernels {
    level: IsaLevel::Scalar,
    dot_i8: scalar::dot_i8,
    qk_tile_i8: scalar::qk_tile_i8,
    pv_accum_i8: scalar::pv_accum_i8,
    axpy_f32: scalar::axpy_f32,
    scale_f32: scalar::scale_f32,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    level: IsaLevel::Avx2,
    dot_i8: x86::dot_i8_avx2,
    qk_tile_i8: x86::qk_tile_i8_avx2,
    pv_accum_i8: x86::pv_accum_i8_avx2,
    axpy_f32: x86::axpy_f32_avx,
    scale_f32: x86::scale_f32_avx,
};

// the VNNI tier upgrades the QKᵀ dot/tile; the P·V lanes (byte-widening
// multiplies and f32 axpy) have no VNNI-specific instruction and reuse
// the AVX2 implementations. Compiled only on rustc ≥ 1.89 (build.rs
// emits `sage_avx512` where the AVX-512 intrinsics are stable); older
// toolchains never detect `vnni`, so the table is never requested.
#[cfg(all(target_arch = "x86_64", sage_avx512))]
static VNNI: Kernels = Kernels {
    level: IsaLevel::Vnni,
    dot_i8: x86::dot_i8_vnni,
    qk_tile_i8: x86::qk_tile_i8_vnni,
    pv_accum_i8: x86::pv_accum_i8_avx2,
    axpy_f32: x86::axpy_f32_avx,
    scale_f32: x86::scale_f32_avx,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    level: IsaLevel::Neon,
    dot_i8: neon::dot_i8_neon,
    qk_tile_i8: neon::qk_tile_i8_neon,
    pv_accum_i8: neon::pv_accum_i8_neon,
    axpy_f32: neon::axpy_f32_neon,
    scale_f32: neon::scale_f32_neon,
};

/// The dispatch table for one specific tier, or `None` when this host
/// cannot execute it. `for_level(IsaLevel::Scalar)` always succeeds.
pub fn for_level(level: IsaLevel) -> Option<&'static Kernels> {
    if !cpu::supported(level) {
        return None;
    }
    match level {
        IsaLevel::Scalar => Some(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2 => Some(&AVX2),
        #[cfg(all(target_arch = "x86_64", sage_avx512))]
        IsaLevel::Vnni => Some(&VNNI),
        #[cfg(target_arch = "aarch64")]
        IsaLevel::Neon => Some(&NEON),
        _ => None,
    }
}

/// The active tier's dispatch table — what the plane kernels fetch once
/// per call. Resolved on first use from [`cpu::active`] (detection
/// clamped by `SAGE_ISA`).
pub fn kernels() -> &'static Kernels {
    static ACTIVE: std::sync::OnceLock<&'static Kernels> = std::sync::OnceLock::new();
    // `get_or_init` yields `&&'static Kernels`; deref to the inner ref
    *ACTIVE
        .get_or_init(|| for_level(cpu::active().level).expect("active ISA tier is host-supported"))
}

/// Dispatched INT8 dot product (convenience for per-pair call sites;
/// the tile kernels go through [`kernels`] directly).
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    (kernels().dot_i8)(a, b)
}

// The scalar-vs-SIMD differential contract (odd lengths, unaligned
// slices, remainder tails, stride gaps, f32 bit equality) is pinned
// once, in `tests/isa_differential.rs` — the unit tests here only cover
// table/dispatch coherence and the i32 overflow headroom.
#[cfg(test)]
mod tests {
    use super::*;

    /// Tables other than scalar that this host can execute.
    fn simd_tables() -> Vec<&'static Kernels> {
        IsaLevel::ALL
            .iter()
            .filter(|&&l| l != IsaLevel::Scalar)
            .filter_map(|&l| for_level(l))
            .collect()
    }

    #[test]
    fn active_table_matches_active_level() {
        assert_eq!(kernels().level, cpu::active().level);
        assert!(for_level(IsaLevel::Scalar).is_some(), "scalar table is unconditional");
        // dispatched convenience form agrees with the table
        let a: Vec<i8> = (-64..64).collect();
        let b: Vec<i8> = (0..128).map(|i| (i % 7 - 3) as i8).collect();
        assert_eq!(dot_i8(&a, &b), (kernels().dot_i8)(&a, &b));
    }

    #[test]
    fn dot_extremes_do_not_overflow_lanes() {
        // ±saturated inputs at a realistic head dim: |Σ| ≤ d·128² fits i32
        for kern in simd_tables() {
            let a = vec![-128i8; 256];
            let b = vec![127i8; 256];
            assert_eq!((kern.dot_i8)(&a, &b), 256 * -128 * 127, "{}", kern.level.name());
            assert_eq!((kern.dot_i8)(&a, &a), 256 * 128 * 128, "{}", kern.level.name());
        }
    }
}
