//! `attn::isa` — runtime-dispatched SIMD microkernels for the INT8 hot
//! path, the CPU analogue of the paper's CUDA kernel work (§4.3: INT8
//! `mma(s8.s8.s32)` is what makes SageAttention fast; here the same dot
//! products hit `pmaddwd`/`vpdpbusd`/`sdot` instead of tensor cores).
//!
//! Structure:
//! * [`cpu`] — capability detection (`OnceLock`-cached) plus the
//!   `SAGE_ISA` override (`scalar|avx2|vnni|neon`).
//! * [`Kernels`] — one dispatch table per tier: [`dot_i8`] (the raw
//!   mma primitive), [`Kernels::qk_tile_i8`] (a whole BLOCK_Q×BLOCK_KV
//!   score tile per call, amortizing K loads across Q rows), the INT8
//!   P·V lane (`pv_accum_i8`), the f32 lanes (`axpy_f32`, `scale_f32`;
//!   8-wide AVX, 16-wide AVX-512 on the VNNI tier, 4-wide NEON), and
//!   the fused fp16-accumulator lanes (`pv_f16_step`, a whole MMA_K
//!   contraction block with the f16 round-trip folded into the
//!   multiply-add, and `scale_round_f16`, the α-rescale with the f16
//!   store folded in) that `attn::pv` drives.
//! * [`kernels`] — the table for the active tier (what
//!   `attn::plane` / `attn::prepared` call); [`for_level`] reaches a
//!   specific tier for differential tests and benches.
//! * [`prefetch`] / [`prefetch_head`] — best-effort software prefetch
//!   (`prefetcht0` / `prfm pldl1keep`) for the paged-KV gather, where
//!   the next physical page is a pointer chase the hardware streamer
//!   cannot predict.
//!
//! **Bit-identity guarantee**: every tier returns exactly the scalar
//! reference's bits. INT8 kernels accumulate in i32 (associative — any
//! lane order gives the same integer); f32 kernels are element-wise
//! mul-then-add with FMA contraction explicitly avoided; the fused f16
//! lanes perform, per element, the same mul/add/round sequence as the
//! `axpy_f32` + `round_f16_slice` composition they replace (hardware
//! F16C rounding is pinned bit-for-bit against the software converter
//! in `util::f16`). The existing plane/prepared bit-identity suites
//! therefore pin all tiers at once, and `tests/isa_differential.rs`
//! fuzzes the microkernels directly.

pub mod cpu;

mod scalar;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

pub use cpu::{ActiveIsa, CpuCaps, IsaLevel};

/// `dot(a, b)` over INT8 with exact i32 accumulation.
pub type DotI8Fn = fn(&[i8], &[i8]) -> i32;
/// `(q, k, d, bq, bk, out, stride)`: `out[r*stride + c] = dot(q_r, k_c)`
/// for a `bq × bk` tile of row-major length-`d` INT8 rows.
pub type QkTileI8Fn = fn(&[i8], &[i8], usize, usize, usize, &mut [i32], usize);
/// `(acc, v, p)`: `acc[i] += p * v[i]` in exact i32.
pub type PvAccumI8Fn = fn(&mut [i32], &[i8], i32);
/// `(out, x, a)`: `out[i] += a * x[i]`, element-wise mul-then-add.
pub type AxpyF32Fn = fn(&mut [f32], &[f32], f32);
/// `(out, a)`: `out[i] *= a`.
pub type ScaleF32Fn = fn(&mut [f32], f32);
/// `(o, p, v, d)`: one fused MMA_K contraction block of the
/// fp16-accumulator P·V simulation. For every output channel `c < d`,
/// accumulate `Σ_t p[t]·v[t*d + c]` over the (≤ 16) steps in f32
/// registers — mul-then-add in `t` order, skipping `p[t] == 0.0` — then
/// round the partial to f16 once and round `o[c] + partial` back into
/// `o[c]`. Element-wise identical to axpy-into-part / round(part) /
/// add / round(o).
pub type PvF16StepFn = fn(&mut [f32], &[f32], &[f32], usize);
/// `(out, a)`: `out[i] = round_f16(out[i] * a)` — the online-softmax α
/// correction with the f16 store folded in (Fp16Accum keeps the
/// accumulator in f16 between tiles).
pub type ScaleRoundF16Fn = fn(&mut [f32], f32);

/// One tier's microkernel dispatch table. Tables are only handed out for
/// tiers the host supports ([`for_level`]), which is what makes the
/// `#[target_feature]` implementations behind these pointers sound.
///
/// Eight entries per tier: the QKᵀ lanes (`dot_i8`, `qk_tile_i8`), the
/// INT8 P·V lane (`pv_accum_i8`), the f32 lanes (`axpy_f32`,
/// `scale_f32`), the fused fp16-accumulator lanes (`pv_f16_step`,
/// `scale_round_f16`), and the advertised [`f32_width`](Self::f32_width).
pub struct Kernels {
    pub level: IsaLevel,
    pub dot_i8: DotI8Fn,
    pub qk_tile_i8: QkTileI8Fn,
    pub pv_accum_i8: PvAccumI8Fn,
    pub axpy_f32: AxpyF32Fn,
    pub scale_f32: ScaleF32Fn,
    pub pv_f16_step: PvF16StepFn,
    pub scale_round_f16: ScaleRoundF16Fn,
    /// f32 elements per vector op in this tier's `axpy_f32`/`scale_f32`
    /// lanes (1 scalar, 4 NEON, 8 AVX, 16 AVX-512).
    pub f32_width: usize,
}

impl Kernels {
    /// How this tier's fused `pv_f16_step` performs the f16 round-trip —
    /// hardware F16C conversions or the bit-identical software
    /// converter (`sage kernels` reporting; depends on runtime F16C
    /// detection and the `SAGE_ISA` override, hence not a table field).
    pub fn pv_f16_round_desc(&self) -> &'static str {
        match self.level {
            IsaLevel::Avx2 | IsaLevel::Vnni if cpu::f16c_enabled() => "fused (F16C round)",
            _ => "fused (software round)",
        }
    }
}

static SCALAR: Kernels = Kernels {
    level: IsaLevel::Scalar,
    dot_i8: scalar::dot_i8,
    qk_tile_i8: scalar::qk_tile_i8,
    pv_accum_i8: scalar::pv_accum_i8,
    axpy_f32: scalar::axpy_f32,
    scale_f32: scalar::scale_f32,
    pv_f16_step: scalar::pv_f16_step,
    scale_round_f16: scalar::scale_round_f16,
    f32_width: 1,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    level: IsaLevel::Avx2,
    dot_i8: x86::dot_i8_avx2,
    qk_tile_i8: x86::qk_tile_i8_avx2,
    pv_accum_i8: x86::pv_accum_i8_avx2,
    axpy_f32: x86::axpy_f32_avx,
    scale_f32: x86::scale_f32_avx,
    pv_f16_step: x86::pv_f16_step_avx,
    scale_round_f16: x86::scale_round_f16_avx,
    f32_width: 8,
};

// the VNNI tier upgrades the QKᵀ dot/tile with `vpdpbusd`, widens the
// f32 and fused-f16 lanes to 16 elements with AVX-512F (the byte-widening
// INT8 P·V multiply has no VNNI-specific instruction and stays on the
// AVX2 lane). Compiled only on rustc ≥ 1.89 (build.rs emits
// `sage_avx512` where the AVX-512 intrinsics are stable); older
// toolchains never detect `vnni`, so the table is never requested.
#[cfg(all(target_arch = "x86_64", sage_avx512))]
static VNNI: Kernels = Kernels {
    level: IsaLevel::Vnni,
    dot_i8: x86::dot_i8_vnni,
    qk_tile_i8: x86::qk_tile_i8_vnni,
    pv_accum_i8: x86::pv_accum_i8_avx2,
    axpy_f32: x86::axpy_f32_avx512,
    scale_f32: x86::scale_f32_avx512,
    pv_f16_step: x86::pv_f16_step_avx512,
    scale_round_f16: x86::scale_round_f16_avx512,
    f32_width: 16,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    level: IsaLevel::Neon,
    dot_i8: neon::dot_i8_neon,
    qk_tile_i8: neon::qk_tile_i8_neon,
    pv_accum_i8: neon::pv_accum_i8_neon,
    axpy_f32: neon::axpy_f32_neon,
    scale_f32: neon::scale_f32_neon,
    pv_f16_step: neon::pv_f16_step_neon,
    scale_round_f16: neon::scale_round_f16_neon,
    f32_width: 4,
};

/// The dispatch table for one specific tier, or `None` when this host
/// cannot execute it. `for_level(IsaLevel::Scalar)` always succeeds.
pub fn for_level(level: IsaLevel) -> Option<&'static Kernels> {
    if !cpu::supported(level) {
        return None;
    }
    match level {
        IsaLevel::Scalar => Some(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2 => Some(&AVX2),
        #[cfg(all(target_arch = "x86_64", sage_avx512))]
        IsaLevel::Vnni => Some(&VNNI),
        #[cfg(target_arch = "aarch64")]
        IsaLevel::Neon => Some(&NEON),
        _ => None,
    }
}

/// The active tier's dispatch table — what the plane kernels fetch once
/// per call. Resolved on first use from [`cpu::active`] (detection
/// clamped by `SAGE_ISA`).
pub fn kernels() -> &'static Kernels {
    static ACTIVE: std::sync::OnceLock<&'static Kernels> = std::sync::OnceLock::new();
    // `get_or_init` yields `&&'static Kernels`; deref to the inner ref
    *ACTIVE
        .get_or_init(|| for_level(cpu::active().level).expect("active ISA tier is host-supported"))
}

/// Dispatched INT8 dot product (convenience for per-pair call sites;
/// the tile kernels go through [`kernels`] directly).
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    (kernels().dot_i8)(a, b)
}

/// The prefetch instruction [`prefetch`] emits on this target (for
/// `sage kernels` reporting).
#[cfg(target_arch = "x86_64")]
pub const PREFETCH_DESC: &str = "prefetcht0";
#[cfg(target_arch = "aarch64")]
pub const PREFETCH_DESC: &str = "prfm pldl1keep";
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub const PREFETCH_DESC: &str = "none (portable no-op)";

/// Best-effort software prefetch of the cache line holding `p` into L1.
/// A pure scheduling hint: never faults (even on wild addresses), never
/// changes architectural state — a no-op on targets without one.
#[inline(always)]
pub fn prefetch<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetcht0 is a hint that cannot fault; SSE is baseline
    // on x86_64.
    unsafe {
        std::arch::x86_64::_mm_prefetch::<{ std::arch::x86_64::_MM_HINT_T0 }>(p as *const i8);
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: prfm is a hint that cannot fault and writes no registers.
    unsafe {
        std::arch::asm!(
            "prfm pldl1keep, [{0}]",
            in(reg) p as *const u8,
            options(nostack, preserves_flags),
        );
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let _ = p;
}

/// Cache lines touched by [`prefetch_head`]: enough to hide the
/// pointer-chase latency of jumping to the next physical KV page — the
/// hardware streamer takes over once the sequential row walk begins.
const PREFETCH_HEAD_LINES: usize = 8;

/// Prefetch the leading cache lines of a slice (up to
/// [`PREFETCH_HEAD_LINES`] × 64 bytes). Used by the paged-KV gather to
/// touch the *next* page's rows while the current tile computes; a
/// no-op for empty slices.
#[inline]
pub fn prefetch_head<T>(s: &[T]) {
    let bytes = std::mem::size_of_val(s).min(PREFETCH_HEAD_LINES * 64);
    let base = s.as_ptr() as *const u8;
    let mut off = 0;
    while off < bytes {
        // SAFETY: `off < bytes ≤ size_of_val(s)`, an in-bounds offset of
        // a live allocation (and prefetch tolerates any address anyway).
        prefetch(unsafe { base.add(off) });
        off += 64;
    }
}

// The scalar-vs-SIMD differential contract (odd lengths, unaligned
// slices, remainder tails, stride gaps, f32 bit equality) is pinned
// once, in `tests/isa_differential.rs` — the unit tests here only cover
// table/dispatch coherence and the i32 overflow headroom.
#[cfg(test)]
mod tests {
    use super::*;

    /// Tables other than scalar that this host can execute.
    fn simd_tables() -> Vec<&'static Kernels> {
        IsaLevel::ALL
            .iter()
            .filter(|&&l| l != IsaLevel::Scalar)
            .filter_map(|&l| for_level(l))
            .collect()
    }

    #[test]
    fn active_table_matches_active_level() {
        assert_eq!(kernels().level, cpu::active().level);
        assert!(for_level(IsaLevel::Scalar).is_some(), "scalar table is unconditional");
        // dispatched convenience form agrees with the table
        let a: Vec<i8> = (-64..64).collect();
        let b: Vec<i8> = (0..128).map(|i| (i % 7 - 3) as i8).collect();
        assert_eq!(dot_i8(&a, &b), (kernels().dot_i8)(&a, &b));
    }

    #[test]
    fn dot_extremes_do_not_overflow_lanes() {
        // ±saturated inputs at a realistic head dim: |Σ| ≤ d·128² fits i32
        for kern in simd_tables() {
            let a = vec![-128i8; 256];
            let b = vec![127i8; 256];
            assert_eq!((kern.dot_i8)(&a, &b), 256 * -128 * 127, "{}", kern.level.name());
            assert_eq!((kern.dot_i8)(&a, &a), 256 * 128 * 128, "{}", kern.level.name());
        }
    }

    #[test]
    fn fused_f16_lanes_agree_across_tables_and_prefetch_is_safe() {
        // table coherence smoke (the real fuzz — odd d, subnormals,
        // overflow edges — lives in tests/isa_differential.rs)
        let scalar = for_level(IsaLevel::Scalar).expect("scalar table");
        let d = 13;
        let p: Vec<f32> =
            (0..16).map(|i| if i % 4 == 0 { 0.0 } else { 0.25 * i as f32 }).collect();
        let v: Vec<f32> = (0..16 * d).map(|i| ((i % 29) as f32 - 14.0) * 0.5).collect();
        for kern in simd_tables() {
            let mut want = vec![1.0f32; d];
            let mut got = vec![1.0f32; d];
            (scalar.pv_f16_step)(&mut want, &p, &v, d);
            (kern.pv_f16_step)(&mut got, &p, &v, d);
            assert_eq!(want, got, "pv_f16_step {}", kern.level.name());
            (scalar.scale_round_f16)(&mut want, 0.731);
            (kern.scale_round_f16)(&mut got, 0.731);
            assert_eq!(want, got, "scale_round_f16 {}", kern.level.name());
            assert!(kern.f32_width >= 1);
            assert!(!kern.pv_f16_round_desc().is_empty());
        }
        // prefetch is a hint: any slice (including empty) is fine
        prefetch_head(&v);
        prefetch_head::<f32>(&[]);
    }
}
