//! `attn::pv` — the single P·V accumulation formulation shared by every
//! blocked kernel (`plane::sage_plane_opt`, `prepared::sage_plane_prepared`
//! and `prepared::sage_plane_paged` all used to carry private copies of
//! these inner loops).
//!
//! One BLOCK_KV tile of V plus one softmaxed P̃ row go in; the Q row's
//! output accumulator is α-rescaled and advanced by `P̃ · V` in the
//! numerics of the selected [`PvMode`](super::PvMode):
//!
//! * **Int8** (§4.3) — P̃ quantized to INT8 with the static 1/127 scale,
//!   i32 accumulation through the ISA `pv_accum_i8` lane, dequantized
//!   once per tile against V's per-channel scales.
//! * **Fp16Accum** (§4.4) — FP16 operands *and* an FP16-held accumulator:
//!   the contraction runs in [`MMA_K`]-step blocks through the fused
//!   `pv_f16_step` ISA lane, which keeps each block's partials in
//!   registers and folds the f16 round-trip into the multiply-add (one
//!   pass over the accumulator where the old composition made three:
//!   axpy into `part`, round `part`, add + round `o`).
//! * **Fp32Accum** — FP16 operands, fp32 accumulation (plain axpy).
//!
//! [`fp16_tile_unfused`] keeps the original three-pass composition as the
//! measurable "before" for the `pv_fp16` bench-hotpath lane and as the
//! differential-fuzz reference; the fused lanes are bit-identical to it
//! on every tier (see `tests/isa_differential.rs`).

use crate::quant;
use crate::util::f16::round_f16_slice;

use super::isa;

/// Contraction block length of the simulated FP16 tensor-core MMA: the
/// accumulator is rounded to f16 once every `MMA_K` P·V steps (matches
/// the reference `fp16_sim.py` and the paper's mma(f16.f16.f16.f16)
/// shape, §4.4).
pub const MMA_K: usize = 16;

/// One BLOCK_KV tile of V in the representation the active
/// [`PvMode`](super::PvMode) consumes: `v` holds `bk` row-major length-`d`
/// rows (tile-local — callers slice the plane, the prepared buffer or the
/// physical page), and Int8 carries the tile's per-channel dequant scales
/// (length `d`).
pub enum PvTile<'a> {
    /// INT8 V rows + per-channel scales (one scale vector per KV block).
    Int8 { v: &'a [i8], scales: &'a [f32] },
    /// fp16-rounded V rows, FP16-held accumulator.
    F16Accum { v: &'a [f32] },
    /// fp16-rounded V rows, fp32 accumulator.
    F32Accum { v: &'a [f32] },
}

/// Advance one Q row's output accumulator `o` (length `d`) by the tile's
/// `P̃ · V` contribution: `o = α·o + P̃ · V` in the tile's numerics.
/// `row` is the softmaxed P̃ row (length = tile rows `bk`); `p_i8`,
/// `p16` and `acc_i32` are caller-owned scratch (≥ `bk`, ≥ `bk`, ≥ `d`).
#[allow(clippy::too_many_arguments)]
pub fn accumulate(
    kern: &isa::Kernels,
    tile: &PvTile<'_>,
    o: &mut [f32],
    alpha: f32,
    row: &[f32],
    p_i8: &mut [i8],
    p16: &mut [f32],
    acc_i32: &mut [i32],
    d: usize,
) {
    let bk = row.len();
    match *tile {
        PvTile::Int8 { v, scales } => {
            // P̃ ∈ [0,1]: static per-block scale 1/127 (§4.3)
            let prow = &mut p_i8[..bk];
            for (pq, &p) in prow.iter_mut().zip(row.iter()) {
                *pq = (p * quant::INT8_MAX).round() as i8;
            }
            (kern.scale_f32)(o, alpha);
            // int32 accumulate over the block (row-major V walk through
            // the ISA lane), dequant once
            let acc32 = &mut acc_i32[..d];
            acc32.fill(0);
            for (bj, &pq) in prow.iter().enumerate() {
                if pq == 0 {
                    continue;
                }
                (kern.pv_accum_i8)(acc32, &v[bj * d..(bj + 1) * d], pq as i32);
            }
            for (oc, (&a, &vs)) in o.iter_mut().zip(acc32.iter().zip(&scales[..d])) {
                *oc += a as f32 * (1.0 / quant::INT8_MAX) * vs;
            }
        }
        PvTile::F16Accum { v } => {
            // α-rescale with the f16 store folded in (one pass), then the
            // fused MMA_K-blocked contraction; P̃ rounded once per row,
            // not per output channel
            (kern.scale_round_f16)(o, alpha);
            let p16b = &mut p16[..bk];
            p16b.copy_from_slice(row);
            round_f16_slice(p16b);
            fp16_tile_fused(kern, o, p16b, v, d);
        }
        PvTile::F32Accum { v } => {
            (kern.scale_f32)(o, alpha);
            let p16b = &mut p16[..bk];
            p16b.copy_from_slice(row);
            round_f16_slice(p16b);
            for (bj, &p) in p16b.iter().enumerate() {
                if p == 0.0 {
                    continue;
                }
                (kern.axpy_f32)(o, &v[bj * d..(bj + 1) * d], p);
            }
        }
    }
}

/// FP16-accumulator contraction of a whole tile through the fused
/// `pv_f16_step` ISA lane: `p` (already f16-rounded) is consumed in
/// [`MMA_K`]-step blocks, each block's partials held in registers and
/// rounded into the f16-held `o` exactly once.
pub fn fp16_tile_fused(kern: &isa::Kernels, o: &mut [f32], p: &[f32], v: &[f32], d: usize) {
    let bk = p.len();
    debug_assert!(o.len() >= d && v.len() >= bk * d);
    let mut bj = 0;
    while bj < bk {
        let je = (bj + MMA_K).min(bk);
        (kern.pv_f16_step)(&mut o[..d], &p[bj..je], &v[bj * d..je * d], d);
        bj = je;
    }
}

/// The original three-pass formulation the fused lane replaced: axpy each
/// nonzero `p` into `part`, round `part`, add into `o`, round `o` — once
/// per [`MMA_K`] block. Kept as the bit-identical reference the
/// differential fuzz pins `pv_f16_step` against, and as the "before" side
/// of the `pv_fp16` bench-hotpath lane. `part` is caller-owned scratch
/// (≥ `d`).
pub fn fp16_tile_unfused(
    kern: &isa::Kernels,
    o: &mut [f32],
    p: &[f32],
    v: &[f32],
    part: &mut [f32],
    d: usize,
) {
    let bk = p.len();
    debug_assert!(o.len() >= d && v.len() >= bk * d && part.len() >= d);
    let partd = &mut part[..d];
    let mut bj = 0;
    while bj < bk {
        let je = (bj + MMA_K).min(bk);
        partd.fill(0.0);
        for (t, &pt) in p.iter().enumerate().take(je).skip(bj) {
            if pt == 0.0 {
                continue;
            }
            (kern.axpy_f32)(partd, &v[t * d..(t + 1) * d], pt);
        }
        round_f16_slice(partd);
        for (oc, &pc) in o[..d].iter_mut().zip(partd.iter()) {
            *oc += pc;
        }
        round_f16_slice(&mut o[..d]);
        bj = je;
    }
}
