//! Kernel registry: every attention variant the crate ships, under a
//! stable name with a capability predicate and a per-plane entry point —
//! the CPU analogue of the reference repo's `core.py:sageattn` dispatch
//! table (SNIPPETS.md §"GPU Dispatch"). Name resolution ([`resolve`]),
//! auto-dispatch ([`auto`]), the CLI's `sage kernels` listing, the
//! adaptive calibrator's plan strings and the serving engine's plan
//! validation all read this table; a new kernel variant (e.g. the
//! SageAttention2 INT4 path on the roadmap) registers a row here to
//! become nameable/dispatchable, plus one arm in
//! `attn::api::run_plane_opt` for its parameterized forms. The `plane`
//! field is the variant's direct plane-level entry point (benches and
//! plane-granular callers; the tensor-level `AttnSpec` dispatches on
//! [`AttnImpl`] so parameterized implementations share the same path).

use crate::quant::{Fp8Format, Granularity};

use super::plane::{self, PlaneOpts, Scratch};
use super::{AttnImpl, SAGE_B, SAGE_T, SAGE_VB, SAGE_VT};

/// What a call site needs from a kernel — the capability-probe input.
/// Today's CPU kernels generalize over shape and masking, so the current
/// predicates only discriminate on `prepared` (and, via [`supports`], on
/// Q/K granularity); the remaining fields exist so future variants with
/// real constraints (e.g. an INT4 path limited to specific head dims)
/// can reject requests without changing any call site.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelReq {
    pub head_dim: usize,
    pub causal: bool,
    /// Sliding-window masking requested.
    pub window: bool,
    /// Grouped-query attention (n_kv_heads < n_heads) requested.
    pub gqa: bool,
    /// The call runs against [`crate::attn::PreparedKV`] state.
    pub prepared: bool,
}

/// Per-plane kernel entry point shared by every registry row:
/// `(scratch, q, k, v, n_q, n_kv, d, opts)` over contiguous (N, d)
/// planes. Reference-only kernels ignore the scratch.
pub type PlaneFn =
    fn(&mut Scratch, &[f32], &[f32], &[f32], usize, usize, usize, PlaneOpts) -> Vec<f32>;

/// One registered kernel variant.
pub struct KernelEntry {
    /// Stable lookup name (the paper's table row label).
    pub name: &'static str,
    pub imp: AttnImpl,
    pub summary: &'static str,
    /// Capability predicate — `auto` skips entries whose predicate
    /// rejects the request, and explicit selections fail fast.
    pub supports: fn(&KernelReq) -> bool,
    /// Per-plane kernel (the tensor-level dispatch lives in
    /// [`crate::attn::api::AttnSpec`]).
    pub plane: PlaneFn,
}

fn supports_any(_req: &KernelReq) -> bool {
    true
}

fn supports_unprepared(req: &KernelReq) -> bool {
    !req.prepared
}

fn plane_exact(
    _s: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    opts: PlaneOpts,
) -> Vec<f32> {
    plane::exact_plane_opt(q, k, v, n_q, n_kv, d, opts)
}

fn plane_online(
    s: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    opts: PlaneOpts,
) -> Vec<f32> {
    plane::online_plane_opt(s, q, k, v, n_q, n_kv, d, opts)
}

macro_rules! sage_plane_fn {
    ($name:ident, $imp:expr) => {
        fn $name(
            s: &mut Scratch,
            q: &[f32],
            k: &[f32],
            v: &[f32],
            n_q: usize,
            n_kv: usize,
            d: usize,
            opts: PlaneOpts,
        ) -> Vec<f32> {
            let AttnImpl::Sage { qk, pv, smooth_k } = $imp else {
                unreachable!("sage_plane_fn! takes a Sage implementation")
            };
            plane::sage_plane_opt(s, q, k, v, n_q, n_kv, d, qk, pv, smooth_k, opts)
        }
    };
}

sage_plane_fn!(plane_sage_t, SAGE_T);
sage_plane_fn!(plane_sage_b, SAGE_B);
sage_plane_fn!(plane_sage_vt, SAGE_VT);
sage_plane_fn!(plane_sage_vb, SAGE_VB);

fn plane_fp8(
    _s: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_q: usize,
    n_kv: usize,
    d: usize,
    opts: PlaneOpts,
) -> Vec<f32> {
    plane::fp8_plane_opt(q, k, v, n_q, n_kv, d, Fp8Format::E4M3, Fp8Format::E4M3, opts)
}

/// The registered kernels, in `auto`-dispatch priority order: the
/// paper's plug-and-play default (SageAttn-B) first, then the other
/// Table-6 variants, then the full-precision and FP8 baselines.
pub static REGISTRY: &[KernelEntry] = &[
    KernelEntry {
        name: "SageAttn-B",
        imp: SAGE_B,
        summary: "INT8 QK per-block + smooth-K + FP16-accum PV (the plug-and-play default)",
        supports: supports_any,
        plane: plane_sage_b,
    },
    KernelEntry {
        name: "SageAttn-T",
        imp: SAGE_T,
        summary: "INT8 QK per-token + smooth-K + FP16-accum PV",
        supports: supports_any,
        plane: plane_sage_t,
    },
    KernelEntry {
        name: "SageAttn-vB",
        imp: SAGE_VB,
        summary: "INT8 QK per-block + smooth-K + INT8 PV (fastest, needs §4.5 calibration)",
        supports: supports_any,
        plane: plane_sage_vb,
    },
    KernelEntry {
        name: "SageAttn-vT",
        imp: SAGE_VT,
        summary: "INT8 QK per-token + smooth-K + INT8 PV",
        supports: supports_any,
        plane: plane_sage_vt,
    },
    KernelEntry {
        name: "online",
        imp: AttnImpl::OnlineFp32,
        summary: "FlashAttention-2 fp32 tiling (full-precision speed baseline)",
        supports: supports_any,
        plane: plane_online,
    },
    KernelEntry {
        name: "exact",
        imp: AttnImpl::Exact,
        summary: "exact fp32 softmax(QK^T/sqrt(d))V (accuracy gold standard)",
        supports: supports_any,
        plane: plane_exact,
    },
    KernelEntry {
        name: "fa3-fp8",
        imp: AttnImpl::Fp8 { qk: Fp8Format::E4M3, pv: Fp8Format::E4M3 },
        summary: "FlashAttention3-style all-FP8 baseline (no PreparedKV path)",
        supports: supports_unprepared,
        plane: plane_fp8,
    },
];

/// All registered kernels (stable order: `auto` priority).
pub fn entries() -> &'static [KernelEntry] {
    REGISTRY
}

/// Look up a registry row by its stable name.
pub fn find(name: &str) -> Option<&'static KernelEntry> {
    REGISTRY.iter().find(|e| e.name == name)
}

/// Resolve a kernel name to an implementation: registry rows (including
/// aliases like `fa3-fp8`) first, then the structured [`AttnImpl`] name
/// grammar (`SageAttn-+fp32accB64-nosmooth`, `fp8(E4M3,E5M2)`, …) — the
/// true inverse of [`AttnImpl::name`]. This is the single resolver the
/// CLI, the adaptive calibrator's plan strings and `AttnSpec::by_name`
/// share.
pub fn resolve(name: &str) -> Option<AttnImpl> {
    find(name).map(|e| e.imp).or_else(|| AttnImpl::by_name(name))
}

/// `core.py:sageattn`-style auto dispatch: the first registry row whose
/// capability predicate accepts the request.
pub fn auto(req: &KernelReq) -> Option<&'static KernelEntry> {
    REGISTRY.iter().find(|e| (e.supports)(req) && supports(&e.imp, req))
}

/// Capability check covering parameterized implementations that aren't
/// registry rows (custom block sizes, granularities, FP8 formats).
pub fn supports(imp: &AttnImpl, req: &KernelReq) -> bool {
    match imp {
        // per-channel Q/K scales cannot dequantize inside the tiled
        // kernel (§4.3)
        AttnImpl::Sage { qk: Granularity::PerChannel, .. } => false,
        // a per-tensor scale covers the whole plane, so appending rows
        // would requantize the entire prefix — exactly what PreparedKV
        // exists to avoid
        AttnImpl::Sage { qk: Granularity::PerTensor, .. } => !req.prepared,
        AttnImpl::Sage { .. } => true,
        // the FP8 baseline has no quantize-once state (per-token FP8
        // scales are recomputed per call)
        AttnImpl::Fp8 { .. } => !req.prepared,
        // fp32 references run off the PreparedKV raw-row fallback
        AttnImpl::Exact | AttnImpl::OnlineFp32 => true,
    }
}

/// Serving-plan families (the artifact name prefixes `fp`/`sage`/
/// `adaptive`) → the registry row each family's kernels lower to. The
/// engine validates its `--plan` flag through this instead of failing
/// later on a missing artifact.
pub fn plan_entry(plan: &str) -> Option<&'static KernelEntry> {
    let name = match plan {
        "fp" => "online",
        // "adaptive" refines -B per layer (§4.5) but lowers from the
        // same kernel family
        "sage" | "adaptive" => "SageAttn-B",
        _ => return None,
    };
    find(name)
}

/// Registered names, comma-separated (for error messages and usage text).
pub fn known_names() -> String {
    REGISTRY.iter().map(|e| e.name).collect::<Vec<_>>().join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::PvMode;
    use crate::synth::{make_qkv, Profile};

    #[test]
    fn every_entry_resolves_and_runs() {
        let (q, k, v) = make_qkv(41, [1, 1, 96, 32], Profile::llama_like());
        let mut scratch = Scratch::new();
        for e in entries() {
            assert_eq!(resolve(e.name).as_ref(), Some(&e.imp), "{}", e.name);
            let out = (e.plane)(
                &mut scratch, &q.data, &k.data, &v.data, 96, 96, 32,
                PlaneOpts::causal(false),
            );
            assert_eq!(out.len(), 96 * 32);
            assert!(out.iter().all(|x| x.is_finite()), "{}", e.name);
        }
    }

    #[test]
    fn auto_prefers_the_sage_default() {
        let req = KernelReq { head_dim: 64, ..Default::default() };
        assert_eq!(auto(&req).unwrap().name, "SageAttn-B");
        // a prepared request must skip prepared-incapable rows but still
        // land on the default (which supports PreparedKV)
        let prep = KernelReq { head_dim: 64, prepared: true, ..Default::default() };
        assert_eq!(auto(&prep).unwrap().name, "SageAttn-B");
    }

    #[test]
    fn capability_checks() {
        let prep = KernelReq { prepared: true, ..Default::default() };
        let plain = KernelReq::default();
        let fp8 = AttnImpl::Fp8 { qk: Fp8Format::E4M3, pv: Fp8Format::E4M3 };
        assert!(supports(&fp8, &plain) && !supports(&fp8, &prep));
        let per_tensor = AttnImpl::Sage {
            qk: Granularity::PerTensor,
            pv: PvMode::Fp16Accum,
            smooth_k: true,
        };
        assert!(supports(&per_tensor, &plain) && !supports(&per_tensor, &prep));
        let per_chan = AttnImpl::Sage {
            qk: Granularity::PerChannel,
            pv: PvMode::Fp16Accum,
            smooth_k: true,
        };
        assert!(!supports(&per_chan, &plain));
        assert!(supports(&SAGE_B, &prep));
    }

    #[test]
    fn plan_families_map_to_registry_rows() {
        assert_eq!(plan_entry("fp").unwrap().name, "online");
        assert_eq!(plan_entry("sage").unwrap().name, "SageAttn-B");
        assert_eq!(plan_entry("adaptive").unwrap().name, "SageAttn-B");
        assert!(plan_entry("nope").is_none());
        assert!(known_names().contains("SageAttn-vB"));
    }
}
