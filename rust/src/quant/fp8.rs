//! FP8 rounding simulation (OCP E4M3 and E5M2), used to reproduce the
//! paper's FlashAttention3-FP8 baseline rows (Tables 1, 2, 3, 17, 18).
//!
//! `round()` maps an f32 to the nearest representable value of the format
//! (round-to-nearest-even), saturating at the max finite value the way
//! tensor-core conversions with saturation do.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fp8Format {
    /// E4M3 (fn variant): 4 exponent bits, 3 mantissa bits, bias 7,
    /// max normal 448, no infinity.
    E4M3,
    /// E5M2: 5 exponent bits, 2 mantissa bits, bias 15, max normal 57344.
    E5M2,
}

impl Fp8Format {
    pub fn max_value(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }

    fn mantissa_bits(self) -> i32 {
        match self {
            Fp8Format::E4M3 => 3,
            Fp8Format::E5M2 => 2,
        }
    }

    /// Minimum normal exponent (unbiased).
    fn min_exp(self) -> i32 {
        match self {
            Fp8Format::E4M3 => -6,
            Fp8Format::E5M2 => -14,
        }
    }

    /// Round an f32 to the nearest value representable in this format.
    pub fn round(self, x: f32) -> f32 {
        if x == 0.0 || x.is_nan() {
            return x;
        }
        let sign = x.signum();
        let a = x.abs();
        let fmax = self.max_value();
        if a >= fmax {
            return sign * fmax; // saturate
        }
        let mbits = self.mantissa_bits();
        // exponent of the value's binade, clamped at the subnormal floor
        let e = (a.log2().floor() as i32).max(self.min_exp());
        // spacing between representable values in this binade
        let quantum = (e - mbits) as f32;
        let q = f32::powi(2.0, quantum as i32);
        let n = a / q;
        // round half to even
        let r = n.round();
        let rounded = if (n - n.floor() - 0.5).abs() < 1e-6 {
            let fl = n.floor();
            if (fl as i64) % 2 == 0 {
                fl
            } else {
                fl + 1.0
            }
        } else {
            r
        };
        (sign * rounded * q).clamp(-fmax, fmax)
    }

    pub fn name(self) -> &'static str {
        match self {
            Fp8Format::E4M3 => "E4M3",
            Fp8Format::E5M2 => "E5M2",
        }
    }

    /// Inverse of [`Fp8Format::name`] (case-insensitive, so CLI kernel
    /// names like `fp8(e4m3,e4m3)` also resolve).
    pub fn by_name(name: &str) -> Option<Fp8Format> {
        match name.to_ascii_uppercase().as_str() {
            "E4M3" => Some(Fp8Format::E4M3),
            "E5M2" => Some(Fp8Format::E5M2),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_known_grid() {
        let f = Fp8Format::E4M3;
        // values exactly representable must be fixed points
        for v in [1.0f32, 1.125, 1.25, 1.875, 2.0, 448.0, 0.015625, -3.5] {
            assert_eq!(f.round(v), v, "{v} should be representable");
        }
        // 1.0625 is halfway between 1.0 and 1.125 -> ties-to-even -> 1.0
        assert_eq!(f.round(1.0625), 1.0);
        assert_eq!(f.round(1.07), 1.125);
    }

    #[test]
    fn e5m2_known_grid() {
        let f = Fp8Format::E5M2;
        for v in [1.0f32, 1.25, 1.5, 1.75, 2.0, 57344.0, -6.0] {
            assert_eq!(f.round(v), v, "{v} should be representable");
        }
        assert_eq!(f.round(1.1), 1.0);
        assert_eq!(f.round(1.4), 1.5);
    }

    #[test]
    fn saturation() {
        assert_eq!(Fp8Format::E4M3.round(1e9), 448.0);
        assert_eq!(Fp8Format::E4M3.round(-1e9), -448.0);
        assert_eq!(Fp8Format::E5M2.round(1e9), 57344.0);
    }

    #[test]
    fn subnormals() {
        // E4M3 smallest subnormal = 2^-9 = 0.001953125
        let f = Fp8Format::E4M3;
        let tiny = f32::powi(2.0, -9);
        assert_eq!(f.round(tiny), tiny);
        assert_eq!(f.round(tiny * 0.4), 0.0);
    }

    #[test]
    fn monotone_rounding() {
        let f = Fp8Format::E4M3;
        let mut prev = f.round(-500.0);
        let mut x = -500.0f32;
        while x < 500.0 {
            let r = f.round(x);
            assert!(r >= prev - 1e-6, "non-monotone at {x}: {prev} -> {r}");
            prev = r;
            x += 0.37;
        }
    }

    #[test]
    fn e4m3_coarser_than_e5m2_near_max_range() {
        // E5M2 has wider range; E4M3 more mantissa precision at moderate values
        let f43 = Fp8Format::E4M3;
        let f52 = Fp8Format::E5M2;
        let x = 3.3f32;
        assert!((f43.round(x) - x).abs() <= (f52.round(x) - x).abs());
    }
}
