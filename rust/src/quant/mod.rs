//! Rust mirror of the paper's quantizers (§3.2, §4.3), the smooth-K
//! transform (§4.2), and FP8 (E4M3/E5M2) rounding simulation for the
//! FlashAttention3-style baselines. Operates on (rows, cols) row-major
//! slabs — one (batch, head) plane of a (B, H, N, d) tensor.

pub mod fp8;

pub use fp8::Fp8Format;

pub const INT8_MAX: f32 = 127.0;
/// INT4 range (paper §6 future work / SageAttention2): [-7, +7].
pub const INT4_MAX: f32 = 7.0;
pub(crate) const EPS: f32 = 1e-8;

/// Quantization granularity for Q/K (paper Table 6 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One scale for the whole plane — cheapest dequant, worst outlier
    /// robustness (Table 1 "per-tensor" rows).
    PerTensor,
    /// One scale per token row — δ = max|row|/127 (§3.2; SageAttn-T).
    PerToken,
    /// One scale per block of consecutive token rows, matching the kernel's
    /// Q/K tile height so dequant is a single broadcast scalar per tile
    /// (§4.3 point 1; SageAttn-B with block = 128).
    PerBlock(usize),
    /// One scale per channel column — infeasible for Q/K inside the tiled
    /// kernel (§4.3) but exactly right for V in the -vT/-vB variants.
    PerChannel,
}

/// An INT8-quantized (rows, cols) plane with per-row scales (per-channel
/// quantization stores per-column scales instead; see `scale_axis`).
#[derive(Clone, Debug)]
pub struct QuantizedPlane {
    pub data: Vec<i8>,
    /// Per-row scales (len = rows) for token/block/tensor granularity
    /// (tensor granularity stores the same value in every slot), or
    /// per-column scales (len = cols) for `Granularity::PerChannel`.
    pub scales: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    pub granularity: Granularity,
}

impl QuantizedPlane {
    /// Dequantize back to f32 (ψ⁻¹).
    pub fn dequant(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        match self.granularity {
            Granularity::PerChannel => {
                for r in 0..self.rows {
                    for c in 0..self.cols {
                        out[r * self.cols + c] =
                            self.data[r * self.cols + c] as f32 * self.scales[c];
                    }
                }
            }
            _ => {
                for r in 0..self.rows {
                    let s = self.scales[r];
                    for c in 0..self.cols {
                        out[r * self.cols + c] = self.data[r * self.cols + c] as f32 * s;
                    }
                }
            }
        }
        out
    }
}

pub(crate) fn amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(EPS)
}

fn quantize_rows_into(x: &[f32], rows: usize, cols: usize, row_scale: &[f32], out: &mut Vec<i8>) {
    out.clear();
    out.reserve(rows * cols);
    for r in 0..rows {
        let inv = 1.0 / row_scale[r];
        for c in 0..cols {
            let q = (x[r * cols + c] * inv).round();
            out.push(q.clamp(-INT8_MAX, INT8_MAX) as i8);
        }
    }
}

/// ψ per-token into caller-owned buffers: one scale per row
/// (δ = max|row| / 127). `data`/`scales` are cleared and refilled, so
/// their capacity is retained across planes (the hot path's
/// zero-allocation contract; see [`crate::attn::Scratch`]).
pub fn quant_per_token_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    data: &mut Vec<i8>,
    scales: &mut Vec<f32>,
) {
    scales.clear();
    scales.extend((0..rows).map(|r| amax(&x[r * cols..(r + 1) * cols]) / INT8_MAX));
    quantize_rows_into(x, rows, cols, scales, data);
}

/// ψ per-token: one scale per row (δ = max|row| / 127).
pub fn quant_per_token(x: &[f32], rows: usize, cols: usize) -> QuantizedPlane {
    let (mut data, mut scales) = (Vec::new(), Vec::new());
    quant_per_token_into(x, rows, cols, &mut data, &mut scales);
    QuantizedPlane { data, scales, rows, cols, granularity: Granularity::PerToken }
}

/// ψ per-block into caller-owned buffers: one scale per `block`
/// consecutive rows, materialized per-row (block-constant) so consumers
/// are granularity-agnostic.
pub fn quant_per_block_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    block: usize,
    data: &mut Vec<i8>,
    scales: &mut Vec<f32>,
) {
    assert!(block > 0, "per-block quantization needs a non-zero block");
    scales.clear();
    scales.resize(rows, 0.0);
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + block).min(rows);
        let s = amax(&x[r0 * cols..r1 * cols]) / INT8_MAX;
        scales[r0..r1].fill(s);
        r0 = r1;
    }
    quantize_rows_into(x, rows, cols, scales, data);
}

/// ψ per-block: one scale per `block` consecutive rows.
pub fn quant_per_block(x: &[f32], rows: usize, cols: usize, block: usize) -> QuantizedPlane {
    let (mut data, mut scales) = (Vec::new(), Vec::new());
    quant_per_block_into(x, rows, cols, block, &mut data, &mut scales);
    QuantizedPlane { data, scales, rows, cols, granularity: Granularity::PerBlock(block) }
}

/// ψ per-tensor into caller-owned buffers: a single scale (stored per-row
/// for uniform consumption).
pub fn quant_per_tensor_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    data: &mut Vec<i8>,
    scales: &mut Vec<f32>,
) {
    let s = amax(x) / INT8_MAX;
    scales.clear();
    scales.resize(rows, s);
    quantize_rows_into(x, rows, cols, scales, data);
}

/// ψ per-tensor: a single scale (stored per-row for uniform consumption).
pub fn quant_per_tensor(x: &[f32], rows: usize, cols: usize) -> QuantizedPlane {
    let (mut data, mut scales) = (Vec::new(), Vec::new());
    quant_per_tensor_into(x, rows, cols, &mut data, &mut scales);
    QuantizedPlane { data, scales, rows, cols, granularity: Granularity::PerTensor }
}

/// ψ per-channel into caller-owned buffers: one scale per column (V in
/// the -vT/-vB kernels); `scales` ends with length `cols`.
pub fn quant_per_channel_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    data: &mut Vec<i8>,
    scales: &mut Vec<f32>,
) {
    scales.clear();
    scales.resize(cols, EPS);
    for r in 0..rows {
        for c in 0..cols {
            scales[c] = scales[c].max(x[r * cols + c].abs());
        }
    }
    for s in scales.iter_mut() {
        *s /= INT8_MAX;
    }
    data.clear();
    data.reserve(rows * cols);
    for r in 0..rows {
        for c in 0..cols {
            let q = (x[r * cols + c] / scales[c]).round();
            data.push(q.clamp(-INT8_MAX, INT8_MAX) as i8);
        }
    }
}

/// ψ per-channel: one scale per column (V in the -vT/-vB kernels).
pub fn quant_per_channel(x: &[f32], rows: usize, cols: usize) -> QuantizedPlane {
    let (mut data, mut scales) = (Vec::new(), Vec::new());
    quant_per_channel_into(x, rows, cols, &mut data, &mut scales);
    QuantizedPlane { data, scales, rows, cols, granularity: Granularity::PerChannel }
}

/// Quantize a (rows, cols) plane to INT8 at the chosen granularity —
/// the ψ transform of paper §3.2 / Table 6.
///
/// ```
/// use sageattention::quant::{quantize, quantize_into, Granularity};
///
/// // a 2×4 plane (two tokens, four channels)
/// let x = vec![0.5, -1.0, 2.0, -4.0, 0.25, 0.5, -0.125, 1.0];
/// let q = quantize(&x, 2, 4, Granularity::PerToken);
/// assert_eq!(q.scales.len(), 2); // one scale per token row
///
/// // the round-trip error is bounded by half a quantization step
/// let back = q.dequant();
/// for r in 0..2 {
///     for c in 0..4 {
///         let err = (x[r * 4 + c] - back[r * 4 + c]).abs();
///         assert!(err <= 0.5 * q.scales[r] + 1e-6);
///     }
/// }
///
/// // the hot path reuses caller-owned buffers instead (zero allocation
/// // once the capacity is warm) — bit-identical to the allocating form
/// let (mut data, mut scales) = (Vec::new(), Vec::new());
/// quantize_into(&x, 2, 4, Granularity::PerToken, &mut data, &mut scales);
/// assert_eq!(data, q.data);
/// assert_eq!(scales, q.scales);
/// ```
pub fn quantize(x: &[f32], rows: usize, cols: usize, g: Granularity) -> QuantizedPlane {
    let (mut data, mut scales) = (Vec::new(), Vec::new());
    quantize_into(x, rows, cols, g, &mut data, &mut scales);
    QuantizedPlane { data, scales, rows, cols, granularity: g }
}

/// [`quantize`] into caller-owned buffers: `data` and `scales` are
/// cleared and refilled (capacity retained across planes), producing
/// bit-identical results to the allocating form. This is how the blocked
/// kernels keep their per-plane `QuantizedPlane` allocations inside
/// [`crate::attn::Scratch`].
pub fn quantize_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    g: Granularity,
    data: &mut Vec<i8>,
    scales: &mut Vec<f32>,
) {
    match g {
        Granularity::PerTensor => quant_per_tensor_into(x, rows, cols, data, scales),
        Granularity::PerToken => quant_per_token_into(x, rows, cols, data, scales),
        Granularity::PerBlock(b) => quant_per_block_into(x, rows, cols, b, data, scales),
        Granularity::PerChannel => quant_per_channel_into(x, rows, cols, data, scales),
    }
}

/// γ(K) = K − mean(K): subtract the per-channel mean over the token axis
/// (paper §4.2). Returns the smoothed plane and the removed mean (len cols).
pub fn smooth_k(k: &[f32], rows: usize, cols: usize) -> (Vec<f32>, Vec<f32>) {
    let (mut out, mut mean) = (Vec::new(), Vec::new());
    smooth_k_into(k, rows, cols, &mut out, &mut mean);
    (out, mean)
}

/// [`smooth_k`] into caller-owned buffers (the hot path's zero-allocation
/// variant: `out`/`mean` retain their capacity across planes). `out` ends
/// with the smoothed plane (len rows·cols), `mean` with the removed
/// per-channel mean (len cols). Bit-identical to [`smooth_k`].
pub fn smooth_k_into(
    k: &[f32],
    rows: usize,
    cols: usize,
    out: &mut Vec<f32>,
    mean: &mut Vec<f32>,
) {
    mean.clear();
    mean.resize(cols, 0.0);
    for r in 0..rows {
        for c in 0..cols {
            mean[c] += k[r * cols + c];
        }
    }
    for m in mean.iter_mut() {
        *m /= rows as f32;
    }
    out.clear();
    out.reserve(rows * cols);
    for r in 0..rows {
        for c in 0..cols {
            out.push(k[r * cols + c] - mean[c]);
        }
    }
}

/// Quantize-dequantize through a numeric format (the accuracy-table
/// sweeps of Tables 2, 3, 17, 18).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FakeQuant {
    /// Identity — keep fp32 (the full-precision reference rows).
    None,
    /// Round through IEEE binary16 (the FP16 operand rows of Table 3).
    Fp16,
    /// INT8 at the given granularity (§3.2's ψ, then ψ⁻¹).
    Int8(Granularity),
    /// 4-bit signed integers — the paper's future-work direction
    /// (SageAttention2 ships this with per-thread granularity + Q
    /// smoothing; here it quantifies how far plain INT4 falls short).
    Int4(Granularity),
    /// FP8, per-token scaled to the format's max value the way
    /// FlashAttention3's quantized mode does (Tables 1/2/3 baselines).
    Fp8(Fp8Format),
}

pub fn fake_quant(x: &[f32], rows: usize, cols: usize, kind: FakeQuant) -> Vec<f32> {
    match kind {
        FakeQuant::None => x.to_vec(),
        FakeQuant::Fp16 => x.iter().map(|&v| crate::util::f16::round_f16(v)).collect(),
        FakeQuant::Int8(g) => quantize(x, rows, cols, g).dequant(),
        FakeQuant::Int4(g) => {
            // reuse the int8 machinery with a 4-bit clamp: scale by
            // max/7, round, clamp to [-7, 7]
            let q8 = quantize(x, rows, cols, g);
            let rescale = INT4_MAX / INT8_MAX;
            let mut out = q8.dequant();
            match q8.granularity {
                Granularity::PerChannel => {
                    for r in 0..rows {
                        for c in 0..cols {
                            let s4 = q8.scales[c] / rescale;
                            out[r * cols + c] =
                                (x[r * cols + c] / s4).round().clamp(-INT4_MAX, INT4_MAX)
                                    * s4;
                        }
                    }
                }
                _ => {
                    for r in 0..rows {
                        let s4 = q8.scales[r] / rescale;
                        for c in 0..cols {
                            out[r * cols + c] =
                                (x[r * cols + c] / s4).round().clamp(-INT4_MAX, INT4_MAX)
                                    * s4;
                        }
                    }
                }
            }
            out
        }
        FakeQuant::Fp8(fmt) => {
            let fmax = fmt.max_value();
            let mut out = vec![0.0f32; rows * cols];
            for r in 0..rows {
                let row = &x[r * cols..(r + 1) * cols];
                let scale = amax(row) / fmax;
                for (c, &v) in row.iter().enumerate() {
                    out[r * cols + c] = fmt.round(v / scale) * scale;
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_plane(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg32::seeded(seed);
        (0..rows * cols).map(|_| rng.normal() * 3.0).collect()
    }

    #[test]
    fn per_token_roundtrip_error_bounded() {
        let (rows, cols) = (37, 64);
        let x = make_plane(rows, cols, 1);
        let q = quant_per_token(&x, rows, cols);
        let deq = q.dequant();
        for r in 0..rows {
            let scale = q.scales[r];
            for c in 0..cols {
                let err = (x[r * cols + c] - deq[r * cols + c]).abs();
                assert!(err <= 0.5 * scale + 1e-6, "err {err} scale {scale}");
            }
        }
    }

    #[test]
    fn per_block_scales_block_constant() {
        let (rows, cols) = (100, 16);
        let x = make_plane(rows, cols, 2);
        let q = quant_per_block(&x, rows, cols, 32);
        for r in 0..rows {
            assert_eq!(q.scales[r], q.scales[(r / 32) * 32]);
        }
    }

    #[test]
    fn per_channel_outlier_isolated() {
        // a huge channel must not degrade other channels' precision
        let (rows, cols) = (64, 8);
        let mut x = make_plane(rows, cols, 3);
        for r in 0..rows {
            x[r * cols] = 1000.0 + r as f32; // channel 0 outlier
        }
        let q = quant_per_channel(&x, rows, cols);
        let deq = q.dequant();
        for r in 0..rows {
            for c in 1..cols {
                let err = (x[r * cols + c] - deq[r * cols + c]).abs();
                assert!(err <= 0.5 * q.scales[c] + 1e-6);
            }
        }
    }

    #[test]
    fn per_tensor_single_scale() {
        let x = make_plane(10, 10, 4);
        let q = quant_per_tensor(&x, 10, 10);
        assert!(q.scales.iter().all(|&s| s == q.scales[0]));
    }

    #[test]
    fn quantize_into_matches_allocating_variant() {
        let (rows, cols) = (70, 24);
        let x = make_plane(rows, cols, 7);
        // dirty, over- and under-sized buffers must give identical bits
        let mut data = vec![42i8; 3];
        let mut scales = vec![-1.0f32; 4096];
        for g in [
            Granularity::PerTensor,
            Granularity::PerToken,
            Granularity::PerBlock(16),
            Granularity::PerChannel,
        ] {
            let q = quantize(&x, rows, cols, g);
            quantize_into(&x, rows, cols, g, &mut data, &mut scales);
            assert_eq!(data, q.data, "{g:?}");
            assert_eq!(scales, q.scales, "{g:?}");
        }
    }

    #[test]
    fn smooth_k_into_matches_allocating_variant() {
        let (rows, cols) = (33, 20);
        let x = make_plane(rows, cols, 8);
        let (out_a, mean_a) = smooth_k(&x, rows, cols);
        // reused buffers (stale contents + excess capacity) give identical bits
        let mut out_b = vec![9.0f32; 5];
        let mut mean_b = vec![-3.0f32; 100];
        smooth_k_into(&x, rows, cols, &mut out_b, &mut mean_b);
        assert_eq!(out_a, out_b);
        assert_eq!(mean_a, mean_b);
    }

    #[test]
    fn smooth_k_removes_mean() {
        let (rows, cols) = (50, 16);
        let mut x = make_plane(rows, cols, 5);
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] += (c as f32) * 10.0; // strong channel bias
            }
        }
        let (sm, mean) = smooth_k(&x, rows, cols);
        for c in 0..cols {
            let col_mean: f32 = (0..rows).map(|r| sm[r * cols + c]).sum::<f32>() / rows as f32;
            assert!(col_mean.abs() < 1e-3, "col {c} mean {col_mean}");
            assert!((mean[c] - (c as f32) * 10.0).abs() < 1.0);
        }
    }

    #[test]
    fn int4_coarser_than_int8() {
        let x = make_plane(64, 64, 9);
        let d8 = fake_quant(&x, 64, 64, FakeQuant::Int8(Granularity::PerToken));
        let d4 = fake_quant(&x, 64, 64, FakeQuant::Int4(Granularity::PerToken));
        let err = |d: &[f32]| {
            x.iter().zip(d).map(|(a, b)| (a - b).abs()).sum::<f32>() / x.len() as f32
        };
        let (e8, e4) = (err(&d8), err(&d4));
        // one quant step is 127/7 ≈ 18x coarser
        assert!(e4 > 8.0 * e8, "int4 {e4} vs int8 {e8}");
        // but still bounded by half an int4 step
        let q = super::quantize(&x, 64, 64, Granularity::PerToken);
        let max_step = q.scales.iter().cloned().fold(0.0f32, f32::max) * 127.0 / 7.0;
        for (a, b) in x.iter().zip(&d4) {
            assert!((a - b).abs() <= 0.5 * max_step + 1e-5);
        }
    }

    #[test]
    fn smoothing_shrinks_quant_error_under_channel_bias() {
        let (rows, cols) = (128, 64);
        let mut rng = crate::util::rng::Pcg32::seeded(6);
        let mut x = vec![0.0f32; rows * cols];
        let bias: Vec<f32> = (0..cols).map(|_| rng.normal() * 20.0).collect();
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] = bias[c] + rng.normal() * 0.5;
            }
        }
        let rms = |v: &[f32], w: &[f32]| {
            (v.iter().zip(w).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / v.len() as f32)
                .sqrt()
        };
        let raw = quant_per_token(&x, rows, cols).dequant();
        let (sm, mean) = smooth_k(&x, rows, cols);
        let smq = quant_per_token(&sm, rows, cols).dequant();
        // add mean back for apples-to-apples reconstruction error
        let mut rec = smq;
        for r in 0..rows {
            for c in 0..cols {
                rec[r * cols + c] += mean[c];
            }
        }
        assert!(rms(&rec, &x) < 0.2 * rms(&raw, &x));
    }
}
