//! Property-testing mini-harness (substrate for the unavailable proptest
//! crate): seeded random case generation with failing-seed reporting, so
//! invariant tests get randomized coverage while staying reproducible.

use crate::util::rng::Pcg32;

/// Run `cases` randomized executions of `body`. Each case gets its own
/// deterministically-derived RNG; on panic the harness reports the case
/// seed so the failure replays with `check_with_seed`.
pub fn check(name: &str, cases: usize, body: impl Fn(&mut Pcg32) + std::panic::RefUnwindSafe) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg32::seeded(seed);
            body(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property '{name}' failed on case {case} (seed {seed:#x})");
            eprintln!("replay: testing::check_with_seed(\"{name}\", {seed:#x}, body)");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single failing case.
pub fn check_with_seed(_name: &str, seed: u64, body: impl Fn(&mut Pcg32)) {
    let mut rng = Pcg32::seeded(seed);
    body(&mut rng);
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Shrink-lite helpers: draw structured values from an RNG.
pub mod gen {
    use crate::util::rng::Pcg32;

    /// Uniform usize in [lo, hi].
    pub fn usize_in(rng: &mut Pcg32, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u32) as usize
    }

    /// A plausible attention shape: (batch, heads, seq, head_dim).
    pub fn attn_shape(rng: &mut Pcg32) -> [usize; 4] {
        let b = usize_in(rng, 1, 3);
        let h = usize_in(rng, 1, 4);
        let n = usize_in(rng, 1, 320);
        let d = *[16, 32, 64, 128].get(rng.below(4) as usize).unwrap();
        [b, h, n, d]
    }

    /// Vector of f32 in [-scale, scale].
    pub fn f32_vec(rng: &mut Pcg32, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.range_f32(-scale, scale)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let count = std::sync::atomic::AtomicUsize::new(0);
        check("counter", 17, |_| {
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::Relaxed), 17);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fails", 10, |rng| {
            assert!(rng.uniform() < 2.0); // always true
            assert!(rng.uniform() >= 0.0);
            panic!("boom");
        });
    }

    #[test]
    fn gen_shapes_in_bounds() {
        let mut rng = Pcg32::seeded(5);
        for _ in 0..100 {
            let [b, h, n, d] = gen::attn_shape(&mut rng);
            assert!(b >= 1 && b <= 3 && h <= 4 && n >= 1 && n <= 320);
            assert!([16, 32, 64, 128].contains(&d));
        }
    }
}
