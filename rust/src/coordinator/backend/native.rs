//! Native serving backend: the toy-transformer forward pass in pure Rust
//! on top of the crate's attention kernels, with a physical paged KV
//! cache — `sage serve` without a byte of PJRT.
//!
//! The forward mirrors `python/compile/model.py` (RMSNorm → QKV → RoPE →
//! attention → SwiGLU), with attention dispatched through the same
//! kernel registry rows the artifact plans lower from ("fp" →
//! `online`, "sage"/"adaptive" → `SageAttn-B`). Per-slot KV lives in the
//! [`PagedKvStore`]: each decode step appends one row per (layer, head)
//! into the blocks named by the accountant's table and runs the
//! prepared-plane kernel straight off the resident pages — the paper's
//! quantize-once decode (§3) as serving state, never re-quantizing a
//! resident prefix.
//!
//! KV is reserved incrementally ([`ReserveMode::Incremental`]): a decode
//! step that crosses a page boundary asks the accountant for one more
//! block, and on `OutOfBlocks` the engine preempts the longest-tail
//! victim (most remaining generation budget, latest arrival on ties),
//! releasing its logical and physical blocks and handing the scheduler a
//! recompute-on-resume [`Request`]. Because paged one-shot and
//! incremental quantization are bit-identical, a resumed request's
//! re-prefilled KV state exactly matches what was evicted.

use std::time::Instant;

use crate::attn::{
    exact_plane_opt, fp8_plane_opt, guard, online_plane_opt, registry, sage_plane_opt, AttnImpl,
    PlaneOpts, Scratch, PAGE_ROWS,
};
use crate::obs::{EventKind, Obs, PhaseTimer, NO_ID, NO_REPLICA};
use crate::quant::Granularity;
use crate::runtime::{ModelCfg, Value};
use crate::tensor::{default_threads, parallel_map};
use crate::util::error::{bail, ensure, Context, Error, Result};
use crate::util::rng::Pcg32;

use super::super::kv_cache::{AllocError, BlockId, KvCacheManager};
use super::super::paged_kv::PagedKvStore;
use super::super::prefix_cache::PrefixCache;
use super::super::request::{Request, RequestId, ResumeState};
use super::super::traffic::ChunkCfg;
use super::{
    advance_slot, flush_stream, sample, EngineBackend, EngineStats, ReserveMode, Slot,
    StepOutcome,
};

/// How decode-step attention reads the KV prefix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeMode {
    /// Through the paged quantize-once state (the shipping hot path):
    /// only Q is quantized per step.
    Prepared,
    /// Gather raw rows and re-run smooth-K + full INT8 quantization of
    /// the prefix every step — the naive engine loop `sage bench-hotpath
    /// --serve-decode` measures against. Numerics only; not for serving.
    RequantEachStep,
}

/// Pure-Rust model replica over the paged physical KV cache.
pub struct NativeEngine {
    cfg: ModelCfg,
    plan: String,
    kernel: &'static registry::KernelEntry,
    imp: AttnImpl,
    decode_mode: DecodeMode,
    params: Vec<Value>,
    paged: PagedKvStore,
    /// Radix prefix cache (`--prefix-cache`; None = disabled).
    cache: Option<PrefixCache>,
    slots: Vec<Option<Slot>>,
    batch: usize,
    inv_freq: Vec<f32>,
    scratch: Scratch,
    /// One-shot fault hook: the next step NaN-poisons the first
    /// non-degraded live slot's logits (flows through the real guard).
    poison_armed: bool,
    /// Chunked prefill (`None` = whole-prompt prefill at admission):
    /// admission defers the compute into `Slot::pending_prefill` and
    /// `step` drains it chunk-by-chunk under the per-tick row budget,
    /// interleaved with decode.
    chunk: Option<ChunkCfg>,
    /// Observability handle ([`Obs::disabled`] = every emit is one dead
    /// branch) and the replica id stamped on engine-level trace events.
    obs: Obs,
    replica: u32,
    pub stats: EngineStats,
}

/// Kernel phase profiling samples one plane call in this many (per
/// scratch, i.e. per engine thread) — dense enough for a stable Figure-2
/// style breakdown, sparse enough that the sampled run stays within the
/// `trace_overhead_frac` floor.
const PHASE_SAMPLE_EVERY: u32 = 8;

impl NativeEngine {
    /// Default decode-slot count (pjrt slots come from the artifact's
    /// batch dimension; the native forward has no such constraint).
    pub const DEFAULT_SLOTS: usize = 4;

    /// Build a native engine for `cfg` and `plan` ("fp"/"sage"/
    /// "adaptive"), initializing parameters from `seed`.
    pub fn new(
        cfg: ModelCfg,
        plan: &str,
        seed: u64,
        slots: usize,
        decode_mode: DecodeMode,
    ) -> Result<NativeEngine> {
        let Some(kernel) = registry::plan_entry(plan) else {
            bail!(
                "unknown attention plan '{plan}' (expected fp|sage|adaptive; \
                 registry kernels: {})",
                registry::known_names()
            );
        };
        ensure!(slots >= 1, "need at least one decode slot");
        ensure!(
            cfg.param_spec.len() == 3 + 9 * cfg.n_layers,
            "config '{}' param spec is not the GPT layout the native forward expects",
            cfg.name
        );
        ensure!(cfg.d_head % 2 == 0, "RoPE needs an even head dim (got {})", cfg.d_head);
        let imp = kernel.imp;
        // the naive requant baseline keeps only raw rows resident
        let store_imp = match decode_mode {
            DecodeMode::Prepared => imp,
            DecodeMode::RequantEachStep => AttnImpl::Exact,
        };
        let paged = PagedKvStore::new(cfg.n_layers, cfg.n_heads, cfg.d_head, store_imp)?;
        let params = cfg.init_params(seed);
        let half = cfg.d_head / 2;
        let inv_freq = (0..half)
            .map(|j| 1.0 / cfg.rope_base.powf(j as f32 / half as f32))
            .collect();
        Ok(NativeEngine {
            cfg,
            plan: plan.to_owned(),
            kernel,
            imp,
            decode_mode,
            params,
            paged,
            cache: None,
            slots: (0..slots).map(|_| None).collect(),
            batch: slots,
            inv_freq,
            scratch: Scratch::new(),
            poison_armed: false,
            chunk: None,
            obs: Obs::disabled(),
            replica: NO_REPLICA,
            stats: EngineStats::default(),
        })
    }

    pub fn decode_mode(&self) -> DecodeMode {
        self.decode_mode
    }

    /// The physical paged store (telemetry / tests).
    pub fn paged_store(&self) -> &PagedKvStore {
        &self.paged
    }

    /// Switch on the radix prefix cache (`sage serve --prefix-cache`).
    ///
    /// The cache chunk is [`PAGE_ROWS`]-aligned (pages are
    /// quantization-self-contained only as wholes) and additionally
    /// coarsened to the plan's Q scale-group size: block-granular Q
    /// scales (`BLOCK_Q` rows per group, spanning two pages) are formed
    /// relative to each forward call's chunk, so a suffix prefill is
    /// bit-identical to an unshared run only when the cached prefix
    /// ends on a Q-group boundary.
    pub fn enable_prefix_cache(&mut self) {
        let chunk = match self.imp {
            AttnImpl::Sage { qk: Granularity::PerBlock(g), .. } => {
                let mut c = PAGE_ROWS;
                while c % g != 0 {
                    c += PAGE_ROWS;
                }
                c
            }
            _ => PAGE_ROWS,
        };
        self.cache = Some(PrefixCache::new(chunk));
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.cache.is_some()
    }

    /// Fork a live request into a new id occupying a free slot —
    /// parallel-sampling-style fan-out. The forked sequence shares
    /// every KV block with its source under the accountant's refcounts
    /// (zero copies); the first decode append on either side hits the
    /// copy-on-write barrier ([`PagedKvStore::prepare_append`]) and
    /// copies the shared tail block(s) then. Returns false when no
    /// decode slot is free.
    pub fn fork_request(
        &mut self,
        src: RequestId,
        dst: RequestId,
        kv: &mut KvCacheManager,
    ) -> Result<bool> {
        let Some(slot_idx) = self.slots.iter().position(Option::is_none) else {
            return Ok(false);
        };
        ensure!(
            kv.seq_tokens(dst).is_none() && !self.paged.is_registered(dst),
            "destination id {dst} already in use"
        );
        let src_slot = self
            .slots
            .iter()
            .flatten()
            .find(|s| s.id == src)
            .with_context(|| format!("request {src} not live in any slot"))?;
        let new_slot = Slot {
            id: dst,
            prompt: src_slot.prompt.clone(),
            pos: src_slot.pos,
            next_token: src_slot.next_token,
            generated: src_slot.generated.clone(),
            params: src_slot.params,
            arrival: src_slot.arrival,
            first_token_at: src_slot.first_token_at,
            rng: src_slot.rng.clone(),
            degraded: src_slot.degraded,
            admitted_at: src_slot.admitted_at,
            pending_prefill: src_slot.pending_prefill.clone(),
            // the fork is a new stream: every inherited token is emitted
            // fresh under the destination id
            streamed: 0,
        };
        ensure!(kv.fork(src, dst).is_ok(), "request {src} unknown to the accountant");
        if let Err(e) = self.paged.fork(src, dst) {
            let _ = kv.release(dst);
            return Err(e);
        }
        self.slots[slot_idx] = Some(new_slot);
        Ok(true)
    }

    /// Evict one LRU cached prefix — the OutOfBlocks relief valve,
    /// tried before preempting live work.
    fn evict_one(&mut self, kv: &mut KvCacheManager) -> Result<bool> {
        let Some(cache) = self.cache.as_mut() else {
            return Ok(false);
        };
        let evicted =
            cache.evict_lru(kv, &mut self.paged).context("prefix-cache eviction failed")?;
        self.stats.cache_evictions = cache.stats.evictions;
        Ok(evicted)
    }

    /// Longest-tail preemption victim: the live slot with the most
    /// remaining generation budget (the request most able to pin blocks
    /// for longest), ties broken toward the latest arrival.
    fn pick_victim(&self) -> Option<usize> {
        let mut best: Option<(usize, usize, Instant)> = None;
        for (i, slot) in self.slots.iter().enumerate() {
            let Some(s) = slot else { continue };
            let remaining = s.params.max_new_tokens.saturating_sub(s.generated.len());
            let better = match &best {
                None => true,
                Some((_, r, arr)) => {
                    remaining > *r || (remaining == *r && s.arrival >= *arr)
                }
            };
            if better {
                best = Some((i, remaining, s.arrival));
            }
        }
        best.map(|(i, _, _)| i)
    }

    /// Evict slot `idx`: release its logical and physical blocks and
    /// return the recompute-on-resume request. Shared by preemption,
    /// drain (tick-error / crash recovery) and the numeric-guard
    /// degraded-retry path.
    fn evict_slot(&mut self, idx: usize, kv: &mut KvCacheManager) -> Result<Request> {
        let s = self.slots[idx].take().context("evicting an empty slot")?;
        // physical before logical: the rc-aware release reads the table
        // and drops only payloads this release takes to rc 0
        self.paged.release(s.id, kv)?;
        if kv.release(s.id).is_err() {
            bail!("logical release failed for evicted request {}", s.id);
        }
        // a chunked slot evicted mid-prefill has no decode progress to
        // carry — it resumes as a fresh admission (full re-prefill)
        let resume = if s.generated.is_empty() {
            None
        } else {
            Some(ResumeState {
                generated: s.generated,
                rng: s.rng,
                first_token_at: s.first_token_at,
                streamed: s.streamed,
            })
        };
        Ok(Request {
            id: s.id,
            prompt: s.prompt,
            params: s.params,
            arrival: s.arrival,
            resume,
            degraded: s.degraded,
        })
    }

    /// [`NativeEngine::evict_slot`] under KV pressure — counted as a
    /// preemption.
    fn preempt_slot(&mut self, idx: usize, kv: &mut KvCacheManager) -> Result<Request> {
        let req = self.evict_slot(idx, kv)?;
        self.stats.preemptions += 1;
        Ok(req)
    }
}

impl EngineBackend for NativeEngine {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn plan(&self) -> &str {
        &self.plan
    }

    fn kernel(&self) -> &'static registry::KernelEntry {
        self.kernel
    }

    fn batch_slots(&self) -> usize {
        self.batch
    }

    fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    fn outstanding_tokens(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| s.params.max_new_tokens.saturating_sub(s.generated.len()))
            .sum()
    }

    /// No AOT prefill shapes to match — any prompt ≤ max_seq works.
    /// Advertise a power-of-two spread for the workload generators.
    fn prefill_sizes(&self) -> Vec<usize> {
        let mut sizes = Vec::new();
        let mut n = 16;
        while n <= self.cfg.max_seq / 2 {
            sizes.push(n);
            n *= 2;
        }
        if sizes.is_empty() {
            sizes.push((self.cfg.max_seq / 2).max(1));
        }
        sizes
    }

    fn reserve_mode(&self) -> ReserveMode {
        ReserveMode::Incremental
    }

    fn set_params(&mut self, params: Vec<Value>) -> Result<()> {
        if params.len() != self.params.len() {
            bail!("expected {} params, got {}", self.params.len(), params.len());
        }
        for (new, spec) in params.iter().zip(&self.cfg.param_spec) {
            if new.shape() != spec.shape.as_slice() {
                bail!("param {} shape mismatch", spec.name);
            }
            new.as_f32().with_context(|| format!("param {} must be f32", spec.name))?;
        }
        self.params = params;
        Ok(())
    }

    fn add_request(&mut self, req: &Request, kv: &mut KvCacheManager) -> Result<bool> {
        let Some(slot_idx) = self.slots.iter().position(Option::is_none) else {
            return Ok(false);
        };
        ensure!(
            kv.block_size() == PAGE_ROWS,
            "native backend pages KV at {PAGE_ROWS} rows/block but the accountant \
             was built with block_size {} (logical and physical must agree)",
            kv.block_size()
        );
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if req.prompt.len() + req.params.max_new_tokens > self.cfg.max_seq {
            bail!("request would overflow the context window");
        }
        let toks = req.prefill_tokens();
        // the batcher reserves at most the prefill rows up front
        // (incremental mode; the prefix-credit gate may have shrunk the
        // reservation to the unshared suffix) — more is an accounting bug
        let reserved = kv
            .seq_tokens(req.id)
            .with_context(|| format!("request {} has no KV reservation", req.id))?;
        ensure!(
            reserved <= toks.len(),
            "request {} reserved {reserved} tokens but prefill needs only {}",
            req.id,
            toks.len()
        );

        let hit = match self.cache.as_mut() {
            Some(c) => {
                self.stats.prefix_lookups += 1;
                c.lookup(&toks)
            }
            None => None,
        };
        let prefix_len = match hit {
            Some((cseq, hlen)) => {
                // swap the batcher's reservation for a fork of the cached
                // prefix, then grow the logical table to the full prompt
                ensure!(
                    kv.release(req.id).is_ok(),
                    "cannot release reservation of request {}",
                    req.id
                );
                ensure!(
                    kv.fork_prefix(cseq, req.id, hlen).is_ok(),
                    "cannot fork {hlen} cached tokens of sequence {cseq}"
                );
                if kv.extend(req.id, toks.len() - hlen).is_err() {
                    // stale admission credit (the cache shrank since the
                    // batcher sized the reservation): bounce — the caller
                    // releases the logical fork and requeues
                    return Ok(false);
                }
                self.paged.fork_prefix(cseq, req.id, hlen)?;
                self.stats.prefix_hits += 1;
                self.stats.prefill_tokens_saved += hlen as u64;
                hlen
            }
            None => {
                if reserved < toks.len()
                    && kv.extend(req.id, toks.len() - reserved).is_err()
                {
                    return Ok(false); // stale credit, no hit to back it
                }
                self.paged.register(req.id)?;
                0
            }
        };

        // copy-on-write barrier: blocks the suffix append will touch may
        // be shared with the cache (or still carry a stale-credit fork)
        loop {
            match self.paged.prepare_append(req.id, kv, toks.len() - prefix_len) {
                Ok(copied) => {
                    self.stats.cow_copies += copied as u64;
                    break;
                }
                Err(AllocError::OutOfBlocks) => {
                    if self.evict_one(kv)? {
                        continue;
                    }
                    // pool exhausted and nothing evictable: bounce
                    let _ = self.paged.release(req.id, kv);
                    return Ok(false);
                }
                Err(e) => bail!("CoW barrier failed for request {}: {e:?}", req.id),
            }
        }
        // fetch the table only now — CoW may have swapped entries
        let table: Vec<BlockId> = kv.seq_blocks(req.id).unwrap().to_vec();

        // chunked prefill: defer the compute — `step` drains the prompt
        // chunk-by-chunk under the tick budget (the admission barrier
        // above already covered the whole suffix horizon). Tokens are
        // validated here so a bad prompt still fails at admission
        // instead of surfacing as a step-time drain.
        if self.chunk.is_some() {
            if let Some(&bad) =
                toks.iter().find(|&&t| !(0..self.cfg.vocab as i32).contains(&t))
            {
                let _ = self.paged.release(req.id, kv);
                bail!("token {bad} outside vocab {}", self.cfg.vocab);
            }
            let (first_token_at, rng, generated, streamed) = match &req.resume {
                Some(res) => {
                    (res.first_token_at, res.rng.clone(), res.generated.clone(), res.streamed)
                }
                None => (Instant::now(), Pcg32::seeded(req.params.seed ^ req.id), Vec::new(), 0),
            };
            self.slots[slot_idx] = Some(Slot {
                id: req.id,
                prompt: req.prompt.clone(),
                pos: prefix_len,
                next_token: generated.last().copied().unwrap_or(0),
                generated,
                params: req.params,
                arrival: req.arrival,
                first_token_at,
                rng,
                degraded: req.degraded,
                admitted_at: Instant::now(),
                pending_prefill: toks[prefix_len..].to_vec(),
                streamed,
            });
            return Ok(true);
        }

        // degraded requests (numeric-guard retries) run attention on the
        // fp path over raw resident rows; appends still quantize into the
        // shared store, so their pages stay audit-clean and cache-sharable
        let (imp, mode) = if req.degraded {
            (AttnImpl::OnlineFp32, DecodeMode::RequantEachStep)
        } else {
            (self.imp, self.decode_mode)
        };
        let t0 = Instant::now();
        let logits = match forward_rows(
            &self.cfg,
            &self.params,
            imp,
            mode,
            &self.inv_freq,
            &mut self.paged,
            &mut self.scratch,
            req.id,
            &table,
            &toks[prefix_len..],
            prefix_len,
        )
        .and_then(|l| {
            guard::check_finite("prefill logits", &l).map_err(Error::msg)?;
            Ok(l)
        }) {
            Ok(l) => l,
            Err(e) => {
                // leave no physical residue behind a failed admission
                let _ = self.paged.release(req.id, kv);
                return Err(e);
            }
        };
        let dur = t0.elapsed();
        self.stats.prefill_time += dur;
        self.stats.prefills += 1;
        self.obs.emit(
            self.replica,
            req.id,
            EventKind::Prefill {
                rows: (toks.len() - prefix_len) as u32,
                dur_ns: dur.as_nanos() as u64,
            },
        );
        if let Some(c) = self.cache.as_mut() {
            c.insert(&toks, req.id, kv, &mut self.paged)?;
        }

        let (first_token_at, rng, generated, streamed) = match &req.resume {
            Some(res) => {
                (res.first_token_at, res.rng.clone(), res.generated.clone(), res.streamed)
            }
            None => {
                let mut rng = Pcg32::seeded(req.params.seed ^ req.id);
                let first = sample(&logits, req.params.temperature, &mut rng);
                self.obs.emit(self.replica, req.id, EventKind::FirstToken);
                (Instant::now(), rng, vec![first], 0)
            }
        };
        self.slots[slot_idx] = Some(Slot {
            id: req.id,
            prompt: req.prompt.clone(),
            pos: toks.len(),
            next_token: *generated.last().expect("at least the first token"),
            generated,
            params: req.params,
            arrival: req.arrival,
            first_token_at,
            rng,
            degraded: req.degraded,
            admitted_at: Instant::now(),
            pending_prefill: Vec::new(),
            streamed,
        });
        Ok(true)
    }

    fn step(&mut self, kv: &mut KvCacheManager) -> Result<StepOutcome> {
        let mut outcome = StepOutcome::default();
        if self.live_slots() == 0 {
            return Ok(outcome);
        }
        let t0 = Instant::now();
        let live_at_entry = self.live_slots();
        let tokens_at_entry = self.stats.tokens_generated;

        // --- chunked-prefill phase: drain pending prompts chunk-by-chunk
        // under the per-tick row budget, before (and never instead of)
        // the decode phase — decode slots advance every tick even with a
        // max-length prefill in flight (no head-of-line blocking).
        if let Some(chunk_cfg) = self.chunk {
            let mut budget = chunk_cfg.tick_rows;
            for b in 0..self.batch {
                let Some(s) = self.slots[b].as_ref() else { continue };
                if s.pending_prefill.is_empty() {
                    continue;
                }
                let rows = chunk_cfg.chunk_rows.min(s.pending_prefill.len());
                if rows > budget {
                    continue; // tick budget spent; next tick resumes here
                }
                budget -= rows;
                let id = s.id;
                let slot_degraded = s.degraded;
                let pos0 = s.pos;
                let chunk_toks: Vec<i32> = s.pending_prefill[..rows].to_vec();
                let (imp, mode) = if slot_degraded {
                    (AttnImpl::OnlineFp32, DecodeMode::RequantEachStep)
                } else {
                    (self.imp, self.decode_mode)
                };
                let table: Vec<BlockId> = kv.seq_blocks(id).unwrap().to_vec();
                let tp = Instant::now();
                let logits = match forward_rows(
                    &self.cfg,
                    &self.params,
                    imp,
                    mode,
                    &self.inv_freq,
                    &mut self.paged,
                    &mut self.scratch,
                    id,
                    &table,
                    &chunk_toks,
                    pos0,
                ) {
                    Ok(l) => l,
                    Err(e) if !slot_degraded && guard::is_nonfinite_err(&e.to_string()) => {
                        let mut evicted = self.evict_slot(b, kv)?;
                        evicted.degraded = true;
                        outcome.degraded.push(evicted);
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                let dur = tp.elapsed();
                self.stats.prefill_time += dur;
                self.obs.emit(
                    self.replica,
                    id,
                    EventKind::PrefillChunk { rows: rows as u32, dur_ns: dur.as_nanos() as u64 },
                );
                let s = self.slots[b].as_mut().expect("slot checked live above");
                s.pending_prefill.drain(..rows);
                s.pos += rows;
                if !s.pending_prefill.is_empty() {
                    continue; // intermediate chunk: its logits are discarded
                }
                // final chunk — the prefill is complete
                if let Err(e) = guard::check_finite("prefill logits", &logits) {
                    if slot_degraded {
                        bail!("request {id} non-finite even on the fp path: {e}");
                    }
                    let mut evicted = self.evict_slot(b, kv)?;
                    evicted.degraded = true;
                    outcome.degraded.push(evicted);
                    continue;
                }
                self.stats.prefills += 1;
                if self.cache.is_some() {
                    // reconstruct the fed token list (prompt + resumed
                    // decode progress) exactly as the one-shot path fed it
                    let s = self.slots[b].as_ref().expect("slot checked live above");
                    let mut toks = s.prompt.clone();
                    let fed = s.generated.len().saturating_sub(1);
                    toks.extend_from_slice(&s.generated[..fed]);
                    if let Some(c) = self.cache.as_mut() {
                        c.insert(&toks, id, kv, &mut self.paged)?;
                    }
                }
                let s = self.slots[b].as_mut().expect("slot checked live above");
                if s.generated.is_empty() {
                    // TTFT clock: the first token exists (and streams) now
                    let first = sample(&logits, s.params.temperature, &mut s.rng);
                    s.generated.push(first);
                    s.next_token = first;
                    s.first_token_at = Instant::now();
                    self.stats.tokens_generated += 1;
                    self.obs.emit(self.replica, id, EventKind::FirstToken);
                } else {
                    s.next_token = *s.generated.last().expect("generated checked non-empty");
                }
                flush_stream(s, &mut outcome.streamed);
            }
        }

        for b in 0..self.batch {
            let Some(s) = self.slots[b].as_ref() else { continue };
            if !s.pending_prefill.is_empty() {
                continue; // still prefilling: no decode step for this slot
            }
            let id = s.id;
            // grow the logical KV by this step's row; on OutOfBlocks,
            // evict a cached prefix if possible, else preempt-and-requeue
            // the longest-tail victim and retry
            loop {
                match kv.extend(id, 1) {
                    Ok(()) => break,
                    Err(AllocError::OutOfBlocks) => {
                        if self.evict_one(kv)? {
                            continue;
                        }
                        let victim = self
                            .pick_victim()
                            .context("OutOfBlocks with no live slot to preempt")?;
                        let evicted = self.preempt_slot(victim, kv)?;
                        outcome.preempted.push(evicted);
                        if victim == b {
                            break; // preempted ourselves; nothing to decode
                        }
                    }
                    Err(e) => {
                        bail!("KV extend failed for slot {b} request {id}: {e:?}");
                    }
                }
            }
            if self.slots[b].is_none() {
                continue; // preempted ourselves above
            }
            // copy-on-write barrier: the appended row may land in (or
            // requantize into) a block shared with the prefix cache or a
            // forked sibling — give this writer private copies first
            loop {
                match self.paged.prepare_append(id, kv, 1) {
                    Ok(copied) => {
                        self.stats.cow_copies += copied as u64;
                        break;
                    }
                    Err(AllocError::OutOfBlocks) => {
                        if self.evict_one(kv)? {
                            continue;
                        }
                        let victim = self
                            .pick_victim()
                            .context("OutOfBlocks with no live slot to preempt")?;
                        let evicted = self.preempt_slot(victim, kv)?;
                        outcome.preempted.push(evicted);
                        if victim == b {
                            break;
                        }
                    }
                    Err(e) => {
                        bail!("CoW barrier failed for slot {b} request {id}: {e:?}");
                    }
                }
            }
            let Some(s) = self.slots[b].as_ref() else { continue };
            let table: Vec<BlockId> = kv.seq_blocks(id).unwrap().to_vec();
            let (tok, pos, temperature) = (s.next_token, s.pos, s.params.temperature);
            let slot_degraded = s.degraded;
            let (imp, mode) = if slot_degraded {
                (AttnImpl::OnlineFp32, DecodeMode::RequantEachStep)
            } else {
                (self.imp, self.decode_mode)
            };
            let mut logits = match forward_rows(
                &self.cfg,
                &self.params,
                imp,
                mode,
                &self.inv_freq,
                &mut self.paged,
                &mut self.scratch,
                id,
                &table,
                &[tok],
                pos,
            ) {
                Ok(l) => l,
                Err(e) if !slot_degraded && guard::is_nonfinite_err(&e.to_string()) => {
                    // quantized plan blew up: evict for a degraded (fp
                    // attention) retry; recompute-on-resume discards any
                    // partially appended rows with the evicted blocks
                    let mut evicted = self.evict_slot(b, kv)?;
                    evicted.degraded = true;
                    outcome.degraded.push(evicted);
                    continue;
                }
                Err(e) => return Err(e),
            };
            if self.poison_armed && !slot_degraded {
                self.poison_armed = false;
                logits[0] = f32::NAN;
            }
            if let Err(e) = guard::check_finite("decode logits", &logits) {
                if slot_degraded {
                    bail!("request {id} non-finite even on the fp path: {e}");
                }
                let mut evicted = self.evict_slot(b, kv)?;
                evicted.degraded = true;
                outcome.degraded.push(evicted);
                continue;
            }
            let s = self.slots[b].as_mut().expect("slot checked live above");
            let next = sample(&logits, temperature, &mut s.rng);
            self.stats.tokens_generated += 1;
            if let Some(resp) = advance_slot(s, next, self.cfg.max_seq, &mut outcome.streamed) {
                outcome.finished.push(resp);
                // reclaim the physical pages; the scheduler releases the
                // logical reservation when it records the response
                self.paged.release(id, kv)?;
                self.slots[b] = None;
            }
        }
        let dur = t0.elapsed();
        self.stats.decode_time += dur;
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += live_at_entry as f64 / self.batch as f64;
        if self.obs.is_enabled() {
            self.obs.emit(
                self.replica,
                NO_ID,
                EventKind::DecodeStep {
                    live: live_at_entry as u32,
                    tokens: (self.stats.tokens_generated - tokens_at_entry) as u32,
                    dur_ns: dur.as_nanos() as u64,
                },
            );
            // flush the scratch's sampled kernel phase accumulators
            let (ns, samples) = self.scratch.take_phase_ns();
            self.obs.add_phase(&ns, samples);
        }
        Ok(outcome)
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }

    fn prefix_credit(&self, req: &Request) -> usize {
        match &self.cache {
            Some(c) => c.lookup_len(&req.prefill_tokens()),
            None => 0,
        }
    }

    fn reclaim_blocks(&mut self, kv: &mut KvCacheManager, need: usize) -> Result<bool> {
        let Some(cache) = self.cache.as_mut() else {
            return Ok(false);
        };
        let freed = cache
            .reclaim(kv, &mut self.paged, need)
            .context("prefix-cache eviction failed")?;
        self.stats.cache_evictions = cache.stats.evictions;
        Ok(freed)
    }

    fn cached_sequences(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.entries())
    }

    fn drain(&mut self, kv: &mut KvCacheManager) -> Result<Vec<Request>> {
        let mut drained = Vec::new();
        for i in 0..self.batch {
            if self.slots[i].is_some() {
                drained.push(self.evict_slot(i, kv)?);
            }
        }
        Ok(drained)
    }

    fn cancel(&mut self, id: RequestId, kv: &mut KvCacheManager) -> Result<bool> {
        let Some(idx) = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|s| s.id == id))
        else {
            return Ok(false);
        };
        let s = self.slots[idx].take().expect("position() found a live slot");
        // physical only — the logical release stays with the caller,
        // mirroring the finish path
        self.paged.release(s.id, kv)?;
        Ok(true)
    }

    fn live_ids(&self) -> Vec<RequestId> {
        self.slots.iter().flatten().map(|s| s.id).collect()
    }

    fn inject_poison(&mut self) -> bool {
        self.poison_armed = true;
        true
    }

    /// Chunked prefill is supported whenever chunk boundaries can stay
    /// aligned with the plan's Q scale groups (per-forward-call groups
    /// restart at each chunk, so alignment is what keeps chunked output
    /// bit-identical to one-shot prefill). Per-tensor Q scales span the
    /// whole call and cannot be chunk-aligned — refused.
    fn set_chunked_prefill(&mut self, cfg: ChunkCfg) -> bool {
        let ok = match self.imp {
            AttnImpl::Sage { qk: Granularity::PerBlock(g), .. } => cfg.aligned_to(g),
            AttnImpl::Sage { qk: Granularity::PerToken, .. } => true,
            AttnImpl::Sage { .. } | AttnImpl::Fp8 { .. } => false,
            AttnImpl::Exact | AttnImpl::OnlineFp32 => true,
        };
        if ok {
            self.chunk = Some(cfg);
        }
        ok
    }

    fn pending_prefill_rows(&self) -> usize {
        self.slots.iter().flatten().map(|s| s.pending_prefill.len()).sum()
    }

    /// Engine-level spans (prefill / prefill chunk / decode step / first
    /// token) are stamped with `replica`; the scratch's sampled kernel
    /// phase profiler is armed (or disarmed) to match, and its
    /// accumulators are flushed into `obs` once per [`Self::step`].
    fn set_obs(&mut self, obs: Obs, replica: u32) {
        let timer = if obs.is_enabled() {
            PhaseTimer::sampled(PHASE_SAMPLE_EVERY)
        } else {
            PhaseTimer::disabled()
        };
        self.scratch.set_phase_timer(timer);
        self.obs = obs;
        self.replica = replica;
    }
}

// ---------------------------------------------------------------------------
// The forward pass (mirrors python/compile/model.py)
// ---------------------------------------------------------------------------

/// Run `tokens` (at absolute positions `pos0..pos0+t`) through the
/// transformer, appending their K/V rows to the paged store and
/// returning the last position's logits. Used for both prefill
/// (`t = prompt len`) and decode (`t = 1`); every sublayer is row-local
/// and attention state is bit-identical one-shot vs incremental, so
/// recompute-on-resume rebuilds exactly the state it evicted.
#[allow(clippy::too_many_arguments)]
fn forward_rows(
    cfg: &ModelCfg,
    params: &[Value],
    imp: AttnImpl,
    mode: DecodeMode,
    inv_freq: &[f32],
    paged: &mut PagedKvStore,
    scratch: &mut Scratch,
    id: RequestId,
    table: &[BlockId],
    tokens: &[i32],
    pos0: usize,
) -> Result<Vec<f32>> {
    let (dm, h, dh, ff) = (cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff);
    let t = tokens.len();
    ensure!(t > 0, "empty forward");
    let p = |i: usize| params[i].as_f32().expect("params validated as f32");

    // token embedding
    let embed = p(0);
    let mut x = vec![0.0f32; t * dm];
    for (r, &tok) in tokens.iter().enumerate() {
        ensure!(
            (0..cfg.vocab as i32).contains(&tok),
            "token {tok} outside vocab {}",
            cfg.vocab
        );
        x[r * dm..(r + 1) * dm].copy_from_slice(&embed[tok as usize * dm..(tok as usize + 1) * dm]);
    }

    let opts = PlaneOpts::causal(true);
    for l in 0..cfg.n_layers {
        let base = 1 + 9 * l;
        // attention sublayer
        let hn = rmsnorm(&x, p(base), dm);
        let mut q = split_heads(&matmul(&hn, t, dm, p(base + 1), h * dh), t, h, dh);
        let mut k = split_heads(&matmul(&hn, t, dm, p(base + 2), h * dh), t, h, dh);
        let v = split_heads(&matmul(&hn, t, dm, p(base + 3), h * dh), t, h, dh);
        apply_rope(&mut q, h, t, dh, inv_freq, pos0);
        apply_rope(&mut k, h, t, dh, inv_freq, pos0);
        paged.append_layer(id, table, l, &k, &v, t)?;
        let n_kv = pos0 + t;
        let attn = match mode {
            DecodeMode::Prepared => {
                paged.attention(id, table, l, &q, h, t, scratch, opts)?
            }
            DecodeMode::RequantEachStep => {
                let mut out = vec![0.0f32; h * t * dh];
                for hd in 0..h {
                    let (kraw, vraw) = paged.gather_layer_raw(id, table, l, hd)?;
                    let qh = &q[hd * t * dh..(hd + 1) * t * dh];
                    let o = match imp {
                        AttnImpl::Sage { qk, pv, smooth_k } => sage_plane_opt(
                            scratch, qh, &kraw, &vraw, t, n_kv, dh, qk, pv, smooth_k, opts,
                        ),
                        AttnImpl::OnlineFp32 => {
                            online_plane_opt(scratch, qh, &kraw, &vraw, t, n_kv, dh, opts)
                        }
                        AttnImpl::Exact => exact_plane_opt(qh, &kraw, &vraw, t, n_kv, dh, opts),
                        AttnImpl::Fp8 { qk, pv } => {
                            fp8_plane_opt(qh, &kraw, &vraw, t, n_kv, dh, qk, pv, opts)
                        }
                    };
                    out[hd * t * dh..(hd + 1) * t * dh].copy_from_slice(&o);
                }
                out
            }
        };
        // numeric guard: a quantization blow-up (NaN/inf tile) surfaces
        // here as a marker-tagged error the serving stack can map to a
        // degraded-mode (fp attention) retry instead of streaming garbage
        guard::check_finite(&format!("attn layer {l}"), &attn).map_err(Error::msg)?;
        let merged = merge_heads(&attn, t, h, dh);
        let proj = matmul(&merged, t, h * dh, p(base + 4), dm);
        for (xi, pi) in x.iter_mut().zip(&proj) {
            *xi += pi;
        }
        // SwiGLU MLP sublayer
        let hn = rmsnorm(&x, p(base + 5), dm);
        let gate = matmul(&hn, t, dm, p(base + 6), ff);
        let up = matmul(&hn, t, dm, p(base + 7), ff);
        let mut act = vec![0.0f32; t * ff];
        for ((a, &g), &u) in act.iter_mut().zip(&gate).zip(&up) {
            *a = silu(g) * u;
        }
        let down = matmul(&act, t, ff, p(base + 8), dm);
        for (xi, di) in x.iter_mut().zip(&down) {
            *xi += di;
        }
    }
    // logits at the last position only (what sampling needs)
    let last = rmsnorm(&x[(t - 1) * dm..t * dm], p(1 + 9 * cfg.n_layers), dm);
    Ok(matmul(&last, 1, dm, p(2 + 9 * cfg.n_layers), cfg.vocab))
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Row-wise RMSNorm (eps mirrors the python model).
fn rmsnorm(x: &[f32], gain: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (xi, oi) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms = xi.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for ((o, &v), &g) in oi.iter_mut().zip(xi).zip(gain) {
            *o = v * inv * g;
        }
    }
    out
}

/// Row-major (m, k) × (k, n) — k-outer accumulation per row for cache
/// friendliness, parallel over rows when the product is big enough to
/// amortize the thread handoff (prefill; decode rows stay serial).
fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let row_of = |i: usize| {
        let mut row = vec![0.0f32; n];
        let ar = &a[i * k..(i + 1) * k];
        for (p, &av) in ar.iter().enumerate() {
            if av != 0.0 {
                let br = &b[p * n..(p + 1) * n];
                for (o, &bv) in row.iter_mut().zip(br) {
                    *o += av * bv;
                }
            }
        }
        row
    };
    if m >= 8 && m * k * n >= (1 << 20) {
        let rows = parallel_map(m, default_threads(), row_of);
        let mut out = Vec::with_capacity(m * n);
        for r in rows {
            out.extend_from_slice(&r);
        }
        out
    } else {
        let mut out = Vec::with_capacity(m * n);
        for i in 0..m {
            out.extend_from_slice(&row_of(i));
        }
        out
    }
}

/// (t, H·dh) → (H, t, dh)
fn split_heads(x: &[f32], t: usize, h: usize, dh: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for r in 0..t {
        for hd in 0..h {
            let src = r * h * dh + hd * dh;
            let dst = (hd * t + r) * dh;
            out[dst..dst + dh].copy_from_slice(&x[src..src + dh]);
        }
    }
    out
}

/// (H, t, dh) → (t, H·dh)
fn merge_heads(x: &[f32], t: usize, h: usize, dh: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for hd in 0..h {
        for r in 0..t {
            let src = (hd * t + r) * dh;
            let dst = r * h * dh + hd * dh;
            out[dst..dst + dh].copy_from_slice(&x[src..src + dh]);
        }
    }
    out
}

/// Split-half (NeoX/Llama) RoPE on an (H, t, dh) slab at absolute
/// positions `pos0..pos0+t` — position-local, so batched prefill and
/// one-row decode produce bit-identical rows.
fn apply_rope(x: &mut [f32], h: usize, t: usize, dh: usize, inv_freq: &[f32], pos0: usize) {
    let half = dh / 2;
    for hd in 0..h {
        for r in 0..t {
            let row = &mut x[(hd * t + r) * dh..(hd * t + r + 1) * dh];
            let pos = (pos0 + r) as f32;
            for (j, &f) in inv_freq.iter().enumerate() {
                let ang = pos * f;
                let (sin, cos) = ang.sin_cos();
                let x1 = row[j];
                let x2 = row[j + half];
                row[j] = x1 * cos - x2 * sin;
                row[j + half] = x2 * cos + x1 * sin;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_reshape_round_trips() {
        let (t, h, dh) = (3usize, 2usize, 4usize);
        let x: Vec<f32> = (0..t * h * dh).map(|i| i as f32).collect();
        assert_eq!(merge_heads(&split_heads(&x, t, h, dh), t, h, dh), x);
    }

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] × [5 6; 7 8] = [19 22; 43 50]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, 2, 2, &b, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn rope_is_position_local() {
        let (h, dh) = (1usize, 8usize);
        let half = dh / 2;
        let inv_freq: Vec<f32> =
            (0..half).map(|j| 1.0 / 10000f32.powf(j as f32 / half as f32)).collect();
        let base: Vec<f32> = (0..2 * dh).map(|i| (i as f32).sin()).collect();
        // rows at positions 5 and 6, rotated together...
        let mut both = base.clone();
        apply_rope(&mut both, h, 2, dh, &inv_freq, 5);
        // ...must equal each row rotated alone at its own position
        let mut r0 = base[..dh].to_vec();
        apply_rope(&mut r0, h, 1, dh, &inv_freq, 5);
        let mut r1 = base[dh..].to_vec();
        apply_rope(&mut r1, h, 1, dh, &inv_freq, 6);
        assert_eq!(&both[..dh], r0.as_slice());
        assert_eq!(&both[dh..], r1.as_slice());
    }

    #[test]
    fn rmsnorm_unit_gain_normalizes() {
        let x = vec![3.0f32, 4.0, 0.0, 0.0];
        let out = rmsnorm(&x, &[1.0; 4], 4);
        let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-3, "normalized mean square {ms}");
    }
}
