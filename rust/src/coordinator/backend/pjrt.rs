//! PJRT artifact backend: drives the AOT transformer executables with
//! continuous batching over a fixed set of decode slots.
//!
//! Per request: one batch-1 `prefill_<plan>_<len>` call builds the KV
//! prefix, which is spliced into a free slot of the persistent
//! (L, B, H, max_seq, d) decode caches; every `step()` then advances all
//! live slots one token through `decode_step_<plan>` (idle slots ride
//! along as padding, the continuous-batching trade the paper's serving
//! setups make). KV is reserved in full at admission
//! ([`ReserveMode::Full`]): the dense caches inside the artifacts commit
//! max_seq rows per slot, so decode can never run out of blocks and the
//! logical accountant's reservation mirrors that commitment.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::attn::registry;
use crate::runtime::pjrt as xla;
use crate::runtime::{Artifact, ModelCfg, Runtime, Value};
use crate::util::error::{bail, Context, Result};
use crate::util::rng::Pcg32;

use super::super::kv_cache::KvCacheManager;
use super::super::request::{Request, RequestId, ResumeState};
use super::{advance_slot, sample, EngineBackend, EngineStats, ReserveMode, Slot, StepOutcome};

/// A model replica bound to one artifact family.
///
/// Hot-path state (parameters, KV caches) lives as pre-marshalled XLA
/// literals: parameters are converted once (§Perf — a 19 MB memcpy per
/// decode step on the `small` config otherwise), and decode-step output
/// caches are fed back as next-step inputs without a host round-trip.
pub struct PjrtEngine {
    cfg: ModelCfg,
    plan: String,
    kernel: &'static registry::KernelEntry,
    params: Vec<Value>,
    params_lit: Vec<xla::Literal>,
    decode: Arc<Artifact>,
    prefills: BTreeMap<usize, Arc<Artifact>>,
    kc_lit: xla::Literal,
    vc_lit: xla::Literal,
    slots: Vec<Option<Slot>>,
    batch: usize,
    pub stats: EngineStats,
}

impl PjrtEngine {
    /// Build an engine for `config` ("tiny"/"small") and `plan`
    /// ("fp"/"sage"/"adaptive"), initializing parameters from `seed`.
    pub fn new(rt: &Runtime, config: &str, plan: &str, seed: u64) -> Result<PjrtEngine> {
        // validate the plan through the kernel registry up front, so a
        // typo reports as "unknown plan" instead of a missing artifact
        let Some(kernel) = registry::plan_entry(plan) else {
            bail!(
                "unknown attention plan '{plan}' (expected fp|sage|adaptive; \
                 registry kernels: {})",
                registry::known_names()
            );
        };
        let cfg = rt
            .manifest
            .configs
            .get(config)
            .with_context(|| format!("config '{config}' not in manifest"))?
            .clone();
        let decode_name = format!("{config}_decode_step_{plan}");
        let decode = rt.load(&decode_name)?;
        let batch = decode.spec.batch.context("decode artifact missing batch")?;
        let mut prefills = BTreeMap::new();
        for name in rt.entries_of_kind("prefill") {
            let spec = &rt.manifest.entries[&name];
            if spec.config.as_deref() == Some(config)
                && name.starts_with(&format!("{config}_prefill_{plan}_"))
            {
                let n = spec.n_prompt.context("prefill missing n_prompt")?;
                prefills.insert(n, rt.load(&name)?);
            }
        }
        if prefills.is_empty() {
            bail!("no prefill artifacts for {config}/{plan}");
        }
        let params = cfg.init_params(seed);
        let params_lit = params
            .iter()
            .map(Value::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let kv_shape = vec![cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.d_head];
        let zero_kv = Value::zeros_f32(&kv_shape);
        Ok(PjrtEngine {
            cfg: cfg.clone(),
            plan: plan.to_owned(),
            kernel,
            params,
            params_lit,
            decode,
            prefills,
            kc_lit: zero_kv.to_literal()?,
            vc_lit: zero_kv.to_literal()?,
            slots: (0..batch).map(|_| None).collect(),
            batch,
            stats: EngineStats::default(),
        })
    }

    /// Copy a batch-1 prefill KV (L,1,H,max,d) into decode slot `b`.
    /// Prefill-only path: pulls the decode caches to host, splices, and
    /// re-marshals (decode steps themselves never round-trip the caches).
    fn splice_kv(&mut self, b: usize, kc1: &[f32], vc1: &[f32]) -> Result<()> {
        let (l, bt, h, mx, d) =
            (self.cfg.n_layers, self.batch, self.cfg.n_heads, self.cfg.max_seq, self.cfg.d_head);
        let layer = h * mx * d;
        let mut kc: Vec<f32> = self.kc_lit.to_vec()?;
        let mut vc: Vec<f32> = self.vc_lit.to_vec()?;
        for li in 0..l {
            let src = li * layer..(li + 1) * layer;
            let dst = (li * bt + b) * layer..(li * bt + b + 1) * layer;
            kc[dst.clone()].copy_from_slice(&kc1[src.clone()]);
            vc[dst].copy_from_slice(&vc1[src]);
        }
        let shape = vec![l, bt, h, mx, d];
        self.kc_lit = Value::f32(kc, &shape).to_literal()?;
        self.vc_lit = Value::f32(vc, &shape).to_literal()?;
        Ok(())
    }
}

impl EngineBackend for PjrtEngine {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn plan(&self) -> &str {
        &self.plan
    }

    fn kernel(&self) -> &'static registry::KernelEntry {
        self.kernel
    }

    fn batch_slots(&self) -> usize {
        self.batch
    }

    fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    fn outstanding_tokens(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| s.params.max_new_tokens.saturating_sub(s.generated.len()))
            .sum()
    }

    /// Supported prompt lengths (must match an AOT prefill artifact after
    /// padding).
    fn prefill_sizes(&self) -> Vec<usize> {
        self.prefills.keys().copied().collect()
    }

    fn reserve_mode(&self) -> ReserveMode {
        ReserveMode::Full
    }

    /// Replace the parameters (e.g. with trained weights from the E2E
    /// training driver). Shapes must match the manifest spec.
    fn set_params(&mut self, params: Vec<Value>) -> Result<()> {
        if params.len() != self.params.len() {
            bail!("expected {} params, got {}", self.params.len(), params.len());
        }
        for (new, spec) in params.iter().zip(&self.cfg.param_spec) {
            if new.shape() != spec.shape.as_slice() {
                bail!("param {} shape mismatch", spec.name);
            }
        }
        self.params_lit =
            params.iter().map(Value::to_literal).collect::<Result<Vec<_>>>()?;
        self.params = params;
        Ok(())
    }

    fn add_request(&mut self, req: &Request, _kv: &mut KvCacheManager) -> Result<bool> {
        let Some(slot_idx) = self.slots.iter().position(Option::is_none) else {
            return Ok(false);
        };
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        // pick the smallest prefill artifact that fits; right-pad with the
        // last prompt token (synthetic workloads use exact sizes)
        let prefill_toks = req.prefill_tokens();
        let Some((&plen, prefill)) =
            self.prefills.iter().find(|(&n, _)| n >= prefill_toks.len())
        else {
            bail!(
                "prompt len {} exceeds largest prefill artifact {:?}",
                prefill_toks.len(),
                self.prefills.keys().last()
            );
        };
        if plen + req.remaining_new_tokens() > self.cfg.max_seq {
            bail!("request would overflow the context window");
        }
        let mut padded = prefill_toks.clone();
        padded.resize(plen, *prefill_toks.last().unwrap());

        let t0 = Instant::now();
        let prompt_lit = Value::i32(padded, &[1, plen]).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = self.params_lit.iter().collect();
        inputs.push(&prompt_lit);
        let prefill = prefill.clone();
        let out = prefill.run_raw(&inputs)?;
        self.stats.prefill_time += t0.elapsed();
        self.stats.prefills += 1;

        let logits: Vec<f32> = out[0].to_vec()?;
        let kc1: Vec<f32> = out[1].to_vec()?;
        let vc1: Vec<f32> = out[2].to_vec()?;
        self.splice_kv(slot_idx, &kc1, &vc1)?;

        // fresh request: sample the first token off the prefill logits;
        // resumed request: decode progress (tokens, sampler state, TTFT
        // stamp) carries over and the prefill logits are recompute waste
        let (first_token_at, rng, generated, streamed) = match &req.resume {
            Some(res) => {
                (res.first_token_at, res.rng.clone(), res.generated.clone(), res.streamed)
            }
            None => {
                let mut rng = Pcg32::seeded(req.params.seed ^ req.id);
                let first = sample(&logits, req.params.temperature, &mut rng);
                (Instant::now(), rng, vec![first], 0)
            }
        };
        self.slots[slot_idx] = Some(Slot {
            id: req.id,
            prompt: req.prompt.clone(),
            pos: plen,
            next_token: *generated.last().expect("at least the first token"),
            generated,
            params: req.params,
            arrival: req.arrival,
            first_token_at,
            rng,
            degraded: req.degraded,
            admitted_at: Instant::now(),
            pending_prefill: Vec::new(),
            streamed,
        });
        Ok(true)
    }

    /// One decode step over all live slots.
    fn step(&mut self, _kv: &mut KvCacheManager) -> Result<StepOutcome> {
        let mut outcome = StepOutcome::default();
        if self.live_slots() == 0 {
            return Ok(outcome);
        }
        let mut tokens = vec![0i32; self.batch];
        let mut pos = vec![0i32; self.batch];
        for (b, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                tokens[b] = s.next_token;
                pos[b] = s.pos as i32;
            }
        }
        let t0 = Instant::now();
        let tok_lit = Value::i32(tokens, &[self.batch]).to_literal()?;
        let pos_lit = Value::i32(pos, &[self.batch]).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = self.params_lit.iter().collect();
        inputs.push(&self.kc_lit);
        inputs.push(&self.vc_lit);
        inputs.push(&tok_lit);
        inputs.push(&pos_lit);
        let mut out = self.decode.run_raw(&inputs)?;
        self.stats.decode_time += t0.elapsed();
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += self.live_slots() as f64 / self.batch as f64;

        let logits: Vec<f32> = out[0].to_vec()?;
        let logits = logits.as_slice();
        // feed the output caches straight back as next-step inputs —
        // no host round-trip on the decode hot path
        self.vc_lit = out.pop().unwrap();
        self.kc_lit = out.pop().unwrap();

        let vocab = self.cfg.vocab;
        for (b, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            let row = &logits[b * vocab..(b + 1) * vocab];
            let tok = sample(row, s.params.temperature, &mut s.rng);
            self.stats.tokens_generated += 1;
            if let Some(resp) = advance_slot(s, tok, self.cfg.max_seq, &mut outcome.streamed) {
                outcome.finished.push(resp);
                *slot = None;
            }
        }
        Ok(outcome)
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }

    fn drain(&mut self, kv: &mut KvCacheManager) -> Result<Vec<Request>> {
        // dense KV lives inside the decode-cache literals — nothing
        // physical to free; release the logical reservations and hand
        // back recompute-on-resume requests
        let mut drained = Vec::new();
        for slot in &mut self.slots {
            let Some(s) = slot.take() else { continue };
            let _ = kv.release(s.id);
            drained.push(Request {
                id: s.id,
                prompt: s.prompt,
                params: s.params,
                arrival: s.arrival,
                resume: Some(ResumeState {
                    generated: s.generated,
                    rng: s.rng,
                    first_token_at: s.first_token_at,
                    streamed: s.streamed,
                }),
                degraded: s.degraded,
            });
        }
        Ok(drained)
    }

    fn cancel(&mut self, id: RequestId, _kv: &mut KvCacheManager) -> Result<bool> {
        for slot in &mut self.slots {
            if slot.as_ref().is_some_and(|s| s.id == id) {
                *slot = None; // no physical pages; logical stays with the caller
                return Ok(true);
            }
        }
        Ok(false)
    }

    fn live_ids(&self) -> Vec<RequestId> {
        self.slots.iter().flatten().map(|s| s.id).collect()
    }
}
