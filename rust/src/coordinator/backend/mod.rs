//! Engine backends: the execution side of the serving stack behind one
//! trait.
//!
//! [`EngineBackend`] is the prefill / decode-step / slot-accounting
//! contract the scheduler drives. Two implementations ship:
//!
//! * [`pjrt::PjrtEngine`] — the AOT-artifact driver (dense per-slot KV
//!   caches inside the XLA executables, full KV reservation at
//!   admission). Behavior-preserving port of the original `Engine`.
//! * [`native::NativeEngine`] — a pure-Rust transformer forward over the
//!   crate's own attention kernels, with a **physical paged KV cache**
//!   ([`crate::coordinator::PagedKvStore`]): per-slot KV is quantize-once
//!   `PreparedKV` state paged at `PAGE_ROWS` rows per block, indexed by
//!   the accountant's block tables, reserved incrementally and reclaimed
//!   by preemption when blocks run out.
//!
//! The attention plan ("fp"/"sage"/"adaptive") stays the experiment knob
//! on both — the paper's plug-and-play switch — while `--backend` picks
//! the execution substrate.

pub mod native;
pub mod pjrt;

use std::time::Duration;

use crate::attn::registry;
use crate::runtime::Value;
use crate::util::error::Result;
use crate::util::rng::Pcg32;

use super::kv_cache::KvCacheManager;
use super::request::{FinishReason, Request, Response};
use super::traffic::{ChunkCfg, StreamedToken};

/// How a backend wants KV blocks reserved at admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReserveMode {
    /// Reserve the full `prompt + max_new_tokens` budget up front
    /// (dense caches: capacity is committed at admission, decode can
    /// never run out). The PJRT backend's mode.
    Full,
    /// Reserve only the prefill rows; decode extends block-by-block and
    /// preempts a victim on `OutOfBlocks` (the paged native backend).
    Incremental,
}

/// What one scheduling step produced.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Requests that finished this step.
    pub finished: Vec<Response>,
    /// Requests preempted for KV blocks this step, ready to requeue
    /// (their logical + physical KV is already released; decode
    /// progress rides in [`Request::resume`]).
    pub preempted: Vec<Request>,
    /// Requests evicted by the numeric guard (non-finite output on the
    /// quantized plan), ready to requeue with [`Request::degraded`] set
    /// so the retry runs attention on the fp path. KV released like
    /// `preempted`.
    pub degraded: Vec<Request>,
    /// Tokens emitted this step, in sample order (the per-token
    /// streaming surface): every sampled-but-unsent token of every live
    /// slot, each tagged with its absolute index in the response so
    /// sinks can detect gaps/duplicates across preemption and failover.
    pub streamed: Vec<StreamedToken>,
}

/// Execution engine contract: admission, decode stepping and slot
/// accounting over one model replica. The scheduler owns the logical
/// [`KvCacheManager`] and threads it through so logical accounting and
/// the backend's physical storage stay in lockstep.
///
/// `Send` so a replica (and the scheduler that owns it) can be driven
/// from its own thread — the multi-replica serve loop runs one thread
/// per replica, as a real fleet would.
pub trait EngineBackend: Send {
    /// Backend discriminator ("pjrt" / "native") for reports and flags.
    fn backend_name(&self) -> &'static str;

    /// Attention plan this engine was built for.
    fn plan(&self) -> &str;

    /// Registry row the plan's kernels lower from.
    fn kernel(&self) -> &'static registry::KernelEntry;

    fn batch_slots(&self) -> usize;

    fn free_slots(&self) -> usize;

    fn live_slots(&self) -> usize {
        self.batch_slots() - self.free_slots()
    }

    /// Total queued work in live slots (for routing load scores).
    fn outstanding_tokens(&self) -> usize;

    /// Prompt lengths this backend can prefill (after padding).
    fn prefill_sizes(&self) -> Vec<usize>;

    /// KV reservation discipline the batcher must apply.
    fn reserve_mode(&self) -> ReserveMode;

    /// Replace the model parameters (manifest order; shapes validated).
    fn set_params(&mut self, params: Vec<Value>) -> Result<()>;

    /// Admit one request: prefill it and occupy a free slot. Returns
    /// false if no slot is free or the prompt cannot fit. The request's
    /// KV must already be reserved in `kv` (per [`reserve_mode`]); on
    /// `Ok(false)` / `Err` the caller keeps ownership of that
    /// reservation (and must release or requeue it — never drop it).
    ///
    /// [`reserve_mode`]: EngineBackend::reserve_mode
    fn add_request(&mut self, req: &Request, kv: &mut KvCacheManager) -> Result<bool>;

    /// One decode step over all live slots.
    fn step(&mut self, kv: &mut KvCacheManager) -> Result<StepOutcome>;

    fn stats(&self) -> &EngineStats;

    /// Prefill tokens of `req` servable from shared cached state (see
    /// [`crate::coordinator::batcher::AdmitGate::prefix_credit`]).
    fn prefix_credit(&self, _req: &Request) -> usize {
        0
    }

    /// Free reclaimable blocks until `kv` has at least `need` free (see
    /// [`crate::coordinator::batcher::AdmitGate::reclaim_blocks`]).
    fn reclaim_blocks(&mut self, _kv: &mut KvCacheManager, _need: usize) -> Result<bool> {
        Ok(false)
    }

    /// Sequences resident in `kv` that belong to the backend's caches
    /// rather than live requests — the scheduler's stall detector must
    /// not mistake them for forgotten work.
    fn cached_sequences(&self) -> usize {
        0
    }

    /// Evict *every* live slot into resumable [`Request`]s, releasing
    /// each slot's physical **and** logical KV (unlike `step`'s
    /// preemption path the backend releases both here, because drain is
    /// called on error exits where the scheduler may not get another
    /// clean look at the slot set). Used by the tick-error recovery path
    /// and by crash failover; a drained backend is empty but reusable.
    fn drain(&mut self, _kv: &mut KvCacheManager) -> Result<Vec<Request>> {
        Ok(Vec::new())
    }

    /// Cancel one live request (deadline expiry): drop its slot and
    /// release its physical KV. Logical release stays with the caller —
    /// mirroring `step`'s finish path. Returns false if `id` is not live.
    fn cancel(&mut self, _id: super::request::RequestId, _kv: &mut KvCacheManager) -> Result<bool> {
        Ok(false)
    }

    /// Ids of requests currently occupying slots.
    fn live_ids(&self) -> Vec<super::request::RequestId> {
        Vec::new()
    }

    /// Fault hook: arm a one-shot NaN injection into the next step's
    /// logits (flows through the real numeric guard). Returns false when
    /// the backend has no poisoning support (pjrt).
    fn inject_poison(&mut self) -> bool {
        false
    }

    /// Injected-fault counters when this backend is a fault wrapper.
    fn fault_stats(&self) -> Option<&crate::coordinator::fault::FaultStats> {
        None
    }

    /// Enable chunked prefill: admission defers the prefill compute and
    /// `step` interleaves fixed-size prefill chunks with decode under a
    /// per-tick row budget. Returns false when the backend does not
    /// support chunking (pjrt — dense artifacts prefill in one call).
    fn set_chunked_prefill(&mut self, _cfg: ChunkCfg) -> bool {
        false
    }

    /// Prefill rows admitted but not yet computed (chunked prefill
    /// backlog) — the admission controller folds this into its
    /// queue-delay estimate.
    fn pending_prefill_rows(&self) -> usize {
        0
    }

    /// Attach an observability handle: the backend stamps `replica` on
    /// its engine-level trace events (prefill chunks, decode steps) and
    /// arms the sampled kernel phase profiler. Default: ignored (pjrt —
    /// the artifact executes opaquely; there is nothing to instrument).
    fn set_obs(&mut self, _obs: crate::obs::Obs, _replica: u32) {}
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub prefills: u64,
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    /// decode-batch occupancy accumulated over steps (live slots / B)
    pub occupancy_sum: f64,
    /// requests preempted for KV blocks (native backend)
    pub preemptions: u64,
    /// prefix-cache lookups at prefill (native backend, `--prefix-cache`)
    pub prefix_lookups: u64,
    /// prefix-cache hits (prefill served partly from cached pages)
    pub prefix_hits: u64,
    /// prefill rows forked from cached pages instead of recomputed
    pub prefill_tokens_saved: u64,
    /// cached prefixes LRU-evicted under pool pressure
    pub cache_evictions: u64,
    /// blocks copied by the copy-on-write barrier
    pub cow_copies: u64,
}

impl EngineStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum / self.decode_steps as f64
        }
    }
}

/// One occupied decode slot (shared by both backends).
pub(crate) struct Slot {
    pub(crate) id: super::request::RequestId,
    /// Original prompt (kept for recompute-on-resume preemption).
    pub(crate) prompt: Vec<i32>,
    /// position the *next* fed token will occupy
    pub(crate) pos: usize,
    pub(crate) next_token: i32,
    pub(crate) generated: Vec<i32>,
    pub(crate) params: super::request::GenParams,
    pub(crate) arrival: std::time::Instant,
    pub(crate) first_token_at: std::time::Instant,
    pub(crate) rng: Pcg32,
    /// Numeric degraded mode: attention reads run on the fp path.
    pub(crate) degraded: bool,
    /// When this slot was admitted into the engine (queue-delay split).
    pub(crate) admitted_at: std::time::Instant,
    /// Prompt rows admitted but not yet prefilled (chunked prefill):
    /// `step` consumes them chunk-by-chunk before the slot decodes.
    /// Empty on unchunked backends/slots.
    pub(crate) pending_prefill: Vec<i32>,
    /// How many of `generated` have been emitted to [`StepOutcome::streamed`].
    pub(crate) streamed: usize,
}

/// Greedy or temperature sampling over a logits row.
pub(crate) fn sample(logits: &[f32], temperature: f32, rng: &mut Pcg32) -> i32 {
    if temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> =
        logits.iter().map(|&l| ((l - m) / temperature).exp()).collect();
    rng.categorical(&weights) as i32
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean time-per-output-token over `n_tokens` generated tokens: the
/// inter-token interval exists only past the first token, so a
/// single-token response reports `None` instead of fabricating a
/// denominator (the old `max(2) - 1` bug understated tail TPOT).
pub(crate) fn tpot_of(e2e_ms: f64, ttft_ms: f64, n_tokens: usize) -> Option<f64> {
    if n_tokens < 2 {
        return None;
    }
    Some((e2e_ms - ttft_ms) / (n_tokens - 1) as f64)
}

/// Emit every sampled-but-unsent token of slot `s` into `out` (the
/// per-token streaming surface). Indices are absolute positions in the
/// response, and `s.streamed` advances with the emission — a token is
/// streamed exactly once per request lifetime, even across
/// preemption/failover (the watermark rides in [`ResumeState::streamed`]).
///
/// [`ResumeState::streamed`]: super::request::ResumeState::streamed
pub(crate) fn flush_stream(s: &mut Slot, out: &mut Vec<StreamedToken>) {
    for (i, &token) in s.generated.iter().enumerate().skip(s.streamed) {
        out.push(StreamedToken { id: s.id, index: i, token });
    }
    s.streamed = s.generated.len();
}

/// Advance slot `s` with the freshly sampled token `next` — the finish
/// epilogue both backends share: stop-token / budget / context-window
/// checks, streaming emission, latency telemetry, and the Response when
/// the request is done (the slot's `generated` is drained into it; the
/// caller clears the slot and reclaims KV).
pub(crate) fn advance_slot(
    s: &mut Slot,
    next: i32,
    max_seq: usize,
    streamed: &mut Vec<StreamedToken>,
) -> Option<Response> {
    s.pos += 1;
    let stop_hit = s.params.stop_token == Some(next);
    if !stop_hit {
        s.generated.push(next);
        s.next_token = next;
    }
    flush_stream(s, streamed);
    let len_hit = s.generated.len() >= s.params.max_new_tokens || s.pos + 1 >= max_seq;
    if !(stop_hit || len_hit) {
        return None;
    }
    let now = std::time::Instant::now();
    let e2e = now.duration_since(s.arrival).as_secs_f64() * 1e3;
    let ttft = s.first_token_at.duration_since(s.arrival).as_secs_f64() * 1e3;
    Some(Response {
        id: s.id,
        finish: if stop_hit { FinishReason::StopToken } else { FinishReason::MaxTokens },
        ttft_ms: ttft,
        queue_ms: s.admitted_at.duration_since(s.arrival).as_secs_f64() * 1e3,
        tpot_ms: tpot_of(e2e, ttft, s.generated.len()),
        e2e_ms: e2e,
        tokens: std::mem::take(&mut s.generated),
        error: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_greedy_is_argmax() {
        let mut rng = Pcg32::seeded(1);
        assert_eq!(sample(&[0.1, 3.0, -1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn sample_temperature_covers_support() {
        let mut rng = Pcg32::seeded(2);
        let logits = [1.0f32, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[sample(&logits, 1.0, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_low_temperature_concentrates() {
        let mut rng = Pcg32::seeded(3);
        let logits = [0.0f32, 5.0, 0.0];
        let hits = (0..100)
            .filter(|_| sample(&logits, 0.1, &mut rng) == 1)
            .count();
        assert!(hits > 95);
    }

    #[test]
    fn tpot_none_for_single_token() {
        assert_eq!(tpot_of(10.0, 4.0, 1), None);
        assert_eq!(tpot_of(10.0, 4.0, 0), None);
        assert_eq!(tpot_of(10.0, 4.0, 3), Some(3.0));
    }
}
