//! L3 serving coordinator (the system side of the reproduction).
//!
//! SageAttention is a serving-acceleration paper, so the coordinator is a
//! vLLM-router-shaped stack: requests flow through admission/batching into
//! per-replica engines that drive the AOT transformer artifacts with
//! continuous batching over a fixed slot set, backed by a paged KV-cache
//! accountant. The attention implementation inside the artifacts — full
//! precision vs SageAttention vs an adaptive per-layer plan (§4.5) — is
//! the experiment knob; everything else stays identical, which is exactly
//! the paper's plug-and-play claim.

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod request;
pub mod router;
pub mod scheduler;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{Engine, EngineStats};
pub use kv_cache::{BlockId, KvCacheManager};
pub use request::{FinishReason, GenParams, Request, RequestId, Response};
pub use router::{Replica, Router, RoutingPolicy};
pub use scheduler::{Scheduler, SchedulerReport};
