//! L3 serving coordinator (the system side of the reproduction).
//!
//! SageAttention is a serving-acceleration paper, so the coordinator is a
//! vLLM-router-shaped stack: requests flow through admission/batching into
//! per-replica engines behind the [`backend::EngineBackend`] trait —
//! either the PJRT artifact driver or the pure-Rust native backend whose
//! per-slot KV is quantize-once `PreparedKV` state held in a physical
//! paged cache ([`PagedKvStore`]) indexed by the [`KvCacheManager`]'s
//! block tables, with preempt-and-requeue (recompute-on-resume) when
//! blocks run out. The attention implementation — full precision vs
//! SageAttention vs an adaptive per-layer plan (§4.5) — is the experiment
//! knob; everything else stays identical, which is exactly the paper's
//! plug-and-play claim.

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod fault;
pub mod kv_cache;
pub mod paged_kv;
pub mod prefix_cache;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod traffic;

pub use backend::native::{DecodeMode, NativeEngine};
pub use backend::pjrt::PjrtEngine;
pub use backend::{EngineBackend, EngineStats, ReserveMode, StepOutcome};
pub use batcher::{AdmitGate, BatchPolicy, Batcher, NoGate};
pub use engine::Engine;
pub use fault::{is_crash, is_injected, FaultStats, FaultingBackend};
pub use kv_cache::{AllocError, BlockId, KvCacheManager};
pub use paged_kv::PagedKvStore;
pub use prefix_cache::PrefixCache;
pub use request::{FinishReason, GenParams, Request, RequestId, Response, ResumeState};
pub use router::{
    Breaker, EngineReplica, Fleet, FleetCfg, FleetReport, Replica, RouteError, Router,
    RoutingPolicy,
};
pub use scheduler::{Scheduler, SchedulerReport};
pub use traffic::{ChunkCfg, SloTargets, StreamLedger, StreamedToken, TokenSink, TrafficCfg};
