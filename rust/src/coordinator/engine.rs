//! `Engine` — the scheduler-facing facade over an [`EngineBackend`].
//!
//! Construction picks the execution substrate (`--backend pjrt|native`):
//! [`Engine::pjrt`] drives the AOT artifacts (requires a runtime +
//! artifacts), [`Engine::native`] runs the pure-Rust forward over the
//! paged PreparedKV cache with zero PJRT involvement. Everything above
//! (scheduler, router, CLI, benches) programs against this one type, so
//! the attention plan *and* the backend are both plug-and-play switches.

use crate::attn::registry;
use crate::runtime::{ModelCfg, Runtime, Value};
use crate::synth::FaultSpec;
use crate::util::error::{Context, Result};

use super::backend::native::{DecodeMode, NativeEngine};
use super::backend::pjrt::PjrtEngine;
use super::backend::{EngineBackend, EngineStats, ReserveMode, StepOutcome};
use super::batcher::AdmitGate;
use super::fault::FaultStats;
use super::kv_cache::KvCacheManager;
use super::request::{Request, RequestId};
use super::traffic::ChunkCfg;

/// A model replica behind the [`EngineBackend`] trait.
pub struct Engine {
    backend: Box<dyn EngineBackend>,
}

impl Engine {
    /// Back-compat constructor: the PJRT artifact backend (the original
    /// `Engine::new`).
    pub fn new(rt: &Runtime, config: &str, plan: &str, seed: u64) -> Result<Engine> {
        Engine::pjrt(rt, config, plan, seed)
    }

    /// The AOT-artifact (PJRT) backend.
    pub fn pjrt(rt: &Runtime, config: &str, plan: &str, seed: u64) -> Result<Engine> {
        Ok(Engine { backend: Box::new(PjrtEngine::new(rt, config, plan, seed)?) })
    }

    /// The native backend on a built-in config ("tiny"/"small") — no
    /// runtime, no artifacts, no PJRT.
    pub fn native(config: &str, plan: &str, seed: u64) -> Result<Engine> {
        let cfg = ModelCfg::builtin(config)
            .with_context(|| format!("'{config}' is not a built-in config (tiny|small)"))?;
        Engine::native_with(cfg, plan, seed, NativeEngine::DEFAULT_SLOTS)
    }

    /// The native backend on an explicit [`ModelCfg`] with a chosen
    /// decode-slot count (benches build custom shapes this way).
    pub fn native_with(cfg: ModelCfg, plan: &str, seed: u64, slots: usize) -> Result<Engine> {
        Ok(Engine {
            backend: Box::new(NativeEngine::new(cfg, plan, seed, slots, DecodeMode::Prepared)?),
        })
    }

    /// [`Engine::native_with`] plus the radix prefix cache
    /// (`sage serve --prefix-cache`): shared-prefix prefills fork cached
    /// pages and compute only the suffix.
    pub fn native_cached(cfg: ModelCfg, plan: &str, seed: u64, slots: usize) -> Result<Engine> {
        let mut backend = NativeEngine::new(cfg, plan, seed, slots, DecodeMode::Prepared)?;
        backend.enable_prefix_cache();
        Ok(Engine { backend: Box::new(backend) })
    }

    /// Wrap an already-built backend (custom implementations, benches).
    pub fn from_backend(backend: Box<dyn EngineBackend>) -> Engine {
        Engine { backend }
    }

    /// Interpose the deterministic fault plane (`sage serve --faults`):
    /// the existing backend is wrapped in a [`FaultingBackend`] replaying
    /// the `spec` schedule from `seed ^ replica`.
    ///
    /// [`FaultingBackend`]: super::fault::FaultingBackend
    pub fn faulted(self, spec: FaultSpec, seed: u64, replica: usize) -> Engine {
        Engine {
            backend: Box::new(super::fault::FaultingBackend::new(
                self.backend,
                spec,
                seed,
                replica,
            )),
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.backend_name()
    }

    pub fn plan(&self) -> &str {
        self.backend.plan()
    }

    /// Registry row this plan's artifacts/kernels lower from.
    pub fn kernel(&self) -> &'static registry::KernelEntry {
        self.backend.kernel()
    }

    pub fn batch_slots(&self) -> usize {
        self.backend.batch_slots()
    }

    pub fn free_slots(&self) -> usize {
        self.backend.free_slots()
    }

    pub fn live_slots(&self) -> usize {
        self.backend.live_slots()
    }

    pub fn outstanding_tokens(&self) -> usize {
        self.backend.outstanding_tokens()
    }

    pub fn prefill_sizes(&self) -> Vec<usize> {
        self.backend.prefill_sizes()
    }

    pub fn reserve_mode(&self) -> ReserveMode {
        self.backend.reserve_mode()
    }

    pub fn set_params(&mut self, params: Vec<Value>) -> Result<()> {
        self.backend.set_params(params)
    }

    /// Admit one request (its KV reservation already made in `kv` per
    /// [`Engine::reserve_mode`]). See [`EngineBackend::add_request`].
    pub fn add_request(&mut self, req: &Request, kv: &mut KvCacheManager) -> Result<bool> {
        self.backend.add_request(req, kv)
    }

    /// One decode step over all live slots.
    pub fn step(&mut self, kv: &mut KvCacheManager) -> Result<StepOutcome> {
        self.backend.step(kv)
    }

    pub fn stats(&self) -> &EngineStats {
        self.backend.stats()
    }

    /// Sequences held by backend-internal caches (see
    /// [`EngineBackend::cached_sequences`]).
    pub fn cached_sequences(&self) -> usize {
        self.backend.cached_sequences()
    }

    /// Evict every live slot into resumable requests, releasing both
    /// physical and logical KV (see [`EngineBackend::drain`]).
    pub fn drain(&mut self, kv: &mut KvCacheManager) -> Result<Vec<Request>> {
        self.backend.drain(kv)
    }

    /// Cancel one live request, releasing its physical KV; the logical
    /// release stays with the caller (see [`EngineBackend::cancel`]).
    pub fn cancel(&mut self, id: RequestId, kv: &mut KvCacheManager) -> Result<bool> {
        self.backend.cancel(id, kv)
    }

    /// Ids of requests currently occupying slots.
    pub fn live_ids(&self) -> Vec<RequestId> {
        self.backend.live_ids()
    }

    /// Injected-fault counters when this engine carries a fault plane.
    pub fn fault_stats(&self) -> Option<&FaultStats> {
        self.backend.fault_stats()
    }

    /// Enable chunked prefill on the backend (see
    /// [`EngineBackend::set_chunked_prefill`]). Returns `false` when the
    /// plan cannot honor the chunk boundary alignment.
    pub fn set_chunked_prefill(&mut self, cfg: ChunkCfg) -> bool {
        self.backend.set_chunked_prefill(cfg)
    }

    /// Prompt rows admitted but not yet prefilled (chunked backlog).
    pub fn pending_prefill_rows(&self) -> usize {
        self.backend.pending_prefill_rows()
    }

    /// Attach an observability handle (see [`EngineBackend::set_obs`]):
    /// engine-level spans stamp `replica` and the kernel phase profiler
    /// arms on backends that support it.
    pub fn set_obs(&mut self, obs: crate::obs::Obs, replica: u32) {
        self.backend.set_obs(obs, replica)
    }
}

/// The scheduler admits through its engine: cached-prefix credit shrinks
/// incremental reservations and LRU eviction of unreferenced cached
/// prefixes can make room for an admission that would otherwise wait.
impl AdmitGate for Engine {
    fn prefix_credit(&self, req: &Request) -> usize {
        self.backend.prefix_credit(req)
    }

    fn reclaim_blocks(&mut self, kv: &mut KvCacheManager, need: usize) -> Result<bool> {
        self.backend.reclaim_blocks(kv, need)
    }
}
