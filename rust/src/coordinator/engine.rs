//! Execution engine: drives the AOT transformer artifacts with continuous
//! batching over a fixed set of decode slots.
//!
//! Per request: one batch-1 `prefill_<plan>_<len>` call builds the KV
//! prefix, which is spliced into a free slot of the persistent
//! (L, B, H, max_seq, d) decode caches; every `step()` then advances all
//! live slots one token through `decode_step_<plan>` (idle slots ride
//! along as padding, the continuous-batching trade the paper's serving
//! setups make). The attention plan ("fp", "sage", "adaptive") only
//! selects which artifact family runs — the plug-and-play switch.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::attn::registry;
use crate::runtime::pjrt as xla;
use crate::runtime::{Artifact, ModelCfg, Runtime, Value};
use crate::util::error::{bail, Context, Result};
use crate::util::rng::Pcg32;

use super::request::{FinishReason, GenParams, Request, RequestId, Response};

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub prefills: u64,
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    /// decode-batch occupancy accumulated over steps (live slots / B)
    pub occupancy_sum: f64,
}

impl EngineStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum / self.decode_steps as f64
        }
    }
}

struct Slot {
    id: RequestId,
    /// position the *next* fed token will occupy
    pos: usize,
    next_token: i32,
    generated: Vec<i32>,
    params: GenParams,
    arrival: Instant,
    first_token_at: Instant,
    rng: Pcg32,
}

/// A model replica bound to one artifact family.
///
/// Hot-path state (parameters, KV caches) lives as pre-marshalled XLA
/// literals: parameters are converted once (§Perf — a 19 MB memcpy per
/// decode step on the `small` config otherwise), and decode-step output
/// caches are fed back as next-step inputs without a host round-trip.
pub struct Engine {
    cfg: ModelCfg,
    plan: String,
    kernel: &'static registry::KernelEntry,
    params: Vec<Value>,
    params_lit: Vec<xla::Literal>,
    decode: Arc<Artifact>,
    prefills: BTreeMap<usize, Arc<Artifact>>,
    kc_lit: xla::Literal,
    vc_lit: xla::Literal,
    slots: Vec<Option<Slot>>,
    batch: usize,
    pub stats: EngineStats,
}

impl Engine {
    /// Build an engine for `config` ("tiny"/"small") and `plan`
    /// ("fp"/"sage"/"adaptive"), initializing parameters from `seed`.
    pub fn new(rt: &Runtime, config: &str, plan: &str, seed: u64) -> Result<Engine> {
        // validate the plan through the kernel registry up front, so a
        // typo reports as "unknown plan" instead of a missing artifact
        let Some(kernel) = registry::plan_entry(plan) else {
            bail!(
                "unknown attention plan '{plan}' (expected fp|sage|adaptive; \
                 registry kernels: {})",
                registry::known_names()
            );
        };
        let cfg = rt
            .manifest
            .configs
            .get(config)
            .with_context(|| format!("config '{config}' not in manifest"))?
            .clone();
        let decode_name = format!("{config}_decode_step_{plan}");
        let decode = rt.load(&decode_name)?;
        let batch = decode.spec.batch.context("decode artifact missing batch")?;
        let mut prefills = BTreeMap::new();
        for name in rt.entries_of_kind("prefill") {
            let spec = &rt.manifest.entries[&name];
            if spec.config.as_deref() == Some(config)
                && name.starts_with(&format!("{config}_prefill_{plan}_"))
            {
                let n = spec.n_prompt.context("prefill missing n_prompt")?;
                prefills.insert(n, rt.load(&name)?);
            }
        }
        if prefills.is_empty() {
            bail!("no prefill artifacts for {config}/{plan}");
        }
        let params = cfg.init_params(seed);
        let params_lit = params
            .iter()
            .map(Value::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let kv_shape = vec![cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.d_head];
        let zero_kv = Value::zeros_f32(&kv_shape);
        Ok(Engine {
            cfg: cfg.clone(),
            plan: plan.to_owned(),
            kernel,
            params,
            params_lit,
            decode,
            prefills,
            kc_lit: zero_kv.to_literal()?,
            vc_lit: zero_kv.to_literal()?,
            slots: (0..batch).map(|_| None).collect(),
            batch,
            stats: EngineStats::default(),
        })
    }

    /// Replace the parameters (e.g. with trained weights from the E2E
    /// training driver). Shapes must match the manifest spec.
    pub fn set_params(&mut self, params: Vec<Value>) -> Result<()> {
        if params.len() != self.params.len() {
            bail!("expected {} params, got {}", self.params.len(), params.len());
        }
        for (new, spec) in params.iter().zip(&self.cfg.param_spec) {
            if new.shape() != spec.shape.as_slice() {
                bail!("param {} shape mismatch", spec.name);
            }
        }
        self.params_lit =
            params.iter().map(Value::to_literal).collect::<Result<Vec<_>>>()?;
        self.params = params;
        Ok(())
    }

    pub fn plan(&self) -> &str {
        &self.plan
    }

    /// Registry row this plan's artifacts lower from (the "adaptive"
    /// plan refines it per layer; see §4.5).
    pub fn kernel(&self) -> &'static registry::KernelEntry {
        self.kernel
    }

    pub fn batch_slots(&self) -> usize {
        self.batch
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    pub fn live_slots(&self) -> usize {
        self.batch - self.free_slots()
    }

    /// Total queued work in live slots (for routing load scores).
    pub fn outstanding_tokens(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| s.params.max_new_tokens.saturating_sub(s.generated.len()))
            .sum()
    }

    /// Supported prompt lengths (must match an AOT prefill artifact after
    /// padding).
    pub fn prefill_sizes(&self) -> Vec<usize> {
        self.prefills.keys().copied().collect()
    }

    /// Admit one request: prefill it and occupy a free slot.
    /// Returns false if no slot is free or the prompt cannot fit.
    pub fn add_request(&mut self, req: &Request) -> Result<bool> {
        let Some(slot_idx) = self.slots.iter().position(Option::is_none) else {
            return Ok(false);
        };
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        // pick the smallest prefill artifact that fits; right-pad with the
        // last prompt token (synthetic workloads use exact sizes)
        let Some((&plen, prefill)) =
            self.prefills.iter().find(|(&n, _)| n >= req.prompt.len())
        else {
            bail!(
                "prompt len {} exceeds largest prefill artifact {:?}",
                req.prompt.len(),
                self.prefills.keys().last()
            );
        };
        if plen + req.params.max_new_tokens > self.cfg.max_seq {
            bail!("request would overflow the context window");
        }
        let mut padded = req.prompt.clone();
        padded.resize(plen, *req.prompt.last().unwrap());

        let t0 = Instant::now();
        let prompt_lit = Value::i32(padded, &[1, plen]).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = self.params_lit.iter().collect();
        inputs.push(&prompt_lit);
        let prefill = prefill.clone();
        let out = prefill.run_raw(&inputs)?;
        self.stats.prefill_time += t0.elapsed();
        self.stats.prefills += 1;

        let logits: Vec<f32> = out[0].to_vec()?;
        let kc1: Vec<f32> = out[1].to_vec()?;
        let vc1: Vec<f32> = out[2].to_vec()?;
        self.splice_kv(slot_idx, &kc1, &vc1)?;

        let mut rng = Pcg32::seeded(req.params.seed ^ req.id);
        let first = sample(&logits, req.params.temperature, &mut rng);
        self.slots[slot_idx] = Some(Slot {
            id: req.id,
            pos: plen,
            next_token: first,
            generated: vec![first],
            params: req.params,
            arrival: req.arrival,
            first_token_at: Instant::now(),
            rng,
        });
        Ok(true)
    }

    /// Copy a batch-1 prefill KV (L,1,H,max,d) into decode slot `b`.
    /// Prefill-only path: pulls the decode caches to host, splices, and
    /// re-marshals (decode steps themselves never round-trip the caches).
    fn splice_kv(&mut self, b: usize, kc1: &[f32], vc1: &[f32]) -> Result<()> {
        let (l, bt, h, mx, d) =
            (self.cfg.n_layers, self.batch, self.cfg.n_heads, self.cfg.max_seq, self.cfg.d_head);
        let layer = h * mx * d;
        let mut kc: Vec<f32> = self.kc_lit.to_vec()?;
        let mut vc: Vec<f32> = self.vc_lit.to_vec()?;
        for li in 0..l {
            let src = li * layer..(li + 1) * layer;
            let dst = (li * bt + b) * layer..(li * bt + b + 1) * layer;
            kc[dst.clone()].copy_from_slice(&kc1[src.clone()]);
            vc[dst].copy_from_slice(&vc1[src]);
        }
        let shape = vec![l, bt, h, mx, d];
        self.kc_lit = Value::f32(kc, &shape).to_literal()?;
        self.vc_lit = Value::f32(vc, &shape).to_literal()?;
        Ok(())
    }

    /// One decode step over all live slots. Returns finished responses.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        if self.live_slots() == 0 {
            return Ok(Vec::new());
        }
        let mut tokens = vec![0i32; self.batch];
        let mut pos = vec![0i32; self.batch];
        for (b, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                tokens[b] = s.next_token;
                pos[b] = s.pos as i32;
            }
        }
        let t0 = Instant::now();
        let tok_lit = Value::i32(tokens, &[self.batch]).to_literal()?;
        let pos_lit = Value::i32(pos, &[self.batch]).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = self.params_lit.iter().collect();
        inputs.push(&self.kc_lit);
        inputs.push(&self.vc_lit);
        inputs.push(&tok_lit);
        inputs.push(&pos_lit);
        let mut out = self.decode.run_raw(&inputs)?;
        self.stats.decode_time += t0.elapsed();
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += self.live_slots() as f64 / self.batch as f64;

        let logits: Vec<f32> = out[0].to_vec()?;
        let logits = logits.as_slice();
        // feed the output caches straight back as next-step inputs —
        // no host round-trip on the decode hot path
        self.vc_lit = out.pop().unwrap();
        self.kc_lit = out.pop().unwrap();

        let vocab = self.cfg.vocab;
        let mut done = Vec::new();
        for (b, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            let row = &logits[b * vocab..(b + 1) * vocab];
            let tok = sample(row, s.params.temperature, &mut s.rng);
            s.pos += 1;
            self.stats.tokens_generated += 1;
            let stop_hit = s.params.stop_token == Some(tok);
            if !stop_hit {
                s.generated.push(tok);
                s.next_token = tok;
            }
            let len_hit =
                s.generated.len() >= s.params.max_new_tokens || s.pos + 1 >= self.cfg.max_seq;
            if stop_hit || len_hit {
                let now = Instant::now();
                let e2e = now.duration_since(s.arrival).as_secs_f64() * 1e3;
                let ttft = s.first_token_at.duration_since(s.arrival).as_secs_f64() * 1e3;
                let n_after_first = (s.generated.len().max(2) - 1) as f64;
                done.push(Response {
                    id: s.id,
                    tokens: std::mem::take(&mut s.generated),
                    finish: if stop_hit {
                        FinishReason::StopToken
                    } else {
                        FinishReason::MaxTokens
                    },
                    ttft_ms: ttft,
                    tpot_ms: (e2e - ttft) / n_after_first,
                    e2e_ms: e2e,
                });
                *slot = None;
            }
        }
        Ok(done)
    }
}

/// Greedy or temperature sampling over a logits row.
fn sample(logits: &[f32], temperature: f32, rng: &mut Pcg32) -> i32 {
    if temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> =
        logits.iter().map(|&l| ((l - m) / temperature).exp()).collect();
    rng.categorical(&weights) as i32
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_greedy_is_argmax() {
        let mut rng = Pcg32::seeded(1);
        assert_eq!(sample(&[0.1, 3.0, -1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn sample_temperature_covers_support() {
        let mut rng = Pcg32::seeded(2);
        let logits = [1.0f32, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[sample(&logits, 1.0, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_low_temperature_concentrates() {
        let mut rng = Pcg32::seeded(3);
        let logits = [0.0f32, 5.0, 0.0];
        let hits = (0..100)
            .filter(|_| sample(&logits, 0.1, &mut rng) == 1)
            .count();
        assert!(hits > 95);
    }
}
