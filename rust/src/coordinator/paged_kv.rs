//! Physical paged KV cache for the native serving backend.
//!
//! [`PagedKvStore`] owns fixed-size blocks whose payload is
//! per-(layer, kv-head) [`KvPage`]s — the paper's §3 quantize-once decode
//! state (smoothed INT8 K rows + block-local scales, per-channel INT8 V
//! scales or fp16-rounded V rows, and the raw fp32 rows as requant
//! source), paged at [`PAGE_ROWS`] rows per block so every page is
//! quantization-self-contained. Blocks are indexed by the
//! [`KvCacheManager`]'s block tables: the logical accountant decides
//! *which* block ids a sequence owns, this store holds *what* lives in
//! them, and the two agree row-for-row (a block covers the same
//! [`PAGE_ROWS`]-token span on both sides).
//!
//! Decode steps run attention directly against the resident pages
//! ([`PagedKvStore::attention`] → [`PagedSegment::run`]), never
//! re-quantizing a resident prefix — bit-identical to the one-shot
//! [`crate::attn::AttnSpec::prepare`]/`run_prepared` path.

use std::collections::{HashMap, HashSet};

use crate::attn::isa;
use crate::attn::{
    gather_raw, AttnImpl, KvPage, PagedSegment, PlaneOpts, PvMode, Scratch, PAGE_ROWS,
};
use crate::util::error::{ensure, Context, Result};

use super::kv_cache::{AllocError, BlockId, KvCacheManager};
use super::request::RequestId;

/// Physical paged KV storage (see module docs).
#[derive(Debug)]
pub struct PagedKvStore {
    n_layers: usize,
    h_kv: usize,
    d: usize,
    imp: AttnImpl,
    /// Block id → per-(layer, kv-head) page payloads
    /// (`n_layers * h_kv` pages per block), bound on first append.
    blocks: HashMap<BlockId, Vec<KvPage>>,
    /// Per-sequence segment metadata (`n_layers * h_kv` entries; O(d)
    /// each — every per-row quantity lives in the blocks).
    segs: HashMap<RequestId, Vec<PagedSegment>>,
}

impl PagedKvStore {
    /// A store for `n_layers` layers of `h_kv` KV heads at head dim `d`,
    /// quantized for `imp` (must have a quantize-once state; FP8 and
    /// per-tensor/per-channel Q/K are rejected like `AttnSpec::prepare`).
    pub fn new(n_layers: usize, h_kv: usize, d: usize, imp: AttnImpl) -> Result<PagedKvStore> {
        // probe: fails fast for kernels without pageable state
        PagedSegment::new(d, imp)?;
        Ok(PagedKvStore {
            n_layers,
            h_kv,
            d,
            imp,
            blocks: HashMap::new(),
            segs: HashMap::new(),
        })
    }

    pub fn kernel(&self) -> AttnImpl {
        self.imp
    }

    pub fn page_rows(&self) -> usize {
        PAGE_ROWS
    }

    /// Register a sequence (empty segments; rows arrive via
    /// [`PagedKvStore::append_layer`]).
    pub fn register(&mut self, id: RequestId) -> Result<()> {
        ensure!(!self.segs.contains_key(&id), "sequence {id} already registered");
        let mut segs = Vec::with_capacity(self.n_layers * self.h_kv);
        for _ in 0..self.n_layers * self.h_kv {
            segs.push(PagedSegment::new(self.d, self.imp)?);
        }
        self.segs.insert(id, segs);
        Ok(())
    }

    pub fn is_registered(&self, id: RequestId) -> bool {
        self.segs.contains_key(&id)
    }

    /// Resident KV rows of a sequence.
    pub fn rows(&self, id: RequestId) -> Option<usize> {
        self.segs.get(&id).map(|s| s[0].n())
    }

    /// Live sequences (must mirror the logical accountant).
    pub fn live_sequences(&self) -> usize {
        self.segs.len()
    }

    /// Append `t` new KV rows for every head of `layer` (row-major
    /// `(h_kv, t, d)` K and V), writing into the physical blocks named
    /// by `table` (the sequence's block table from the accountant).
    pub fn append_layer(
        &mut self,
        id: RequestId,
        table: &[BlockId],
        layer: usize,
        k: &[f32],
        v: &[f32],
        t: usize,
    ) -> Result<()> {
        ensure!(layer < self.n_layers, "layer {layer} out of range");
        ensure!(k.len() == self.h_kv * t * self.d && v.len() == k.len(), "KV row shape mismatch");
        let n = self
            .segs
            .get(&id)
            .with_context(|| format!("sequence {id} not registered"))?[layer * self.h_kv]
            .n();
        ensure!(
            table.len() * PAGE_ROWS >= n + t,
            "block table of {} blocks cannot hold {} rows (logical/physical divergence)",
            table.len(),
            n + t
        );
        let planes = self.n_layers * self.h_kv;
        for h in 0..self.h_kv {
            let plane = layer * self.h_kv + h;
            // take the plane's pages out of the blocks, append, put back
            // (safe multi-index mutation without unsafe aliasing)
            let mut pages: Vec<KvPage> = Vec::with_capacity(table.len());
            for b in table {
                let blk = self
                    .blocks
                    .entry(*b)
                    .or_insert_with(|| vec![KvPage::new(); planes]);
                pages.push(std::mem::take(&mut blk[plane]));
            }
            let rows = h * t * self.d..(h + 1) * t * self.d;
            let seg = &mut self.segs.get_mut(&id).unwrap()[plane];
            seg.append(&mut pages, &k[rows.clone()], &v[rows]);
            for (b, pg) in table.iter().zip(pages) {
                self.blocks.get_mut(b).expect("block bound above")[plane] = pg;
            }
        }
        Ok(())
    }

    /// Attention for `h_q` query heads of `layer` against the resident
    /// pages (GQA: `h_q` must be a multiple of the store's KV heads).
    /// `q` is row-major `(h_q, n_q, d)`; the output matches. The decode
    /// hot path: quantized K/V is read through the block table, only Q
    /// is quantized per call.
    #[allow(clippy::too_many_arguments)]
    pub fn attention(
        &self,
        id: RequestId,
        table: &[BlockId],
        layer: usize,
        q: &[f32],
        h_q: usize,
        n_q: usize,
        scratch: &mut Scratch,
        opts: PlaneOpts,
    ) -> Result<Vec<f32>> {
        let segs = self
            .segs
            .get(&id)
            .with_context(|| format!("sequence {id} not registered"))?;
        ensure!(
            h_q >= self.h_kv && h_q % self.h_kv == 0,
            "{} query heads not a multiple of {} KV heads",
            h_q,
            self.h_kv
        );
        ensure!(q.len() == h_q * n_q * self.d, "Q shape mismatch");
        let group = h_q / self.h_kv;
        let mut out = vec![0.0f32; h_q * n_q * self.d];
        for qh in 0..h_q {
            let plane = layer * self.h_kv + qh / group;
            let seg = &segs[plane];
            let pages = self.plane_pages(table, plane, seg.n())?;
            // warm the first page while the kernel quantizes Q — the
            // block table just chased HashMap pointers, so the page rows
            // are a likely cache miss; the tile loop prefetches the rest
            // (attn::isa::prefetch_head)
            if let Some(first) = pages.first() {
                isa::prefetch_head(&first.k_i8);
                isa::prefetch_head(&first.k_scales);
            }
            let qh_rows = &q[qh * n_q * self.d..(qh + 1) * n_q * self.d];
            let o = seg.run(scratch, qh_rows, n_q, &pages, opts);
            out[qh * n_q * self.d..(qh + 1) * n_q * self.d].copy_from_slice(&o);
        }
        Ok(out)
    }

    /// Raw fp32 K/V rows of one (layer, kv-head) plane, gathered through
    /// the block table — the requant-every-step serving baseline (and
    /// recompute source).
    pub fn gather_layer_raw(
        &self,
        id: RequestId,
        table: &[BlockId],
        layer: usize,
        head: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let segs = self
            .segs
            .get(&id)
            .with_context(|| format!("sequence {id} not registered"))?;
        let plane = layer * self.h_kv + head;
        let n = segs[plane].n();
        let pages = self.plane_pages(table, plane, n)?;
        Ok(gather_raw(&pages, n, self.d))
    }

    /// Share `src`'s entire resident state with a new sequence `dst`
    /// (parallel-sampling / beam fan-out). Pages are *not* copied: both
    /// sequences resolve the same blocks through their tables, under
    /// the accountant's refcounts (`KvCacheManager::fork`); the first
    /// append on either side goes through [`PagedKvStore::prepare_append`],
    /// which copies any still-shared block it would dirty.
    pub fn fork(&mut self, src: RequestId, dst: RequestId) -> Result<()> {
        let rows = self.rows(src).with_context(|| format!("sequence {src} not registered"))?;
        self.fork_prefix(src, dst, rows)
    }

    /// Share the first `rows` resident rows of `src` with a new
    /// sequence `dst` — the physical half of a prefix-cache hit. Only
    /// the O(d)-per-plane segment metadata is cloned
    /// ([`PagedSegment::fork_prefix`]); the rows stay in shared pages.
    /// `rows` must equal `src`'s resident count or cut on a page
    /// boundary (pages are quantization-self-contained only as wholes).
    pub fn fork_prefix(&mut self, src: RequestId, dst: RequestId, rows: usize) -> Result<()> {
        ensure!(!self.segs.contains_key(&dst), "sequence {dst} already registered");
        let src_segs = self
            .segs
            .get(&src)
            .with_context(|| format!("sequence {src} not registered"))?;
        let mut segs = Vec::with_capacity(src_segs.len());
        for s in src_segs {
            segs.push(s.fork_prefix(rows)?);
        }
        self.segs.insert(dst, segs);
        Ok(())
    }

    /// Copy-on-write barrier: before appending `t` rows to `id`, give it
    /// exclusive ownership of every block the append will rewrite — the
    /// tail span of the new rows plus the trailing partial K scale group
    /// ([`PagedSegment::mutation_horizon`]; block-granular K scales can
    /// reach one page further back than the tail). Shared blocks are
    /// swapped for fresh ones by the accountant ([`KvCacheManager::cow_block`])
    /// and their payload cloned here; returns the number of payload
    /// copies made. `Err(OutOfBlocks)` feeds the caller's preemption
    /// path — a partial CoW left behind is consistent (already-copied
    /// blocks are exclusively owned and skipped on retry). The caller
    /// must have extended the logical table to cover `t` more rows.
    pub fn prepare_append(
        &mut self,
        id: RequestId,
        kv: &mut KvCacheManager,
        t: usize,
    ) -> std::result::Result<usize, AllocError> {
        let Some(segs) = self.segs.get(&id) else {
            return Err(AllocError::UnknownSequence);
        };
        if t == 0 {
            return Ok(0);
        }
        let n = segs[0].n();
        let first = segs[0].mutation_horizon(n) / PAGE_ROWS;
        let last = (n + t - 1) / PAGE_ROWS;
        let table_len = kv.seq_blocks(id).ok_or(AllocError::Corrupt)?.len();
        if last >= table_len {
            return Err(AllocError::Corrupt); // caller skipped the logical extend
        }
        let mut copied = 0;
        for idx in first..=last {
            let (old, new) = kv.cow_block(id, idx)?;
            if old == new {
                continue;
            }
            // a shared-but-unbound block (reserved, no rows yet) has no
            // payload to carry over — the swap alone suffices
            if let Some(payload) = self.blocks.get(&old).cloned() {
                self.blocks.insert(new, payload);
                copied += 1;
            }
        }
        Ok(copied)
    }

    /// Drop a sequence and reclaim the payload of blocks this release
    /// takes to `rc == 0`. Call *before* the logical
    /// [`KvCacheManager::release`]: the accountant still holds the
    /// table, and a block with `rc > 1` is still owned by another
    /// sequence (or a cached prefix), so its payload must survive. A
    /// missing table or a zero refcount on a table block means the
    /// table and the refcounts disagree — a loud error in release
    /// builds too, with the store untouched.
    pub fn release(&mut self, id: RequestId, kv: &KvCacheManager) -> Result<()> {
        ensure!(self.segs.contains_key(&id), "sequence {id} not registered");
        let table = kv.seq_blocks(id).with_context(|| {
            format!("sequence {id}: physical pages but no logical table (table/refcount disagreement)")
        })?;
        ensure!(
            table.iter().all(|&b| kv.ref_count(b) > 0),
            "sequence {id}: table references a block with rc 0 (table/refcount disagreement)"
        );
        self.segs.remove(&id);
        for &b in table {
            if kv.ref_count(b) == 1 {
                self.blocks.remove(&b);
            }
        }
        Ok(())
    }

    /// Resident physical payload in bytes (telemetry).
    pub fn resident_bytes(&self) -> usize {
        self.blocks
            .values()
            .map(|blk| blk.iter().map(KvPage::payload_bytes).sum::<usize>())
            .sum()
    }

    /// Physical/logical agreement check (the invariant tests' hook):
    /// all planes of a sequence agree on the row count, the logical
    /// block table covers the physical rows, and every block holding
    /// rows is bound. `tables` resolves a sequence to its accountant
    /// block table (`None` = unknown to the accountant).
    pub fn check_agreement(
        &self,
        tables: impl Fn(RequestId) -> Option<Vec<BlockId>>,
    ) -> std::result::Result<(), String> {
        for (&id, segs) in &self.segs {
            let n = segs[0].n();
            if segs.iter().any(|s| s.n() != n) {
                return Err(format!("sequence {id}: planes disagree on row count"));
            }
            let Some(table) = tables(id) else {
                return Err(format!("sequence {id}: physical rows but no logical table"));
            };
            if table.len() * PAGE_ROWS < n {
                return Err(format!(
                    "sequence {id}: {} logical blocks < {n} physical rows",
                    table.len()
                ));
            }
            for (i, b) in table.iter().enumerate() {
                let expect = n.saturating_sub(i * PAGE_ROWS).min(PAGE_ROWS);
                if expect == 0 {
                    continue; // reserved but not yet written
                }
                let Some(blk) = self.blocks.get(b) else {
                    return Err(format!("sequence {id}: row-bearing block {b} unbound"));
                };
                // shared blocks may hold more rows than a prefix-forked
                // sequence expects, never fewer
                let have = blk[0].rows(self.d);
                if have < expect {
                    return Err(format!(
                        "sequence {id}: block {b} holds {have} rows, expected ≥ {expect}"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Deep physical↔logical audit for the invariant harness:
    /// everything [`PagedKvStore::check_agreement`] checks, plus
    /// per-page internal consistency (plane row agreement and quantized
    /// payload lengths per the store's kernel), refcount agreement (a
    /// row-bearing block is referenced; an *exclusively* owned one holds
    /// exactly the rows its sequence expects), and leak detection (every
    /// bound payload is reachable from some live table).
    pub fn audit(
        &self,
        tables: impl Fn(RequestId) -> Option<Vec<BlockId>>,
        ref_count: impl Fn(BlockId) -> u32,
    ) -> std::result::Result<(), String> {
        self.check_agreement(&tables)?;
        let mut reachable: HashSet<BlockId> = HashSet::new();
        for (&id, segs) in &self.segs {
            let n = segs[0].n();
            let table = tables(id).expect("checked by check_agreement");
            for (i, &b) in table.iter().enumerate() {
                reachable.insert(b);
                let expect = n.saturating_sub(i * PAGE_ROWS).min(PAGE_ROWS);
                if expect == 0 {
                    continue;
                }
                let blk = self.blocks.get(&b).expect("checked by check_agreement");
                let rows = blk[0].rows(self.d);
                for (p, pg) in blk.iter().enumerate() {
                    if pg.rows(self.d) != rows {
                        return Err(format!(
                            "block {b}: plane {p} holds {} rows, plane 0 holds {rows}",
                            pg.rows(self.d)
                        ));
                    }
                    if let Err(e) = self.page_consistent(pg, rows) {
                        return Err(format!("block {b} plane {p}: {e}"));
                    }
                }
                let rc = ref_count(b);
                if rc == 0 {
                    return Err(format!("row-bearing block {b} has rc 0"));
                }
                if rc == 1 && rows != expect {
                    return Err(format!(
                        "block {b}: exclusively owned with {rows} rows but sequence {id} expects {expect}"
                    ));
                }
            }
        }
        for &b in self.blocks.keys() {
            if !reachable.contains(&b) {
                return Err(format!(
                    "block {b}: payload bound but no live table references it (leak)"
                ));
            }
        }
        Ok(())
    }

    /// One page's internal consistency against its resident row count.
    fn page_consistent(&self, pg: &KvPage, rows: usize) -> std::result::Result<(), String> {
        let d = self.d;
        if pg.k_raw.len() != rows * d || pg.v_raw.len() != rows * d {
            return Err(format!(
                "raw payload covers {}/{} K and {}/{} V rows",
                pg.k_raw.len() / d,
                rows,
                pg.v_raw.len() / d,
                rows
            ));
        }
        if let AttnImpl::Sage { pv, .. } = self.imp {
            if pg.k_i8.len() != rows * d || pg.k_scales.len() != rows {
                return Err(format!("INT8 K covers {}/{rows} rows", pg.k_i8.len() / d));
            }
            match pv {
                PvMode::Int8 => {
                    if pg.v_i8.len() != rows * d || (rows > 0 && pg.v_scales.len() != d) {
                        return Err(format!("INT8 V covers {}/{rows} rows", pg.v_i8.len() / d));
                    }
                }
                _ => {
                    if pg.v_f16.len() != rows * d {
                        return Err(format!("f16 V covers {}/{rows} rows", pg.v_f16.len() / d));
                    }
                }
            }
        }
        Ok(())
    }

    fn plane_pages<'a>(
        &'a self,
        table: &[BlockId],
        plane: usize,
        n: usize,
    ) -> Result<Vec<&'a KvPage>> {
        ensure!(
            table.len() * PAGE_ROWS >= n,
            "block table of {} blocks cannot cover {n} resident rows",
            table.len()
        );
        let mut pages = Vec::with_capacity(table.len());
        for (i, b) in table.iter().enumerate() {
            if i * PAGE_ROWS >= n {
                break; // trailing blocks reserved but not yet written
            }
            let blk = self
                .blocks
                .get(b)
                .with_context(|| format!("block {b} in table but unbound in the paged store"))?;
            pages.push(&blk[plane]);
        }
        Ok(pages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::{AttnSpec, SAGE_B};
    use crate::synth::{make_qkv, Profile};

    #[test]
    fn paged_store_matches_one_shot_prepared_kv() {
        // the serving invariant: decode through pages == AttnSpec's
        // one-shot PreparedKV path, bit for bit
        let (n, d, h) = (150usize, 32usize, 2usize);
        let (q, k, v) = make_qkv(61, [1, h, n, d], Profile::diffusion_like());
        let spec = AttnSpec::sage_b().causal(true);
        let kv = spec.prepare(&k, &v).unwrap();
        let gold = spec.run_prepared(&q.narrow_n(n - 1, n), &kv).unwrap();

        let mut store = PagedKvStore::new(1, h, d, SAGE_B).unwrap();
        store.register(7).unwrap();
        let table: Vec<BlockId> = (0..n.div_ceil(PAGE_ROWS) as BlockId).collect();
        // interleave per-head rows into (h, t, d) chunks and append
        let mut r = 0;
        for step in [64usize, 1, 30].iter().cycle() {
            if r >= n {
                break;
            }
            let e = (r + step).min(n);
            let t = e - r;
            let mut kc = Vec::with_capacity(h * t * d);
            let mut vc = Vec::with_capacity(h * t * d);
            for hi in 0..h {
                kc.extend_from_slice(&k.head(0, hi)[r * d..e * d]);
                vc.extend_from_slice(&v.head(0, hi)[r * d..e * d]);
            }
            store.append_layer(7, &table, 0, &kc, &vc, t).unwrap();
            r = e;
        }
        assert_eq!(store.rows(7), Some(n));

        let mut scratch = Scratch::new();
        let mut q_last = Vec::with_capacity(h * d);
        for hi in 0..h {
            q_last.extend_from_slice(&q.head(0, hi)[(n - 1) * d..n * d]);
        }
        let out = store
            .attention(7, &table, 0, &q_last, h, 1, &mut scratch, PlaneOpts::causal(true))
            .unwrap();
        assert_eq!(out, gold.data, "paged attention != one-shot PreparedKV");
    }

    #[test]
    fn release_reclaims_blocks() {
        let (n, d) = (100usize, 16usize);
        let (_, k, v) = make_qkv(62, [1, 1, n, d], Profile::llama_like());
        let mut store = PagedKvStore::new(1, 1, d, SAGE_B).unwrap();
        let mut kv = KvCacheManager::new(8, PAGE_ROWS);
        kv.allocate(1, n).unwrap();
        store.register(1).unwrap();
        let table = kv.seq_blocks(1).unwrap().to_vec();
        store.append_layer(1, &table, 0, &k.data, &v.data, n).unwrap();
        assert!(store.resident_bytes() > 0);
        assert_eq!(store.live_sequences(), 1);
        store.release(1, &kv).unwrap();
        kv.release(1).unwrap();
        assert_eq!(store.live_sequences(), 0);
        assert_eq!(store.resident_bytes(), 0);
        assert!(store.release(1, &kv).is_err());
    }

    #[test]
    fn cow_gives_writer_private_copies_and_preserves_shared_rows() {
        let d = 16usize;
        let n = PAGE_ROWS + 10; // partial tail block
        let (_, k, v) = make_qkv(64, [1, 1, n + 1, d], Profile::llama_like());
        let mut store = PagedKvStore::new(1, 1, d, SAGE_B).unwrap();
        let mut kv = KvCacheManager::new(8, PAGE_ROWS);
        kv.allocate(1, n).unwrap();
        store.register(1).unwrap();
        let t1 = kv.seq_blocks(1).unwrap().to_vec();
        store.append_layer(1, &t1, 0, &k.data[..n * d], &v.data[..n * d], n).unwrap();

        kv.fork(1, 2).unwrap();
        store.fork(1, 2).unwrap();

        // seq 2 appends one row: SAGE_B's K scale group (BLOCK_Q = 128)
        // spans both pages, so the CoW barrier must copy *both* shared
        // blocks, not just the tail
        let free_before = kv.free_blocks();
        kv.extend(2, 1).unwrap();
        let copied = store.prepare_append(2, &mut kv, 1).unwrap();
        assert_eq!(copied, 2);
        assert_eq!(kv.free_blocks(), free_before - 2);
        let t2 = kv.seq_blocks(2).unwrap().to_vec();
        assert_ne!(t1, t2, "writer must have private blocks after CoW");
        store.append_layer(2, &t2, 0, &k.data[n * d..], &v.data[n * d..], 1).unwrap();

        // seq 1's rows are bit-identical to before the fork
        let (k1, v1) = store.gather_layer_raw(1, &t1, 0, 0).unwrap();
        assert_eq!(k1, k.data[..n * d]);
        assert_eq!(v1, v.data[..n * d]);
        // and the full audit holds
        kv.check_invariants().unwrap();
        store
            .audit(|id| kv.seq_blocks(id).map(<[BlockId]>::to_vec), |b| kv.ref_count(b))
            .unwrap();

        // releases reclaim exactly the unshared payloads
        store.release(2, &kv).unwrap();
        kv.release(2).unwrap();
        store.release(1, &kv).unwrap();
        kv.release(1).unwrap();
        assert_eq!(store.resident_bytes(), 0);
        assert_eq!(kv.free_blocks(), 8);
    }

    #[test]
    fn divergence_is_detected() {
        let d = 16usize;
        let (_, k, v) = make_qkv(63, [1, 1, PAGE_ROWS * 2, d], Profile::llama_like());
        let mut store = PagedKvStore::new(1, 1, d, SAGE_B).unwrap();
        store.register(1).unwrap();
        // table too small for the rows → logical/physical divergence
        let err = store.append_layer(1, &[0], 0, &k.data, &v.data, PAGE_ROWS * 2);
        assert!(err.is_err());
        // unknown sequence
        assert!(store.append_layer(9, &[0], 0, &k.data, &v.data, 1).is_err());
    }
}
