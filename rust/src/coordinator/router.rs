//! Request router over model replicas (the multi-engine front door,
//! vllm-project/router-shaped). Replicas expose a load score; policies
//! pick a target. The router is generic over [`Replica`] so it is testable
//! without PJRT and reusable for heterogeneous backends.

use super::request::Request;
use super::scheduler::Scheduler;

/// Anything that can accept routed requests.
pub trait Replica {
    fn id(&self) -> usize;
    /// Current load score (higher = busier). Units are implementation-
    /// defined but must be comparable across replicas of one router.
    fn load(&self) -> f64;
    /// Hand the request over. Returns false if the replica must refuse
    /// (e.g. admission queue full) so the router can try elsewhere.
    fn submit(&mut self, req: Request) -> bool;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    LeastLoaded,
    /// Least-loaded among the `k` next round-robin candidates — the
    /// "power of two choices" compromise.
    PowerOfK(usize),
}

impl RoutingPolicy {
    /// Parse the `sage serve --route` vocabulary (`rr|least|power2`).
    pub fn by_name(name: &str) -> Option<RoutingPolicy> {
        match name {
            "rr" => Some(RoutingPolicy::RoundRobin),
            "least" => Some(RoutingPolicy::LeastLoaded),
            "power2" => Some(RoutingPolicy::PowerOfK(2)),
            _ => None,
        }
    }

    /// The `--route` name this policy parses from (inverse of
    /// [`RoutingPolicy::by_name`] for the named policies).
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "rr",
            RoutingPolicy::LeastLoaded => "least",
            RoutingPolicy::PowerOfK(_) => "power2",
        }
    }
}

/// A scheduler-backed replica: any [`super::backend::EngineBackend`]
/// behind the [`super::Engine`] facade, fronted by its own batcher + KV
/// accountant. Load is outstanding decode work plus queued requests, so
/// heterogeneous backends (pjrt vs native) are comparable under one
/// router.
pub struct EngineReplica {
    pub id: usize,
    pub sched: Scheduler,
}

impl EngineReplica {
    pub fn new(id: usize, sched: Scheduler) -> EngineReplica {
        EngineReplica { id, sched }
    }
}

impl Replica for EngineReplica {
    fn id(&self) -> usize {
        self.id
    }

    fn load(&self) -> f64 {
        self.sched.engine.outstanding_tokens() as f64 + self.sched.batcher.pending() as f64
    }

    fn submit(&mut self, req: Request) -> bool {
        self.sched.submit(req);
        true
    }
}

/// Stateless-per-request router with per-replica counters.
pub struct Router {
    policy: RoutingPolicy,
    next: usize,
    pub routed: Vec<u64>,
}

impl Router {
    pub fn new(policy: RoutingPolicy, n_replicas: usize) -> Router {
        Router { policy, next: 0, routed: vec![0; n_replicas] }
    }

    /// Route one request (clone-on-try: replicas may refuse and the
    /// router falls through to the next candidate).
    pub fn route<R: Replica>(
        &mut self,
        replicas: &mut [R],
        req: &Request,
    ) -> Option<usize> {
        let n = replicas.len();
        if n == 0 {
            return None;
        }
        let order: Vec<usize> = match self.policy {
            RoutingPolicy::RoundRobin => (0..n).map(|i| (self.next + i) % n).collect(),
            RoutingPolicy::LeastLoaded => {
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_by(|&a, &b| {
                    replicas[a].load().partial_cmp(&replicas[b].load()).unwrap()
                });
                idx
            }
            RoutingPolicy::PowerOfK(k) => {
                let k = k.clamp(1, n);
                let mut cand: Vec<usize> = (0..k).map(|i| (self.next + i) % n).collect();
                cand.sort_by(|&a, &b| {
                    replicas[a].load().partial_cmp(&replicas[b].load()).unwrap()
                });
                cand.extend((k..n).map(|i| (self.next + i) % n));
                cand
            }
        };
        self.next = (self.next + 1) % n;
        for &i in &order {
            if replicas[i].submit(req.clone()) {
                self.routed[i] += 1;
                return Some(i);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;

    struct Mock {
        id: usize,
        load: f64,
        cap: usize,
        accepted: Vec<u64>,
    }

    impl Replica for Mock {
        fn id(&self) -> usize {
            self.id
        }
        fn load(&self) -> f64 {
            self.load
        }
        fn submit(&mut self, req: Request) -> bool {
            if self.accepted.len() >= self.cap {
                return false;
            }
            self.accepted.push(req.id);
            self.load += 1.0;
            true
        }
    }

    fn mocks(loads: &[f64]) -> Vec<Mock> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &load)| Mock { id, load, cap: usize::MAX, accepted: vec![] })
            .collect()
    }

    fn req(id: u64) -> Request {
        Request::new(id, vec![1], GenParams::default())
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutingPolicy::RoundRobin, 3);
        let mut reps = mocks(&[0.0, 0.0, 0.0]);
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(&mut reps, &req(i)).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_min() {
        let mut r = Router::new(RoutingPolicy::LeastLoaded, 3);
        let mut reps = mocks(&[5.0, 1.0, 3.0]);
        assert_eq!(r.route(&mut reps, &req(1)).unwrap(), 1);
        // replica 1 now at 2.0, still least
        assert_eq!(r.route(&mut reps, &req(2)).unwrap(), 1);
        // at 3.0, ties broken by sort stability -> 1 or 2 acceptable
        let third = r.route(&mut reps, &req(3)).unwrap();
        assert!(third == 1 || third == 2);
    }

    #[test]
    fn refusal_falls_through() {
        let mut r = Router::new(RoutingPolicy::RoundRobin, 2);
        let mut reps = mocks(&[0.0, 0.0]);
        reps[0].cap = 0; // always refuses
        for i in 0..4 {
            assert_eq!(r.route(&mut reps, &req(i)).unwrap(), 1);
        }
        assert_eq!(reps[1].accepted.len(), 4);
    }

    #[test]
    fn all_refuse_returns_none() {
        let mut r = Router::new(RoutingPolicy::LeastLoaded, 2);
        let mut reps = mocks(&[0.0, 0.0]);
        reps[0].cap = 0;
        reps[1].cap = 0;
        assert!(r.route(&mut reps, &req(1)).is_none());
    }

    #[test]
    fn policy_names_round_trip() {
        for name in ["rr", "least", "power2"] {
            let p = RoutingPolicy::by_name(name).expect(name);
            assert_eq!(p.name(), name);
        }
        assert_eq!(RoutingPolicy::by_name("random"), None);
        assert_eq!(RoutingPolicy::by_name("power2"), Some(RoutingPolicy::PowerOfK(2)));
    }

    #[test]
    fn power_of_k_prefers_lighter_of_window() {
        let mut r = Router::new(RoutingPolicy::PowerOfK(2), 3);
        let mut reps = mocks(&[9.0, 1.0, 5.0]);
        // window {0,1}: picks 1
        assert_eq!(r.route(&mut reps, &req(1)).unwrap(), 1);
    }
}
