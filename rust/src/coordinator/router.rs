//! Request router over model replicas (the multi-engine front door,
//! vllm-project/router-shaped). Replicas expose a load score; policies
//! pick a target. The router is generic over [`Replica`] so it is testable
//! without PJRT and reusable for heterogeneous backends.
//!
//! [`Fleet`] is the fault-tolerant driver on top (ISSUE 7 tentpole §2):
//! it owns one supervised [`Scheduler`] per replica and drives them
//! round-robin in deterministic virtual time. Per-replica supervision
//! tracks consecutive step failures behind a circuit breaker
//! (closed → open → half-open), fails crashed replicas over by
//! re-routing their drained queue + in-flight requests (recompute-on-
//! resume), enforces per-request retry budgets with exponential backoff,
//! and sweeps TTFT/total deadlines — every request terminates in a typed
//! [`Response`], never a silent drop.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::{EventKind, Obs, NO_ID, NO_REPLICA};
use crate::util::error::{ensure, Result};

use super::fault::is_crash;
use super::request::{FinishReason, Request, RequestId, Response};
use super::scheduler::{Scheduler, SchedulerReport};
use super::traffic::{estimate_ttft_ticks, ChunkCfg, SloTargets, StreamLedger, TokenSink};

/// Anything that can accept routed requests.
pub trait Replica {
    fn id(&self) -> usize;
    /// Current load score (higher = busier). Units are implementation-
    /// defined but must be comparable across replicas of one router.
    fn load(&self) -> f64;
    /// Hand the request over. Returns false if the replica must refuse
    /// (e.g. admission queue full) so the router can try elsewhere.
    fn submit(&mut self, req: Request) -> bool;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    LeastLoaded,
    /// Least-loaded among the `k` next round-robin candidates — the
    /// "power of two choices" compromise.
    PowerOfK(usize),
}

impl RoutingPolicy {
    /// Parse the `sage serve --route` vocabulary (`rr|least|power2`).
    pub fn by_name(name: &str) -> Option<RoutingPolicy> {
        match name {
            "rr" => Some(RoutingPolicy::RoundRobin),
            "least" => Some(RoutingPolicy::LeastLoaded),
            "power2" => Some(RoutingPolicy::PowerOfK(2)),
            _ => None,
        }
    }

    /// The `--route` name this policy parses from (inverse of
    /// [`RoutingPolicy::by_name`] for the named policies).
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "rr",
            RoutingPolicy::LeastLoaded => "least",
            RoutingPolicy::PowerOfK(_) => "power2",
        }
    }
}

/// A scheduler-backed replica: any [`super::backend::EngineBackend`]
/// behind the [`super::Engine`] facade, fronted by its own batcher + KV
/// accountant. Load is outstanding decode work plus queued requests, so
/// heterogeneous backends (pjrt vs native) are comparable under one
/// router.
pub struct EngineReplica {
    pub id: usize,
    pub sched: Scheduler,
}

impl EngineReplica {
    pub fn new(id: usize, sched: Scheduler) -> EngineReplica {
        EngineReplica { id, sched }
    }
}

impl Replica for EngineReplica {
    fn id(&self) -> usize {
        self.id
    }

    fn load(&self) -> f64 {
        self.sched.engine.outstanding_tokens() as f64 + self.sched.batcher.pending() as f64
    }

    fn submit(&mut self, req: Request) -> bool {
        self.sched.submit(req);
        true
    }
}

/// Why a request could not be routed. Callers must handle this —
/// typically by requeueing with backoff at the fleet level — never by
/// dropping the request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// The replica set is empty.
    NoReplicas,
    /// Every replica refused the request (full queues, open breakers,
    /// crashed replicas).
    AllRefused,
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::NoReplicas => write!(f, "no replicas to route to"),
            RouteError::AllRefused => write!(f, "every replica refused the request"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Stateless-per-request router with per-replica counters.
pub struct Router {
    policy: RoutingPolicy,
    next: usize,
    pub routed: Vec<u64>,
}

impl Router {
    pub fn new(policy: RoutingPolicy, n_replicas: usize) -> Router {
        Router { policy, next: 0, routed: vec![0; n_replicas] }
    }

    /// Route one request (clone-on-try: replicas may refuse and the
    /// router falls through to the next candidate). An all-refuse
    /// outcome is a typed [`RouteError`], not a silent drop.
    pub fn route<R: Replica>(
        &mut self,
        replicas: &mut [R],
        req: &Request,
    ) -> Result<usize, RouteError> {
        let n = replicas.len();
        if n == 0 {
            return Err(RouteError::NoReplicas);
        }
        let order: Vec<usize> = match self.policy {
            RoutingPolicy::RoundRobin => (0..n).map(|i| (self.next + i) % n).collect(),
            RoutingPolicy::LeastLoaded => {
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_by(|&a, &b| {
                    replicas[a].load().partial_cmp(&replicas[b].load()).unwrap()
                });
                idx
            }
            RoutingPolicy::PowerOfK(k) => {
                let k = k.clamp(1, n);
                let mut cand: Vec<usize> = (0..k).map(|i| (self.next + i) % n).collect();
                cand.sort_by(|&a, &b| {
                    replicas[a].load().partial_cmp(&replicas[b].load()).unwrap()
                });
                cand.extend((k..n).map(|i| (self.next + i) % n));
                cand
            }
        };
        self.next = (self.next + 1) % n;
        for &i in &order {
            if replicas[i].submit(req.clone()) {
                self.routed[i] += 1;
                return Ok(i);
            }
        }
        Err(RouteError::AllRefused)
    }
}

// ---------------------------------------------------------------------------
// Fleet: supervised replicas + recovery (ISSUE 7 tentpole §2)
// ---------------------------------------------------------------------------

/// Circuit-breaker state for one replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Breaker {
    /// Healthy: admissions flow.
    Closed,
    /// Tripped: refuses admissions and is not stepped until virtual
    /// tick `until` (crashes use `u64::MAX` — permanently open).
    Open { until: u64 },
    /// Cooldown elapsed: accepting probe traffic; the next step result
    /// decides between `Closed` and another `Open` period.
    HalfOpen,
}

/// Fleet recovery policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct FleetCfg {
    /// Per-request retry budget: a request drained off an errored
    /// replica more than this many times terminally fails.
    pub max_retries: u32,
    /// Exponential backoff base (ticks): retry `k` waits `base^k`.
    pub backoff_base: u64,
    /// Consecutive step failures that open a replica's breaker.
    pub breaker_threshold: u32,
    /// Ticks an opened breaker stays open before half-opening.
    pub breaker_cooldown: u64,
    /// Hard stop for the driving loop (defense against a fault spec
    /// that can never make progress, e.g. `oom:1.0`).
    pub max_ticks: u64,
    /// Prefill rows one replica tick can absorb (the chunked-prefill
    /// tick budget) — the drain rate the SLO admission estimator uses.
    /// `None` = whole-prompt prefill at admission: a tick absorbs any
    /// prompt, so the estimator only sees queueing, not prefill length.
    pub tick_prefill_rows: Option<usize>,
}

impl Default for FleetCfg {
    fn default() -> FleetCfg {
        FleetCfg {
            max_retries: 3,
            backoff_base: 2,
            breaker_threshold: 2,
            breaker_cooldown: 8,
            max_ticks: 1_000_000,
            tick_prefill_rows: None,
        }
    }
}

/// One supervised replica: a scheduler plus its health state.
struct Supervised {
    id: usize,
    sched: Scheduler,
    breaker: Breaker,
    consec_failures: u32,
    crashed: bool,
}

impl Replica for Supervised {
    fn id(&self) -> usize {
        self.id
    }

    fn load(&self) -> f64 {
        if self.crashed {
            return f64::INFINITY;
        }
        self.sched.engine.outstanding_tokens() as f64 + self.sched.batcher.pending() as f64
    }

    fn submit(&mut self, req: Request) -> bool {
        // open breakers and dead replicas refuse; half-open accepts the
        // probe traffic that decides recovery
        if self.crashed || matches!(self.breaker, Breaker::Open { .. }) {
            return false;
        }
        self.sched.submit(req);
        true
    }
}

/// Per-request supervision state.
struct Meta {
    retries: u32,
    submitted_at: u64,
    ttft_deadline: Option<u64>,
    total_deadline: Option<u64>,
    done: bool,
    /// SLO *targets* (soft, shed/report) — distinct from the hard
    /// deadlines above (cancel).
    slo: SloTargets,
    /// Virtual tick the first streamed token appeared (needs the
    /// streaming ledger installed via [`Fleet::enable_streaming`]).
    first_token_tick: Option<u64>,
    /// Whether the finished response met its SLO targets; `None` until
    /// terminal (and for untracked requests).
    slo_met: Option<bool>,
}

/// A request waiting (or backing off) at the fleet level.
struct Pending {
    req: Request,
    not_before: u64,
}

/// Did a *successful* response land inside its SLO targets? TTFT is
/// first-streamed-token tick minus arrival; TPOT is the mean decode
/// interval after the first token (vacuously met for single-token
/// responses). A tracked request that never streamed — possible only
/// when the ledger is not installed — counts as a miss rather than a
/// silent pass.
fn slo_satisfied(m: &Meta, tokens: usize, now: u64) -> bool {
    let ttft_ok = match (m.slo.ttft_ticks, m.first_token_tick) {
        (Some(target), Some(first)) => first.saturating_sub(m.submitted_at) <= target,
        (Some(_), None) => false,
        (None, _) => true,
    };
    let tpot_ok = match (m.slo.tpot_ticks, m.first_token_tick) {
        (Some(target), Some(first)) if tokens > 1 => {
            now.saturating_sub(first) as f64 / (tokens - 1) as f64 <= target
        }
        (Some(_), None) => false,
        _ => true,
    };
    ttft_ok && tpot_ok
}

/// Aggregated outcome of a fleet run.
#[derive(Debug, Default)]
pub struct FleetReport {
    pub submitted: u64,
    /// Successful completions.
    pub served: u64,
    /// Typed terminal failures (retry budget, unservable, fleet down).
    pub failed: u64,
    /// Deadline cancellations.
    pub cancelled_deadline: u64,
    /// Requests shed by SLO admission control (estimated TTFT beyond
    /// target at dispatch — turned away, never started).
    pub shed: u64,
    /// Requests carrying SLO targets (the goodput denominator).
    pub slo_tracked: u64,
    /// Tracked requests that finished within their targets.
    pub slo_met: u64,
    /// Requests re-dispatched after a transient replica error.
    pub retried: u64,
    /// Requests re-routed off a crashed replica.
    pub failed_over: u64,
    /// Faults injected across all replicas (fault plane active).
    pub injected: u64,
    /// Numeric-guard fp-path retries across all replicas.
    pub degraded_fallbacks: u64,
    /// Requests that left without any terminal response — must be 0.
    pub dropped: u64,
    /// Tokens streamed through the fleet ledger (0 when streaming was
    /// not enabled).
    pub streamed_tokens: u64,
    /// Duplicate streamed indices the ledger flagged — double emission
    /// across failover/preemption; must stay 0.
    pub stream_duplicates: u64,
    /// Skipped streamed indices the ledger flagged — must stay 0.
    pub stream_gaps: u64,
    /// Virtual ticks the run took.
    pub ticks: u64,
    pub wall_s: f64,
    /// `hist[k]` = requests that needed exactly `k` retries
    /// (`hist.last()` buckets `>= max_retries + 1`).
    pub retries_hist: Vec<u64>,
    /// Every terminal response, sorted by request id.
    pub responses: Vec<Response>,
    /// Per-replica scheduler reports (routing/latency detail).
    pub replicas: Vec<SchedulerReport>,
}

impl FleetReport {
    /// Terminal accounting: every submitted request left through a
    /// response (`served + failed + cancelled + shed == submitted`).
    pub fn fully_accounted(&self) -> bool {
        self.dropped == 0
            && self.served + self.failed + self.cancelled_deadline + self.shed
                == self.submitted
    }

    /// Goodput under SLO: fraction of SLO-tracked requests that were
    /// served within their targets. Shed and failed tracked requests
    /// count as misses — shedding trades individual misses for keeping
    /// the admitted set on target, it does not launder them away.
    pub fn goodput_under_slo_frac(&self) -> f64 {
        if self.slo_tracked == 0 {
            1.0
        } else {
            self.slo_met as f64 / self.slo_tracked as f64
        }
    }

    pub fn tokens_out(&self) -> u64 {
        self.responses.iter().map(|r| r.tokens.len() as u64).sum()
    }
}

/// Deterministic fault-tolerant driver over supervised replicas.
///
/// Single-threaded by design: virtual time (one `tick()` = one round
/// over the fleet) makes recovery decisions — breaker cooldowns,
/// backoff, deadlines — replayable from a seed, which the chaos tests
/// and `sage chaos` rely on. Throughput-oriented serving without faults
/// keeps the thread-per-replica path in `main.rs`.
pub struct Fleet {
    replicas: Vec<Supervised>,
    router: Router,
    cfg: FleetCfg,
    now: u64,
    pending: VecDeque<Pending>,
    meta: BTreeMap<RequestId, Meta>,
    failures: Vec<Response>,
    submitted: u64,
    retried: u64,
    failed_over: u64,
    cancelled_deadline: u64,
    shed: u64,
    route_refusals: u64,
    /// Fleet-wide streaming audit, shared with every replica's sink
    /// (see [`Fleet::enable_streaming`]); also the TTFT clock — a
    /// request's first token is the tick its ledger count went
    /// positive.
    ledger: Option<Arc<Mutex<StreamLedger>>>,
    /// Shared observability handle (see [`Fleet::set_obs`]); the
    /// disabled default is a no-op on every emission site.
    obs: Obs,
}

impl Fleet {
    pub fn new(scheds: Vec<Scheduler>, policy: RoutingPolicy, cfg: FleetCfg) -> Fleet {
        let n = scheds.len();
        Fleet {
            replicas: scheds
                .into_iter()
                .enumerate()
                .map(|(id, sched)| Supervised {
                    id,
                    sched,
                    breaker: Breaker::Closed,
                    consec_failures: 0,
                    crashed: false,
                })
                .collect(),
            router: Router::new(policy, n),
            cfg,
            now: 0,
            pending: VecDeque::new(),
            meta: BTreeMap::new(),
            failures: Vec::new(),
            submitted: 0,
            retried: 0,
            failed_over: 0,
            cancelled_deadline: 0,
            shed: 0,
            route_refusals: 0,
            ledger: None,
            obs: Obs::disabled(),
        }
    }

    /// Attach an observability handle fleet-wide: every replica's
    /// scheduler (and through it the engine's kernel phase profiler)
    /// shares the same `obs`, while the fleet itself stamps the virtual
    /// tick and emits the fleet-level lifecycle spans — submit,
    /// dispatch, shed, retry, failover, crash, breaker-open — that no
    /// single replica can see.
    pub fn set_obs(&mut self, obs: Obs) {
        for sup in &mut self.replicas {
            sup.sched.set_obs(obs.clone(), sup.id as u32, true);
        }
        self.obs = obs;
    }

    pub fn submit(&mut self, req: Request) {
        let now = self.now;
        self.submit_at(req, now);
    }

    /// Submit with an open-loop arrival time: the request enters the
    /// dispatch queue at virtual tick `due` (its `arrival_ms` mapped
    /// through the tick scale), and its deadlines/SLO clocks start
    /// there — not at tick 0 when the workload was generated.
    pub fn submit_at(&mut self, req: Request, due: u64) {
        self.submitted += 1;
        let kind = EventKind::Submit { prompt_len: req.prompt.len() as u32 };
        self.obs.emit(NO_REPLICA, req.id, kind);
        self.meta.insert(
            req.id,
            Meta {
                retries: 0,
                submitted_at: due.max(self.now),
                ttft_deadline: req.params.ttft_deadline,
                total_deadline: req.params.total_deadline,
                done: false,
                slo: SloTargets {
                    ttft_ticks: req.params.slo_ttft,
                    tpot_ticks: req.params.slo_tpot,
                },
                first_token_tick: None,
                slo_met: None,
            },
        );
        self.pending.push_back(Pending { req, not_before: due });
    }

    /// Install a fleet-wide [`StreamLedger`] as every replica's token
    /// sink. Tokens stream through it as replicas decode; the returned
    /// handle lets the caller read totals / assert `is_clean()` after
    /// the run. Also arms SLO tracking's TTFT clock.
    pub fn enable_streaming(&mut self) -> Arc<Mutex<StreamLedger>> {
        let ledger: Arc<Mutex<StreamLedger>> = Arc::new(Mutex::new(StreamLedger::new()));
        for sup in &mut self.replicas {
            let sink: Arc<Mutex<dyn TokenSink>> = ledger.clone();
            sup.sched.set_sink(sink);
        }
        self.ledger = Some(ledger.clone());
        ledger
    }

    /// Enable chunked prefill on every replica. Returns false (leaving
    /// refusing replicas unchunked) if any backend cannot honor the
    /// chunk alignment for its plan.
    pub fn set_chunked_prefill(&mut self, cfg: ChunkCfg) -> bool {
        let mut all = true;
        for sup in &mut self.replicas {
            all &= sup.sched.engine.set_chunked_prefill(cfg);
        }
        all
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.replicas.iter().any(|s| s.sched.has_work())
    }

    /// All-refuse routing outcomes that were requeued with backoff.
    pub fn route_refusals(&self) -> u64 {
        self.route_refusals
    }

    /// Replica breaker states (telemetry / tests).
    pub fn breaker_states(&self) -> Vec<Breaker> {
        self.replicas.iter().map(|s| s.breaker).collect()
    }

    /// KV audit over every replica (chaos soaks): the accountant's
    /// structural invariants always hold; with `expect_empty` — a
    /// drained fleet — every block must be back in the pool (leaks on
    /// any recovery path fail here).
    pub fn audit_kv(&self, expect_empty: bool) -> Result<()> {
        for sup in &self.replicas {
            let kv = &sup.sched.kv;
            if let Err(e) = kv.check_invariants() {
                crate::bail!("replica {} KV invariants broken: {e}", sup.id);
            }
            if expect_empty {
                ensure!(
                    kv.free_blocks() == kv.total_blocks(),
                    "replica {} leaked {} block(s)",
                    sup.id,
                    kv.total_blocks() - kv.free_blocks()
                );
            }
        }
        Ok(())
    }

    fn record_terminal(&mut self, resp: Response) {
        // fleet-level terminals (shed, deadline sweep, retry-budget
        // exhaustion, whole-fleet-down) never pass through a replica
        // scheduler's `record_response`, so this is their one and only
        // trace-emission site — exactly-once terminal spans
        let kind = match resp.finish {
            FinishReason::DeadlineExceeded => EventKind::DeadlineCancel,
            FinishReason::Shed => EventKind::Shed,
            FinishReason::MaxTokens | FinishReason::StopToken => {
                EventKind::Finish { tokens: resp.tokens.len() as u32 }
            }
            FinishReason::Failed | FinishReason::Rejected => EventKind::Fail,
        };
        self.obs.emit(NO_REPLICA, resp.id, kind);
        if let Some(m) = self.meta.get_mut(&resp.id) {
            m.done = true;
            if !m.slo.is_empty() && m.slo_met.is_none() {
                // shed / failed / cancelled tracked requests miss
                m.slo_met = Some(false);
            }
        }
        self.failures.push(resp);
    }

    /// Estimated TTFT (ticks) for a request dispatched now: the healthy
    /// replicas' outstanding prefill backlog — queued prompt rows plus
    /// admitted-but-unprefilled chunk rows — drained at the per-tick
    /// prefill budget.
    fn estimate_ttft(&self, own_rows: usize) -> u64 {
        let mut backlog = 0usize;
        let mut healthy = 0usize;
        for sup in &self.replicas {
            if sup.crashed || matches!(sup.breaker, Breaker::Open { .. }) {
                continue;
            }
            healthy += 1;
            backlog += sup.sched.batcher.queued_prefill_rows()
                + sup.sched.engine.pending_prefill_rows();
        }
        // with chunking off a tick prefills whole prompts, so the
        // effective drain rate is unbounded and only queueing remains
        let rows_per_tick = self.cfg.tick_prefill_rows.unwrap_or(usize::MAX / 2);
        estimate_ttft_ticks(backlog, own_rows, rows_per_tick, healthy)
    }

    /// Stamp the TTFT clock: any tracked request whose ledger count
    /// just went positive streamed its first token this tick.
    fn stamp_first_tokens(&mut self) {
        let Some(ledger) = &self.ledger else { return };
        let ledger = ledger.lock().expect("stream ledger poisoned");
        let mut stamped = 0u64;
        for (&id, m) in self.meta.iter_mut() {
            if m.first_token_tick.is_none() && ledger.streamed_of(id) > 0 {
                m.first_token_tick = Some(self.now);
                stamped += 1;
            }
        }
        drop(ledger);
        if stamped > 0 {
            // the fleet-side TTFT clock; must agree with the scheduler-
            // side `ttft_us` histogram count (pinned by a tier-1 test)
            self.obs.counter_add("fleet_first_tokens", stamped);
        }
    }

    /// Cancel `id` wherever it lives (fleet queue, replica queue, live
    /// slot — rc-correct). Returns whether anything was cancelled.
    fn cancel_anywhere(&mut self, id: RequestId) -> Result<bool> {
        if let Some(i) = self.pending.iter().position(|p| p.req.id == id) {
            self.pending.remove(i);
            return Ok(true);
        }
        for sup in &mut self.replicas {
            if sup.sched.cancel(id)? {
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Cancel `id` only if it is still queued (TTFT deadlines: a live
    /// slot already produced its first token at prefill).
    fn cancel_queued(&mut self, id: RequestId) -> bool {
        if let Some(i) = self.pending.iter().position(|p| p.req.id == id) {
            self.pending.remove(i);
            return true;
        }
        self.replicas.iter_mut().any(|sup| sup.sched.batcher.remove(id).is_some())
    }

    fn sweep_deadlines(&mut self) -> Result<()> {
        let now = self.now;
        let expired: Vec<(RequestId, &'static str)> = self
            .meta
            .iter()
            .filter(|(_, m)| !m.done)
            .filter_map(|(&id, m)| {
                let age = now.saturating_sub(m.submitted_at);
                if m.total_deadline.is_some_and(|d| age > d) {
                    Some((id, "total"))
                } else if m.ttft_deadline.is_some_and(|d| age > d) {
                    Some((id, "ttft"))
                } else {
                    None
                }
            })
            .collect();
        for (id, kind) in expired {
            let cancelled = if kind == "total" {
                self.cancel_anywhere(id)?
            } else {
                self.cancel_queued(id)
            };
            if cancelled {
                self.cancelled_deadline += 1;
                self.record_terminal(Response::failure(
                    id,
                    FinishReason::DeadlineExceeded,
                    format!("{kind} deadline exceeded at tick {now}"),
                ));
            }
            // not found anywhere queued/live → already terminal (or, for
            // ttft, already past its first token): nothing to cancel
        }
        Ok(())
    }

    /// One round of fleet virtual time: deadline sweep → dispatch due
    /// pending requests → step every healthy replica, applying the
    /// supervision policy to each outcome.
    pub fn tick(&mut self) -> Result<()> {
        self.now += 1;
        self.obs.set_tick(self.now);
        // breaker cooldowns elapse at the top of the tick
        for sup in &mut self.replicas {
            if let Breaker::Open { until } = sup.breaker {
                if until <= self.now {
                    sup.breaker = Breaker::HalfOpen;
                }
            }
        }
        self.sweep_deadlines()?;
        // dispatch: route everything whose backoff has elapsed
        if !self.pending.is_empty() && self.replicas.iter().all(|s| s.crashed) {
            // nobody left to run anything: terminal-fail the backlog
            // rather than spinning to max_ticks
            let backlog: Vec<Pending> = self.pending.drain(..).collect();
            for p in backlog {
                self.record_terminal(Response::failure(
                    p.req.id,
                    FinishReason::Failed,
                    "no healthy replicas: entire fleet is down",
                ));
            }
        }
        let mut waiting = VecDeque::new();
        while let Some(p) = self.pending.pop_front() {
            if p.not_before > self.now {
                waiting.push_back(p);
                continue;
            }
            // SLO admission control: at *first* dispatch (retries keep
            // whatever admission already promised them), estimate TTFT
            // from the live prefill backlog and shed a request whose
            // target is already unreachable — a typed terminal
            // response, counted against goodput, never a silent drop.
            if let Some(target) = p.req.params.slo_ttft {
                let first_try =
                    self.meta.get(&p.req.id).map_or(true, |m| m.retries == 0);
                if first_try {
                    let est = self.estimate_ttft(p.req.prefill_len());
                    if est > target {
                        self.shed += 1;
                        let now = self.now;
                        self.record_terminal(Response::failure(
                            p.req.id,
                            FinishReason::Shed,
                            format!(
                                "shed at tick {now}: estimated TTFT {est} ticks \
                                 exceeds target {target}"
                            ),
                        ));
                        continue;
                    }
                }
            }
            match self.router.route(&mut self.replicas, &p.req) {
                Ok(r) => {
                    self.obs.emit(r as u32, p.req.id, EventKind::Dispatch);
                }
                Err(RouteError::NoReplicas | RouteError::AllRefused) => {
                    // typed route error → requeue with backoff, never drop
                    self.route_refusals += 1;
                    waiting.push_back(Pending {
                        req: p.req,
                        not_before: self.now + self.cfg.backoff_base.max(1),
                    });
                }
            }
        }
        self.pending = waiting;
        // drive the fleet one scheduler tick each
        for i in 0..self.replicas.len() {
            let sup = &mut self.replicas[i];
            if sup.crashed
                || matches!(sup.breaker, Breaker::Open { .. })
                || !sup.sched.has_work()
            {
                continue;
            }
            match sup.sched.tick() {
                Ok(done) => {
                    sup.consec_failures = 0;
                    sup.breaker = Breaker::Closed;
                    self.stamp_first_tokens();
                    let now = self.now;
                    for resp in done {
                        if let Some(m) = self.meta.get_mut(&resp.id) {
                            m.done = true;
                            if !m.slo.is_empty() {
                                m.slo_met = Some(match resp.finish {
                                    FinishReason::MaxTokens | FinishReason::StopToken => {
                                        slo_satisfied(m, resp.tokens.len(), now)
                                    }
                                    _ => false,
                                });
                            }
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    if is_crash(&msg) {
                        // permanent: fail the whole replica over. Its
                        // engine was already drained by the errored tick;
                        // drain() scoops the queue too.
                        sup.crashed = true;
                        sup.breaker = Breaker::Open { until: u64::MAX };
                        let replica = sup.id as u32;
                        let orphans = sup.sched.drain()?;
                        self.obs.emit(replica, NO_ID, EventKind::Crash);
                        self.failed_over += orphans.len() as u64;
                        for req in orphans {
                            // the new home is unknown until re-dispatch;
                            // the next Dispatch span carries the target
                            let kind = EventKind::Failover { to: NO_REPLICA };
                            self.obs.emit(replica, req.id, kind);
                            self.pending.push_back(Pending { req, not_before: self.now + 1 });
                        }
                    } else {
                        // transient: trip the breaker after consecutive
                        // failures and pull the work off the wounded
                        // replica — each drained request is billed one
                        // retry (poison-pill requests must exhaust their
                        // budget, not loop forever) and backs off
                        // exponentially before re-routing
                        sup.consec_failures += 1;
                        if sup.consec_failures >= self.cfg.breaker_threshold
                            || matches!(sup.breaker, Breaker::HalfOpen)
                        {
                            sup.breaker =
                                Breaker::Open { until: self.now + self.cfg.breaker_cooldown };
                            self.obs.emit(sup.id as u32, NO_ID, EventKind::BreakerOpen);
                        }
                        let replica = sup.id as u32;
                        let drained = sup.sched.drain()?;
                        for req in drained {
                            let Some(m) = self.meta.get_mut(&req.id) else { continue };
                            m.retries += 1;
                            if m.retries > self.cfg.max_retries {
                                let retries = m.retries;
                                self.record_terminal(Response::failure(
                                    req.id,
                                    FinishReason::Failed,
                                    format!(
                                        "retry budget exhausted after {retries} attempts \
                                         (last error: {msg})"
                                    ),
                                ));
                            } else {
                                self.retried += 1;
                                let attempt = m.retries;
                                self.obs.emit(replica, req.id, EventKind::Retry { attempt });
                                let backoff =
                                    self.cfg.backoff_base.max(1).saturating_pow(m.retries);
                                self.pending
                                    .push_back(Pending { req, not_before: self.now + backoff });
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Drive to completion and aggregate the report. Every submitted
    /// request is guaranteed a terminal response.
    pub fn run_to_completion(mut self) -> Result<FleetReport> {
        let t0 = Instant::now();
        while self.has_work() {
            ensure!(
                self.now < self.cfg.max_ticks,
                "fleet made no progress within {} ticks (fault spec too hostile?)",
                self.cfg.max_ticks
            );
            self.tick()?;
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let mut report = FleetReport {
            submitted: self.submitted,
            retried: self.retried,
            failed_over: self.failed_over,
            cancelled_deadline: self.cancelled_deadline,
            shed: self.shed,
            ticks: self.now,
            wall_s,
            responses: self.failures,
            ..FleetReport::default()
        };
        for m in self.meta.values() {
            if !m.slo.is_empty() {
                report.slo_tracked += 1;
                if m.slo_met == Some(true) {
                    report.slo_met += 1;
                }
            }
        }
        if let Some(ledger) = &self.ledger {
            let ledger = ledger.lock().expect("stream ledger poisoned");
            report.streamed_tokens = ledger.tokens;
            report.stream_duplicates = ledger.duplicates;
            report.stream_gaps = ledger.gaps;
        }
        for sup in self.replicas {
            let rep = sup.sched.into_report(wall_s);
            report.injected += rep.injected;
            report.degraded_fallbacks += rep.degraded_fallbacks;
            report.responses.extend(rep.responses.iter().cloned());
            report.replicas.push(rep);
        }
        report.responses.sort_by_key(|r| r.id);
        for r in &report.responses {
            match r.finish {
                FinishReason::MaxTokens | FinishReason::StopToken => report.served += 1,
                FinishReason::DeadlineExceeded | FinishReason::Shed => {}
                FinishReason::Failed | FinishReason::Rejected => report.failed += 1,
            }
        }
        report.dropped = report.submitted.saturating_sub(
            report.served + report.failed + report.cancelled_deadline + report.shed,
        );
        let buckets = self.cfg.max_retries as usize + 2;
        report.retries_hist = vec![0; buckets];
        for m in self.meta.values() {
            report.retries_hist[(m.retries as usize).min(buckets - 1)] += 1;
        }
        // absorb the fleet counters into the shared metrics registry so
        // exporters see one source of truth (replica-level counters are
        // published by each scheduler's `into_report`)
        let fleet_counters = [
            ("fleet_submitted", report.submitted),
            ("fleet_served", report.served),
            ("fleet_failed", report.failed),
            ("fleet_cancelled_deadline", report.cancelled_deadline),
            ("fleet_shed", report.shed),
            ("fleet_retried", report.retried),
            ("fleet_failed_over", report.failed_over),
            ("fleet_route_refusals", self.route_refusals),
            ("fleet_slo_tracked", report.slo_tracked),
            ("fleet_slo_met", report.slo_met),
        ];
        for (name, v) in fleet_counters {
            if v > 0 {
                self.obs.counter_add(name, v);
            }
        }
        self.obs.gauge_set("fleet_ticks", report.ticks as f64);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;

    struct Mock {
        id: usize,
        load: f64,
        cap: usize,
        accepted: Vec<u64>,
    }

    impl Replica for Mock {
        fn id(&self) -> usize {
            self.id
        }
        fn load(&self) -> f64 {
            self.load
        }
        fn submit(&mut self, req: Request) -> bool {
            if self.accepted.len() >= self.cap {
                return false;
            }
            self.accepted.push(req.id);
            self.load += 1.0;
            true
        }
    }

    fn mocks(loads: &[f64]) -> Vec<Mock> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &load)| Mock { id, load, cap: usize::MAX, accepted: vec![] })
            .collect()
    }

    fn req(id: u64) -> Request {
        Request::new(id, vec![1], GenParams::default())
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutingPolicy::RoundRobin, 3);
        let mut reps = mocks(&[0.0, 0.0, 0.0]);
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(&mut reps, &req(i)).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_min() {
        let mut r = Router::new(RoutingPolicy::LeastLoaded, 3);
        let mut reps = mocks(&[5.0, 1.0, 3.0]);
        assert_eq!(r.route(&mut reps, &req(1)).unwrap(), 1);
        // replica 1 now at 2.0, still least
        assert_eq!(r.route(&mut reps, &req(2)).unwrap(), 1);
        // at 3.0, ties broken by sort stability -> 1 or 2 acceptable
        let third = r.route(&mut reps, &req(3)).unwrap();
        assert!(third == 1 || third == 2);
    }

    #[test]
    fn refusal_falls_through() {
        let mut r = Router::new(RoutingPolicy::RoundRobin, 2);
        let mut reps = mocks(&[0.0, 0.0]);
        reps[0].cap = 0; // always refuses
        for i in 0..4 {
            assert_eq!(r.route(&mut reps, &req(i)).unwrap(), 1);
        }
        assert_eq!(reps[1].accepted.len(), 4);
    }

    #[test]
    fn all_refuse_is_typed_error_not_a_drop() {
        let mut r = Router::new(RoutingPolicy::LeastLoaded, 2);
        let mut reps = mocks(&[0.0, 0.0]);
        reps[0].cap = 0;
        reps[1].cap = 0;
        // the caller keeps the request (route borrows it) and receives a
        // typed error it must requeue on — see the fleet chaos tests for
        // the requeue-with-backoff assertion end to end
        let request = req(1);
        assert_eq!(r.route(&mut reps, &request).unwrap_err(), RouteError::AllRefused);
        assert_eq!(request.id, 1, "request must survive an all-refuse outcome");
        let none: [Mock; 0] = [];
        let mut empty = none;
        assert_eq!(r.route(&mut empty, &request).unwrap_err(), RouteError::NoReplicas);
    }

    #[test]
    fn policy_names_round_trip() {
        for name in ["rr", "least", "power2"] {
            let p = RoutingPolicy::by_name(name).expect(name);
            assert_eq!(p.name(), name);
        }
        assert_eq!(RoutingPolicy::by_name("random"), None);
        assert_eq!(RoutingPolicy::by_name("power2"), Some(RoutingPolicy::PowerOfK(2)));
    }

    #[test]
    fn power_of_k_prefers_lighter_of_window() {
        let mut r = Router::new(RoutingPolicy::PowerOfK(2), 3);
        let mut reps = mocks(&[9.0, 1.0, 5.0]);
        // window {0,1}: picks 1
        assert_eq!(r.route(&mut reps, &req(1)).unwrap(), 1);
    }
}
