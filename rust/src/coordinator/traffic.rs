//! Traffic plane: chunked prefill, per-token streaming, and SLO-aware
//! admission — the pieces that make the serving stack behave under
//! *open-loop* load instead of batch replay.
//!
//! * **Chunked prefill** ([`ChunkCfg`]): admission defers the prefill
//!   compute; the engine's `step` consumes each admitted prompt in
//!   fixed-size row chunks under a per-tick row budget, interleaved
//!   with decode steps, so one long-context prefill can no longer
//!   head-of-line-block every decoding stream. Chunk boundaries stay
//!   aligned to the plan's Q scale-group size, which keeps chunked
//!   prefill bit-identical to one-shot prefill on the sage plans (Q
//!   scale groups are per-forward-call and restart at every chunk
//!   boundary; K scales are position-absolute).
//! * **Streaming** ([`TokenSink`] / [`StreamedToken`]): responses emit
//!   tokens as they are sampled, each tagged with its absolute index,
//!   so TTFT is first-streamed-token time and sinks can prove no
//!   duplicate/gap slipped through preemption or crash failover
//!   ([`StreamLedger`]).
//! * **SLO admission** ([`SloTargets`], [`estimate_ttft_ticks`]):
//!   per-request TTFT/TPOT *targets* — distinct from the fault plane's
//!   hard deadlines. The fleet estimates queue delay from the live
//!   prefill backlog and *sheds* work that cannot meet its target at
//!   saturation ([`crate::coordinator::FinishReason::Shed`]), reporting
//!   goodput-under-SLO instead of serving guaranteed misses.

use std::collections::HashMap;

use crate::util::error::{ensure, Result};

use super::request::RequestId;

/// One streamed token: request, absolute index within the response, and
/// the token itself. Indices let any sink detect duplicates and gaps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamedToken {
    pub id: RequestId,
    pub index: usize,
    pub token: i32,
}

/// Receiver for per-token streaming output. `Send` so a sink can be
/// shared across per-replica scheduler threads behind a mutex.
pub trait TokenSink: Send {
    fn on_token(&mut self, tok: StreamedToken);
}

/// Chunked-prefill configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkCfg {
    /// Rows per prefill chunk. Must be a multiple of the plan's Q
    /// scale-group size (BLOCK_Q = 128 on the sage plans — enforced by
    /// the backend at [`set_chunked_prefill`] time) so requant horizons
    /// and the CoW barrier stay aligned and chunked output is
    /// bit-identical to unchunked.
    ///
    /// [`set_chunked_prefill`]: super::backend::EngineBackend::set_chunked_prefill
    pub chunk_rows: usize,
    /// Prefill row budget per engine tick, across all prefilling slots.
    /// Bounds the prefill work a tick can absorb so decode TPOT stays
    /// bounded. At least `chunk_rows`.
    pub tick_rows: usize,
}

impl ChunkCfg {
    pub fn new(chunk_rows: usize, tick_rows: usize) -> Result<ChunkCfg> {
        ensure!(chunk_rows >= 1, "prefill chunk must be at least 1 row");
        ensure!(
            tick_rows >= chunk_rows,
            "per-tick prefill budget ({tick_rows}) below chunk size ({chunk_rows})"
        );
        Ok(ChunkCfg { chunk_rows, tick_rows })
    }

    /// One chunk per tick — the simplest fair schedule.
    pub fn per_tick(chunk_rows: usize) -> Result<ChunkCfg> {
        Self::new(chunk_rows, chunk_rows)
    }

    /// Whether every chunk boundary lands on a `group`-row boundary
    /// (the plan's Q scale-group size; 1 for fp plans).
    pub fn aligned_to(&self, group: usize) -> bool {
        group <= 1 || self.chunk_rows % group == 0
    }
}

/// Per-request SLO targets, in scheduler ticks (virtual time, so
/// goodput-under-SLO is deterministic under replay).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloTargets {
    /// Target ticks from arrival to first streamed token.
    pub ttft_ticks: Option<u64>,
    /// Target mean ticks per output token after the first.
    pub tpot_ticks: Option<f64>,
}

impl SloTargets {
    pub fn is_empty(&self) -> bool {
        self.ttft_ticks.is_none() && self.tpot_ticks.is_none()
    }
}

/// Traffic-plane knobs the serve driver threads through the fleet.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrafficCfg {
    /// Chunked prefill; `None` = whole-prompt prefill at admission.
    pub chunk: Option<ChunkCfg>,
    /// SLO targets stamped onto every generated request.
    pub slo: SloTargets,
    /// Honor `SynthRequest::arrival_ms` as open-loop arrivals (one tick
    /// = `tick_ms` of arrival time) instead of submitting everything at
    /// tick 0.
    pub open_loop: bool,
    /// Virtual-time scale for open-loop arrival replay.
    pub tick_ms: f64,
}

/// Estimated ticks until a newly admitted request streams its first
/// token: the outstanding prefill backlog (queued rows + admitted but
/// not-yet-computed chunk rows) plus the request's own prefill, drained
/// at `rows_per_tick` per healthy replica, plus one tick to sample.
/// With chunking off, a tick prefills a whole request, so callers pass
/// the backlog in requests-worth of rows and a large `rows_per_tick`.
pub fn estimate_ttft_ticks(
    backlog_rows: usize,
    own_rows: usize,
    rows_per_tick: usize,
    healthy_replicas: usize,
) -> u64 {
    let capacity = rows_per_tick.max(1) * healthy_replicas.max(1);
    ((backlog_rows + own_rows).div_ceil(capacity) + 1) as u64
}

/// A [`TokenSink`] that audits the stream: counts tokens, flags
/// duplicates (an index at or below the request's high-water mark —
/// the double-emission failover must never produce) and gaps (an index
/// that skips ahead). The chaos soaks assert `duplicates == 0 && gaps
/// == 0` across crash failover and preemption.
#[derive(Debug, Default)]
pub struct StreamLedger {
    next_index: HashMap<RequestId, usize>,
    pub tokens: u64,
    pub duplicates: u64,
    pub gaps: u64,
}

impl StreamLedger {
    pub fn new() -> StreamLedger {
        StreamLedger::default()
    }

    /// Tokens streamed for one request so far.
    pub fn streamed_of(&self, id: RequestId) -> usize {
        self.next_index.get(&id).copied().unwrap_or(0)
    }

    pub fn is_clean(&self) -> bool {
        self.duplicates == 0 && self.gaps == 0
    }
}

impl TokenSink for StreamLedger {
    fn on_token(&mut self, tok: StreamedToken) {
        let next = self.next_index.entry(tok.id).or_insert(0);
        if tok.index < *next {
            self.duplicates += 1;
            return;
        }
        if tok.index > *next {
            self.gaps += 1;
        }
        *next = tok.index + 1;
        self.tokens += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_cfg_validates() {
        assert!(ChunkCfg::new(0, 4).is_err());
        assert!(ChunkCfg::new(8, 4).is_err(), "tick budget below chunk size");
        let c = ChunkCfg::new(128, 256).unwrap();
        assert!(c.aligned_to(128));
        assert!(!c.aligned_to(96));
        assert!(c.aligned_to(1), "fp plans accept any chunk");
        assert_eq!(ChunkCfg::per_tick(64).unwrap(), ChunkCfg { chunk_rows: 64, tick_rows: 64 });
    }

    #[test]
    fn ttft_estimate_scales_with_backlog_and_capacity() {
        // empty system: own prefill in one tick + sample tick
        assert_eq!(estimate_ttft_ticks(0, 64, 64, 1), 2);
        // backlog drains ahead of us
        assert_eq!(estimate_ttft_ticks(256, 64, 64, 1), 6);
        // more replicas drain it faster
        assert_eq!(estimate_ttft_ticks(256, 64, 64, 2), 4);
        // zero guards
        assert!(estimate_ttft_ticks(10, 10, 0, 0) >= 1);
    }

    #[test]
    fn stream_ledger_flags_duplicates_and_gaps() {
        let mut l = StreamLedger::new();
        l.on_token(StreamedToken { id: 1, index: 0, token: 5 });
        l.on_token(StreamedToken { id: 1, index: 1, token: 6 });
        l.on_token(StreamedToken { id: 2, index: 0, token: 7 });
        assert_eq!(l.tokens, 3);
        assert!(l.is_clean());
        assert_eq!(l.streamed_of(1), 2);
        // duplicate: index below the watermark
        l.on_token(StreamedToken { id: 1, index: 0, token: 5 });
        assert_eq!(l.duplicates, 1);
        // gap: index skips ahead
        l.on_token(StreamedToken { id: 2, index: 3, token: 9 });
        assert_eq!(l.gaps, 1);
        assert!(!l.is_clean());
    }
}
