//! Paged KV-cache accountant: fixed-size token blocks, per-sequence block
//! tables, ref-counted blocks (prefix sharing-ready), and admission
//! control. The physical cache inside the AOT artifacts is a dense
//! (L, B, H, max_seq, d) tensor per slot; this manager owns the *logical*
//! capacity decisions — which requests may occupy a slot and when memory
//! is exhausted — the way vLLM's block manager fronts its GPU allocator.

use std::collections::HashMap;

use crate::coordinator::request::RequestId;

pub type BlockId = u32;

/// Errors are admission decisions, not failures.
#[derive(Debug, PartialEq, Eq)]
pub enum AllocError {
    /// Not enough free blocks right now.
    OutOfBlocks,
    /// Sequence unknown.
    UnknownSequence,
}

#[derive(Clone, Debug)]
struct SeqState {
    blocks: Vec<BlockId>,
    tokens: usize,
}

/// Block-granular KV accounting.
#[derive(Debug)]
pub struct KvCacheManager {
    block_size: usize,
    free: Vec<BlockId>,
    ref_counts: Vec<u32>,
    seqs: HashMap<RequestId, SeqState>,
}

impl KvCacheManager {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        KvCacheManager {
            block_size,
            free: (0..total_blocks as BlockId).rev().collect(),
            ref_counts: vec![0; total_blocks],
            seqs: HashMap::new(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.ref_counts.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a sequence of `tokens` total tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Register a sequence and reserve blocks for `tokens` tokens.
    pub fn allocate(&mut self, id: RequestId, tokens: usize) -> Result<(), AllocError> {
        let need = self.blocks_for(tokens.max(1));
        if need > self.free.len() {
            return Err(AllocError::OutOfBlocks);
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            debug_assert_eq!(self.ref_counts[b as usize], 0);
            self.ref_counts[b as usize] = 1;
            blocks.push(b);
        }
        self.seqs.insert(id, SeqState { blocks, tokens });
        Ok(())
    }

    /// Extend a sequence by `extra` tokens, acquiring blocks as needed.
    pub fn extend(&mut self, id: RequestId, extra: usize) -> Result<(), AllocError> {
        let seq = self.seqs.get_mut(&id).ok_or(AllocError::UnknownSequence)?;
        let new_tokens = seq.tokens + extra;
        let need_total = new_tokens.div_ceil(self.block_size);
        let need_extra = need_total.saturating_sub(seq.blocks.len());
        if need_extra > self.free.len() {
            return Err(AllocError::OutOfBlocks);
        }
        for _ in 0..need_extra {
            let b = self.free.pop().unwrap();
            self.ref_counts[b as usize] = 1;
            seq.blocks.push(b);
        }
        seq.tokens = new_tokens;
        Ok(())
    }

    /// Release all blocks of a sequence (decrement refs; shared blocks
    /// survive until their last reference drops).
    pub fn release(&mut self, id: RequestId) -> Result<(), AllocError> {
        let seq = self.seqs.remove(&id).ok_or(AllocError::UnknownSequence)?;
        for b in seq.blocks {
            let rc = &mut self.ref_counts[b as usize];
            debug_assert!(*rc > 0);
            *rc -= 1;
            if *rc == 0 {
                self.free.push(b);
            }
        }
        Ok(())
    }

    /// Fork: share all of `src`'s blocks with a new sequence (prefix
    /// sharing / beam search). Copy-on-write is the caller's concern at
    /// the physical layer; here it is pure ref-counting.
    pub fn fork(&mut self, src: RequestId, dst: RequestId) -> Result<(), AllocError> {
        let state = self.seqs.get(&src).ok_or(AllocError::UnknownSequence)?.clone();
        for &b in &state.blocks {
            self.ref_counts[b as usize] += 1;
        }
        self.seqs.insert(dst, state);
        Ok(())
    }

    pub fn seq_tokens(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.tokens)
    }

    pub fn seq_blocks(&self, id: RequestId) -> Option<&[BlockId]> {
        self.seqs.get(&id).map(|s| s.blocks.as_slice())
    }

    pub fn live_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Internal consistency check (used by the property tests): every
    /// block is either free with rc 0 or referenced rc times in total.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut refs = vec![0u32; self.ref_counts.len()];
        for seq in self.seqs.values() {
            for &b in &seq.blocks {
                refs[b as usize] += 1;
            }
        }
        for (i, (&actual, &expected)) in self.ref_counts.iter().zip(&refs).enumerate() {
            if actual != expected {
                return Err(format!("block {i}: rc {actual} but {expected} references"));
            }
        }
        let mut seen = vec![false; self.ref_counts.len()];
        for &b in &self.free {
            if seen[b as usize] {
                return Err(format!("block {b} on free list twice"));
            }
            seen[b as usize] = true;
            if self.ref_counts[b as usize] != 0 {
                return Err(format!("free block {b} has rc {}", self.ref_counts[b as usize]));
            }
        }
        for (i, &rc) in self.ref_counts.iter().enumerate() {
            if rc == 0 && !seen[i] {
                return Err(format!("block {i} leaked (rc 0, not free)"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_extend_release_cycle() {
        let mut kv = KvCacheManager::new(8, 16);
        assert!(kv.can_admit(100)); // 7 blocks
        kv.allocate(1, 33).unwrap(); // 3 blocks
        assert_eq!(kv.free_blocks(), 5);
        kv.extend(1, 15).unwrap(); // 48 total -> still 3 blocks
        assert_eq!(kv.free_blocks(), 5);
        kv.extend(1, 1).unwrap(); // 49 -> 4 blocks
        assert_eq!(kv.free_blocks(), 4);
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 8);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut kv = KvCacheManager::new(4, 16);
        kv.allocate(1, 64).unwrap(); // all 4 blocks
        assert_eq!(kv.allocate(2, 1), Err(AllocError::OutOfBlocks));
        assert!(!kv.can_admit(1));
        kv.release(1).unwrap();
        assert!(kv.can_admit(64));
    }

    #[test]
    fn fork_shares_blocks() {
        let mut kv = KvCacheManager::new(4, 16);
        kv.allocate(1, 32).unwrap(); // 2 blocks
        kv.fork(1, 2).unwrap();
        assert_eq!(kv.free_blocks(), 2); // shared, not copied
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 2); // still referenced by 2
        kv.release(2).unwrap();
        assert_eq!(kv.free_blocks(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn unknown_sequence_errors() {
        let mut kv = KvCacheManager::new(2, 8);
        assert_eq!(kv.release(9), Err(AllocError::UnknownSequence));
        assert_eq!(kv.extend(9, 1), Err(AllocError::UnknownSequence));
    }
}
