//! Paged KV-cache accountant: fixed-size token blocks, per-sequence block
//! tables, ref-counted blocks (prefix sharing-ready), and admission
//! control. The physical cache inside the AOT artifacts is a dense
//! (L, B, H, max_seq, d) tensor per slot; this manager owns the *logical*
//! capacity decisions — which requests may occupy a slot and when memory
//! is exhausted — the way vLLM's block manager fronts its GPU allocator.

use std::collections::HashMap;

use crate::coordinator::request::RequestId;

pub type BlockId = u32;

/// Errors are admission decisions, not failures — except [`Corrupt`],
/// which reports a table/refcount disagreement (double allocate, rc
/// underflow, out-of-range table index) loudly in release builds
/// instead of silently corrupting shared state.
///
/// [`Corrupt`]: AllocError::Corrupt
#[derive(Debug, PartialEq, Eq)]
pub enum AllocError {
    /// Not enough free blocks right now.
    OutOfBlocks,
    /// Sequence unknown.
    UnknownSequence,
    /// Logical state disagrees with itself or with its caller.
    Corrupt,
}

#[derive(Clone, Debug)]
struct SeqState {
    blocks: Vec<BlockId>,
    tokens: usize,
}

/// Block-granular KV accounting.
#[derive(Debug)]
pub struct KvCacheManager {
    block_size: usize,
    free: Vec<BlockId>,
    ref_counts: Vec<u32>,
    seqs: HashMap<RequestId, SeqState>,
}

impl KvCacheManager {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        KvCacheManager {
            block_size,
            free: (0..total_blocks as BlockId).rev().collect(),
            ref_counts: vec![0; total_blocks],
            seqs: HashMap::new(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.ref_counts.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a sequence of `tokens` total tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Register a sequence and reserve blocks for `tokens` tokens.
    pub fn allocate(&mut self, id: RequestId, tokens: usize) -> Result<(), AllocError> {
        if self.seqs.contains_key(&id) {
            return Err(AllocError::Corrupt); // double allocate would leak the old table
        }
        let need = self.blocks_for(tokens.max(1));
        if need > self.free.len() {
            return Err(AllocError::OutOfBlocks);
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            debug_assert_eq!(self.ref_counts[b as usize], 0);
            self.ref_counts[b as usize] = 1;
            blocks.push(b);
        }
        self.seqs.insert(id, SeqState { blocks, tokens });
        Ok(())
    }

    /// Extend a sequence by `extra` tokens, acquiring blocks as needed.
    pub fn extend(&mut self, id: RequestId, extra: usize) -> Result<(), AllocError> {
        let seq = self.seqs.get_mut(&id).ok_or(AllocError::UnknownSequence)?;
        let new_tokens = seq.tokens + extra;
        let need_total = new_tokens.div_ceil(self.block_size);
        let need_extra = need_total.saturating_sub(seq.blocks.len());
        if need_extra > self.free.len() {
            return Err(AllocError::OutOfBlocks);
        }
        for _ in 0..need_extra {
            let b = self.free.pop().unwrap();
            self.ref_counts[b as usize] = 1;
            seq.blocks.push(b);
        }
        seq.tokens = new_tokens;
        Ok(())
    }

    /// Release all blocks of a sequence: decrement refs, returning a
    /// block to the free list only when its last reference drops
    /// (`rc == 0`) — shared blocks survive for their other owners. A
    /// table block with `rc == 0` means the table and the refcounts
    /// disagree; that errors loudly (release builds included) with the
    /// state untouched rather than underflowing.
    pub fn release(&mut self, id: RequestId) -> Result<(), AllocError> {
        let seq = self.seqs.get(&id).ok_or(AllocError::UnknownSequence)?;
        if seq.blocks.iter().any(|&b| self.ref_counts[b as usize] == 0) {
            return Err(AllocError::Corrupt);
        }
        let seq = self.seqs.remove(&id).expect("checked above");
        for b in seq.blocks {
            let rc = &mut self.ref_counts[b as usize];
            *rc -= 1;
            if *rc == 0 {
                self.free.push(b);
            }
        }
        Ok(())
    }

    /// Fork: share all of `src`'s blocks with a new sequence (prefix
    /// sharing / beam search). Copy-on-write is the caller's concern at
    /// the physical layer; here it is pure ref-counting.
    ///
    /// ```
    /// use sageattention::coordinator::kv_cache::KvCacheManager;
    /// let mut kv = KvCacheManager::new(4, 16);
    /// kv.allocate(1, 32).unwrap(); // 2 blocks
    /// kv.fork(1, 2).unwrap(); // shares both blocks, no copies
    /// assert_eq!(kv.free_blocks(), 2);
    /// assert_eq!(kv.seq_blocks(1), kv.seq_blocks(2));
    /// // the first append into the shared tail must go through
    /// // `cow_block`, which gives the writer a private copy:
    /// let (old, new) = kv.cow_block(2, 1).unwrap();
    /// assert_ne!(old, new);
    /// assert_eq!(kv.free_blocks(), 1);
    /// ```
    pub fn fork(&mut self, src: RequestId, dst: RequestId) -> Result<(), AllocError> {
        if self.seqs.contains_key(&dst) {
            return Err(AllocError::Corrupt);
        }
        let state = self.seqs.get(&src).ok_or(AllocError::UnknownSequence)?.clone();
        for &b in &state.blocks {
            self.ref_counts[b as usize] += 1;
        }
        self.seqs.insert(dst, state);
        Ok(())
    }

    /// Fork only the first `tokens` tokens of `src` into `dst` —
    /// the accountant half of a prefix-cache hit. `tokens` must be
    /// non-zero and at most `src`'s token count; the shared prefix's
    /// blocks get an extra reference, nothing is copied.
    pub fn fork_prefix(
        &mut self,
        src: RequestId,
        dst: RequestId,
        tokens: usize,
    ) -> Result<(), AllocError> {
        if self.seqs.contains_key(&dst) {
            return Err(AllocError::Corrupt);
        }
        let state = self.seqs.get(&src).ok_or(AllocError::UnknownSequence)?;
        if tokens == 0 || tokens > state.tokens {
            return Err(AllocError::Corrupt);
        }
        let keep = self.blocks_for(tokens).min(state.blocks.len());
        let blocks: Vec<BlockId> = state.blocks[..keep].to_vec();
        for &b in &blocks {
            self.ref_counts[b as usize] += 1;
        }
        self.seqs.insert(dst, SeqState { blocks, tokens });
        Ok(())
    }

    /// Copy-on-write support: give `id` exclusive ownership of the
    /// block at table position `idx`. An unshared block is returned
    /// unchanged (`old == new`); a shared one (`rc > 1`) is swapped for
    /// a freshly allocated block — the old block keeps its remaining
    /// references, the table entry now points at the new block with
    /// `rc == 1`. The *payload* copy is the physical layer's job
    /// ([`PagedKvStore::prepare_append`]); here it is pure accounting.
    ///
    /// Returns `(old, new)` so the caller knows which payload to clone.
    ///
    /// [`PagedKvStore::prepare_append`]: crate::coordinator::paged_kv::PagedKvStore::prepare_append
    pub fn cow_block(
        &mut self,
        id: RequestId,
        idx: usize,
    ) -> Result<(BlockId, BlockId), AllocError> {
        let seq = self.seqs.get(&id).ok_or(AllocError::UnknownSequence)?;
        let &old = seq.blocks.get(idx).ok_or(AllocError::Corrupt)?;
        match self.ref_counts[old as usize] {
            0 => Err(AllocError::Corrupt), // referenced block with rc 0
            1 => Ok((old, old)),
            _ => {
                let Some(new) = self.free.pop() else {
                    return Err(AllocError::OutOfBlocks);
                };
                self.ref_counts[new as usize] = 1;
                self.ref_counts[old as usize] -= 1;
                self.seqs.get_mut(&id).expect("checked above").blocks[idx] = new;
                Ok((old, new))
            }
        }
    }

    /// Current reference count of a block (0 for free or out of range).
    pub fn ref_count(&self, b: BlockId) -> u32 {
        self.ref_counts.get(b as usize).copied().unwrap_or(0)
    }

    pub fn seq_tokens(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.tokens)
    }

    pub fn seq_blocks(&self, id: RequestId) -> Option<&[BlockId]> {
        self.seqs.get(&id).map(|s| s.blocks.as_slice())
    }

    pub fn live_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Internal consistency check (used by the property tests): every
    /// block is either free with rc 0 or referenced rc times in total.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut refs = vec![0u32; self.ref_counts.len()];
        for seq in self.seqs.values() {
            for &b in &seq.blocks {
                refs[b as usize] += 1;
            }
        }
        for (i, (&actual, &expected)) in self.ref_counts.iter().zip(&refs).enumerate() {
            if actual != expected {
                return Err(format!("block {i}: rc {actual} but {expected} references"));
            }
        }
        let mut seen = vec![false; self.ref_counts.len()];
        for &b in &self.free {
            if seen[b as usize] {
                return Err(format!("block {b} on free list twice"));
            }
            seen[b as usize] = true;
            if self.ref_counts[b as usize] != 0 {
                return Err(format!("free block {b} has rc {}", self.ref_counts[b as usize]));
            }
        }
        for (i, &rc) in self.ref_counts.iter().enumerate() {
            if rc == 0 && !seen[i] {
                return Err(format!("block {i} leaked (rc 0, not free)"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_extend_release_cycle() {
        let mut kv = KvCacheManager::new(8, 16);
        assert!(kv.can_admit(100)); // 7 blocks
        kv.allocate(1, 33).unwrap(); // 3 blocks
        assert_eq!(kv.free_blocks(), 5);
        kv.extend(1, 15).unwrap(); // 48 total -> still 3 blocks
        assert_eq!(kv.free_blocks(), 5);
        kv.extend(1, 1).unwrap(); // 49 -> 4 blocks
        assert_eq!(kv.free_blocks(), 4);
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 8);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut kv = KvCacheManager::new(4, 16);
        kv.allocate(1, 64).unwrap(); // all 4 blocks
        assert_eq!(kv.allocate(2, 1), Err(AllocError::OutOfBlocks));
        assert!(!kv.can_admit(1));
        kv.release(1).unwrap();
        assert!(kv.can_admit(64));
    }

    #[test]
    fn fork_shares_blocks() {
        let mut kv = KvCacheManager::new(4, 16);
        kv.allocate(1, 32).unwrap(); // 2 blocks
        kv.fork(1, 2).unwrap();
        assert_eq!(kv.free_blocks(), 2); // shared, not copied
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 2); // still referenced by 2
        kv.release(2).unwrap();
        assert_eq!(kv.free_blocks(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn unknown_sequence_errors() {
        let mut kv = KvCacheManager::new(2, 8);
        assert_eq!(kv.release(9), Err(AllocError::UnknownSequence));
        assert_eq!(kv.extend(9, 1), Err(AllocError::UnknownSequence));
    }

    #[test]
    fn double_allocate_rejected() {
        let mut kv = KvCacheManager::new(4, 16);
        kv.allocate(1, 16).unwrap();
        assert_eq!(kv.allocate(1, 16), Err(AllocError::Corrupt));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn cow_block_swaps_only_shared_blocks() {
        let mut kv = KvCacheManager::new(4, 16);
        kv.allocate(1, 32).unwrap(); // blocks [a, b]
        // unshared: no-op
        let (old, new) = kv.cow_block(1, 1).unwrap();
        assert_eq!(old, new);
        assert_eq!(kv.free_blocks(), 2);
        kv.fork(1, 2).unwrap();
        let (old, new) = kv.cow_block(2, 1).unwrap();
        assert_ne!(old, new);
        assert_eq!(kv.ref_count(old), 1); // back to exclusive for seq 1
        assert_eq!(kv.ref_count(new), 1);
        assert_eq!(kv.seq_blocks(1).unwrap()[1], old);
        assert_eq!(kv.seq_blocks(2).unwrap()[1], new);
        kv.check_invariants().unwrap();
        // pool exhausted: CoW propagates OutOfBlocks
        kv.allocate(3, 16).unwrap();
        kv.fork(1, 4).unwrap();
        assert_eq!(kv.cow_block(4, 0), Err(AllocError::OutOfBlocks));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn fork_prefix_shares_leading_blocks_only() {
        let mut kv = KvCacheManager::new(8, 16);
        kv.allocate(1, 40).unwrap(); // 3 blocks
        kv.fork_prefix(1, 2, 16).unwrap(); // 1 block shared
        assert_eq!(kv.seq_tokens(2), Some(16));
        assert_eq!(kv.seq_blocks(2).unwrap(), &kv.seq_blocks(1).unwrap()[..1]);
        assert_eq!(kv.free_blocks(), 5);
        assert_eq!(kv.fork_prefix(1, 3, 0), Err(AllocError::Corrupt));
        assert_eq!(kv.fork_prefix(1, 3, 41), Err(AllocError::Corrupt));
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 7); // shared head block survives
        kv.release(2).unwrap();
        assert_eq!(kv.free_blocks(), 8);
        kv.check_invariants().unwrap();
    }
}
