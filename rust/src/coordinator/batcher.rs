//! Admission queue + batching policy (continuous batching front-end).
//!
//! Arriving requests wait in a FIFO; the scheduler asks the batcher which
//! requests to admit given the free decode slots and the KV accountant's
//! capacity. Policies trade head-of-line fairness against utilization.

use std::collections::VecDeque;

use super::kv_cache::KvCacheManager;
use super::request::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Strict FIFO: never admit request i+1 before request i.
    Fifo,
    /// FIFO with a skip window: if the head doesn't fit (KV capacity),
    /// later small requests may be admitted (bounded reordering).
    SkipSmall { window: usize },
}

/// Queue of pending requests with admission logic.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<Request>,
    policy: BatchPolicy,
    pub admitted: u64,
    pub enqueued: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { queue: VecDeque::new(), policy, admitted: 0, enqueued: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Admit up to `free_slots` requests that fit in `kv`'s free capacity,
    /// reserving their KV budget. Returns admitted requests in queue order.
    pub fn admit(&mut self, free_slots: usize, kv: &mut KvCacheManager) -> Vec<Request> {
        let mut admitted = Vec::new();
        let window = match self.policy {
            BatchPolicy::Fifo => 0,
            BatchPolicy::SkipSmall { window } => window,
        };
        let mut i = 0;
        while admitted.len() < free_slots && i < self.queue.len() {
            let fits = kv.can_admit(self.queue[i].max_tokens());
            if fits {
                let req = self.queue.remove(i).unwrap();
                kv.allocate(req.id, req.max_tokens())
                    .expect("can_admit checked");
                admitted.push(req);
                // do not advance i: the next element shifted into place
            } else if i < window {
                i += 1; // skip the stuck head within the window
            } else {
                break; // head-of-line blocks further admission
            }
        }
        self.admitted += admitted.len() as u64;
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            vec![0; prompt_len],
            GenParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    #[test]
    fn fifo_admits_in_order() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(100, 16);
        for i in 0..5 {
            b.push(req(i, 16, 16));
        }
        let admitted = b.admit(3, &mut kv);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.pending(), 2);
        assert_eq!(kv.live_sequences(), 3);
    }

    #[test]
    fn fifo_blocks_on_big_head() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(4, 16); // 64 tokens capacity
        b.push(req(0, 64, 64)); // needs 8 blocks -> never fits
        b.push(req(1, 8, 8)); // would fit
        let admitted = b.admit(2, &mut kv);
        assert!(admitted.is_empty(), "FIFO must not leapfrog the head");
    }

    #[test]
    fn skip_small_leapfrogs_within_window() {
        let mut b = Batcher::new(BatchPolicy::SkipSmall { window: 2 });
        let mut kv = KvCacheManager::new(4, 16);
        b.push(req(0, 64, 64)); // stuck head
        b.push(req(1, 8, 8));
        let admitted = b.admit(2, &mut kv);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(b.pending(), 1); // head still waiting
    }

    #[test]
    fn admit_respects_slot_count() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(100, 16);
        for i in 0..10 {
            b.push(req(i, 8, 8));
        }
        assert_eq!(b.admit(4, &mut kv).len(), 4);
        assert_eq!(b.admit(0, &mut kv).len(), 0);
    }
}
