//! Admission queue + batching policy (continuous batching front-end).
//!
//! Arriving requests wait in a FIFO; the scheduler asks the batcher which
//! requests to admit given the free decode slots and the KV accountant's
//! capacity. Policies trade head-of-line fairness against utilization.

use std::collections::VecDeque;

use super::backend::ReserveMode;
use super::kv_cache::KvCacheManager;
use super::request::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Strict FIFO: never admit request i+1 before request i.
    Fifo,
    /// FIFO with a skip window: if the head doesn't fit (KV capacity),
    /// later small requests may be admitted (bounded reordering).
    SkipSmall { window: usize },
}

/// Queue of pending requests with admission logic.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<Request>,
    policy: BatchPolicy,
    pub admitted: u64,
    pub enqueued: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { queue: VecDeque::new(), policy, admitted: 0, enqueued: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    /// Return a request to the *head* of the queue: preempted or
    /// bounced requests resume before newer arrivals (no re-count in
    /// `enqueued` — the request was already counted on first push).
    pub fn push_front(&mut self, req: Request) {
        self.queue.push_front(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Admit up to `free_slots` requests that fit in `kv`'s free capacity,
    /// reserving their KV budget in full ([`ReserveMode::Full`]).
    /// Returns admitted requests in queue order.
    pub fn admit(&mut self, free_slots: usize, kv: &mut KvCacheManager) -> Vec<Request> {
        self.admit_with(free_slots, kv, ReserveMode::Full)
    }

    /// [`Batcher::admit`] under an explicit reservation discipline.
    ///
    /// * [`ReserveMode::Full`] reserves `prompt + max_new_tokens` rows —
    ///   the dense-cache (PJRT) contract: admission is the only gate.
    /// * [`ReserveMode::Incremental`] reserves only the prefill rows and
    ///   additionally requires the request to *eventually* fit the pool
    ///   alone (`blocks_for(max_tokens) ≤ total_blocks`), so decode-time
    ///   preemption can always make progress; growth happens step-by-step
    ///   in the engine.
    pub fn admit_with(
        &mut self,
        free_slots: usize,
        kv: &mut KvCacheManager,
        mode: ReserveMode,
    ) -> Vec<Request> {
        let mut admitted = Vec::new();
        let window = match self.policy {
            BatchPolicy::Fifo => 0,
            BatchPolicy::SkipSmall { window } => window,
        };
        let mut i = 0;
        while admitted.len() < free_slots && i < self.queue.len() {
            let req = &self.queue[i];
            // allocate() claims at least one block even for zero tokens,
            // so probe with max(1) to keep can_admit and allocate aligned
            let (fits, reserve) = match mode {
                ReserveMode::Full => {
                    (kv.can_admit(req.max_tokens().max(1)), req.max_tokens())
                }
                ReserveMode::Incremental => (
                    kv.can_admit(req.prefill_len().max(1))
                        && kv.blocks_for(req.max_tokens()) <= kv.total_blocks(),
                    req.prefill_len(),
                ),
            };
            if fits {
                let req = self.queue.remove(i).unwrap();
                kv.allocate(req.id, reserve).expect("can_admit checked");
                admitted.push(req);
                // do not advance i: the next element shifted into place
            } else if i < window {
                i += 1; // skip the stuck head within the window
            } else {
                break; // head-of-line blocks further admission
            }
        }
        self.admitted += admitted.len() as u64;
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            vec![0; prompt_len],
            GenParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    #[test]
    fn fifo_admits_in_order() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(100, 16);
        for i in 0..5 {
            b.push(req(i, 16, 16));
        }
        let admitted = b.admit(3, &mut kv);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.pending(), 2);
        assert_eq!(kv.live_sequences(), 3);
    }

    #[test]
    fn fifo_blocks_on_big_head() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(4, 16); // 64 tokens capacity
        b.push(req(0, 64, 64)); // needs 8 blocks -> never fits
        b.push(req(1, 8, 8)); // would fit
        let admitted = b.admit(2, &mut kv);
        assert!(admitted.is_empty(), "FIFO must not leapfrog the head");
    }

    #[test]
    fn skip_small_leapfrogs_within_window() {
        let mut b = Batcher::new(BatchPolicy::SkipSmall { window: 2 });
        let mut kv = KvCacheManager::new(4, 16);
        b.push(req(0, 64, 64)); // stuck head
        b.push(req(1, 8, 8));
        let admitted = b.admit(2, &mut kv);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(b.pending(), 1); // head still waiting
    }

    #[test]
    fn incremental_reserves_prefill_only() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(4, 16); // 64-token pool
        // full reservation would need 4 blocks; incremental needs 1 now
        b.push(req(0, 16, 48));
        let admitted = b.admit_with(1, &mut kv, ReserveMode::Incremental);
        assert_eq!(admitted.len(), 1);
        assert_eq!(kv.free_blocks(), 3, "only the prefill row block is reserved");
        // a request that could never fit the pool alone is not admitted
        b.push(req(1, 16, 64)); // 80 tokens > 64-token pool
        assert!(b.admit_with(1, &mut kv, ReserveMode::Incremental).is_empty());
    }

    #[test]
    fn push_front_resumes_before_newer_arrivals() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(100, 16);
        b.push(req(1, 8, 8));
        b.push_front(req(0, 8, 8));
        let admitted = b.admit(2, &mut kv);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn admit_respects_slot_count() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(100, 16);
        for i in 0..10 {
            b.push(req(i, 8, 8));
        }
        assert_eq!(b.admit(4, &mut kv).len(), 4);
        assert_eq!(b.admit(0, &mut kv).len(), 0);
    }
}
