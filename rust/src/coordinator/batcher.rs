//! Admission queue + batching policy (continuous batching front-end).
//!
//! Arriving requests wait in a FIFO; the scheduler asks the batcher which
//! requests to admit given the free decode slots and the KV accountant's
//! capacity. Policies trade head-of-line fairness against utilization.

use std::collections::VecDeque;

use crate::util::error::Result;

use super::backend::ReserveMode;
use super::kv_cache::KvCacheManager;
use super::request::Request;

/// Admission-time hooks a backend may provide. The default
/// implementation is a no-op gate (no prefix cache, nothing to
/// reclaim); the native backend credits cached prefixes so admission
/// reserves only the unshared suffix, and LRU-evicts unreferenced
/// cached prefixes when the pool runs low.
pub trait AdmitGate {
    /// Prefill tokens of `req` servable from shared cached state — the
    /// batcher subtracts this credit when sizing an
    /// [`ReserveMode::Incremental`] reservation.
    fn prefix_credit(&self, _req: &Request) -> usize {
        0
    }

    /// Try to raise the accountant's free-block count to at least
    /// `need` by releasing reclaimable state (e.g. LRU-evicting
    /// unreferenced cached prefixes). Returns whether anything was
    /// freed; errors signal corrupted cache bookkeeping.
    fn reclaim_blocks(&mut self, _kv: &mut KvCacheManager, _need: usize) -> Result<bool> {
        Ok(false)
    }
}

/// The no-op [`AdmitGate`].
pub struct NoGate;

impl AdmitGate for NoGate {}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Strict FIFO: never admit request i+1 before request i.
    Fifo,
    /// FIFO with a skip window: if the head doesn't fit (KV capacity),
    /// later small requests may be admitted (bounded reordering).
    SkipSmall { window: usize },
}

/// Queue of pending requests with admission logic.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<Request>,
    policy: BatchPolicy,
    pub admitted: u64,
    pub enqueued: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { queue: VecDeque::new(), policy, admitted: 0, enqueued: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    /// Return a request to the *head* of the queue: preempted or
    /// bounced requests resume before newer arrivals (no re-count in
    /// `enqueued` — the request was already counted on first push).
    pub fn push_front(&mut self, req: Request) {
        self.queue.push_front(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Prompt rows waiting in the queue — the queued half of the
    /// prefill backlog the SLO admission estimator drains against
    /// (the admitted half is the engine's `pending_prefill_rows`).
    pub fn queued_prefill_rows(&self) -> usize {
        self.queue.iter().map(|r| r.prefill_len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Ids of queued requests, head first (deadline sweeps).
    pub fn ids(&self) -> Vec<super::request::RequestId> {
        self.queue.iter().map(|r| r.id).collect()
    }

    /// Pull one queued request out by id (deadline cancellation).
    pub fn remove(&mut self, id: super::request::RequestId) -> Option<Request> {
        let i = self.queue.iter().position(|r| r.id == id)?;
        self.queue.remove(i)
    }

    /// Take the whole queue (crash failover: re-route everything).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }

    /// Admit up to `free_slots` requests that fit in `kv`'s free capacity,
    /// reserving their KV budget in full ([`ReserveMode::Full`]).
    /// Returns admitted requests in queue order.
    pub fn admit(&mut self, free_slots: usize, kv: &mut KvCacheManager) -> Vec<Request> {
        self.admit_with(free_slots, kv, ReserveMode::Full)
    }

    /// [`Batcher::admit`] under an explicit reservation discipline.
    ///
    /// * [`ReserveMode::Full`] reserves `prompt + max_new_tokens` rows —
    ///   the dense-cache (PJRT) contract: admission is the only gate.
    /// * [`ReserveMode::Incremental`] reserves only the prefill rows and
    ///   additionally requires the request to *eventually* fit the pool
    ///   alone (`blocks_for(max_tokens) ≤ total_blocks`), so decode-time
    ///   preemption can always make progress; growth happens step-by-step
    ///   in the engine.
    pub fn admit_with(
        &mut self,
        free_slots: usize,
        kv: &mut KvCacheManager,
        mode: ReserveMode,
    ) -> Vec<Request> {
        self.admit_gated(free_slots, kv, mode, &mut NoGate)
            .expect("NoGate cannot fail")
    }

    /// [`Batcher::admit_with`] through a backend [`AdmitGate`]:
    /// cached-prefix credit shrinks [`ReserveMode::Incremental`]
    /// reservations to the unshared suffix (capped one token short of
    /// the prefill — the engine always computes the last prompt
    /// position), and a request that doesn't fit right now may still be
    /// admitted after the gate reclaims evictable blocks.
    pub fn admit_gated(
        &mut self,
        free_slots: usize,
        kv: &mut KvCacheManager,
        mode: ReserveMode,
        gate: &mut dyn AdmitGate,
    ) -> Result<Vec<Request>> {
        let mut admitted = Vec::new();
        let window = match self.policy {
            BatchPolicy::Fifo => 0,
            BatchPolicy::SkipSmall { window } => window,
        };
        let mut i = 0;
        while admitted.len() < free_slots && i < self.queue.len() {
            let req = &self.queue[i];
            // allocate() claims at least one block even for zero tokens,
            // so probe with max(1) to keep can_admit and allocate aligned
            let (mut fits, reserve) = match mode {
                ReserveMode::Full => {
                    (kv.can_admit(req.max_tokens().max(1)), req.max_tokens())
                }
                ReserveMode::Incremental => {
                    let credit =
                        gate.prefix_credit(req).min(req.prefill_len().saturating_sub(1));
                    let reserve = req.prefill_len() - credit;
                    let eventual = kv.blocks_for(req.max_tokens()) <= kv.total_blocks();
                    (eventual && kv.can_admit(reserve.max(1)), reserve)
                }
            };
            if !fits
                && kv.blocks_for(req.max_tokens()) <= kv.total_blocks()
                && gate.reclaim_blocks(kv, kv.blocks_for(reserve.max(1)))?
            {
                fits = kv.can_admit(reserve.max(1));
            }
            if fits {
                let req = self.queue.remove(i).unwrap();
                kv.allocate(req.id, reserve).expect("can_admit checked");
                admitted.push(req);
                // do not advance i: the next element shifted into place
            } else if i < window {
                i += 1; // skip the stuck head within the window
            } else {
                break; // head-of-line blocks further admission
            }
        }
        self.admitted += admitted.len() as u64;
        Ok(admitted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            vec![0; prompt_len],
            GenParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    #[test]
    fn fifo_admits_in_order() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(100, 16);
        for i in 0..5 {
            b.push(req(i, 16, 16));
        }
        let admitted = b.admit(3, &mut kv);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.pending(), 2);
        assert_eq!(kv.live_sequences(), 3);
    }

    #[test]
    fn fifo_blocks_on_big_head() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(4, 16); // 64 tokens capacity
        b.push(req(0, 64, 64)); // needs 8 blocks -> never fits
        b.push(req(1, 8, 8)); // would fit
        let admitted = b.admit(2, &mut kv);
        assert!(admitted.is_empty(), "FIFO must not leapfrog the head");
    }

    #[test]
    fn skip_small_leapfrogs_within_window() {
        let mut b = Batcher::new(BatchPolicy::SkipSmall { window: 2 });
        let mut kv = KvCacheManager::new(4, 16);
        b.push(req(0, 64, 64)); // stuck head
        b.push(req(1, 8, 8));
        let admitted = b.admit(2, &mut kv);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(b.pending(), 1); // head still waiting
    }

    #[test]
    fn incremental_reserves_prefill_only() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(4, 16); // 64-token pool
        // full reservation would need 4 blocks; incremental needs 1 now
        b.push(req(0, 16, 48));
        let admitted = b.admit_with(1, &mut kv, ReserveMode::Incremental);
        assert_eq!(admitted.len(), 1);
        assert_eq!(kv.free_blocks(), 3, "only the prefill row block is reserved");
        // a request that could never fit the pool alone is not admitted
        b.push(req(1, 16, 64)); // 80 tokens > 64-token pool
        assert!(b.admit_with(1, &mut kv, ReserveMode::Incremental).is_empty());
    }

    #[test]
    fn push_front_resumes_before_newer_arrivals() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(100, 16);
        b.push(req(1, 8, 8));
        b.push_front(req(0, 8, 8));
        let admitted = b.admit(2, &mut kv);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn admit_respects_slot_count() {
        let mut b = Batcher::new(BatchPolicy::Fifo);
        let mut kv = KvCacheManager::new(100, 16);
        for i in 0..10 {
            b.push(req(i, 8, 8));
        }
        assert_eq!(b.admit(4, &mut kv).len(), 4);
        assert_eq!(b.admit(0, &mut kv).len(), 0);
    }
}
