//! Radix-tree prefix cache over the paged KV store.
//!
//! SageAttention's quantize-once economics (§3) amortizes K/V smoothing
//! and quantization across *queries*; this module amortizes it across
//! *requests*. Each tree node covers one chunk of token ids and pins an
//! already-prefilled, already-quantized prefix as a cache-owned sequence
//! in the [`KvCacheManager`] / [`PagedKvStore`] pair — prefix sharing is
//! plain ref-counted block sharing, the same machinery that backs
//! copy-on-write forking. A prefill that matches a cached path forks the
//! deepest node's pages ([`PagedKvStore::fork_prefix`]) and computes only
//! the suffix.
//!
//! The chunk size is the caller's choice of alignment, a multiple of
//! [`PAGE_ROWS`]: pages are quantization-self-contained only as wholes,
//! and kernels with block-granular Q scales (`BLOCK_Q` rows per group)
//! additionally need hit lengths on a Q-group boundary for the suffix
//! forward to be bit-identical to an unshared run — so the native
//! backend passes `lcm(PAGE_ROWS, BLOCK_Q)` for such plans and
//! `PAGE_ROWS` otherwise.
//!
//! Eviction is LRU over *leaves* only (an inner node's blocks are prefix
//! of its children's, so freeing it alone would reclaim nothing), and a
//! node's blocks physically free only when their refcount drops to zero
//! — an entry currently forked by a live request is safe to evict
//! logically, its pages survive under the live reference.

use std::collections::HashMap;

use crate::attn::PAGE_ROWS;
use crate::util::error::{ensure, Result};

use super::kv_cache::KvCacheManager;
use super::paged_kv::PagedKvStore;
use super::request::RequestId;

/// Cache-owned sequences live in a reserved id namespace so they can
/// never collide with scheduler-issued request ids.
pub const CACHE_SEQ_BASE: RequestId = 1 << 62;

#[derive(Debug)]
struct Node {
    /// Token ids of this node's chunk (the edge label from the parent).
    key: Vec<i32>,
    parent: usize,
    /// Child node index per next-chunk token ids.
    children: HashMap<Vec<i32>, usize>,
    /// The cache-owned sequence pinning `depth * chunk` prefilled
    /// tokens (`None` only for the root and recycled slab entries).
    seq: Option<RequestId>,
    /// LRU clock value of the last lookup that traversed this node.
    last_hit: u64,
}

/// Telemetry counters (mirrored into `EngineStats` by the backend).
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixCacheStats {
    pub inserts: u64,
    pub evictions: u64,
}

/// Radix-tree prefix cache (see module docs).
#[derive(Debug)]
pub struct PrefixCache {
    /// Slab of nodes; index 0 is the root. Evicted slots are recycled
    /// through `free_slots`.
    nodes: Vec<Node>,
    free_slots: Vec<usize>,
    chunk: usize,
    next_seq: RequestId,
    clock: u64,
    pub stats: PrefixCacheStats,
}

impl PrefixCache {
    /// A cache at `chunk`-token granularity (multiple of [`PAGE_ROWS`];
    /// see the module docs for why kernels with block-granular Q scales
    /// need a coarser chunk).
    pub fn new(chunk: usize) -> PrefixCache {
        assert!(chunk > 0 && chunk % PAGE_ROWS == 0, "chunk must be a PAGE_ROWS multiple");
        PrefixCache {
            nodes: vec![Node {
                key: Vec::new(),
                parent: 0,
                children: HashMap::new(),
                seq: None,
                last_hit: 0,
            }],
            free_slots: Vec::new(),
            chunk,
            next_seq: CACHE_SEQ_BASE,
            clock: 0,
            stats: PrefixCacheStats::default(),
        }
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Materialized entries (= cache-owned sequences resident in the
    /// accountant and the store).
    pub fn entries(&self) -> usize {
        self.nodes.iter().filter(|n| n.seq.is_some()).count()
    }

    /// Whole chunks of `toks` usable as a cached prefix: capped one
    /// token short of the prompt so a hit always leaves at least one
    /// suffix token to prefill (the engine needs its logits).
    fn usable_chunks(&self, len: usize) -> usize {
        len.saturating_sub(1) / self.chunk
    }

    /// Longest cached prefix of `toks` in tokens, without touching LRU
    /// state — the admission-time credit estimate.
    pub fn lookup_len(&self, toks: &[i32]) -> usize {
        let (_, depth) = self.walk(toks, self.usable_chunks(toks.len()));
        depth * self.chunk
    }

    /// Longest cached prefix of `toks`: the pinning sequence id and the
    /// prefix length in tokens. Bumps the LRU clock of every node on
    /// the matched path.
    pub fn lookup(&mut self, toks: &[i32]) -> Option<(RequestId, usize)> {
        let (node, depth) = self.walk(toks, self.usable_chunks(toks.len()));
        if depth == 0 {
            return None;
        }
        self.clock += 1;
        let mut cur = node;
        while cur != 0 {
            self.nodes[cur].last_hit = self.clock;
            cur = self.nodes[cur].parent;
        }
        let seq = self.nodes[node].seq.expect("non-root nodes are materialized");
        Some((seq, depth * self.chunk))
    }

    /// Cache every whole chunk of `toks` along its radix path, pinning
    /// new depths by prefix-forking `src` (a live sequence holding at
    /// least `toks.len()` prefilled rows). Depths already cached are
    /// shared, not re-pinned. Requires enough free blocks only for the
    /// accountant's table clones — pages are shared, never copied.
    pub fn insert(
        &mut self,
        toks: &[i32],
        src: RequestId,
        kv: &mut KvCacheManager,
        store: &mut PagedKvStore,
    ) -> Result<()> {
        let chunks = toks.len() / self.chunk;
        let mut cur = 0usize;
        for c in 0..chunks {
            let key = toks[c * self.chunk..(c + 1) * self.chunk].to_vec();
            cur = match self.nodes[cur].children.get(&key) {
                Some(&child) => child,
                None => {
                    let sid = self.next_seq;
                    let rows = (c + 1) * self.chunk;
                    ensure!(
                        kv.fork_prefix(src, sid, rows).is_ok(),
                        "prefix-cache insert: cannot fork {rows} tokens of sequence {src}"
                    );
                    if let Err(e) = store.fork_prefix(src, sid, rows) {
                        let _ = kv.release(sid);
                        return Err(e);
                    }
                    self.next_seq += 1;
                    let node = Node {
                        key: key.clone(),
                        parent: cur,
                        children: HashMap::new(),
                        seq: Some(sid),
                        last_hit: self.clock,
                    };
                    let idx = match self.free_slots.pop() {
                        Some(i) => {
                            self.nodes[i] = node;
                            i
                        }
                        None => {
                            self.nodes.push(node);
                            self.nodes.len() - 1
                        }
                    };
                    self.nodes[cur].children.insert(key, idx);
                    self.stats.inserts += 1;
                    idx
                }
            };
        }
        Ok(())
    }

    /// Evict the least-recently-used *leaf* entry, releasing its
    /// sequence from the accountant and the store (blocks physically
    /// free only at refcount zero — entries still forked by live
    /// requests are safe to drop). Returns false when the cache is
    /// empty.
    pub fn evict_lru(
        &mut self,
        kv: &mut KvCacheManager,
        store: &mut PagedKvStore,
    ) -> Result<bool> {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, n)| n.seq.is_some() && n.children.is_empty())
            .min_by_key(|(_, n)| n.last_hit)
            .map(|(i, _)| i);
        let Some(idx) = victim else {
            return Ok(false);
        };
        let seq = self.nodes[idx].seq.expect("filtered on seq");
        store.release(seq, kv)?;
        ensure!(kv.release(seq).is_ok(), "prefix-cache entry {seq} unknown to the accountant");
        let parent = self.nodes[idx].parent;
        let key = std::mem::take(&mut self.nodes[idx].key);
        self.nodes[parent].children.remove(&key);
        self.nodes[idx].seq = None;
        self.nodes[idx].children = HashMap::new();
        self.free_slots.push(idx);
        self.stats.evictions += 1;
        Ok(true)
    }

    /// Evict LRU entries until the accountant has at least `need` free
    /// blocks or the cache is empty. Returns whether anything was
    /// evicted.
    pub fn reclaim(
        &mut self,
        kv: &mut KvCacheManager,
        store: &mut PagedKvStore,
        need: usize,
    ) -> Result<bool> {
        let mut any = false;
        while kv.free_blocks() < need && self.evict_lru(kv, store)? {
            any = true;
        }
        Ok(any)
    }

    /// Walk the radix path of `toks`, at most `max_chunks` deep.
    /// Returns the deepest matched node and its depth in chunks.
    fn walk(&self, toks: &[i32], max_chunks: usize) -> (usize, usize) {
        let mut cur = 0usize;
        let mut depth = 0usize;
        for c in 0..max_chunks {
            let key = &toks[c * self.chunk..(c + 1) * self.chunk];
            match self.nodes[cur].children.get(key) {
                Some(&child) => {
                    cur = child;
                    depth += 1;
                }
                None => break,
            }
        }
        (cur, depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::SAGE_B;
    use crate::synth::{make_qkv, Profile};

    /// A store + accountant pair with one live sequence of `n` prefilled
    /// rows under id 1.
    fn fixture(n: usize, pool: usize) -> (PagedKvStore, KvCacheManager) {
        let d = 16;
        let (_, k, v) = make_qkv(71, [1, 1, n, d], Profile::llama_like());
        let mut store = PagedKvStore::new(1, 1, d, SAGE_B).unwrap();
        let mut kv = KvCacheManager::new(pool, PAGE_ROWS);
        kv.allocate(1, n).unwrap();
        store.register(1).unwrap();
        let table = kv.seq_blocks(1).unwrap().to_vec();
        store.append_layer(1, &table, 0, &k.data, &v.data, n).unwrap();
        (store, kv)
    }

    #[test]
    fn lookup_walks_longest_cached_prefix() {
        let n = 3 * PAGE_ROWS;
        let (mut store, mut kv) = fixture(n, 16);
        let mut cache = PrefixCache::new(PAGE_ROWS);
        let toks: Vec<i32> = (0..n as i32).collect();
        cache.insert(&toks, 1, &mut kv, &mut store).unwrap();
        assert_eq!(cache.entries(), 3);

        // full match, capped one token short of the prompt: a prompt of
        // exactly n tokens may only use 2 chunks
        assert_eq!(cache.lookup_len(&toks), 2 * PAGE_ROWS);
        // longer prompt with the same prefix uses all 3 chunks
        let mut longer = toks.clone();
        longer.extend([9999, 9998]);
        let (seq, len) = cache.lookup(&longer).unwrap();
        assert_eq!(len, 3 * PAGE_ROWS);
        assert!(seq >= CACHE_SEQ_BASE);
        // diverging second chunk matches only the first
        let mut diverge = toks.clone();
        diverge[PAGE_ROWS] ^= 1;
        assert_eq!(cache.lookup_len(&diverge), PAGE_ROWS);
        // diverging first token matches nothing
        let mut miss = toks.clone();
        miss[0] ^= 1;
        assert!(cache.lookup(&miss).is_none());

        kv.check_invariants().unwrap();
        store
            .audit(|id| kv.seq_blocks(id).map(<[_]>::to_vec), |b| kv.ref_count(b))
            .unwrap();
    }

    #[test]
    fn eviction_is_lru_leaf_first_and_frees_unshared_blocks() {
        let n = 2 * PAGE_ROWS;
        let (mut store, mut kv) = fixture(n, 16);
        let mut cache = PrefixCache::new(PAGE_ROWS);
        let toks: Vec<i32> = (0..n as i32).collect();
        cache.insert(&toks, 1, &mut kv, &mut store).unwrap();
        // release the live source; the cache alone pins the blocks now
        store.release(1, &kv).unwrap();
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 16 - 2);

        // first eviction takes the leaf (depth 2), freeing its private
        // tail block only; the root child (depth 1) still pins block 0
        assert!(cache.evict_lru(&mut kv, &mut store).unwrap());
        assert_eq!(cache.entries(), 1);
        assert_eq!(kv.free_blocks(), 16 - 1);
        assert!(cache.evict_lru(&mut kv, &mut store).unwrap());
        assert_eq!(cache.entries(), 0);
        assert_eq!(kv.free_blocks(), 16);
        assert_eq!(store.resident_bytes(), 0);
        assert!(!cache.evict_lru(&mut kv, &mut store).unwrap());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reclaim_respects_lru_order_across_paths() {
        let n = PAGE_ROWS;
        let (mut store, mut kv) = fixture(n, 4);
        let toks_a: Vec<i32> = (0..n as i32).collect();
        let mut cache = PrefixCache::new(PAGE_ROWS);
        cache.insert(&toks_a, 1, &mut kv, &mut store).unwrap();
        store.release(1, &kv).unwrap();
        kv.release(1).unwrap();

        // a second, diverging cached path
        let d = 16;
        let (_, k, v) = make_qkv(72, [1, 1, n, d], Profile::llama_like());
        kv.allocate(2, n).unwrap();
        store.register(2).unwrap();
        let t2 = kv.seq_blocks(2).unwrap().to_vec();
        store.append_layer(2, &t2, 0, &k.data, &v.data, n).unwrap();
        let toks_b: Vec<i32> = (1000..1000 + n as i32).collect();
        cache.insert(&toks_b, 2, &mut kv, &mut store).unwrap();
        store.release(2, &kv).unwrap();
        kv.release(2).unwrap();

        // touch path A so B becomes the LRU victim
        let mut probe = toks_a.clone();
        probe.push(7);
        assert!(cache.lookup(&probe).is_some());
        assert!(cache.evict_lru(&mut kv, &mut store).unwrap());
        let mut probe_b = toks_b.clone();
        probe_b.push(7);
        assert!(cache.lookup(&probe_b).is_none(), "LRU must have evicted path B");
        assert!(cache.lookup(&probe).is_some(), "path A must survive");
    }
}
