//! Request/response types flowing through the serving stack.

use std::time::Instant;

use crate::util::rng::Pcg32;

pub type RequestId = u64;

/// Sampling parameters.
#[derive(Clone, Copy, Debug)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
    /// Stop token (model-dependent); `None` = run to max_new_tokens.
    pub stop_token: Option<i32>,
    pub seed: u64,
    /// TTFT deadline in scheduler ticks (virtual time — deterministic
    /// under replay); the request is cancelled if its first token has not
    /// been produced within this many ticks of submission.
    pub ttft_deadline: Option<u64>,
    /// Total-completion deadline in scheduler ticks from submission.
    pub total_deadline: Option<u64>,
    /// SLO *target* (not a hard deadline): desired TTFT in scheduler
    /// ticks. Unlike `ttft_deadline`, missing it never cancels work —
    /// the admission controller sheds at saturation and the fleet
    /// reports goodput-under-SLO (fraction of requests meeting targets).
    pub slo_ttft: Option<u64>,
    /// SLO target: desired mean ticks per output token after the first.
    pub slo_tpot: Option<f64>,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_new_tokens: 32,
            temperature: 0.0,
            stop_token: None,
            seed: 0,
            ttft_deadline: None,
            total_deadline: None,
            slo_ttft: None,
            slo_tpot: None,
        }
    }
}

/// Decode progress carried across a preemption (recompute-on-resume):
/// the tokens generated so far, the sampler state, and the original
/// first-token timestamp so TTFT stays honest. The KV itself is *not*
/// carried — it is recomputed by re-prefilling `prompt ++ generated`
/// (vLLM's recompute preemption), which the paged cache's bit-identity
/// invariant makes exact for the resident quantized state.
#[derive(Clone, Debug)]
pub struct ResumeState {
    /// Tokens sampled before the preemption (the last one has not been
    /// fed through a decode step yet).
    pub generated: Vec<i32>,
    pub rng: Pcg32,
    pub first_token_at: Instant,
    /// How many of `generated` were already streamed to the token sink
    /// before the preemption — the resumed slot starts emitting at this
    /// index, so failover/recompute never double-emits a token.
    pub streamed: usize,
}

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub params: GenParams,
    pub arrival: Instant,
    /// `Some` when this request was preempted and requeued.
    pub resume: Option<ResumeState>,
    /// Numeric degraded mode: a non-finite guard trip on the sage plan
    /// flags the request, and every later (re)compute runs its attention
    /// on the fp path while KV pages stay in the shared quantized store.
    pub degraded: bool,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, params: GenParams) -> Request {
        Request { id, prompt, params, arrival: Instant::now(), resume: None, degraded: false }
    }

    /// Total KV footprint this request may need (prompt + generation).
    pub fn max_tokens(&self) -> usize {
        self.prompt.len() + self.params.max_new_tokens
    }

    /// Tokens a prefill must process to (re)build this request's KV
    /// prefix: the prompt, plus — after a preemption — every generated
    /// token that had already been fed through a decode step (all but
    /// the last sampled one).
    pub fn prefill_tokens(&self) -> Vec<i32> {
        let mut toks = self.prompt.clone();
        if let Some(r) = &self.resume {
            toks.extend_from_slice(&r.generated[..r.generated.len().saturating_sub(1)]);
        }
        toks
    }

    /// Length of [`Request::prefill_tokens`] without materializing it.
    pub fn prefill_len(&self) -> usize {
        self.prompt.len()
            + self.resume.as_ref().map_or(0, |r| r.generated.len().saturating_sub(1))
    }

    /// Generation budget still outstanding.
    pub fn remaining_new_tokens(&self) -> usize {
        self.params
            .max_new_tokens
            .saturating_sub(self.resume.as_ref().map_or(0, |r| r.generated.len()))
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    /// Evicted: would not fit (admission failure surfaced to the caller).
    Rejected,
    /// Terminal failure after exhausting the retry budget (or a
    /// non-retryable hard error) — never a silent drop.
    Failed,
    /// Cancelled because a TTFT/total deadline expired.
    DeadlineExceeded,
    /// Shed by SLO-aware admission: the controller judged (from the live
    /// queue-delay estimate) that the request could not meet its TTFT
    /// target at current load, and rejected it instead of serving a
    /// guaranteed SLO miss.
    Shed,
}

/// A finished request with serving telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Time to first token (prefill + queueing), ms.
    pub ttft_ms: f64,
    /// Queue delay — arrival to engine admission, ms. Splitting this out
    /// of TTFT keeps open-loop replay honest: a flattering TTFT can no
    /// longer hide time spent waiting in the batcher queue.
    pub queue_ms: f64,
    /// Mean time per output token after the first, ms; `None` for
    /// single-token responses (no inter-token interval exists — a
    /// fabricated denominator would understate tail TPOT).
    pub tpot_ms: Option<f64>,
    /// End-to-end latency, ms.
    pub e2e_ms: f64,
    /// `Some(why)` for terminal failures ([`FinishReason::Failed`] /
    /// [`FinishReason::DeadlineExceeded`] / [`FinishReason::Rejected`]).
    pub error: Option<String>,
}

impl Response {
    /// A typed terminal failure: the request leaves the system through a
    /// `Response`, never by vanishing from a queue.
    pub fn failure(id: RequestId, finish: FinishReason, why: impl Into<String>) -> Response {
        Response {
            id,
            tokens: Vec::new(),
            finish,
            ttft_ms: 0.0,
            queue_ms: 0.0,
            tpot_ms: None,
            e2e_ms: 0.0,
            error: Some(why.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_tokens_budget() {
        let r = Request::new(
            1,
            vec![1, 2, 3],
            GenParams { max_new_tokens: 5, ..Default::default() },
        );
        assert_eq!(r.max_tokens(), 8);
        assert_eq!(r.prefill_len(), 3);
        assert_eq!(r.prefill_tokens(), vec![1, 2, 3]);
        assert_eq!(r.remaining_new_tokens(), 5);
    }

    #[test]
    fn resume_accounting() {
        let mut r = Request::new(
            2,
            vec![1, 2],
            GenParams { max_new_tokens: 5, ..Default::default() },
        );
        r.resume = Some(ResumeState {
            generated: vec![10, 11, 12],
            rng: Pcg32::seeded(0),
            first_token_at: Instant::now(),
            streamed: 0,
        });
        // the last sampled token (12) has not been fed yet: the re-prefill
        // covers prompt + fed tokens, and 12 rides as the next decode input
        assert_eq!(r.prefill_tokens(), vec![1, 2, 10, 11]);
        assert_eq!(r.prefill_len(), 4);
        assert_eq!(r.remaining_new_tokens(), 2);
        assert_eq!(r.max_tokens(), 7);
    }
}
