//! Request/response types flowing through the serving stack.

use std::time::Instant;

pub type RequestId = u64;

/// Sampling parameters.
#[derive(Clone, Copy, Debug)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
    /// Stop token (model-dependent); `None` = run to max_new_tokens.
    pub stop_token: Option<i32>,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams { max_new_tokens: 32, temperature: 0.0, stop_token: None, seed: 0 }
    }
}

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub params: GenParams,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, params: GenParams) -> Request {
        Request { id, prompt, params, arrival: Instant::now() }
    }

    /// Total KV footprint this request may need (prompt + generation).
    pub fn max_tokens(&self) -> usize {
        self.prompt.len() + self.params.max_new_tokens
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    /// Evicted: would not fit (admission failure surfaced to the caller).
    Rejected,
}

/// A finished request with serving telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Time to first token (prefill + queueing), ms.
    pub ttft_ms: f64,
    /// Mean time per output token after the first, ms.
    pub tpot_ms: f64,
    /// End-to-end latency, ms.
    pub e2e_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_tokens_budget() {
        let r = Request::new(
            1,
            vec![1, 2, 3],
            GenParams { max_new_tokens: 5, ..Default::default() },
        );
        assert_eq!(r.max_tokens(), 8);
    }
}
