//! Prefill/decode scheduler: ties batcher + KV accountant + engine into
//! the serving loop. One `tick()` = admit what fits, prefill admissions,
//! advance the decode batch one token, release finished sequences and
//! requeue preempted ones.
//!
//! Failure discipline (ISSUE 7): a request only ever leaves the
//! scheduler through a [`Response`] — successful, or a typed terminal
//! failure — never by silently vanishing. An errored `tick()` drains
//! every in-flight slot back into the queue with its physical and
//! logical KV released, so `check_invariants` / `audit` stay clean on
//! the error path and a supervisor can re-drive or fail over the queue.

use crate::attn::guard::is_nonfinite_err;
use crate::obs::{EventKind, Obs, NO_REPLICA};
use crate::util::error::{bail, Result};

use crate::metrics::LatencyStats;

use std::sync::{Arc, Mutex};

use super::batcher::Batcher;
use super::engine::Engine;
use super::kv_cache::KvCacheManager;
use super::request::{FinishReason, Request, RequestId, Response};
use super::traffic::TokenSink;

/// Serving telemetry for one run.
#[derive(Debug, Default)]
pub struct SchedulerReport {
    pub responses: Vec<Response>,
    /// TTFT over *successful* responses only — failed or cancelled
    /// attempts would pollute the latency stats with zeros/partials.
    pub ttft: LatencyStats,
    /// TPOT over successful multi-token responses only (single-token
    /// responses have no inter-token interval and report `tpot_ms: None`).
    pub tpot: LatencyStats,
    /// Arrival→admission wait over successful responses — the queueing
    /// component of TTFT, split out so saturation shows up as queue
    /// growth rather than as mysterious prefill slowness.
    pub queue_delay: LatencyStats,
    pub e2e: LatencyStats,
    pub wall_s: f64,
    pub tokens_out: u64,
    /// Requests preempted for KV blocks and requeued (native backend's
    /// recompute-on-resume policy).
    pub preemptions: u64,
    /// Admissions bounced by the engine (no slot after all, a stale
    /// prefix-cache credit, or an injected OutOfBlocks) and requeued
    /// with their blocks released — never silently dropped.
    pub requeued: u64,
    /// Responses whose TPOT was undefined (single-token).
    pub tpot_undefined: u64,
    /// Prefix-cache lookups at prefill (`--prefix-cache`).
    pub prefix_lookups: u64,
    /// Prefills that forked a cached prefix instead of recomputing it.
    pub prefix_hits: u64,
    /// Prefill rows served from cached pages (never recomputed).
    pub prefill_tokens_saved: u64,
    /// Cached prefixes LRU-evicted under pool pressure.
    pub cache_evictions: u64,
    /// Blocks privately copied by the copy-on-write barrier.
    pub cow_copies: u64,
    /// Terminal failures ([`FinishReason::Failed`] / `Rejected`) —
    /// requests that left through a typed failure response.
    pub failed: u64,
    /// Requests cancelled by a TTFT/total deadline.
    pub cancelled_deadline: u64,
    /// Requests shed by SLO admission control — turned away up front
    /// because their TTFT target was already unreachable at the offered
    /// load ([`FinishReason::Shed`]).
    pub shed: u64,
    /// Numeric-guard trips retried on the fp attention path.
    pub degraded_fallbacks: u64,
    /// Faults injected into this replica (fault plane active).
    pub injected: u64,
    /// Step errors retried by the fleet supervisor (fleet runs only).
    pub retried: u64,
    /// Requests re-routed off a crashed replica (fleet runs only).
    pub failed_over: u64,
    /// Requests dropped without any response — must stay 0; counted by
    /// the fleet's terminal accounting (`served + failed == submitted`).
    pub dropped: u64,
}

impl SchedulerReport {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.wall_s
        }
    }

    /// Fraction of prefix-cache lookups that hit.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Successful responses (the complement of `failed + cancelled`).
    pub fn served(&self) -> u64 {
        self.responses
            .iter()
            .filter(|r| {
                matches!(r.finish, FinishReason::MaxTokens | FinishReason::StopToken)
            })
            .count() as u64
    }
}

/// The serving loop driver.
pub struct Scheduler {
    pub batcher: Batcher,
    pub kv: KvCacheManager,
    pub engine: Engine,
    report: SchedulerReport,
    /// Per-token streaming receiver; shared so one fleet-level ledger
    /// can audit every replica's stream.
    sink: Option<Arc<Mutex<dyn TokenSink>>>,
    /// Observability handle (disabled = every emit is one dead branch)
    /// and the replica id stamped on scheduler lifecycle events.
    obs: Obs,
    replica: u32,
    /// Under a fleet, arrival (`Submit`) belongs to the fleet driver —
    /// the scheduler only sees requests at dispatch time.
    fleet_managed: bool,
}

impl Scheduler {
    pub fn new(batcher: Batcher, kv: KvCacheManager, engine: Engine) -> Scheduler {
        Scheduler {
            batcher,
            kv,
            engine,
            report: SchedulerReport::default(),
            sink: None,
            obs: Obs::disabled(),
            replica: NO_REPLICA,
            fleet_managed: false,
        }
    }

    /// Install a streaming sink: every token the engine samples from
    /// here on is forwarded as it is produced.
    pub fn set_sink(&mut self, sink: Arc<Mutex<dyn TokenSink>>) {
        self.sink = Some(sink);
    }

    /// Attach an observability handle: lifecycle events stamp `replica`,
    /// terminal latency samples record into the shared `ttft_us` /
    /// `queue_us` / `tpot_us` / `e2e_us` histograms (the single TTFT
    /// clock both the scheduler report and the fleet ledger read), and
    /// the engine arms its kernel phase profiler. `fleet_managed`
    /// suppresses `Submit` events — the fleet records arrival when the
    /// request enters the system, before dispatch.
    pub fn set_obs(&mut self, obs: Obs, replica: u32, fleet_managed: bool) {
        self.engine.set_obs(obs.clone(), replica);
        self.obs = obs;
        self.replica = replica;
        self.fleet_managed = fleet_managed;
    }

    pub fn submit(&mut self, req: Request) {
        if !self.fleet_managed {
            let kind = EventKind::Submit { prompt_len: req.prompt.len() as u32 };
            self.obs.emit(self.replica, req.id, kind);
        }
        self.batcher.push(req);
    }

    pub fn has_work(&self) -> bool {
        !self.batcher.is_empty() || self.engine.live_slots() > 0
    }

    /// One scheduling round. Returns responses that finished this tick
    /// (successes *and* typed terminal failures).
    pub fn tick(&mut self) -> Result<Vec<Response>> {
        // 1. admission: fill free decode slots from the queue, gated by
        //    slot availability and KV capacity under the backend's
        //    reservation discipline
        let mode = self.engine.reserve_mode();
        let free = self.engine.free_slots();
        let mut failures: Vec<Response> = Vec::new();
        let mut bounced = false;
        if free > 0 && !self.batcher.is_empty() {
            let mut admitted =
                self.batcher.admit_gated(free, &mut self.kv, mode, &mut self.engine)?;
            let mut iter = admitted.drain(..);
            while let Some(req) = iter.next() {
                match self.engine.add_request(&req, &mut self.kv) {
                    Ok(true) => {
                        let kind = EventKind::Admit { resumed: req.resume.is_some() };
                        self.obs.emit(self.replica, req.id, kind);
                    }
                    Ok(false) => {
                        // the engine bounced an admission the batcher had
                        // already reserved blocks for (full after all, a
                        // stale prefix-cache credit, or an injected OOM):
                        // release + requeue it and everything behind it,
                        // head-first in original order — dropping any of
                        // these would leak their blocks forever
                        bounced = true;
                        self.report.requeued += 1;
                        self.obs.emit(self.replica, req.id, EventKind::Requeue);
                        let rest: Vec<Request> = std::iter::once(req).chain(iter).collect();
                        for r in rest.into_iter().rev() {
                            let _ = self.kv.release(r.id);
                            self.batcher.push_front(r);
                        }
                        break;
                    }
                    Err(e) => {
                        // the backend left no physical residue; drop the
                        // logical reservation before deciding the fate
                        let _ = self.kv.release(req.id);
                        let msg = format!("{e:#}");
                        if is_nonfinite_err(&msg) && !req.degraded {
                            // quantized-plan blow-up at prefill: retry
                            // this request on the fp attention path
                            let mut retry = req;
                            retry.degraded = true;
                            self.report.degraded_fallbacks += 1;
                            self.obs.emit(self.replica, retry.id, EventKind::Degrade);
                            bounced = true; // suppress the stall bail
                            self.batcher.push_front(retry);
                        } else {
                            // unservable (bad prompt, over budget, fp
                            // path still non-finite): typed failure, keep
                            // serving the rest of the batch
                            failures.push(Response::failure(
                                req.id,
                                FinishReason::Failed,
                                msg,
                            ));
                        }
                    }
                }
            }
        }
        // stall detection: the engine is idle, every resident sequence
        // (if any) belongs to the backend's reclaimable prefix cache,
        // and the queue head still did not fit — admission already tried
        // evicting that cache, so this can never change; fail loudly
        // instead of spinning forever. Skipped on any bounced/degraded
        // requeue this tick: those heads *can* be admitted later.
        if !bounced
            && failures.is_empty()
            && self.engine.live_slots() == 0
            && !self.batcher.is_empty()
            && self.kv.live_sequences() == self.engine.cached_sequences()
        {
            bail!(
                "queued request can never be admitted: it needs more KV blocks \
                 than the whole pool holds ({} blocks of {})",
                self.kv.total_blocks(),
                self.kv.block_size()
            );
        }
        // 2. decode step for the live batch
        let outcome = match self.engine.step(&mut self.kv) {
            Ok(o) => o,
            Err(e) => {
                // drain every in-flight slot back into the queue with its
                // physical AND logical KV released: the error path leaves
                // the accountant/audit clean and loses no request
                let drained = self.engine.drain(&mut self.kv)?;
                for req in drained.into_iter().rev() {
                    self.batcher.push_front(req);
                }
                for resp in failures {
                    self.record_failure(resp);
                }
                return Err(e);
            }
        };
        // stream tokens sampled this tick. Only a *successful* step
        // streams: an errored step drains slots with their `streamed`
        // watermarks intact, so failover resumes exactly past the last
        // token the sink saw — no duplicates, no gaps.
        if let Some(sink) = &self.sink {
            if !outcome.streamed.is_empty() {
                let mut sink = sink.lock().expect("token sink poisoned");
                for tok in &outcome.streamed {
                    sink.on_token(*tok);
                }
            }
        }
        // 3. requeue preempted requests at the head (their logical and
        //    physical blocks were released inside the step), and
        //    numeric-guard evictions flagged for the fp path
        for req in outcome.preempted {
            self.report.preemptions += 1;
            self.obs.emit(self.replica, req.id, EventKind::Preempt);
            self.batcher.push_front(req);
        }
        for req in outcome.degraded {
            self.report.degraded_fallbacks += 1;
            self.obs.emit(self.replica, req.id, EventKind::Degrade);
            self.batcher.push_front(req);
        }
        // 4. release finished sequences' logical KV blocks (backends
        //    reclaim the physical side themselves)
        let mut done = outcome.finished;
        for resp in &done {
            let _ = self.kv.release(resp.id);
        }
        done.extend(failures);
        for resp in &done {
            self.record_response(resp);
        }
        self.report.responses.extend(done.iter().cloned());
        Ok(done)
    }

    /// Record telemetry for one terminal response. Latency stats cover
    /// successful attempts only — failure/cancellation responses carry
    /// no meaningful latency and would skew the percentiles. This is
    /// the *only* place a terminal trace event is emitted and the only
    /// writer of the shared latency histograms — every other layer
    /// (fleet ledgers included) funnels terminals through here, which
    /// is what keeps one request = one terminal span and one TTFT
    /// sample per served request.
    fn record_response(&mut self, resp: &Response) {
        match resp.finish {
            FinishReason::MaxTokens | FinishReason::StopToken => {
                self.report.ttft.record(std::time::Duration::from_micros(
                    (resp.ttft_ms * 1000.0) as u64,
                ));
                self.report.queue_delay.record(std::time::Duration::from_micros(
                    (resp.queue_ms.max(0.0) * 1000.0) as u64,
                ));
                match resp.tpot_ms {
                    Some(tpot) => self.report.tpot.record(
                        std::time::Duration::from_micros((tpot.max(0.0) * 1000.0) as u64),
                    ),
                    None => self.report.tpot_undefined += 1,
                }
                self.report.e2e.record(std::time::Duration::from_micros(
                    (resp.e2e_ms * 1000.0) as u64,
                ));
                self.report.tokens_out += resp.tokens.len() as u64;
                self.obs.record_us("ttft_us", (resp.ttft_ms * 1000.0) as u64);
                self.obs.record_us("queue_us", (resp.queue_ms.max(0.0) * 1000.0) as u64);
                if let Some(tpot) = resp.tpot_ms {
                    self.obs.record_us("tpot_us", (tpot.max(0.0) * 1000.0) as u64);
                }
                self.obs.record_us("e2e_us", (resp.e2e_ms * 1000.0) as u64);
                let kind = EventKind::Finish { tokens: resp.tokens.len() as u32 };
                self.obs.emit(self.replica, resp.id, kind);
            }
            FinishReason::DeadlineExceeded => {
                self.report.cancelled_deadline += 1;
                self.obs.emit(self.replica, resp.id, EventKind::DeadlineCancel);
            }
            FinishReason::Shed => {
                self.report.shed += 1;
                self.obs.emit(self.replica, resp.id, EventKind::Shed);
            }
            FinishReason::Failed | FinishReason::Rejected => {
                self.report.failed += 1;
                self.obs.emit(self.replica, resp.id, EventKind::Fail);
            }
        }
    }

    /// Record a terminal failure produced outside `tick` (deadline
    /// sweeps, retry-budget exhaustion at the fleet level).
    pub fn record_failure(&mut self, resp: Response) {
        self.record_response(&resp);
        self.report.responses.push(resp);
    }

    /// Cancel one request wherever it lives: a queued copy is removed
    /// (queued requests hold no KV), a live slot is cancelled with its
    /// physical then logical KV released (audit-clean). Returns whether
    /// anything was cancelled.
    pub fn cancel(&mut self, id: RequestId) -> Result<bool> {
        if self.batcher.remove(id).is_some() {
            return Ok(true);
        }
        if self.engine.cancel(id, &mut self.kv)? {
            let _ = self.kv.release(id);
            return Ok(true);
        }
        Ok(false)
    }

    /// Evict everything — live slots (KV released) and the queue — for
    /// crash failover: the returned requests are ready to re-route.
    pub fn drain(&mut self) -> Result<Vec<Request>> {
        let mut out = self.engine.drain(&mut self.kv)?;
        out.extend(self.batcher.drain_all());
        Ok(out)
    }

    /// Copy the engine's cumulative prefix-cache / CoW / fault counters
    /// into the report (they live engine-side because the hits happen
    /// inside `add_request` / `step`).
    fn absorb_engine_stats(&mut self) {
        let s = self.engine.stats();
        self.report.prefix_lookups = s.prefix_lookups;
        self.report.prefix_hits = s.prefix_hits;
        self.report.prefill_tokens_saved = s.prefill_tokens_saved;
        self.report.cache_evictions = s.cache_evictions;
        self.report.cow_copies = s.cow_copies;
        if let Some(f) = self.engine.fault_stats() {
            self.report.injected = f.total();
        }
    }

    /// Mirror the report's counters into the shared metrics registry at
    /// report time, so the exported surface (Prometheus text, trace
    /// `otherData.metrics`) carries exactly what the human tables print.
    /// Counters are monotone and replicas share one registry, so a
    /// fleet's registry holds the across-replica sums.
    fn publish_report_metrics(&self) {
        if !self.obs.is_enabled() {
            return;
        }
        let r = &self.report;
        for (name, v) in [
            ("served", r.served()),
            ("tokens_out", r.tokens_out),
            ("preemptions", r.preemptions),
            ("requeued", r.requeued),
            ("failed", r.failed),
            ("shed", r.shed),
            ("cancelled_deadline", r.cancelled_deadline),
            ("degraded_fallbacks", r.degraded_fallbacks),
            ("faults_injected", r.injected),
            ("prefix_lookups", r.prefix_lookups),
            ("prefix_hits", r.prefix_hits),
            ("prefill_tokens_saved", r.prefill_tokens_saved),
            ("cache_evictions", r.cache_evictions),
            ("cow_copies", r.cow_copies),
        ] {
            if v > 0 {
                self.obs.counter_add(name, v);
            }
        }
    }

    /// Drive to completion and return the report.
    pub fn run_to_completion(mut self) -> Result<SchedulerReport> {
        let t0 = std::time::Instant::now();
        while self.has_work() {
            self.tick()?;
        }
        self.report.wall_s = t0.elapsed().as_secs_f64();
        self.absorb_engine_stats();
        self.publish_report_metrics();
        Ok(self.report)
    }

    pub fn into_report(mut self, wall_s: f64) -> SchedulerReport {
        self.report.wall_s = wall_s;
        self.absorb_engine_stats();
        self.publish_report_metrics();
        std::mem::take(&mut self.report)
    }
}
