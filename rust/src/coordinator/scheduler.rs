//! Prefill/decode scheduler: ties batcher + KV accountant + engine into
//! the serving loop. One `tick()` = admit what fits, prefill admissions,
//! advance the decode batch one token, release finished sequences.

use crate::util::error::Result;

use crate::metrics::LatencyStats;

use super::batcher::Batcher;
use super::engine::Engine;
use super::kv_cache::KvCacheManager;
use super::request::{Request, Response};

/// Serving telemetry for one run.
#[derive(Debug, Default)]
pub struct SchedulerReport {
    pub responses: Vec<Response>,
    pub ttft: LatencyStats,
    pub tpot: LatencyStats,
    pub e2e: LatencyStats,
    pub wall_s: f64,
    pub tokens_out: u64,
}

impl SchedulerReport {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.wall_s
        }
    }
}

/// The serving loop driver.
pub struct Scheduler {
    pub batcher: Batcher,
    pub kv: KvCacheManager,
    pub engine: Engine,
    report: SchedulerReport,
}

impl Scheduler {
    pub fn new(batcher: Batcher, kv: KvCacheManager, engine: Engine) -> Scheduler {
        Scheduler { batcher, kv, engine, report: SchedulerReport::default() }
    }

    pub fn submit(&mut self, req: Request) {
        self.batcher.push(req);
    }

    pub fn has_work(&self) -> bool {
        !self.batcher.is_empty() || self.engine.live_slots() > 0
    }

    /// One scheduling round. Returns responses that finished this tick.
    pub fn tick(&mut self) -> Result<Vec<Response>> {
        // 1. admission: fill free decode slots from the queue, gated by
        //    both slot availability and KV block capacity
        let free = self.engine.free_slots();
        if free > 0 && !self.batcher.is_empty() {
            for req in self.batcher.admit(free, &mut self.kv) {
                let ok = self.engine.add_request(&req)?;
                debug_assert!(ok, "engine slot accounting diverged from batcher");
            }
        }
        // 2. decode step for the live batch
        let done = self.engine.step()?;
        // 3. release finished sequences' KV blocks
        for resp in &done {
            let _ = self.kv.release(resp.id);
            self.report.ttft.record(std::time::Duration::from_micros(
                (resp.ttft_ms * 1000.0) as u64,
            ));
            self.report.tpot.record(std::time::Duration::from_micros(
                (resp.tpot_ms.max(0.0) * 1000.0) as u64,
            ));
            self.report.e2e.record(std::time::Duration::from_micros(
                (resp.e2e_ms * 1000.0) as u64,
            ));
            self.report.tokens_out += resp.tokens.len() as u64;
        }
        self.report.responses.extend(done.iter().cloned());
        Ok(done)
    }

    /// Drive to completion and return the report.
    pub fn run_to_completion(mut self) -> Result<SchedulerReport> {
        let t0 = std::time::Instant::now();
        while self.has_work() {
            self.tick()?;
        }
        self.report.wall_s = t0.elapsed().as_secs_f64();
        Ok(self.report)
    }

    pub fn into_report(mut self, wall_s: f64) -> SchedulerReport {
        self.report.wall_s = wall_s;
        std::mem::take(&mut self.report)
    }
}
