//! Prefill/decode scheduler: ties batcher + KV accountant + engine into
//! the serving loop. One `tick()` = admit what fits, prefill admissions,
//! advance the decode batch one token, release finished sequences and
//! requeue preempted ones.

use crate::util::error::{bail, Result};

use crate::metrics::LatencyStats;

use super::batcher::Batcher;
use super::engine::Engine;
use super::kv_cache::KvCacheManager;
use super::request::{Request, Response};

/// Serving telemetry for one run.
#[derive(Debug, Default)]
pub struct SchedulerReport {
    pub responses: Vec<Response>,
    pub ttft: LatencyStats,
    /// TPOT over multi-token responses only (single-token responses have
    /// no inter-token interval and report `tpot_ms: None`).
    pub tpot: LatencyStats,
    pub e2e: LatencyStats,
    pub wall_s: f64,
    pub tokens_out: u64,
    /// Requests preempted for KV blocks and requeued (native backend's
    /// recompute-on-resume policy).
    pub preemptions: u64,
    /// Admissions bounced by the engine (no slot after all, or a stale
    /// prefix-cache credit) and requeued with their blocks released —
    /// never silently dropped.
    pub requeued: u64,
    /// Responses whose TPOT was undefined (single-token).
    pub tpot_undefined: u64,
    /// Prefix-cache lookups at prefill (`--prefix-cache`).
    pub prefix_lookups: u64,
    /// Prefills that forked a cached prefix instead of recomputing it.
    pub prefix_hits: u64,
    /// Prefill rows served from cached pages (never recomputed).
    pub prefill_tokens_saved: u64,
    /// Cached prefixes LRU-evicted under pool pressure.
    pub cache_evictions: u64,
    /// Blocks privately copied by the copy-on-write barrier.
    pub cow_copies: u64,
}

impl SchedulerReport {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.wall_s
        }
    }

    /// Fraction of prefix-cache lookups that hit.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }
}

/// The serving loop driver.
pub struct Scheduler {
    pub batcher: Batcher,
    pub kv: KvCacheManager,
    pub engine: Engine,
    report: SchedulerReport,
}

impl Scheduler {
    pub fn new(batcher: Batcher, kv: KvCacheManager, engine: Engine) -> Scheduler {
        Scheduler { batcher, kv, engine, report: SchedulerReport::default() }
    }

    pub fn submit(&mut self, req: Request) {
        self.batcher.push(req);
    }

    pub fn has_work(&self) -> bool {
        !self.batcher.is_empty() || self.engine.live_slots() > 0
    }

    /// One scheduling round. Returns responses that finished this tick.
    pub fn tick(&mut self) -> Result<Vec<Response>> {
        // 1. admission: fill free decode slots from the queue, gated by
        //    slot availability and KV capacity under the backend's
        //    reservation discipline
        let mode = self.engine.reserve_mode();
        let free = self.engine.free_slots();
        if free > 0 && !self.batcher.is_empty() {
            let mut admitted =
                self.batcher.admit_gated(free, &mut self.kv, mode, &mut self.engine)?;
            let mut placed = 0;
            let mut admit_err = None;
            while placed < admitted.len() {
                match self.engine.add_request(&admitted[placed], &mut self.kv) {
                    Ok(true) => placed += 1,
                    Ok(false) => {
                        // the engine bounced an admission the batcher had
                        // already reserved blocks for (the release-builds
                        // failure mode behind the old debug_assert!)
                        self.report.requeued += 1;
                        break;
                    }
                    Err(e) => {
                        admit_err = Some(e);
                        break;
                    }
                }
            }
            // everything not placed still holds its reservation: release
            // it and requeue at the head in original order — dropping any
            // of these would leak their blocks forever. A hard-errored
            // request is unservable (bad prompt, over budget): drop it
            // with its blocks released and surface the error.
            let mut not_placed = admitted.split_off(placed);
            if admit_err.is_some() && !not_placed.is_empty() {
                let failed = not_placed.remove(0);
                let _ = self.kv.release(failed.id);
            }
            for req in not_placed.into_iter().rev() {
                let _ = self.kv.release(req.id);
                self.batcher.push_front(req);
            }
            if let Some(e) = admit_err {
                return Err(e);
            }
        }
        // stall detection: the engine is idle, every resident sequence
        // (if any) belongs to the backend's reclaimable prefix cache,
        // and the queue head still did not fit — admission already tried
        // evicting that cache, so this can never change; fail loudly
        // instead of spinning forever
        if self.engine.live_slots() == 0
            && !self.batcher.is_empty()
            && self.kv.live_sequences() == self.engine.cached_sequences()
        {
            bail!(
                "queued request can never be admitted: it needs more KV blocks \
                 than the whole pool holds ({} blocks of {})",
                self.kv.total_blocks(),
                self.kv.block_size()
            );
        }
        // 2. decode step for the live batch
        let outcome = self.engine.step(&mut self.kv)?;
        // 3. requeue preempted requests at the head (their logical and
        //    physical blocks were released inside the step)
        for req in outcome.preempted {
            self.report.preemptions += 1;
            self.batcher.push_front(req);
        }
        // 4. release finished sequences' logical KV blocks (backends
        //    reclaim the physical side themselves)
        let done = outcome.finished;
        for resp in &done {
            let _ = self.kv.release(resp.id);
            self.report.ttft.record(std::time::Duration::from_micros(
                (resp.ttft_ms * 1000.0) as u64,
            ));
            match resp.tpot_ms {
                Some(tpot) => self.report.tpot.record(std::time::Duration::from_micros(
                    (tpot.max(0.0) * 1000.0) as u64,
                )),
                None => self.report.tpot_undefined += 1,
            }
            self.report.e2e.record(std::time::Duration::from_micros(
                (resp.e2e_ms * 1000.0) as u64,
            ));
            self.report.tokens_out += resp.tokens.len() as u64;
        }
        self.report.responses.extend(done.iter().cloned());
        Ok(done)
    }

    /// Copy the engine's cumulative prefix-cache / CoW counters into the
    /// report (they live engine-side because the hits happen inside
    /// `add_request` / `step`).
    fn absorb_engine_stats(&mut self) {
        let s = self.engine.stats();
        self.report.prefix_lookups = s.prefix_lookups;
        self.report.prefix_hits = s.prefix_hits;
        self.report.prefill_tokens_saved = s.prefill_tokens_saved;
        self.report.cache_evictions = s.cache_evictions;
        self.report.cow_copies = s.cow_copies;
    }

    /// Drive to completion and return the report.
    pub fn run_to_completion(mut self) -> Result<SchedulerReport> {
        let t0 = std::time::Instant::now();
        while self.has_work() {
            self.tick()?;
        }
        self.report.wall_s = t0.elapsed().as_secs_f64();
        self.absorb_engine_stats();
        Ok(self.report)
    }

    pub fn into_report(mut self, wall_s: f64) -> SchedulerReport {
        self.report.wall_s = wall_s;
        self.absorb_engine_stats();
        std::mem::take(&mut self.report)
    }
}
