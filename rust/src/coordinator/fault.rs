//! Deterministic fault-injection plane (ISSUE 7 tentpole §1).
//!
//! [`FaultingBackend`] wraps any [`EngineBackend`] and injects the fault
//! mix described by a [`FaultSpec`] — step errors, latency spikes,
//! spurious admission bounces (the `OutOfBlocks` shape), whole-replica
//! crashes, and NaN-poisoned logits — all drawn from one `Pcg32` stream
//! seeded from `seed ^ replica`, so a given `--seed` replays the
//! identical fault schedule. Crashes are schedule-based
//! (`crash:rN@tM`), not probabilistic: failover tests need to know
//! exactly when a replica dies.
//!
//! Injected failures are distinguishable from organic ones by message
//! markers ([`STEP_MARKER`], [`CRASH_MARKER`]); the fleet supervisor
//! keys its recovery policy off [`is_crash`], never off string matching
//! against organic error text.

use crate::synth::FaultSpec;
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg32;

use super::backend::{EngineBackend, EngineStats, ReserveMode, StepOutcome};
use super::kv_cache::KvCacheManager;
use super::request::{Request, RequestId};

/// Marker carried by injected transient step errors.
pub const STEP_MARKER: &str = "[injected:step]";
/// Marker carried by injected whole-replica crashes (permanent).
pub const CRASH_MARKER: &str = "[injected:crash]";

/// Was this error injected by the fault plane (either kind)?
pub fn is_injected(msg: &str) -> bool {
    msg.contains(STEP_MARKER) || msg.contains(CRASH_MARKER)
}

/// Is this error a whole-replica crash (permanent — the supervisor must
/// fail over, not retry)?
pub fn is_crash(msg: &str) -> bool {
    msg.contains(CRASH_MARKER)
}

/// Injected-fault counters (per wrapped replica).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub step_errs: u64,
    pub crashes: u64,
    pub slow: u64,
    pub oom: u64,
    pub poison: u64,
}

impl FaultStats {
    pub fn total(&self) -> u64 {
        self.step_errs + self.crashes + self.slow + self.oom + self.poison
    }
}

/// [`EngineBackend`] decorator injecting the [`FaultSpec`] fault mix.
pub struct FaultingBackend {
    inner: Box<dyn EngineBackend>,
    spec: FaultSpec,
    rng: Pcg32,
    replica: usize,
    /// Steps attempted so far (the crash schedule's clock).
    steps: u64,
    crashed: bool,
    stats: FaultStats,
}

impl FaultingBackend {
    pub fn new(
        inner: Box<dyn EngineBackend>,
        spec: FaultSpec,
        seed: u64,
        replica: usize,
    ) -> FaultingBackend {
        FaultingBackend {
            inner,
            spec,
            rng: Pcg32::seeded(seed ^ (replica as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            replica,
            steps: 0,
            crashed: false,
            stats: FaultStats::default(),
        }
    }

    pub fn injected(&self) -> &FaultStats {
        &self.stats
    }
}

impl EngineBackend for FaultingBackend {
    fn backend_name(&self) -> &'static str {
        self.inner.backend_name()
    }

    fn plan(&self) -> &str {
        self.inner.plan()
    }

    fn kernel(&self) -> &'static crate::attn::registry::KernelEntry {
        self.inner.kernel()
    }

    fn batch_slots(&self) -> usize {
        self.inner.batch_slots()
    }

    fn free_slots(&self) -> usize {
        self.inner.free_slots()
    }

    fn outstanding_tokens(&self) -> usize {
        self.inner.outstanding_tokens()
    }

    fn prefill_sizes(&self) -> Vec<usize> {
        self.inner.prefill_sizes()
    }

    fn reserve_mode(&self) -> ReserveMode {
        self.inner.reserve_mode()
    }

    fn set_params(&mut self, params: Vec<crate::runtime::Value>) -> Result<()> {
        self.inner.set_params(params)
    }

    fn add_request(&mut self, req: &Request, kv: &mut KvCacheManager) -> Result<bool> {
        if self.crashed {
            // a dead replica refuses politely — the contract's Ok(false)
            // keeps reservation ownership with the caller, and the next
            // step()'s crash error triggers the supervisor's failover
            return Ok(false);
        }
        if self.spec.oom > 0.0 && self.rng.bernoulli(self.spec.oom) {
            // spurious OutOfBlocks shape: admission bounces, caller
            // requeues (exactly what a genuinely full pool produces)
            self.stats.oom += 1;
            return Ok(false);
        }
        self.inner.add_request(req, kv)
    }

    fn step(&mut self, kv: &mut KvCacheManager) -> Result<StepOutcome> {
        if self.crashed {
            return Err(Error::msg(format!(
                "{CRASH_MARKER} replica {} is down",
                self.replica
            )));
        }
        let t = self.steps;
        self.steps += 1;
        if self.spec.crashes.iter().any(|c| c.replica == self.replica && c.step == t) {
            self.crashed = true;
            self.stats.crashes += 1;
            return Err(Error::msg(format!(
                "{CRASH_MARKER} replica {} died at step {t}",
                self.replica
            )));
        }
        // fixed draw order, every draw taken unconditionally: one fault
        // firing must not shift the schedule of later decisions
        let fire_slow = self.rng.bernoulli(self.spec.slow_p);
        let fire_poison = self.rng.bernoulli(self.spec.poison);
        let fire_step = self.rng.bernoulli(self.spec.step_err);
        if fire_slow && self.spec.slow_ms > 0.0 {
            self.stats.slow += 1;
            std::thread::sleep(std::time::Duration::from_micros(
                (self.spec.slow_ms * 1000.0) as u64,
            ));
        }
        if fire_poison && self.inner.inject_poison() {
            self.stats.poison += 1;
        }
        if fire_step {
            self.stats.step_errs += 1;
            return Err(Error::msg(format!(
                "{STEP_MARKER} replica {} transient step failure at step {t}",
                self.replica
            )));
        }
        self.inner.step(kv)
    }

    fn stats(&self) -> &EngineStats {
        self.inner.stats()
    }

    fn prefix_credit(&self, req: &Request) -> usize {
        self.inner.prefix_credit(req)
    }

    fn reclaim_blocks(&mut self, kv: &mut KvCacheManager, need: usize) -> Result<bool> {
        self.inner.reclaim_blocks(kv, need)
    }

    fn cached_sequences(&self) -> usize {
        self.inner.cached_sequences()
    }

    fn drain(&mut self, kv: &mut KvCacheManager) -> Result<Vec<Request>> {
        // recovery paths bypass injection: a fleet must always be able
        // to pull in-flight work off a (crashed) replica cleanly
        self.inner.drain(kv)
    }

    fn cancel(&mut self, id: RequestId, kv: &mut KvCacheManager) -> Result<bool> {
        self.inner.cancel(id, kv)
    }

    fn live_ids(&self) -> Vec<RequestId> {
        self.inner.live_ids()
    }

    fn inject_poison(&mut self) -> bool {
        self.inner.inject_poison()
    }

    fn fault_stats(&self) -> Option<&FaultStats> {
        Some(&self.stats)
    }

    fn set_chunked_prefill(&mut self, cfg: super::traffic::ChunkCfg) -> bool {
        self.inner.set_chunked_prefill(cfg)
    }

    fn pending_prefill_rows(&self) -> usize {
        self.inner.pending_prefill_rows()
    }

    fn set_obs(&mut self, obs: crate::obs::Obs, replica: u32) {
        self.inner.set_obs(obs, replica)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markers_classify() {
        assert!(is_injected(&format!("{STEP_MARKER} replica 0 ...")));
        assert!(is_injected(&format!("outer context: {CRASH_MARKER} replica 1 died")));
        assert!(is_crash(&format!("{CRASH_MARKER} x")));
        assert!(!is_crash(&format!("{STEP_MARKER} x")));
        assert!(!is_injected("CoW barrier failed"));
    }
}
