//! Adaptive quantization (paper §4.5): per-layer kernel selection.
//!
//! SageAttn-vB is ~4% faster than SageAttn-B but less accurate on some
//! layers. The paper calibrates with representative inputs, measures each
//! layer's cosine similarity under -vB, and selects -vB only where the
//! similarity clears 99.8% (the worst similarity -B exhibits); remaining
//! layers run -B. The resulting plan feeds back into `aot.py --plan-file`
//! to emit the `*_adaptive` artifacts.

use crate::attn::{registry, AttnImpl, AttnSpec};
use crate::metrics::cos_sim;
use crate::synth::Profile;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// The paper's selection threshold: -vB must beat the worst cosine
/// similarity observed from -B (0.998).
pub const COS_THRESHOLD: f32 = 0.998;

/// One layer's calibration measurement.
#[derive(Clone, Debug)]
pub struct LayerCalibration {
    pub layer: usize,
    pub cos_vb: f32,
    pub cos_b: f32,
    pub choice: &'static str,
}

/// A per-layer attention plan (artifact plan strings).
#[derive(Clone, Debug, PartialEq)]
pub struct Plan(pub Vec<String>);

impl Plan {
    /// Serialize as the JSON string array `aot.py --plan-file` consumes.
    pub fn to_json(&self) -> String {
        Json::Arr(self.0.iter().map(|s| Json::Str(s.clone())).collect()).to_string()
    }

    /// Parse a plan back from its JSON form (`aot.py --plan-file` input).
    pub fn from_json(text: &str) -> crate::util::error::Result<Plan> {
        let v = Json::parse(text)?;
        Ok(Plan(v
            .as_str_vec()
            .ok_or_else(|| crate::format_err!("plan must be a string array"))?))
    }

    pub fn speedup_estimate(&self) -> f64 {
        // §4.5: each -vB layer contributes ~4% attention speedup over -B
        let n = self.0.len() as f64;
        let vb = self.0.iter().filter(|s| s.as_str() == "SageAttn-vB").count() as f64;
        1.0 + 0.04 * vb / n.max(1.0)
    }

    /// Resolve every layer's kernel through the attention registry —
    /// consumers run plan entries via [`crate::attn::AttnSpec`] instead
    /// of re-matching the strings by hand.
    pub fn kernels(&self) -> crate::util::error::Result<Vec<AttnImpl>> {
        self.0
            .iter()
            .map(|name| {
                registry::resolve(name).ok_or_else(|| {
                    crate::format_err!(
                        "plan entry '{name}' is not a registered kernel (registered: {})",
                        registry::known_names()
                    )
                })
            })
            .collect()
    }
}

/// Calibration input supplier: per-layer QKV tensors. Real deployments
/// capture activations; here layers are synthesized with layer-dependent
/// outlier severity (DESIGN.md §3).
pub fn synth_layer_inputs(
    n_layers: usize,
    shape: [usize; 4],
    profile: Profile,
    seed: u64,
) -> Vec<(Tensor, Tensor, Tensor)> {
    (0..n_layers)
        .map(|l| {
            let sev = 0.25 + 1.5 * l as f32 / (n_layers.max(2) - 1) as f32;
            let mut p = profile.with_severity(sev);
            // heavy-tailed (diffusion-like) models develop attention-sink
            // layers at depth — exactly the layers where -vB fails the
            // 99.8% bar and the calibrator must fall back to -B
            if profile.heavy_tail > 0.2 && l >= 3 * n_layers / 4 {
                p = p.with_sink(1.0, 5.0 + 2.0 * (l as f32 / n_layers as f32));
            }
            crate::synth::make_qkv(seed + l as u64, shape, p)
        })
        .collect()
}

/// Run the §4.5 calibration over per-layer inputs: measure -vB and -B
/// against full precision, choose per layer.
///
/// ```
/// use sageattention::adaptive::{calibrate, synth_layer_inputs, COS_THRESHOLD};
/// use sageattention::attn::AttnSpec;
/// use sageattention::synth::Profile;
///
/// // two synthetic "layers" of captured activations (B, H, N, d)
/// let layers = synth_layer_inputs(2, [1, 1, 64, 32], Profile::llama_like(), 1);
/// let (plan, detail) = calibrate(&layers, false);
/// assert_eq!(plan.0.len(), 2);
/// for d in &detail {
///     // every layer picked -vB only if it cleared the 99.8% bar (§4.5)
///     if d.choice == "SageAttn-vB" {
///         assert!(d.cos_vb >= COS_THRESHOLD);
///     }
///     assert!(d.cos_b > 0.9, "the -B fallback must stay accurate");
/// }
/// // the plan serializes to the JSON that `aot.py --plan-file` consumes
/// let json = plan.to_json();
/// assert!(json.starts_with('['));
///
/// // plan entries resolve through the kernel registry, ready to run:
/// let (q, k, v) = &layers[0];
/// for imp in plan.kernels().unwrap() {
///     let out = AttnSpec::new(imp).run(q, k, v).unwrap();
///     assert_eq!(out.shape, q.shape);
/// }
/// ```
pub fn calibrate(
    layers: &[(Tensor, Tensor, Tensor)],
    causal: bool,
) -> (Plan, Vec<LayerCalibration>) {
    let exact = AttnSpec::exact().causal(causal);
    let vb = AttnSpec::sage_vb().causal(causal);
    let b = AttnSpec::sage_b().causal(causal);
    let mut plan = Vec::new();
    let mut detail = Vec::new();
    for (i, (q, k, v)) in layers.iter().enumerate() {
        let gold = exact.run(q, k, v).expect("calibration layer shapes are valid");
        let o_vb = vb.run(q, k, v).expect("calibration layer shapes are valid");
        let o_b = b.run(q, k, v).expect("calibration layer shapes are valid");
        let cos_vb = cos_sim(&gold.data, &o_vb.data);
        let cos_b = cos_sim(&gold.data, &o_b.data);
        let choice = if cos_vb >= COS_THRESHOLD { "SageAttn-vB" } else { "SageAttn-B" };
        plan.push(choice.to_owned());
        detail.push(LayerCalibration { layer: i, cos_vb, cos_b, choice });
    }
    (Plan(plan), detail)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_json_roundtrip() {
        let p = Plan(vec!["SageAttn-B".into(), "SageAttn-vB".into()]);
        let p2 = Plan::from_json(&p.to_json()).unwrap();
        assert_eq!(p, p2);
    }

    #[test]
    fn plan_kernels_resolve_through_registry() {
        let p = Plan(vec!["SageAttn-B".into(), "SageAttn-vB".into()]);
        assert_eq!(p.kernels().unwrap(), vec![crate::attn::SAGE_B, crate::attn::SAGE_VB]);
        let err = Plan(vec!["bogus".into()]).kernels().unwrap_err().to_string();
        assert!(err.contains("registered"), "{err}");
    }

    #[test]
    fn calibrate_picks_vb_on_benign_layers() {
        // benign (llama-like) layers: vB should qualify nearly everywhere
        let layers = synth_layer_inputs(4, [1, 2, 128, 64], Profile::llama_like(), 11);
        let (plan, detail) = calibrate(&layers, false);
        let n_vb = plan.0.iter().filter(|s| s.as_str() == "SageAttn-vB").count();
        assert!(n_vb >= 2, "expected mostly vB on benign layers, plan {plan:?} {detail:?}");
        for d in &detail {
            assert!(d.cos_b >= 0.99, "B baseline degraded: {d:?}");
        }
    }

    #[test]
    fn calibrate_falls_back_on_hostile_layers() {
        // crank severity: deepest layers should fail the threshold
        let profile = Profile::diffusion_like().with_severity(4.0);
        let layers = synth_layer_inputs(4, [1, 2, 128, 64], profile, 13);
        let (plan, detail) = calibrate(&layers, false);
        // the plan must be valid regardless of mix
        assert_eq!(plan.0.len(), 4);
        for (c, d) in plan.0.iter().zip(&detail) {
            if d.cos_vb >= COS_THRESHOLD {
                assert_eq!(c, "SageAttn-vB");
            } else {
                assert_eq!(c, "SageAttn-B");
            }
        }
    }

    #[test]
    fn speedup_estimate_bounds() {
        assert_eq!(Plan(vec!["SageAttn-B".into()]).speedup_estimate(), 1.0);
        let all_vb = Plan(vec!["SageAttn-vB".into(); 10]);
        assert!((all_vb.speedup_estimate() - 1.04).abs() < 1e-9);
    }
}
