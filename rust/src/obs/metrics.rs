//! `obs::metrics` — counters, gauges and log-bucketed histograms.
//!
//! The registry absorbs the numbers that used to live only as hand-
//! rolled fields on `SchedulerReport` / `FleetReport` / `FaultStats`:
//! every report counter is mirrored here at report time, so one
//! queryable, exportable surface (Prometheus text, trace `otherData`)
//! carries everything the human tables print. Latency distributions are
//! first-class: [`Histo`] is a log-linear bucketed histogram (8
//! sub-buckets per power-of-two octave, fixed 496-slot array) with
//! interpolated p50/p95/p99 and exact count/sum/max — recording is a
//! shift, a mask and an array increment, never an allocation.

use std::collections::BTreeMap;

const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS; // 8 sub-buckets per octave
const N_BUCKETS: usize = ((64 - SUB_BITS as u64) + 1) as usize * SUB as usize;

/// Log-linear bucketed histogram over `u64` values (microseconds by
/// convention for latency series). Relative bucket error ≤ 1/8.
#[derive(Clone, Debug)]
pub struct Histo {
    buckets: Vec<u64>, // N_BUCKETS slots, allocated once at creation
    count: u64,
    sum: u64,
    max: u64,
    min: u64,
}

fn bucket_of(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let o = 63 - v.leading_zeros() as u64; // floor log2, >= SUB_BITS
    let sub = (v >> (o - SUB_BITS as u64)) & (SUB - 1);
    ((o - SUB_BITS as u64 + 1) * SUB + sub) as usize
}

fn bucket_low(i: usize) -> u64 {
    let (g, sub) = (i as u64 / SUB, i as u64 % SUB);
    if g == 0 {
        sub
    } else {
        (SUB + sub) << (g - 1)
    }
}

fn bucket_width(i: usize) -> u64 {
    let g = i as u64 / SUB;
    if g == 0 {
        1
    } else {
        1 << (g - 1)
    }
}

impl Default for Histo {
    fn default() -> Self {
        Histo::new()
    }
}

impl Histo {
    pub fn new() -> Histo {
        Histo { buckets: vec![0; N_BUCKETS], count: 0, sum: 0, max: 0, min: u64::MAX }
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
        self.min = self.min.min(v);
    }

    pub fn merge(&mut self, other: &Histo) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn max(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Interpolated quantile (`q` in [0,1]): walk buckets to the one
    /// holding the q-th sample, interpolate linearly inside it, clamp to
    /// the exact observed max. Empty histogram → 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                let frac = (rank - seen) as f64 / c as f64;
                let est = bucket_low(i) as f64 + bucket_width(i) as f64 * frac;
                return (est as u64).min(self.max).max(self.min);
            }
            seen += c;
        }
        self.max
    }
}

/// Named metrics: monotone counters, last-write gauges, histograms.
/// `BTreeMap` keys give deterministic export order.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histos: BTreeMap<String, Histo>,
}

impl Registry {
    pub fn counter_add(&mut self, name: &str, n: u64) {
        match self.counters.get_mut(name) {
            Some(c) => *c += n,
            None => {
                self.counters.insert(name.to_string(), n);
            }
        }
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        match self.gauges.get_mut(name) {
            Some(g) => *g = v,
            None => {
                self.gauges.insert(name.to_string(), v);
            }
        }
    }

    /// Record one value (µs by convention) into the named histogram.
    pub fn record(&mut self, name: &str, v: u64) {
        match self.histos.get_mut(name) {
            Some(h) => h.record(v),
            None => {
                let mut h = Histo::new();
                h.record(v);
                self.histos.insert(name.to_string(), h);
            }
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histo(&self, name: &str) -> Option<&Histo> {
        self.histos.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn histos(&self) -> impl Iterator<Item = (&str, &Histo)> {
        self.histos.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histos.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_contiguous_and_monotone() {
        let mut prev = 0usize;
        for v in 0u64..4096 {
            let b = bucket_of(v);
            assert!(b == prev || b == prev + 1, "gap at v={v}: {prev} -> {b}");
            assert!(bucket_low(b) <= v, "low({b}) > {v}");
            assert!(v < bucket_low(b) + bucket_width(b), "v={v} past bucket {b}");
            prev = b;
        }
        assert!(bucket_of(u64::MAX) < N_BUCKETS);
    }

    #[test]
    fn small_values_are_exact() {
        for v in 0u64..16 {
            assert_eq!(bucket_low(bucket_of(v)), v);
        }
    }

    #[test]
    fn quantiles_bracket_uniform_data() {
        let mut h = Histo::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((400..=625).contains(&p50), "p50 {p50}");
        assert!((900..=1000).contains(&p99), "p99 {p99}");
        assert_eq!(h.max(), 1000);
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn merge_matches_combined_recording() {
        let mut a = Histo::new();
        let mut b = Histo::new();
        let mut both = Histo::new();
        for v in 0..100u64 {
            a.record(v * 3);
            both.record(v * 3);
            b.record(v * 7 + 1);
            both.record(v * 7 + 1);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.sum(), both.sum());
        assert_eq!(a.max(), both.max());
        assert_eq!(a.quantile(0.5), both.quantile(0.5));
    }

    #[test]
    fn registry_round_trip() {
        let mut r = Registry::default();
        r.counter_add("served", 3);
        r.counter_add("served", 2);
        r.gauge_set("occupancy", 0.5);
        r.record("ttft_us", 1200);
        assert_eq!(r.counter("served"), 5);
        assert_eq!(r.gauge("occupancy"), Some(0.5));
        assert_eq!(r.histo("ttft_us").unwrap().count(), 1);
    }
}
