//! `obs::phase` — sampled per-phase timing of the attention hot path.
//!
//! The paper's opening claim (PAPER.md §1, Figure 2) is a latency-share
//! argument: attention dominates end-to-end time at long sequence, so an
//! 8-bit attention kernel moves the whole pipeline. Crediting that claim
//! — and the per-kernel wins stacked on top of it — needs the same
//! breakdown *inside* the kernel: how much of a plane call goes to
//!
//! * [`Phase::Quant`] — smoothing K and per-block INT8 quantization of
//!   Q/K (paper §3.2, the ΔS=7° trick that keeps 8-bit QK accurate),
//! * [`Phase::QkTile`] — the `mma(s8.s8.s32)` score tiles (§4.2–4.3),
//! * [`Phase::Softmax`] — the per-row online-softmax rescale,
//! * [`Phase::Pv`] — the P̃·V accumulation in the selected
//!   [`PvMode`](crate::attn::PvMode) numerics (INT8 §4.3, fused
//!   FP16-accumulator §4.4),
//! * [`Phase::F16Round`] — the explicit fp16 round-trip of V at plane
//!   entry (the mma(f16.f16.f32) operand precision of §4.4; the rounds
//!   folded *inside* the fused P·V lanes bill to [`Phase::Pv`]).
//!
//! The timer is **sampled, not per-element**: it rides in
//! [`Scratch`](crate::attn::Scratch) and times every `every`-th plane
//! call end to end, so the zero-allocation and bit-identity guarantees
//! of the kernels are untouched (timing reads a clock; it never changes
//! what the kernel computes) and the disabled path is a single branch
//! per plane call. Accumulated nanoseconds are flushed into
//! [`Obs`](super::Obs) by whoever owns the scratch (the native engine
//! after each step, the bench lanes at the end of a run).

use std::time::Instant;

/// Number of instrumented kernel phases (fixed-slot accumulators — no
/// lookup, no allocation).
pub const PHASE_COUNT: usize = 5;

/// One instrumented phase of a blocked attention plane call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Smooth-K + per-block INT8 quantization of Q/K (and V on the
    /// int8-PV path). Paper §3.2 / §4.2.
    Quant = 0,
    /// One BLOCK_Q×BLOCK_KV `mma(s8.s8.s32)` score tile (§4.2–4.3).
    QkTile = 1,
    /// Per-row online-softmax max/exp/rescale bookkeeping.
    Softmax = 2,
    /// P̃·V accumulation (INT8 §4.3 / fused FP16-accumulator §4.4).
    Pv = 3,
    /// fp16 round-trip of V at plane entry (§4.4 operand precision).
    F16Round = 4,
}

impl Phase {
    /// All phases, in slot order.
    pub const ALL: [Phase; PHASE_COUNT] =
        [Phase::Quant, Phase::QkTile, Phase::Softmax, Phase::Pv, Phase::F16Round];

    /// Stable export name (trace JSON / Prometheus label).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Quant => "quant",
            Phase::QkTile => "qk_tile",
            Phase::Softmax => "softmax",
            Phase::Pv => "pv",
            Phase::F16Round => "f16_round",
        }
    }
}

/// Sampled phase timer owned by a kernel [`Scratch`](crate::attn::Scratch).
///
/// `every == 0` means disabled: [`begin_plane`](PhaseTimer::begin_plane)
/// is one branch, [`section`](PhaseTimer::section) returns `None`, and
/// no clock is ever read. When enabled, every `every`-th plane call is
/// *active*: its sections read `Instant::now()` around each phase and
/// accumulate nanoseconds into fixed slots. Sampling is at plane-call
/// granularity so an active plane is timed coherently (all its phases
/// from the same call) and inactive planes pay only the `active` check.
#[derive(Clone, Debug)]
pub struct PhaseTimer {
    every: u32,
    calls: u32,
    active: bool,
    ns: [u64; PHASE_COUNT],
    samples: u64,
}

impl Default for PhaseTimer {
    fn default() -> Self {
        PhaseTimer::disabled()
    }
}

impl PhaseTimer {
    /// A timer that never samples (the default in every `Scratch`).
    pub const fn disabled() -> PhaseTimer {
        PhaseTimer { every: 0, calls: 0, active: false, ns: [0; PHASE_COUNT], samples: 0 }
    }

    /// Time every `every`-th plane call (`every = 1` times all of them).
    pub fn sampled(every: u32) -> PhaseTimer {
        PhaseTimer { every: every.max(1), ..PhaseTimer::disabled() }
    }

    pub fn is_enabled(&self) -> bool {
        self.every > 0
    }

    /// Mark the start of one plane call and decide whether it is
    /// sampled. Disabled timers take the early return.
    #[inline]
    pub fn begin_plane(&mut self) {
        if self.every == 0 {
            return;
        }
        self.calls += 1;
        if self.calls >= self.every {
            self.calls = 0;
            self.active = true;
            self.samples += 1;
        } else {
            self.active = false;
        }
    }

    /// Open a timed section: `Some(now)` on an active plane, `None`
    /// otherwise. Pair with [`commit`](PhaseTimer::commit).
    #[inline]
    pub fn section(&self) -> Option<Instant> {
        if self.active {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a section opened by [`section`](PhaseTimer::section),
    /// crediting the elapsed time to `phase`. `None` (inactive plane)
    /// is a no-op.
    #[inline]
    pub fn commit(&mut self, phase: Phase, t0: Option<Instant>) {
        if let Some(t0) = t0 {
            self.ns[phase as usize] += t0.elapsed().as_nanos() as u64;
        }
    }

    /// Drain accumulated (per-phase nanoseconds, sampled plane calls),
    /// resetting both. Sampling cadence is preserved.
    pub fn take(&mut self) -> ([u64; PHASE_COUNT], u64) {
        let out = (self.ns, self.samples);
        self.ns = [0; PHASE_COUNT];
        self.samples = 0;
        out
    }

    /// Accumulated totals without draining.
    pub fn totals(&self) -> (&[u64; PHASE_COUNT], u64) {
        (&self.ns, self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_never_samples() {
        let mut t = PhaseTimer::disabled();
        for _ in 0..100 {
            t.begin_plane();
            assert!(t.section().is_none());
        }
        assert_eq!(t.take(), ([0; PHASE_COUNT], 0));
    }

    #[test]
    fn sampling_cadence() {
        let mut t = PhaseTimer::sampled(4);
        let mut active = 0;
        for _ in 0..16 {
            t.begin_plane();
            if t.section().is_some() {
                active += 1;
            }
        }
        assert_eq!(active, 4);
        let (_, samples) = t.take();
        assert_eq!(samples, 4);
    }

    #[test]
    fn commit_accumulates_into_slot() {
        let mut t = PhaseTimer::sampled(1);
        t.begin_plane();
        let s = t.section();
        assert!(s.is_some());
        t.commit(Phase::QkTile, s);
        let (ns, samples) = t.take();
        assert_eq!(samples, 1);
        assert!(ns[Phase::QkTile as usize] > 0);
        assert_eq!(ns[Phase::Quant as usize], 0);
    }
}
