//! `obs::trace` — typed lifecycle events in a preallocated ring.
//!
//! Every layer of the serving stack emits the same small, `Copy`
//! [`Event`] record: request lifecycle transitions from the fleet and
//! scheduler (submit → dispatch/shed → admit → prefill chunk N → first
//! token → preempt/degrade/failover → finish), and per-step engine spans
//! (decode step, prefill chunk) from the native backend. Events carry a
//! monotone sequence number, the virtual tick of the fleet driver, and
//! wall nanoseconds since the recorder was created — the pair the
//! Chrome-trace exporter needs to lay spans on a timeline and the
//! determinism tests need to replay (ticks and sequence are seeded-
//! deterministic under the virtual-time driver; nanos are masked).
//!
//! The ring is **preallocated**: recording an event never allocates.
//! When the ring is full, *new* events are dropped (and counted) rather
//! than overwriting old ones — dropping the oldest would silently
//! orphan `Submit` spans and make every later well-formedness check
//! lie. `sage trace --check` fails a trace with a nonzero drop count.

/// Sentinel for events not tied to a request (engine-level spans).
pub const NO_ID: u64 = u64::MAX;

/// Sentinel for events not tied to a replica.
pub const NO_REPLICA: u32 = u32::MAX;

/// Default ring capacity (events); ~48 B each.
pub const DEFAULT_EVENT_CAPACITY: usize = 1 << 16;

/// What happened. Payloads are small and `Copy` — everything else
/// (latency distributions, counters) belongs in [`super::metrics`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request entered the system (fleet arrival or direct submit).
    Submit { prompt_len: u32 },
    /// Fleet handed the request to a replica's scheduler.
    Dispatch,
    /// Engine accepted the request into a decode slot (`resumed` when
    /// this is a re-admission after preemption/degrade/failover).
    Admit { resumed: bool },
    /// Admission bounced (no slot / no KV) and the request requeued.
    Requeue,
    /// One chunked-prefill chunk executed (`rows` prompt rows).
    PrefillChunk { rows: u32, dur_ns: u64 },
    /// One unchunked prefill executed (whole prompt in one call).
    Prefill { rows: u32, dur_ns: u64 },
    /// First output token of the request left the engine.
    FirstToken,
    /// One engine decode step over `live` slots emitting `tokens`.
    DecodeStep { live: u32, tokens: u32, dur_ns: u64 },
    /// Preempted for KV blocks; will requeue and resume.
    Preempt,
    /// Evicted by the numeric guard; retries on the fp path.
    Degrade,
    /// Fleet retried the request after a transient replica error.
    Retry { attempt: u32 },
    /// Fleet rerouted the request off a crashed replica.
    Failover { to: u32 },
    /// Replica crashed (terminal backend failure).
    Crash,
    /// Circuit breaker opened on a replica.
    BreakerOpen,
    /// Terminal: shed by SLO admission control.
    Shed,
    /// Terminal: cancelled by deadline sweep.
    DeadlineCancel,
    /// Terminal: failed (retry budget exhausted / rejected).
    Fail,
    /// Terminal: served to completion with `tokens` output tokens.
    Finish { tokens: u32 },
}

impl EventKind {
    /// Stable export name (trace JSON `args.kind`).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Submit { .. } => "submit",
            EventKind::Dispatch => "dispatch",
            EventKind::Admit { .. } => "admit",
            EventKind::Requeue => "requeue",
            EventKind::PrefillChunk { .. } => "prefill_chunk",
            EventKind::Prefill { .. } => "prefill",
            EventKind::FirstToken => "first_token",
            EventKind::DecodeStep { .. } => "decode_step",
            EventKind::Preempt => "preempt",
            EventKind::Degrade => "degrade",
            EventKind::Retry { .. } => "retry",
            EventKind::Failover { .. } => "failover",
            EventKind::Crash => "crash",
            EventKind::BreakerOpen => "breaker_open",
            EventKind::Shed => "shed",
            EventKind::DeadlineCancel => "deadline_cancel",
            EventKind::Fail => "fail",
            EventKind::Finish { .. } => "finish",
        }
    }

    /// Terminal lifecycle states — exactly one per request id in a
    /// well-formed trace.
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            EventKind::Shed | EventKind::DeadlineCancel | EventKind::Fail | EventKind::Finish { .. }
        )
    }
}

/// One recorded event. `seq` is a global monotone counter (drain order
/// == emission order under the single-threaded virtual-time driver);
/// `tick` is the fleet's virtual clock (0 outside fleet runs); `nanos`
/// is wall time since the recorder was created.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub seq: u64,
    pub tick: u64,
    pub nanos: u64,
    pub replica: u32,
    pub id: u64,
    pub kind: EventKind,
}

/// Preallocated event buffer: push never allocates, overflow drops the
/// *newest* event and counts it.
#[derive(Debug)]
pub(crate) struct Ring {
    buf: Vec<Event>,
    dropped: u64,
    seq: u64,
}

impl Ring {
    pub(crate) fn with_capacity(cap: usize) -> Ring {
        Ring { buf: Vec::with_capacity(cap.max(1)), dropped: 0, seq: 0 }
    }

    pub(crate) fn push(&mut self, mut ev: Event) {
        ev.seq = self.seq;
        self.seq += 1;
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    pub(crate) fn events(&self) -> &[Event] {
        &self.buf
    }

    pub(crate) fn recorded(&self) -> u64 {
        self.buf.len() as u64
    }

    pub(crate) fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev() -> Event {
        Event { seq: 0, tick: 0, nanos: 0, replica: NO_REPLICA, id: NO_ID, kind: EventKind::Shed }
    }

    #[test]
    fn ring_assigns_monotone_seq() {
        let mut r = Ring::with_capacity(8);
        for _ in 0..3 {
            r.push(ev());
        }
        let seqs: Vec<u64> = r.events().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn ring_drops_newest_on_overflow() {
        let mut r = Ring::with_capacity(2);
        for _ in 0..5 {
            r.push(ev());
        }
        assert_eq!(r.recorded(), 2);
        assert_eq!(r.dropped(), 3);
        // the survivors are the oldest two
        assert_eq!(r.events()[0].seq, 0);
        assert_eq!(r.events()[1].seq, 1);
    }
}
