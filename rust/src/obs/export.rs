//! `obs::export` — Chrome-trace JSON, Prometheus text, and the trace
//! checker/analyzer behind `sage trace`.
//!
//! The Chrome trace ("Trace Event Format", the object-with-`traceEvents`
//! flavor Perfetto and `chrome://tracing` both load) lays the run on two
//! kinds of rows: **pid 0** holds one thread per request id — a complete
//! `"X"` span from submit to its terminal event with every lifecycle
//! transition as an instant on the same row — and **pid 1+r** holds
//! replica `r`'s engine work (prefill chunks and decode steps as `"X"`
//! spans with real durations). `otherData` carries the accounting
//! totals, the sampled kernel-phase nanoseconds and a metrics snapshot,
//! so one file answers both "where did this request's latency go?" and
//! "which phase dominates a plane?" (the paper's Figure 2 question).
//!
//! Everything here round-trips through [`crate::util::json::Json`]:
//! [`analyze`] re-reads an emitted file and replays the same
//! well-formedness rules `sage trace --check` enforces — no second
//! schema to drift.

use std::collections::BTreeMap;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::trace::{Event, EventKind, NO_ID, NO_REPLICA};
use super::{Phase, Snapshot};

/// Quantiles every histogram exports, everywhere.
pub const QUANTILES: [(f64, &str); 3] = [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")];

fn kind_args(ev: &Event) -> Vec<(&'static str, Json)> {
    let mut args = vec![
        ("kind", Json::str(ev.kind.name())),
        ("seq", Json::num(ev.seq as f64)),
        ("tick", Json::num(ev.tick as f64)),
    ];
    if ev.id != NO_ID {
        args.push(("id", Json::num(ev.id as f64)));
    }
    match ev.kind {
        EventKind::Submit { prompt_len } => args.push(("prompt_len", Json::num(prompt_len as f64))),
        EventKind::PrefillChunk { rows, .. } | EventKind::Prefill { rows, .. } => {
            args.push(("rows", Json::num(rows as f64)))
        }
        EventKind::DecodeStep { live, tokens, .. } => {
            args.push(("live", Json::num(live as f64)));
            args.push(("tokens", Json::num(tokens as f64)));
        }
        EventKind::Admit { resumed } => args.push(("resumed", Json::Bool(resumed))),
        EventKind::Retry { attempt } => args.push(("attempt", Json::num(attempt as f64))),
        EventKind::Failover { to } => args.push(("to", Json::num(to as f64))),
        EventKind::Finish { tokens } => args.push(("tokens", Json::num(tokens as f64))),
        _ => {}
    }
    args
}

fn dur_ns_of(kind: EventKind) -> Option<u64> {
    match kind {
        EventKind::PrefillChunk { dur_ns, .. }
        | EventKind::Prefill { dur_ns, .. }
        | EventKind::DecodeStep { dur_ns, .. } => Some(dur_ns),
        _ => None,
    }
}

/// Build the Chrome-trace document from a drained event stream plus the
/// metrics/phase snapshot.
pub fn chrome_trace(events: &[Event], snap: &Snapshot) -> Json {
    let mut rows: Vec<Json> = Vec::new();
    let mut replicas: Vec<u32> = Vec::new();

    // pid 0 = one row per request: synthesized submit→terminal span
    rows.push(Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(0.0)),
        ("name", Json::str("process_name")),
        ("args", Json::obj(vec![("name", Json::str("requests"))])),
    ]));
    let mut by_id: BTreeMap<u64, Vec<&Event>> = BTreeMap::new();
    for ev in events {
        if ev.id != NO_ID {
            by_id.entry(ev.id).or_default().push(ev);
        }
        if ev.replica != NO_REPLICA && !replicas.contains(&ev.replica) {
            replicas.push(ev.replica);
        }
    }
    for (&id, evs) in &by_id {
        let submit = evs.iter().find(|e| matches!(e.kind, EventKind::Submit { .. }));
        let terminal = evs.iter().find(|e| e.kind.is_terminal());
        let first = submit.map_or(evs[0].nanos, |e| e.nanos);
        let last = terminal.map_or(evs[evs.len() - 1].nanos, |e| e.nanos);
        let mut args = vec![("terminal", Json::str(terminal.map_or("open", |e| e.kind.name())))];
        if let Some(e) = submit {
            if let EventKind::Submit { prompt_len } = e.kind {
                args.push(("prompt_len", Json::num(prompt_len as f64)));
            }
        }
        rows.push(Json::obj(vec![
            ("ph", Json::str("X")),
            ("name", Json::str(format!("req-{id}"))),
            ("cat", Json::str("request")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(id as f64)),
            ("ts", Json::num(first as f64 / 1e3)),
            ("dur", Json::num(last.saturating_sub(first) as f64 / 1e3)),
            ("args", Json::obj(args)),
        ]));
    }

    replicas.sort_unstable();
    for &r in &replicas {
        rows.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("pid", Json::num(r as f64 + 1.0)),
            ("name", Json::str("process_name")),
            ("args", Json::obj(vec![("name", Json::str(format!("replica-{r}")))])),
        ]));
    }

    for ev in events {
        let args = Json::obj(kind_args(ev));
        let row = match dur_ns_of(ev.kind) {
            // engine work: a real-duration span on the replica's row
            Some(dur_ns) => {
                let pid = if ev.replica == NO_REPLICA { 0.0 } else { ev.replica as f64 + 1.0 };
                Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("name", Json::str(ev.kind.name())),
                    ("cat", Json::str("engine")),
                    ("pid", Json::num(pid)),
                    ("tid", Json::num(if ev.id == NO_ID { 0.0 } else { ev.id as f64 })),
                    ("ts", Json::num(ev.nanos.saturating_sub(dur_ns) as f64 / 1e3)),
                    ("dur", Json::num(dur_ns as f64 / 1e3)),
                    ("args", args),
                ])
            }
            // lifecycle transition: an instant on the request's row (or
            // the replica's row for request-less fleet events)
            None => {
                let (pid, tid) = if ev.id == NO_ID {
                    (if ev.replica == NO_REPLICA { 0.0 } else { ev.replica as f64 + 1.0 }, 0.0)
                } else {
                    (0.0, ev.id as f64)
                };
                Json::obj(vec![
                    ("ph", Json::str("i")),
                    ("name", Json::str(ev.kind.name())),
                    ("cat", Json::str("lifecycle")),
                    ("s", Json::str("t")),
                    ("pid", Json::num(pid)),
                    ("tid", Json::num(tid)),
                    ("ts", Json::num(ev.nanos as f64 / 1e3)),
                    ("args", args),
                ])
            }
        };
        rows.push(row);
    }

    let acct = accounting(events);
    Json::obj(vec![
        ("traceEvents", Json::Arr(rows)),
        (
            "otherData",
            Json::obj(vec![
                ("schema", Json::num(1.0)),
                ("accounting", acct),
                (
                    "events",
                    Json::obj(vec![
                        ("recorded", Json::num(snap.events_recorded as f64)),
                        ("dropped", Json::num(snap.events_dropped as f64)),
                    ]),
                ),
                ("phases", phases_json(snap)),
                ("metrics", metrics_json(snap)),
            ]),
        ),
    ])
}

fn accounting(events: &[Event]) -> Json {
    let (mut submitted, mut finished, mut shed, mut failed, mut cancelled) = (0u64, 0, 0, 0, 0);
    for ev in events {
        match ev.kind {
            EventKind::Submit { .. } => submitted += 1,
            EventKind::Finish { .. } => finished += 1,
            EventKind::Shed => shed += 1,
            EventKind::Fail => failed += 1,
            EventKind::DeadlineCancel => cancelled += 1,
            _ => {}
        }
    }
    Json::obj(vec![
        ("submitted", Json::num(submitted as f64)),
        ("finished", Json::num(finished as f64)),
        ("shed", Json::num(shed as f64)),
        ("failed", Json::num(failed as f64)),
        ("cancelled", Json::num(cancelled as f64)),
    ])
}

fn phases_json(snap: &Snapshot) -> Json {
    let mut pairs: Vec<(&str, Json)> = Phase::ALL
        .iter()
        .map(|&p| (p.name(), Json::num(snap.phase_ns[p as usize] as f64)))
        .collect();
    pairs.push(("sampled_planes", Json::num(snap.phase_samples as f64)));
    Json::obj(pairs)
}

fn metrics_json(snap: &Snapshot) -> Json {
    let reg = &snap.registry;
    let counters = Json::obj(reg.counters().map(|(k, v)| (k, Json::num(v as f64))).collect());
    let gauges = Json::obj(reg.gauges().map(|(k, v)| (k, Json::num(v))).collect());
    let histos = Json::obj(
        reg.histos()
            .map(|(k, h)| {
                let mut fields = vec![
                    ("count", Json::num(h.count() as f64)),
                    ("sum", Json::num(h.sum() as f64)),
                    ("max", Json::num(h.max() as f64)),
                ];
                for &(q, label) in &QUANTILES {
                    fields.push((label, Json::num(h.quantile(q) as f64)));
                }
                (k, Json::obj(fields))
            })
            .collect(),
    );
    Json::obj(vec![("counters", counters), ("gauges", gauges), ("histograms", histos)])
}

/// Prometheus text exposition of a snapshot (counters, gauges,
/// histograms as summaries, kernel phases as a labeled counter family).
pub fn prometheus(snap: &Snapshot) -> String {
    fn sanitize(name: &str) -> String {
        name.chars().map(|c| if c.is_ascii_alphanumeric() || c == ':' { c } else { '_' }).collect()
    }
    let mut out = String::new();
    let reg = &snap.registry;
    for (name, v) in reg.counters() {
        let n = sanitize(name);
        out.push_str(&format!("# TYPE sage_{n} counter\nsage_{n} {v}\n"));
    }
    for (name, v) in reg.gauges() {
        let n = sanitize(name);
        out.push_str(&format!("# TYPE sage_{n} gauge\nsage_{n} {v}\n"));
    }
    for (name, h) in reg.histos() {
        let n = sanitize(name);
        out.push_str(&format!("# TYPE sage_{n} summary\n"));
        for &(q, label) in &QUANTILES {
            out.push_str(&format!("sage_{n}{{quantile=\"{label}\"}} {}\n", h.quantile(q)));
        }
        out.push_str(&format!("sage_{n}_sum {}\nsage_{n}_count {}\n", h.sum(), h.count()));
    }
    if snap.phase_samples > 0 {
        out.push_str("# TYPE sage_kernel_phase_ns counter\n");
        for &p in &Phase::ALL {
            out.push_str(&format!(
                "sage_kernel_phase_ns{{phase=\"{}\"}} {}\n",
                p.name(),
                snap.phase_ns[p as usize]
            ));
        }
        out.push_str("# TYPE sage_kernel_sampled_planes counter\n");
        out.push_str(&format!("sage_kernel_sampled_planes {}\n", snap.phase_samples));
    }
    out
}

/// Per-request critical path reconstructed from a trace file.
#[derive(Debug, Clone)]
pub struct ReqPath {
    pub id: u64,
    pub prompt_len: u64,
    pub submit_us: f64,
    pub admit_us: Option<f64>,
    pub first_token_us: Option<f64>,
    pub terminal_us: f64,
    pub terminal: String,
    pub chunks: u64,
    pub chunk_rows: u64,
    pub preempts: u64,
    pub retries: u64,
}

/// What [`analyze`] extracts from an emitted trace file.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub requests: Vec<ReqPath>,
    /// (phase name, sampled nanoseconds), kernel phases in slot order.
    pub phases: Vec<(String, u64)>,
    pub phase_samples: u64,
    pub submitted: u64,
    pub events_dropped: u64,
    /// Well-formedness violations; empty == the trace passes `--check`.
    pub problems: Vec<String>,
}

/// Parse + validate an emitted Chrome trace: every request id must open
/// with `submit` and close with exactly one terminal event, the
/// `otherData` accounting must equal what the events imply, and no
/// events may have been dropped. Structural schema violations are hard
/// errors; per-request violations are collected in
/// [`TraceReport::problems`] so `--check` can list all of them.
pub fn analyze(doc: &Json) -> Result<TraceReport> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("trace file has no traceEvents array")?;
    let other = doc.get("otherData").context("trace file has no otherData")?;

    struct Acc {
        prompt_len: u64,
        submit: Option<f64>,
        admit: Option<f64>,
        first_token: Option<f64>,
        terminals: Vec<(String, f64)>,
        last_us: f64,
        chunks: u64,
        chunk_rows: u64,
        preempts: u64,
        retries: u64,
    }
    let mut by_id: BTreeMap<u64, Acc> = BTreeMap::new();
    for row in events {
        let Some(args) = row.get("args") else { continue };
        let Some(kind) = args.get("kind").and_then(Json::as_str) else { continue };
        let Some(id) = args.get("id").and_then(Json::as_f64) else { continue };
        let ts = row.get("ts").and_then(Json::as_f64).context("event missing ts")?;
        let a = by_id.entry(id as u64).or_insert(Acc {
            prompt_len: 0,
            submit: None,
            admit: None,
            first_token: None,
            terminals: Vec::new(),
            last_us: ts,
            chunks: 0,
            chunk_rows: 0,
            preempts: 0,
            retries: 0,
        });
        a.last_us = a.last_us.max(ts);
        match kind {
            "submit" => {
                a.submit = Some(ts);
                a.prompt_len = args.get("prompt_len").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            }
            "admit" if a.admit.is_none() => a.admit = Some(ts),
            "first_token" if a.first_token.is_none() => a.first_token = Some(ts),
            "prefill_chunk" => {
                a.chunks += 1;
                a.chunk_rows += args.get("rows").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            }
            "preempt" => a.preempts += 1,
            "retry" => a.retries += 1,
            "finish" | "shed" | "fail" | "deadline_cancel" => {
                a.terminals.push((kind.to_string(), ts));
            }
            _ => {}
        }
    }

    let mut problems = Vec::new();
    let mut requests = Vec::new();
    let (mut n_finished, mut n_shed, mut n_failed, mut n_cancelled) = (0u64, 0u64, 0u64, 0u64);
    for (&id, a) in &by_id {
        let Some(submit_us) = a.submit else {
            problems.push(format!("orphan spans: request {id} has events but no submit"));
            continue;
        };
        match a.terminals.len() {
            0 => {
                problems.push(format!("unaccounted request: {id} submitted but never terminated"));
                continue;
            }
            1 => {}
            n => problems.push(format!("request {id} has {n} terminal events")),
        }
        let (terminal, terminal_us) = a.terminals[0].clone();
        if terminal_us < submit_us {
            problems.push(format!("request {id} terminates before it is submitted"));
        }
        match terminal.as_str() {
            "finish" => n_finished += 1,
            "shed" => n_shed += 1,
            "fail" => n_failed += 1,
            _ => n_cancelled += 1,
        }
        requests.push(ReqPath {
            id,
            prompt_len: a.prompt_len,
            submit_us,
            admit_us: a.admit,
            first_token_us: a.first_token,
            terminal_us,
            terminal,
            chunks: a.chunks,
            chunk_rows: a.chunk_rows,
            preempts: a.preempts,
            retries: a.retries,
        });
    }

    let acct = other.get("accounting").context("otherData missing accounting")?;
    let get = |k: &str| -> Result<u64> {
        let v = acct.get(k).and_then(Json::as_f64);
        Ok(v.with_context(|| format!("accounting missing {k}"))? as u64)
    };
    let submitted = get("submitted")?;
    for (key, computed) in [
        ("finished", n_finished),
        ("shed", n_shed),
        ("failed", n_failed),
        ("cancelled", n_cancelled),
    ] {
        let recorded = get(key)?;
        if recorded != computed {
            problems.push(format!("accounting.{key} = {recorded} but the events show {computed}"));
        }
    }
    if submitted != by_id.values().filter(|a| a.submit.is_some()).count() as u64 {
        problems.push(format!(
            "accounting.submitted = {submitted} but {} submit events present",
            by_id.values().filter(|a| a.submit.is_some()).count()
        ));
    }
    let terminal_total = n_finished + n_shed + n_failed + n_cancelled;
    if terminal_total != submitted {
        problems.push(format!(
            "unaccounted requests: {submitted} submitted, only {terminal_total} reached a terminal"
        ));
    }

    let events_dropped = other
        .path("events.dropped")
        .and_then(Json::as_f64)
        .context("otherData missing events.dropped")? as u64;
    if events_dropped > 0 {
        problems.push(format!("{events_dropped} events dropped (ring too small for this run)"));
    }

    let phases_obj = other.get("phases").context("otherData missing phases")?;
    let mut phases = Vec::new();
    for &p in &Phase::ALL {
        let ns = phases_obj.get(p.name()).and_then(Json::as_f64).unwrap_or(0.0) as u64;
        phases.push((p.name().to_string(), ns));
    }
    let phase_samples =
        phases_obj.get("sampled_planes").and_then(Json::as_f64).unwrap_or(0.0) as u64;

    Ok(TraceReport { requests, phases, phase_samples, submitted, events_dropped, problems })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Obs;

    fn seeded_obs() -> Obs {
        let obs = Obs::enabled();
        obs.emit(0, 1, EventKind::Submit { prompt_len: 8 });
        obs.emit(0, 1, EventKind::Admit { resumed: false });
        obs.emit(0, 1, EventKind::PrefillChunk { rows: 8, dur_ns: 1000 });
        obs.emit(0, 1, EventKind::FirstToken);
        obs.emit(0, NO_ID, EventKind::DecodeStep { live: 1, tokens: 1, dur_ns: 500 });
        obs.emit(0, 1, EventKind::Finish { tokens: 4 });
        obs.emit(0, 2, EventKind::Submit { prompt_len: 4 });
        obs.emit(0, 2, EventKind::Shed);
        obs
    }

    #[test]
    fn round_trip_is_well_formed() {
        let obs = seeded_obs();
        let doc = chrome_trace(&obs.events(), &obs.snapshot());
        let text = format!("{doc}");
        let parsed = Json::parse(&text).expect("emitted trace parses");
        let rep = analyze(&parsed).expect("schema-valid");
        assert!(rep.problems.is_empty(), "problems: {:?}", rep.problems);
        assert_eq!(rep.submitted, 2);
        assert_eq!(rep.requests.len(), 2);
        let r1 = rep.requests.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(r1.terminal, "finish");
        assert_eq!(r1.chunks, 1);
        assert_eq!(r1.chunk_rows, 8);
        assert!(r1.first_token_us.is_some());
    }

    #[test]
    fn missing_terminal_is_flagged() {
        let obs = Obs::enabled();
        obs.emit(0, 9, EventKind::Submit { prompt_len: 4 });
        let doc = chrome_trace(&obs.events(), &obs.snapshot());
        let rep = analyze(&doc).expect("structurally valid");
        assert!(rep.problems.iter().any(|p| p.contains("never terminated")));
    }

    #[test]
    fn orphan_span_is_flagged() {
        let obs = Obs::enabled();
        obs.emit(0, 5, EventKind::FirstToken);
        let doc = chrome_trace(&obs.events(), &obs.snapshot());
        let rep = analyze(&doc).expect("structurally valid");
        assert!(rep.problems.iter().any(|p| p.contains("orphan")));
    }

    #[test]
    fn prometheus_exposition_has_series() {
        let obs = seeded_obs();
        obs.counter_add("served", 1);
        obs.record_us("ttft_us", 1234);
        let text = prometheus(&obs.snapshot());
        assert!(text.contains("# TYPE sage_served counter"));
        assert!(text.contains("sage_ttft_us{quantile=\"0.5\"}"));
        assert!(text.contains("sage_ttft_us_count 1"));
    }
}
