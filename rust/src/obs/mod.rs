//! `obs` — runtime-gated tracing, metrics and kernel profiling.
//!
//! One cheap handle, [`Obs`], threads through every layer of the stack:
//! the fleet driver, the scheduler, the engine backends and (via the
//! [`PhaseTimer`] riding in each kernel `Scratch`) the attention hot
//! path. A **disabled** handle is `Option::None` — every emit site is a
//! single branch, no clock read, no lock, no allocation — so the
//! serving and kernel paths carry instrumentation at no measurable cost
//! (the `trace_overhead_frac` bench-hotpath lane gates this at ≥ 0.97).
//!
//! An **enabled** handle shares one preallocated event ring
//! ([`trace::Ring`]), one metrics [`Registry`], and fixed-slot atomic
//! phase accumulators behind an `Arc`. Recording an event takes an
//! uncontended mutex (the virtual-time fleet driver is single-threaded;
//! the thread-per-replica serve loop emits a handful of events per tick,
//! orders of magnitude below kernel work); kernel phase timing never
//! touches the ring — it accumulates in the thread-confined `Scratch`
//! timer and is flushed into the shared atomics once per engine step.
//!
//! Deliberately **not** a global: tests run concurrently in one process,
//! and a process-global recorder would cross-pollute their event
//! streams. Every run that wants observability builds its own handle
//! and passes clones down.

pub mod export;
pub mod metrics;
pub mod phase;
pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

pub use metrics::{Histo, Registry};
pub use phase::{Phase, PhaseTimer, PHASE_COUNT};
pub use trace::{Event, EventKind, DEFAULT_EVENT_CAPACITY, NO_ID, NO_REPLICA};

struct Inner {
    ring: trace::Ring,
    reg: Registry,
}

struct Shared {
    start: Instant,
    tick: AtomicU64,
    phase_ns: [AtomicU64; PHASE_COUNT],
    phase_samples: AtomicU64,
    inner: Mutex<Inner>,
}

/// Handle to one observability domain (or to nothing). Clone freely;
/// clones share the same ring/registry/accumulators.
#[derive(Clone, Default)]
pub struct Obs(Option<Arc<Shared>>);

/// Point-in-time copy of everything the registry and phase accumulators
/// hold — what the exporters consume.
#[derive(Clone)]
pub struct Snapshot {
    pub registry: Registry,
    pub phase_ns: [u64; PHASE_COUNT],
    pub phase_samples: u64,
    pub events_recorded: u64,
    pub events_dropped: u64,
}

impl Snapshot {
    /// Total sampled kernel nanoseconds across phases.
    pub fn phase_total_ns(&self) -> u64 {
        self.phase_ns.iter().sum()
    }
}

impl Obs {
    /// The no-op handle: every emit site reduces to one branch.
    pub fn disabled() -> Obs {
        Obs(None)
    }

    /// An enabled recorder with the default event capacity.
    pub fn enabled() -> Obs {
        Obs::with_capacity(DEFAULT_EVENT_CAPACITY)
    }

    /// An enabled recorder holding at most `cap` events.
    pub fn with_capacity(cap: usize) -> Obs {
        Obs(Some(Arc::new(Shared {
            start: Instant::now(),
            tick: AtomicU64::new(0),
            phase_ns: Default::default(),
            phase_samples: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                ring: trace::Ring::with_capacity(cap),
                reg: Registry::default(),
            }),
        })))
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    fn lock(sh: &Shared) -> MutexGuard<'_, Inner> {
        // a panicking holder must not silence every later export
        sh.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Advance the shared virtual clock stamped onto events (the fleet
    /// driver's tick; advisory outside virtual-time runs).
    #[inline]
    pub fn set_tick(&self, tick: u64) {
        if let Some(sh) = &self.0 {
            sh.tick.store(tick, Ordering::Relaxed);
        }
    }

    pub fn tick(&self) -> u64 {
        self.0.as_ref().map_or(0, |sh| sh.tick.load(Ordering::Relaxed))
    }

    /// Record one lifecycle event (no-op when disabled).
    #[inline]
    pub fn emit(&self, replica: u32, id: u64, kind: EventKind) {
        let Some(sh) = &self.0 else { return };
        let ev = Event {
            seq: 0, // assigned by the ring
            tick: sh.tick.load(Ordering::Relaxed),
            nanos: sh.start.elapsed().as_nanos() as u64,
            replica,
            id,
            kind,
        };
        Self::lock(sh).ring.push(ev);
    }

    #[inline]
    pub fn counter_add(&self, name: &str, n: u64) {
        if let Some(sh) = &self.0 {
            Self::lock(sh).reg.counter_add(name, n);
        }
    }

    #[inline]
    pub fn gauge_set(&self, name: &str, v: f64) {
        if let Some(sh) = &self.0 {
            Self::lock(sh).reg.gauge_set(name, v);
        }
    }

    /// Record `v` µs into the named histogram (no-op when disabled).
    #[inline]
    pub fn record_us(&self, name: &str, us: u64) {
        if let Some(sh) = &self.0 {
            Self::lock(sh).reg.record(name, us);
        }
    }

    #[inline]
    pub fn record_duration(&self, name: &str, d: Duration) {
        self.record_us(name, d.as_micros() as u64);
    }

    /// Fold a drained [`PhaseTimer`] into the shared accumulators
    /// (atomic adds — safe from any engine thread).
    pub fn add_phase(&self, ns: &[u64; PHASE_COUNT], samples: u64) {
        let Some(sh) = &self.0 else { return };
        for (slot, &v) in sh.phase_ns.iter().zip(ns.iter()) {
            if v > 0 {
                slot.fetch_add(v, Ordering::Relaxed);
            }
        }
        if samples > 0 {
            sh.phase_samples.fetch_add(samples, Ordering::Relaxed);
        }
    }

    /// All recorded events, in emission (seq) order.
    pub fn events(&self) -> Vec<Event> {
        self.0.as_ref().map_or_else(Vec::new, |sh| Self::lock(sh).ring.events().to_vec())
    }

    /// Copy out the registry + phase accumulators.
    pub fn snapshot(&self) -> Snapshot {
        match &self.0 {
            None => Snapshot {
                registry: Registry::default(),
                phase_ns: [0; PHASE_COUNT],
                phase_samples: 0,
                events_recorded: 0,
                events_dropped: 0,
            },
            Some(sh) => {
                let g = Self::lock(sh);
                let mut phase_ns = [0u64; PHASE_COUNT];
                for (o, s) in phase_ns.iter_mut().zip(sh.phase_ns.iter()) {
                    *o = s.load(Ordering::Relaxed);
                }
                Snapshot {
                    registry: g.reg.clone(),
                    phase_ns,
                    phase_samples: sh.phase_samples.load(Ordering::Relaxed),
                    events_recorded: g.ring.recorded(),
                    events_dropped: g.ring.dropped(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let obs = Obs::disabled();
        obs.emit(0, 1, EventKind::Shed);
        obs.counter_add("x", 1);
        obs.record_us("h", 5);
        obs.add_phase(&[1; PHASE_COUNT], 1);
        assert!(!obs.is_enabled());
        assert!(obs.events().is_empty());
        let s = obs.snapshot();
        assert!(s.registry.is_empty());
        assert_eq!(s.phase_total_ns(), 0);
    }

    #[test]
    fn clones_share_state() {
        let obs = Obs::enabled();
        let other = obs.clone();
        obs.set_tick(7);
        other.emit(2, 42, EventKind::FirstToken);
        other.counter_add("served", 1);
        let evs = obs.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].tick, 7);
        assert_eq!(evs[0].id, 42);
        assert_eq!(obs.snapshot().registry.counter("served"), 1);
    }

    #[test]
    fn phase_flush_accumulates() {
        let obs = Obs::enabled();
        let mut ns = [0u64; PHASE_COUNT];
        ns[Phase::QkTile as usize] = 100;
        obs.add_phase(&ns, 2);
        obs.add_phase(&ns, 1);
        let s = obs.snapshot();
        assert_eq!(s.phase_ns[Phase::QkTile as usize], 200);
        assert_eq!(s.phase_samples, 3);
    }
}
