//! IEEE 754 binary16 ("half") conversions, used to simulate the paper's
//! mma(f16.f16.f16.f16) tensor-core path (§4.4): FP16 operands and an FP16
//! accumulator. Round-to-nearest-even, matching hardware.
//!
//! Substrate note: the `half` crate is unavailable offline; this is a
//! standalone implementation with exhaustive round-trip tests.

/// A binary16 value stored as its bit pattern.
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    /// Largest finite f16 (65504).
    pub const MAX: F16 = F16(0x7BFF);

    #[inline]
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }
}

/// Convert f32 to f16 bits with round-to-nearest-even and proper
/// overflow-to-infinity / subnormal handling.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // inf / nan
        let payload = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | payload;
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if e >= -14 {
        // normal f16
        let mut m = mant >> 13; // 10 bits
        let rem = mant & 0x1FFF;
        // round to nearest even
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            m = 0;
            he += 1;
            if he >= 0x1F {
                return sign | 0x7C00;
            }
        }
        return sign | ((he as u16) << 10) | (m as u16);
    }
    if e >= -25 {
        // subnormal f16
        let full = mant | 0x0080_0000; // implicit leading 1
        let shift = (-14 - e) as u32 + 13;
        let m = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m = m;
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1;
        }
        return sign | (m as u16);
    }
    sign // underflow to zero
}

/// Convert f16 bits to f32 exactly.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: value = mant * 2^-24; normalize into f32.
            // MSB position p of mant gives value 2^(p-24) * (1.frac)
            let p = 31 - mant.leading_zeros(); // 0..=9
            let m = (mant << (10 - p)) & 0x03FF; // drop implicit 1, align to 10 bits
            let e = 127 - 24 + p;
            sign | (e << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through f16 precision (quantize-dequantize).
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Round a whole slice through f16 precision in place. Uses the x86 F16C
/// conversion instructions (8 lanes per op) when available — the software
/// fallback is bit-identical (§Perf: the fp16-accumulator simulation is
/// the native sage kernel's hot spot).
///
/// Feature detection goes through the shared
/// [`crate::attn::isa::cpu`] capability cache (the crate's single
/// detection surface), so `SAGE_ISA=scalar` forces this portable path
/// along with every other scalar microkernel.
pub fn round_f16_slice(xs: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::attn::isa::cpu::f16c_enabled() {
            // SAFETY: `f16c_enabled` requires the detected F16C bit.
            unsafe { round_f16_slice_f16c(xs) };
            return;
        }
    }
    for x in xs.iter_mut() {
        *x = round_f16(*x);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "f16c", enable = "avx")]
unsafe fn round_f16_slice_f16c(xs: &mut [f32]) {
    use std::arch::x86_64::*;
    let mut chunks = xs.chunks_exact_mut(8);
    for c in chunks.by_ref() {
        // round-to-nearest-even, matching f32_to_f16_bits
        let v = _mm256_loadu_ps(c.as_ptr());
        let h = _mm256_cvtps_ph::<_MM_FROUND_TO_NEAREST_INT>(v);
        let back = _mm256_cvtph_ps(h);
        _mm256_storeu_ps(c.as_mut_ptr(), back);
    }
    for x in chunks.into_remainder() {
        *x = round_f16(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_f16_values() {
        // every finite f16 bit pattern must round-trip exactly
        for bits in 0u16..=0xFFFF {
            let exp = (bits >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan
            }
            let f = f16_bits_to_f32(bits);
            let back = f32_to_f16_bits(f);
            assert_eq!(bits, back, "bits {bits:#06x} -> {f} -> {back:#06x}");
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // rounds to inf
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8); // smallest subnormal
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16; ties to even
        let halfway = 1.0 + f32::powi(2.0, -11);
        assert_eq!(f32_to_f16_bits(halfway), 0x3C00);
        let above = 1.0 + f32::powi(2.0, -11) + f32::powi(2.0, -20);
        assert_eq!(f32_to_f16_bits(above), 0x3C01);
    }

    #[test]
    fn nan_and_inf() {
        assert!(f16_bits_to_f32(0x7E00).is_nan());
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f32_to_f16_bits(f32::NAN) & 0x7C00, 0x7C00);
        assert_ne!(f32_to_f16_bits(f32::NAN) & 0x03FF, 0);
    }

    /// The contract the fused `pv_f16_step`/`scale_round_f16` ISA lanes
    /// inherit: the dispatched slice round (hardware F16C where
    /// detected) equals the software per-element round bit-for-bit, on
    /// every remainder length 0..8 and across the awkward corners of
    /// the f16 range — subnormals, ±0.0, and values straddling the
    /// 65504→inf overflow edge. (NaN payloads are excluded: they differ
    /// by design and never reach the kernels.)
    #[test]
    fn slice_round_matches_scalar_round_bit_for_bit() {
        use crate::util::rng::Pcg32;
        let specials: &[f32] = &[
            0.0,
            -0.0,
            5.960_464_5e-8, // smallest f16 subnormal
            -5.960_464_5e-8,
            2.0e-8, // below the smallest subnormal: rounds to ±0
            6.097_6e-5, // largest-subnormal neighborhood
            f32::MIN_POSITIVE,
            65503.9, // just under f16::MAX
            65504.0, // f16::MAX exactly
            65519.9, // rounds down to 65504
            65520.0, // halfway: rounds to inf
            -65520.0,
            1.0e30, // far overflow → inf
            -1.0e30,
            1.0 + f32::powi(2.0, -11), // RNE tie at 1.0
        ];
        let mut rng = Pcg32::seeded(616);
        // every remainder length 0..8, plus 8k+r lengths that exercise
        // full vector chunks ahead of the tail
        for len in (0..=8usize).chain([9, 15, 16, 17, 23, 31, 64, 71]) {
            for trial in 0..8 {
                let xs: Vec<f32> = (0..len)
                    .map(|i| {
                        if (i + trial) % 3 == 0 {
                            specials[(i * 7 + trial) % specials.len()]
                        } else {
                            rng.normal() * 1000.0
                        }
                    })
                    .collect();
                let want: Vec<u32> = xs.iter().map(|&x| round_f16(x).to_bits()).collect();
                let mut got = xs.clone();
                round_f16_slice(&mut got);
                let got: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "len {len} trial {trial} input {xs:?}");
            }
        }
    }
}
