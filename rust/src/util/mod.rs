//! Self-contained substrates standing in for unavailable ecosystem crates
//! (offline image, DESIGN.md §3): IEEE half-precision conversion, a PCG
//! random generator, a JSON parser/writer for the artifact manifest, and
//! an `anyhow`-shaped error/context type.

pub mod error;
pub mod f16;
pub mod json;
pub mod rng;

/// Ceil-division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}
