//! Deterministic random generation: PCG32 core + normal/categorical
//! sampling. Substrate for the unavailable `rand`/`rand_distr` crates;
//! used by the synthetic QKV generators, parameter init, the request
//! workload generators and the property-test harness.

/// PCG-XSH-RR 32-bit generator (O'Neill 2014). Small, fast, seedable,
/// and statistically solid for simulation workloads.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller (polar form avoided; trig is fine).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// N(mu, sigma^2).
    #[inline]
    pub fn normal_with(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Fill a buffer with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], sigma: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Exponential with rate lambda (mean 1/lambda) — request inter-arrival times.
    #[inline]
    pub fn exponential(&mut self, lambda: f32) -> f32 {
        -self.uniform().max(1e-12).ln() / lambda
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(1, 1);
        let mut b = Pcg32::new(1, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg32::seeded(9);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }
}
