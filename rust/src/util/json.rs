//! Minimal JSON parser + writer (RFC 8259 subset sufficient for the
//! artifact manifest, calibration plans and benchmark reports).
//! Substrate for the unavailable `serde_json` (DESIGN.md §3).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style access with a dotted path (no escaping).
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape-style arrays: [2, 8, 256, 64] -> Vec<usize>.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_str_vec(&self) -> Option<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_owned))
            .collect()
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_owned(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_owned() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                b if b < 0x20 => return Err(self.err("control char in string")),
                b => {
                    // re-assemble UTF-8 multibyte sequences verbatim
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("bad hex digit"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---- serialization --------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":{"x":{"shape":[2,8,256,64],"dtype":"float32"}},"n":3}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""é☃ 😀 ünïcödé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é☃ 😀 ünïcödé");
        let round = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, round);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""\q""#).is_err());
    }

    #[test]
    fn usize_vec_and_path() {
        let j = Json::parse(r#"{"shape": [1, 2, 3]}"#).unwrap();
        assert_eq!(j.path("shape").unwrap().as_usize_vec().unwrap(), vec![1, 2, 3]);
    }
}
