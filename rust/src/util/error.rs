//! Hand-rolled error type standing in for the unavailable `anyhow` crate
//! (offline image — same convention as the clap/rand/serde substitutes in
//! this module). Provides:
//!
//! * [`Error`] / [`Result`] — a string-message error that accumulates
//!   context frames ("outer: inner") the way `anyhow` renders `{:#}`.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both
//!   `Result<T, E: Display>` and `Option<T>`.
//! * [`bail!`], [`ensure!`], [`format_err!`] — the matching macros.

use std::fmt;

/// Crate-wide result alias (the `anyhow::Result` stand-in).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error. Context frames are folded into the message
/// as `"context: cause"`, so `{}` and `{:#}` both render the full chain.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context frame.
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { msg: s.to_owned() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e)
    }
}

impl From<crate::util::json::ParseError> for Error {
    fn from(e: crate::util::json::ParseError) -> Error {
        Error::msg(e)
    }
}

/// `.context(..)` / `.with_context(..)` — the `anyhow::Context` stand-in,
/// implemented for fallible results and for `Option` (missing value →
/// error with the given message).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`] (the `anyhow::bail!` stand-in).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Bail unless a condition holds (the `anyhow::ensure!` stand-in).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// Build (without returning) a formatted [`Error`].
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

pub use crate::{bail, ensure, format_err};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));

        let o: Option<u32> = None;
        assert_eq!(o.context("missing field").unwrap_err().to_string(), "missing field");
        let some: Option<u32> = Some(7);
        assert_eq!(some.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn from_conversions() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
        let _e: Error = format_err!("x = {}", 1);
    }
}
