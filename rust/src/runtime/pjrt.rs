//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The offline image cannot vendor the `xla` crate (it links against a
//! prebuilt `xla_extension`), so this module mirrors the slice of its API
//! the runtime layer uses. [`Literal`] is a real host-side container —
//! marshalling ([`crate::runtime::Value::to_literal`] /
//! `from_literal`) round-trips losslessly — while everything that would
//! need an actual XLA backend (HLO parsing, compilation, execution)
//! returns a clear "unavailable in the offline build" error. Code above
//! this boundary (manifest handling, the coordinator's accounting, all
//! rust-native numerics) runs unchanged; artifact execution paths fail
//! loudly instead of silently producing wrong answers.

use crate::util::error::{Error, Result};

/// Element dtypes crossing the artifact ABI (f32 activations, i32 tokens).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }

    fn name(self) -> &'static str {
        match self {
            ElementType::F32 => "f32",
            ElementType::S32 => "s32",
        }
    }
}

/// Host types that can be decoded out of a [`Literal`].
pub trait NativeType: Sized {
    const TY: ElementType;
    fn from_ne_bytes(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_ne_bytes(bytes: [u8; 4]) -> f32 {
        f32::from_ne_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_ne_bytes(bytes: [u8; 4]) -> i32 {
        i32::from_ne_bytes(bytes)
    }
}

/// A host tensor literal: dtype + shape + native-endian bytes. Fully
/// functional (unlike the executable types below) so the Value marshalling
/// layer and its tests work without XLA.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let expected = shape.iter().product::<usize>() * ty.byte_width();
        if data.len() != expected {
            return Err(Error::msg(format!(
                "literal data is {} bytes, shape {:?} of {} needs {}",
                data.len(),
                shape,
                ty.name(),
                expected
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Decode into a host vector; the requested type must match.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::msg(format!(
                "literal holds {}, requested {}",
                self.ty.name(),
                T::TY.name()
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_ne_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Device→host transfer; host literals are already on the host.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.clone())
    }

    /// Unpack a tuple literal. Only executables produce tuples, and the
    /// stub cannot execute, so this is unreachable in the offline build.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::msg(
            "tuple literals require an XLA execution result; unavailable in the offline build",
        ))
    }
}

fn unavailable(what: &str) -> Error {
    Error::msg(format!(
        "{what} unavailable: this build uses the offline PJRT stub \
         (crate::runtime::pjrt) — link the real `xla` bindings to execute artifacts"
    ))
}

/// Parsed HLO module handle. The stub has no HLO parser, so construction
/// always fails (after checking the file is at least readable, so missing
/// files and unparseable files report distinct errors).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("reading HLO text {path}: {e}")))?;
        Err(unavailable("HLO text parsing"))
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, device-loaded executable. Never constructible through the
/// stub (compilation fails), so `execute` is unreachable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<Literal>>> {
        Err(unavailable("artifact execution"))
    }
}

/// The PJRT client. Creation succeeds (so manifest-only runtimes work —
/// opening an artifact directory, listing entries, accounting); anything
/// touching a device does not.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (offline; no XLA backend)".to_owned()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("artifact compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32_and_i32() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert!(lit.to_vec::<i32>().is_err(), "dtype confusion must fail");

        let is = [7i32, -9];
        let bytes: Vec<u8> = is.iter().flat_map(|x| x.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &bytes).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), is);
    }

    #[test]
    fn literal_rejects_size_mismatch() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &[0u8; 12])
                .is_err()
        );
    }

    #[test]
    fn execution_paths_fail_loudly() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let comp = XlaComputation { _private: () };
        let err = client.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("offline"), "{err}");
    }
}
