//! PJRT runtime: loads HLO-text artifacts produced by `python/compile/aot.py`
//! and executes them on the CPU PJRT client. This is the only boundary to
//! XLA — everything above it (coordinator, benches, examples) works with
//! plain host [`Value`]s.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see aot.py).
//!
//! Offline builds (this image) link the [`pjrt`] stub instead of the real
//! `xla` bindings: manifest handling and value marshalling work in full,
//! while compiling/executing an artifact returns an "unavailable" error.

mod manifest;
pub mod pjrt;
mod value;

pub use manifest::{ArtifactSpec, Manifest, ModelCfg, ParamSpec, TensorSpec};
pub use value::Value;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use self::pjrt as xla;
use crate::util::error::{bail, Context, Result};

/// A compiled artifact plus its manifest spec.
pub struct Artifact {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with host values; validates arity/shape/dtype against the
    /// manifest, marshals literals, and unpacks the result tuple.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (v, spec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if v.shape() != spec.shape.as_slice() || v.dtype_name() != spec.dtype {
                bail!(
                    "{}: input {} mismatch: got {:?}/{}, manifest wants {:?}/{}",
                    self.name,
                    i,
                    v.shape(),
                    v.dtype_name(),
                    spec.shape,
                    spec.dtype
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Value::to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec))
            .collect()
    }

    /// Zero-validation execution over pre-marshalled literals, returning
    /// raw output literals. The serving hot path uses this to keep large
    /// state (parameters, KV caches) in literal form across steps instead
    /// of round-tripping host vectors (§Perf: saves ~40 MB of memcpy per
    /// decode step on the `small` config).
    pub fn run_raw(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = self.exe.execute::<&xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

/// Artifact store: PJRT client + manifest + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: std::sync::Mutex<HashMap<String, std::sync::Arc<Artifact>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir, manifest, cache: std::sync::Mutex::new(HashMap::new()) })
    }

    /// Default artifact location (repo-root `artifacts/`), overridable via
    /// `SAGE_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("SAGE_ARTIFACTS") {
            return PathBuf::from(p);
        }
        // walk up from cwd looking for artifacts/manifest.json
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an artifact by manifest name; cached.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let spec = self
            .manifest
            .entries
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let art = std::sync::Arc::new(Artifact { name: name.to_owned(), spec, exe });
        tracing_compile(name, t0.elapsed());
        self.cache.lock().unwrap().insert(name.to_owned(), art.clone());
        Ok(art)
    }

    /// All manifest entry names with a given `kind`.
    pub fn entries_of_kind(&self, kind: &str) -> Vec<String> {
        self.manifest
            .entries
            .iter()
            .filter(|(_, e)| e.kind.as_deref() == Some(kind))
            .map(|(n, _)| n.clone())
            .collect()
    }
}

fn tracing_compile(name: &str, dur: std::time::Duration) {
    if std::env::var("SAGE_LOG").is_ok() {
        eprintln!("[runtime] compiled {name} in {dur:?}");
    }
}
