//! Typed view of `artifacts/manifest.json` (written by aot.py): artifact
//! entry specs and model configurations including the parameter-init spec
//! that lets rust construct model weights without python.

use std::collections::BTreeMap;

use crate::util::error::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: j.get("shape").and_then(Json::as_usize_vec).context("shape")?,
            dtype: j.get("dtype").and_then(Json::as_str).context("dtype")?.to_owned(),
        })
    }
}

/// One artifact entry: file + I/O contract + experiment metadata.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub kind: Option<String>,
    pub config: Option<String>,
    pub plan: Option<Vec<String>>,
    pub batch: Option<usize>,
    pub n_prompt: Option<usize>,
    pub causal: Option<bool>,
    pub impl_name: Option<String>,
    pub shape: Option<Vec<usize>>,
}

/// One parameter of the transformer: name, shape, init std
/// (std < 0 marks a norm gain initialized to ones).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init_std: f32,
}

/// A model configuration (mirrors python `configs.ModelConfig`).
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    /// RoPE frequency base (the native backend's forward needs it;
    /// manifests without the field default to 10000).
    pub rope_base: f32,
    pub n_params: usize,
    pub param_spec: Vec<ParamSpec>,
}

#[derive(Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, ArtifactSpec>,
    pub configs: BTreeMap<String, ModelCfg>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        let mut entries = BTreeMap::new();
        for (name, e) in root.get("entries").and_then(Json::as_obj).context("entries")? {
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<_>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .context("outputs")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<_>>()?;
            entries.insert(
                name.clone(),
                ArtifactSpec {
                    file: e.get("file").and_then(Json::as_str).context("file")?.to_owned(),
                    inputs,
                    outputs,
                    kind: e.get("kind").and_then(Json::as_str).map(str::to_owned),
                    config: e.get("config").and_then(Json::as_str).map(str::to_owned),
                    plan: e.get("plan").and_then(Json::as_str_vec),
                    batch: e.get("batch").and_then(Json::as_usize),
                    n_prompt: e.get("n_prompt").and_then(Json::as_usize),
                    causal: e.get("causal").and_then(Json::as_bool),
                    impl_name: e.get("impl").and_then(Json::as_str).map(str::to_owned),
                    shape: e.get("shape").and_then(Json::as_usize_vec),
                },
            );
        }
        let mut configs = BTreeMap::new();
        if let Some(cfgs) = root.get("configs").and_then(Json::as_obj) {
            for (name, c) in cfgs {
                let get = |k: &str| c.get(k).and_then(Json::as_usize).context(k.to_owned());
                let param_spec = c
                    .get("param_spec")
                    .and_then(Json::as_arr)
                    .context("param_spec")?
                    .iter()
                    .map(|p| {
                        Ok(ParamSpec {
                            name: p.get("name").and_then(Json::as_str).context("name")?.to_owned(),
                            shape: p.get("shape").and_then(Json::as_usize_vec).context("shape")?,
                            init_std: p
                                .get("init_std")
                                .and_then(Json::as_f64)
                                .context("init_std")? as f32,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                configs.insert(
                    name.clone(),
                    ModelCfg {
                        name: name.clone(),
                        vocab: get("vocab")?,
                        d_model: get("d_model")?,
                        n_layers: get("n_layers")?,
                        n_heads: get("n_heads")?,
                        d_head: get("d_head")?,
                        d_ff: get("d_ff")?,
                        max_seq: get("max_seq")?,
                        rope_base: c
                            .get("rope_base")
                            .and_then(Json::as_f64)
                            .unwrap_or(10000.0) as f32,
                        n_params: get("n_params")?,
                        param_spec,
                    },
                );
            }
        }
        Ok(Manifest { entries, configs })
    }
}

impl ModelCfg {
    /// Construct a GPT-style config with the parameter spec the model
    /// layout implies (mirrors python `model.param_spec`: embed, then
    /// per-layer ln1/wq/wk/wv/wo/ln2/w_gate/w_up/w_down, then
    /// ln_f/unembed) — the native backend's manifest-free path, and the
    /// benches' way to build custom shapes.
    #[allow(clippy::too_many_arguments)]
    pub fn gpt(
        name: &str,
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
        d_ff: usize,
        max_seq: usize,
    ) -> ModelCfg {
        let (d, h, dh, f) = (d_model, n_heads, d_head, d_ff);
        let mut spec = vec![ParamSpec {
            name: "embed".to_owned(),
            shape: vec![vocab, d],
            init_std: 0.02,
        }];
        let resid_std = 0.02 / ((2 * n_layers) as f32).sqrt();
        for i in 0..n_layers {
            let p = format!("layer{i}.");
            let mut push = |suffix: &str, shape: Vec<usize>, std: f32| {
                spec.push(ParamSpec { name: format!("{p}{suffix}"), shape, init_std: std });
            };
            push("ln1", vec![d], -1.0);
            push("wq", vec![d, h * dh], 0.02);
            push("wk", vec![d, h * dh], 0.02);
            push("wv", vec![d, h * dh], 0.02);
            push("wo", vec![h * dh, d], resid_std);
            push("ln2", vec![d], -1.0);
            push("w_gate", vec![d, f], 0.02);
            push("w_up", vec![d, f], 0.02);
            push("w_down", vec![f, d], resid_std);
        }
        spec.push(ParamSpec { name: "ln_f".to_owned(), shape: vec![d], init_std: -1.0 });
        spec.push(ParamSpec {
            name: "unembed".to_owned(),
            shape: vec![d, vocab],
            init_std: 0.02,
        });
        let n_params = spec.iter().map(|p| p.shape.iter().product::<usize>()).sum();
        ModelCfg {
            name: name.to_owned(),
            vocab,
            d_model,
            n_layers,
            n_heads,
            d_head,
            d_ff,
            max_seq,
            rope_base: 10000.0,
            n_params,
            param_spec: spec,
        }
    }

    /// The built-in configs (mirroring python `configs.TINY`/`SMALL`) —
    /// what `--backend native` serves without artifacts or a manifest.
    pub fn builtin(name: &str) -> Option<ModelCfg> {
        match name {
            "tiny" => Some(ModelCfg::gpt("tiny", 256, 128, 2, 2, 64, 256, 128)),
            "small" => Some(ModelCfg::gpt("small", 1024, 256, 4, 4, 64, 1024, 256)),
            _ => None,
        }
    }

    /// Initialize flat parameters per the spec (normal(0, std), ones for
    /// std < 0) with a deterministic seed — the rust-side `init_params`.
    pub fn init_params(&self, seed: u64) -> Vec<crate::runtime::Value> {
        let mut rng = crate::util::rng::Pcg32::seeded(seed);
        self.param_spec
            .iter()
            .map(|p| {
                let n = p.shape.iter().product();
                let data = if p.init_std < 0.0 {
                    vec![1.0f32; n]
                } else {
                    let mut v = vec![0.0f32; n];
                    rng.fill_normal(&mut v, p.init_std);
                    v
                };
                crate::runtime::Value::F32 { data, shape: p.shape.clone() }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "entries": {
        "attn_exact_1x2x256x64": {
          "file": "attn_exact_1x2x256x64.hlo.txt",
          "inputs": [{"shape": [1,2,256,64], "dtype": "float32"}],
          "outputs": [{"shape": [1,2,256,64], "dtype": "float32"}],
          "kind": "attention", "impl": "exact", "causal": false,
          "shape": [1,2,256,64]
        }
      },
      "configs": {
        "tiny": {
          "name": "tiny", "vocab": 256, "d_model": 128, "n_layers": 2,
          "n_heads": 2, "d_head": 64, "d_ff": 256, "max_seq": 128,
          "rope_base": 10000.0, "n_params": 12345,
          "param_spec": [
            {"name": "embed", "shape": [256, 128], "init_std": 0.02},
            {"name": "layer0.ln1", "shape": [128], "init_std": -1.0}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = &m.entries["attn_exact_1x2x256x64"];
        assert_eq!(e.inputs[0].shape, vec![1, 2, 256, 64]);
        assert_eq!(e.kind.as_deref(), Some("attention"));
        assert_eq!(e.impl_name.as_deref(), Some("exact"));
        let c = &m.configs["tiny"];
        assert_eq!(c.vocab, 256);
        assert_eq!(c.param_spec.len(), 2);
    }

    #[test]
    fn builtin_configs_match_python_layout() {
        let tiny = ModelCfg::builtin("tiny").unwrap();
        assert_eq!(tiny.vocab, 256);
        assert_eq!(tiny.d_model, 128);
        assert_eq!(tiny.max_seq, 128);
        assert_eq!(tiny.rope_base, 10000.0);
        // embed + 9 per layer + ln_f + unembed
        assert_eq!(tiny.param_spec.len(), 3 + 9 * tiny.n_layers);
        assert_eq!(tiny.param_spec[0].name, "embed");
        assert_eq!(tiny.param_spec[1].name, "layer0.ln1");
        assert_eq!(tiny.param_spec[5].name, "layer0.wo");
        assert_eq!(tiny.param_spec.last().unwrap().name, "unembed");
        // norm gains are ones-initialized (std < 0)
        assert!(tiny.param_spec[1].init_std < 0.0);
        let small = ModelCfg::builtin("small").unwrap();
        assert_eq!(small.n_layers, 4);
        assert_eq!(small.d_ff, 1024);
        assert!(ModelCfg::builtin("huge").is_none());
        // init_params agrees with the generated spec
        let params = tiny.init_params(3);
        assert_eq!(params.len(), tiny.param_spec.len());
        assert_eq!(params[0].shape(), &[256, 128]);
    }

    #[test]
    fn init_params_respects_spec() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let params = m.configs["tiny"].init_params(42);
        assert_eq!(params.len(), 2);
        // embed: normal with std 0.02
        if let crate::runtime::Value::F32 { data, shape } = &params[0] {
            assert_eq!(shape, &vec![256, 128]);
            let std = (data.iter().map(|x| x * x).sum::<f32>() / data.len() as f32).sqrt();
            assert!((std - 0.02).abs() < 0.002, "std {std}");
        } else {
            panic!("wrong dtype");
        }
        // ln gain: all ones
        if let crate::runtime::Value::F32 { data, .. } = &params[1] {
            assert!(data.iter().all(|&x| x == 1.0));
        } else {
            panic!("wrong dtype");
        }
    }
}
