//! Host-side tensor values crossing the PJRT boundary.

use super::manifest::TensorSpec;
use super::pjrt as xla;
use crate::util::error::{bail, Result};

/// A host tensor: the only dtypes crossing the artifact ABI are f32
/// (activations, params, caches) and i32 (tokens, step/pos counters).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Value {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Value {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Value::F32 { data, shape: shape.to_vec() }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Value {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Value::I32 { data, shape: shape.to_vec() }
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32 { data: vec![v], shape: vec![] }
    }

    pub fn zeros_f32(shape: &[usize]) -> Value {
        Value::F32 { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } | Value::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Value::F32 { .. } => "float32",
            Value::I32 { .. } => "int32",
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 value, got {}", self.dtype_name()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 value, got {}", self.dtype_name()),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape());
        }
        Ok(d[0])
    }

    /// Into an attention-layout tensor for the native numerics code.
    pub fn to_tensor(&self) -> Result<crate::tensor::Tensor> {
        Ok(crate::tensor::Tensor::new(self.as_f32()?.to_vec(), self.shape()))
    }

    pub fn from_tensor(t: &crate::tensor::Tensor) -> Value {
        Value::F32 { data: t.data.clone(), shape: t.shape.clone() }
    }

    /// Marshal into an XLA literal (one host copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32 { data, shape } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    bytes,
                )?
            }
            Value::I32 { data, shape } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    bytes,
                )?
            }
        };
        Ok(lit)
    }

    /// Unmarshal from an XLA literal per the manifest spec (one host copy).
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Value> {
        match spec.dtype.as_str() {
            "float32" => Ok(Value::F32 { data: lit.to_vec::<f32>()?, shape: spec.shape.clone() }),
            "int32" => Ok(Value::I32 { data: lit.to_vec::<i32>()?, shape: spec.shape.clone() }),
            other => bail!("unsupported artifact dtype {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shape_dtype() {
        let v = Value::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(v.dtype_name(), "float32");
        assert_eq!(v.numel(), 4);
        assert!(v.as_i32().is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let v = Value::scalar_i32(7);
        assert_eq!(v.shape(), &[] as &[usize]);
        assert_eq!(v.as_i32().unwrap(), &[7]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Value::f32(vec![0.0; 3], &[2, 2]);
    }
}
