//! Tile-level analytic GPU cost model (DESIGN.md §3 substitution for the
//! paper's RTX4090/3090 testbeds).
//!
//! The paper's speed results (Figures 6–9, Tables 7/10/11/16/19) compare
//! attention kernels on fixed hardware. Those comparisons are functions of
//! (a) how many mma ops each variant issues in which tensor-core mode,
//! (b) how many bytes move between DRAM and the SMs, and (c) fixed
//! overheads (launch, quantization passes). This module prices those terms
//! against published device specs, with per-kernel pipeline-efficiency
//! factors calibrated once against the paper's reported peaks (FA2 = 165
//! TOPS, SageAttn = 341 TOPS on RTX4090 @ hd64) — after which every other
//! number (crossovers, model-shape speedups, 3090 scaling) is *predicted*.
//!
//! `TOPS` follows the paper's convention: 4·N²·d ops (two matmuls, 2 ops
//! per MAC), halved under a causal mask.

mod device;
mod kernels;

pub use device::{DeviceSpec, RTX3090, RTX4090};
pub use kernels::{predict, AttnKernel, CostBreakdown};

use crate::metrics::attention_ops;

/// One speed-measurement point: a kernel on a device at a shape.
#[derive(Clone, Copy, Debug)]
pub struct Workpoint {
    pub batch: usize,
    pub heads: usize,
    pub n_q: usize,
    pub n_kv: usize,
    pub head_dim: usize,
    pub causal: bool,
}

impl Workpoint {
    pub fn square(batch: usize, heads: usize, n: usize, d: usize, causal: bool) -> Self {
        Workpoint { batch, heads, n_q: n, n_kv: n, head_dim: d, causal }
    }

    pub fn ops(&self) -> f64 {
        attention_ops(self.batch, self.heads, self.n_q, self.n_kv, self.head_dim, self.causal)
    }
}

/// Predicted achieved TOPS for `kernel` on `dev` at `wp`.
pub fn predict_tops(dev: &DeviceSpec, kernel: AttnKernel, wp: Workpoint) -> f64 {
    let cost = predict(dev, kernel, wp);
    wp.ops() / cost.total_s / 1e12
}

/// Predicted latency in milliseconds.
pub fn predict_ms(dev: &DeviceSpec, kernel: AttnKernel, wp: Workpoint) -> f64 {
    predict(dev, kernel, wp).total_s * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wp(n: usize, d: usize, causal: bool) -> Workpoint {
        Workpoint::square(4, 32, n, d, causal)
    }

    #[test]
    fn calibration_matches_paper_peaks_rtx4090() {
        // Paper: SageAttn peaks at ~341 TOPS, FA2 at ~165 TOPS (4090, hd64).
        let sage = predict_tops(&RTX4090, AttnKernel::SageAttnB, wp(32768, 64, false));
        let fa2 = predict_tops(&RTX4090, AttnKernel::FlashAttention2, wp(32768, 64, false));
        assert!((sage - 341.0).abs() / 341.0 < 0.15, "sage {sage}");
        assert!((fa2 - 165.0).abs() / 165.0 < 0.15, "fa2 {fa2}");
    }

    #[test]
    fn sage_beats_fa2_by_about_2x_at_long_seq() {
        for &d in &[64usize, 128] {
            let sage = predict_tops(&RTX4090, AttnKernel::SageAttnB, wp(16384, d, false));
            let fa2 = predict_tops(&RTX4090, AttnKernel::FlashAttention2, wp(16384, d, false));
            let ratio = sage / fa2;
            assert!((1.6..=2.6).contains(&ratio), "hd{d} ratio {ratio}");
        }
    }

    #[test]
    fn xformers_slowest_of_fused_kernels() {
        let x = predict_tops(&RTX4090, AttnKernel::Xformers, wp(8192, 64, false));
        let fa2 = predict_tops(&RTX4090, AttnKernel::FlashAttention2, wp(8192, 64, false));
        let sage = predict_tops(&RTX4090, AttnKernel::SageAttnB, wp(8192, 64, false));
        assert!(x < fa2 && fa2 < sage);
        // paper: sage ≈ 2.7–2.9× xformers on average
        let ratio = sage / x;
        assert!((2.0..=3.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn short_sequences_lose_throughput() {
        let short = predict_tops(&RTX4090, AttnKernel::SageAttnB, wp(1024, 64, false));
        let long = predict_tops(&RTX4090, AttnKernel::SageAttnB, wp(32768, 64, false));
        assert!(short < 0.8 * long, "short {short} long {long}");
    }

    #[test]
    fn rtx3090_proportionally_slower() {
        let s4090 = predict_tops(&RTX4090, AttnKernel::SageAttnB, wp(16384, 64, false));
        let s3090 = predict_tops(&RTX3090, AttnKernel::SageAttnB, wp(16384, 64, false));
        let ratio = s4090 / s3090;
        // 4090 int8 peak is ~2.3x the 3090's; allow slack for memory terms
        assert!((1.8..=3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn torch_sdpa_ooms_at_long_seq() {
        // Table 16: naive attention materializes the N×N matrix and OOMs at 8k
        let c = predict(&RTX4090, AttnKernel::TorchNaive, wp(8192, 64, false));
        assert!(c.oom, "torch at 8k should OOM");
        let c2 = predict(&RTX4090, AttnKernel::TorchNaive, wp(2048, 64, false));
        assert!(!c2.oom);
    }

    #[test]
    fn smoothing_overhead_below_half_percent() {
        // Table 10: smooth-K costs < 0.2% of attention time
        let with = predict(&RTX4090, AttnKernel::SageAttnB, wp(17776, 64, false));
        let without = predict(
            &RTX4090,
            AttnKernel::SageAttnBNoSmooth,
            wp(17776, 64, false),
        );
        let overhead = (with.total_s - without.total_s) / without.total_s;
        assert!(
            (0.0..0.005).contains(&overhead),
            "smooth-K overhead {overhead}"
        );
    }

    #[test]
    fn vb_slightly_faster_than_b() {
        // §4.5: SageAttn-vB ≈ 4% faster than SageAttn-B
        let b = predict(&RTX4090, AttnKernel::SageAttnB, wp(17776, 64, false));
        let vb = predict(&RTX4090, AttnKernel::SageAttnVB, wp(17776, 64, false));
        let gain = b.total_s / vb.total_s - 1.0;
        assert!((0.005..0.12).contains(&gain), "vB gain over B: {gain}");
    }

    #[test]
    fn causal_halves_ops_not_tops() {
        let full = predict_tops(&RTX4090, AttnKernel::SageAttnB, wp(16384, 64, false));
        let causal = predict_tops(&RTX4090, AttnKernel::SageAttnB, wp(16384, 64, true));
        // causal TOPS stay in the same ballpark (both ops and time halve)
        assert!((causal / full - 1.0).abs() < 0.35, "full {full} causal {causal}");
    }
}
