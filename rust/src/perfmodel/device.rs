//! Published device specifications for the paper's two testbeds.
//!
//! Rates are *dense* tensor-core throughputs (no sparsity doubling), in
//! tera-ops/s; bandwidths in GB/s. Sources: NVIDIA Ada/Ampere whitepapers.
//! The key ratios the paper exploits hold on both cards:
//!   int8 = 4 × fp16-with-fp32-acc,  fp16-with-fp16-acc = 2 × fp16-with-fp32-acc.

/// Tensor-core and memory characteristics of one GPU.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// mma(f16.f16.f32.f32) dense rate, TFLOPS.
    pub fp16_fp32acc_tflops: f64,
    /// mma(f16.f16.f16.f16) dense rate, TFLOPS (2× on consumer cards).
    pub fp16_fp16acc_tflops: f64,
    /// mma(u8.u8.s32) dense rate, TOPS.
    pub int8_tops: f64,
    /// FP8 tensor rate, TOPS (0 where the arch has no FP8 MMA — Ada has
    /// FP8 only via Hopper-class transformer engines; RTX4090 FP8 ==
    /// INT8-rate/2 per the paper's "INT8 two times faster than FP8").
    pub fp8_tops: f64,
    /// CUDA-core fp32 vector rate (softmax / exp / rescale work), TFLOPS.
    pub fp32_vector_tflops: f64,
    /// DRAM bandwidth, GB/s.
    pub dram_gbps: f64,
    /// Usable device memory for activations, GiB (Table 16 OOM modeling).
    pub mem_gib: f64,
    /// Number of SMs (occupancy / wave quantization modeling).
    pub sms: usize,
    /// Kernel launch + tail latency floor, microseconds.
    pub launch_us: f64,
}

/// NVIDIA GeForce RTX 4090 (Ada, AD102).
pub const RTX4090: DeviceSpec = DeviceSpec {
    name: "RTX4090",
    fp16_fp32acc_tflops: 165.2,
    fp16_fp16acc_tflops: 330.3,
    int8_tops: 660.6,
    fp8_tops: 330.3,
    fp32_vector_tflops: 82.6,
    dram_gbps: 1008.0,
    mem_gib: 24.0,
    sms: 128,
    launch_us: 6.0,
};

/// NVIDIA GeForce RTX 3090 (Ampere, GA102).
pub const RTX3090: DeviceSpec = DeviceSpec {
    name: "RTX3090",
    fp16_fp32acc_tflops: 71.0,
    fp16_fp16acc_tflops: 142.0,
    int8_tops: 284.0,
    fp8_tops: 142.0,
    fp32_vector_tflops: 35.6,
    dram_gbps: 936.0,
    mem_gib: 24.0,
    sms: 82,
    launch_us: 6.0,
};

impl DeviceSpec {
    pub fn by_name(name: &str) -> Option<&'static DeviceSpec> {
        match name {
            "RTX4090" | "rtx4090" | "4090" => Some(&RTX4090),
            "RTX3090" | "rtx3090" | "3090" => Some(&RTX3090),
            _ => None,
        }
    }
}
