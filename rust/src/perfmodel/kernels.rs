//! Per-kernel cost functions. Every kernel is described by which
//! tensor-core mode each matmul uses, how visible the softmax/vector work
//! is (how much the pipeline hides), how many DRAM bytes it moves, and a
//! throughput ramp n_half modeling per-tile prologue amortization (the
//! rising TOPS-vs-seqlen curves of Figures 6–9).
//!
//! Calibration: two constants (SageAttn mma efficiency, FA2 mma
//! efficiency) are set so the RTX4090/hd64 peaks match the paper's 341 and
//! 165 TOPS. Everything else is derived from device specs and arithmetic.

use super::device::DeviceSpec;
use super::Workpoint;

/// Attention kernels the paper benchmarks (Figures 6–9, Tables 7/16/19).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AttnKernel {
    /// torch.nn.functional SDPA math path: materializes S and P in HBM.
    TorchNaive,
    /// SageAttention's quantized matmuls grafted onto the Torch (unfused,
    /// materializing) attention — Table 16.
    SageTorchBased,
    /// xformers memory-efficient attention (fused, fp16, fp32 accum).
    Xformers,
    /// FlashAttention-2 (fused, fp16 operands, fp32 accumulators).
    FlashAttention2,
    /// FlashAttention-3 FP8 mode (Hopper-only in reality; priced at the
    /// device's FP8 rate for what-if comparisons).
    FlashAttention3Fp8,
    /// SageAttn-T: per-token INT8 QK + FP16/FP16-acc PV (smooth-K fused).
    SageAttnT,
    /// SageAttn-B: per-block INT8 QK + FP16/FP16-acc PV (smooth-K fused).
    SageAttnB,
    /// SageAttn-vT: per-token INT8 QK + INT8 PV.
    SageAttnVT,
    /// SageAttn-vB: per-block INT8 QK + INT8 PV (the fastest variant).
    SageAttnVB,
    /// SageAttn-B with the smooth-K pass disabled (Table 10 ablation).
    SageAttnBNoSmooth,
    /// SageAttn-T without fusing quantization into RoPE: pays an extra
    /// read+write pass over Q,K (§4.6 fusion-trick ablation).
    SageAttnTUnfused,
}

/// Which tensor-core pipe a matmul runs on.
#[derive(Clone, Copy, Debug)]
enum MmaMode {
    Fp16Fp32Acc,
    Fp16Fp16Acc,
    Int8,
    Fp8,
}

impl MmaMode {
    fn rate(self, dev: &DeviceSpec) -> f64 {
        match self {
            MmaMode::Fp16Fp32Acc => dev.fp16_fp32acc_tflops,
            MmaMode::Fp16Fp16Acc => dev.fp16_fp16acc_tflops,
            MmaMode::Int8 => dev.int8_tops,
            MmaMode::Fp8 => dev.fp8_tops,
        }
    }
}

struct KernelDesc {
    qk: MmaMode,
    pv: MmaMode,
    /// fraction of each matmul pipe's peak the kernel sustains
    qk_eff: f64,
    pv_eff: f64,
    /// fraction of softmax/vector work NOT hidden behind the mma pipe
    softmax_visibility: f64,
    /// extra vector flops per S element (quant/dequant epilogues)
    extra_vec_flops: f64,
    /// bytes per element of Q/K and of V/O in DRAM
    qk_bytes: f64,
    vo_bytes: f64,
    /// materializes S and P in DRAM (naive kernels)
    materializes: bool,
    /// bytes per S/P element when materialized (fp16 = 2, int8 = 1)
    mat_bytes: f64,
    /// extra full passes over Q,K in DRAM (unfused quantization)
    unfused_quant_passes: f64,
    /// reads K once more for the token-mean (smooth-K, fused into RoPE)
    smooth_k: bool,
    /// TOPS ramp half-point (elements of N_kv) — pipeline fill/prologue
    n_half: f64,
}

fn desc(kernel: AttnKernel) -> KernelDesc {
    use AttnKernel::*;
    use MmaMode::*;
    // Calibrated constants (see module docs): sage mma efficiency and FA2
    // mma efficiency pin the two paper peaks; the rest is derived.
    const SAGE_EFF: f64 = 0.865;
    const FA2_EFF: f64 = 1.00;
    match kernel {
        TorchNaive => KernelDesc {
            qk: Fp16Fp32Acc,
            pv: Fp16Fp32Acc,
            qk_eff: 0.70,
            pv_eff: 0.70,
            softmax_visibility: 1.0, // separate kernels, nothing hidden
            extra_vec_flops: 0.0,
            qk_bytes: 2.0,
            vo_bytes: 2.0,
            materializes: true,
            mat_bytes: 2.0,
            unfused_quant_passes: 0.0,
            smooth_k: false,
            n_half: 256.0,
        },
        SageTorchBased => KernelDesc {
            qk: Int8,
            pv: Int8,
            qk_eff: 0.70,
            pv_eff: 0.70,
            softmax_visibility: 1.0,
            extra_vec_flops: 4.0,
            qk_bytes: 1.0,
            vo_bytes: 1.0,
            materializes: true,
            mat_bytes: 1.0, // S/P stored INT8
            unfused_quant_passes: 1.0,
            smooth_k: true,
            n_half: 256.0,
        },
        Xformers => KernelDesc {
            qk: Fp16Fp32Acc,
            pv: Fp16Fp32Acc,
            qk_eff: 0.78,
            pv_eff: 0.78,
            softmax_visibility: 0.45,
            extra_vec_flops: 0.0,
            qk_bytes: 2.0,
            vo_bytes: 2.0,
            materializes: false,
            mat_bytes: 0.0,
            unfused_quant_passes: 0.0,
            smooth_k: false,
            n_half: 700.0,
        },
        FlashAttention2 => KernelDesc {
            qk: Fp16Fp32Acc,
            pv: Fp16Fp32Acc,
            qk_eff: FA2_EFF,
            pv_eff: FA2_EFF,
            softmax_visibility: 0.06,
            extra_vec_flops: 0.0,
            qk_bytes: 2.0,
            vo_bytes: 2.0,
            materializes: false,
            mat_bytes: 0.0,
            unfused_quant_passes: 0.0,
            smooth_k: false,
            n_half: 500.0,
        },
        FlashAttention3Fp8 => KernelDesc {
            qk: Fp8,
            pv: Fp8,
            qk_eff: 0.90,
            pv_eff: 0.90,
            softmax_visibility: 0.08,
            extra_vec_flops: 2.0,
            qk_bytes: 1.0,
            vo_bytes: 1.0,
            materializes: false,
            mat_bytes: 0.0,
            unfused_quant_passes: 0.0,
            smooth_k: false,
            n_half: 600.0,
        },
        SageAttnT | SageAttnB | SageAttnBNoSmooth | SageAttnTUnfused => KernelDesc {
            qk: Int8,
            pv: Fp16Fp16Acc,
            // per-token scales need a dequant multiply per S *row element*
            // from a strided vector (vs one broadcast scalar per block):
            // the paper measures SageAttn-T ≈ 11% under -B (Table 11:
            // 292.17 vs ~327 TOPS)
            qk_eff: if matches!(kernel, SageAttnT | SageAttnTUnfused) {
                SAGE_EFF * 0.89
            } else {
                SAGE_EFF
            },
            pv_eff: if matches!(kernel, SageAttnT | SageAttnTUnfused) {
                SAGE_EFF * 0.89
            } else {
                SAGE_EFF
            },
            // per-token scales cost marginally more dequant work than
            // per-block; the difference is within noise for the model
            softmax_visibility: 0.10,
            extra_vec_flops: 2.0, // S-tile dequant multiply-adds
            qk_bytes: 1.0,
            vo_bytes: 2.0,
            materializes: false,
            mat_bytes: 0.0,
            unfused_quant_passes: if kernel == SageAttnTUnfused { 1.0 } else { 0.0 },
            smooth_k: kernel != SageAttnBNoSmooth,
            n_half: 620.0,
        },
        SageAttnVT | SageAttnVB => KernelDesc {
            qk: Int8,
            pv: Int8,
            qk_eff: if kernel == SageAttnVT { SAGE_EFF * 0.89 } else { SAGE_EFF },
            // INT8 PV sustains well under the 2× ideal: P̃ must be
            // quantized in-register every tile and the per-channel V scales
            // dequantized in the epilogue — calibrated to the paper's
            // "about 4% faster than SageAttn-B" (§4.5)
            pv_eff: 0.47,
            softmax_visibility: 0.10,
            extra_vec_flops: 4.0, // + P̃ quantization
            qk_bytes: 1.0,
            vo_bytes: 1.0,
            materializes: false,
            mat_bytes: 0.0,
            unfused_quant_passes: 0.0,
            smooth_k: true,
            n_half: 620.0,
        },
    }
}

/// Cost prediction with its components (seconds).
#[derive(Clone, Copy, Debug)]
pub struct CostBreakdown {
    pub mma_s: f64,
    pub softmax_s: f64,
    pub dram_s: f64,
    pub launch_s: f64,
    pub total_s: f64,
    /// bytes of HBM the kernel must hold live (S/P materialization)
    pub workspace_bytes: f64,
    pub oom: bool,
}

/// Predict the cost of one attention call.
pub fn predict(dev: &DeviceSpec, kernel: AttnKernel, wp: Workpoint) -> CostBreakdown {
    let k = desc(kernel);
    let bh = (wp.batch * wp.heads) as f64;
    let causal_frac = if wp.causal { 0.5 } else { 1.0 };
    let s_elems = bh * wp.n_q as f64 * wp.n_kv as f64 * causal_frac;
    let matmul_ops = 2.0 * s_elems * wp.head_dim as f64; // per matmul

    // --- mma pipe time, with the short-sequence ramp ---
    let ramp = wp.n_kv as f64 / (wp.n_kv as f64 + k.n_half);
    let qk_rate = k.qk.rate(dev) * 1e12 * k.qk_eff * ramp;
    let pv_rate = k.pv.rate(dev) * 1e12 * k.pv_eff * ramp;
    let mma_s = matmul_ops / qk_rate + matmul_ops / pv_rate;

    // --- softmax / vector work (8 flops per S element: max, sub, exp,
    // add, rescale ×2, plus bookkeeping) + quant epilogues ---
    let vec_flops = s_elems * (8.0 + k.extra_vec_flops);
    let softmax_s = vec_flops / (dev.fp32_vector_tflops * 1e12) * k.softmax_visibility;

    // --- DRAM traffic ---
    let qk_elems = bh * (wp.n_q + wp.n_kv) as f64 * wp.head_dim as f64;
    let vo_elems = bh * (wp.n_kv + wp.n_q) as f64 * wp.head_dim as f64;
    let mut bytes = qk_elems * k.qk_bytes + vo_elems * k.vo_bytes;
    // per-token fp32 scales for the quantized kernels (negligible, counted)
    if matches!(k.qk, MmaMode::Int8 | MmaMode::Fp8) {
        bytes += bh * (wp.n_q + wp.n_kv) as f64 * 4.0;
    }
    // unfused quantization: extra read (fp16) + write (int8) of Q and K
    bytes += k.unfused_quant_passes * bh * (wp.n_q + wp.n_kv) as f64 * wp.head_dim as f64 * 3.0;
    // smooth-K: the token mean is computed inside the fused RoPE+quant
    // kernel while K is already in registers, so only the cross-CTA
    // reduction + broadcast-subtract remain (~¼ of a streaming K pass) —
    // additive, since it serializes before quantization
    let smooth_s = if k.smooth_k {
        0.25 * bh * wp.n_kv as f64 * wp.head_dim as f64 * k.qk_bytes
            / (dev.dram_gbps * 1e9)
    } else {
        0.0
    };
    let mut workspace = 0.0;
    if k.materializes {
        // S write+read and P write+read (naive kernels)
        let s_bytes = bh * wp.n_q as f64 * wp.n_kv as f64 * k.mat_bytes;
        bytes += 4.0 * s_bytes;
        // live capacity: the softmax path holds S and P at ≥ fp16 even
        // when the matmul traffic is int8 (Table 16: both variants OOM)
        workspace = 2.0 * bh * wp.n_q as f64 * wp.n_kv as f64 * k.mat_bytes.max(2.0);
    }
    let dram_s = bytes / (dev.dram_gbps * 1e9);

    // --- occupancy: fewer CTAs than SMs can't fill the device ---
    let ctas = bh * (wp.n_q as f64 / 128.0).ceil();
    let occupancy = (ctas / dev.sms as f64).min(1.0).max(0.05);
    let mma_s = mma_s / occupancy;

    let launch_s = dev.launch_us * 1e-6;
    let compute_s = mma_s + softmax_s;
    let total_s = launch_s + compute_s.max(dram_s) + smooth_s;
    let oom = workspace > 0.8 * dev.mem_gib * (1u64 << 30) as f64;

    CostBreakdown { mma_s, softmax_s, dram_s, launch_s, total_s, workspace_bytes: workspace, oom }
}

impl AttnKernel {
    pub fn name(self) -> &'static str {
        use AttnKernel::*;
        match self {
            TorchNaive => "Torch",
            SageTorchBased => "Sage(Torch-based)",
            Xformers => "xformers",
            FlashAttention2 => "FlashAttn2",
            FlashAttention3Fp8 => "FlashAttn3-FP8",
            SageAttnT => "SageAttn-T",
            SageAttnB => "SageAttn-B",
            SageAttnVT => "SageAttn-vT",
            SageAttnVB => "SageAttn-vB",
            SageAttnBNoSmooth => "SageAttn-B(no smooth)",
            SageAttnTUnfused => "SageAttn-T(unfused quant)",
        }
    }

    pub fn by_name(name: &str) -> Option<AttnKernel> {
        use AttnKernel::*;
        Some(match name {
            "Torch" => TorchNaive,
            "Sage(Torch-based)" => SageTorchBased,
            "xformers" => Xformers,
            "FlashAttn2" => FlashAttention2,
            "FlashAttn3-FP8" => FlashAttention3Fp8,
            "SageAttn-T" => SageAttnT,
            "SageAttn-B" => SageAttnB,
            "SageAttn-vT" => SageAttnVT,
            "SageAttn-vB" => SageAttnVB,
            _ => return None,
        })
    }
}
