//! Scenario tests for the `AttnSpec`/`PreparedKV` surface: the behaviors
//! the unified API adds over the legacy `attention()` free function —
//! GQA head grouping, sliding windows, the BNHD layout, softmax-scale
//! overrides, and quantize-once decode state. Each test pins an exact
//! equivalence (bitwise where the math guarantees it) rather than a
//! loose cosine bound.

use sageattention::attn::{AttnSpec, Layout, BLOCK_KV};
use sageattention::metrics::cos_sim;
use sageattention::synth::{make_qkv, Profile};
use sageattention::tensor::Tensor;

/// GQA must equal MHA with the KV heads explicitly repeated: query head
/// `hi` reads KV head `hi / (h / h_kv)`, which is exactly what repeating
/// each KV head `h / h_kv` times produces — same plane slices, same
/// arithmetic, bit-identical output.
#[test]
fn gqa_equals_mha_with_repeated_kv_heads() {
    let (b, h, h_kv, n, d) = (2usize, 4usize, 2usize, 96usize, 32usize);
    let (q, _, _) = make_qkv(1, [b, h, n, d], Profile::llama_like());
    let (_, k, v) = make_qkv(2, [b, h_kv, n, d], Profile::llama_like());

    // repeat each KV head group times → an MHA-shaped K/V
    let group = h / h_kv;
    let repeat = |t: &Tensor| {
        let mut out = Tensor::zeros(&[b, h, n, d]);
        for bi in 0..b {
            for hi in 0..h {
                out.head_mut(bi, hi).copy_from_slice(t.head(bi, hi / group));
            }
        }
        out
    };
    let k_rep = repeat(&k);
    let v_rep = repeat(&v);

    for name in ["SageAttn-B", "SageAttn-vT", "online", "fa3-fp8"] {
        let spec = AttnSpec::by_name(name).unwrap().causal(true);
        let gqa = spec.kv_heads(h_kv).run(&q, &k, &v).unwrap();
        let mha = spec.run(&q, &k_rep, &v_rep).unwrap();
        assert_eq!(gqa.data, mha.data, "{name}");
        assert_eq!(gqa.shape, vec![b, h, n, d]);
    }
}

/// A sliding window at least as wide as the KV sequence must be
/// bit-identical to plain causal attention (every query's window already
/// covers all its attendable keys).
#[test]
fn window_covering_sequence_equals_full_attention() {
    let (q, k, v) = make_qkv(3, [1, 2, 150, 64], Profile::diffusion_like());
    for name in ["SageAttn-B", "SageAttn-vB", "exact"] {
        let spec = AttnSpec::by_name(name).unwrap().causal(true);
        let full = spec.run(&q, &k, &v).unwrap();
        let windowed = spec.window(150).run(&q, &k, &v).unwrap();
        assert_eq!(full.data, windowed.data, "{name}");
        // a narrow window genuinely changes the result
        let narrow = spec.window(8).run(&q, &k, &v).unwrap();
        assert_ne!(full.data, narrow.data, "{name} window had no effect");
        assert!(narrow.data.iter().all(|x| x.is_finite()));
    }
}

/// Running in BNHD layout must equal transposing, running in BHND, and
/// transposing back — bit-identical, since the layout only changes how
/// planes are gathered.
#[test]
fn bnhd_layout_round_trips_against_bhnd() {
    let (b, h, n, d) = (2usize, 3usize, 70usize, 16usize);
    let (q, k, v) = make_qkv(4, [b, h, n, d], Profile::vit_like());
    // permute (B,H,N,d) → (B,N,H,d)
    let to_bnhd = |t: &Tensor| {
        let mut out = Tensor::zeros(&[b, n, h, d]);
        for bi in 0..b {
            for hi in 0..h {
                for ni in 0..n {
                    let src = &t.head(bi, hi)[ni * d..(ni + 1) * d];
                    let dst = ((bi * n + ni) * h + hi) * d;
                    out.data[dst..dst + d].copy_from_slice(src);
                }
            }
        }
        out
    };
    let (qb, kb, vb) = (to_bnhd(&q), to_bnhd(&k), to_bnhd(&v));
    for name in ["SageAttn-T", "SageAttn-vB", "exact"] {
        let bhnd = AttnSpec::by_name(name).unwrap().causal(true).run(&q, &k, &v).unwrap();
        let bnhd = AttnSpec::by_name(name)
            .unwrap()
            .causal(true)
            .layout(Layout::BNHD)
            .run(&qb, &kb, &vb)
            .unwrap();
        assert_eq!(bnhd.shape, vec![b, n, h, d], "{name}");
        assert_eq!(to_bnhd(&bhnd).data, bnhd.data, "{name}");
    }
}

/// Incremental `PreparedKV::extend` must be bit-identical to one-shot
/// preparation — state and outputs — across anchor/scale-group/V-block
/// boundaries, for every prepared-capable kernel family.
#[test]
fn prepared_incremental_extend_is_bit_identical_to_oneshot() {
    let (b, h, n, d) = (1usize, 2usize, 200usize, 32usize);
    let (q, k, v) = make_qkv(5, [b, h, n, d], Profile::diffusion_like());
    let n0 = 70; // not a multiple of BLOCK_KV (64) or BLOCK_Q (128)
    assert_ne!(n0 % BLOCK_KV, 0);
    for name in ["SageAttn-T", "SageAttn-B", "SageAttn-vT", "SageAttn-vB", "exact"] {
        let spec = AttnSpec::by_name(name).unwrap().causal(true);
        let oneshot = spec.prepare(&k, &v).unwrap();
        // prefix + per-token growth (the decode pattern)
        let mut inc = spec.prepare(&k.narrow_n(0, n0), &v.narrow_n(0, n0)).unwrap();
        for t in n0..n {
            inc.extend(&k.narrow_n(t, t + 1), &v.narrow_n(t, t + 1)).unwrap();
        }
        assert_eq!(oneshot, inc, "{name}: incremental state diverged");
        // and from an empty prefix, in irregular chunks
        let mut chunked = spec.prepare(&k.narrow_n(0, 0), &v.narrow_n(0, 0)).unwrap();
        let mut t = 0;
        for step in [1usize, 63, 64, 65, 7].iter().cycle() {
            if t >= n {
                break;
            }
            let e = (t + step).min(n);
            chunked.extend(&k.narrow_n(t, e), &v.narrow_n(t, e)).unwrap();
            t = e;
        }
        assert_eq!(oneshot, chunked, "{name}: chunked state diverged");
        // identical state ⇒ identical outputs, for full and 1-row queries
        let a = spec.run_prepared(&q, &oneshot).unwrap();
        let bb = spec.run_prepared(&q, &inc).unwrap();
        assert_eq!(a.data, bb.data, "{name}");
    }
}

/// The prepared path must stay accurate (its smooth-K mean is anchored to
/// the first KV block, which softmax invariance makes a pure quant-error
/// tradeoff) and agree closely with the one-shot kernel.
#[test]
fn prepared_tracks_unprepared_and_exact() {
    let (q, k, v) = make_qkv(6, [1, 2, 256, 64], Profile::diffusion_like());
    let gold = AttnSpec::exact().run(&q, &k, &v).unwrap();
    for (name, min_cos) in [("SageAttn-B", 0.999), ("SageAttn-vB", 0.99)] {
        let spec = AttnSpec::by_name(name).unwrap();
        let kv = spec.prepare(&k, &v).unwrap();
        let prepared = spec.run_prepared(&q, &kv).unwrap();
        let unprepared = spec.run(&q, &k, &v).unwrap();
        let c_gold = cos_sim(&gold.data, &prepared.data);
        let c_pair = cos_sim(&unprepared.data, &prepared.data);
        assert!(c_gold > min_cos, "{name} vs exact: {c_gold}");
        assert!(c_pair > 0.999, "{name} prepared vs one-shot: {c_pair}");
    }
}

/// PreparedKV decode with GQA + sliding window composes: repeated query
/// batches against one prepared prefix, grouped KV heads, causal window.
#[test]
fn prepared_decode_composes_with_gqa_and_window() {
    let (b, h, h_kv, n, d) = (1usize, 4usize, 2usize, 160usize, 32usize);
    let (q, _, _) = make_qkv(7, [b, h, n, d], Profile::llama_like());
    let (_, k, v) = make_qkv(8, [b, h_kv, n, d], Profile::llama_like());
    let spec = AttnSpec::sage_b().causal(true).window(96).kv_heads(h_kv);
    let mut kv = spec.prepare(&k.narrow_n(0, n - 4), &v.narrow_n(0, n - 4)).unwrap();
    for t in (n - 4)..n {
        kv.extend(&k.narrow_n(t, t + 1), &v.narrow_n(t, t + 1)).unwrap();
        let step = spec.run_prepared(&q.narrow_n(t, t + 1), &kv).unwrap();
        assert_eq!(step.shape, vec![b, h, 1, d]);
        assert!(step.data.iter().all(|x| x.is_finite()));
    }
    // whole-batch query against the full prepared state matches the
    // bit-identical one-shot preparation
    let oneshot = spec.prepare(&k, &v).unwrap();
    assert_eq!(kv, oneshot);
    let a = spec.run_prepared(&q, &kv).unwrap();
    let bb = spec.run_prepared(&q, &oneshot).unwrap();
    assert_eq!(a.data, bb.data);
}

/// sm_scale override: the default is 1/√d, and an explicit equal value
/// is bit-identical; a different value changes the result.
#[test]
fn sm_scale_override_default_identity() {
    let (q, k, v) = make_qkv(9, [1, 1, 64, 16], Profile::llama_like());
    let spec = AttnSpec::sage_t();
    let default = spec.run(&q, &k, &v).unwrap();
    let explicit = spec.sm_scale(1.0 / (16f32).sqrt()).run(&q, &k, &v).unwrap();
    assert_eq!(default.data, explicit.data);
    let sharper = spec.sm_scale(0.5).run(&q, &k, &v).unwrap();
    assert_ne!(default.data, sharper.data);
}
